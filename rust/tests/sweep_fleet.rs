//! The lease-based multi-process sweep fabric, exercised in-process:
//! several `run_sweep_fleet` workers (threads here, separate processes
//! in CI) share one manifest through the lease ledger alone.
//!
//! Contracts under test:
//!
//! * a fleet — at any worker count, under any chaos kill/reclaim
//!   pattern — compacts to a manifest *byte-identical* to a
//!   single-process `run_sweep`'s;
//! * a leased run is never double-executed: claims are confirmed by
//!   fencing token, commits re-check the token, and a zombie's late
//!   commit is rejected and logged (never merged);
//! * a chaos-killed worker's lease expires, another worker reclaims it,
//!   and the run *resumes* from its step-level snapshots
//!   (`resumed_from_step` telemetry);
//! * racing manifest appends — with injected transient I/O faults —
//!   never interleave bytes within a line;
//! * clock skew up to a full TTL in either direction never gets a live
//!   holder reclaimed: expiry decisions are margin-padded and a reclaim
//!   needs [`lease::confirm_expired`]'s logical proof of death;
//! * ledger rotation — racing live appenders or firing mid-sweep —
//!   preserves fencing-token monotonicity and replay equivalence while
//!   bounding the file to one line per run;
//! * tail work-stealing produces byte-identical manifests: a stolen
//!   probe shard changes *where* half a θ±εz evaluation runs, never a
//!   single committed bit.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};

use addax::config::Config;
use addax::jsonlite::{obj, Json};
use addax::metrics::Curve;
use addax::sched::lease;
use addax::sched::manifest::Outcome;
use addax::sched::{
    fleet_commit, leases_path, run_sweep, run_sweep_fleet, ChaosPlan, FleetExit, FleetOptions,
    LeaseAction, LeaseRecord, LeaseTable, ManifestRow, RunSpec, SweepManifest, SweepOptions,
    SweepSpec,
};

/// Small but representative grid: a FO method, a ZO-only method (runs
/// `zo_mult ×` steps), and zero-shot (steps = 0 — never crashes, never
/// snapshots), across two seeds.
const SPEC: &str = r#"
[sweep]
name = "fleet-test"
backend = "mock"
steps = 12
zo_mult = 2
eval_examples = 24
mock_dim = 32
train = 120
val = 48
test = 48
lease_ttl_secs = 0.2

[grid]
optimizers = "addax, mezo, zero-shot"
tasks = "sst2"
seeds = "0, 1"
"#;

fn specs() -> Vec<RunSpec> {
    let cfg = Config::parse(SPEC).unwrap();
    SweepSpec::from_config(&cfg).unwrap().expand().unwrap()
}

fn fresh_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("addax_fleet_test_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn opts(dir: &Path) -> SweepOptions {
    SweepOptions {
        budget_gb: 100.0,
        gpus: 1,
        workers: 1,
        resume: true,
        manifest_path: dir.join("manifest.jsonl"),
        verbose: false,
        ckpt: true,
        ..SweepOptions::default()
    }
}

fn fleet(worker_id: &str, ttl_ms: u64, chaos: Option<ChaosPlan>) -> FleetOptions {
    let mut f = FleetOptions::new(worker_id, ttl_ms);
    f.chaos = chaos;
    f
}

/// The byte-identity control: the same grid through the classic
/// single-process path.
fn control_manifest_for(tag: &str, grid: Vec<RunSpec>) -> String {
    let dir = fresh_dir(tag);
    let o = opts(&dir);
    run_sweep(grid, &o).unwrap();
    let bytes = std::fs::read_to_string(&o.manifest_path).unwrap();
    std::fs::remove_dir_all(&dir).ok();
    bytes
}


#[test]
fn single_worker_fleet_matches_classic_sweep_byte_for_byte() {
    let dir = fresh_dir("single");
    let o = opts(&dir);
    let exit = run_sweep_fleet(specs(), &o, &fleet("w0", 500, None)).unwrap();
    assert!(exit.crashed.is_none());
    assert_eq!(exit.summary.total, 6);
    assert_eq!(exit.summary.executed, 6);
    assert_eq!(exit.summary.reclaimed, 0);
    assert_eq!(exit.summary.fenced, 0);
    let line = exit.summary.line();
    assert!(line.contains("reclaimed=0"), "{line}");
    assert!(line.contains("fenced=0"), "{line}");
    let fleet_bytes = std::fs::read_to_string(&o.manifest_path).unwrap();
    assert_eq!(
        fleet_bytes,
        control_manifest_for("control_single", specs()),
        "fleet must compact to the classic bytes"
    );
    // compaction strips every lease stamp from the durable file
    assert!(!fleet_bytes.contains("\"lease\""), "stamps must not survive compaction");
    // the lease ledger is kept (it is the fleet's audit trail)
    let ledger = std::fs::read_to_string(leases_path(&o.manifest_path)).unwrap();
    assert_eq!(ledger.matches("\"action\":\"claim\"").count(), 6);
    assert_eq!(ledger.matches("\"action\":\"release\"").count(), 6);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn three_workers_execute_each_run_once_and_match_control() {
    let dir = fresh_dir("trio");
    let o = opts(&dir);
    let exits: Vec<FleetExit> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..3)
            .map(|i| {
                let o = o.clone();
                s.spawn(move || {
                    run_sweep_fleet(specs(), &o, &fleet(&format!("w{i}"), 500, None)).unwrap()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    // Every run executed exactly once *fleet-wide*: per-worker executed
    // counts sum to the grid size (claims serialize via the ledger).
    let executed: usize = exits.iter().map(|e| e.summary.executed).sum();
    assert_eq!(executed, 6, "each run must be executed exactly once across the fleet");
    assert!(exits.iter().all(|e| e.crashed.is_none()));
    assert!(exits.iter().all(|e| e.summary.fenced == 0));
    let fleet_bytes = std::fs::read_to_string(&o.manifest_path).unwrap();
    assert_eq!(
        fleet_bytes,
        control_manifest_for("control_trio", specs()),
        "3-worker fleet must match the control bytes"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn chaos_kill_is_reclaimed_resumed_and_byte_identical() {
    // Pick a seed with guaranteed kill coverage over this grid instead
    // of hoping (zero-shot runs can never crash).
    let grid = specs();
    let seed = (1..200u64)
        .find(|&s| {
            ChaosPlan::new(s).crashes_any(grid.iter().map(|r| (r.run_id.as_str(), r.steps)))
        })
        .expect("some seed under 200 must crash this grid");
    let plan = ChaosPlan::new(seed);

    let dir = fresh_dir("chaos");
    let o = opts(&dir);
    // Each thread is one CI worker process with its restart loop: rerun
    // on a chaos crash (exit 96 at the CLI), stop on a clean exit.
    let exits: Vec<FleetExit> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..3)
            .map(|i| {
                let o = o.clone();
                let grid = grid.clone();
                s.spawn(move || {
                    let mut all = Vec::new();
                    for attempt in 0.. {
                        let f = fleet(&format!("w{i}r{attempt}"), 200, Some(plan));
                        let exit = run_sweep_fleet(grid.clone(), &o, &f).unwrap();
                        let done = exit.crashed.is_none();
                        all.push(exit);
                        if done {
                            break;
                        }
                    }
                    all
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });

    let crashes: usize = exits.iter().filter(|e| e.crashed.is_some()).count();
    assert!(crashes >= 1, "the chosen chaos seed must have killed at least one worker");
    let reclaimed: usize = exits.iter().map(|e| e.summary.reclaimed).sum();
    assert!(reclaimed >= 1, "a killed worker's expired lease must be reclaimed");
    // Counted once fleet-wide despite crashes, restarts and reclaims.
    let executed: usize = exits.iter().map(|e| e.summary.executed).sum();
    assert_eq!(executed, 6, "kill/reclaim must not double-count executions");

    // The reclaimed run *resumed* from its snapshots and said so in the
    // telemetry side file; the reclaim itself is an event row there too.
    let times = std::fs::read_to_string(SweepManifest::times_path(&o.manifest_path)).unwrap();
    assert!(times.contains("\"event\":\"reclaim\""), "reclaim must be logged: {times}");
    assert!(times.contains("\"resumed_from_step\""), "reclaimed run must resume: {times}");
    // ... and never in the manifest: the kill pattern is byte-invisible.
    let fleet_bytes = std::fs::read_to_string(&o.manifest_path).unwrap();
    assert!(!fleet_bytes.contains("reclaim"));
    assert_eq!(
        fleet_bytes,
        control_manifest_for("control_chaos", specs()),
        "compacted manifest must be byte-identical under the kill/reclaim pattern"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn zombie_commit_is_fenced_rejected_and_logged_never_merged() {
    let dir = fresh_dir("zombie");
    let o = opts(&dir);
    let spec = specs().into_iter().find(|s| s.steps > 0).unwrap();
    let lease_path = leases_path(&o.manifest_path);

    // A zombie: claimed at token 1, then went silent past its TTL.
    let stale = |action| LeaseRecord {
        run_id: spec.run_id.clone(),
        worker: "zombie".to_string(),
        token: 1,
        seq: 0,
        action,
        expires_ms: lease::now_ms().saturating_sub(10_000),
        probe: None,
    };
    lease::append(&lease_path, &stale(LeaseAction::Claim)).unwrap();
    let table = LeaseTable::load(&lease_path).unwrap();
    assert!(
        table.claimable(&spec.run_id, lease::now_ms(), 500),
        "expired lease must be claimable even under a skew margin"
    );

    // A live worker reclaims at token 2 and commits.
    lease::append(
        &lease_path,
        &LeaseRecord {
            run_id: spec.run_id.clone(),
            worker: "fresh".to_string(),
            token: 2,
            seq: 0,
            action: LeaseAction::Reclaim,
            expires_ms: lease::now_ms() + 60_000,
            probe: None,
        },
    )
    .unwrap();
    let (row, timing) = addax::sched::execute_run(&spec).unwrap();
    let mut m = SweepManifest::load(&o.manifest_path).unwrap();
    assert!(fleet_commit(&mut m, "fresh", 2, row.clone(), &timing).unwrap());

    // The zombie wakes up and tries to commit its own (identical, by
    // determinism) row at the stale token: rejected, logged, not merged.
    let mut m = SweepManifest::load(&o.manifest_path).unwrap();
    assert_eq!(m.len(), 1);
    assert!(
        !fleet_commit(&mut m, "zombie", 1, row, &timing).unwrap(),
        "a stale-token commit must be fenced"
    );
    let raw = std::fs::read_to_string(&o.manifest_path).unwrap();
    assert_eq!(raw.lines().count(), 1, "the zombie must not have appended a row");
    let times = std::fs::read_to_string(SweepManifest::times_path(&o.manifest_path)).unwrap();
    assert!(times.contains("\"event\":\"fenced\""), "{times}");
    assert!(times.contains("fenced zombie append rejected"), "{times}");
    // the fresh worker's stamped row survives a reload intact
    let m = SweepManifest::load(&o.manifest_path).unwrap();
    assert_eq!(m.len(), 1);
    assert_eq!(m.fenced_rows, 0);
    std::fs::remove_dir_all(&dir).ok();
}

/// A synthetic (cheap) manifest row for the append-race property test.
fn synthetic_row(run_id: &str) -> ManifestRow {
    ManifestRow {
        run_id: run_id.to_string(),
        spec: obj(vec![("task", Json::from("sst2"))]),
        outcome: Outcome {
            kind: "train".to_string(),
            best_val_acc: 0.5,
            best_val_step: 4,
            test_acc: 0.5,
            test_f1: 0.5,
            final_train_loss: 0.25,
            steps: 8,
            loss_curve: Curve::default(),
            val_curve: Curve::default(),
        },
    }
}

#[test]
fn racing_appends_with_injected_faults_never_tear_a_line() {
    // Satellite property: N in-process workers hammering one manifest
    // (each append riding the retry path, with deterministic transient
    // faults injected every 3rd append) produce a file where *every*
    // line parses and *every* row survives — no interleaved bytes, no
    // lost appends, no corrupt lines.
    const WORKERS: usize = 8;
    const PER_WORKER: usize = 40;
    let dir = fresh_dir("race");
    let path = dir.join("manifest.jsonl");
    let barrier = std::sync::Barrier::new(WORKERS);
    std::thread::scope(|s| {
        for w in 0..WORKERS {
            let path = path.clone();
            let barrier = &barrier;
            s.spawn(move || {
                let mut m = SweepManifest::load(&path).unwrap();
                barrier.wait();
                for i in 0..PER_WORKER {
                    if i % 3 == 0 {
                        addax::ioutil::inject_transient_faults(2);
                    }
                    let row = synthetic_row(&format!("run-w{w}-{i:03}"));
                    // Half the fleet appends stamped (the fleet path),
                    // half classic — both must hold the line invariant.
                    if w % 2 == 0 {
                        m.append_stamped(row, 1, &format!("w{w}")).unwrap();
                    } else {
                        m.append(row).unwrap();
                    }
                }
            });
        }
    });
    let raw = std::fs::read_to_string(&path).unwrap();
    assert_eq!(raw.lines().count(), WORKERS * PER_WORKER);
    for line in raw.lines() {
        let v = Json::parse(line).unwrap_or_else(|e| panic!("torn line {line:?}: {e}"));
        ManifestRow::from_json(&v).expect("every line must round-trip");
    }
    let m = SweepManifest::load(&path).unwrap();
    assert_eq!(m.len(), WORKERS * PER_WORKER);
    assert_eq!(m.corrupt_lines, 0);
    assert_eq!(m.fenced_rows, 0);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn racing_claims_grant_exactly_one_winner_per_run() {
    // The no-double-execution half of the property: many workers race
    // to claim the same runs; per run, exactly one confirmed winner per
    // token generation (equal tokens — first appender wins).
    const WORKERS: usize = 8;
    const RUNS: usize = 10;
    let dir = fresh_dir("claims");
    let path = dir.join("manifest.leases.jsonl");
    let wins = AtomicUsize::new(0);
    let barrier = std::sync::Barrier::new(WORKERS);
    std::thread::scope(|s| {
        for w in 0..WORKERS {
            let path = path.clone();
            let (wins, barrier) = (&wins, &barrier);
            s.spawn(move || {
                let me = format!("w{w}");
                barrier.wait();
                for r in 0..RUNS {
                    let run_id = format!("run-{r:02}");
                    lease::append(
                        &path,
                        &LeaseRecord {
                            run_id: run_id.clone(),
                            worker: me.clone(),
                            token: 1,
                            seq: 0,
                            action: LeaseAction::Claim,
                            expires_ms: lease::now_ms() + 60_000,
                            probe: None,
                        },
                    )
                    .unwrap();
                    let t = LeaseTable::load(&path).unwrap();
                    if t.holder(&run_id) == Some((me.as_str(), 1)) {
                        wins.fetch_add(1, Ordering::Relaxed);
                    }
                }
            });
        }
    });
    assert_eq!(
        wins.load(Ordering::Relaxed),
        RUNS,
        "every run must be granted to exactly one of the {WORKERS} racing claimants"
    );
    // and the ledger itself is intact: all claims landed, all parse
    let t = LeaseTable::load(&path).unwrap();
    assert_eq!(t.corrupt_lines, 0);
    let raw = std::fs::read_to_string(&path).unwrap();
    assert_eq!(raw.lines().count(), WORKERS * RUNS);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn skewed_clocks_never_reclaim_a_live_holder_and_match_control() {
    // Three workers whose lease clocks disagree by a full TTL in each
    // direction — the worst offset the chaos model injects — and a skew
    // margin deliberately SMALLER than the skew, so the margin alone
    // cannot save us: the logical quiet-holder confirmation must.
    let dir = fresh_dir("skew");
    let o = opts(&dir);
    let exits: Vec<FleetExit> = std::thread::scope(|s| {
        let handles: Vec<_> = [-500i64, 0, 500]
            .into_iter()
            .enumerate()
            .map(|(i, off)| {
                let o = o.clone();
                s.spawn(move || {
                    let mut f = fleet(&format!("w{i}"), 500, None);
                    f.clock_offset_ms = Some(off);
                    f.skew_margin_ms = 100;
                    run_sweep_fleet(specs(), &o, &f).unwrap()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let executed: usize = exits.iter().map(|e| e.summary.executed).sum();
    assert_eq!(executed, 6, "each run must still execute exactly once under skew");
    let reclaimed: usize = exits.iter().map(|e| e.summary.reclaimed).sum();
    assert_eq!(reclaimed, 0, "a live holder must never be reclaimed under ±TTL skew");
    assert!(exits.iter().all(|e| e.summary.fenced == 0));
    let times = std::fs::read_to_string(SweepManifest::times_path(&o.manifest_path)).unwrap();
    assert!(!times.contains("\"event\":\"reclaim\""), "no reclaim event allowed: {times}");
    let fleet_bytes = std::fs::read_to_string(&o.manifest_path).unwrap();
    assert_eq!(
        fleet_bytes,
        control_manifest_for("control_skew", specs()),
        "skewed fleet must match the control bytes"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn rotation_under_racing_appenders_keeps_tokens_monotonic() {
    // Satellite property: appenders running the real claim-confirm /
    // release-confirm protocol against a rotator thread that fires at
    // every opportunity. Rotation may swallow an append in its rename
    // window — the protocol absorbs that by re-reading — but granted
    // fencing tokens must stay strictly monotonic per run, and the final
    // replay must be clean.
    const WORKERS: usize = 4;
    const ROUNDS: u64 = 10;
    let dir = fresh_dir("rotate_race");
    let path = dir.join("manifest.leases.jsonl");
    let done = std::sync::atomic::AtomicBool::new(false);
    std::thread::scope(|s| {
        let (path_r, done_r) = (path.clone(), &done);
        let rotator = s.spawn(move || {
            while !done_r.load(Ordering::Relaxed) {
                lease::rotate(&path_r, 1).unwrap();
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
        });
        let appenders: Vec<_> = (0..WORKERS)
            .map(|w| {
                let path = path.clone();
                s.spawn(move || {
                    let run_id = format!("run-{w}");
                    let me = format!("w{w}");
                    let mut last_granted = 0u64;
                    for _ in 0..ROUNDS {
                        // claim-confirm: append at max_token + 1, re-read;
                        // a rotation-swallowed claim fails confirmation
                        // and is retried at a recomputed token.
                        let granted = loop {
                            let t = LeaseTable::load(&path).unwrap();
                            let token = t.max_token(&run_id) + 1;
                            lease::append_durable(
                                &path,
                                &LeaseRecord {
                                    run_id: run_id.clone(),
                                    worker: me.clone(),
                                    token,
                                    seq: 0,
                                    action: LeaseAction::Claim,
                                    expires_ms: lease::now_ms() + 60_000,
                                    probe: None,
                                },
                            )
                            .unwrap();
                            let t = LeaseTable::load(&path).unwrap();
                            if t.holder(&run_id) == Some((me.as_str(), token)) {
                                break token;
                            }
                        };
                        // Monotonic, not strictly increasing: in the
                        // documented worst interleaving a rotation may
                        // swallow a just-confirmed claim, and the retried
                        // round is re-granted the SAME token (the
                        // duplicate-execution case the protocol absorbs).
                        // What rotation must never do is hand out a LOWER
                        // token — that would un-fence a zombie.
                        assert!(
                            granted >= last_granted,
                            "{run_id}: granted token {granted} after {last_granted} — \
                             rotation regressed the fencing floor"
                        );
                        last_granted = granted;
                        // release, then confirm it stuck (a swallow
                        // reverts to an all-released snapshot, so any
                        // released state ends the round).
                        loop {
                            lease::append_durable(
                                &path,
                                &LeaseRecord {
                                    run_id: run_id.clone(),
                                    worker: me.clone(),
                                    token: granted,
                                    seq: 0,
                                    action: LeaseAction::Release,
                                    expires_ms: lease::now_ms(),
                                    probe: None,
                                },
                            )
                            .unwrap();
                            let t = LeaseTable::load(&path).unwrap();
                            match t.state(&run_id) {
                                Some(st) if st.released => break,
                                // unreleased or vanished under a
                                // rotation: keep releasing
                                _ => {}
                            }
                        }
                    }
                })
            })
            .collect();
        for h in appenders {
            h.join().unwrap();
        }
        done.store(true, Ordering::Relaxed);
        rotator.join().unwrap();
    });
    // Quiesced replay-equivalence check: rotating the settled ledger must
    // not change its replayed table at all, and must leave the compact
    // one-line-per-run form (the racing rotator may already have).
    let before = LeaseTable::load(&path).unwrap();
    assert_eq!(before.corrupt_lines, 0, "racing rotation must never tear a line");
    assert!(before.all_released());
    lease::rotate(&path, 1).unwrap();
    let after = LeaseTable::load(&path).unwrap();
    for w in 0..WORKERS {
        let run_id = format!("run-{w}");
        let (b, a) = (before.state(&run_id).unwrap(), after.state(&run_id).unwrap());
        assert_eq!(b, a, "{run_id}: rotation changed the replayed state");
        assert!(a.released);
        assert!(a.token >= 1, "{run_id}: fencing token lost entirely");
    }
    let raw = std::fs::read_to_string(&path).unwrap();
    assert_eq!(raw.lines().count(), WORKERS, "compact ledger is one line per run:\n{raw}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn mid_sweep_rotation_bounds_the_ledger_and_stays_byte_identical() {
    // A single worker with an aggressive rotation threshold: the ledger
    // is GC'd repeatedly DURING the sweep (at all-released commit
    // points), and that must be invisible in the compacted manifest.
    let dir = fresh_dir("rotate_sweep");
    let o = opts(&dir);
    let mut f = fleet("w0", 500, None);
    f.rotate_after_lines = 4;
    let exit = run_sweep_fleet(specs(), &o, &f).unwrap();
    assert_eq!(exit.summary.executed, 6);
    assert_eq!(exit.summary.reclaimed, 0);
    let times = std::fs::read_to_string(SweepManifest::times_path(&o.manifest_path)).unwrap();
    assert!(times.contains("\"event\":\"rotate\""), "rotation must be logged: {times}");
    // The surviving ledger is the compact form: one release per run,
    // every fencing token intact.
    let ledger_path = leases_path(&o.manifest_path);
    let raw = std::fs::read_to_string(&ledger_path).unwrap();
    assert_eq!(raw.lines().count(), 6, "ledger must compact to one line per run:\n{raw}");
    assert_eq!(raw.matches("\"action\":\"release\"").count(), 6, "{raw}");
    let t = LeaseTable::load(&ledger_path).unwrap();
    assert!(t.all_released());
    for run in specs() {
        assert!(t.max_token(&run.run_id) >= 1, "{}: token lost in rotation", run.run_id);
    }
    let fleet_bytes = std::fs::read_to_string(&o.manifest_path).unwrap();
    assert_eq!(
        fleet_bytes,
        control_manifest_for("control_rotate", specs()),
        "mid-sweep rotation must not change a single manifest byte"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// A single long ZO run for the tail-steal test: one holder, one thief,
/// nothing else to claim.
const STEAL_SPEC: &str = r#"
[sweep]
name = "steal-test"
backend = "mock"
steps = 30
zo_mult = 2
eval_examples = 24
mock_dim = 32
train = 120
val = 48
test = 48
lease_ttl_secs = 2

[grid]
optimizers = "mezo"
tasks = "sst2"
seeds = "0"
"#;

fn steal_specs() -> Vec<RunSpec> {
    let cfg = Config::parse(STEAL_SPEC).unwrap();
    SweepSpec::from_config(&cfg).unwrap().expand().unwrap()
}

#[test]
fn tail_stealing_is_exercised_and_byte_identical() {
    let dir = fresh_dir("steal");
    let o = opts(&dir);
    let exits: Vec<FleetExit> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..2)
            .map(|i| {
                let o = o.clone();
                s.spawn(move || {
                    let mut f = fleet(&format!("w{i}"), 2_000, None);
                    // CI-determinism knob: the holder's first probe waits
                    // for a thief to advertise instead of racing one —
                    // mock steps are microseconds, natural timing would
                    // never demonstrate a steal.
                    f.steal_wait_ms = 4_000;
                    run_sweep_fleet(steal_specs(), &o, &f).unwrap()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let executed: usize = exits.iter().map(|e| e.summary.executed).sum();
    assert_eq!(executed, 1);
    let stolen: u64 = exits.iter().map(|e| e.summary.stolen).sum();
    assert!(stolen >= 1, "the idle worker must have served at least one probe shard");
    assert!(exits.iter().any(|e| e.summary.line().contains(&format!("stolen={stolen}"))));
    let times = std::fs::read_to_string(SweepManifest::times_path(&o.manifest_path)).unwrap();
    assert!(times.contains("\"event\":\"steal\""), "steal telemetry missing: {times}");
    // The steal side dir is cleaned up with the run.
    let steal_run_dir =
        o.manifest_path.parent().unwrap().join("steal").join(&steal_specs()[0].run_id);
    assert!(!steal_run_dir.exists(), "steal side dir must not outlive the run");
    // And none of it moved a byte: stolen shards are bit-identical.
    let fleet_bytes = std::fs::read_to_string(&o.manifest_path).unwrap();
    assert_eq!(
        fleet_bytes,
        control_manifest_for("control_steal", steal_specs()),
        "a stolen probe shard must not change a single manifest byte"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn fleet_mode_rejects_foot_guns() {
    let dir = fresh_dir("refuse");
    let base = opts(&dir);
    let f = fleet("w0", 500, None);
    let err = |o: &SweepOptions, f: &FleetOptions| {
        run_sweep_fleet(specs(), o, f).unwrap_err().to_string()
    };
    let no_ckpt = SweepOptions { ckpt: false, ..base.clone() };
    assert!(err(&no_ckpt, &f).contains("--no-ckpt"), "reclaim needs snapshots");
    let halted = SweepOptions { halt_after: 3, ..base.clone() };
    assert!(err(&halted, &f).contains("--chaos-seed"), "halt-after is not a fleet knob");
    let no_resume = SweepOptions { resume: false, ..base.clone() };
    assert!(err(&no_resume, &f).contains("--resume"));
    assert!(err(&base, &fleet("", 500, None)).contains("--worker-id"));
    assert!(err(&base, &fleet("w0", 5, None)).contains("--lease-ttl"));
    std::fs::remove_dir_all(&dir).ok();
}
