//! Scheduler determinism and resume semantics (mock backend — no
//! artifacts needed; this is exactly what the CI gate exercises).
//!
//! Contract under test: for a fixed spec, the compacted manifest is
//! byte-identical (1) at any worker count, (2) after a kill + resume
//! (including a torn trailing line), and (3) re-running skips everything
//! without touching a byte.

use std::path::PathBuf;

use addax::config::Config;
use addax::sched::{run_sweep, RunSpec, SweepManifest, SweepOptions, SweepSpec};

const SPEC: &str = r#"
[sweep]
name = "test"
backend = "mock"
steps = 12
zo_mult = 2
eval_examples = 24
mock_dim = 32
train = 120
val = 48
test = 48

[grid]
optimizers = "addax, mezo, ip-sgd, zero-shot"
tasks = "sst2, rte"
seeds = "0, 1"
dtypes = "f32, bf16"
"#;

fn specs() -> Vec<RunSpec> {
    let cfg = Config::parse(SPEC).unwrap();
    SweepSpec::from_config(&cfg).unwrap().expand().unwrap()
}

fn fresh_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("addax_sweep_test_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn opts(dir: &std::path::Path, workers: usize) -> SweepOptions {
    SweepOptions {
        // Covers the largest f32-priced cell (ip-sgd on rte ≈ 91 GB at
        // opt-13b pricing); bf16 cells are half that.
        budget_gb: 100.0,
        gpus: 1,
        workers,
        resume: true,
        manifest_path: dir.join("manifest.jsonl"),
        verbose: false,
        // The determinism tests below target manifest semantics; the
        // checkpoint path has its own halt/resume test + tests/ckpt_resume.rs.
        ckpt: false,
        ..SweepOptions::default()
    }
}

#[test]
fn manifest_is_bit_identical_across_worker_counts() {
    // 4 optimizers x 2 tasks x 2 seeds x 2 dtypes (seeds are identity:
    // they seed the dataset, so even zero-shot differs per seed; the
    // storage dtype is identity too — f32 and bf16 cells are distinct
    // runs, and the byte-identity proof below covers both precisions)
    let expected_runs = 32;
    let mut bytes: Vec<String> = Vec::new();
    for workers in [1usize, 4] {
        let dir = fresh_dir(&format!("workers{workers}"));
        let o = opts(&dir, workers);
        let summary = run_sweep(specs(), &o).unwrap();
        assert_eq!(summary.total, expected_runs);
        assert_eq!(summary.executed, expected_runs);
        assert_eq!(summary.skipped, 0);
        bytes.push(std::fs::read_to_string(&o.manifest_path).unwrap());
        std::fs::remove_dir_all(&dir).ok();
    }
    assert_eq!(
        bytes[0], bytes[1],
        "compacted manifest must not depend on the worker count"
    );
    // Both precisions are really in the file (dtype reaches the rows).
    assert_eq!(bytes[0].matches("\"dtype\":\"bf16\"").count(), 16);
    assert_eq!(bytes[0].matches("\"dtype\":\"f32\"").count(), 16);
}

#[test]
fn resume_after_kill_matches_uninterrupted_run() {
    // Reference: one uninterrupted sweep.
    let ref_dir = fresh_dir("ref");
    let ref_opts = opts(&ref_dir, 2);
    run_sweep(specs(), &ref_opts).unwrap();
    let reference = std::fs::read_to_string(&ref_opts.manifest_path).unwrap();

    // "Killed" sweep: a prefix of the reference rows plus a torn partial
    // line, exactly what a SIGKILL mid-append leaves behind.
    let kill_dir = fresh_dir("kill");
    let kill_opts = opts(&kill_dir, 3);
    let prefix: String = reference
        .lines()
        .take(5)
        .map(|l| format!("{l}\n"))
        .collect::<String>()
        + "{\"run_id\": \"torn-mid-app";
    std::fs::write(&kill_opts.manifest_path, prefix).unwrap();

    let summary = run_sweep(specs(), &kill_opts).unwrap();
    assert_eq!(summary.skipped, 5, "prefix rows must be skipped, torn line dropped");
    assert_eq!(summary.executed, summary.total - 5);
    let resumed = std::fs::read_to_string(&kill_opts.manifest_path).unwrap();
    assert_eq!(resumed, reference, "resume must converge to the uninterrupted bytes");
    std::fs::remove_dir_all(&ref_dir).ok();
    std::fs::remove_dir_all(&kill_dir).ok();
}

#[test]
fn halted_sweep_resumes_step_level_to_identical_bytes() {
    // Control: uninterrupted sweep with parameter dumps.
    let ctrl_dir = fresh_dir("halt_ctrl");
    let mut ctrl = opts(&ctrl_dir, 2);
    ctrl.dump_params = true;
    run_sweep(specs(), &ctrl).unwrap();
    let ctrl_manifest = std::fs::read_to_string(&ctrl.manifest_path).unwrap();

    // Preempted sweep: every training run halts after 5 steps (snapshot
    // written first); the zero-shot runs (steps = 0) complete normally.
    let kill_dir = fresh_dir("halt_kill");
    let mut o = opts(&kill_dir, 3);
    o.ckpt = true;
    o.dump_params = true;
    o.halt_after = 5;
    let first = run_sweep(specs(), &o).unwrap();
    assert_eq!(first.halted, 24, "all training runs must be preempted");
    assert_eq!(first.executed, 8, "zero-shot cells have no steps to halt");

    // Resume: every halted run continues from its step-5 snapshot.
    o.halt_after = 0;
    let second = run_sweep(specs(), &o).unwrap();
    assert_eq!(second.executed, 24);
    assert_eq!(second.skipped, 8);
    assert_eq!(second.halted, 0);

    // Byte-identical manifest vs the uninterrupted control.
    let resumed_manifest = std::fs::read_to_string(&o.manifest_path).unwrap();
    assert_eq!(resumed_manifest, ctrl_manifest, "step-level resume must not change a byte");
    // Step-level resume really happened, and only for the training runs.
    let times = std::fs::read_to_string(SweepManifest::times_path(&o.manifest_path)).unwrap();
    assert_eq!(times.matches("\"resumed_from_step\":5").count(), 24, "{times}");
    // Byte-identical final parameter dumps, both precisions included.
    let ctrl_params = ctrl_dir.join("params");
    let kill_params = kill_dir.join("params");
    let mut compared = 0usize;
    for entry in std::fs::read_dir(&ctrl_params).unwrap().flatten() {
        let name = entry.file_name();
        let a = std::fs::read(entry.path()).unwrap();
        let b = std::fs::read(kill_params.join(&name)).unwrap();
        assert_eq!(a, b, "param dump {name:?} must be byte-identical");
        compared += 1;
    }
    assert_eq!(compared, 32, "one dump per run");
    // Checkpoint dirs are cleaned up once rows are durable.
    let leftover = std::fs::read_dir(kill_dir.join("ckpt"))
        .map(|d| d.flatten().count())
        .unwrap_or(0);
    assert_eq!(leftover, 0, "completed runs must not leave checkpoints behind");
    std::fs::remove_dir_all(&ctrl_dir).ok();
    std::fs::remove_dir_all(&kill_dir).ok();
}

#[test]
fn rerun_skips_everything_and_changes_nothing() {
    let dir = fresh_dir("rerun");
    let o = opts(&dir, 4);
    let first = run_sweep(specs(), &o).unwrap();
    let before = std::fs::read_to_string(&o.manifest_path).unwrap();
    let second = run_sweep(specs(), &o).unwrap();
    assert_eq!(second.executed, 0);
    assert_eq!(second.skipped, first.total);
    let after = std::fs::read_to_string(&o.manifest_path).unwrap();
    assert_eq!(before, after);

    // A stale checkpoint dir left by a kill between row-append and
    // cleanup must be reclaimed by the next resume sweep that skips the
    // (completed) run.
    let stale = dir.join("ckpt").join(&specs()[0].run_id);
    std::fs::create_dir_all(&stale).unwrap();
    std::fs::write(stale.join("step-00000001.ck"), b"stale").unwrap();
    let mut with_ckpt = opts(&dir, 2);
    with_ckpt.ckpt = true;
    let third = run_sweep(specs(), &with_ckpt).unwrap();
    assert_eq!(third.executed, 0);
    assert!(!stale.exists(), "completed-run snapshots must be garbage-collected");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn halt_without_checkpointing_is_refused() {
    // halt-after with --no-ckpt could never make progress (each resume
    // restarts from 0 and halts at the same step) — must be rejected.
    let dir = fresh_dir("haltnockpt");
    let mut o = opts(&dir, 2); // opts() disables ckpt
    o.halt_after = 3;
    let err = run_sweep(specs(), &o).unwrap_err();
    assert!(format!("{err}").contains("checkpointing"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn without_resume_an_existing_manifest_is_refused() {
    let dir = fresh_dir("noresume");
    let mut o = opts(&dir, 2);
    run_sweep(specs(), &o).unwrap();
    o.resume = false;
    let err = run_sweep(specs(), &o).unwrap_err();
    assert!(format!("{err}").contains("--resume"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn oversized_run_reports_the_budget() {
    let dir = fresh_dir("oversize");
    let mut o = opts(&dir, 2);
    o.budget_gb = 1.0; // nothing at opt-13b pricing fits in 1 GB
    let err = run_sweep(specs(), &o).unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("budget"), "{msg}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn tables_aggregate_from_manifest_rows_alone() {
    // The inversion contract: after a sweep, every requested row is
    // reconstructible from the manifest file with no training state.
    let dir = fresh_dir("aggregate");
    let o = opts(&dir, 4);
    let all = specs();
    run_sweep(all.clone(), &o).unwrap();
    let manifest = SweepManifest::load(&o.manifest_path).unwrap();
    assert_eq!(manifest.len(), 32);
    for spec in &all {
        let row = manifest.get(&spec.run_id).expect("row present");
        assert_eq!(row.spec_str("task").unwrap(), spec.task);
        if spec.steps > 0 {
            assert_eq!(row.outcome.steps, spec.steps);
            assert_eq!(row.outcome.loss_curve.points.len(), spec.steps);
            assert!(row.outcome.final_train_loss.is_finite());
        } else {
            assert_eq!(row.outcome.kind, "eval");
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}
