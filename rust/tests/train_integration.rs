//! Integration: the full coordinator loop on live XLA artifacts.
//! Skips gracefully when `make artifacts` has not run.

use addax::coordinator::{evaluate, train, TrainConfig};
use addax::data::{opt_task, Dataset};
use addax::optim::{Addax, IpSgd, MeZo};
use addax::runtime::manifest::default_artifacts_dir;
use addax::runtime::XlaExec;

fn ready() -> bool {
    default_artifacts_dir().join("manifest.json").exists()
}

fn setup(model: &str) -> (XlaExec, Dataset) {
    let exec = XlaExec::new(&default_artifacts_dir(), model).unwrap();
    let entry = exec.entry().clone();
    let ds = Dataset::generate(
        opt_task("sst2").unwrap(),
        entry.vocab,
        Some(entry.max_len),
        0,
        400,
        100,
        100,
    );
    (exec, ds)
}

#[test]
fn addax_training_reduces_loss_on_tiny() {
    if !ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let (mut exec, ds) = setup("tiny");
    let mut params = exec.load_initial_params().unwrap();
    let mut opt = Addax::new(5e-2, 1e-3, 0.03, 4, 4);
    let cfg = TrainConfig { steps: 60, eval_every: 30, eval_examples: 50, ..Default::default() };
    let r = train(&mut exec, &mut params, &mut opt, &ds, usize::MAX, &cfg).unwrap();
    let first = r.loss_curve.points[0].1;
    assert!(
        r.final_train_loss < 0.7 * first,
        "loss {first} -> {} after 60 addax steps",
        r.final_train_loss
    );
    assert!(params.all_finite());
}

#[test]
fn mezo_training_runs_forward_only_on_tiny() {
    if !ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let (mut exec, ds) = setup("tiny");
    let mut params = exec.load_initial_params().unwrap();
    let mut opt = MeZo::new(1e-4, 1e-3, 8);
    let cfg = TrainConfig { steps: 20, eval_every: 20, eval_examples: 30, ..Default::default() };
    let r = train(&mut exec, &mut params, &mut opt, &ds, usize::MAX, &cfg).unwrap();
    use addax::runtime::ModelExec;
    assert_eq!(exec.stats().grad_calls, 0, "MeZO must never backprop");
    assert!(r.final_train_loss.is_finite());
}

#[test]
fn training_is_deterministic_across_runs() {
    if !ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let run = || {
        let (mut exec, ds) = setup("tiny");
        let mut params = exec.load_initial_params().unwrap();
        let mut opt = IpSgd::new(5e-2, 4);
        let cfg = TrainConfig {
            steps: 15,
            eval_every: 15,
            eval_examples: 30,
            seed: 9,
            ..Default::default()
        };
        let r = train(&mut exec, &mut params, &mut opt, &ds, usize::MAX, &cfg).unwrap();
        (r.final_train_loss, r.best_val_acc)
    };
    let a = run();
    let b = run();
    // XLA CPU executions are deterministic; the whole loop must be too.
    assert_eq!(a, b);
}

#[test]
fn length_partition_routes_long_examples_to_forward_only() {
    if !ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    // multirc scaled into tiny's buckets still has a long tail; with L_T
    // at the median, Addax must be able to train even if grads only exist
    // for small buckets. (tiny has grad artifacts for all buckets, so
    // here we just verify the partition path end-to-end.)
    let mut exec = XlaExec::new(&default_artifacts_dir(), "tiny").unwrap();
    let entry = exec.entry().clone();
    let ds = Dataset::generate(
        opt_task("multirc").unwrap(),
        entry.vocab,
        Some(entry.max_len),
        1,
        300,
        60,
        60,
    );
    let mut lens: Vec<usize> = ds.train.iter().map(|e| e.context.len() + 1).collect();
    lens.sort_unstable();
    let lt = lens[lens.len() / 2];
    let mut params = exec.load_initial_params().unwrap();
    let mut opt = Addax::new(3e-2, 1e-3, 0.05, 4, 4);
    let cfg = TrainConfig { steps: 25, eval_every: 25, eval_examples: 30, ..Default::default() };
    let r = train(&mut exec, &mut params, &mut opt, &ds, lt, &cfg).unwrap();
    assert!(r.final_train_loss.is_finite());
}

#[test]
fn evaluation_improves_with_training() {
    if !ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let (mut exec, ds) = setup("tiny");
    let mut params = exec.load_initial_params().unwrap();
    let before = evaluate(&mut exec, &params, &ds.test, 80).unwrap();
    let mut opt = IpSgd::new(7e-2, 8);
    let cfg = TrainConfig { steps: 250, eval_every: 50, eval_examples: 60, ..Default::default() };
    let r = train(&mut exec, &mut params, &mut opt, &ds, usize::MAX, &cfg).unwrap();
    assert!(
        r.best_val_acc > before.accuracy + 0.1,
        "training should beat zero-shot: {} -> {}",
        before.accuracy,
        r.best_val_acc
    );
}
