//! Property-based tests (hand-rolled generators; the offline crate set has
//! no proptest). Each property is exercised over many random seeds drawn
//! from a deterministic PRNG, covering the coordinator-level invariants:
//! routing/partitioning, batching, seed-replay state management, and the
//! memory model's structure.

use addax::data::{generate, opt_task, partition, training_batch, Example, OPT_TASKS};
use addax::jsonlite::Json;
use addax::memory::{footprint, geometry, Method, Workload};
use addax::optim::{spsa_g0, z_dot_grads, Addax, IpSgd, MeZo, Optimizer, StepBatches};
use addax::params::ParamStore;
use addax::runtime::mock::QuadraticExec;
use addax::runtime::{ModelExec, TokenBatch};
use addax::tensor::Dtype;
use addax::zorng::{Xoshiro256, NOISE_BLOCK};

const CASES: usize = 60;

/// The paper's fp16 storage profile (2 B/param) for the memory props.
const FP16: Dtype = Dtype::Bf16;

fn rng_for(case: usize) -> Xoshiro256 {
    Xoshiro256::new(0xBEEF ^ (case as u64 * 2654435761))
}

/// Partition invariant: every example lands on the correct side, nothing
/// is lost, and the Addax-WA edge case doubles the dataset.
#[test]
fn prop_partition_is_exact_split() {
    for case in 0..CASES {
        let mut rng = rng_for(case);
        let task = OPT_TASKS[rng.next_below(OPT_TASKS.len())];
        let n = 20 + rng.next_below(200);
        let ex = generate(&task, n, 4096, None, case as u64);
        let l_max = ex.iter().map(Example::len).max().unwrap();
        let lt = 1 + rng.next_below(l_max + 20);
        let (d0, d1) = partition(&ex, lt);
        if lt >= l_max {
            assert_eq!(d0.len(), n);
            assert_eq!(d1.len(), n);
        } else {
            for &i in &d0 {
                assert!(ex[i].len() > lt || d0.len() == n);
            }
            for &i in &d1 {
                assert!(ex[i].len() <= lt || d1.len() == n);
            }
            if d0.len() != n && d1.len() != n {
                assert_eq!(d0.len() + d1.len(), n);
            }
        }
    }
}

/// Batch invariant: `from_rows` + `padded_to` + `chunks` preserve every
/// token and label, in order.
#[test]
fn prop_batching_preserves_tokens() {
    for case in 0..CASES {
        let mut rng = rng_for(case);
        let n = 1 + rng.next_below(12);
        let rows: Vec<(Vec<i32>, Vec<i32>)> = (0..n)
            .map(|_| {
                let l = 1 + rng.next_below(40);
                let ids: Vec<i32> = (0..l).map(|_| rng.next_below(500) as i32 + 1).collect();
                let labels: Vec<i32> =
                    (0..l).map(|_| rng.next_below(3) as i32 - 1).collect();
                (ids, labels)
            })
            .collect();
        let b = TokenBatch::from_rows(&rows);
        // round-trip rows
        for (r, (ids, labels)) in rows.iter().enumerate() {
            assert_eq!(&b.ids[r * b.seq..r * b.seq + ids.len()], &ids[..]);
            assert_eq!(&b.labels[r * b.seq..r * b.seq + labels.len()], &labels[..]);
        }
        // chunks partition the rows
        let k = 1 + rng.next_below(5);
        let chunks = b.chunks(k);
        assert_eq!(chunks.iter().map(|c| c.batch).sum::<usize>(), n);
        let labeled: usize = chunks.iter().map(|c| c.labeled_tokens()).sum();
        assert_eq!(labeled, b.labeled_tokens());
        // padding adds nothing labeled
        let p = b.padded_to(n + 2, b.seq + 3);
        assert_eq!(p.labeled_tokens(), b.labeled_tokens());
    }
}

/// Seed-replay invariant: perturb(+e); perturb(-2e); perturb(+e) returns
/// within float tolerance, for any seed/shape/scale; and the update
/// direction equals the replayed noise exactly.
#[test]
fn prop_seed_replay_roundtrip() {
    for case in 0..CASES {
        let mut rng = rng_for(case);
        let shapes: Vec<(String, Vec<usize>)> = (0..1 + rng.next_below(5))
            .map(|i| (format!("t{i}"), vec![1 + rng.next_below(300)]))
            .collect();
        let mut p = ParamStore::zeros(&shapes);
        p.perturb(case as u64, 1.0);
        let before = p.clone();
        let seed = rng.next_u64();
        let eps = 10f32.powi(-(1 + rng.next_below(5) as i32));
        p.perturb(seed, eps);
        p.perturb(seed, -2.0 * eps);
        p.perturb(seed, eps);
        let drift = p.dist_sq(&before);
        assert!(drift < 1e-6, "case {case}: drift {drift}");
    }
}

/// SPSA estimate approximates the true directional derivative on the
/// quadratic within noise bounds, for random dimensions and seeds.
#[test]
fn prop_spsa_matches_directional_derivative() {
    for case in 0..30 {
        let mut rng = rng_for(case);
        let d = 4 + rng.next_below(60);
        let mut exec = QuadraticExec::new(d, 0.5, 2.0, 0.0, case as u64);
        let mut p = ParamStore::zeros(&[("w".to_string(), vec![d])]);
        p.perturb(case as u64 + 1, 1.0);
        let rows: Vec<_> = (0..3).map(|i| (vec![i as i32 + 1], vec![-1])).collect();
        let b = TokenBatch::from_rows(&rows);
        let seed = rng.next_u64();
        let (g0, _) = spsa_g0(&mut p, &mut exec, &b, 1e-4, seed).unwrap();
        let g = exec.grads(&p, &b).unwrap();
        let dir = z_dot_grads(seed, &g.grads);
        assert!(
            (g0 - dir).abs() <= 0.05 * dir.abs().max(1.0),
            "case {case} d {d}: {g0} vs {dir}"
        );
    }
}

/// Random stores whose tensors straddle noise-block boundaries.
fn random_store(rng: &mut Xoshiro256, n_tensors: usize) -> ParamStore {
    let shapes: Vec<(String, Vec<usize>)> = (0..n_tensors)
        .map(|i| {
            // sizes from sub-block to several blocks, hugging the edges
            let n = match rng.next_below(4) {
                0 => 1 + rng.next_below(NOISE_BLOCK - 1),
                1 => NOISE_BLOCK + rng.next_below(3) - 1, // BLOCK-1 .. BLOCK+1
                2 => NOISE_BLOCK * (1 + rng.next_below(3)) + rng.next_below(50),
                _ => 2 * NOISE_BLOCK - rng.next_below(7),
            };
            (format!("t{i}"), vec![n])
        })
        .collect();
    ParamStore::zeros(&shapes)
}

/// Parallel-vs-serial invariant: the counter-addressed sweep produces
/// bit-identical stores at every worker count, for random shapes that
/// straddle block boundaries.
#[test]
fn prop_parallel_perturb_bit_identical() {
    for case in 0..20 {
        let mut rng = rng_for(case);
        let n_tensors = 1 + rng.next_below(5);
        let seed = rng.next_u64();
        let scale = 0.1 + rng.next_f64() as f32;
        let mut serial = random_store(&mut rng.clone(), n_tensors);
        serial.perturb_with_workers(seed, scale, 1);
        for workers in [2, 4, 8] {
            let mut par = random_store(&mut rng.clone(), n_tensors);
            par.perturb_with_workers(seed, scale, workers);
            for (a, b) in par.iter().zip(serial.iter()) {
                assert_eq!(
                    a.tensor, b.tensor,
                    "case {case} workers {workers}: parallel != serial"
                );
            }
        }
    }
}

/// Fusion invariant: `restore_and_zo_update` equals the unfused
/// restore-then-update two-pass exactly (bit for bit), from any probe
/// state.
#[test]
fn prop_fused_restore_update_exact() {
    for case in 0..20 {
        let mut rng = rng_for(case);
        let n_tensors = 1 + rng.next_below(4);
        let mut fused = random_store(&mut rng, n_tensors);
        fused.perturb(case as u64, 1.0);
        let mut two_pass = fused.clone();
        let seed = rng.next_u64();
        let eps = 10f32.powi(-(1 + rng.next_below(4) as i32));
        let (lr, coeff, g0) = (
            rng.next_f64() as f32 * 0.1,
            rng.next_f64() as f32,
            (rng.next_f64() as f32 - 0.5) * 4.0,
        );
        // both sit at θ − εz after the probe sweeps
        fused.perturb(seed, eps);
        fused.perturb(seed, -2.0 * eps);
        two_pass.perturb(seed, eps);
        two_pass.perturb(seed, -2.0 * eps);

        fused.restore_and_zo_update(seed, eps, lr, coeff, g0);
        two_pass.perturb(seed, eps);
        two_pass.zo_update(seed, lr, coeff, g0);
        for (a, b) in fused.iter().zip(two_pass.iter()) {
            assert_eq!(a.tensor, b.tensor, "case {case}: fused != two-pass");
        }
    }
}

/// Subset-replay invariant (hybrid baseline): a subset probe pair plus the
/// fused subset restore with lr_zo = 0 returns the store to θ within float
/// tolerance, and the noise of an included tensor matches the full-sweep
/// noise regardless of the filter.
#[test]
fn prop_subset_replay_lines_up() {
    for case in 0..20 {
        let mut rng = rng_for(case);
        let n_tensors = 2 + rng.next_below(4);
        let mut p = random_store(&mut rng, n_tensors);
        p.perturb(case as u64, 1.0);
        let before = p.clone();
        let seed = rng.next_u64();
        let eps = 1e-3f32;
        let keep = rng.next_below(n_tensors);
        let filt = move |idx: usize, _: &str| idx != keep;
        p.perturb_subset(seed, eps, filt);
        p.perturb_subset(seed, -2.0 * eps, filt);
        p.restore_and_zo_update_subset(seed, eps, 0.0, 1.0, 0.7, filt);
        let drift = p.dist_sq(&before);
        assert!(drift < 1e-6, "case {case}: subset roundtrip drift {drift}");

        // filter independence: included tensors get the same noise as a
        // full perturb would give them
        let mut sub = random_store(&mut rng_for(case), n_tensors);
        let mut full = sub.clone();
        sub.perturb_subset(seed, 0.5, filt);
        full.perturb(seed, 0.5);
        for (idx, (a, b)) in sub.iter().zip(full.iter()).enumerate() {
            if idx != keep {
                assert_eq!(a.tensor, b.tensor, "case {case} tensor {idx}");
            }
        }
    }
}

/// Optimizer state invariant: any optimizer step keeps params finite and
/// changes them (unless lr = 0), on random problems.
#[test]
fn prop_steps_finite_and_effective() {
    for case in 0..30 {
        let mut rng = rng_for(case);
        let d = 8 + rng.next_below(32);
        let mut exec = QuadraticExec::new(d, 0.5, 2.0, 0.2, case as u64);
        let mut p = ParamStore::zeros(&[("w".to_string(), vec![d])]);
        p.perturb(case as u64, 1.0);
        let mut opts: Vec<Box<dyn Optimizer>> = vec![
            Box::new(Addax::new(0.03, 1e-3, 0.2, 2, 2)),
            Box::new(MeZo::new(0.01, 1e-3, 2)),
            Box::new(IpSgd::new(0.03, 2)),
        ];
        for opt in opts.iter_mut() {
            let before = p.clone();
            let needs = opt.needs();
            let mk = |n: usize, rng: &mut Xoshiro256| {
                let rows: Vec<_> = (0..n)
                    .map(|_| (vec![rng.next_below(100) as i32 + 1], vec![-1]))
                    .collect();
                TokenBatch::from_rows(&rows)
            };
            let batches = StepBatches {
                fo: (needs.fo > 0).then(|| mk(needs.fo, &mut rng)),
                zo: (needs.zo > 0).then(|| mk(needs.zo, &mut rng)),
            };
            let stats = opt.step(&mut p, &mut exec, &batches, rng.next_u64()).unwrap();
            assert!(stats.loss.is_finite());
            assert!(p.all_finite(), "{} produced non-finite params", opt.name());
            assert!(p.dist_sq(&before) > 0.0, "{} was a no-op", opt.name());
        }
    }
}

/// Memory model structure: footprints are monotone in batch/length for
/// every method, and Addax's is never more than IP-SGD's at the same FO
/// workload (it replaces part of the work with forward-only passes).
#[test]
fn prop_memory_monotone_and_addax_bounded() {
    let g = geometry::OPT_13B;
    for case in 0..CASES {
        let mut rng = rng_for(case);
        let b = 1 + rng.next_below(16);
        let l = 32 + rng.next_below(700);
        for m in [Method::MeZo, Method::Sgd, Method::IpSgd, Method::Adam] {
            let wl = |bb, ll| match m {
                Method::MeZo => Workload::zo(bb, ll),
                _ => Workload::fo(bb, ll),
            };
            let f0 = footprint(&g, m, wl(b, l), FP16).total;
            let f1 = footprint(&g, m, wl(b + 1, l), FP16).total;
            let f2 = footprint(&g, m, wl(b, l + 16), FP16).total;
            assert!(f1 > f0 && f2 > f0, "{m:?} not monotone");
        }
        // Addax with L_T <= L and same K1=batch is bounded by IP-SGD at
        // (batch, L) as long as its ZO phase fits in the FO phase's
        // activations... at minimum it must beat IP-SGD at the same full
        // length when L_T is small.
        let lt = 32 + rng.next_below(l.saturating_sub(32).max(1));
        let addax = footprint(&g, Method::Addax, Workload::mixed(b, lt.min(l), 6, l), FP16);
        let ipsgd = footprint(&g, Method::IpSgd, Workload::fo(b, l), FP16);
        if lt < l / 2 && b >= 4 {
            assert!(
                addax.total <= ipsgd.total,
                "case {case}: addax {} > ipsgd {} (b={b} l={l} lt={lt})",
                addax.gb(),
                ipsgd.gb()
            );
        }
    }
}

/// jsonlite fuzz: dump ∘ parse = id on randomly generated values.
#[test]
fn prop_json_roundtrip() {
    fn gen(rng: &mut Xoshiro256, depth: usize) -> Json {
        match if depth == 0 { rng.next_below(4) } else { rng.next_below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.next_below(2) == 0),
            2 => Json::Num((rng.next_below(2_000_001) as f64 - 1e6) / 64.0),
            3 => Json::Str(
                (0..rng.next_below(12))
                    .map(|_| {
                        let opts = ['a', 'é', '"', '\\', '\n', 'z', '7', ' '];
                        opts[rng.next_below(opts.len())]
                    })
                    .collect(),
            ),
            4 => Json::Arr((0..rng.next_below(5)).map(|_| gen(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.next_below(5))
                    .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    for case in 0..200 {
        let mut rng = rng_for(case);
        let v = gen(&mut rng, 3);
        let text = v.dump();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("case {case}: {e}\n{text}"));
        assert_eq!(v, back, "case {case}");
    }
}

/// Training batches built from any index subset only reference real rows.
#[test]
fn prop_training_batch_indices() {
    let task = opt_task("rte").unwrap();
    let ex = generate(task, 100, 2048, Some(128), 5);
    for case in 0..CASES {
        let mut rng = rng_for(case);
        let idx: Vec<usize> = (0..1 + rng.next_below(8)).map(|_| rng.next_below(100)).collect();
        let b = training_batch(&ex, &idx);
        assert_eq!(b.batch, idx.len());
        for (r, &i) in idx.iter().enumerate() {
            let (ids, _) = ex[i].training_row();
            assert_eq!(&b.ids[r * b.seq..r * b.seq + ids.len()], &ids[..]);
        }
    }
}

/// bf16 sweeps are bit-identical at every worker count, for random
/// shapes straddling block boundaries — the half-precision edition of
/// `prop_parallel_perturb_bit_identical` (encode/decode is per-element,
/// so thread interleaving cannot change a single rounding).
#[test]
fn prop_bf16_parallel_sweeps_bit_identical() {
    for case in 0..12 {
        let mut rng = rng_for(case);
        let n_tensors = 1 + rng.next_below(4);
        let seed = rng.next_u64();
        let eps = 0.01 + rng.next_f64() as f32 * 0.05;
        let run = |workers: usize, rng_seed: &mut Xoshiro256| -> ParamStore {
            let mut s = random_store(rng_seed, n_tensors).to_dtype(Dtype::Bf16);
            s.set_noise_workers(workers);
            s.perturb(case as u64, 1.0);
            s.perturb(seed, eps);
            s.perturb(seed, -2.0 * eps);
            s.restore_and_zo_update(seed, eps, 0.03, 0.7, 1.1);
            s
        };
        let serial = run(1, &mut rng.clone());
        for workers in [2, 4, 8] {
            let par = run(workers, &mut rng.clone());
            for (a, b) in par.iter().zip(serial.iter()) {
                assert_eq!(a.tensor, b.tensor, "case {case} workers {workers}");
            }
        }
    }
}

/// Trajectory-drift bound: running the same optimizer with the same
/// seeds/batches on a bf16 store must stay close to the f32 trajectory
/// on the quadratic mock — quantization perturbs, it must not derail.
/// ε is set above the bf16 quantization step (ulp(1) = 2^-8) so the
/// SPSA probes remain visible in storage.
#[test]
fn prop_bf16_trajectory_drift_bounded_on_quadratic() {
    for case in 0..6 {
        let d = 32;
        let steps = 150;
        let mk_batches = |rng: &mut Xoshiro256, needs_fo: usize, needs_zo: usize| {
            let mk = |n: usize, rng: &mut Xoshiro256| {
                let rows: Vec<_> = (0..n)
                    .map(|_| (vec![rng.next_below(1000) as i32 + 1, 7], vec![-1, -1]))
                    .collect();
                TokenBatch::from_rows(&rows)
            };
            StepBatches {
                fo: (needs_fo > 0).then(|| mk(needs_fo, rng)),
                zo: (needs_zo > 0).then(|| mk(needs_zo, rng)),
            }
        };
        let run = |dtype: Dtype| -> (f64, ParamStore) {
            let mut exec = QuadraticExec::new(d, 0.5, 2.0, 0.0, 7 + case as u64);
            let mut opt = Addax::new(0.05, 1e-2, 0.3, 2, 2);
            let mut p =
                ParamStore::zeros_in(&[("w".to_string(), vec![d])], dtype);
            let mut rng = rng_for(case);
            for s in 0..steps {
                let needs = opt.needs();
                let batches = mk_batches(&mut rng, needs.fo, needs.zo);
                opt.step(&mut p, &mut exec, &batches, s as u64 * 7919 + 1).unwrap();
            }
            (exec.suboptimality(&p), p)
        };
        let (sub32, p32) = run(Dtype::F32);
        let (sub16, p16) = run(Dtype::Bf16);
        assert!(p16.all_finite(), "case {case}: bf16 run diverged");
        // Both converge from the ~O(10) initial suboptimality…
        assert!(sub16 < 1.0, "case {case}: bf16 suboptimality {sub16}");
        // …the bf16 loss floor stays near the f32 one…
        assert!(
            sub16 <= sub32 + 0.05,
            "case {case}: bf16 {sub16} vs f32 {sub32}"
        );
        // …and the parameter trajectories agree to quantization scale:
        // per-coordinate RMS gap well under the ~0.4% bf16 relative step
        // accumulated over the run (generous 0.1 absolute bound on
        // unit-scale targets).
        let rms = (p16.dist_sq(&p32) / d as f64).sqrt();
        assert!(rms < 0.1, "case {case}: rms trajectory gap {rms}");
    }
}
