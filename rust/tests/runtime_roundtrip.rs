//! Integration: the full python-AOT → rust-PJRT round trip.
//!
//! Requires `make artifacts` (skips gracefully if absent). These tests are
//! the load-bearing proof that all three layers compose: Pallas kernels
//! lowered inside the L2 model, executed by the L3 runtime, with losses
//! and gradients that behave like a real LM's.

use addax::params::ParamStore;
use addax::runtime::manifest::{default_artifacts_dir, ArtifactKind};
use addax::runtime::{ModelExec, TokenBatch, XlaExec};
use addax::zorng::Xoshiro256;

fn artifacts_ready() -> bool {
    default_artifacts_dir().join("manifest.json").exists()
}

fn exec_for(model: &str) -> XlaExec {
    XlaExec::new(&default_artifacts_dir(), model).expect("XlaExec")
}

fn toy_batch(vocab: usize, batch: usize, seq: usize, seed: u64) -> TokenBatch {
    let mut rng = Xoshiro256::new(seed);
    let rows: Vec<(Vec<i32>, Vec<i32>)> = (0..batch)
        .map(|_| {
            let ids: Vec<i32> =
                (0..seq).map(|_| 1 + rng.next_below(vocab - 1) as i32).collect();
            // next-token labels over positions 0..seq-1
            let mut labels = vec![-1; seq];
            for t in 0..seq - 1 {
                labels[t] = ids[t + 1];
            }
            (ids, labels)
        })
        .collect();
    TokenBatch::from_rows(&rows)
}

#[test]
fn forward_loss_near_log_vocab_at_init() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut exec = exec_for("tiny");
    let params = exec.load_initial_params().unwrap();
    let vocab = exec.entry().vocab;
    let b = toy_batch(vocab, 4, 24, 1);
    let out = exec.forward(&params, &b).unwrap();
    let loss = out.mean_loss();
    let expected = (vocab as f64).ln();
    assert!(
        (loss - expected).abs() < 0.5,
        "init loss {loss} should be ≈ ln(V) = {expected}"
    );
    assert_eq!(out.sums.len(), 4);
    // every row has seq-1 labeled tokens
    for &c in &out.counts {
        assert_eq!(c, 23.0);
    }
}

#[test]
fn grad_step_reduces_loss() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut exec = exec_for("tiny");
    let mut params = exec.load_initial_params().unwrap();
    let b = toy_batch(exec.entry().vocab, 8, 24, 2);
    let g = exec.grads(&params, &b).unwrap();
    assert!(g.count > 0.0);
    let before = g.loss as f64;
    params.fo_update_all(0.5, 1.0, &g.grads);
    let after = exec.forward(&params, &b).unwrap().mean_loss();
    assert!(
        after < before,
        "one SGD step must reduce loss on its own batch: {before} -> {after}"
    );
}

#[test]
fn padding_rows_and_cols_do_not_change_results() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut exec = exec_for("tiny");
    let params = exec.load_initial_params().unwrap();
    // 3 rows of length 20 -> runs in the 32-bucket padded to batch 8.
    let b = toy_batch(exec.entry().vocab, 3, 20, 3);
    let out = exec.forward(&params, &b).unwrap();
    // Same rows padded by hand to length 29: still the 32-bucket.
    let b2 = b.padded_to(3, 29);
    let out2 = exec.forward(&params, &b2).unwrap();
    for (a, c) in out.sums.iter().zip(out2.sums.iter()) {
        assert!((a - c).abs() < 1e-3, "{a} vs {c}");
    }
}

#[test]
fn pallas_and_ref_artifacts_agree() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut ep = exec_for("tiny");
    let mut er = exec_for("tiny-ref");
    let params = ep.load_initial_params().unwrap();
    let b = toy_batch(ep.entry().vocab, 4, 30, 4);
    let op = ep.forward(&params, &b).unwrap();
    let or = er.forward(&params, &b).unwrap();
    for (a, c) in op.sums.iter().zip(or.sums.iter()) {
        let rel = (a - c).abs() / c.abs().max(1.0);
        assert!(rel < 1e-3, "pallas {a} vs ref {c}");
    }
    let gp = ep.grads(&params, &b).unwrap();
    let gr = er.grads(&params, &b).unwrap();
    assert!((gp.loss - gr.loss).abs() < 1e-3);
    let mut max_rel = 0.0f32;
    for (tp, tr) in gp.grads.iter().zip(gr.grads.iter()) {
        for (&x, &y) in tp.iter().zip(tr.iter()) {
            let rel = (x - y).abs() / y.abs().max(1e-2);
            max_rel = max_rel.max(rel);
        }
    }
    assert!(max_rel < 2e-2, "grad mismatch {max_rel}");
}

#[test]
fn zo_estimate_matches_directional_derivative() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut exec = exec_for("tiny");
    let mut params = exec.load_initial_params().unwrap();
    let b = toy_batch(exec.entry().vocab, 4, 24, 5);
    let eps = 1e-3f32;
    let seed = 42u64;

    // SPSA estimate: (L(θ+εz) − L(θ−εz)) / 2ε via seed replay (Alg. 2).
    params.perturb(seed, eps);
    let lp = exec.forward(&params, &b).unwrap().mean_loss();
    params.perturb(seed, -2.0 * eps);
    let lm = exec.forward(&params, &b).unwrap().mean_loss();
    params.perturb(seed, eps);
    let g0 = (lp - lm) / (2.0 * eps as f64);

    // True directional derivative z·∇L from the grads artifact, with z
    // replayed under the counter-addressed block scheme.
    let g = exec.grads(&params, &b).unwrap();
    let dir = addax::optim::z_dot_grads(seed, &g.grads);
    let rel = (g0 - dir).abs() / dir.abs().max(1e-3);
    assert!(
        rel < 0.15,
        "SPSA {g0:.5} vs directional {dir:.5} (rel {rel:.3})"
    );
}

#[test]
fn long_sequences_have_forward_but_chunking_works() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut exec = exec_for("tiny");
    let params = exec.load_initial_params().unwrap();
    // 10 rows > artifact batch 8: forces 2-chunk execution.
    let b = toy_batch(exec.entry().vocab, 10, 24, 6);
    let out = exec.forward(&params, &b).unwrap();
    assert_eq!(out.sums.len(), 10);
    // grads over 10 rows must equal grads computed as one whole thing:
    // compare against two manual halves merged by count weighting.
    let g_all = exec.grads(&params, &b).unwrap();
    let chunks = b.chunks(5);
    let g1 = exec.grads(&params, &chunks[0]).unwrap();
    let g2 = exec.grads(&params, &chunks[1]).unwrap();
    let c1 = g1.count as f64;
    let c2 = g2.count as f64;
    for ((ta, t1), t2) in g_all.grads.iter().zip(g1.grads.iter()).zip(g2.grads.iter()) {
        for ((&a, &x), &y) in ta.iter().zip(t1.iter()).zip(t2.iter()) {
            let merged = (c1 * x as f64 + c2 * y as f64) / (c1 + c2);
            assert!((a as f64 - merged).abs() < 1e-4, "{a} vs {merged}");
        }
    }
}

#[test]
fn missing_grads_bucket_errors_like_oom() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut exec = exec_for("tiny");
    let params = exec.load_initial_params().unwrap();
    let max = exec.max_bucket(ArtifactKind::Grads).unwrap();
    let b = toy_batch(exec.entry().vocab, 2, max + 1, 7);
    assert!(exec.grads(&params, &b).is_err());
}

#[test]
fn initial_params_match_manifest_specs() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let exec = exec_for("small");
    let params = exec.load_initial_params().unwrap();
    assert_eq!(params.n_scalars(), exec.entry().n_params);
    assert!(params.all_finite());
    // zeros everywhere would mean a bad dump
    let store2 = ParamStore::zeros(&exec.param_specs());
    assert!(params.dist_sq(&store2) > 0.0);
}
