//! The checkpoint subsystem's defining contract, proven on the quadratic
//! mock: a run killed at **any** step and resumed is *byte-identical* —
//! same final manifest row, same parameter dump — to the uninterrupted
//! run, in both f32 and bf16, for stateless (Addax/MeZO) and stateful
//! (Adam) optimizers. Plus the degradation ladder: resume from an older
//! snapshot when the newest is gone, and a clean from-scratch fallback
//! (with a surfaced note) when every snapshot is corrupt.

use std::path::{Path, PathBuf};

use addax::coordinator::Halted;
use addax::optim::OptSpec;
use addax::sched::{execute_run, execute_run_with, Backend, RunCtx, RunSpec};
use addax::tensor::Dtype;

fn fresh_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("addax_ckptres_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn spec(opt: &str, dtype: Dtype, steps: usize) -> RunSpec {
    let mut s = RunSpec::new(Backend::Mock, "sst2", OptSpec::named(opt), steps, 3);
    s.dtype = dtype;
    s.eval_every = 4;
    s.eval_examples = 30;
    s.mock_dim = 40;
    s.n_train = 120;
    s.n_val = 40;
    s.n_test = 40;
    s.sealed()
}

fn ctx(dir: &Path, spec: &RunSpec, halt_after: usize, dump: Option<PathBuf>) -> RunCtx {
    RunCtx {
        ckpt_dir: Some(spec.ckpt_dir(dir)),
        ckpt_every: 0, // eval cadence
        ckpt_keep: 2,
        halt_after,
        dump_path: dump,
        ..RunCtx::default()
    }
}

/// Run `spec` uninterrupted (no checkpointing) → (manifest line, dump).
fn control(spec: &RunSpec, dir: &Path) -> (String, Vec<u8>) {
    let dump = dir.join("control.bin");
    let c = RunCtx { dump_path: Some(dump.clone()), ..RunCtx::default() };
    let (row, timing) = execute_run_with(spec, &c).unwrap();
    assert_eq!(timing.resumed_from_step, None);
    (row.to_line(), std::fs::read(dump).unwrap())
}

/// Halt `spec` after `kill_at` steps, then resume to completion.
fn kill_and_resume(spec: &RunSpec, dir: &Path, kill_at: usize) -> (String, Vec<u8>, usize) {
    let err = execute_run_with(spec, &ctx(dir, spec, kill_at, None)).unwrap_err();
    assert!(err.downcast_ref::<Halted>().is_some(), "want Halted, got: {err:#}");
    let dump = dir.join("resumed.bin");
    let (row, timing) =
        execute_run_with(spec, &ctx(dir, spec, 0, Some(dump.clone()))).unwrap();
    let resumed_from = timing.resumed_from_step.expect("run must have resumed");
    (row.to_line(), std::fs::read(dump).unwrap(), resumed_from)
}

#[test]
fn kill_at_arbitrary_step_resumes_byte_identically_in_both_dtypes() {
    // Addax exercises the mixed ZO+FO path; kill points cover the first
    // step, mid-run off-cadence, and the penultimate step.
    for dtype in [Dtype::F32, Dtype::Bf16] {
        let s = spec("addax", dtype, 20);
        let dir = fresh_dir(&format!("addax_{}", dtype.label()));
        let (want_row, want_dump) = control(&s, &dir);
        for kill_at in [1usize, 7, 19] {
            let run_dir = fresh_dir(&format!("addax_{}_{kill_at}", dtype.label()));
            let (row, dump, resumed_from) = kill_and_resume(&s, &run_dir, kill_at);
            assert_eq!(resumed_from, kill_at, "halt writes a snapshot at the kill step");
            assert_eq!(row, want_row, "dtype={} kill_at={kill_at}", dtype.label());
            assert_eq!(dump, want_dump, "dtype={} kill_at={kill_at}", dtype.label());
            std::fs::remove_dir_all(&run_dir).ok();
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn adam_moments_survive_the_kill() {
    // The stateful case: without OptState serialization the moments
    // restart at zero and the resumed trajectory diverges from control.
    // Same kill matrix as the stateless test: first, mid-run, and
    // penultimate step (steps = 16 here).
    for dtype in [Dtype::F32, Dtype::Bf16] {
        let s = spec("adam", dtype, 16);
        let dir = fresh_dir(&format!("adam_{}", dtype.label()));
        let (want_row, want_dump) = control(&s, &dir);
        for kill_at in [1usize, 9, 15] {
            let run_dir = fresh_dir(&format!("adam_{}_{kill_at}", dtype.label()));
            let (row, dump, resumed_from) = kill_and_resume(&s, &run_dir, kill_at);
            assert_eq!(resumed_from, kill_at);
            assert_eq!(row, want_row, "dtype={} kill_at={kill_at}", dtype.label());
            assert_eq!(dump, want_dump, "dtype={} kill_at={kill_at}", dtype.label());
            std::fs::remove_dir_all(&run_dir).ok();
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn resume_from_an_older_snapshot_replays_the_gap_identically() {
    // Kill at step 11, then delete the newest snapshot: the run must fall
    // back to an older one and re-execute the gap to the same bytes —
    // the "killed at a step with no snapshot" case.
    let s = spec("mezo", Dtype::F32, 24);
    let dir = fresh_dir("older_ctrl");
    let (want_row, want_dump) = control(&s, &dir);
    let run_dir = fresh_dir("older_kill");
    let err = execute_run_with(&s, &ctx(&run_dir, &s, 11, None)).unwrap_err();
    assert!(err.downcast_ref::<Halted>().is_some());
    let ck_dir = s.ckpt_dir(&run_dir);
    std::fs::remove_file(ck_dir.join("step-00000011.ck")).unwrap();
    let dump = run_dir.join("resumed.bin");
    let (row, timing) =
        execute_run_with(&s, &ctx(&run_dir, &s, 0, Some(dump.clone()))).unwrap();
    let resumed_from = timing.resumed_from_step.unwrap();
    assert!(resumed_from < 11, "must resume from an older snapshot, got {resumed_from}");
    assert_eq!(row.to_line(), want_row);
    assert_eq!(std::fs::read(dump).unwrap(), want_dump);
    std::fs::remove_dir_all(&run_dir).ok();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn corrupt_snapshots_degrade_to_from_scratch_with_a_note() {
    // Every corruption class from the satellite list must produce a clean
    // fallback: the worker runs from scratch (bit-identical to control,
    // since from-scratch IS the control) and surfaces a note.
    let s = spec("addax", Dtype::F32, 12);
    let ctrl_dir = fresh_dir("corrupt_ctrl");
    let (want_row, want_dump) = control(&s, &ctrl_dir);

    type Corruptor = fn(&mut Vec<u8>);
    let corruptors: [(&str, Corruptor); 3] = [
        ("truncate", |b: &mut Vec<u8>| b.truncate(b.len() / 2)),
        ("flip-crc-byte", |b: &mut Vec<u8>| {
            let n = b.len();
            b[n - 2] ^= 0x10;
        }),
        ("wrong-magic", |b: &mut Vec<u8>| b[..8].copy_from_slice(b"XXXXXXXX")),
    ];
    for (name, corrupt) in corruptors {
        let run_dir = fresh_dir(&format!("corrupt_{name}"));
        let err = execute_run_with(&s, &ctx(&run_dir, &s, 5, None)).unwrap_err();
        assert!(err.downcast_ref::<Halted>().is_some());
        let ck_dir = s.ckpt_dir(&run_dir);
        let mut corrupted = 0usize;
        for entry in std::fs::read_dir(&ck_dir).unwrap().flatten() {
            let path = entry.path();
            if path.extension().map(|e| e == "ck").unwrap_or(false) {
                let mut bytes = std::fs::read(&path).unwrap();
                corrupt(&mut bytes);
                std::fs::write(&path, &bytes).unwrap();
                corrupted += 1;
            }
        }
        assert!(corrupted > 0, "{name}: no snapshots were written?");
        let dump = run_dir.join("resumed.bin");
        let (row, timing) =
            execute_run_with(&s, &ctx(&run_dir, &s, 0, Some(dump.clone()))).unwrap();
        assert_eq!(timing.resumed_from_step, None, "{name}: must NOT claim a resume");
        let note = timing.note.expect("corruption must surface a note");
        assert!(note.contains("invalid snapshot"), "{name}: {note}");
        assert!(note.contains("scratch"), "{name}: {note}");
        assert_eq!(row.to_line(), want_row, "{name}");
        assert_eq!(std::fs::read(dump).unwrap(), want_dump, "{name}");
        std::fs::remove_dir_all(&run_dir).ok();
    }
    std::fs::remove_dir_all(&ctrl_dir).ok();
}

#[test]
fn dtype_and_identity_mismatches_are_rejected_cleanly() {
    // A snapshot written by the bf16 twin (distinct run id AND dtype) and
    // one from a different grid seed (same dtype, different identity)
    // must both be refused — from-scratch fallback, clean note, never a
    // panic or a silently grafted state.
    let f32_spec = spec("mezo", Dtype::F32, 12);
    let ctrl_dir = fresh_dir("mismatch_ctrl");
    let (want_row, want_dump) = control(&f32_spec, &ctrl_dir);

    for (name, other) in [
        ("dtype", spec("mezo", Dtype::Bf16, 12)),
        ("identity", {
            let mut s = RunSpec::new(Backend::Mock, "sst2", OptSpec::named("mezo"), 12, 4);
            s.dtype = Dtype::F32;
            s.eval_every = 4;
            s.eval_examples = 30;
            s.mock_dim = 40;
            s.n_train = 120;
            s.n_val = 40;
            s.n_test = 40;
            s.sealed()
        }),
    ] {
        assert_ne!(other.run_id, f32_spec.run_id);
        let run_dir = fresh_dir(&format!("mismatch_{name}"));
        // Halt the OTHER run so its snapshots land in the directory the
        // f32 run will scan (simulated operator mix-up).
        let mut other_ctx = ctx(&run_dir, &other, 5, None);
        other_ctx.ckpt_dir = Some(f32_spec.ckpt_dir(&run_dir));
        let err = execute_run_with(&other, &other_ctx).unwrap_err();
        assert!(err.downcast_ref::<Halted>().is_some());

        let dump = run_dir.join("resumed.bin");
        let (row, timing) =
            execute_run_with(&f32_spec, &ctx(&run_dir, &f32_spec, 0, Some(dump.clone())))
                .unwrap();
        assert_eq!(timing.resumed_from_step, None, "{name}");
        let note = timing.note.expect("mismatch must surface a note");
        assert!(note.contains("invalid snapshot"), "{name}: {note}");
        assert_eq!(row.to_line(), want_row, "{name}");
        assert_eq!(std::fs::read(dump).unwrap(), want_dump, "{name}");
        std::fs::remove_dir_all(&run_dir).ok();
    }
    std::fs::remove_dir_all(&ctrl_dir).ok();
}

#[test]
fn execute_run_default_context_never_checkpoints() {
    // The historical entry point keeps its exact behavior: no checkpoint
    // side effects, same row as the checkpointing control.
    let s = spec("addax", Dtype::F32, 12);
    let (row_a, _) = execute_run(&s).unwrap();
    let (row_b, _) = execute_run(&s).unwrap();
    assert_eq!(row_a.to_line(), row_b.to_line());
}
