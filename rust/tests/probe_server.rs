//! The observability plane, end to end. The defining contract under
//! test: **probes cannot move a deterministic byte**. Control verbs
//! (checkpoint / pause / abort) ride the existing snapshot and `Halted`
//! rails at step boundaries, so a probed run — even one paused mid-way,
//! checkpointed off-cadence, or aborted and finished later by another
//! worker — produces a manifest row (and parameter dump, and compacted
//! sweep manifest) byte-identical to an unprobed control's. The HTTP
//! server itself is exercised live over a real sweep.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::PathBuf;

use addax::config::Config;
use addax::jsonlite::Json;
use addax::obs::fleet::load_fleet;
use addax::obs::{ProbeServer, StatusBoard};
use addax::optim::OptSpec;
use addax::sched::{
    execute_run, execute_run_with, lease, leases_path, run_sweep, run_sweep_fleet, Backend,
    FleetOptions, LeaseTable, RunCtx, RunSpec, SweepManifest, SweepOptions, SweepSpec,
};

fn fresh_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("addax_probe_test_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn spec(opt: &str, steps: usize) -> RunSpec {
    let mut s = RunSpec::new(Backend::Mock, "sst2", OptSpec::named(opt), steps, 3);
    s.eval_every = 4;
    s.eval_examples = 30;
    s.mock_dim = 40;
    s.n_train = 120;
    s.n_val = 40;
    s.n_test = 40;
    s.sealed()
}

fn phase(probe: &addax::obs::RunProbe) -> String {
    probe.to_json().get("phase").unwrap().as_str().unwrap().to_string()
}

fn step_of(probe: &addax::obs::RunProbe) -> f64 {
    probe.to_json().get("step").unwrap().as_f64().unwrap()
}

#[test]
fn pre_armed_checkpoint_verb_snapshots_off_cadence_without_moving_bytes() {
    let s = spec("addax", 12);
    let ctrl = fresh_dir("ckpt_ctrl");
    let dump_c = ctrl.join("c.bin");
    let (row_c, _) = execute_run_with(
        &s,
        &RunCtx {
            ckpt_dir: Some(s.ckpt_dir(&ctrl)),
            ckpt_keep: 8,
            dump_path: Some(dump_c.clone()),
            ..RunCtx::default()
        },
    )
    .unwrap();

    // The operator hit POST /runs/<id>/checkpoint before step 1: the
    // request is consumed at the first step boundary.
    let probed = fresh_dir("ckpt_probe");
    let board = StatusBoard::new();
    let probe = board.register(&s.run_id, s.steps);
    probe.request_checkpoint();
    let dump_p = probed.join("p.bin");
    let (row_p, _) = execute_run_with(
        &s,
        &RunCtx {
            ckpt_dir: Some(s.ckpt_dir(&probed)),
            ckpt_keep: 8,
            dump_path: Some(dump_p.clone()),
            probe: Some(probe.clone()),
            ..RunCtx::default()
        },
    )
    .unwrap();
    assert_eq!(row_p.to_line(), row_c.to_line(), "a served checkpoint must not move a byte");
    assert_eq!(std::fs::read(dump_p).unwrap(), std::fs::read(dump_c).unwrap());
    // The verb produced an off-cadence snapshot the control lacks.
    assert!(s.ckpt_dir(&probed).join("step-00000001.ck").exists());
    assert!(!s.ckpt_dir(&ctrl).join("step-00000001.ck").exists());
    assert_eq!(phase(&probe), "done");
    assert_eq!(step_of(&probe) as usize, s.steps);
    std::fs::remove_dir_all(&ctrl).ok();
    std::fs::remove_dir_all(&probed).ok();
}

#[test]
fn pause_stalls_the_step_clock_and_resume_matches_control() {
    let s = spec("addax", 12);
    let (row_c, _) = execute_run(&s).unwrap();

    let board = StatusBoard::new();
    let probe = board.register(&s.run_id, s.steps);
    probe.request_pause(); // armed before the run starts
    let (p2, s2) = (probe.clone(), s.clone());
    let h = std::thread::spawn(move || {
        execute_run_with(&s2, &RunCtx { probe: Some(p2), ..RunCtx::default() }).unwrap()
    });
    // The run parks at the first step boundary and reports it.
    let mut spins = 0;
    while phase(&probe) != "paused" {
        std::thread::sleep(std::time::Duration::from_millis(5));
        spins += 1;
        assert!(spins < 2000, "run never reached the pause gate (phase {})", phase(&probe));
    }
    let parked_at = step_of(&probe);
    std::thread::sleep(std::time::Duration::from_millis(80));
    assert_eq!(step_of(&probe), parked_at, "a paused run must not advance");
    probe.request_resume();
    let (row_p, _) = h.join().unwrap();
    assert_eq!(phase(&probe), "done");
    assert_eq!(row_p.to_line(), row_c.to_line(), "pause/resume must not move a byte");
}

/// Minimal HTTP/1.1 client for the live-server tests.
fn fetch(addr: &str, method: &str, target: &str) -> (u16, Json) {
    let mut stream = TcpStream::connect(addr).unwrap();
    write!(
        stream,
        "{method} {target} HTTP/1.1\r\nHost: probe\r\nConnection: close\r\n\r\n"
    )
    .unwrap();
    let mut buf = String::new();
    stream.read_to_string(&mut buf).unwrap();
    let status: u16 = buf.split_whitespace().nth(1).unwrap().parse().unwrap();
    let body = buf.split("\r\n\r\n").nth(1).unwrap_or("");
    (status, Json::parse(body).unwrap_or_else(|e| panic!("bad JSON body {body:?}: {e}")))
}

/// A tiny all-training grid (no zero-shot) so every run has metrics.
const LIVE_SPEC: &str = r#"
[sweep]
name = "probe-live"
backend = "mock"
steps = 8
zo_mult = 2
eval_examples = 24
mock_dim = 32
train = 120
val = 48
test = 48

[grid]
optimizers = "addax"
tasks = "sst2"
seeds = "0, 1"
"#;

fn live_grid() -> Vec<RunSpec> {
    let cfg = Config::parse(LIVE_SPEC).unwrap();
    SweepSpec::from_config(&cfg).unwrap().expand().unwrap()
}

fn opts(dir: &std::path::Path) -> SweepOptions {
    SweepOptions {
        budget_gb: 100.0,
        gpus: 1,
        workers: 1,
        resume: true,
        manifest_path: dir.join("manifest.jsonl"),
        verbose: false,
        ckpt: true,
        ..SweepOptions::default()
    }
}

#[test]
fn live_server_over_a_probed_sweep_serves_runs_metrics_and_mem() {
    let ctrl = fresh_dir("live_ctrl");
    run_sweep(live_grid(), &opts(&ctrl)).unwrap();
    let control_bytes = std::fs::read_to_string(opts(&ctrl).manifest_path).unwrap();

    let dir = fresh_dir("live");
    let board = StatusBoard::new();
    let server = ProbeServer::start(board.clone(), 0).unwrap();
    let addr = server.addr().to_string();
    let mut o = opts(&dir);
    o.probe = Some(board);
    let summary = run_sweep(live_grid(), &o).unwrap();
    assert_eq!(summary.executed, live_grid().len());

    // /runs: every run registered, every run done, valid JSON throughout.
    let (status, runs) = fetch(&addr, "GET", "/runs");
    assert_eq!(status, 200);
    assert_eq!(runs.get("n").unwrap().as_usize().unwrap(), live_grid().len());
    let arr = runs.get("runs").unwrap().as_arr().unwrap().to_vec();
    for r in &arr {
        assert_eq!(r.get("phase").unwrap().as_str().unwrap(), "done", "{}", r.dump());
        assert!(r.get("loss_tail").unwrap().as_arr().unwrap().len() <= 5);
    }

    // /runs/<id>/metrics: field projection + bounded tail.
    let id = arr[0].get("run_id").unwrap().as_str().unwrap().to_string();
    let (status, m) = fetch(&addr, "GET", &format!("/runs/{id}/metrics?fields=step,loss&last=3"));
    assert_eq!(status, 200);
    let rows = m.get("rows").unwrap().as_arr().unwrap();
    assert!(!rows.is_empty() && rows.len() <= 3, "{}", m.dump());
    for row in rows {
        let keys: Vec<&String> = row.as_obj().unwrap().keys().collect();
        assert!(keys.iter().all(|k| *k == "step" || *k == "loss"), "{}", row.dump());
    }

    // /mem: a real RSS reading against the analytic plane.
    let (status, mem) = fetch(&addr, "GET", "/mem");
    assert_eq!(status, 200);
    assert!(mem.get("rss_bytes").unwrap().as_f64().unwrap() > 0.0, "{}", mem.dump());
    assert!(mem.opt("threshold_bytes_per_sec").is_some());

    // Unknown run and bad query fail cleanly, server stays up.
    assert_eq!(fetch(&addr, "GET", "/runs/nope").0, 404);
    assert_eq!(fetch(&addr, "GET", &format!("/runs/{id}/metrics?last=soon")).0, 400);

    // The acceptance bar: probed bytes == unprobed bytes.
    let probed_bytes = std::fs::read_to_string(&o.manifest_path).unwrap();
    assert_eq!(probed_bytes, control_bytes, "a probed sweep must compact to the control bytes");
    drop(server);
    std::fs::remove_dir_all(&ctrl).ok();
    std::fs::remove_dir_all(&dir).ok();
}

/// The fleet grid: FO + ZO + zero-shot across two seeds (same shape as
/// the sweep_fleet tests).
const FLEET_SPEC: &str = r#"
[sweep]
name = "probe-fleet"
backend = "mock"
steps = 12
zo_mult = 2
eval_examples = 24
mock_dim = 32
train = 120
val = 48
test = 48
lease_ttl_secs = 0.5

[grid]
optimizers = "addax, mezo, zero-shot"
tasks = "sst2"
seeds = "0, 1"
"#;

fn fleet_grid() -> Vec<RunSpec> {
    let cfg = Config::parse(FLEET_SPEC).unwrap();
    SweepSpec::from_config(&cfg).unwrap().expand().unwrap()
}

#[test]
fn probe_abort_releases_the_lease_and_a_second_worker_finishes_byte_identically() {
    let ctrl = fresh_dir("abort_ctrl");
    run_sweep(fleet_grid(), &opts(&ctrl)).unwrap();
    let control_bytes = std::fs::read_to_string(opts(&ctrl).manifest_path).unwrap();

    // Worker 0 carries the board; the abort is armed before it starts
    // (registration is get-or-insert, so the worker reuses this probe).
    let dir = fresh_dir("abort");
    let mut o = opts(&dir);
    let board = StatusBoard::new();
    o.probe = Some(board.clone());
    let victim = fleet_grid().into_iter().find(|s| s.steps > 0).unwrap();
    board.register(&victim.run_id, victim.steps).request_abort();
    let exit = run_sweep_fleet(fleet_grid(), &o, &FleetOptions::new("w0", 500)).unwrap();
    assert!(exit.crashed.is_none());
    assert_eq!(exit.summary.halted, 1, "{}", exit.summary.line());
    assert_eq!(exit.summary.executed, fleet_grid().len() - 1);
    let times = std::fs::read_to_string(SweepManifest::times_path(&o.manifest_path)).unwrap();
    assert!(times.contains("\"event\":\"abort\""), "abort must be logged: {times}");
    let probe = board.get(&victim.run_id).unwrap();
    assert_eq!(phase(&probe), "halted");
    // Released, not committed: the manifest lacks the victim, but its
    // snapshots survive — they ARE the resume state.
    let manifest = SweepManifest::load(&o.manifest_path).unwrap();
    assert!(!manifest.contains(&victim.run_id));
    assert!(victim.ckpt_dir(&o.ckpt_root()).exists(), "abort must keep the snapshots");

    // Worker 1 (no probe plane at all) picks the run up and finishes it
    // from the snapshot.
    let o2 = SweepOptions { probe: None, ..o.clone() };
    let exit2 = run_sweep_fleet(fleet_grid(), &o2, &FleetOptions::new("w1", 500)).unwrap();
    assert_eq!(exit2.summary.executed, 1, "{}", exit2.summary.line());
    assert_eq!(exit2.summary.halted, 0);
    let times = std::fs::read_to_string(SweepManifest::times_path(&o.manifest_path)).unwrap();
    assert!(times.contains("\"resumed_from_step\""), "the pickup must resume: {times}");

    // The kill is byte-invisible: compacted manifest == control, and the
    // abort never leaked out of the telemetry side file.
    let bytes = std::fs::read_to_string(&o.manifest_path).unwrap();
    assert_eq!(bytes, control_bytes, "an aborted+resumed fleet must match the control bytes");
    assert!(!bytes.contains("abort"));
    std::fs::remove_dir_all(&ctrl).ok();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn fleet_status_reconstructs_a_finished_probed_fleet_consistently() {
    let ctrl = fresh_dir("fs_ctrl");
    run_sweep(fleet_grid(), &opts(&ctrl)).unwrap();
    let control_bytes = std::fs::read_to_string(opts(&ctrl).manifest_path).unwrap();

    // Worker 0 runs the whole grid with a probe server, advertising its
    // address in every lease record; worker 1 joins after the drain and
    // finds nothing claimable.
    let dir = fresh_dir("fs");
    let o = opts(&dir);
    let board = StatusBoard::new();
    let server = ProbeServer::start(board.clone(), 0).unwrap();
    let mut o0 = o.clone();
    o0.probe = Some(board);
    let mut f0 = FleetOptions::new("w0", 2_000);
    f0.probe_addr = Some(server.addr().to_string());
    let exit = run_sweep_fleet(fleet_grid(), &o0, &f0).unwrap();
    assert!(exit.crashed.is_none());
    assert_eq!(exit.summary.executed, fleet_grid().len());
    let exit2 = run_sweep_fleet(fleet_grid(), &o, &FleetOptions::new("w1", 2_000)).unwrap();
    assert_eq!(exit2.summary.executed, 0, "{}", exit2.summary.line());

    // The aggregator's consistency bar over a drained fleet: every run
    // it can see is exactly one done manifest row, zero live leases.
    let mut view = load_fleet(&o.manifest_path, lease::now_ms(), 250).unwrap();
    view.federate(std::time::Duration::from_millis(200));
    let manifest = SweepManifest::load(&o.manifest_path).unwrap();
    assert_eq!(view.done, manifest.len(), "every manifest row must read back as done");
    assert_eq!(view.runs.len(), manifest.len(), "no phantom runs beyond the manifest");
    assert_eq!((view.active, view.expired), (0, 0), "a drained fleet holds no live lease");
    for r in &view.runs {
        assert_eq!(r.state, "done", "{}", r.run_id);
        assert!(r.best_val.is_some(), "{} must carry the row's best_val", r.run_id);
    }
    for w in &view.workers {
        assert!(w.held.is_empty(), "{} still holds {:?}", w.worker, w.held);
    }
    // The ledger agrees with the reconstruction...
    let leases = LeaseTable::load(&leases_path(&o.manifest_path)).unwrap();
    assert!(leases.all_released());
    // ...and the probed, advertised, aggregated fleet still compacts to
    // the unprobed control's bytes: observability moved nothing.
    let bytes = std::fs::read_to_string(&o.manifest_path).unwrap();
    assert_eq!(bytes, control_bytes, "a probed fleet must match the control bytes");
    drop(server);
    std::fs::remove_dir_all(&ctrl).ok();
    std::fs::remove_dir_all(&dir).ok();
}
