//! Data substrate: synthetic task generation, the length-based partition
//! `D = D⁰ ∪ D¹` (Alg. 1 lines 2-5), samplers, and batch construction.
//!
//! ## Token layout of one example
//!
//! ```text
//! [ctx₀ … ctx_{n-1}, verbalizer(answer)]
//! ```
//!
//! Vocabulary map (token ids): 0 = padding, 1..=C are the class
//! verbalizers, the rest of the vocab carries the context. A fraction
//! `signal` of the context tokens is drawn from a class-specific band, so
//! a model must learn band→verbalizer associations — a planted
//! linear-separable signal whose difficulty is controlled per task.
//!
//! Training labels follow the paper's classification setup: the loss is
//! taken on the verbalizer position only. Evaluation scores every class's
//! verbalizer by its average log-likelihood and predicts the argmax
//! (App. D.3).

pub mod tasks;

use crate::runtime::TokenBatch;
use crate::zorng::Xoshiro256;

pub use tasks::{opt_task, roberta_task, TaskDef, TaskType, OPT_TASKS, ROBERTA_TASKS};

/// One generated example.
#[derive(Clone, Debug)]
pub struct Example {
    /// Context tokens (verbalizer NOT included).
    pub context: Vec<i32>,
    /// Ground-truth class.
    pub answer: usize,
    pub n_classes: usize,
}

impl Example {
    /// Total sequence length including the verbalizer token.
    pub fn len(&self) -> usize {
        self.context.len() + 1
    }

    /// True when the example has no tokens at all. Defined honestly off
    /// [`Example::len`] (which counts the verbalizer, so any generated
    /// example reports ≥ 1) instead of the old hardcoded `false`.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Verbalizer token id for class `c` (ids 1..=n_classes).
    pub fn verbalizer(c: usize) -> i32 {
        1 + c as i32
    }

    /// (ids, labels) for training: loss on the verbalizer position only.
    pub fn training_row(&self) -> (Vec<i32>, Vec<i32>) {
        let mut ids = self.context.clone();
        ids.push(Self::verbalizer(self.answer));
        let mut labels = vec![-1; ids.len()];
        let n = ids.len();
        labels[n - 2] = ids[n - 1]; // position n-2 predicts the verbalizer
        (ids, labels)
    }

    /// (ids, labels) scoring candidate class `c` at evaluation time.
    pub fn candidate_row(&self, c: usize) -> (Vec<i32>, Vec<i32>) {
        let mut ids = self.context.clone();
        ids.push(Self::verbalizer(c));
        let mut labels = vec![-1; ids.len()];
        let n = ids.len();
        labels[n - 2] = ids[n - 1];
        (ids, labels)
    }
}

/// Deterministic generator for a task's examples.
///
/// `max_len` rescales the task's length distribution so that its `L_max`
/// maps onto the model preset's bucket ceiling (DESIGN.md §3: trainable
/// runs are laptop-scale; memory simulations use the unscaled lengths).
pub fn generate(
    task: &TaskDef,
    n: usize,
    vocab: usize,
    max_len: Option<usize>,
    seed: u64,
) -> Vec<Example> {
    let mut rng = Xoshiro256::new(seed ^ 0xDA7A);
    let scale = match max_len {
        Some(m) if task.lengths.l_max > m => m as f64 / task.lengths.l_max as f64,
        _ => 1.0,
    };
    let first_ctx = 1 + task.n_classes as i32; // context band starts here
    let ctx_tokens = vocab as i32 - first_ctx;
    assert!(ctx_tokens > 2 * task.n_classes as i32, "vocab too small for task");
    let band = ctx_tokens / task.n_classes as i32;
    (0..n)
        .map(|_| {
            let answer = rng.next_below(task.n_classes);
            let len = sample_length(&task.lengths, scale, &mut rng);
            let ctx_len = len.saturating_sub(1).max(2);
            let context = (0..ctx_len)
                .map(|_| {
                    if rng.next_f64() < task.signal {
                        // class-specific band
                        first_ctx
                            + answer as i32 * band
                            + rng.next_below(band as usize) as i32
                    } else {
                        first_ctx + rng.next_below(ctx_tokens as usize) as i32
                    }
                })
                .collect();
            Example { context, answer, n_classes: task.n_classes }
        })
        .collect()
}

fn sample_length(d: &tasks::LengthDist, scale: f64, rng: &mut Xoshiro256) -> usize {
    // log-normal via Box-Muller on the task's median/sigma
    let u1 = rng.next_f64().max(1e-12);
    let u2 = rng.next_f64();
    let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
    let raw = (d.median.ln() + d.sigma * z).exp();
    let lo = ((d.min_len as f64) * scale).max(4.0);
    let hi = (d.l_max as f64) * scale;
    (raw * scale).clamp(lo, hi).round() as usize
}

/// A generated dataset split into train/val/test (paper: 1000/500/1000).
#[derive(Clone, Debug)]
pub struct Dataset {
    pub task: TaskDef,
    pub train: Vec<Example>,
    pub val: Vec<Example>,
    pub test: Vec<Example>,
}

impl Dataset {
    /// Generate with the paper's split sizes scaled by `frac`.
    pub fn generate(
        task: &TaskDef,
        vocab: usize,
        max_len: Option<usize>,
        seed: u64,
        n_train: usize,
        n_val: usize,
        n_test: usize,
    ) -> Self {
        Self {
            task: *task,
            train: generate(task, n_train, vocab, max_len, seed),
            val: generate(task, n_val, vocab, max_len, seed.wrapping_add(1)),
            test: generate(task, n_test, vocab, max_len, seed.wrapping_add(2)),
        }
    }

    /// Longest sequence in the training split (the `L_max` of Alg. 1).
    pub fn l_max(&self) -> usize {
        self.train.iter().map(Example::len).max().unwrap_or(0)
    }
}

/// The length-based partition of Algorithm 1 (lines 2-5).
///
/// Returns indices into `examples`: `(d0, d1)` with
/// `D⁰ = {x : len(x) > L_T}` and `D¹ = {x : len(x) ≤ L_T}`.
/// If `L_T ≥ L_max` both partitions are the full dataset (Addax-WA,
/// line 3). If either partition would be empty, it falls back to the full
/// dataset so sampling stays well-defined.
pub fn partition(examples: &[Example], lt: usize) -> (Vec<usize>, Vec<usize>) {
    let l_max = examples.iter().map(Example::len).max().unwrap_or(0);
    let all: Vec<usize> = (0..examples.len()).collect();
    if lt >= l_max {
        return (all.clone(), all);
    }
    let d0: Vec<usize> = examples
        .iter()
        .enumerate()
        .filter(|(_, e)| e.len() > lt)
        .map(|(i, _)| i)
        .collect();
    let d1: Vec<usize> = examples
        .iter()
        .enumerate()
        .filter(|(_, e)| e.len() <= lt)
        .map(|(i, _)| i)
        .collect();
    let d0 = if d0.is_empty() { all.clone() } else { d0 };
    let d1 = if d1.is_empty() { all } else { d1 };
    (d0, d1)
}

/// Uniform-with-replacement minibatch sampler over an index set.
///
/// The stream is checkpointable: [`Sampler::rng_state`] captures the
/// generator mid-stream and [`Sampler::from_state`] continues it exactly
/// — the serialized form of the train-batch streams in the `ckpt`
/// snapshots (the pool itself is re-derived from the dataset seed).
pub struct Sampler<'a> {
    pool: &'a [usize],
    rng: Xoshiro256,
}

impl<'a> Sampler<'a> {
    pub fn new(pool: &'a [usize], seed: u64) -> Self {
        assert!(!pool.is_empty(), "empty sampling pool");
        Self { pool, rng: Xoshiro256::new(seed) }
    }

    /// Resume a sampler whose generator state was captured mid-stream.
    pub fn from_state(pool: &'a [usize], state: [u64; 4]) -> Self {
        assert!(!pool.is_empty(), "empty sampling pool");
        Self { pool, rng: Xoshiro256::from_state(state) }
    }

    /// The generator state after every draw so far.
    pub fn rng_state(&self) -> [u64; 4] {
        self.rng.state()
    }

    pub fn draw(&mut self, k: usize) -> Vec<usize> {
        (0..k).map(|_| self.pool[self.rng.next_below(self.pool.len())]).collect()
    }
}

/// Build a training [`TokenBatch`] from example indices.
pub fn training_batch(examples: &[Example], idx: &[usize]) -> TokenBatch {
    let rows: Vec<_> = idx.iter().map(|&i| examples[i].training_row()).collect();
    TokenBatch::from_rows(&rows)
}

/// Build the candidate-scoring batch for one example (one row per class).
pub fn candidate_batch(example: &Example) -> TokenBatch {
    let rows: Vec<_> =
        (0..example.n_classes).map(|c| example.candidate_row(c)).collect();
    TokenBatch::from_rows(&rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sst2() -> &'static TaskDef {
        opt_task("sst2").unwrap()
    }

    #[test]
    fn generation_is_deterministic() {
        let a = generate(sst2(), 20, 512, None, 7);
        let b = generate(sst2(), 20, 512, None, 7);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.context, y.context);
            assert_eq!(x.answer, y.answer);
        }
        let c = generate(sst2(), 20, 512, None, 8);
        assert!(a.iter().zip(c.iter()).any(|(x, y)| x.context != y.context));
    }

    #[test]
    fn lengths_respect_bounds_and_skew() {
        let t = opt_task("multirc").unwrap();
        let ex = generate(t, 3000, 4096, None, 1);
        let lens: Vec<usize> = ex.iter().map(Example::len).collect();
        let max = *lens.iter().max().unwrap();
        let min = *lens.iter().min().unwrap();
        assert!(max <= 739 && min >= t.lengths.min_len.min(4));
        // right-skew: mean > median
        let mut sorted = lens.clone();
        sorted.sort_unstable();
        let median = sorted[sorted.len() / 2] as f64;
        let mean = lens.iter().sum::<usize>() as f64 / lens.len() as f64;
        assert!(mean > median, "mean {mean} median {median}");
        // the long tail is rare: <20% of examples above 2x median
        let tail = lens.iter().filter(|&&l| l as f64 > 2.0 * median).count();
        assert!(tail < lens.len() / 5);
    }

    #[test]
    fn max_len_rescaling() {
        let t = opt_task("multirc").unwrap();
        let ex = generate(t, 500, 4096, Some(128), 2);
        assert!(ex.iter().map(Example::len).max().unwrap() <= 128);
    }

    #[test]
    fn training_row_labels_only_verbalizer() {
        let ex = &generate(sst2(), 1, 512, None, 3)[0];
        let (ids, labels) = ex.training_row();
        assert_eq!(ids.len(), labels.len());
        assert_eq!(*ids.last().unwrap(), Example::verbalizer(ex.answer));
        let labeled: Vec<usize> =
            labels.iter().enumerate().filter(|(_, &l)| l >= 0).map(|(i, _)| i).collect();
        assert_eq!(labeled, vec![ids.len() - 2]);
        assert_eq!(labels[ids.len() - 2], *ids.last().unwrap());
    }

    #[test]
    fn partition_splits_by_threshold() {
        let ex = generate(opt_task("rte").unwrap(), 400, 512, None, 5);
        let lt = 64;
        let (d0, d1) = partition(&ex, lt);
        assert!(d0.iter().all(|&i| ex[i].len() > lt));
        assert!(d1.iter().all(|&i| ex[i].len() <= lt));
        assert_eq!(d0.len() + d1.len(), 400);
    }

    #[test]
    fn partition_lt_above_lmax_gives_full_dataset_twice() {
        let ex = generate(sst2(), 50, 512, None, 6);
        let (d0, d1) = partition(&ex, 10_000);
        assert_eq!(d0.len(), 50);
        assert_eq!(d1.len(), 50);
    }

    #[test]
    fn partition_never_empty() {
        let ex = generate(sst2(), 50, 512, None, 7);
        // LT below every length: d1 would be empty -> falls back to full
        let (_, d1) = partition(&ex, 1);
        assert!(!d1.is_empty());
    }

    #[test]
    fn sampler_draws_from_pool() {
        let pool = vec![3, 5, 9];
        let mut s = Sampler::new(&pool, 1);
        for i in s.draw(100) {
            assert!(pool.contains(&i));
        }
    }

    #[test]
    fn sampler_state_roundtrip_continues_the_stream() {
        let pool: Vec<usize> = (0..37).collect();
        let mut a = Sampler::new(&pool, 5);
        a.draw(13);
        let snap = a.rng_state();
        let tail_a = a.draw(20);
        let mut b = Sampler::from_state(&pool, snap);
        assert_eq!(b.draw(20), tail_a, "restored sampler must replay identically");
    }

    #[test]
    fn examples_are_never_empty_and_len_agrees() {
        for e in generate(sst2(), 30, 512, None, 11) {
            assert!(!e.is_empty());
            assert_eq!(e.len(), e.context.len() + 1);
        }
    }

    #[test]
    fn candidate_batch_has_one_row_per_class() {
        let ex = &generate(opt_task("cb").unwrap(), 1, 512, None, 8)[0];
        let b = candidate_batch(ex);
        assert_eq!(b.batch, 3);
        // all rows share the context, differ in the last token
        let last0 = b.ids[b.seq - 1];
        let last1 = b.ids[2 * b.seq - 1];
        assert_ne!(last0, last1);
    }

    #[test]
    fn signal_tokens_are_class_banded() {
        // With signal=1.0 every context token lies in the class band.
        let mut t = *sst2();
        t.signal = 1.0;
        let ex = generate(&t, 10, 512, None, 9);
        let first_ctx = 1 + t.n_classes as i32;
        let band = (512 - first_ctx) / t.n_classes as i32;
        for e in ex {
            let lo = first_ctx + e.answer as i32 * band;
            let hi = lo + band;
            assert!(e.context.iter().all(|&t| t >= lo && t < hi));
        }
    }
}
