//! The task catalog: synthetic stand-ins for the paper's datasets.
//!
//! Each task mirrors the corresponding dataset's *statistical shape*:
//! number of classes, and a right-skewed sequence-length distribution
//! (log-normal, Fig. 6) with the `L_max` that drives the paper's memory
//! results (MultiRC's documented `L_max = 739`; the others tuned so the
//! OOM pattern of Tables 12-15 reproduces under the memory model — see
//! DESIGN.md §3).
//!
//! Content is a planted-signal classification problem: context tokens are
//! drawn from a class-conditional mixture, the final token is the class
//! verbalizer, and the model is scored exactly the way the paper scores
//! OPT (App. D.3): per-candidate average log-likelihood.

/// Length distribution: log-normal with median `median`, log-std `sigma`,
/// truncated to `[min_len, l_max]`.
#[derive(Clone, Copy, Debug)]
pub struct LengthDist {
    pub median: f64,
    pub sigma: f64,
    pub min_len: usize,
    pub l_max: usize,
}

/// Task category (mirrors the paper's Table 12 "task type" row).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskType {
    Classification,
    MultipleChoice,
    Generation,
}

/// A synthetic task definition.
#[derive(Clone, Copy, Debug)]
pub struct TaskDef {
    pub name: &'static str,
    pub n_classes: usize,
    pub task_type: TaskType,
    pub lengths: LengthDist,
    /// Probability that a context token carries the class signal.
    pub signal: f64,
    /// Is this one of the "long" datasets in the paper's Table 1 split?
    pub long: bool,
}

macro_rules! task {
    ($name:expr, $nc:expr, $ty:expr, $med:expr, $sig:expr, $min:expr, $lmax:expr, $signal:expr, $long:expr) => {
        TaskDef {
            name: $name,
            n_classes: $nc,
            task_type: $ty,
            lengths: LengthDist { median: $med, sigma: $sig, min_len: $min, l_max: $lmax },
            signal: $signal,
            long: $long,
        }
    };
}

use TaskType::*;

/// The nine OPT tasks of Table 12 (+ COPA for Fig. 3-right).
pub const OPT_TASKS: &[TaskDef] = &[
    task!("sst2", 2, Classification, 28.0, 0.35, 12, 60, 0.50, false),
    task!("rte", 2, Classification, 64.0, 0.55, 24, 280, 0.45, false),
    task!("cb", 3, Classification, 70.0, 0.50, 28, 270, 0.50, false),
    task!("boolq", 2, Classification, 180.0, 0.55, 60, 700, 0.40, true),
    task!("wsc", 2, Classification, 38.0, 0.45, 16, 120, 0.45, false),
    task!("wic", 2, Classification, 36.0, 0.45, 16, 110, 0.45, false),
    task!("multirc", 2, Classification, 260.0, 0.45, 80, 739, 0.40, true),
    task!("record", 4, MultipleChoice, 26.0, 0.30, 14, 48, 0.50, false),
    task!("squad", 8, Generation, 200.0, 0.50, 60, 680, 0.42, true),
    task!("copa", 2, MultipleChoice, 22.0, 0.30, 12, 40, 0.52, false),
];

/// The six RoBERTa-large tasks of Table 11 (short, k-shot).
pub const ROBERTA_TASKS: &[TaskDef] = &[
    task!("sst2", 2, Classification, 28.0, 0.35, 12, 60, 0.50, false),
    task!("sst5", 5, Classification, 28.0, 0.35, 12, 60, 0.42, false),
    task!("snli", 3, Classification, 34.0, 0.40, 14, 80, 0.45, false),
    task!("mnli", 3, Classification, 36.0, 0.40, 14, 90, 0.45, false),
    task!("rte", 2, Classification, 48.0, 0.45, 20, 120, 0.45, false),
    task!("trec", 6, Classification, 16.0, 0.30, 8, 36, 0.50, false),
];

/// Look up an OPT task by name.
pub fn opt_task(name: &str) -> Option<&'static TaskDef> {
    OPT_TASKS.iter().find(|t| t.name == name)
}

/// Look up a RoBERTa task by name.
pub fn roberta_task(name: &str) -> Option<&'static TaskDef> {
    ROBERTA_TASKS.iter().find(|t| t.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn multirc_has_documented_lmax() {
        assert_eq!(opt_task("multirc").unwrap().lengths.l_max, 739);
    }

    #[test]
    fn long_short_split_matches_table1() {
        // Paper Table 1: short = {SST-2, RTE, WSC, WIC}, long = {BoolQ,
        // MultiRC, SQuAD}.
        for name in ["sst2", "rte", "wsc", "wic"] {
            assert!(!opt_task(name).unwrap().long, "{name}");
        }
        for name in ["boolq", "multirc", "squad"] {
            assert!(opt_task(name).unwrap().long, "{name}");
        }
    }

    #[test]
    fn all_tasks_have_sane_distributions() {
        for t in OPT_TASKS.iter().chain(ROBERTA_TASKS) {
            assert!(t.lengths.min_len < t.lengths.l_max, "{}", t.name);
            assert!(t.lengths.median >= t.lengths.min_len as f64, "{}", t.name);
            assert!(t.lengths.median <= t.lengths.l_max as f64, "{}", t.name);
            assert!(t.n_classes >= 2, "{}", t.name);
        }
    }

    #[test]
    fn lookup() {
        assert!(opt_task("sst2").is_some());
        assert!(opt_task("nope").is_none());
        assert!(roberta_task("trec").is_some());
    }
}
