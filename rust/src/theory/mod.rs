//! Theory substrate: empirical validation of Theorems 3.1 / 3.2 and the
//! optimal-α formula on closed-form objectives.
//!
//! The paper proves, for smooth losses with bounded-variance stochastic
//! gradients:
//!
//! * **Thm 3.1 (nonconvex):**
//!   `E‖∇L‖² = O( T^{-1/2} · sqrt((1−α)²/K¹ + α²d/K⁰) )`,
//!   nearly dimension-free at `α* = K⁰/(K⁰ + dK¹)`;
//! * **Thm 3.2 (strongly convex):**
//!   `E‖θ_T − θ*‖² = O( ln T / T · ((1−α)²/K¹ + α²d/K⁰) )`.
//!
//! These experiments run Addax on the [`QuadraticExec`] mock (which
//! satisfies assumptions G.1/G.2/G.4 exactly) and measure how the error
//! scales with `T`, `d` and `α` — `repro theory` prints the tables and
//! EXPERIMENTS.md records the fitted exponents.

use anyhow::Result;

use crate::optim::{Addax, MeZo, Optimizer, StepBatches};
use crate::params::ParamStore;
use crate::runtime::mock::QuadraticExec;
use crate::runtime::TokenBatch;
use crate::zorng::Xoshiro256;

/// Outcome of one synthetic optimization run.
#[derive(Clone, Copy, Debug)]
pub struct TheoryRun {
    pub d: usize,
    pub t: usize,
    pub alpha: f32,
    /// Final ‖∇L(θ_T)‖² (noise-free).
    pub grad_norm_sq: f64,
    /// Final ‖θ_T − θ*‖².
    pub dist_sq: f64,
    /// Mean ‖∇L‖² over the trajectory (the quantity Thm 3.1 bounds).
    pub mean_grad_norm_sq: f64,
}

fn batch(n: usize, rng: &mut Xoshiro256) -> TokenBatch {
    let rows: Vec<_> = (0..n)
        .map(|_| (vec![rng.next_below(1 << 20) as i32 + 1], vec![-1]))
        .collect();
    TokenBatch::from_rows(&rows)
}

/// Run Addax (or MeZO if `mezo=true`) on a d-dimensional quadratic.
pub fn run_synthetic(
    d: usize,
    t: usize,
    alpha: f32,
    k0: usize,
    k1: usize,
    lr: f32,
    sigma: f32,
    mezo: bool,
    seed: u64,
) -> Result<TheoryRun> {
    let mut exec = QuadraticExec::new(d, 0.5, 2.0, sigma, seed ^ 0xABCD);
    let mut params = ParamStore::zeros(&[("w".to_string(), vec![d])]);
    let mut rng = Xoshiro256::new(seed);
    let mut opt_addax;
    let mut opt_mezo;
    let opt: &mut dyn Optimizer = if mezo {
        opt_mezo = MeZo::new(lr, 1e-4, k0);
        &mut opt_mezo
    } else {
        opt_addax = Addax::new(lr, 1e-4, alpha, k0, k1);
        &mut opt_addax
    };
    let needs = opt.needs();
    let mut grad_sum = 0.0;
    for s in 0..t {
        let batches = StepBatches {
            fo: (needs.fo > 0).then(|| batch(needs.fo, &mut rng)),
            zo: (needs.zo > 0).then(|| batch(needs.zo, &mut rng)),
        };
        opt.step(&mut params, &mut exec, &batches, seed ^ (s as u64 * 2654435761))?;
        grad_sum += exec.grad_norm_sq(&params);
    }
    Ok(TheoryRun {
        d,
        t,
        alpha,
        grad_norm_sq: exec.grad_norm_sq(&params),
        dist_sq: exec.dist_sq(&params),
        mean_grad_norm_sq: grad_sum / t as f64,
    })
}

/// Fit the exponent `p` in `err ≈ c · T^{-p}` from (T, err) pairs.
pub fn fit_rate_exponent(points: &[(usize, f64)]) -> f64 {
    // least squares on log-log
    let n = points.len() as f64;
    let (mut sx, mut sy, mut sxx, mut sxy) = (0.0, 0.0, 0.0, 0.0);
    for &(t, e) in points {
        let x = (t as f64).ln();
        let y = e.max(1e-300).ln();
        sx += x;
        sy += y;
        sxx += x * x;
        sxy += x * y;
    }
    let slope = (n * sxy - sx * sy) / (n * sxx - sx * sx);
    -slope
}

/// Sweep α at fixed (K⁰, K¹, d): the variance factor the theorems share.
pub fn variance_factor(alpha: f64, k0: usize, k1: usize, d: usize) -> f64 {
    (1.0 - alpha).powi(2) / k1 as f64 + alpha * alpha * d as f64 / k0 as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strongly_convex_rate_near_one_over_t() {
        // Thm 3.2: dist ~ ln(T)/T ⇒ fitted exponent ≈ 1.
        let mut pts = Vec::new();
        for &t in &[200usize, 400, 800, 1600] {
            // lr ~ ln(T)/(mu T) per the theorem; mu = 0.5
            let lr = ((t as f32).ln() / (0.25 * t as f32)).min(0.4);
            let r = run_synthetic(16, t, 0.2, 4, 4, lr, 0.3, false, 11).unwrap();
            pts.push((t, r.dist_sq));
        }
        let p = fit_rate_exponent(&pts);
        assert!(p > 0.6 && p < 1.6, "fitted exponent {p} (points {pts:?})");
    }

    #[test]
    fn addax_dimension_dependence_much_weaker_than_mezo() {
        // At fixed T and tuned-for-small-d lr, MeZO degrades with d much
        // faster than Addax with small α (Remark 1).
        let t = 600;
        let mut addax_ratio = Vec::new();
        let mut mezo_ratio = Vec::new();
        for &d in &[8usize, 128] {
            let alpha = Addax::optimal_alpha(4, 4, d);
            let a = run_synthetic(d, t, alpha, 4, 4, 0.05, 0.2, false, 5).unwrap();
            let m = run_synthetic(d, t, 1.0, 4, 4, 0.05 / (d as f32).sqrt(), 0.2, true, 5)
                .unwrap();
            addax_ratio.push(a.dist_sq / d as f64);
            mezo_ratio.push(m.dist_sq / d as f64);
        }
        // Addax per-coordinate error roughly flat in d; MeZO's grows.
        assert!(
            mezo_ratio[1] / mezo_ratio[0].max(1e-12)
                > 3.0 * (addax_ratio[1] / addax_ratio[0].max(1e-12)),
            "addax {addax_ratio:?} mezo {mezo_ratio:?}"
        );
    }

    #[test]
    fn variance_factor_minimized_at_optimal_alpha() {
        let (k0, k1, d) = (6, 4, 500);
        let a_star = Addax::optimal_alpha(k0, k1, d) as f64;
        let at_star = variance_factor(a_star, k0, k1, d);
        for a in [0.0, 0.1, 0.5, 0.9, 1.0] {
            assert!(variance_factor(a, k0, k1, d) >= at_star - 1e-12, "α={a}");
        }
    }

    #[test]
    fn rate_exponent_fitter_recovers_known_slope() {
        let pts: Vec<(usize, f64)> =
            [100usize, 200, 400, 800].iter().map(|&t| (t, 5.0 / t as f64)).collect();
        let p = fit_rate_exponent(&pts);
        assert!((p - 1.0).abs() < 1e-6, "{p}");
    }
}
