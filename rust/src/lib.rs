//! # Addax — memory-efficient LM fine-tuning with mixed ZO/FO gradients
//!
//! Rust + JAX + Pallas reproduction of *"Addax: Utilizing Zeroth-Order
//! Gradients to Improve Memory Efficiency and Performance of SGD for
//! Fine-Tuning Language Models"* (ICLR 2025).
//!
//! Three layers:
//! * **L1** (`python/compile/kernels/`): Pallas flash-attention, fused
//!   softmax-xent, layernorm — build-time only.
//! * **L2** (`python/compile/model.py`): OPT-style transformer lowered
//!   once to HLO-text artifacts.
//! * **L3** (this crate): the training coordinator — data partitioning by
//!   sequence length, seed-replay zeroth-order perturbation, in-place
//!   optimizers (Addax, MeZO, IP-SGD, SGD, Adam, hybrid ZO-FO), the GPU
//!   memory simulator, the memory-aware sweep scheduler (`sched/`) that
//!   packs concurrent runs onto device budgets behind a resumable
//!   manifest, the crash-safe checkpoint subsystem (`ckpt/`: versioned
//!   CRC-checked tensor snapshots giving every run byte-identical
//!   step-level resume), the live observability plane (`obs/`: an
//!   opt-in embedded HTTP probe server over running sweeps), and the
//!   experiment harness regenerating every table/figure of the paper
//!   as pure aggregations over that manifest.
//!
//! Python never runs on the training path: the `addax` binary is
//! self-contained once `make artifacts` has produced `artifacts/`.

pub mod ckpt;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod ioutil;
pub mod jsonlite;
pub mod metrics;
pub mod memory;
pub mod obs;
pub mod optim;
pub mod params;
pub mod repro;
pub mod runtime;
pub mod sched;
pub mod tensor;
pub mod theory;
pub mod zorng;
