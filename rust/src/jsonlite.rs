//! Minimal JSON parser + writer (this build environment is offline and the
//! vendored crate set has no serde), sufficient for `artifacts/manifest.json`
//! and the experiment result files.
//!
//! Supports the full JSON grammar except exotic number forms; numbers are
//! parsed as `f64` with an exactness check for integer accessors.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (wanted key {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 || n > u64::MAX as f64 {
            bail!("not a non-negative integer: {n}");
        }
        Ok(n as usize)
    }

    pub fn as_u64(&self) -> Result<u64> {
        Ok(self.as_usize()? as u64)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool"),
        }
    }

    // -- writer ----------------------------------------------------------

    /// Serialize (compact).
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Convenience builder for object literals.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(key, v);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', got {:?} at byte {}", c as char, self.i),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected ',' or ']', got {:?} at byte {}", c as char, self.i),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // Surrogate pairs: handle the common BMP case,
                            // replace unpaired surrogates.
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // Re-scan UTF-8 multibyte sequences properly.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let width = utf8_width(c);
                        let end = start + width;
                        if end > self.b.len() {
                            bail!("truncated UTF-8");
                        }
                        s.push_str(std::str::from_utf8(&self.b[start..end])?);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        let n: f64 = text.parse().map_err(|_| anyhow!("bad number {text:?}"))?;
        Ok(Json::Num(n))
    }
}

fn utf8_width(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shaped_json() {
        let text = r#"{
            "format_version": 1,
            "models": {
                "tiny": {
                    "impl": "pallas", "causal": true, "n_params": 141056,
                    "params": [{"name": "embed.tok", "shape": [512, 64]}],
                    "artifacts": [
                        {"kind": "forward", "batch": 8, "seq_len": 32, "file": "a.hlo.txt"}
                    ]
                }
            }
        }"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("format_version").unwrap().as_usize().unwrap(), 1);
        let tiny = v.get("models").unwrap().get("tiny").unwrap();
        assert_eq!(tiny.get("impl").unwrap().as_str().unwrap(), "pallas");
        assert!(tiny.get("causal").unwrap().as_bool().unwrap());
        let shape = tiny.get("params").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[0].as_usize().unwrap(), 512);
    }

    #[test]
    fn roundtrips_through_dump() {
        let v = obj(vec![
            ("a", Json::from(1.5)),
            ("b", Json::from(vec![1usize, 2, 3])),
            ("c", Json::from("hi \"there\"\n")),
            ("d", Json::Null),
            ("e", Json::from(true)),
        ]);
        let text = v.dump();
        let back = Json::parse(&text).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn numbers_scientific_and_negative() {
        assert_eq!(Json::parse("-1.5e3").unwrap().as_f64().unwrap(), -1500.0);
        assert!(Json::parse("1.5").unwrap().as_usize().is_err());
        assert_eq!(Json::parse("42").unwrap().as_usize().unwrap(), 42);
    }

    #[test]
    fn unicode_strings() {
        let v = Json::parse(r#""héllo A""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo A");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }
}
