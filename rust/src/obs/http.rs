//! The embedded probe server: a deliberately tiny HTTP/1.1 subset over
//! std's `TcpListener` — no new dependencies, no async runtime.
//!
//! Split for testability: [`parse_request_line`] / [`parse_query`] and
//! [`route`] are pure functions unit-tested without sockets; only
//! [`ProbeServer`] owns threads. The server handles one connection at a
//! time (a probe plane serves an operator's `curl`, not traffic), reads
//! with a 2 s timeout so a half-open client cannot wedge it, and always
//! answers `Connection: close`.
//!
//! Endpoints:
//!
//! | verb | path | meaning |
//! |------|------|---------|
//! | GET  | `/runs` | every registered run's live status |
//! | GET  | `/runs/<id>` | one run's status |
//! | GET  | `/runs/<id>/metrics?fields=a,b&last=N` | recent telemetry rows, projected |
//! | GET  | `/mem?slope=S` | analytic footprint vs. RSS + leak verdict |
//! | GET  | `/healthz` | liveness |
//! | POST | `/runs/<id>/checkpoint\|pause\|resume\|abort` | arm a control flag |
//!
//! Control verbs return `202 Accepted`: they arm a flag the training
//! loop consumes at its next step boundary — nothing happens inline
//! with the HTTP request, which is exactly why a probed run stays
//! byte-identical to an unprobed one (see the [module docs](super)).

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::mem::{self, MemSamples, DEFAULT_LEAK_SLOPE};
use super::StatusBoard;
use crate::jsonlite::{obj, Json};

/// Default row count for `/runs/<id>/metrics` when `last` is absent.
pub const DEFAULT_LAST: usize = 50;

/// RSS sampling cadence of the background sampler thread.
const SAMPLE_EVERY: Duration = Duration::from_millis(250);

/// Decode `%XX` escapes and `+`-as-space. Invalid escapes pass through
/// verbatim — a probe server should answer 404, not panic, on junk.
fn percent_decode(s: &str) -> String {
    let b = s.as_bytes();
    let mut out = Vec::with_capacity(b.len());
    let mut i = 0;
    while i < b.len() {
        match b[i] {
            b'+' => {
                out.push(b' ');
                i += 1;
            }
            b'%' if i + 3 <= b.len() => {
                // Work on raw bytes: slicing the &str here could land
                // mid-way through a multibyte char and panic.
                let hex = std::str::from_utf8(&b[i + 1..i + 3])
                    .ok()
                    .and_then(|h| u8::from_str_radix(h, 16).ok());
                match hex {
                    Some(v) => {
                        out.push(v);
                        i += 3;
                    }
                    None => {
                        out.push(b'%');
                        i += 1;
                    }
                }
            }
            c => {
                out.push(c);
                i += 1;
            }
        }
    }
    String::from_utf8_lossy(&out).into_owned()
}

/// Parse `k=v&k2=v2` into decoded pairs. Bare keys get empty values.
pub fn parse_query(q: &str) -> Vec<(String, String)> {
    q.split('&')
        .filter(|kv| !kv.is_empty())
        .map(|kv| match kv.split_once('=') {
            Some((k, v)) => (percent_decode(k), percent_decode(v)),
            None => (percent_decode(kv), String::new()),
        })
        .collect()
}

/// Parse an HTTP/1.x request line into `(METHOD, decoded path, query)`.
/// `None` on anything that is not a plausible request line.
pub fn parse_request_line(line: &str) -> Option<(String, String, Vec<(String, String)>)> {
    let mut it = line.split_whitespace();
    let method = it.next()?.to_ascii_uppercase();
    let target = it.next()?;
    let version = it.next()?;
    if !version.starts_with("HTTP/") || !target.starts_with('/') {
        return None;
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p, parse_query(q)),
        None => (target, Vec::new()),
    };
    Some((method, percent_decode(path), query))
}

fn err_json(msg: &str) -> Json {
    obj(vec![("error", Json::from(msg))])
}

fn not_found() -> (u16, Json) {
    (404, err_json("not found"))
}

fn opt_num(v: Option<f64>) -> Json {
    v.map(Json::from).unwrap_or(Json::Null)
}

/// The `/mem` payload: analytic model vs. measured reality, plus the
/// least-squares leak verdict over the sampler window.
fn mem_report(board: &StatusBoard, samples: &MemSamples, threshold: f64) -> Json {
    let fit = samples.fit();
    obj(vec![
        ("rss_bytes", opt_num(mem::rss_bytes().map(|b| b as f64))),
        ("analytic_bytes", Json::from(board.analytic_bytes())),
        ("samples", Json::from(samples.len())),
        ("elapsed_secs", opt_num(samples.last().map(|(t, _)| t))),
        ("slope_bytes_per_sec", opt_num(fit.map(|(s, _)| s))),
        ("r2", opt_num(fit.map(|(_, r2)| r2))),
        ("threshold_bytes_per_sec", Json::from(threshold)),
        ("leak_suspected", Json::from(samples.leak_suspected(threshold))),
    ])
}

/// Pure router: `(method, path, query)` → `(status, JSON body)`.
/// Everything observable about the probe API is decided here.
pub fn route(
    board: &StatusBoard,
    samples: &MemSamples,
    method: &str,
    path: &str,
    query: &[(String, String)],
) -> (u16, Json) {
    let parts: Vec<&str> = path.split('/').filter(|s| !s.is_empty()).collect();
    let q = |k: &str| query.iter().find(|(key, _)| key == k).map(|(_, v)| v.as_str());
    match (method, parts.as_slice()) {
        ("GET", []) | ("GET", ["healthz"]) => (200, obj(vec![("ok", Json::from(true))])),
        ("GET", ["runs"]) => (
            200,
            obj(vec![("n", Json::from(board.len())), ("runs", board.runs_json())]),
        ),
        ("GET", ["runs", id]) => match board.get(id) {
            Some(p) => (200, p.to_json()),
            None => not_found(),
        },
        ("GET", ["runs", id, "metrics"]) => match board.get(id) {
            Some(p) => {
                let fields: Option<Vec<String>> = q("fields").map(|f| {
                    f.split(',').filter(|s| !s.is_empty()).map(str::to_string).collect()
                });
                let last = match q("last").map(str::parse::<usize>) {
                    Some(Ok(n)) => n,
                    Some(Err(_)) => return (400, err_json("last must be a non-negative integer")),
                    None => DEFAULT_LAST,
                };
                (
                    200,
                    obj(vec![
                        ("run_id", Json::from(*id)),
                        ("rows", p.metrics_json(fields.as_deref(), last)),
                    ]),
                )
            }
            None => not_found(),
        },
        ("GET", ["mem"]) => {
            let threshold = match q("slope").map(str::parse::<f64>) {
                Some(Ok(v)) => v,
                Some(Err(_)) => return (400, err_json("slope must be a number (bytes/sec)")),
                None => DEFAULT_LEAK_SLOPE,
            };
            (200, mem_report(board, samples, threshold))
        }
        ("POST", ["runs", id, verb]) => match board.get(id) {
            Some(p) => {
                match *verb {
                    "checkpoint" => p.request_checkpoint(),
                    "pause" => p.request_pause(),
                    "resume" => p.request_resume(),
                    "abort" => p.request_abort(),
                    _ => return not_found(),
                }
                (
                    202,
                    obj(vec![
                        ("ok", Json::from(true)),
                        ("run_id", Json::from(*id)),
                        ("verb", Json::from(*verb)),
                    ]),
                )
            }
            None => not_found(),
        },
        ("GET", _) | ("POST", _) => not_found(),
        _ => (405, err_json("method not allowed")),
    }
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        _ => "OK",
    }
}

fn write_response(stream: &mut TcpStream, status: u16, body: &Json) -> std::io::Result<()> {
    let text = body.dump();
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        status,
        reason(status),
        text.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(text.as_bytes())?;
    stream.flush()
}

fn handle_conn(
    mut stream: TcpStream,
    board: &StatusBoard,
    samples: &Mutex<MemSamples>,
) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(2)))?;
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 1024];
    // Read until end-of-headers; any body (control POSTs carry none
    // worth reading) is ignored.
    while !buf.windows(4).any(|w| w == b"\r\n\r\n") && buf.len() < 16 * 1024 {
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            break;
        }
        buf.extend_from_slice(&chunk[..n]);
    }
    let text = String::from_utf8_lossy(&buf);
    let (status, body) = match text.lines().next().and_then(parse_request_line) {
        Some((method, path, query)) => {
            let snap = samples.lock().unwrap_or_else(|p| p.into_inner()).clone();
            route(board, &snap, &method, &path, &query)
        }
        None => (400, err_json("malformed request line")),
    };
    write_response(&mut stream, status, &body)
}

/// The running probe server: an accept-loop thread plus a background
/// RSS sampler feeding the `/mem` window. Binds loopback only — this
/// is an operator's local window, not a network service. Dropping it
/// stops both threads (a self-connection unblocks the accept loop).
pub struct ProbeServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    sampler: Option<JoinHandle<()>>,
}

impl ProbeServer {
    /// Bind `127.0.0.1:port` (`0` = kernel-assigned ephemeral port;
    /// read it back with [`ProbeServer::port`]) and start serving.
    pub fn start(board: StatusBoard, port: u16) -> Result<ProbeServer> {
        let listener = TcpListener::bind(("127.0.0.1", port))
            .with_context(|| format!("probe: cannot bind 127.0.0.1:{port}"))?;
        let addr = listener.local_addr().context("probe: local_addr")?;
        let stop = Arc::new(AtomicBool::new(false));
        let samples = Arc::new(Mutex::new(MemSamples::default()));

        let sampler = {
            let stop = Arc::clone(&stop);
            let samples = Arc::clone(&samples);
            std::thread::spawn(move || {
                let t0 = Instant::now();
                while !stop.load(Ordering::Relaxed) {
                    if let Some(rss) = mem::rss_bytes() {
                        samples
                            .lock()
                            .unwrap_or_else(|p| p.into_inner())
                            .push(t0.elapsed().as_secs_f64(), rss as f64);
                    }
                    // Sleep in short slices so Drop returns promptly.
                    let mut slept = Duration::ZERO;
                    while slept < SAMPLE_EVERY && !stop.load(Ordering::Relaxed) {
                        let slice = Duration::from_millis(50);
                        std::thread::sleep(slice);
                        slept += slice;
                    }
                }
            })
        };

        let accept = {
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                for stream in listener.incoming() {
                    if stop.load(Ordering::Relaxed) {
                        break;
                    }
                    if let Ok(stream) = stream {
                        // Per-connection errors (client hung up mid-read)
                        // must not kill the server.
                        let _ = handle_conn(stream, &board, &samples);
                    }
                }
            })
        };

        Ok(ProbeServer { addr, stop, accept: Some(accept), sampler: Some(sampler) })
    }

    pub fn port(&self) -> u16 {
        self.addr.port()
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Drop for ProbeServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // Unblock the accept loop; it checks `stop` before serving.
        let _ = TcpStream::connect_timeout(&self.addr, Duration::from_millis(200));
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(h) = self.sampler.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn get(board: &StatusBoard, path: &str) -> (u16, Json) {
        let (m, p, q) = parse_request_line(&format!("GET {path} HTTP/1.1")).unwrap();
        route(board, &MemSamples::default(), &m, &p, &q)
    }

    fn post(board: &StatusBoard, path: &str) -> (u16, Json) {
        let (m, p, q) = parse_request_line(&format!("POST {path} HTTP/1.1")).unwrap();
        route(board, &MemSamples::default(), &m, &p, &q)
    }

    #[test]
    fn request_line_parsing() {
        let (m, p, q) = parse_request_line("GET /runs HTTP/1.1").unwrap();
        assert_eq!((m.as_str(), p.as_str()), ("GET", "/runs"));
        assert!(q.is_empty());

        let (m, p, q) =
            parse_request_line("post /runs/a%20b/metrics?fields=loss,step&last=5 HTTP/1.0")
                .unwrap();
        assert_eq!(m, "POST", "method is upcased");
        assert_eq!(p, "/runs/a b/metrics", "path is percent-decoded");
        assert_eq!(
            q,
            vec![
                ("fields".to_string(), "loss,step".to_string()),
                ("last".to_string(), "5".to_string())
            ]
        );

        assert!(parse_request_line("").is_none());
        assert!(parse_request_line("GET").is_none());
        assert!(parse_request_line("GET /x FTP/9").is_none(), "not-HTTP version");
        assert!(parse_request_line("GET runs HTTP/1.1").is_none(), "relative target");
    }

    #[test]
    fn query_parsing_handles_bare_keys_and_escapes() {
        let q = parse_query("a=1&b&c=x%2Cy&d=p+q&");
        assert_eq!(
            q,
            vec![
                ("a".into(), "1".into()),
                ("b".into(), String::new()),
                ("c".into(), "x,y".into()),
                ("d".into(), "p q".into()),
            ]
        );
    }

    #[test]
    fn router_status_codes() {
        let board = StatusBoard::new();
        board.register("run1", 10);

        assert_eq!(get(&board, "/healthz").0, 200);
        assert_eq!(get(&board, "/runs").0, 200);
        assert_eq!(get(&board, "/runs/run1").0, 200);
        assert_eq!(get(&board, "/runs/ghost").0, 404);
        assert_eq!(get(&board, "/nope").0, 404);
        assert_eq!(get(&board, "/runs/run1/metrics?last=zebra").0, 400);
        assert_eq!(get(&board, "/mem?slope=fast").0, 400);
        assert_eq!(post(&board, "/runs/run1/dance").0, 404);
        assert_eq!(post(&board, "/runs/ghost/abort").0, 404);

        let (m, p, q) = parse_request_line("DELETE /runs HTTP/1.1").unwrap();
        assert_eq!(route(&board, &MemSamples::default(), &m, &p, &q).0, 405);
    }

    #[test]
    fn control_verbs_arm_flags() {
        let board = StatusBoard::new();
        let probe = board.register("r", 10);

        assert_eq!(post(&board, "/runs/r/checkpoint").0, 202);
        assert!(probe.take_checkpoint_request());
        assert_eq!(post(&board, "/runs/r/pause").0, 202);
        assert!(probe.paused());
        assert_eq!(post(&board, "/runs/r/resume").0, 202);
        assert!(!probe.paused());
        assert_eq!(post(&board, "/runs/r/abort").0, 202);
        assert!(probe.abort_requested());
    }

    #[test]
    fn metrics_projection_and_last() {
        let board = StatusBoard::new();
        let probe = board.register("r", 10);
        for i in 0..20usize {
            probe.record_step(
                i,
                i as f64,
                0.0,
                obj(vec![
                    ("step", Json::from(i)),
                    ("loss", Json::from(i as f64)),
                    ("grad_norm", Json::from(1.0)),
                ]),
            );
        }
        let (code, body) = get(&board, "/runs/r/metrics?fields=step,loss&last=3");
        assert_eq!(code, 200);
        let rows = body.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].get("step").unwrap().as_usize().unwrap(), 17);
        assert!(rows[0].opt("grad_norm").is_none(), "projection drops unrequested fields");
        assert_eq!(rows[2].get("loss").unwrap().as_f64().unwrap(), 19.0);
    }

    #[test]
    fn mem_endpoint_reports_threshold_override() {
        let board = StatusBoard::new();
        board.register("r", 10).set_footprint_bytes(123.0);
        let (code, body) = get(&board, "/mem?slope=42.5");
        assert_eq!(code, 200);
        assert_eq!(body.get("threshold_bytes_per_sec").unwrap().as_f64().unwrap(), 42.5);
        assert_eq!(body.get("analytic_bytes").unwrap().as_f64().unwrap(), 123.0);
        assert_eq!(body.get("leak_suspected").unwrap().as_bool().unwrap(), false);
    }

    #[test]
    fn live_server_round_trip() {
        let board = StatusBoard::new();
        let probe = board.register("live-run", 40);
        probe.record_step(
            2,
            0.25,
            0.5,
            obj(vec![("step", Json::from(2usize)), ("loss", Json::from(0.25))]),
        );
        let server = ProbeServer::start(board.clone(), 0).unwrap();
        assert_ne!(server.port(), 0, "ephemeral port resolved");

        let fetch = |req: &str| -> (String, Json) {
            let mut s = TcpStream::connect(server.addr()).unwrap();
            s.write_all(req.as_bytes()).unwrap();
            let mut resp = String::new();
            s.read_to_string(&mut resp).unwrap();
            let (head, body) = resp.split_once("\r\n\r\n").expect("header/body split");
            (head.lines().next().unwrap().to_string(), Json::parse(body).unwrap())
        };

        let (status, body) = fetch("GET /runs HTTP/1.1\r\nHost: t\r\n\r\n");
        assert!(status.contains("200"), "{status}");
        assert_eq!(body.get("n").unwrap().as_usize().unwrap(), 1);
        let run = &body.get("runs").unwrap().as_arr().unwrap()[0];
        assert_eq!(run.get("run_id").unwrap().as_str().unwrap(), "live-run");
        assert_eq!(run.get("step").unwrap().as_usize().unwrap(), 2);

        let (status, _) = fetch("POST /runs/live-run/abort HTTP/1.1\r\nHost: t\r\n\r\n");
        assert!(status.contains("202"), "{status}");
        assert!(probe.abort_requested(), "verb armed through the real socket path");

        let (status, body) = fetch("GET /mem HTTP/1.1\r\nHost: t\r\n\r\n");
        assert!(status.contains("200"), "{status}");
        assert!(body.opt("rss_bytes").is_some());

        let (status, _) = fetch("BOGUS-LINE\r\n\r\n");
        assert!(status.contains("400"), "{status}");

        drop(server); // must join cleanly, not hang
    }
}
