//! The embedded probe server: a deliberately tiny HTTP/1.1 subset over
//! std's `TcpListener` — no new dependencies, no async runtime.
//!
//! Split for testability: [`parse_request_line`] / [`parse_query`] and
//! [`route`] are pure functions unit-tested without sockets; only
//! [`ProbeServer`] owns threads. The server handles one connection at a
//! time (a probe plane serves an operator's `curl`, not traffic), reads
//! with a 2 s timeout so a half-open client cannot wedge it, and always
//! answers `Connection: close`.
//!
//! Endpoints:
//!
//! | verb | path | meaning |
//! |------|------|---------|
//! | GET  | `/runs?last=N&summary=1` | every registered run's live status |
//! | GET  | `/runs/<id>?last=N&summary=1` | one run's status |
//! | GET  | `/runs/<id>/metrics?fields=a,b&last=N&where=…&agg=…` | recent telemetry rows, filtered/projected/aggregated |
//! | GET  | `/mem?slope=S` | analytic footprint vs. RSS + leak verdict |
//! | GET  | `/metrics` | Prometheus text exposition ([`prom`](super::prom)) |
//! | GET  | `/healthz` | liveness |
//! | POST | `/runs/<id>/checkpoint\|pause\|resume\|abort` | arm a control flag |
//!
//! `/runs` scrape-size knobs: `last=N` caps each run's loss/val tails
//! (default 5), `summary=1` omits the tails entirely. `/runs/<id>/metrics`
//! query predicates: `where=loss<2.0,step>=100` filters the ring window
//! (clauses ANDed; ops `< <= > >= = !=`), `agg=mean:loss,max:step,count`
//! returns aggregates instead of rows. Grammar in EXPERIMENTS.md
//! §Observability.
//!
//! Control verbs return `202 Accepted`: they arm a flag the training
//! loop consumes at its next step boundary — nothing happens inline
//! with the HTTP request, which is exactly why a probed run stays
//! byte-identical to an unprobed one (see the [module docs](super)).

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::mem::{self, MemSamples, DEFAULT_LEAK_SLOPE};
use super::{prom, StatusBoard, DEFAULT_TAIL};
use crate::jsonlite::{obj, Json};
use crate::metrics::{AggSpec, Predicate};

/// Default row count for `/runs/<id>/metrics` when `last` is absent.
pub const DEFAULT_LAST: usize = 50;

/// RSS sampling cadence of the background sampler thread.
const SAMPLE_EVERY: Duration = Duration::from_millis(250);

/// Default `/mem` leak-detector window in seconds
/// (`--mem-window-secs`); at the 250 ms cadence this is 512 samples.
pub const DEFAULT_MEM_WINDOW_SECS: f64 = 128.0;

/// Sample capacity of a leak-detector window of `secs` seconds at the
/// fixed [`SAMPLE_EVERY`] cadence.
pub fn mem_window_cap(secs: f64) -> usize {
    (secs / SAMPLE_EVERY.as_secs_f64()).ceil().max(2.0) as usize
}

/// Decode `%XX` escapes and `+`-as-space. Invalid escapes pass through
/// verbatim — a probe server should answer 404, not panic, on junk.
fn percent_decode(s: &str) -> String {
    let b = s.as_bytes();
    let mut out = Vec::with_capacity(b.len());
    let mut i = 0;
    while i < b.len() {
        match b[i] {
            b'+' => {
                out.push(b' ');
                i += 1;
            }
            b'%' if i + 3 <= b.len() => {
                // Work on raw bytes: slicing the &str here could land
                // mid-way through a multibyte char and panic.
                let hex = std::str::from_utf8(&b[i + 1..i + 3])
                    .ok()
                    .and_then(|h| u8::from_str_radix(h, 16).ok());
                match hex {
                    Some(v) => {
                        out.push(v);
                        i += 3;
                    }
                    None => {
                        out.push(b'%');
                        i += 1;
                    }
                }
            }
            c => {
                out.push(c);
                i += 1;
            }
        }
    }
    String::from_utf8_lossy(&out).into_owned()
}

/// Parse `k=v&k2=v2` into decoded pairs. Bare keys get empty values.
pub fn parse_query(q: &str) -> Vec<(String, String)> {
    q.split('&')
        .filter(|kv| !kv.is_empty())
        .map(|kv| match kv.split_once('=') {
            Some((k, v)) => (percent_decode(k), percent_decode(v)),
            None => (percent_decode(kv), String::new()),
        })
        .collect()
}

/// Parse an HTTP/1.x request line into `(METHOD, decoded path, query)`.
/// `None` on anything that is not a plausible request line.
pub fn parse_request_line(line: &str) -> Option<(String, String, Vec<(String, String)>)> {
    let mut it = line.split_whitespace();
    let method = it.next()?.to_ascii_uppercase();
    let target = it.next()?;
    let version = it.next()?;
    if !version.starts_with("HTTP/") || !target.starts_with('/') {
        return None;
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p, parse_query(q)),
        None => (target, Vec::new()),
    };
    Some((method, percent_decode(path), query))
}

fn err_json(msg: &str) -> Json {
    obj(vec![("error", Json::from(msg))])
}

fn not_found() -> (u16, Json) {
    (404, err_json("not found"))
}

fn opt_num(v: Option<f64>) -> Json {
    v.map(Json::from).unwrap_or(Json::Null)
}

/// The `/mem` payload: analytic model vs. measured reality, plus the
/// least-squares leak verdict over the sampler window.
fn mem_report(board: &StatusBoard, samples: &MemSamples, threshold: f64) -> Json {
    let fit = samples.fit();
    obj(vec![
        ("rss_bytes", opt_num(mem::rss_bytes().map(|b| b as f64))),
        ("analytic_bytes", Json::from(board.analytic_bytes())),
        ("samples", Json::from(samples.len())),
        ("elapsed_secs", opt_num(samples.last().map(|(t, _)| t))),
        ("slope_bytes_per_sec", opt_num(fit.map(|(s, _)| s))),
        ("r2", opt_num(fit.map(|(_, r2)| r2))),
        ("threshold_bytes_per_sec", Json::from(threshold)),
        ("leak_suspected", Json::from(samples.leak_suspected(threshold))),
    ])
}

/// The `?summary=` flag: present with no value, `1` or `true` all mean
/// "omit the tails"; an explicit `0`/`false` means the default view.
fn summary_flag(v: Option<&str>) -> bool {
    matches!(v, Some("") | Some("1") | Some("true"))
}

/// Pure router: `(method, path, query)` → `(status, JSON body)`.
/// Everything observable about the probe API is decided here — except
/// `GET /metrics`, whose body is Prometheus *text*, handled by
/// [`route_request`] above this JSON layer.
pub fn route(
    board: &StatusBoard,
    samples: &MemSamples,
    method: &str,
    path: &str,
    query: &[(String, String)],
) -> (u16, Json) {
    let parts: Vec<&str> = path.split('/').filter(|s| !s.is_empty()).collect();
    let q = |k: &str| query.iter().find(|(key, _)| key == k).map(|(_, v)| v.as_str());
    let tail = |default: usize| match q("last").map(str::parse::<usize>) {
        Some(Ok(n)) => Ok(n),
        Some(Err(_)) => Err(()),
        None => Ok(default),
    };
    match (method, parts.as_slice()) {
        ("GET", []) | ("GET", ["healthz"]) => (200, obj(vec![("ok", Json::from(true))])),
        ("GET", ["runs"]) => {
            let Ok(rows) = tail(DEFAULT_TAIL) else {
                return (400, err_json("last must be a non-negative integer"));
            };
            (
                200,
                obj(vec![
                    ("n", Json::from(board.len())),
                    ("runs", board.runs_json_opts(rows, summary_flag(q("summary")))),
                ]),
            )
        }
        ("GET", ["runs", id]) => match board.get(id) {
            Some(p) => {
                let Ok(rows) = tail(DEFAULT_TAIL) else {
                    return (400, err_json("last must be a non-negative integer"));
                };
                (200, p.to_json_opts(rows, summary_flag(q("summary"))))
            }
            None => not_found(),
        },
        ("GET", ["runs", id, "metrics"]) => match board.get(id) {
            Some(p) => {
                let fields: Option<Vec<String>> = q("fields").map(|f| {
                    f.split(',').filter(|s| !s.is_empty()).map(str::to_string).collect()
                });
                let Ok(last) = tail(DEFAULT_LAST) else {
                    return (400, err_json("last must be a non-negative integer"));
                };
                let preds = match q("where").map(Predicate::parse_list) {
                    Some(Ok(p)) => p,
                    Some(Err(e)) => return (400, err_json(&format!("bad where clause: {e}"))),
                    None => Vec::new(),
                };
                if let Some(spec) = q("agg") {
                    return match AggSpec::parse_list(spec) {
                        Ok(aggs) => (
                            200,
                            obj(vec![
                                ("run_id", Json::from(*id)),
                                ("agg", p.metrics_agg_json(last, &preds, &aggs)),
                            ]),
                        ),
                        Err(e) => (400, err_json(&format!("bad agg clause: {e}"))),
                    };
                }
                (
                    200,
                    obj(vec![
                        ("run_id", Json::from(*id)),
                        ("rows", p.metrics_json_where(fields.as_deref(), last, &preds)),
                    ]),
                )
            }
            None => not_found(),
        },
        ("GET", ["mem"]) => {
            let threshold = match q("slope").map(str::parse::<f64>) {
                Some(Ok(v)) => v,
                Some(Err(_)) => return (400, err_json("slope must be a number (bytes/sec)")),
                None => DEFAULT_LEAK_SLOPE,
            };
            (200, mem_report(board, samples, threshold))
        }
        ("POST", ["runs", id, verb]) => match board.get(id) {
            Some(p) => {
                match *verb {
                    "checkpoint" => p.request_checkpoint(),
                    "pause" => p.request_pause(),
                    "resume" => p.request_resume(),
                    "abort" => p.request_abort(),
                    _ => return not_found(),
                }
                (
                    202,
                    obj(vec![
                        ("ok", Json::from(true)),
                        ("run_id", Json::from(*id)),
                        ("verb", Json::from(*verb)),
                    ]),
                )
            }
            None => not_found(),
        },
        ("GET", _) | ("POST", _) => not_found(),
        _ => (405, err_json("method not allowed")),
    }
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        500 => "Internal Server Error",
        _ => "OK",
    }
}

/// A routed response body: JSON for the API endpoints, plain text for
/// the Prometheus exposition.
pub enum Payload {
    Json(Json),
    Text(String),
}

/// Full router including the non-JSON endpoint: `GET /metrics` renders
/// the Prometheus text exposition; everything else is [`route`].
pub fn route_request(
    board: &StatusBoard,
    samples: &MemSamples,
    method: &str,
    path: &str,
    query: &[(String, String)],
) -> (u16, Payload) {
    if method == "GET" && path.trim_end_matches('/') == "/metrics" {
        return (200, Payload::Text(prom::render_worker(board, samples)));
    }
    let (status, body) = route(board, samples, method, path, query);
    (status, Payload::Json(body))
}

/// Serialize one HTTP/1.1 response. Shared with the fleet aggregator's
/// server ([`super::fleet`]), which speaks the same tiny subset.
pub(crate) fn write_payload(
    stream: &mut TcpStream,
    status: u16,
    body: &Payload,
) -> std::io::Result<()> {
    let (ctype, text) = match body {
        Payload::Json(v) => ("application/json", v.dump()),
        // The exposition-format content type Prometheus scrapers expect.
        Payload::Text(t) => ("text/plain; version=0.0.4; charset=utf-8", t.clone()),
    };
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        status,
        reason(status),
        ctype,
        text.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(text.as_bytes())?;
    stream.flush()
}

/// Read a request until end-of-headers (2 s timeout, 16 KiB cap) and
/// parse its request line. Shared with the fleet server.
pub(crate) fn read_request(
    stream: &mut TcpStream,
) -> std::io::Result<Option<(String, String, Vec<(String, String)>)>> {
    stream.set_read_timeout(Some(Duration::from_secs(2)))?;
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 1024];
    // Read until end-of-headers; any body (control POSTs carry none
    // worth reading) is ignored.
    while !buf.windows(4).any(|w| w == b"\r\n\r\n") && buf.len() < 16 * 1024 {
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            break;
        }
        buf.extend_from_slice(&chunk[..n]);
    }
    let text = String::from_utf8_lossy(&buf);
    Ok(text.lines().next().and_then(parse_request_line))
}

fn handle_conn(
    mut stream: TcpStream,
    board: &StatusBoard,
    samples: &Mutex<MemSamples>,
) -> std::io::Result<()> {
    let (status, body) = match read_request(&mut stream)? {
        Some((method, path, query)) => {
            let snap = samples.lock().unwrap_or_else(|p| p.into_inner()).clone();
            route_request(board, &snap, &method, &path, &query)
        }
        None => (400, Payload::Json(err_json("malformed request line"))),
    };
    write_payload(&mut stream, status, &body)
}

/// The running probe server: an accept-loop thread plus a background
/// RSS sampler feeding the `/mem` window. Binds loopback only — this
/// is an operator's local window, not a network service. Dropping it
/// stops both threads (a self-connection unblocks the accept loop).
pub struct ProbeServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    sampler: Option<JoinHandle<()>>,
}

impl ProbeServer {
    /// Bind `127.0.0.1:port` (`0` = kernel-assigned ephemeral port;
    /// read it back with [`ProbeServer::port`]) and start serving, with
    /// the default [`DEFAULT_MEM_WINDOW_SECS`] leak-detector window.
    pub fn start(board: StatusBoard, port: u16) -> Result<ProbeServer> {
        Self::start_with_window(board, port, DEFAULT_MEM_WINDOW_SECS)
    }

    /// [`ProbeServer::start`] with an explicit `/mem` leak-detector
    /// window (`--mem-window-secs` / `sweep.mem_window_secs`): the RSS
    /// sampler keeps `window_secs` of history at its fixed 250 ms
    /// cadence, and the slope/r² fit runs over exactly that window.
    pub fn start_with_window(
        board: StatusBoard,
        port: u16,
        window_secs: f64,
    ) -> Result<ProbeServer> {
        let listener = TcpListener::bind(("127.0.0.1", port))
            .with_context(|| format!("probe: cannot bind 127.0.0.1:{port}"))?;
        let addr = listener.local_addr().context("probe: local_addr")?;
        let stop = Arc::new(AtomicBool::new(false));
        let samples = Arc::new(Mutex::new(MemSamples::new(mem_window_cap(window_secs))));

        let sampler = {
            let stop = Arc::clone(&stop);
            let samples = Arc::clone(&samples);
            std::thread::spawn(move || {
                let t0 = Instant::now();
                while !stop.load(Ordering::Relaxed) {
                    if let Some(rss) = mem::rss_bytes() {
                        samples
                            .lock()
                            .unwrap_or_else(|p| p.into_inner())
                            .push(t0.elapsed().as_secs_f64(), rss as f64);
                    }
                    // Sleep in short slices so Drop returns promptly.
                    let mut slept = Duration::ZERO;
                    while slept < SAMPLE_EVERY && !stop.load(Ordering::Relaxed) {
                        let slice = Duration::from_millis(50);
                        std::thread::sleep(slice);
                        slept += slice;
                    }
                }
            })
        };

        let accept = {
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                for stream in listener.incoming() {
                    if stop.load(Ordering::Relaxed) {
                        break;
                    }
                    if let Ok(stream) = stream {
                        // Per-connection errors (client hung up mid-read)
                        // must not kill the server.
                        let _ = handle_conn(stream, &board, &samples);
                    }
                }
            })
        };

        Ok(ProbeServer { addr, stop, accept: Some(accept), sampler: Some(sampler) })
    }

    pub fn port(&self) -> u16 {
        self.addr.port()
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Drop for ProbeServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // Unblock the accept loop; it checks `stop` before serving.
        let _ = TcpStream::connect_timeout(&self.addr, Duration::from_millis(200));
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(h) = self.sampler.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn get(board: &StatusBoard, path: &str) -> (u16, Json) {
        let (m, p, q) = parse_request_line(&format!("GET {path} HTTP/1.1")).unwrap();
        route(board, &MemSamples::default(), &m, &p, &q)
    }

    fn post(board: &StatusBoard, path: &str) -> (u16, Json) {
        let (m, p, q) = parse_request_line(&format!("POST {path} HTTP/1.1")).unwrap();
        route(board, &MemSamples::default(), &m, &p, &q)
    }

    #[test]
    fn request_line_parsing() {
        let (m, p, q) = parse_request_line("GET /runs HTTP/1.1").unwrap();
        assert_eq!((m.as_str(), p.as_str()), ("GET", "/runs"));
        assert!(q.is_empty());

        let (m, p, q) =
            parse_request_line("post /runs/a%20b/metrics?fields=loss,step&last=5 HTTP/1.0")
                .unwrap();
        assert_eq!(m, "POST", "method is upcased");
        assert_eq!(p, "/runs/a b/metrics", "path is percent-decoded");
        assert_eq!(
            q,
            vec![
                ("fields".to_string(), "loss,step".to_string()),
                ("last".to_string(), "5".to_string())
            ]
        );

        assert!(parse_request_line("").is_none());
        assert!(parse_request_line("GET").is_none());
        assert!(parse_request_line("GET /x FTP/9").is_none(), "not-HTTP version");
        assert!(parse_request_line("GET runs HTTP/1.1").is_none(), "relative target");
    }

    #[test]
    fn query_parsing_handles_bare_keys_and_escapes() {
        let q = parse_query("a=1&b&c=x%2Cy&d=p+q&");
        assert_eq!(
            q,
            vec![
                ("a".into(), "1".into()),
                ("b".into(), String::new()),
                ("c".into(), "x,y".into()),
                ("d".into(), "p q".into()),
            ]
        );
    }

    #[test]
    fn router_status_codes() {
        let board = StatusBoard::new();
        board.register("run1", 10);

        assert_eq!(get(&board, "/healthz").0, 200);
        assert_eq!(get(&board, "/runs").0, 200);
        assert_eq!(get(&board, "/runs/run1").0, 200);
        assert_eq!(get(&board, "/runs/ghost").0, 404);
        assert_eq!(get(&board, "/nope").0, 404);
        assert_eq!(get(&board, "/runs/run1/metrics?last=zebra").0, 400);
        assert_eq!(get(&board, "/mem?slope=fast").0, 400);
        assert_eq!(post(&board, "/runs/run1/dance").0, 404);
        assert_eq!(post(&board, "/runs/ghost/abort").0, 404);

        let (m, p, q) = parse_request_line("DELETE /runs HTTP/1.1").unwrap();
        assert_eq!(route(&board, &MemSamples::default(), &m, &p, &q).0, 405);
    }

    #[test]
    fn control_verbs_arm_flags() {
        let board = StatusBoard::new();
        let probe = board.register("r", 10);

        assert_eq!(post(&board, "/runs/r/checkpoint").0, 202);
        assert!(probe.take_checkpoint_request());
        assert_eq!(post(&board, "/runs/r/pause").0, 202);
        assert!(probe.paused());
        assert_eq!(post(&board, "/runs/r/resume").0, 202);
        assert!(!probe.paused());
        assert_eq!(post(&board, "/runs/r/abort").0, 202);
        assert!(probe.abort_requested());
    }

    #[test]
    fn metrics_projection_and_last() {
        let board = StatusBoard::new();
        let probe = board.register("r", 10);
        for i in 0..20usize {
            probe.record_step(
                i,
                i as f64,
                0.0,
                obj(vec![
                    ("step", Json::from(i)),
                    ("loss", Json::from(i as f64)),
                    ("grad_norm", Json::from(1.0)),
                ]),
            );
        }
        let (code, body) = get(&board, "/runs/r/metrics?fields=step,loss&last=3");
        assert_eq!(code, 200);
        let rows = body.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].get("step").unwrap().as_usize().unwrap(), 17);
        assert!(rows[0].opt("grad_norm").is_none(), "projection drops unrequested fields");
        assert_eq!(rows[2].get("loss").unwrap().as_f64().unwrap(), 19.0);
    }

    #[test]
    fn runs_scrape_knobs_cap_and_summarize() {
        let board = StatusBoard::new();
        let probe = board.register("r", 10);
        for i in 0..8usize {
            probe.record_step(
                i,
                i as f64,
                0.0,
                obj(vec![("step", Json::from(i)), ("loss", Json::from(i as f64))]),
            );
        }
        let (code, body) = get(&board, "/runs?last=2");
        assert_eq!(code, 200);
        let run = &body.get("runs").unwrap().as_arr().unwrap()[0];
        assert_eq!(run.get("loss_tail").unwrap().as_arr().unwrap().len(), 2);
        let (code, body) = get(&board, "/runs?summary=1");
        assert_eq!(code, 200);
        let run = &body.get("runs").unwrap().as_arr().unwrap()[0];
        assert!(run.opt("loss_tail").is_none(), "summary omits the tails");
        assert_eq!(run.get("step").unwrap().as_usize().unwrap(), 7);
        assert_eq!(get(&board, "/runs?last=zebra").0, 400);
        // the single-run view takes the same knobs (bare ?summary works)
        let (code, body) = get(&board, "/runs/r?summary&last=1");
        assert_eq!(code, 200);
        assert!(body.opt("loss_tail").is_none());
        let (_, body) = get(&board, "/runs/r?last=3");
        assert_eq!(body.get("loss_tail").unwrap().as_arr().unwrap().len(), 3);
        // an explicit summary=0 keeps the default view
        let (_, body) = get(&board, "/runs/r?summary=0");
        assert!(body.opt("loss_tail").is_some());
    }

    #[test]
    fn metrics_where_filters_and_agg_aggregates() {
        let board = StatusBoard::new();
        let probe = board.register("r", 10);
        for i in 0..6usize {
            probe.record_step(
                i,
                (5 - i) as f64,
                0.0,
                obj(vec![
                    ("step", Json::from(i * 10)),
                    ("loss", Json::from((5 - i) as f64)),
                ]),
            );
        }
        let (code, body) = get(&board, "/runs/r/metrics?where=loss%3C2.0,step%3E=30");
        assert_eq!(code, 200);
        let rows = body.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2, "loss<2 ∧ step>=30 keeps steps 40 and 50");
        let (code, body) = get(&board, "/runs/r/metrics?where=loss%3C2.0&agg=mean:loss,count");
        assert_eq!(code, 200);
        let agg = body.get("agg").unwrap();
        assert_eq!(agg.get("mean:loss").unwrap().as_f64().unwrap(), 0.5);
        assert_eq!(agg.get("count").unwrap().as_usize().unwrap(), 2);
        assert_eq!(get(&board, "/runs/r/metrics?where=loss").0, 400, "no operator");
        assert_eq!(get(&board, "/runs/r/metrics?agg=median:loss").0, 400, "unknown fn");
    }

    #[test]
    fn metrics_endpoint_is_prometheus_text() {
        let board = StatusBoard::new();
        board.register("r", 10);
        let (m, p, q) = parse_request_line("GET /metrics HTTP/1.1").unwrap();
        let (code, payload) = route_request(&board, &MemSamples::default(), &m, &p, &q);
        assert_eq!(code, 200);
        match payload {
            Payload::Text(t) => {
                assert!(t.contains("# TYPE addax_run_step gauge"), "{t}");
                assert!(t.contains("addax_run_step{run_id=\"r\"} 0"), "{t}");
            }
            Payload::Json(_) => panic!("/metrics must be text, not JSON"),
        }
        // everything else still routes to JSON
        let (_, payload) = route_request(
            &board,
            &MemSamples::default(),
            "GET",
            "/runs",
            &[],
        );
        assert!(matches!(payload, Payload::Json(_)));
    }

    #[test]
    fn mem_window_cap_follows_the_sampler_cadence() {
        assert_eq!(mem_window_cap(DEFAULT_MEM_WINDOW_SECS), 512);
        assert_eq!(mem_window_cap(1.0), 4);
        assert_eq!(mem_window_cap(0.0), 2, "floor at a fittable window");
    }

    #[test]
    fn mem_endpoint_reports_threshold_override() {
        let board = StatusBoard::new();
        board.register("r", 10).set_footprint_bytes(123.0);
        let (code, body) = get(&board, "/mem?slope=42.5");
        assert_eq!(code, 200);
        assert_eq!(body.get("threshold_bytes_per_sec").unwrap().as_f64().unwrap(), 42.5);
        assert_eq!(body.get("analytic_bytes").unwrap().as_f64().unwrap(), 123.0);
        assert_eq!(body.get("leak_suspected").unwrap().as_bool().unwrap(), false);
    }

    #[test]
    fn live_server_round_trip() {
        let board = StatusBoard::new();
        let probe = board.register("live-run", 40);
        probe.record_step(
            2,
            0.25,
            0.5,
            obj(vec![("step", Json::from(2usize)), ("loss", Json::from(0.25))]),
        );
        let server = ProbeServer::start(board.clone(), 0).unwrap();
        assert_ne!(server.port(), 0, "ephemeral port resolved");

        let fetch = |req: &str| -> (String, Json) {
            let mut s = TcpStream::connect(server.addr()).unwrap();
            s.write_all(req.as_bytes()).unwrap();
            let mut resp = String::new();
            s.read_to_string(&mut resp).unwrap();
            let (head, body) = resp.split_once("\r\n\r\n").expect("header/body split");
            (head.lines().next().unwrap().to_string(), Json::parse(body).unwrap())
        };

        let (status, body) = fetch("GET /runs HTTP/1.1\r\nHost: t\r\n\r\n");
        assert!(status.contains("200"), "{status}");
        assert_eq!(body.get("n").unwrap().as_usize().unwrap(), 1);
        let run = &body.get("runs").unwrap().as_arr().unwrap()[0];
        assert_eq!(run.get("run_id").unwrap().as_str().unwrap(), "live-run");
        assert_eq!(run.get("step").unwrap().as_usize().unwrap(), 2);

        let (status, _) = fetch("POST /runs/live-run/abort HTTP/1.1\r\nHost: t\r\n\r\n");
        assert!(status.contains("202"), "{status}");
        assert!(probe.abort_requested(), "verb armed through the real socket path");

        let (status, body) = fetch("GET /mem HTTP/1.1\r\nHost: t\r\n\r\n");
        assert!(status.contains("200"), "{status}");
        assert!(body.opt("rss_bytes").is_some());

        let (status, _) = fetch("BOGUS-LINE\r\n\r\n");
        assert!(status.contains("400"), "{status}");

        // the exposition endpoint serves text with the scrape content type
        let mut s = TcpStream::connect(server.addr()).unwrap();
        s.write_all(b"GET /metrics HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
        let mut resp = String::new();
        s.read_to_string(&mut resp).unwrap();
        assert!(resp.contains("text/plain; version=0.0.4"), "{resp}");
        assert!(resp.contains("# TYPE addax_run_loss gauge"), "{resp}");
        assert!(resp.contains("addax_run_loss{run_id=\"live-run\"} 0.25"), "{resp}");

        drop(server); // must join cleanly, not hang
    }
}
