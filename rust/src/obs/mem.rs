//! Memory-side observability: actual RSS vs. the analytic model, plus a
//! linear-growth leak detector.
//!
//! The paper's central claim is a *memory* claim — Addax fits where SGD
//! OOMs — and `memory::footprint` is the analytic model the scheduler
//! prices runs with. This file supplies the other half of the
//! comparison: what the process is *actually* resident at, sampled from
//! `/proc/self/statm` (Linux; [`rss_bytes`] degrades to `None` on other
//! platforms, and the `/mem` endpoint reports `null` rather than lying).
//!
//! The leak detector is deliberately simple and fully deterministic
//! given its samples: an ordinary least-squares line through the
//! `(elapsed secs, rss bytes)` window. A leak is *suspected* — never
//! proven — when the fitted slope exceeds a threshold in bytes/sec AND
//! the fit actually explains the data (`r² ≥ 0.5`), so a noisy flat
//! series with one reallocation spike does not alarm. Thresholds and
//! semantics are documented in `EXPERIMENTS.md` §Observability.

use std::collections::VecDeque;

/// `AT_PAGESZ` from the ELF auxiliary vector (`/proc/self/auxv` entry
/// type 6): the page size `/proc/self/statm` counts in. Falls back to
/// 4096 when auxv is unreadable (non-Linux, locked-down procfs).
fn page_size() -> u64 {
    let Ok(raw) = std::fs::read("/proc/self/auxv") else {
        return 4096;
    };
    let word = std::mem::size_of::<usize>();
    for pair in raw.chunks_exact(2 * word) {
        let mut k = [0u8; 8];
        let mut v = [0u8; 8];
        k[..word].copy_from_slice(&pair[..word]);
        v[..word].copy_from_slice(&pair[word..]);
        if u64::from_le_bytes(k) == 6 {
            let val = u64::from_le_bytes(v);
            if val > 0 {
                return val;
            }
        }
    }
    4096
}

/// Resident set size of this process in bytes, from the second field of
/// `/proc/self/statm` (resident pages × page size). `None` where procfs
/// is absent — callers must surface "unknown", not zero.
pub fn rss_bytes() -> Option<u64> {
    let text = std::fs::read_to_string("/proc/self/statm").ok()?;
    let pages: u64 = text.split_whitespace().nth(1)?.parse().ok()?;
    Some(pages * page_size())
}

/// Default leak-detector threshold: 1 MiB/min of *sustained* linear
/// growth. Training allocates in steps (params, snapshots, eval
/// buffers) and settles; a steady upward line across the whole sample
/// window is the leak shape this flags.
pub const DEFAULT_LEAK_SLOPE: f64 = (1 << 20) as f64 / 60.0;

/// Minimum samples before the detector will venture an opinion — below
/// this a "slope" is an artifact of two points and a ruler.
pub const MIN_LEAK_SAMPLES: usize = 8;

/// A bounded window of `(elapsed_secs, rss_bytes)` samples with the
/// least-squares machinery for the `/mem` endpoint.
///
/// Deterministic in its inputs: tests feed synthetic series and assert
/// exact verdicts; the live sampler thread feeds [`rss_bytes`] readings.
#[derive(Clone, Debug)]
pub struct MemSamples {
    cap: usize,
    pts: VecDeque<(f64, f64)>,
}

impl MemSamples {
    /// Window of at most `cap` samples (oldest evicted first).
    pub fn new(cap: usize) -> Self {
        Self { cap: cap.max(2), pts: VecDeque::new() }
    }

    pub fn push(&mut self, elapsed_secs: f64, rss_bytes: f64) {
        if self.pts.len() == self.cap {
            self.pts.pop_front();
        }
        self.pts.push_back((elapsed_secs, rss_bytes));
    }

    pub fn len(&self) -> usize {
        self.pts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pts.is_empty()
    }

    /// Latest sample, if any.
    pub fn last(&self) -> Option<(f64, f64)> {
        self.pts.back().copied()
    }

    /// Ordinary least-squares `(slope bytes/sec, r²)` over the window;
    /// `None` below [`MIN_LEAK_SAMPLES`] or on a degenerate time axis.
    pub fn fit(&self) -> Option<(f64, f64)> {
        let n = self.pts.len();
        if n < MIN_LEAK_SAMPLES {
            return None;
        }
        let nf = n as f64;
        let (mut sx, mut sy) = (0.0, 0.0);
        for &(x, y) in &self.pts {
            sx += x;
            sy += y;
        }
        let (mx, my) = (sx / nf, sy / nf);
        let (mut sxx, mut sxy, mut syy) = (0.0, 0.0, 0.0);
        for &(x, y) in &self.pts {
            sxx += (x - mx) * (x - mx);
            sxy += (x - mx) * (y - my);
            syy += (y - my) * (y - my);
        }
        if sxx <= 0.0 {
            return None; // all samples at one instant
        }
        let slope = sxy / sxx;
        // r² = explained/total variance; a perfectly flat series has
        // syy == 0 and *no* leak shape, so report a zero fit quality.
        let r2 = if syy <= 0.0 { 0.0 } else { (sxy * sxy) / (sxx * syy) };
        Some((slope, r2))
    }

    /// The verdict: sustained growth above `slope_threshold` bytes/sec
    /// with a fit that explains at least half the variance. `false`
    /// whenever the window is too small to judge.
    pub fn leak_suspected(&self, slope_threshold: f64) -> bool {
        match self.fit() {
            Some((slope, r2)) => slope > slope_threshold && r2 >= 0.5,
            None => false,
        }
    }
}

impl Default for MemSamples {
    fn default() -> Self {
        Self::new(512)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn filled(f: impl Fn(usize) -> f64) -> MemSamples {
        let mut m = MemSamples::new(64);
        for i in 0..32 {
            m.push(i as f64, f(i));
        }
        m
    }

    #[test]
    fn rss_is_readable_on_linux() {
        // CI runs on Linux; a non-Linux dev box may legitimately get None.
        if std::path::Path::new("/proc/self/statm").exists() {
            let rss = rss_bytes().expect("statm present but unreadable");
            assert!(rss > 1 << 20, "a live Rust process is > 1 MiB resident, got {rss}");
        }
    }

    #[test]
    fn linear_growth_is_flagged() {
        // 1 MiB/sec of perfectly linear growth: slope ≈ 2^20, r² = 1.
        let m = filled(|i| 1e8 + (i as f64) * (1 << 20) as f64);
        let (slope, r2) = m.fit().unwrap();
        assert!((slope - (1 << 20) as f64).abs() < 1.0, "slope {slope}");
        assert!(r2 > 0.999);
        assert!(m.leak_suspected(DEFAULT_LEAK_SLOPE));
    }

    #[test]
    fn flat_and_noisy_series_do_not_alarm() {
        let flat = filled(|_| 2e8);
        assert!(!flat.leak_suspected(DEFAULT_LEAK_SLOPE), "flat series is not a leak");
        // A transient spike (one eval buffer, freed next sample) is not
        // *sustained* linear growth — the fit explains almost none of it.
        let spike = filled(|i| if i == 15 { 4e8 } else { 2e8 });
        assert!(!spike.leak_suspected(DEFAULT_LEAK_SLOPE), "single spike is not a leak");
    }

    #[test]
    fn too_few_samples_abstain() {
        let mut m = MemSamples::new(64);
        for i in 0..(MIN_LEAK_SAMPLES - 1) {
            m.push(i as f64, (i as f64) * 1e9); // wildly leaky, but unjudgeable
        }
        assert!(m.fit().is_none());
        assert!(!m.leak_suspected(0.0));
    }

    #[test]
    fn window_is_bounded_and_degenerate_time_axis_is_safe() {
        let mut m = MemSamples::new(4);
        for i in 0..100 {
            m.push(0.0, i as f64); // same instant every time
        }
        assert_eq!(m.len(), 4);
        assert!(m.fit().is_none(), "zero time variance cannot fit a slope");
    }
}
