//! The fleet aggregator behind `addax fleet-status`: one read-only view
//! of a whole multi-process sweep, reconstructed from the side files the
//! workers already write.
//!
//! No worker cooperates with the aggregator and no new file is written.
//! [`load_fleet`] replays:
//!
//! * `manifest.jsonl` — completed rows (the *done* set) plus the fenced
//!   duplicates its load fences off;
//! * `manifest.leases.jsonl` — the lease table ([`LeaseTable::load`]),
//!   giving per-run holder/token/seq/expiry and each holder's advertised
//!   probe address;
//! * `manifest.times.jsonl` — lifecycle events (`reclaim`, `fenced`,
//!   `abort`, `rotate`, `steal`) and resumed-run timing rows;
//! * `steal/<run_id>/` — per-run work-stealing side dirs.
//!
//! Every reader is tolerant of torn trailing lines and mid-rotation
//! snapshots exactly like the workers' own loads — an aggregator
//! pointed at a live, crashing, rotating fleet must render a view,
//! never a panic.
//!
//! **Probe federation**: lease claim/renew records carry the holder's
//! probe address ([`LeaseRecord::probe`]). [`FleetView::federate`] fans
//! out `GET /runs?summary=1` to each distinct advertised address with a
//! short timeout and merges the live rows (step, loss, staleness) into
//! the ledger view. Unreachable probes degrade gracefully: the worker
//! is marked unreachable and its runs keep their ledger-only state.
//!
//! [`FleetServer`] wraps the view in the same std-only HTTP subset as
//! the worker probe: `GET /fleet` (JSON) and `GET /metrics` (Prometheus
//! text, fleet-wide series — including `addax_fenced_rows_total`, which
//! only the ledger knows), rebuilt per request.
//!
//! [`LeaseRecord::probe`]: crate::sched::lease::LeaseRecord

use std::collections::{BTreeMap, BTreeSet};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use super::http::{read_request, write_payload, Payload};
use super::mem;
use super::prom::PromText;
use crate::ioutil;
use crate::jsonlite::{obj, Json};
use crate::sched::lease::{self, LeaseTable};
use crate::sched::manifest::SweepManifest;

/// Default timeout for each federated probe fetch: long enough for a
/// loopback or LAN probe, short enough that a dead worker can't stall
/// the whole `/fleet` render.
pub const DEFAULT_FEDERATE_TIMEOUT: Duration = Duration::from_millis(400);

/// One run's reconstructed position in the fleet state machine.
#[derive(Clone, Debug)]
pub struct RunView {
    pub run_id: String,
    /// `done` (manifest row exists), `active` (live lease), `expired`
    /// (unreleased lease past expiry + skew margin), `released`
    /// (retired lease, no row — claimable), or `pending` (seen only in
    /// telemetry, never leased).
    pub state: &'static str,
    /// Last recorded lease holder, if any lease record ever touched it.
    pub worker: Option<String>,
    /// Fencing token (0 = never leased).
    pub token: u64,
    pub seq: u64,
    /// Lease expiry minus `now` (negative = overdue); only for
    /// unreleased leases.
    pub expires_in_ms: Option<i64>,
    /// The holder's advertised probe address.
    pub probe: Option<String>,
    /// Lease reclaims recorded in the times side file.
    pub resumes: u64,
    /// A timing row shows this run restarted off step-level snapshots.
    pub resumed_from_snapshot: bool,
    /// Probe shards computed by thief workers (times `steal` events).
    pub stolen_shards: u64,
    /// Best validation accuracy from the manifest row (done runs).
    pub best_val: Option<f64>,
    /// The live `/runs` row federated from the holder's probe.
    pub live: Option<Json>,
}

/// One worker's holdings, grouped from the lease table.
#[derive(Clone, Debug)]
pub struct WorkerView {
    pub worker: String,
    /// Runs whose current (unreleased) lease this worker holds.
    pub held: Vec<String>,
    /// Highest renewal seq seen from this worker — the logical
    /// liveness signal: compare across two `/fleet` fetches to see a
    /// holder making progress regardless of clock skew.
    pub max_seq: u64,
    /// Freshest held-lease expiry minus `now` (negative = overdue).
    pub freshest_expires_in_ms: Option<i64>,
    pub probe: Option<String>,
    /// Set by federation: `None` until attempted or no probe address.
    pub reachable: Option<bool>,
}

/// The reconstructed fleet: per-run, per-worker, and total views.
#[derive(Debug)]
pub struct FleetView {
    pub manifest_path: PathBuf,
    pub now_ms: u64,
    pub skew_margin_ms: u64,
    pub runs: Vec<RunView>,
    pub workers: Vec<WorkerView>,
    pub done: usize,
    pub active: usize,
    pub expired: usize,
    /// Non-done runs a worker could claim right now (released, expired,
    /// or never leased).
    pub claimable: usize,
    /// Zombie rows the manifest load fenced off.
    pub fenced_rows: usize,
    /// `fenced` lifecycle events in the times file (zombie appends
    /// rejected at commit time).
    pub fenced_events: u64,
    pub reclaims: u64,
    pub aborts: u64,
    pub rotations: u64,
    pub stolen_shards: u64,
    pub corrupt_manifest_lines: usize,
    pub corrupt_lease_lines: usize,
}

/// Lifecycle counters parsed out of `manifest.times.jsonl`. Torn lines,
/// an empty file, and an absent file all yield the zero value — the
/// times file is telemetry and must never block a fleet view.
#[derive(Debug, Default)]
struct TimesEvents {
    reclaims: BTreeMap<String, u64>,
    steals: BTreeMap<String, u64>,
    resumed: BTreeSet<String>,
    rotations: u64,
    fenced_events: u64,
    aborts: u64,
    run_ids: BTreeSet<String>,
}

fn load_times_events(manifest: &Path) -> TimesEvents {
    let mut ev = TimesEvents::default();
    let Ok(lines) = ioutil::read_lossy_lines(&SweepManifest::times_path(manifest)) else {
        return ev;
    };
    for line in &lines {
        if line.trim().is_empty() {
            continue;
        }
        let Ok(v) = Json::parse(line) else { continue };
        let run = v.opt("run_id").and_then(|j| j.as_str().ok()).unwrap_or("-").to_string();
        let drain_scoped = run == "-"; // e.g. the drain-time ledger rotation
        if !drain_scoped {
            ev.run_ids.insert(run.clone());
        }
        let Some(event) = v.opt("event").and_then(|j| j.as_str().ok()) else {
            // A timing row; the resumed marker is the only state it adds.
            if v.opt("resumed_from_step").is_some() && !drain_scoped {
                ev.resumed.insert(run);
            }
            continue;
        };
        match event {
            "reclaim" => *ev.reclaims.entry(run).or_insert(0) += 1,
            "rotate" => ev.rotations += 1,
            "fenced" => ev.fenced_events += 1,
            "abort" => ev.aborts += 1,
            "steal" => {
                // Note shape: "<n> probe shard(s) computed by a thief
                // worker" — fall back to 1 shard if the count moved.
                let n = v
                    .opt("note")
                    .and_then(|j| j.as_str().ok())
                    .and_then(|n| n.split_whitespace().next())
                    .and_then(|w| w.parse::<u64>().ok())
                    .unwrap_or(1);
                *ev.steals.entry(run).or_insert(0) += n;
            }
            _ => {} // unknown future events are not ours to reject
        }
    }
    ev
}

/// Run ids with a `steal/<run_id>/` side dir (the work-stealing
/// rendezvous the workers publish next to the manifest).
fn steal_dir_runs(manifest: &Path) -> BTreeSet<String> {
    let dir = match manifest.parent() {
        Some(p) if !p.as_os_str().is_empty() => p.to_path_buf(),
        _ => PathBuf::from("."),
    }
    .join("steal");
    let mut out = BTreeSet::new();
    let Ok(rd) = std::fs::read_dir(&dir) else { return out };
    for e in rd.flatten() {
        if e.file_type().map(|t| t.is_dir()).unwrap_or(false) {
            out.insert(e.file_name().to_string_lossy().into_owned());
        }
    }
    out
}

/// Reconstruct the fleet, read-only, from the manifest and its side
/// files. `now_ms`/`skew_margin_ms` gate the active-vs-expired split
/// with exactly the padding workers use ([`LeaseTable::claimable`]).
pub fn load_fleet(manifest: &Path, now_ms: u64, skew_margin_ms: u64) -> Result<FleetView> {
    let m = SweepManifest::load(manifest)
        .with_context(|| format!("loading manifest {}", manifest.display()))?;
    let leases = LeaseTable::load(&lease::leases_path(manifest))
        .with_context(|| format!("loading lease ledger beside {}", manifest.display()))?;
    let ev = load_times_events(manifest);
    let stealing = steal_dir_runs(manifest);

    // The observable universe: a run exists for this view once any side
    // file mentions it. (The sweep *spec* is deliberately not consulted
    // — the aggregator works from ledgers alone, so it can watch a
    // fleet whose spec file it cannot read.)
    let mut ids: BTreeSet<String> = BTreeSet::new();
    ids.extend(m.rows().map(|r| r.run_id.clone()));
    ids.extend(leases.iter().map(|(id, _)| id.to_string()));
    ids.extend(ev.run_ids.iter().cloned());
    ids.extend(stealing.iter().cloned());

    let mut runs = Vec::new();
    let mut workers: BTreeMap<String, WorkerView> = BTreeMap::new();
    let (mut done, mut active, mut expired, mut claimable) = (0usize, 0usize, 0usize, 0usize);
    for id in &ids {
        let row = m.get(id);
        let ls = leases.state(id);
        let live_lease = ls
            .is_some_and(|s| !s.released && now_ms < s.expires_ms.saturating_add(skew_margin_ms));
        let state = if row.is_some() {
            done += 1;
            "done"
        } else if let Some(s) = ls {
            if s.released {
                claimable += 1;
                "released"
            } else if live_lease {
                active += 1;
                "active"
            } else {
                expired += 1;
                claimable += 1;
                "expired"
            }
        } else {
            claimable += 1;
            "pending"
        };
        if let Some(s) = ls {
            let w = workers.entry(s.worker.clone()).or_insert_with(|| WorkerView {
                worker: s.worker.clone(),
                held: Vec::new(),
                max_seq: 0,
                freshest_expires_in_ms: None,
                probe: None,
                reachable: None,
            });
            w.max_seq = w.max_seq.max(s.seq);
            if !s.released {
                w.held.push(id.clone());
                let delta = s.expires_ms as i64 - now_ms as i64;
                w.freshest_expires_in_ms =
                    Some(w.freshest_expires_in_ms.map_or(delta, |c| c.max(delta)));
                if w.probe.is_none() {
                    w.probe = s.probe.clone();
                }
            }
        }
        runs.push(RunView {
            run_id: id.clone(),
            state,
            worker: ls.map(|s| s.worker.clone()),
            token: ls.map_or(0, |s| s.token),
            seq: ls.map_or(0, |s| s.seq),
            expires_in_ms: ls
                .filter(|s| !s.released)
                .map(|s| s.expires_ms as i64 - now_ms as i64),
            probe: ls.and_then(|s| s.probe.clone()),
            resumes: ev.reclaims.get(id).copied().unwrap_or(0),
            resumed_from_snapshot: ev.resumed.contains(id),
            stolen_shards: ev.steals.get(id).copied().unwrap_or(0),
            best_val: row.map(|r| r.outcome.best_val_acc),
            live: None,
        });
    }
    Ok(FleetView {
        manifest_path: manifest.to_path_buf(),
        now_ms,
        skew_margin_ms,
        runs,
        workers: workers.into_values().collect(),
        done,
        active,
        expired,
        claimable,
        fenced_rows: m.fenced_rows,
        fenced_events: ev.fenced_events,
        reclaims: ev.reclaims.values().sum(),
        aborts: ev.aborts,
        rotations: ev.rotations,
        stolen_shards: ev.steals.values().sum(),
        corrupt_manifest_lines: m.corrupt_lines,
        corrupt_lease_lines: leases.corrupt_lines,
    })
}

/// One-shot HTTP GET against `host:port`, returning the parsed JSON
/// body on a 200 — `None` on connect/read timeout, non-200, or a
/// malformed body. The degraded path IS the contract: federation must
/// never make a fleet view worse than ledger-only.
pub fn http_get_json(addr: &str, path: &str, timeout: Duration) -> Option<Json> {
    let sock: SocketAddr = addr.parse().ok()?;
    let mut s = TcpStream::connect_timeout(&sock, timeout).ok()?;
    s.set_read_timeout(Some(timeout)).ok()?;
    s.set_write_timeout(Some(timeout)).ok()?;
    write!(s, "GET {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n").ok()?;
    let mut resp = String::new();
    s.read_to_string(&mut resp).ok()?;
    let (head, body) = resp.split_once("\r\n\r\n")?;
    if !head.lines().next()?.contains(" 200 ") {
        return None;
    }
    Json::parse(body).ok()
}

impl FleetView {
    /// Fan out `GET /runs?summary=1` to every distinct advertised probe
    /// address, merging live rows into [`RunView::live`] and stamping
    /// [`WorkerView::reachable`]. Serial on purpose: a fleet has a
    /// handful of workers, and the per-probe `timeout` bounds the total.
    pub fn federate(&mut self, timeout: Duration) {
        let addrs: BTreeSet<String> =
            self.workers.iter().filter_map(|w| w.probe.clone()).collect();
        let mut reach: BTreeMap<String, bool> = BTreeMap::new();
        let mut live_rows: BTreeMap<String, Json> = BTreeMap::new();
        for addr in &addrs {
            match http_get_json(addr, "/runs?summary=1", timeout) {
                Some(body) => {
                    reach.insert(addr.clone(), true);
                    if let Ok(rows) = body.get("runs").and_then(|r| r.as_arr()) {
                        for row in rows {
                            if let Some(id) = row.opt("run_id").and_then(|j| j.as_str().ok()) {
                                live_rows.insert(id.to_string(), row.clone());
                            }
                        }
                    }
                }
                None => {
                    reach.insert(addr.clone(), false);
                }
            }
        }
        for w in &mut self.workers {
            w.reachable = w.probe.as_ref().map(|a| reach.get(a).copied().unwrap_or(false));
        }
        for r in &mut self.runs {
            r.live = live_rows.get(&r.run_id).cloned();
        }
    }

    /// The `GET /fleet` payload (also `addax fleet-status`'s stdout).
    pub fn to_json(&self) -> Json {
        let opt_str = |v: &Option<String>| {
            v.as_ref().map(|s| Json::from(s.clone())).unwrap_or(Json::Null)
        };
        let runs = self
            .runs
            .iter()
            .map(|r| {
                obj(vec![
                    ("run_id", Json::from(r.run_id.clone())),
                    ("state", Json::from(r.state)),
                    ("worker", opt_str(&r.worker)),
                    ("token", Json::from(r.token as usize)),
                    ("seq", Json::from(r.seq as usize)),
                    (
                        "expires_in_ms",
                        r.expires_in_ms.map(|d| Json::from(d as f64)).unwrap_or(Json::Null),
                    ),
                    ("probe", opt_str(&r.probe)),
                    ("resumes", Json::from(r.resumes as usize)),
                    ("resumed_from_snapshot", Json::from(r.resumed_from_snapshot)),
                    ("stolen_shards", Json::from(r.stolen_shards as usize)),
                    ("best_val", r.best_val.map(Json::from).unwrap_or(Json::Null)),
                    ("live", r.live.clone().unwrap_or(Json::Null)),
                ])
            })
            .collect();
        let workers = self
            .workers
            .iter()
            .map(|w| {
                obj(vec![
                    ("worker", Json::from(w.worker.clone())),
                    (
                        "held",
                        Json::Arr(w.held.iter().map(|h| Json::from(h.clone())).collect()),
                    ),
                    ("max_seq", Json::from(w.max_seq as usize)),
                    (
                        "freshest_expires_in_ms",
                        w.freshest_expires_in_ms
                            .map(|d| Json::from(d as f64))
                            .unwrap_or(Json::Null),
                    ),
                    ("probe", opt_str(&w.probe)),
                    (
                        "reachable",
                        w.reachable.map(Json::from).unwrap_or(Json::Null),
                    ),
                ])
            })
            .collect();
        obj(vec![
            ("manifest", Json::from(self.manifest_path.display().to_string())),
            ("now_ms", Json::from(self.now_ms as usize)),
            ("skew_margin_ms", Json::from(self.skew_margin_ms as usize)),
            (
                "totals",
                obj(vec![
                    ("runs", Json::from(self.runs.len())),
                    ("done", Json::from(self.done)),
                    ("active", Json::from(self.active)),
                    ("expired", Json::from(self.expired)),
                    ("claimable", Json::from(self.claimable)),
                    ("fenced_rows", Json::from(self.fenced_rows)),
                    ("fenced_events", Json::from(self.fenced_events as usize)),
                    ("reclaims", Json::from(self.reclaims as usize)),
                    ("aborts", Json::from(self.aborts as usize)),
                    ("rotations", Json::from(self.rotations as usize)),
                    ("stolen_shards", Json::from(self.stolen_shards as usize)),
                    (
                        "corrupt_manifest_lines",
                        Json::from(self.corrupt_manifest_lines),
                    ),
                    ("corrupt_lease_lines", Json::from(self.corrupt_lease_lines)),
                ]),
            ),
            ("workers", Json::Arr(workers)),
            ("runs", Json::Arr(runs)),
        ])
    }
}

/// The aggregator's `GET /metrics`: fleet-wide Prometheus series. Live
/// per-run gauges come from federation and are omitted (never zeroed)
/// for runs whose probe was unreachable; ledger counters — including
/// `addax_fenced_rows_total`, which no single worker can know — come
/// from the view itself.
pub fn render_fleet(view: &FleetView) -> String {
    let mut p = PromText::new();
    let live_num = |r: &RunView, key: &str| {
        r.live.as_ref().and_then(|l| l.opt(key)).and_then(|j| j.as_f64().ok())
    };
    p.header("addax_run_step", "gauge", "Latest step, federated from the holder's probe.");
    for r in &view.runs {
        if let Some(step) = live_num(r, "step") {
            p.sample("addax_run_step", &[("run_id", &r.run_id)], step);
        }
    }
    p.header("addax_run_loss", "gauge", "Latest loss, federated from the holder's probe.");
    for r in &view.runs {
        if let Some(loss) = live_num(r, "loss") {
            p.sample("addax_run_loss", &[("run_id", &r.run_id)], loss);
        }
    }
    p.header(
        "addax_run_best_val",
        "gauge",
        "Best validation accuracy (manifest row, else the live probe).",
    );
    for r in &view.runs {
        if let Some(best) = r.best_val.or_else(|| live_num(r, "best_val")) {
            p.sample("addax_run_best_val", &[("run_id", &r.run_id)], best);
        }
    }
    p.header("addax_lease_active", "gauge", "Live (unreleased, unexpired) leases per worker.");
    let mut active_by: BTreeMap<&str, f64> =
        view.workers.iter().map(|w| (w.worker.as_str(), 0.0)).collect();
    for r in &view.runs {
        if r.state == "active" {
            if let Some(w) = &r.worker {
                *active_by.entry(w.as_str()).or_insert(0.0) += 1.0;
            }
        }
    }
    for (w, n) in &active_by {
        p.sample("addax_lease_active", &[("worker", w)], *n);
    }
    p.header(
        "addax_fenced_rows_total",
        "counter",
        "Zombie manifest rows fenced on load plus fenced commit events.",
    );
    p.sample(
        "addax_fenced_rows_total",
        &[],
        view.fenced_rows as f64 + view.fenced_events as f64,
    );
    p.header("addax_stolen_shards_total", "counter", "Probe shards computed by thief workers.");
    p.sample("addax_stolen_shards_total", &[], view.stolen_shards as f64);
    p.header(
        "addax_footprint_bytes",
        "gauge",
        "Sum of analytic footprints reported by reachable worker probes.",
    );
    let footprints: Vec<f64> =
        view.runs.iter().filter_map(|r| live_num(r, "footprint_bytes")).collect();
    if !footprints.is_empty() {
        p.sample("addax_footprint_bytes", &[], footprints.iter().sum());
    }
    p.header("addax_rss_bytes", "gauge", "Resident set size of the aggregator process.");
    if let Some(rss) = mem::rss_bytes() {
        p.sample("addax_rss_bytes", &[], rss as f64);
    }
    p.finish()
}

/// The aggregator server: `GET /fleet`, `GET /metrics`, `GET /healthz`
/// on loopback, rebuilding the view from the side files on every
/// request (the ledgers ARE the state — there is nothing to cache or
/// invalidate). Same tiny HTTP subset and lifecycle as
/// [`ProbeServer`](super::ProbeServer).
pub struct FleetServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl FleetServer {
    pub fn start(
        manifest: PathBuf,
        port: u16,
        skew_margin_ms: u64,
        federate_timeout: Duration,
    ) -> Result<FleetServer> {
        let listener = TcpListener::bind(("127.0.0.1", port))
            .with_context(|| format!("fleet-status: cannot bind 127.0.0.1:{port}"))?;
        let addr = listener.local_addr().context("fleet-status: local_addr")?;
        let stop = Arc::new(AtomicBool::new(false));
        let accept = {
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                for stream in listener.incoming() {
                    if stop.load(Ordering::Relaxed) {
                        break;
                    }
                    if let Ok(mut stream) = stream {
                        let _ =
                            Self::handle(&mut stream, &manifest, skew_margin_ms, federate_timeout);
                    }
                }
            })
        };
        Ok(FleetServer { addr, stop, accept: Some(accept) })
    }

    fn handle(
        stream: &mut TcpStream,
        manifest: &Path,
        skew_margin_ms: u64,
        federate_timeout: Duration,
    ) -> std::io::Result<()> {
        let err = |msg: &str| Payload::Json(obj(vec![("error", Json::from(msg))]));
        let (status, payload) = match read_request(stream)? {
            Some((method, path, _query)) if method == "GET" => {
                match path.trim_end_matches('/') {
                    "" | "/healthz" => {
                        (200, Payload::Json(obj(vec![("ok", Json::from(true))])))
                    }
                    endpoint @ ("/fleet" | "/metrics") => {
                        match load_fleet(manifest, lease::now_ms(), skew_margin_ms) {
                            Ok(mut view) => {
                                view.federate(federate_timeout);
                                if endpoint == "/fleet" {
                                    (200, Payload::Json(view.to_json()))
                                } else {
                                    (200, Payload::Text(render_fleet(&view)))
                                }
                            }
                            Err(e) => (500, err(&format!("{e:#}"))),
                        }
                    }
                    _ => (404, err("not found")),
                }
            }
            Some(_) => (405, err("method not allowed")),
            None => (400, err("malformed request line")),
        };
        write_payload(stream, status, &payload)
    }

    pub fn port(&self) -> u16 {
        self.addr.port()
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Drop for FleetServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        let _ = TcpStream::connect_timeout(&self.addr, Duration::from_millis(200));
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Curve;
    use crate::obs::{ProbeServer, StatusBoard};
    use crate::optim::OptSpec;
    use crate::sched::lease::{append, LeaseAction, LeaseRecord};
    use crate::sched::manifest::{ManifestRow, Outcome};
    use crate::sched::spec::{Backend, RunSpec};

    fn tmp_manifest(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("addax_fleet_{tag}_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        dir.join("manifest.jsonl")
    }

    fn done_row(seed: u64) -> ManifestRow {
        let spec = RunSpec::new(Backend::Mock, "sst2", OptSpec::named("mezo"), 10, seed);
        let mut loss_curve = Curve::default();
        loss_curve.push(0, 2.5);
        ManifestRow {
            run_id: spec.run_id.clone(),
            spec: spec.to_json(),
            outcome: Outcome {
                kind: "train".to_string(),
                best_val_acc: 0.75,
                best_val_step: 5,
                test_acc: 0.7,
                test_f1: 0.65,
                final_train_loss: 0.5,
                steps: 10,
                loss_curve,
                val_curve: Curve::default(),
            },
        }
    }

    fn rec(run: &str, worker: &str, token: u64, action: LeaseAction, expires: u64) -> LeaseRecord {
        LeaseRecord {
            run_id: run.to_string(),
            worker: worker.to_string(),
            token,
            seq: 0,
            action,
            expires_ms: expires,
            probe: None,
        }
    }

    fn run_of<'a>(view: &'a FleetView, id: &str) -> &'a RunView {
        view.runs.iter().find(|r| r.run_id == id).unwrap_or_else(|| panic!("no run {id}"))
    }

    #[test]
    fn ledger_reconstruction_counts_the_state_machine() {
        let manifest = tmp_manifest("recon");
        let mut m = SweepManifest::load(&manifest).unwrap();
        let row = done_row(0);
        let done_id = row.run_id.clone();
        m.append(row).unwrap();
        let leases = lease::leases_path(&manifest);
        // done run: released lease; plus one active, one expired holder
        append(&leases, &rec(&done_id, "w0", 1, LeaseAction::Claim, 5_000)).unwrap();
        append(&leases, &rec(&done_id, "w0", 1, LeaseAction::Release, 5_000)).unwrap();
        let mut active = rec("run-active", "w1", 2, LeaseAction::Claim, 1_000_000);
        active.probe = Some("127.0.0.1:9".to_string());
        active.seq = 4;
        append(&leases, &active).unwrap();
        append(&leases, &rec("run-dead", "w2", 3, LeaseAction::Claim, 1_000)).unwrap();
        SweepManifest::append_event(&manifest, "run-active", "reclaim", "w1 reclaimed").unwrap();
        SweepManifest::append_event(
            &manifest,
            "run-active",
            "steal",
            "3 probe shard(s) computed by a thief worker",
        )
        .unwrap();
        SweepManifest::append_event(&manifest, "-", "rotate", "ledger rotated").unwrap();

        let view = load_fleet(&manifest, 10_000, 500).unwrap();
        assert_eq!((view.done, view.active, view.expired, view.claimable), (1, 1, 1, 1));
        assert_eq!(run_of(&view, &done_id).state, "done");
        assert_eq!(run_of(&view, &done_id).best_val, Some(0.75));
        let a = run_of(&view, "run-active");
        assert_eq!((a.state, a.token, a.seq), ("active", 2, 4));
        assert_eq!(a.probe.as_deref(), Some("127.0.0.1:9"));
        assert_eq!(a.resumes, 1);
        assert_eq!(a.stolen_shards, 3);
        assert_eq!(run_of(&view, "run-dead").state, "expired");
        assert_eq!(view.rotations, 1);
        assert_eq!(view.stolen_shards, 3);
        // per-worker grouping: w1 holds the active run and advertises
        let w1 = view.workers.iter().find(|w| w.worker == "w1").unwrap();
        assert_eq!(w1.held, vec!["run-active".to_string()]);
        assert_eq!(w1.max_seq, 4);
        assert!(w1.freshest_expires_in_ms.unwrap() > 0);
        let w2 = view.workers.iter().find(|w| w.worker == "w2").unwrap();
        assert!(w2.freshest_expires_in_ms.unwrap() < 0, "overdue shows negative");
        // the JSON shape carries the totals
        let j = view.to_json();
        assert_eq!(j.get("totals").unwrap().get("done").unwrap().as_usize().unwrap(), 1);
        assert_eq!(j.get("workers").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn torn_trailing_lines_in_every_side_file_never_panic() {
        let manifest = tmp_manifest("torn");
        let mut m = SweepManifest::load(&manifest).unwrap();
        m.append(done_row(1)).unwrap();
        let leases = lease::leases_path(&manifest);
        append(&leases, &rec("r-live", "w0", 1, LeaseAction::Claim, u64::MAX / 2)).unwrap();
        // tear all three files mid-line, ending inside a multi-byte char
        for p in [&manifest, &leases, &SweepManifest::times_path(&manifest)] {
            let mut bytes = std::fs::read(p).unwrap_or_default();
            bytes.extend_from_slice(b"{\"run_id\":\"caf");
            bytes.push(0xC3);
            std::fs::write(p, &bytes).unwrap();
        }
        let view = load_fleet(&manifest, 1_000, 0).unwrap();
        assert_eq!(view.done, 1);
        assert_eq!(view.active, 1);
        assert_eq!(view.corrupt_manifest_lines, 1);
        assert_eq!(view.corrupt_lease_lines, 1);
    }

    #[test]
    fn mid_rotation_snapshot_beside_the_ledger_is_ignored() {
        let manifest = tmp_manifest("midrot");
        let leases = lease::leases_path(&manifest);
        append(&leases, &rec("a", "w0", 2, LeaseAction::Claim, 9_000)).unwrap();
        append(&leases, &rec("a", "w0", 2, LeaseAction::Release, 9_000)).unwrap();
        // a crashed rotation leaves its pre-rename tmp file behind; the
        // aggregator must read the ledger path only, never the tmp
        let tmp = leases.with_extension("jsonl.rot.99999.0");
        std::fs::write(&tmp, "{\"action\":\"release\",\"run_id\":\"ghost\",").unwrap();
        let view = load_fleet(&manifest, 1_000, 0).unwrap();
        assert_eq!(view.runs.len(), 1, "the tmp file's ghost run must not appear");
        assert_eq!(run_of(&view, "a").state, "released");
        assert_eq!(view.corrupt_lease_lines, 0);
    }

    #[test]
    fn pre_probe_era_lease_lines_read_as_probe_absent() {
        let manifest = tmp_manifest("preprobe");
        let leases = lease::leases_path(&manifest);
        // raw ledger lines from before the probe (and seq) fields existed
        std::fs::write(
            &leases,
            "{\"action\":\"claim\",\"expires_ms\":900000000000000,\"run_id\":\"old\",\
             \"token\":1,\"worker\":\"w0\"}\n",
        )
        .unwrap();
        let view = load_fleet(&manifest, 1_000, 0).unwrap();
        let r = run_of(&view, "old");
        assert_eq!((r.state, r.probe.as_deref(), r.seq), ("active", None, 0));
        let w0 = view.workers.iter().find(|w| w.worker == "w0").unwrap();
        assert_eq!(w0.probe, None);
        assert_eq!(w0.reachable, None, "no probe address: federation never attempted");
    }

    #[test]
    fn empty_and_absent_times_files_yield_a_clean_view() {
        let manifest = tmp_manifest("notimes");
        let leases = lease::leases_path(&manifest);
        append(&leases, &rec("r", "w0", 1, LeaseAction::Claim, u64::MAX / 2)).unwrap();
        // absent times file
        let view = load_fleet(&manifest, 1_000, 0).unwrap();
        assert_eq!((view.reclaims, view.rotations, view.stolen_shards), (0, 0, 0));
        // empty times file
        std::fs::write(SweepManifest::times_path(&manifest), "").unwrap();
        let view = load_fleet(&manifest, 1_000, 0).unwrap();
        assert_eq!(view.active, 1);
        assert_eq!((view.fenced_events, view.aborts), (0, 0));
    }

    #[test]
    fn federation_merges_live_rows_and_degrades_when_unreachable() {
        let manifest = tmp_manifest("fed");
        let leases = lease::leases_path(&manifest);
        // a real worker probe with one live run
        let board = StatusBoard::new();
        let probe = board.register("run-live", 10);
        probe.set_running(10);
        probe.record_step(
            7,
            0.125,
            0.0,
            obj(vec![("step", Json::from(7usize)), ("loss", Json::from(0.125))]),
        );
        let server = ProbeServer::start(board, 0).unwrap();
        let live_addr = format!("127.0.0.1:{}", server.port());
        // an address that refuses connections: bind, learn, drop
        let dead_addr = {
            let l = TcpListener::bind(("127.0.0.1", 0)).unwrap();
            format!("127.0.0.1:{}", l.local_addr().unwrap().port())
        };
        let mut claim = rec("run-live", "w0", 1, LeaseAction::Claim, u64::MAX / 2);
        claim.probe = Some(live_addr);
        append(&leases, &claim).unwrap();
        let mut claim = rec("run-gone", "w1", 1, LeaseAction::Claim, u64::MAX / 2);
        claim.probe = Some(dead_addr);
        append(&leases, &claim).unwrap();

        let mut view = load_fleet(&manifest, 1_000, 0).unwrap();
        view.federate(Duration::from_millis(300));
        let live = run_of(&view, "run-live").live.as_ref().expect("live row merged");
        assert_eq!(live.get("step").unwrap().as_usize().unwrap(), 7);
        assert_eq!(live.get("loss").unwrap().as_f64().unwrap(), 0.125);
        assert!(live.opt("loss_tail").is_none(), "federation uses the summary view");
        assert!(run_of(&view, "run-gone").live.is_none(), "unreachable degrades to ledger-only");
        let reach = |w: &str| {
            view.workers.iter().find(|x| x.worker == w).unwrap().reachable
        };
        assert_eq!(reach("w0"), Some(true));
        assert_eq!(reach("w1"), Some(false));
        // the fleet exposition carries the federated gauges + ledger counters
        let text = render_fleet(&view);
        assert!(text.contains("addax_run_step{run_id=\"run-live\"} 7"), "{text}");
        assert!(text.contains("addax_run_loss{run_id=\"run-live\"} 0.125"), "{text}");
        assert!(text.contains("addax_fenced_rows_total 0"), "{text}");
        assert!(text.contains("addax_lease_active{worker=\"w0\"} 1"), "{text}");
        assert!(!text.contains("addax_run_step{run_id=\"run-gone\"}"), "{text}");
    }

    #[test]
    fn fleet_server_serves_fleet_json_and_prometheus_text() {
        let manifest = tmp_manifest("server");
        let mut m = SweepManifest::load(&manifest).unwrap();
        m.append(done_row(2)).unwrap();
        let leases = lease::leases_path(&manifest);
        append(&leases, &rec("r-open", "w0", 1, LeaseAction::Claim, u64::MAX / 2)).unwrap();
        let server = FleetServer::start(
            manifest.clone(),
            0,
            0,
            Duration::from_millis(100),
        )
        .unwrap();
        let fetch = |path: &str| -> (String, String) {
            let mut s = TcpStream::connect(server.addr()).unwrap();
            write!(s, "GET {path} HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
            let mut resp = String::new();
            s.read_to_string(&mut resp).unwrap();
            let (head, body) = resp.split_once("\r\n\r\n").expect("header/body split");
            (head.to_string(), body.to_string())
        };
        let (head, body) = fetch("/fleet");
        assert!(head.contains("200"), "{head}");
        let v = Json::parse(&body).unwrap();
        assert_eq!(v.get("totals").unwrap().get("done").unwrap().as_usize().unwrap(), 1);
        assert_eq!(v.get("totals").unwrap().get("active").unwrap().as_usize().unwrap(), 1);
        let (head, body) = fetch("/metrics");
        assert!(head.contains("text/plain; version=0.0.4"), "{head}");
        assert!(body.contains("# TYPE addax_fenced_rows_total counter"), "{body}");
        let (head, _) = fetch("/nope");
        assert!(head.contains("404"), "{head}");
        let (head, _) = fetch("/healthz");
        assert!(head.contains("200"), "{head}");
        drop(server); // must join cleanly
    }
}
