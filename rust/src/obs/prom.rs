//! Prometheus text exposition (format 0.0.4) for the probe plane.
//!
//! Both observability servers answer `GET /metrics` through here: the
//! per-worker [`ProbeServer`](super::ProbeServer) renders its
//! [`StatusBoard`] (live in-process runs + the RSS/leak detector), and
//! the fleet aggregator renders its ledger-reconstructed
//! [`FleetView`](super::FleetView). One scrape config covers both — see
//! OPERATIONS.md for the recipe.
//!
//! Format rules kept here (and checked by CI's python validator):
//!
//! * every metric gets exactly one `# HELP` and one `# TYPE` line,
//!   immediately followed by all of its samples (series grouped);
//! * metric names match `[a-zA-Z_:][a-zA-Z0-9_:]*`, label values are
//!   escaped (`\\`, `\"`, `\n`);
//! * absent measurements are *omitted*, never emitted as 0 or NaN — the
//!   same "null is not zero" rule the JSON endpoints follow;
//! * no duplicate series: one writer walks each metric once.

use std::fmt::Write as _;

use super::{mem, MemSamples, StatusBoard};

/// Escape a label value per the exposition format: backslash, double
/// quote and newline.
pub fn escape_label_value(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Render an f64 the exposition format accepts (`NaN`, `+Inf`, `-Inf`
/// spellings — Rust's `Display` would print `inf`).
pub fn format_value(v: f64) -> String {
    if v.is_nan() {
        "NaN".to_string()
    } else if v.is_infinite() {
        if v > 0.0 { "+Inf".to_string() } else { "-Inf".to_string() }
    } else {
        format!("{v}")
    }
}

/// Incremental exposition writer: `header` once per metric, then its
/// samples — the call order is the grouping guarantee.
#[derive(Debug, Default)]
pub struct PromText {
    out: String,
}

impl PromText {
    pub fn new() -> Self {
        Self::default()
    }

    /// Emit the `# HELP` / `# TYPE` pair for `name` (`typ` is `gauge`
    /// or `counter`). Call exactly once per metric, before its samples.
    pub fn header(&mut self, name: &str, typ: &str, help: &str) {
        let _ = writeln!(self.out, "# HELP {name} {help}");
        let _ = writeln!(self.out, "# TYPE {name} {typ}");
    }

    /// Emit one sample line, with optional labels.
    pub fn sample(&mut self, name: &str, labels: &[(&str, &str)], value: f64) {
        if labels.is_empty() {
            let _ = writeln!(self.out, "{name} {}", format_value(value));
        } else {
            let rendered: Vec<String> = labels
                .iter()
                .map(|(k, v)| format!("{k}=\"{}\"", escape_label_value(v)))
                .collect();
            let _ = writeln!(self.out, "{name}{{{}}} {}", rendered.join(","), format_value(value));
        }
    }

    pub fn finish(self) -> String {
        self.out
    }
}

/// The per-worker probe server's `GET /metrics`: per-run gauges off the
/// status board plus the process-level memory series. Fleet-wide
/// counters that only the ledger knows (fenced rows) live on the
/// aggregator's exposition ([`fleet`](super::fleet)), not here — a
/// worker never fabricates a 0 for a number it doesn't track.
pub fn render_worker(board: &StatusBoard, samples: &MemSamples) -> String {
    let mut p = PromText::new();
    let runs: Vec<_> = board.probes().iter().map(|r| r.prom_sample()).collect();

    p.header("addax_run_step", "gauge", "Latest training step of a probed run.");
    for r in &runs {
        p.sample("addax_run_step", &[("run_id", &r.run_id)], r.step as f64);
    }
    p.header("addax_run_loss", "gauge", "Latest training loss of a probed run.");
    for r in &runs {
        if let Some(loss) = r.loss {
            p.sample("addax_run_loss", &[("run_id", &r.run_id)], loss);
        }
    }
    p.header("addax_run_best_val", "gauge", "Best validation accuracy so far.");
    for r in &runs {
        if let Some(best) = r.best_val {
            p.sample("addax_run_best_val", &[("run_id", &r.run_id)], best);
        }
    }
    p.header(
        "addax_lease_active",
        "gauge",
        "1 while this process holds (or awaits execution under) the run's lease.",
    );
    for r in &runs {
        p.sample(
            "addax_lease_active",
            &[("run_id", &r.run_id)],
            if r.lease_active { 1.0 } else { 0.0 },
        );
    }
    p.header(
        "addax_stolen_shards_total",
        "counter",
        "Probe shards of this worker's runs computed by thief workers.",
    );
    p.sample(
        "addax_stolen_shards_total",
        &[],
        runs.iter().map(|r| r.stolen).sum::<u64>() as f64,
    );
    p.header(
        "addax_footprint_bytes",
        "gauge",
        "Analytic memory-model footprint of the registered runs.",
    );
    p.sample("addax_footprint_bytes", &[], board.analytic_bytes());
    p.header("addax_rss_bytes", "gauge", "Resident set size of this worker process.");
    if let Some(rss) = mem::rss_bytes() {
        p.sample("addax_rss_bytes", &[], rss as f64);
    }
    // The /mem leak detector's regression, as scrapeable gauges: slope
    // of RSS over the sampling window and the fit's r² (omitted until
    // enough samples exist for a fit, like /mem reports null).
    if let Some((slope, r2)) = samples.fit() {
        p.header(
            "addax_mem_slope_bytes_per_sec",
            "gauge",
            "RSS growth slope over the leak-detector window.",
        );
        p.sample("addax_mem_slope_bytes_per_sec", &[], slope);
        p.header("addax_mem_r2", "gauge", "Fit quality (r-squared) of the RSS slope.");
        p.sample("addax_mem_r2", &[], r2);
    }
    p.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jsonlite::{obj, Json};

    #[test]
    fn label_escaping_covers_the_format_rules() {
        assert_eq!(escape_label_value("plain"), "plain");
        assert_eq!(escape_label_value("a\"b"), "a\\\"b");
        assert_eq!(escape_label_value("a\\b"), "a\\\\b");
        assert_eq!(escape_label_value("a\nb"), "a\\nb");
        assert_eq!(format_value(2.5), "2.5");
        assert_eq!(format_value(f64::NAN), "NaN");
        assert_eq!(format_value(f64::INFINITY), "+Inf");
        assert_eq!(format_value(f64::NEG_INFINITY), "-Inf");
    }

    #[test]
    fn worker_exposition_is_well_formed() {
        let board = StatusBoard::new();
        let p = board.register("run-a", 10);
        p.set_running(10);
        p.record_step(
            3,
            0.5,
            0.25,
            obj(vec![("step", Json::from(3usize)), ("loss", Json::from(0.5))]),
        );
        p.record_eval(4, 0.7, 0.7, obj(vec![("val_acc", Json::from(0.7))]));
        p.set_lease("w0", 2);
        board.register("run-b", 5); // pending, no loss yet
        let text = render_worker(&board, &MemSamples::default());

        // every metric has its HELP/TYPE pair, and series are unique
        let mut seen_series = std::collections::BTreeSet::new();
        let mut helped = std::collections::BTreeSet::new();
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("# HELP ") {
                let name = rest.split_whitespace().next().unwrap();
                assert!(helped.insert(name.to_string()), "duplicate HELP for {name}");
                continue;
            }
            if line.starts_with('#') {
                continue;
            }
            let series = line.rsplit_once(' ').unwrap().0;
            assert!(seen_series.insert(series.to_string()), "duplicate series {series}");
            let metric = series.split('{').next().unwrap();
            assert!(helped.contains(metric), "sample before HELP for {metric}");
            assert!(
                metric.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':'),
                "bad metric name {metric}"
            );
        }
        // the advertised series are present with the right values
        assert!(text.contains("addax_run_step{run_id=\"run-a\"} 4"), "{text}");
        assert!(text.contains("addax_run_loss{run_id=\"run-a\"} 0.5"), "{text}");
        assert!(text.contains("addax_run_best_val{run_id=\"run-a\"} 0.7"), "{text}");
        assert!(text.contains("addax_lease_active{run_id=\"run-a\"} 1"), "{text}");
        assert!(text.contains("addax_lease_active{run_id=\"run-b\"} 0"), "{text}");
        assert!(text.contains("addax_stolen_shards_total 0"), "{text}");
        assert!(text.contains("addax_footprint_bytes"), "{text}");
        // absent measurements are omitted, not zeroed
        assert!(!text.contains("addax_run_loss{run_id=\"run-b\"}"), "{text}");
        // too few mem samples: the detector gauges are absent entirely
        assert!(!text.contains("addax_mem_slope_bytes_per_sec"), "{text}");
    }
}
