//! Live observability plane: an opt-in, in-process status registry plus
//! the embedded HTTP probe server that exposes it.
//!
//! Today the only window into a running sweep is the JSONL side files
//! *after* it finishes. This module adds a live one — without touching
//! a single deterministic byte:
//!
//! * [`StatusBoard`] — a shared registry of per-run [`RunProbe`]s. The
//!   sweep worker registers runs, the coordinator's training loop
//!   updates them at step boundaries, and the probe server reads them.
//! * [`RunProbe`] — one run's live status (step, loss/val/`zo_loss`
//!   tails, lease token/seq, `resumed_from_step`, stolen-shard count)
//!   plus a bounded [`MetricsRing`] of recent telemetry rows, plus the
//!   three control flags (`checkpoint` / `pause` / `abort`) the HTTP
//!   control verbs set.
//! * [`http::ProbeServer`] — a tiny std-`TcpListener` HTTP/1.1 server
//!   (`--probe-port`; no new dependencies) serving `GET /runs`
//!   (`?last=N`, `?summary=1`), `GET /runs/<id>/metrics`
//!   (`?fields`/`?last`/`?where`/`?agg`), `GET /mem`, `GET /metrics`
//!   (Prometheus text exposition, [`prom`]) and
//!   `POST /runs/<id>/checkpoint|pause|resume|abort`.
//! * [`mem`] — actual RSS from `/proc/self/statm` vs. the analytic
//!   `memory::footprint` pricing, with a least-squares leak detector
//!   over a configurable window (`--mem-window-secs`).
//! * [`fleet`] — the read-only fleet aggregator behind
//!   `addax fleet-status`: reconstructs cross-worker state from the
//!   manifest/lease/times side files alone, federates live `/runs`
//!   tails from worker probes advertised in lease records, and serves
//!   `GET /fleet` + `GET /metrics` for the whole fleet.
//!
//! ## Invariant: probes cannot move a deterministic byte
//!
//! Everything the probe plane *writes* is a control flag consumed at a
//! step boundary, and every consumption routes through machinery that
//! already preserves byte-identity:
//!
//! * `checkpoint` requests one extra snapshot — snapshots record the
//!   trajectory, they never steer it;
//! * `pause` parks the training loop between steps — pure wall-clock,
//!   which lives in the times side file, outside the manifest contract;
//! * `abort` rides the exact `halt_after` rail: snapshot first, then a
//!   typed [`Halted`] error, and a later resume finishes the run
//!   byte-identically (`tests/probe_server.rs` proves the compacted
//!   manifest `cmp`-matches a probe-free control).
//!
//! Everything the probe plane *reads* is a copy taken at a step
//! boundary under a mutex the training loop holds only long enough to
//! clone small scalars. No probe read or HTTP request appears anywhere
//! in a gradient, a sample draw, or a manifest row.
//!
//! [`Halted`]: crate::coordinator::Halted
//! [`MetricsRing`]: crate::metrics::MetricsRing

pub mod fleet;
pub mod http;
pub mod mem;
pub mod prom;

pub use fleet::{FleetServer, FleetView};
pub use http::ProbeServer;
pub use mem::{rss_bytes, MemSamples};

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::jsonlite::{obj, Json};
use crate::metrics::MetricsRing;

/// Lifecycle phase of a probed run, as shown in `GET /runs`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RunPhase {
    /// Registered but not yet claimed/executing in this process.
    Pending,
    Running,
    /// Completed: its manifest row is durable (or committed by someone).
    Done,
    /// Preempted via `halt_after`, chaos, or a probe `abort` — it has
    /// checkpoints, not a row; a resume sweep finishes it.
    Halted,
}

impl RunPhase {
    pub fn label(&self) -> &'static str {
        match self {
            RunPhase::Pending => "pending",
            RunPhase::Running => "running",
            RunPhase::Done => "done",
            RunPhase::Halted => "halted",
        }
    }
}

/// Mutable status scalars, updated at step boundaries under one mutex.
#[derive(Debug)]
struct RunState {
    phase: RunPhase,
    step: usize,
    steps_total: usize,
    loss: Option<f64>,
    zo_loss: Option<f64>,
    val_acc: Option<f64>,
    best_val: Option<f64>,
    resumed_from_step: Option<usize>,
    /// Probe shards of this run computed by thief workers (fleet).
    stolen: u64,
    /// Analytic `memory::footprint` pricing for this run, in bytes.
    footprint_bytes: Option<f64>,
    /// Fleet lease identity: `(worker, fencing token)`.
    lease: Option<(String, u64)>,
    /// When `record_step`/`record_eval` last touched this probe, plus
    /// the first touch and the touch count — enough to derive both the
    /// `last_update_ms` age and the observed mean update cadence the
    /// `stale` flag compares against.
    last_update: Option<Instant>,
    first_update: Option<Instant>,
    updates: u64,
}

/// One run's live status + control flags. Shared as an `Arc` between
/// the sweep worker (writes lease/steal fields), the coordinator's
/// training loop (writes step telemetry, consumes control flags) and
/// the probe server (reads everything, sets control flags).
#[derive(Debug)]
pub struct RunProbe {
    pub run_id: String,
    state: Mutex<RunState>,
    ring: Mutex<MetricsRing>,
    /// Renewal sequence of the current lease heartbeat (fleet).
    lease_seq: AtomicU64,
    ckpt_req: AtomicBool,
    pause_req: AtomicBool,
    abort_req: AtomicBool,
}

/// Recent-row window per run: large enough to cover several eval
/// cadences of the smoke grids, small enough to be memory-noise.
const RING_CAP: usize = 256;

/// Default loss/val tail length in `/runs` rows (`?last=` overrides).
pub const DEFAULT_TAIL: usize = 5;

/// Minimum quiet time before the `stale` flag can fire, regardless of
/// how fast the run's observed cadence is.
const STALE_FLOOR_MS: f64 = 1_000.0;

impl RunProbe {
    fn new(run_id: &str, steps_total: usize) -> Self {
        Self {
            run_id: run_id.to_string(),
            state: Mutex::new(RunState {
                phase: RunPhase::Pending,
                step: 0,
                steps_total,
                loss: None,
                zo_loss: None,
                val_acc: None,
                best_val: None,
                resumed_from_step: None,
                stolen: 0,
                footprint_bytes: None,
                lease: None,
                last_update: None,
                first_update: None,
                updates: 0,
            }),
            ring: Mutex::new(MetricsRing::new(RING_CAP)),
            lease_seq: AtomicU64::new(0),
            ckpt_req: AtomicBool::new(false),
            pause_req: AtomicBool::new(false),
            abort_req: AtomicBool::new(false),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, RunState> {
        // A poisoned mutex means a panic mid-update; status telemetry
        // must keep serving rather than cascade the panic into the
        // probe server thread.
        self.state.lock().unwrap_or_else(|p| p.into_inner())
    }

    // ---- writers (sweep worker / coordinator side) ---------------------

    pub fn set_footprint_bytes(&self, bytes: f64) {
        self.lock().footprint_bytes = Some(bytes);
    }

    pub fn set_lease(&self, worker: &str, token: u64) {
        self.lock().lease = Some((worker.to_string(), token));
        self.lease_seq.store(0, Ordering::Relaxed);
    }

    /// Heartbeat renewals bump this — `/runs` shows a live holder's
    /// logical clock advancing, which is exactly what a reclaim
    /// confirmation looks for.
    pub fn set_lease_seq(&self, seq: u64) {
        self.lease_seq.store(seq, Ordering::Relaxed);
    }

    pub fn set_running(&self, steps_total: usize) {
        let mut s = self.lock();
        s.phase = RunPhase::Running;
        s.steps_total = steps_total;
    }

    pub fn set_resumed_from(&self, step: usize) {
        let mut s = self.lock();
        s.resumed_from_step = Some(step);
        s.step = step;
    }

    pub fn set_stolen(&self, shards: u64) {
        self.lock().stolen = shards;
    }

    pub fn set_done(&self) {
        self.lock().phase = RunPhase::Done;
    }

    pub fn set_halted(&self, at_step: usize) {
        let mut s = self.lock();
        s.phase = RunPhase::Halted;
        s.step = at_step;
    }

    /// Step-boundary telemetry from the training loop: update the
    /// scalars and push the same row the JSONL logger writes into the
    /// ring (one lock each, scalars only — the loop never blocks on a
    /// slow HTTP reader).
    pub fn record_step(&self, step: usize, loss: f64, zo_loss: f64, row: Json) {
        {
            let mut s = self.lock();
            s.phase = RunPhase::Running;
            s.step = step;
            s.loss = Some(loss);
            s.zo_loss = Some(zo_loss);
            Self::touch(&mut s);
        }
        self.ring.lock().unwrap_or_else(|p| p.into_inner()).push(row);
    }

    pub fn record_eval(&self, step: usize, val_acc: f64, best_val: f64, row: Json) {
        {
            let mut s = self.lock();
            s.step = step;
            s.val_acc = Some(val_acc);
            s.best_val = Some(best_val);
            Self::touch(&mut s);
        }
        self.ring.lock().unwrap_or_else(|p| p.into_inner()).push(row);
    }

    fn touch(s: &mut RunState) {
        let now = Instant::now();
        s.last_update = Some(now);
        s.first_update.get_or_insert(now);
        s.updates += 1;
    }

    // ---- control plane (HTTP side sets, training loop consumes) --------

    pub fn request_checkpoint(&self) {
        self.ckpt_req.store(true, Ordering::Relaxed);
    }

    pub fn request_pause(&self) {
        self.pause_req.store(true, Ordering::Relaxed);
    }

    pub fn request_resume(&self) {
        self.pause_req.store(false, Ordering::Relaxed);
    }

    pub fn request_abort(&self) {
        self.abort_req.store(true, Ordering::Relaxed);
    }

    /// Consume a pending checkpoint request (one snapshot per request).
    pub fn take_checkpoint_request(&self) -> bool {
        self.ckpt_req.swap(false, Ordering::Relaxed)
    }

    pub fn paused(&self) -> bool {
        self.pause_req.load(Ordering::Relaxed)
    }

    pub fn abort_requested(&self) -> bool {
        self.abort_req.load(Ordering::Relaxed)
    }

    /// Consume a pending abort request.
    pub fn take_abort_request(&self) -> bool {
        self.abort_req.swap(false, Ordering::Relaxed)
    }

    // ---- readers (probe server side) -----------------------------------

    /// The `GET /runs` entry for this run. Numbers that can be absent
    /// (no step yet, no eval yet, no lease) are `null`, never zero —
    /// an operator must be able to tell "not measured" from "0.0".
    pub fn to_json(&self) -> Json {
        self.to_json_opts(DEFAULT_TAIL, false)
    }

    /// [`RunProbe::to_json`] with the scrape-size knobs: `tail_rows`
    /// caps the loss/val tails (`?last=N`), and `summary` omits them
    /// entirely (`?summary=1`) — so a thousand-run grid can't make one
    /// scrape allocate the whole board.
    pub fn to_json_opts(&self, tail_rows: usize, summary: bool) -> Json {
        let s = self.lock();
        let opt_num = |v: Option<f64>| v.map(Json::from).unwrap_or(Json::Null);
        let lease = match &s.lease {
            Some((worker, token)) => obj(vec![
                ("seq", Json::from(self.lease_seq.load(Ordering::Relaxed) as usize)),
                ("token", Json::from(*token as usize)),
                ("worker", Json::from(worker.clone())),
            ]),
            None => Json::Null,
        };
        let tail = |key: &str| {
            let rows = self
                .ring
                .lock()
                .unwrap_or_else(|p| p.into_inner())
                .query(Some(&[key.to_string()]), tail_rows);
            Json::Arr(
                rows.into_iter().filter_map(|r| r.opt(key).cloned()).collect(),
            )
        };
        // Age of the most recent record_step/record_eval, plus the
        // wedged-worker flag: running, updated at least twice (so a
        // cadence exists), and quiet past 3× the observed mean
        // inter-update gap. The floor keeps microsecond-cadence mock
        // runs from flapping the flag between scrape and step.
        let (age_ms, stale) = match (s.last_update, s.first_update) {
            (Some(last), Some(first)) => {
                let age = last.elapsed().as_secs_f64() * 1e3;
                let running = s.phase == RunPhase::Running && !self.paused();
                let stale = running && s.updates >= 2 && {
                    let mean_gap_ms =
                        (last - first).as_secs_f64() * 1e3 / (s.updates - 1) as f64;
                    age > (3.0 * mean_gap_ms).max(STALE_FLOOR_MS)
                };
                (Json::from(age as usize), stale)
            }
            _ => (Json::Null, false),
        };
        let mut pairs = vec![
            ("run_id", Json::from(self.run_id.clone())),
            ("phase", Json::from(self.lock_free_phase_label(&s))),
            ("step", Json::from(s.step)),
            ("steps_total", Json::from(s.steps_total)),
            ("loss", opt_num(s.loss)),
            ("zo_loss", opt_num(s.zo_loss)),
            ("val_acc", opt_num(s.val_acc)),
            ("best_val", opt_num(s.best_val)),
            (
                "resumed_from_step",
                s.resumed_from_step.map(Json::from).unwrap_or(Json::Null),
            ),
            ("stolen", Json::from(s.stolen as usize)),
            ("footprint_bytes", opt_num(s.footprint_bytes)),
            ("lease", lease),
            ("last_update_ms", age_ms),
            ("stale", Json::from(stale)),
        ];
        if !summary {
            pairs.push(("loss_tail", tail("loss")));
            pairs.push(("val_tail", tail("val_acc")));
        }
        obj(pairs)
    }

    fn lock_free_phase_label(&self, s: &RunState) -> &'static str {
        if s.phase == RunPhase::Running && self.paused() {
            "paused"
        } else {
            s.phase.label()
        }
    }

    /// `GET /runs/<id>/metrics` — the last `last` ring rows, projected
    /// to `fields` when given.
    pub fn metrics_json(&self, fields: Option<&[String]>, last: usize) -> Json {
        Json::Arr(self.ring.lock().unwrap_or_else(|p| p.into_inner()).query(fields, last))
    }

    /// `GET /runs/<id>/metrics?where=…` — the filtered window, projected.
    pub fn metrics_json_where(
        &self,
        fields: Option<&[String]>,
        last: usize,
        preds: &[crate::metrics::Predicate],
    ) -> Json {
        Json::Arr(
            self.ring
                .lock()
                .unwrap_or_else(|p| p.into_inner())
                .query_where(fields, last, preds),
        )
    }

    /// `GET /runs/<id>/metrics?agg=…` — aggregates over the filtered
    /// window, keyed by clause (`"mean:loss"`, `"count"`, …).
    pub fn metrics_agg_json(
        &self,
        last: usize,
        preds: &[crate::metrics::Predicate],
        aggs: &[crate::metrics::AggSpec],
    ) -> Json {
        self.ring.lock().unwrap_or_else(|p| p.into_inner()).aggregate(last, preds, aggs)
    }

    /// Analytic footprint in bytes, if the scheduler priced this run.
    pub fn footprint_bytes(&self) -> Option<f64> {
        self.lock().footprint_bytes
    }

    /// Snapshot of the scalars the Prometheus exposition renders —
    /// one lock, no JSON round-trip.
    pub fn prom_sample(&self) -> PromSample {
        let s = self.lock();
        PromSample {
            run_id: self.run_id.clone(),
            step: s.step,
            loss: s.loss,
            best_val: s.best_val,
            lease_active: s.lease.is_some()
                && matches!(s.phase, RunPhase::Pending | RunPhase::Running),
            stolen: s.stolen,
            footprint_bytes: s.footprint_bytes,
        }
    }
}

/// One run's scalar snapshot for `GET /metrics` (see [`prom`]).
#[derive(Clone, Debug)]
pub struct PromSample {
    pub run_id: String,
    pub step: usize,
    pub loss: Option<f64>,
    pub best_val: Option<f64>,
    /// The run currently holds (or awaits execution under) a lease in
    /// this process — done/halted runs have retired theirs.
    pub lease_active: bool,
    pub stolen: u64,
    pub footprint_bytes: Option<f64>,
}

/// The shared run registry: cheap to clone (an `Arc`), safe to share
/// between the sweep worker threads and the probe server thread.
#[derive(Clone, Debug, Default)]
pub struct StatusBoard {
    runs: Arc<Mutex<BTreeMap<String, Arc<RunProbe>>>>,
}

impl StatusBoard {
    pub fn new() -> Self {
        Self::default()
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, BTreeMap<String, Arc<RunProbe>>> {
        self.runs.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Get-or-insert the probe for `run_id`. Re-registering (a fleet
    /// reclaim, a resume sweep) returns the *same* probe, so control
    /// flags set while a run was between sessions are honored at its
    /// next step boundary.
    pub fn register(&self, run_id: &str, steps_total: usize) -> Arc<RunProbe> {
        Arc::clone(
            self.lock()
                .entry(run_id.to_string())
                .or_insert_with(|| Arc::new(RunProbe::new(run_id, steps_total))),
        )
    }

    pub fn get(&self, run_id: &str) -> Option<Arc<RunProbe>> {
        self.lock().get(run_id).cloned()
    }

    pub fn len(&self) -> usize {
        self.lock().len()
    }

    pub fn is_empty(&self) -> bool {
        self.lock().is_empty()
    }

    /// The `GET /runs` payload: every registered run, in run-id order.
    pub fn runs_json(&self) -> Json {
        self.runs_json_opts(DEFAULT_TAIL, false)
    }

    /// [`StatusBoard::runs_json`] with the `?last=N` tail cap and the
    /// `?summary=1` tail-omitting mode.
    pub fn runs_json_opts(&self, tail_rows: usize, summary: bool) -> Json {
        let probes: Vec<Arc<RunProbe>> = self.lock().values().cloned().collect();
        Json::Arr(probes.iter().map(|p| p.to_json_opts(tail_rows, summary)).collect())
    }

    /// Every registered probe, in run-id order (the `/metrics` walk).
    pub fn probes(&self) -> Vec<Arc<RunProbe>> {
        self.lock().values().cloned().collect()
    }

    /// Sum of the analytic footprints of registered runs (for `/mem`).
    pub fn analytic_bytes(&self) -> f64 {
        let probes: Vec<Arc<RunProbe>> = self.lock().values().cloned().collect();
        probes.iter().filter_map(|p| p.footprint_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_is_get_or_insert_and_flags_survive() {
        let board = StatusBoard::new();
        let a = board.register("r1", 40);
        a.request_abort();
        let b = board.register("r1", 40);
        assert!(Arc::ptr_eq(&a, &b), "re-registration must return the same probe");
        assert!(b.take_abort_request(), "flags set between sessions survive");
        assert!(!b.take_abort_request(), "take consumes");
        assert_eq!(board.len(), 1);
    }

    #[test]
    fn status_json_distinguishes_null_from_zero() {
        let board = StatusBoard::new();
        let p = board.register("r1", 10);
        let v = p.to_json();
        assert_eq!(v.get("loss").unwrap(), &Json::Null);
        assert_eq!(v.get("lease").unwrap(), &Json::Null);
        assert_eq!(v.get("phase").unwrap().as_str().unwrap(), "pending");

        p.set_running(10);
        p.record_step(
            3,
            0.5,
            0.0,
            obj(vec![("step", Json::from(3usize)), ("loss", Json::from(0.5))]),
        );
        p.set_lease("w0", 2);
        p.set_lease_seq(7);
        let v = p.to_json();
        assert_eq!(v.get("step").unwrap().as_usize().unwrap(), 3);
        assert_eq!(v.get("loss").unwrap().as_f64().unwrap(), 0.5);
        let lease = v.get("lease").unwrap();
        assert_eq!(lease.get("worker").unwrap().as_str().unwrap(), "w0");
        assert_eq!(lease.get("token").unwrap().as_usize().unwrap(), 2);
        assert_eq!(lease.get("seq").unwrap().as_usize().unwrap(), 7);
        assert_eq!(v.get("loss_tail").unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn last_update_age_and_stale_flag() {
        let p = StatusBoard::new().register("r", 10);
        // never updated: age is null, stale is false
        let v = p.to_json();
        assert_eq!(v.get("last_update_ms").unwrap(), &Json::Null);
        assert!(!v.get("stale").unwrap().as_bool().unwrap());
        // one update: an age exists, but no cadence yet → not stale
        p.record_step(1, 0.9, 0.0, obj(vec![("step", Json::from(1usize))]));
        let v = p.to_json();
        assert!(v.get("last_update_ms").unwrap().as_usize().unwrap() < 10_000);
        assert!(!v.get("stale").unwrap().as_bool().unwrap(), "one update has no cadence");
        // a second update still isn't stale (quiet time under the floor)
        p.record_step(2, 0.8, 0.0, obj(vec![("step", Json::from(2usize))]));
        assert!(!p.to_json().get("stale").unwrap().as_bool().unwrap());
        // done runs are never stale, however long quiet
        p.set_done();
        assert!(!p.to_json().get("stale").unwrap().as_bool().unwrap());
    }

    #[test]
    fn summary_and_tail_cap_bound_the_scrape() {
        let p = StatusBoard::new().register("r", 10);
        for i in 0..8usize {
            p.record_step(
                i,
                1.0,
                0.0,
                obj(vec![("step", Json::from(i)), ("loss", Json::from(1.0))]),
            );
        }
        // default tail is 5
        assert_eq!(p.to_json().get("loss_tail").unwrap().as_arr().unwrap().len(), 5);
        // ?last=2 caps it
        assert_eq!(
            p.to_json_opts(2, false).get("loss_tail").unwrap().as_arr().unwrap().len(),
            2
        );
        // ?summary=1 omits the tails entirely but keeps the scalars
        let v = p.to_json_opts(5, true);
        assert!(v.opt("loss_tail").is_none());
        assert!(v.opt("val_tail").is_none());
        assert_eq!(v.get("step").unwrap().as_usize().unwrap(), 7);
        // the board-level variant threads the knobs through
        let board = StatusBoard::new();
        board.register("a", 1);
        let rows = board.runs_json_opts(3, true);
        assert!(rows.as_arr().unwrap()[0].opt("loss_tail").is_none());
    }

    #[test]
    fn pause_flag_shows_as_paused_phase() {
        let p = StatusBoard::new().register("r", 5);
        p.set_running(5);
        assert_eq!(p.to_json().get("phase").unwrap().as_str().unwrap(), "running");
        p.request_pause();
        assert!(p.paused());
        assert_eq!(p.to_json().get("phase").unwrap().as_str().unwrap(), "paused");
        p.request_resume();
        assert!(!p.paused());
    }
}
