//! Metrics: accuracy / macro-F1, curves, timers, JSONL run logs, and the
//! bounded [`MetricsRing`] that feeds the probe server's metrics endpoint.

use std::io::Write;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::jsonlite::Json;

/// Classification accuracy.
pub fn accuracy(pred: &[usize], truth: &[usize]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    if pred.is_empty() {
        return 0.0;
    }
    let hits = pred.iter().zip(truth).filter(|(p, t)| p == t).count();
    hits as f64 / pred.len() as f64
}

/// Macro-averaged F1 over `n_classes` (the paper reports accuracy/F1;
/// F1 matters for the skewed generation-style tasks).
pub fn macro_f1(pred: &[usize], truth: &[usize], n_classes: usize) -> f64 {
    assert_eq!(pred.len(), truth.len());
    let mut f1_sum = 0.0;
    let mut counted = 0;
    for c in 0..n_classes {
        let tp = pred.iter().zip(truth).filter(|(&p, &t)| p == c && t == c).count() as f64;
        let fp = pred.iter().zip(truth).filter(|(&p, &t)| p == c && t != c).count() as f64;
        let fn_ = pred.iter().zip(truth).filter(|(&p, &t)| p != c && t == c).count() as f64;
        if tp + fp + fn_ == 0.0 {
            continue; // class absent from both => skip (sklearn convention)
        }
        let f1 = if tp == 0.0 { 0.0 } else { 2.0 * tp / (2.0 * tp + fp + fn_) };
        f1_sum += f1;
        counted += 1;
    }
    if counted == 0 {
        0.0
    } else {
        f1_sum / counted as f64
    }
}

/// A (step, value) curve.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Curve {
    pub points: Vec<(usize, f64)>,
}

impl Curve {
    pub fn push(&mut self, step: usize, value: f64) {
        self.points.push((step, value));
    }

    pub fn last(&self) -> Option<f64> {
        self.points.last().map(|&(_, v)| v)
    }

    /// Smoothed value: mean of the last `k` points.
    pub fn tail_mean(&self, k: usize) -> f64 {
        let n = self.points.len();
        if n == 0 {
            return f64::NAN;
        }
        let start = n.saturating_sub(k);
        let slice = &self.points[start..];
        slice.iter().map(|&(_, v)| v).sum::<f64>() / slice.len() as f64
    }

    /// First step at which the curve dips below `threshold` (time-to-loss).
    pub fn first_below(&self, threshold: f64) -> Option<usize> {
        self.points.iter().find(|&&(_, v)| v < threshold).map(|&(s, _)| s)
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.points
                .iter()
                .map(|&(s, v)| Json::Arr(vec![Json::from(s), Json::from(v)]))
                .collect(),
        )
    }

    /// Parse the [`Curve::to_json`] form back ( `[[step, value], ...]` ).
    /// The sweep manifest stores per-run curves keyed by run id; the
    /// figure harnesses read them back through here.
    pub fn from_json(v: &Json) -> Result<Self> {
        let mut c = Curve::default();
        for p in v.as_arr()? {
            let pair = p.as_arr()?;
            anyhow::ensure!(pair.len() == 2, "curve point is not a [step, value] pair");
            c.push(pair[0].as_usize()?, pair[1].as_f64()?);
        }
        Ok(c)
    }
}

/// Buffered JSONL writer for per-step telemetry.
pub struct JsonlLogger {
    out: Option<std::io::BufWriter<std::fs::File>>,
}

impl JsonlLogger {
    /// `None` path = disabled logger (no-op). Truncates an existing file.
    pub fn new(path: Option<&Path>) -> Result<Self> {
        Self::open(path, false)
    }

    /// Like [`JsonlLogger::new`] but appends to an existing file — what a
    /// checkpoint-resumed run uses, so the rows its first session wrote
    /// for the already-completed steps survive.
    pub fn append(path: Option<&Path>) -> Result<Self> {
        Self::open(path, true)
    }

    fn open(path: Option<&Path>, append: bool) -> Result<Self> {
        let out = match path {
            Some(p) => {
                if let Some(dir) = p.parent() {
                    std::fs::create_dir_all(dir).ok();
                }
                let file = std::fs::OpenOptions::new()
                    .create(true)
                    .append(append)
                    .write(true)
                    .truncate(!append)
                    .open(p)
                    .with_context(|| format!("creating log {}", p.display()))?;
                Some(std::io::BufWriter::new(file))
            }
            None => None,
        };
        Ok(Self { out })
    }

    pub fn log(&mut self, record: Json) {
        if let Some(w) = &mut self.out {
            let _ = writeln!(w, "{}", record.dump());
        }
    }

    pub fn flush(&mut self) {
        if let Some(w) = &mut self.out {
            let _ = w.flush();
        }
    }
}

/// Comparison operator of one `?where=` clause.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CmpOp {
    Lt,
    Le,
    Gt,
    Ge,
    Eq,
    Ne,
}

/// One `field OP value` clause of a `?where=` filter over the metrics
/// ring (`?where=loss<2.0,step>=100` — clauses are comma-separated and
/// ANDed). Values are numeric: the ring's queryable fields (step, loss,
/// zo_loss, val_acc, best_val…) all are, and numeric comparison is what
/// threshold predicates mean.
#[derive(Clone, Debug)]
pub struct Predicate {
    pub field: String,
    pub op: CmpOp,
    pub value: f64,
}

impl Predicate {
    /// Parse a comma-separated clause list. Operators: `<= >= != < > =`
    /// (two-character forms matched first). Empty input is an error —
    /// callers pass the parameter only when present.
    pub fn parse_list(s: &str) -> Result<Vec<Predicate>> {
        let mut out = Vec::new();
        for clause in s.split(',') {
            let clause = clause.trim();
            if clause.is_empty() {
                bail!("empty where-clause in {s:?}");
            }
            let (op_str, op) = [
                ("<=", CmpOp::Le),
                (">=", CmpOp::Ge),
                ("!=", CmpOp::Ne),
                ("<", CmpOp::Lt),
                (">", CmpOp::Gt),
                ("=", CmpOp::Eq),
            ]
            .into_iter()
            .find(|(sym, _)| clause.contains(sym))
            .ok_or_else(|| {
                anyhow::anyhow!("where-clause {clause:?} has no operator (<=,>=,!=,<,>,=)")
            })?;
            let (field, value) = clause.split_once(op_str).unwrap();
            let field = field.trim();
            if field.is_empty() {
                bail!("where-clause {clause:?} names no field");
            }
            let value: f64 = value
                .trim()
                .parse()
                .with_context(|| format!("where-clause {clause:?}: value is not a number"))?;
            out.push(Predicate { field: field.to_string(), op, value });
        }
        Ok(out)
    }

    /// Does this row satisfy the clause? Non-object rows, absent fields
    /// and non-numeric values all fail it (mirroring projection's
    /// absent-field-is-omitted rule).
    pub fn matches(&self, row: &Json) -> bool {
        let Some(v) = row.opt(&self.field).and_then(|v| v.as_f64().ok()) else {
            return false;
        };
        match self.op {
            CmpOp::Lt => v < self.value,
            CmpOp::Le => v <= self.value,
            CmpOp::Gt => v > self.value,
            CmpOp::Ge => v >= self.value,
            CmpOp::Eq => v == self.value,
            CmpOp::Ne => v != self.value,
        }
    }
}

/// Aggregate function of one `?agg=` clause.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AggFn {
    Mean,
    Min,
    Max,
    Sum,
    Count,
}

/// One clause of an `?agg=` list: `mean:loss`, `max:step`, `min:f`,
/// `sum:f`, or a bare `count` (matching-row count, no field).
#[derive(Clone, Debug)]
pub struct AggSpec {
    pub func: AggFn,
    pub field: Option<String>,
}

impl AggSpec {
    /// Parse a comma-separated aggregate list (`mean:loss,max:step,count`).
    pub fn parse_list(s: &str) -> Result<Vec<AggSpec>> {
        let mut out = Vec::new();
        for clause in s.split(',') {
            let clause = clause.trim();
            if clause == "count" {
                out.push(AggSpec { func: AggFn::Count, field: None });
                continue;
            }
            let Some((func, field)) = clause.split_once(':') else {
                bail!("agg-clause {clause:?} is not `count` or `fn:field`");
            };
            let func = match func.trim() {
                "mean" => AggFn::Mean,
                "min" => AggFn::Min,
                "max" => AggFn::Max,
                "sum" => AggFn::Sum,
                other => bail!("unknown aggregate {other:?} (mean, min, max, sum, count)"),
            };
            let field = field.trim();
            if field.is_empty() {
                bail!("agg-clause {clause:?} names no field");
            }
            out.push(AggSpec { func, field: Some(field.to_string()) });
        }
        if out.is_empty() {
            bail!("empty agg list");
        }
        Ok(out)
    }

    /// The clause's output key: its canonical spec string.
    pub fn key(&self) -> String {
        let name = match self.func {
            AggFn::Mean => "mean",
            AggFn::Min => "min",
            AggFn::Max => "max",
            AggFn::Sum => "sum",
            AggFn::Count => "count",
        };
        match &self.field {
            Some(f) => format!("{name}:{f}"),
            None => name.to_string(),
        }
    }
}

/// A bounded ring of recent telemetry rows, feeding the probe server's
/// `GET /runs/<id>/metrics` endpoint (`obs` module).
///
/// The training loop pushes the same [`Json`] row it writes to the
/// JSONL log; old rows fall off the front at capacity. `query` is the
/// whole read API: the last `last` rows, optionally projected down to
/// a field subset (absent fields are simply omitted from that row, so
/// eval-only columns like `val_acc` don't force nulls into step rows).
#[derive(Clone, Debug)]
pub struct MetricsRing {
    cap: usize,
    rows: std::collections::VecDeque<Json>,
}

impl MetricsRing {
    pub fn new(cap: usize) -> Self {
        Self { cap: cap.max(1), rows: std::collections::VecDeque::new() }
    }

    pub fn push(&mut self, row: Json) {
        if self.rows.len() == self.cap {
            self.rows.pop_front();
        }
        self.rows.push_back(row);
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// The last `last` rows in insertion order, projected to `fields`
    /// when given (non-object rows pass through a projection untouched).
    pub fn query(&self, fields: Option<&[String]>, last: usize) -> Vec<Json> {
        self.query_where(fields, last, &[])
    }

    /// [`MetricsRing::query`] with a `?where=` filter: only rows
    /// satisfying **every** predicate survive (a row missing a
    /// predicate's field, or holding a non-numeric value there, is
    /// filtered out — the same absent-field rule projection uses). The
    /// `last` window applies *before* the filter: "of the last N rows,
    /// the matching ones", so the window stays the bounded-allocation
    /// knob it already was.
    pub fn query_where(
        &self,
        fields: Option<&[String]>,
        last: usize,
        preds: &[Predicate],
    ) -> Vec<Json> {
        let start = self.rows.len().saturating_sub(last);
        self.rows
            .iter()
            .skip(start)
            .filter(|row| preds.iter().all(|p| p.matches(row)))
            .map(|row| match (fields, row) {
                (Some(keys), Json::Obj(m)) => Json::Obj(
                    m.iter()
                        .filter(|(k, _)| keys.iter().any(|f| f == *k))
                        .map(|(k, v)| (k.clone(), v.clone()))
                        .collect(),
                ),
                _ => row.clone(),
            })
            .collect()
    }

    /// Evaluate `?agg=` clauses over the filtered window: one output key
    /// per clause (its literal spec string, e.g. `"mean:loss"`), `count`
    /// counting matching rows and the field aggregates skipping rows
    /// where the field is absent or non-numeric (projection's rule).
    /// An aggregate with no contributing rows is `null`, never `NaN`.
    pub fn aggregate(&self, last: usize, preds: &[Predicate], aggs: &[AggSpec]) -> Json {
        let rows = self.query_where(None, last, preds);
        let mut out = std::collections::BTreeMap::new();
        for spec in aggs {
            let value = match (&spec.func, &spec.field) {
                (AggFn::Count, _) => Json::from(rows.len()),
                (_, Some(field)) => {
                    let vals: Vec<f64> = rows
                        .iter()
                        .filter_map(|r| r.opt(field).and_then(|v| v.as_f64().ok()))
                        .collect();
                    if vals.is_empty() {
                        Json::Null
                    } else {
                        Json::from(match spec.func {
                            AggFn::Mean => vals.iter().sum::<f64>() / vals.len() as f64,
                            AggFn::Min => vals.iter().copied().fold(f64::INFINITY, f64::min),
                            AggFn::Max => {
                                vals.iter().copied().fold(f64::NEG_INFINITY, f64::max)
                            }
                            AggFn::Sum => vals.iter().sum::<f64>(),
                            AggFn::Count => unreachable!("count handled above"),
                        })
                    }
                }
                // parse_list never builds a field-less non-count clause
                (_, None) => Json::Null,
            };
            out.insert(spec.key(), value);
        }
        Json::Obj(out)
    }
}

impl Default for MetricsRing {
    fn default() -> Self {
        Self::new(256)
    }
}

/// Write a result JSON file under `results/`.
pub fn write_result(name: &str, value: &Json) -> Result<std::path::PathBuf> {
    let dir = std::path::PathBuf::from("results");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("{name}.json"));
    std::fs::write(&path, value.dump())?;
    Ok(path)
}

/// Simple fixed-width markdown-ish table printer for the repro harness.
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut s = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!(" {:<w$} |", c, w = w));
            }
            line.push('\n');
            line
        };
        s.push_str(&fmt_row(&self.header, &widths));
        s.push('|');
        for w in &widths {
            s.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        s.push('\n');
        for r in &self.rows {
            s.push_str(&fmt_row(r, &widths));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_basic() {
        assert_eq!(accuracy(&[0, 1, 1], &[0, 1, 0]), 2.0 / 3.0);
        assert_eq!(accuracy(&[], &[]), 0.0);
    }

    #[test]
    fn f1_perfect_and_degenerate() {
        assert!((macro_f1(&[0, 1, 0, 1], &[0, 1, 0, 1], 2) - 1.0).abs() < 1e-9);
        // all wrong
        assert!(macro_f1(&[1, 0], &[0, 1], 2) < 1e-9);
        // skipped empty classes
        let f = macro_f1(&[0, 0], &[0, 0], 5);
        assert!((f - 1.0).abs() < 1e-9);
    }

    #[test]
    fn f1_imbalanced_differs_from_accuracy() {
        // 9 of class 0 right, 1 of class 1 wrong
        let truth = vec![0, 0, 0, 0, 0, 0, 0, 0, 0, 1];
        let pred = vec![0; 10];
        let acc = accuracy(&pred, &truth);
        let f1 = macro_f1(&pred, &truth, 2);
        assert!(acc > 0.85 && f1 < 0.55, "acc {acc} f1 {f1}");
    }

    #[test]
    fn curve_json_roundtrip() {
        let mut c = Curve::default();
        for (s, v) in [(0, 3.5), (10, 2.25), (20, 1.0)] {
            c.push(s, v);
        }
        let back = Curve::from_json(&c.to_json()).unwrap();
        assert_eq!(back.points, c.points);
        assert!(Curve::from_json(&Json::Arr(vec![Json::from(1.0)])).is_err());
    }

    #[test]
    fn curve_ops() {
        let mut c = Curve::default();
        for (s, v) in [(0, 3.0), (10, 2.0), (20, 1.0)] {
            c.push(s, v);
        }
        assert_eq!(c.last(), Some(1.0));
        assert_eq!(c.first_below(1.5), Some(20));
        assert!((c.tail_mean(2) - 1.5).abs() < 1e-9);
    }

    #[test]
    fn jsonl_logger_append_preserves_earlier_rows() {
        let dir = std::env::temp_dir().join(format!("addax_jsonl_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("log.jsonl");
        let mut a = JsonlLogger::new(Some(&path)).unwrap();
        a.log(Json::from(1.0));
        a.flush();
        drop(a);
        let mut b = JsonlLogger::append(Some(&path)).unwrap();
        b.log(Json::from(2.0));
        b.flush();
        drop(b);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "1\n2\n", "append must keep the first session's rows");
        // new() truncates
        let mut c = JsonlLogger::new(Some(&path)).unwrap();
        c.log(Json::from(3.0));
        c.flush();
        drop(c);
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "3\n");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn metrics_ring_caps_and_projects() {
        use crate::jsonlite::obj;
        let mut r = MetricsRing::new(4);
        for i in 0..10usize {
            r.push(obj(vec![("step", Json::from(i)), ("loss", Json::from(i as f64))]));
        }
        assert_eq!(r.len(), 4, "ring is bounded");
        let all = r.query(None, 100);
        assert_eq!(all.len(), 4);
        assert_eq!(all[0].get("step").unwrap().as_usize().unwrap(), 6, "oldest surviving row");

        let tail = r.query(Some(&["loss".to_string()]), 2);
        assert_eq!(tail.len(), 2);
        assert_eq!(tail[1].get("loss").unwrap().as_f64().unwrap(), 9.0);
        assert!(tail[1].opt("step").is_none(), "projection drops other fields");

        // Projecting a field a row lacks omits it rather than nulling.
        let none = r.query(Some(&["val_acc".to_string()]), 1);
        assert!(none[0].as_obj().unwrap().is_empty());
    }

    /// The seeded ring every predicate test reads: 6 step rows with
    /// loss 5,4,3,2,1,0 at steps 0..=50, plus one eval row carrying
    /// `val_acc` but no `loss`.
    fn seeded_ring() -> MetricsRing {
        use crate::jsonlite::obj;
        let mut r = MetricsRing::new(16);
        for i in 0..6usize {
            r.push(obj(vec![
                ("step", Json::from(i * 10)),
                ("loss", Json::from(5.0 - i as f64)),
            ]));
        }
        r.push(obj(vec![("step", Json::from(55usize)), ("val_acc", Json::from(0.75))]));
        r
    }

    #[test]
    fn where_predicates_filter_rows() {
        let r = seeded_ring();
        // loss<2.0 keeps the loss=1 and loss=0 rows (the eval row has no
        // loss field and is filtered out, like projection omits it)
        let preds = Predicate::parse_list("loss<2.0").unwrap();
        let rows = r.query_where(None, 100, &preds);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].get("step").unwrap().as_usize().unwrap(), 40);
        // ANDed clauses: loss<2.0,step>=50 keeps exactly the last step row
        let preds = Predicate::parse_list("loss<2.0,step>=50").unwrap();
        let rows = r.query_where(None, 100, &preds);
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("loss").unwrap().as_f64().unwrap(), 0.0);
        // = and != are exact
        assert_eq!(r.query_where(None, 100, &Predicate::parse_list("loss=3").unwrap()).len(), 1);
        assert_eq!(r.query_where(None, 100, &Predicate::parse_list("loss!=3").unwrap()).len(), 5);
        // the `last` window applies before the filter
        let preds = Predicate::parse_list("loss<=5").unwrap();
        assert_eq!(r.query_where(None, 2, &preds).len(), 1, "window first, then filter");
        // projection still composes
        let filter = Predicate::parse_list("loss<2.0").unwrap();
        let rows = r.query_where(Some(&["step".to_string()]), 100, &filter);
        assert!(rows[0].opt("loss").is_none());
    }

    #[test]
    fn aggregates_match_hand_computed_values() {
        let r = seeded_ring();
        let aggs = AggSpec::parse_list("mean:loss,max:step,min:loss,sum:loss,count").unwrap();
        // unfiltered: losses 5,4,3,2,1,0 → mean 2.5, sum 15; steps up to
        // 55; count = 7 rows (the eval row counts, it matched no filter)
        let out = r.aggregate(100, &[], &aggs);
        assert_eq!(out.get("mean:loss").unwrap().as_f64().unwrap(), 2.5);
        assert_eq!(out.get("max:step").unwrap().as_f64().unwrap(), 55.0);
        assert_eq!(out.get("min:loss").unwrap().as_f64().unwrap(), 0.0);
        assert_eq!(out.get("sum:loss").unwrap().as_f64().unwrap(), 15.0);
        assert_eq!(out.get("count").unwrap().as_usize().unwrap(), 7);
        // filtered: loss<2.0,step>=100 from the issue's example shape —
        // here loss<2.0,step>=40 keeps losses 1,0 → mean 0.5, max step 50
        let preds = Predicate::parse_list("loss<2.0,step>=40").unwrap();
        let out = r.aggregate(100, &preds, &aggs);
        assert_eq!(out.get("mean:loss").unwrap().as_f64().unwrap(), 0.5);
        assert_eq!(out.get("max:step").unwrap().as_f64().unwrap(), 50.0);
        assert_eq!(out.get("count").unwrap().as_usize().unwrap(), 2);
        // an aggregate nothing contributes to is null, never NaN
        let preds = Predicate::parse_list("loss<-1").unwrap();
        let out = r.aggregate(100, &preds, &aggs);
        assert!(matches!(out.get("mean:loss").unwrap(), Json::Null));
        assert_eq!(out.get("count").unwrap().as_usize().unwrap(), 0);
    }

    #[test]
    fn predicate_and_agg_parsing_rejects_malformed_input() {
        assert!(Predicate::parse_list("").is_err());
        assert!(Predicate::parse_list("loss").is_err(), "no operator");
        assert!(Predicate::parse_list("<2.0").is_err(), "no field");
        assert!(Predicate::parse_list("loss<abc").is_err(), "non-numeric value");
        assert!(Predicate::parse_list("loss<2.0,").is_err(), "trailing comma");
        let p = &Predicate::parse_list("step>=10").unwrap()[0];
        assert_eq!((p.field.as_str(), p.op, p.value), ("step", CmpOp::Ge, 10.0));
        assert!(AggSpec::parse_list("").is_err());
        assert!(AggSpec::parse_list("median:loss").is_err(), "unknown fn");
        assert!(AggSpec::parse_list("mean:").is_err(), "no field");
        assert!(AggSpec::parse_list("mean").is_err(), "fn needs :field");
        assert_eq!(AggSpec::parse_list("count").unwrap()[0].key(), "count");
        assert_eq!(AggSpec::parse_list("mean:loss").unwrap()[0].key(), "mean:loss");
    }

    #[test]
    fn table_render_aligns() {
        let mut t = Table::new(&["a", "method"]);
        t.row(vec!["1".into(), "mezo".into()]);
        let s = t.render();
        assert!(s.contains("| a | method |"));
        assert!(s.lines().count() == 3);
    }
}
