//! Metrics: accuracy / macro-F1, curves, timers, JSONL run logs, and the
//! bounded [`MetricsRing`] that feeds the probe server's metrics endpoint.

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use crate::jsonlite::Json;

/// Classification accuracy.
pub fn accuracy(pred: &[usize], truth: &[usize]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    if pred.is_empty() {
        return 0.0;
    }
    let hits = pred.iter().zip(truth).filter(|(p, t)| p == t).count();
    hits as f64 / pred.len() as f64
}

/// Macro-averaged F1 over `n_classes` (the paper reports accuracy/F1;
/// F1 matters for the skewed generation-style tasks).
pub fn macro_f1(pred: &[usize], truth: &[usize], n_classes: usize) -> f64 {
    assert_eq!(pred.len(), truth.len());
    let mut f1_sum = 0.0;
    let mut counted = 0;
    for c in 0..n_classes {
        let tp = pred.iter().zip(truth).filter(|(&p, &t)| p == c && t == c).count() as f64;
        let fp = pred.iter().zip(truth).filter(|(&p, &t)| p == c && t != c).count() as f64;
        let fn_ = pred.iter().zip(truth).filter(|(&p, &t)| p != c && t == c).count() as f64;
        if tp + fp + fn_ == 0.0 {
            continue; // class absent from both => skip (sklearn convention)
        }
        let f1 = if tp == 0.0 { 0.0 } else { 2.0 * tp / (2.0 * tp + fp + fn_) };
        f1_sum += f1;
        counted += 1;
    }
    if counted == 0 {
        0.0
    } else {
        f1_sum / counted as f64
    }
}

/// A (step, value) curve.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Curve {
    pub points: Vec<(usize, f64)>,
}

impl Curve {
    pub fn push(&mut self, step: usize, value: f64) {
        self.points.push((step, value));
    }

    pub fn last(&self) -> Option<f64> {
        self.points.last().map(|&(_, v)| v)
    }

    /// Smoothed value: mean of the last `k` points.
    pub fn tail_mean(&self, k: usize) -> f64 {
        let n = self.points.len();
        if n == 0 {
            return f64::NAN;
        }
        let start = n.saturating_sub(k);
        let slice = &self.points[start..];
        slice.iter().map(|&(_, v)| v).sum::<f64>() / slice.len() as f64
    }

    /// First step at which the curve dips below `threshold` (time-to-loss).
    pub fn first_below(&self, threshold: f64) -> Option<usize> {
        self.points.iter().find(|&&(_, v)| v < threshold).map(|&(s, _)| s)
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.points
                .iter()
                .map(|&(s, v)| Json::Arr(vec![Json::from(s), Json::from(v)]))
                .collect(),
        )
    }

    /// Parse the [`Curve::to_json`] form back ( `[[step, value], ...]` ).
    /// The sweep manifest stores per-run curves keyed by run id; the
    /// figure harnesses read them back through here.
    pub fn from_json(v: &Json) -> Result<Self> {
        let mut c = Curve::default();
        for p in v.as_arr()? {
            let pair = p.as_arr()?;
            anyhow::ensure!(pair.len() == 2, "curve point is not a [step, value] pair");
            c.push(pair[0].as_usize()?, pair[1].as_f64()?);
        }
        Ok(c)
    }
}

/// Buffered JSONL writer for per-step telemetry.
pub struct JsonlLogger {
    out: Option<std::io::BufWriter<std::fs::File>>,
}

impl JsonlLogger {
    /// `None` path = disabled logger (no-op). Truncates an existing file.
    pub fn new(path: Option<&Path>) -> Result<Self> {
        Self::open(path, false)
    }

    /// Like [`JsonlLogger::new`] but appends to an existing file — what a
    /// checkpoint-resumed run uses, so the rows its first session wrote
    /// for the already-completed steps survive.
    pub fn append(path: Option<&Path>) -> Result<Self> {
        Self::open(path, true)
    }

    fn open(path: Option<&Path>, append: bool) -> Result<Self> {
        let out = match path {
            Some(p) => {
                if let Some(dir) = p.parent() {
                    std::fs::create_dir_all(dir).ok();
                }
                let file = std::fs::OpenOptions::new()
                    .create(true)
                    .append(append)
                    .write(true)
                    .truncate(!append)
                    .open(p)
                    .with_context(|| format!("creating log {}", p.display()))?;
                Some(std::io::BufWriter::new(file))
            }
            None => None,
        };
        Ok(Self { out })
    }

    pub fn log(&mut self, record: Json) {
        if let Some(w) = &mut self.out {
            let _ = writeln!(w, "{}", record.dump());
        }
    }

    pub fn flush(&mut self) {
        if let Some(w) = &mut self.out {
            let _ = w.flush();
        }
    }
}

/// A bounded ring of recent telemetry rows, feeding the probe server's
/// `GET /runs/<id>/metrics` endpoint (`obs` module).
///
/// The training loop pushes the same [`Json`] row it writes to the
/// JSONL log; old rows fall off the front at capacity. `query` is the
/// whole read API: the last `last` rows, optionally projected down to
/// a field subset (absent fields are simply omitted from that row, so
/// eval-only columns like `val_acc` don't force nulls into step rows).
#[derive(Clone, Debug)]
pub struct MetricsRing {
    cap: usize,
    rows: std::collections::VecDeque<Json>,
}

impl MetricsRing {
    pub fn new(cap: usize) -> Self {
        Self { cap: cap.max(1), rows: std::collections::VecDeque::new() }
    }

    pub fn push(&mut self, row: Json) {
        if self.rows.len() == self.cap {
            self.rows.pop_front();
        }
        self.rows.push_back(row);
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// The last `last` rows in insertion order, projected to `fields`
    /// when given (non-object rows pass through a projection untouched).
    pub fn query(&self, fields: Option<&[String]>, last: usize) -> Vec<Json> {
        let start = self.rows.len().saturating_sub(last);
        self.rows
            .iter()
            .skip(start)
            .map(|row| match (fields, row) {
                (Some(keys), Json::Obj(m)) => Json::Obj(
                    m.iter()
                        .filter(|(k, _)| keys.iter().any(|f| f == *k))
                        .map(|(k, v)| (k.clone(), v.clone()))
                        .collect(),
                ),
                _ => row.clone(),
            })
            .collect()
    }
}

impl Default for MetricsRing {
    fn default() -> Self {
        Self::new(256)
    }
}

/// Write a result JSON file under `results/`.
pub fn write_result(name: &str, value: &Json) -> Result<std::path::PathBuf> {
    let dir = std::path::PathBuf::from("results");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("{name}.json"));
    std::fs::write(&path, value.dump())?;
    Ok(path)
}

/// Simple fixed-width markdown-ish table printer for the repro harness.
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut s = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!(" {:<w$} |", c, w = w));
            }
            line.push('\n');
            line
        };
        s.push_str(&fmt_row(&self.header, &widths));
        s.push('|');
        for w in &widths {
            s.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        s.push('\n');
        for r in &self.rows {
            s.push_str(&fmt_row(r, &widths));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_basic() {
        assert_eq!(accuracy(&[0, 1, 1], &[0, 1, 0]), 2.0 / 3.0);
        assert_eq!(accuracy(&[], &[]), 0.0);
    }

    #[test]
    fn f1_perfect_and_degenerate() {
        assert!((macro_f1(&[0, 1, 0, 1], &[0, 1, 0, 1], 2) - 1.0).abs() < 1e-9);
        // all wrong
        assert!(macro_f1(&[1, 0], &[0, 1], 2) < 1e-9);
        // skipped empty classes
        let f = macro_f1(&[0, 0], &[0, 0], 5);
        assert!((f - 1.0).abs() < 1e-9);
    }

    #[test]
    fn f1_imbalanced_differs_from_accuracy() {
        // 9 of class 0 right, 1 of class 1 wrong
        let truth = vec![0, 0, 0, 0, 0, 0, 0, 0, 0, 1];
        let pred = vec![0; 10];
        let acc = accuracy(&pred, &truth);
        let f1 = macro_f1(&pred, &truth, 2);
        assert!(acc > 0.85 && f1 < 0.55, "acc {acc} f1 {f1}");
    }

    #[test]
    fn curve_json_roundtrip() {
        let mut c = Curve::default();
        for (s, v) in [(0, 3.5), (10, 2.25), (20, 1.0)] {
            c.push(s, v);
        }
        let back = Curve::from_json(&c.to_json()).unwrap();
        assert_eq!(back.points, c.points);
        assert!(Curve::from_json(&Json::Arr(vec![Json::from(1.0)])).is_err());
    }

    #[test]
    fn curve_ops() {
        let mut c = Curve::default();
        for (s, v) in [(0, 3.0), (10, 2.0), (20, 1.0)] {
            c.push(s, v);
        }
        assert_eq!(c.last(), Some(1.0));
        assert_eq!(c.first_below(1.5), Some(20));
        assert!((c.tail_mean(2) - 1.5).abs() < 1e-9);
    }

    #[test]
    fn jsonl_logger_append_preserves_earlier_rows() {
        let dir = std::env::temp_dir().join(format!("addax_jsonl_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("log.jsonl");
        let mut a = JsonlLogger::new(Some(&path)).unwrap();
        a.log(Json::from(1.0));
        a.flush();
        drop(a);
        let mut b = JsonlLogger::append(Some(&path)).unwrap();
        b.log(Json::from(2.0));
        b.flush();
        drop(b);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "1\n2\n", "append must keep the first session's rows");
        // new() truncates
        let mut c = JsonlLogger::new(Some(&path)).unwrap();
        c.log(Json::from(3.0));
        c.flush();
        drop(c);
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "3\n");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn metrics_ring_caps_and_projects() {
        use crate::jsonlite::obj;
        let mut r = MetricsRing::new(4);
        for i in 0..10usize {
            r.push(obj(vec![("step", Json::from(i)), ("loss", Json::from(i as f64))]));
        }
        assert_eq!(r.len(), 4, "ring is bounded");
        let all = r.query(None, 100);
        assert_eq!(all.len(), 4);
        assert_eq!(all[0].get("step").unwrap().as_usize().unwrap(), 6, "oldest surviving row");

        let tail = r.query(Some(&["loss".to_string()]), 2);
        assert_eq!(tail.len(), 2);
        assert_eq!(tail[1].get("loss").unwrap().as_f64().unwrap(), 9.0);
        assert!(tail[1].opt("step").is_none(), "projection drops other fields");

        // Projecting a field a row lacks omits it rather than nulling.
        let none = r.query(Some(&["val_acc".to_string()]), 1);
        assert!(none[0].as_obj().unwrap().is_empty());
    }

    #[test]
    fn table_render_aligns() {
        let mut t = Table::new(&["a", "method"]);
        t.row(vec!["1".into(), "mezo".into()]);
        let s = t.render();
        assert!(s.contains("| a | method |"));
        assert!(s.lines().count() == 3);
    }
}
