//! `addax` — the L3 coordinator CLI.
//!
//! ```text
//! addax train  [--config FILE] [--set k=v ...]     fine-tune one run
//!              [--probe-port P [--probe-linger S]]
//! addax sweep  [--spec FILE | --smoke] [--budget-gb G] [--gpus N]
//!              [--workers W] [--resume] [--manifest PATH] [--dry-run]
//!              [--no-ckpt] [--ckpt-every N] [--ckpt-keep K]
//!              [--halt-after N] [--dump-params]
//!              [--probe-port P [--probe-linger S]]
//!              [--worker-id ID [--lease-ttl SECS] [--chaos-seed S]]
//! addax fleet-status [--manifest PATH] [--probe-port P] [--watch]
//!                                                   read-only fleet aggregator
//! addax ckpt   inspect|verify FILE...              snapshot header / full CRC pass
//! addax ckpt   diff A B                            compare two snapshots
//! addax repro  <id|all> [--fast] [--model KEY]     regenerate a paper table/figure
//! addax memory --geometry G --method M [-b B] [-l L] [--gpus N] [--device D]
//! addax list                                       models, tasks, experiments
//! ```
//!
//! (CLI is hand-rolled: the offline vendored crate set has no clap.)

use anyhow::{bail, Context, Result};

use addax::ckpt;
use addax::config::Config;
use addax::coordinator::train;
use addax::data;
use addax::jsonlite::Json;
use addax::memory::{self, footprint, geometry, Device, Dtype, Method, Workload};
use addax::obs::fleet::{load_fleet, DEFAULT_FEDERATE_TIMEOUT};
use addax::obs::http::DEFAULT_MEM_WINDOW_SECS;
use addax::obs::{FleetServer, ProbeServer, StatusBoard};
use addax::repro::{self, Harness};
use addax::runtime::manifest::{default_artifacts_dir, Manifest};
use addax::runtime::XlaExec;
use addax::sched::{
    pack, run_sweep, run_sweep_fleet, ChaosPlan, FleetOptions, SweepOptions, SweepSpec,
};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("train") => cmd_train(&args[1..]),
        Some("sweep") => cmd_sweep(&args[1..]),
        Some("fleet-status") => cmd_fleet_status(&args[1..]),
        Some("ckpt") => cmd_ckpt(&args[1..]),
        Some("repro") => cmd_repro(&args[1..]),
        Some("memory") => cmd_memory(&args[1..]),
        Some("list") => cmd_list(),
        Some("help") | None => {
            print_help();
            Ok(())
        }
        Some(other) => {
            print_help();
            bail!("unknown subcommand {other:?}")
        }
    }
}

fn print_help() {
    println!(
        "addax — rust coordinator for the Addax reproduction\n\n\
         USAGE:\n  addax train  [--config FILE] [--set section.key=value ...]\n  \
         \x20            [--probe-port P [--probe-linger S]]\n  \
         addax sweep  [--spec FILE | --smoke] [--budget-gb G] [--gpus N] [--workers W]\n  \
         \x20            [--resume] [--manifest PATH] [--dry-run] [--set section.key=value ...]\n  \
         \x20            [--no-ckpt] [--ckpt-every N] [--ckpt-keep K] [--halt-after N]\n  \
         \x20            [--dump-params] [--probe-port P [--probe-linger S]]\n  \
         \x20            [--worker-id ID [--lease-ttl SECS] [--chaos-seed S]\n  \
         \x20            [--skew-margin-ms MS] [--clock-offset-ms MS] [--rotate-after N]\n  \
         \x20            [--no-steal] [--steal-wait-ms MS]]\n  \
         addax fleet-status [--manifest PATH] [--probe-port P] [--watch]\n  \
         \x20            [--skew-margin-ms MS] [--federate-timeout-ms MS] [--no-federate]\n  \
         addax ckpt   inspect FILE... | verify FILE... | diff A B\n  \
         addax repro  <id|all> [--fast] [--model KEY]\n  \
         addax memory --geometry G --method M [--batch B] [--len L] [--gpus N] [--hbm GB]\n  \
         \x20            [--dtype f32|bf16]\n  \
         addax list\n\nSWEEP:\n  \
         Expands the spec's (optimizer x task x seed x lr x eps x dtype) grid,\n  \
         prices each run with the analytic memory model at its storage dtype,\n  \
         bin-packs runs that co-fit onto the simulated device budget\n  \
         (--budget-gb x --gpus), and executes each wave concurrently (--workers).\n  \
         Results append to a crash-safe JSONL manifest; --resume skips runs\n  \
         already recorded, and the compacted manifest is byte-identical for a\n  \
         spec at any worker count (bf16 cells included). Runs checkpoint into\n  \
         <manifest dir>/ckpt/<run_id>/ (ADDAXCK1 snapshots; --ckpt-every 0 =\n  \
         eval cadence) so a killed run resumes at step granularity — byte-\n  \
         identically. --halt-after N preempts every run after N steps (the\n  \
         deterministic kill used by CI); --dump-params writes each finished\n  \
         run's final parameters for byte-compare proofs. `repro` tables/figures\n  \
         aggregate from the same manifest. --smoke runs the built-in 24-run grid\n  \
         (see configs/sweep_smoke.toml).\n\nFLEET:\n  \
         --worker-id ID makes this process one worker in a multi-process fleet:\n  \
         any number of `addax sweep --worker-id <id> --resume` invocations may\n  \
         share one --manifest. Workers claim runs by appending lease records\n  \
         (run_id + worker + fencing token + expiry) to the sibling\n  \
         manifest.leases.jsonl, heartbeat at TTL/3 (--lease-ttl SECS, default\n  \
         from sweep.lease_ttl_secs), reclaim expired leases and resume the dead\n  \
         worker's run from its step-level snapshots; a zombie's late commit is\n  \
         fenced by token and discarded. Reclaim is skew-tolerant: a lease only\n  \
         looks expired --skew-margin-ms MS (default sweep.skew_margin_ms) past\n  \
         its expiry, and the reclaimer first confirms the holder is logically\n  \
         quiet (no new renewal seq across spaced ledger reloads) — so a live\n  \
         worker on a skewed clock is never reclaimed. When every lease is\n  \
         released and the ledger exceeds --rotate-after N lines (default 512,\n  \
         0 = never), it is rotated to one release line per run, preserving\n  \
         fencing-token monotonicity. Idle workers steal probe-shard work from\n  \
         still-leased mock ZO runs (bit-identical; --no-steal opts out;\n  \
         --steal-wait-ms MS makes holders wait for a thief — CI only).\n  \
         --chaos-seed S deterministically injects worker crashes (exit 96,\n  \
         lease left to expire), heartbeat stalls, transient I/O faults and\n  \
         per-worker clock skew (±TTL; --clock-offset-ms MS pins it) — same\n  \
         seed, same faults, every machine. The compacted manifest stays\n  \
         byte-identical to a single-process sweep's under any kill/reclaim\n  \
         pattern.\n\nPROBE:\n  \
         --probe-port P (or sweep.probe_port; 0 = ephemeral) starts a loopback\n  \
         HTTP status server over this process's runs: GET /runs, \n  \
         GET /runs/<id>/metrics?fields=...&last=N, GET /mem (analytic footprint\n  \
         vs measured RSS + leak detector), POST /runs/<id>/checkpoint|pause|\n  \
         resume|abort. Control verbs ride the existing halt/checkpoint rails at\n  \
         step boundaries, so a probed run stays byte-identical to an unprobed\n  \
         one. --probe-linger S holds the server open after the sweep for a\n  \
         final scrape (CI). GET /metrics serves the Prometheus text exposition;\n  \
         --mem-window-secs S (or sweep.mem_window_secs) sets the /mem leak-\n  \
         detector regression window. See OPERATIONS.md for the endpoint\n  \
         reference.\n\nFLEET-STATUS:\n  \
         Read-only fleet aggregator: reconstructs the whole fleet's state from\n  \
         the side files workers already write (manifest + lease ledger + times\n  \
         telemetry + steal dirs) — per-worker held runs and lease freshness,\n  \
         per-run state-machine position (done/active/expired/released/pending),\n  \
         resume/steal/rotation counters. When lease records advertise probe\n  \
         addresses, it federates live step/loss from each worker's probe\n  \
         server (--federate-timeout-ms MS per probe, --no-federate opts out);\n  \
         unreachable probes degrade to ledger-only. Without --probe-port it\n  \
         prints one JSON snapshot (--watch re-prints every --interval-secs S);\n  \
         with --probe-port P it serves GET /fleet + GET /metrics + GET /healthz\n  \
         for scrapers. Never writes: aggregation cannot perturb a fleet.\n\nCKPT:\n  \
         inspect prints a snapshot's header (identity hash, dtype, step, eval\n  \
         cadence, tensors); verify additionally checks every chunk CRC; diff\n  \
         compares two snapshots (header fields + per-tensor element diffs).\n\n\
         EXPERIMENT IDS:\n  \
         fig3 fig4 fig5 fig6 fig8 fig11 theory table11 table12 table13 table14 table15 all"
    );
}

/// Parse `--flag value` pairs and bare flags from an arg slice.
fn flag<'a>(args: &'a [String], name: &str) -> Option<&'a str> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .map(String::as_str)
}

fn has(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

/// `--probe-port P` (0 = ephemeral), else the config's `sweep.probe_port`.
fn probe_port(args: &[String], from_cfg: Option<u16>) -> Result<Option<u16>> {
    match flag(args, "--probe-port") {
        Some(s) => {
            Ok(Some(s.parse().context("--probe-port wants a port number (0 = ephemeral)")?))
        }
        None => Ok(from_cfg),
    }
}

/// `--probe-linger SECS`: how long to hold the probe server open after
/// the work finishes, so a scraper (CI) can take a final reading.
fn probe_linger_secs(args: &[String]) -> Result<f64> {
    match flag(args, "--probe-linger") {
        Some(s) => s.parse().context("--probe-linger wants seconds"),
        None => Ok(0.0),
    }
}

/// `--mem-window-secs S` (else the config's `sweep.mem_window_secs`):
/// the `/mem` leak-detector regression window.
fn mem_window_secs(args: &[String], from_cfg: f64) -> Result<f64> {
    let w = match flag(args, "--mem-window-secs") {
        Some(s) => s.parse().context("--mem-window-secs wants seconds (a number)")?,
        None => from_cfg,
    };
    if w <= 0.0 {
        bail!("--mem-window-secs {w} must be positive");
    }
    Ok(w)
}

/// Hold the probe server open for `secs`; it Drop-stops when the caller
/// returns. No-op when the plane is off.
fn probe_linger(server: &Option<ProbeServer>, secs: f64) {
    if let Some(srv) = server {
        if secs > 0.0 {
            println!("probe: lingering {secs}s on http://{}", srv.addr());
            std::thread::sleep(std::time::Duration::from_secs_f64(secs));
        }
    }
}

fn cmd_train(args: &[String]) -> Result<()> {
    let mut cfg = match flag(args, "--config") {
        Some(path) => Config::from_file(std::path::Path::new(path))?,
        None => Config::parse("")?,
    };
    let mut i = 0;
    while i < args.len() {
        if args[i] == "--set" {
            let kv = args.get(i + 1).context("--set wants key=value")?;
            cfg.set(kv)?;
            i += 2;
        } else {
            i += 1;
        }
    }

    let model_key = cfg.model_key();
    let task_name = cfg.task_name();
    let task = data::opt_task(&task_name)
        .or_else(|| data::roberta_task(&task_name))
        .with_context(|| format!("unknown task {task_name:?}"))?;

    let mut exec = XlaExec::new(&default_artifacts_dir(), &model_key)?;
    let entry = exec.entry().clone();
    // Bound once: the same values feed both the dataset and the snapshot
    // identity below — two copies could drift and break resume refusal.
    let data_seed = cfg.u64_or("data.seed", 0)?;
    let n_train = cfg.usize_or("data.train", 1000)?;
    let n_val = cfg.usize_or("data.val", 300)?;
    let n_test = cfg.usize_or("data.test", 500)?;
    let ds = data::Dataset::generate(
        task,
        entry.vocab,
        Some(entry.max_len),
        data_seed,
        n_train,
        n_val,
        n_test,
    );
    // The AOT dump is f32; a bf16 store rounds it nearest-even on load.
    let dtype = cfg.dtype()?;
    let mut params = exec.load_initial_params()?.to_dtype(dtype);
    let mut opt = cfg.optimizer()?;
    let mut tc = cfg.train_config()?;
    if tc.ckpt_dir.is_some() {
        // Full-fidelity snapshot identity: the OptSpec id covers every
        // hyper-parameter the named optimizer consumes, so editing lr/eps
        // /batch between kill and restart refuses the stale snapshots.
        // `e{}` is the raw eval_every (0 = steps/20): with steps already
        // in the identity it resolves the cadence deterministically, so
        // a cadence edit refuses (and lets GC evict) stale snapshots.
        tc.ckpt_identity = format!(
            "{}.{}.{}.l{}.ds{}.n{}-{}-{}.s{}.t{}.e{}.x{}.{}",
            model_key,
            cfg.opt_spec()?.id(),
            task.name,
            cfg.lt()?,
            data_seed,
            n_train,
            n_val,
            n_test,
            tc.seed,
            tc.steps,
            tc.eval_every,
            tc.eval_examples,
            dtype.label(),
        );
    }
    // Observability plane (opt-in): a loopback HTTP status server over
    // this one run. Pure telemetry — probes never change trained bytes.
    let cfg_port = match cfg.f32_or("sweep.probe_port", -1.0)? {
        p if p < 0.0 => None,
        p => Some(p as u16),
    };
    let cfg_window = cfg.f32_or("sweep.mem_window_secs", DEFAULT_MEM_WINDOW_SECS as f32)? as f64;
    let linger_secs = probe_linger_secs(args)?;
    let mut probe_server = None;
    if let Some(port) = probe_port(args, cfg_port)? {
        let board = StatusBoard::new();
        let probe = board.register(&format!("train-{model_key}-{}", task.name), tc.steps);
        probe.set_footprint_bytes(params.storage_bytes() as f64);
        tc.probe = Some(probe);
        let srv = ProbeServer::start_with_window(board, port, mem_window_secs(args, cfg_window)?)?;
        println!("probe: listening on http://{}", srv.addr());
        probe_server = Some(srv);
    }
    println!(
        "train: model={model_key} task={} optimizer={} steps={} lt={} dtype={}",
        task.name,
        opt.name(),
        tc.steps,
        if cfg.lt()? == usize::MAX { "inf".to_string() } else { cfg.lt()?.to_string() },
        dtype.label(),
    );
    let r = train(&mut exec, &mut params, &mut *opt, &ds, cfg.lt()?, &tc)?;
    if let Some(step) = r.resumed_from_step {
        println!("(resumed from checkpoint at step {step})");
    }
    if !r.ckpt_note.is_empty() {
        println!("(checkpoint note: {})", r.ckpt_note);
    }
    println!(
        "\nresult: best_val {:.3} @ step {} | test acc {:.3} f1 {:.3} | \
         time-to-best {:.1}s | total {:.1}s (compile {:.1}s excluded from steps)",
        r.best_val_acc,
        r.best_val_step,
        r.test_acc,
        r.test_f1,
        r.time_to_best_secs,
        r.total_secs,
        exec.compile_secs,
    );
    if let Some(out) = flag(args, "--out") {
        std::fs::write(out, r.to_json().dump())?;
        println!("wrote {out}");
    }
    probe_linger(&probe_server, linger_secs);
    Ok(())
}

/// The built-in smoke sweep: a 12-run mock grid small enough for CI but
/// wide enough to exercise packing, concurrency and resume end to end.
/// The embedded text IS `configs/sweep_smoke.toml` — `--smoke` and the
/// CI `--spec` path cannot diverge.
const SMOKE_SPEC: &str = include_str!("../../configs/sweep_smoke.toml");

fn cmd_sweep(args: &[String]) -> Result<()> {
    let text = if has(args, "--smoke") {
        SMOKE_SPEC.to_string()
    } else {
        let path = flag(args, "--spec").context("sweep wants --spec FILE (or --smoke)")?;
        std::fs::read_to_string(path).with_context(|| format!("reading spec {path}"))?
    };
    let mut cfg = Config::parse(&text)?;
    let mut i = 0;
    while i < args.len() {
        if args[i] == "--set" {
            let kv = args.get(i + 1).context("--set wants key=value")?;
            cfg.set(kv)?;
            i += 2;
        } else {
            i += 1;
        }
    }
    let sweep = SweepSpec::from_config(&cfg)?;
    let specs = sweep.expand()?;

    // Observability plane (opt-in): a loopback HTTP status server over
    // this process's runs. Pure telemetry — a probed sweep's compacted
    // manifest is byte-identical to an unprobed one (see rust/src/obs/).
    let linger_secs = probe_linger_secs(args)?;
    let mut probe_server = None;
    let mut board = None;
    if let Some(port) = probe_port(args, sweep.probe_port)? {
        let b = StatusBoard::new();
        let srv = ProbeServer::start_with_window(
            b.clone(),
            port,
            mem_window_secs(args, sweep.mem_window_secs)?,
        )?;
        println!("probe: listening on http://{}", srv.addr());
        probe_server = Some(srv);
        board = Some(b);
    }

    let opts = SweepOptions {
        budget_gb: match flag(args, "--budget-gb") {
            Some(s) => s.parse().context("--budget-gb wants a number")?,
            None => sweep.budget_gb,
        },
        gpus: match flag(args, "--gpus") {
            Some(s) => s.parse().context("--gpus wants an integer")?,
            None => sweep.gpus,
        },
        workers: match flag(args, "--workers") {
            Some(s) => s.parse().context("--workers wants an integer")?,
            None => 4,
        },
        resume: has(args, "--resume"),
        manifest_path: flag(args, "--manifest")
            .unwrap_or("results/sweep/manifest.jsonl")
            .into(),
        verbose: true,
        ckpt: !has(args, "--no-ckpt"),
        ckpt_every: match flag(args, "--ckpt-every") {
            Some(s) => s.parse().context("--ckpt-every wants an integer")?,
            None => 0,
        },
        ckpt_keep: match flag(args, "--ckpt-keep") {
            Some(s) => s.parse().context("--ckpt-keep wants an integer")?,
            None => 2,
        },
        halt_after: match flag(args, "--halt-after") {
            Some(s) => s.parse().context("--halt-after wants an integer")?,
            None => 0,
        },
        dump_params: has(args, "--dump-params"),
        probe: board,
    };
    println!(
        "sweep {:?}: {} runs over {} optimizer(s) x {} task(s) x {} seed(s), \
         budget {:.0} GB x {} device(s), {} worker(s)",
        sweep.name,
        specs.len(),
        sweep.optimizers.len(),
        sweep.tasks.len(),
        sweep.seeds.len(),
        opts.budget_gb,
        opts.gpus,
        opts.workers,
    );
    if has(args, "--dry-run") {
        let waves = pack(specs, opts.budget_gb * 1e9 * opts.gpus as f64)?;
        for (i, w) in waves.iter().enumerate() {
            println!("wave {:>2}: {:>5.1} GB", i + 1, w.bytes / 1e9);
            for r in &w.runs {
                println!("    {:>6.1} GB  {}", r.bytes / 1e9, r.spec.run_id);
            }
        }
        println!("(dry run: nothing executed)");
        return Ok(());
    }
    if let Some(worker_id) = flag(args, "--worker-id") {
        // Fleet mode: this process is one lease-coordinated worker among
        // many sharing the manifest. Lease/chaos knobs only make sense
        // here, so reject them without a worker identity (below).
        let ttl_secs: f64 = match flag(args, "--lease-ttl") {
            Some(s) => s.parse().context("--lease-ttl wants seconds (a number)")?,
            None => sweep.lease_ttl_secs,
        };
        let mut fleet = FleetOptions::new(worker_id, (ttl_secs * 1000.0).round().max(0.0) as u64);
        fleet.chaos = match flag(args, "--chaos-seed") {
            Some(s) => Some(ChaosPlan::new(s.parse().context("--chaos-seed wants a u64")?)),
            None => None,
        };
        fleet.skew_margin_ms = match flag(args, "--skew-margin-ms") {
            Some(s) => s.parse().context("--skew-margin-ms wants milliseconds")?,
            None => sweep.skew_margin_ms,
        };
        if let Some(s) = flag(args, "--clock-offset-ms") {
            // Test/CI knob: pin this worker's lease clock offset instead
            // of deriving one from --chaos-seed.
            fleet.clock_offset_ms = Some(s.parse().context("--clock-offset-ms wants signed ms")?);
        }
        if let Some(s) = flag(args, "--rotate-after") {
            fleet.rotate_after_lines = s.parse().context("--rotate-after wants a line count")?;
        }
        if let Some(s) = flag(args, "--steal-wait-ms") {
            fleet.steal_wait_ms = s.parse().context("--steal-wait-ms wants milliseconds")?;
        }
        fleet.no_steal = has(args, "--no-steal");
        if let Some(srv) = &probe_server {
            // Advertise this worker's probe address in its lease records
            // so a fleet-status aggregator can federate live run state.
            fleet.probe_addr = Some(srv.addr().to_string());
        }
        let exit = run_sweep_fleet(specs, &opts, &fleet)?;
        println!("{}", exit.summary.line());
        if let Some(run_id) = exit.crashed {
            // Exit 96 marks a *planned* chaos kill (lease left to
            // expire), so restart loops can tell it from a real failure.
            println!("chaos-crash: worker {worker_id} killed in {run_id} (exit 96)");
            std::process::exit(96);
        }
        probe_linger(&probe_server, linger_secs);
        return Ok(());
    }
    for f in [
        "--lease-ttl",
        "--chaos-seed",
        "--skew-margin-ms",
        "--clock-offset-ms",
        "--rotate-after",
        "--steal-wait-ms",
    ] {
        if flag(args, f).is_some() {
            bail!("{f} is a fleet flag — pair it with --worker-id <id>");
        }
    }
    if has(args, "--no-steal") {
        bail!("--no-steal is a fleet flag — pair it with --worker-id <id>");
    }
    let summary = run_sweep(specs, &opts)?;
    println!("{}", summary.line());
    if summary.halted > 0 {
        println!(
            "({} run(s) preempted by --halt-after and checkpointed; rerun with \
             --resume to finish them step-level)",
            summary.halted
        );
    }
    probe_linger(&probe_server, linger_secs);
    Ok(())
}

/// `addax fleet-status` — the read-only fleet aggregator. Reconstructs
/// fleet-wide state from the manifest and its side files (lease ledger,
/// times telemetry, steal dirs), optionally federating live run state
/// from the probe addresses advertised in lease records. One JSON
/// snapshot to stdout by default; `--watch` re-prints on an interval;
/// `--probe-port P` serves GET /fleet + /metrics + /healthz instead.
fn cmd_fleet_status(args: &[String]) -> Result<()> {
    let manifest = std::path::PathBuf::from(
        flag(args, "--manifest").unwrap_or("results/sweep/manifest.jsonl"),
    );
    let skew_margin_ms: u64 = match flag(args, "--skew-margin-ms") {
        Some(s) => s.parse().context("--skew-margin-ms wants milliseconds")?,
        None => 250,
    };
    let timeout = match flag(args, "--federate-timeout-ms") {
        Some(s) => std::time::Duration::from_millis(
            s.parse().context("--federate-timeout-ms wants milliseconds")?,
        ),
        None => DEFAULT_FEDERATE_TIMEOUT,
    };
    if let Some(port) = flag(args, "--probe-port") {
        let port: u16 =
            port.parse().context("--probe-port wants a port number (0 = ephemeral)")?;
        let srv = FleetServer::start(manifest, port, skew_margin_ms, timeout)?;
        println!("probe: listening on http://{}", srv.addr());
        // Serve until killed: the aggregator holds no state worth a
        // graceful drain — every request re-reads the ledgers.
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }
    let interval = std::time::Duration::from_secs_f64(match flag(args, "--interval-secs") {
        Some(s) => s.parse().context("--interval-secs wants seconds")?,
        None => 2.0,
    });
    loop {
        let mut view = load_fleet(&manifest, addax::sched::lease::now_ms(), skew_margin_ms)?;
        if !has(args, "--no-federate") {
            view.federate(timeout);
        }
        println!("{}", view.to_json().dump());
        if !has(args, "--watch") {
            return Ok(());
        }
        std::thread::sleep(interval);
    }
}

/// `addax ckpt inspect|verify|diff` — snapshot introspection.
fn cmd_ckpt(args: &[String]) -> Result<()> {
    let paths: Vec<&String> = args.iter().skip(1).filter(|a| !a.starts_with("--")).collect();
    match args.first().map(String::as_str) {
        Some("inspect") | Some("verify") => {
            let full = args[0] == "verify";
            if paths.is_empty() {
                bail!("ckpt {} wants at least one snapshot file", args[0]);
            }
            let mut bad = 0usize;
            for path in &paths {
                let p = std::path::Path::new(path.as_str());
                let res = if full { ckpt::verify(p) } else { ckpt::inspect(p) };
                match res {
                    Ok(info) => {
                        println!(
                            "{path}: OK{}\n  identity {} (hash {})\n  dtype {} | optimizer {} \
                             | step {} | eval_every {} | best {} @ step {}\n  {} tensor(s), \
                             {} chunk(s), {} payload bytes",
                            if full { " (all CRCs verified)" } else { "" },
                            info.identity,
                            info.identity_hash,
                            info.dtype.label(),
                            info.opt_name,
                            info.step,
                            info.eval_every,
                            info.best_val,
                            info.best_step,
                            info.specs.len(),
                            info.chunks.len(),
                            info.total_chunk_bytes(),
                        );
                    }
                    Err(e) => {
                        println!("{path}: BAD — {e:#}");
                        bad += 1;
                    }
                }
            }
            if bad > 0 {
                bail!("{bad} of {} snapshot(s) failed verification", paths.len());
            }
            Ok(())
        }
        Some("diff") => {
            let [a, b] = paths.as_slice() else {
                bail!("ckpt diff wants exactly two snapshot files");
            };
            let report = ckpt::diff_report(
                std::path::Path::new(a.as_str()),
                std::path::Path::new(b.as_str()),
            )?;
            print!("{report}");
            Ok(())
        }
        other => bail!("ckpt wants inspect | verify | diff, got {other:?}"),
    }
}

fn cmd_repro(args: &[String]) -> Result<()> {
    let id = args
        .iter()
        .find(|a| !a.starts_with("--"))
        .context("repro wants an experiment id (or `all`)")?;
    let fast = has(args, "--fast");
    let model = flag(args, "--model").unwrap_or("tiny");
    let mut harness = Harness::new(model, fast);
    repro::run(id, &mut harness)
}

fn cmd_memory(args: &[String]) -> Result<()> {
    let gname = flag(args, "--geometry").unwrap_or("opt-13b");
    let g = geometry::by_name(gname).with_context(|| format!("unknown geometry {gname:?}"))?;
    let method = match flag(args, "--method").unwrap_or("addax") {
        "mezo" => Method::MeZo,
        "zo-sgd" => Method::ZoSgdNaive,
        "sgd" => Method::Sgd,
        "ip-sgd" => Method::IpSgd,
        "adam" => Method::Adam,
        "addax" => Method::Addax,
        "hybrid-zofo" => Method::HybridZoFo,
        m => bail!("unknown method {m:?}"),
    };
    let b: usize = flag(args, "--batch").unwrap_or("8").parse()?;
    let l: usize = flag(args, "--len").unwrap_or("300").parse()?;
    let k0: usize = flag(args, "--k0").unwrap_or("6").parse()?;
    let lt: usize = flag(args, "--lt").unwrap_or(&l.to_string()).parse()?;
    let gpus: usize = flag(args, "--gpus").unwrap_or("1").parse()?;
    let hbm: f64 = flag(args, "--hbm").unwrap_or("40").parse()?;
    // Default to the paper's fp16 storage profile (2 B/param = bf16
    // here); Adam prices fp32 inside `footprint` regardless.
    let dtype = Dtype::parse(flag(args, "--dtype").unwrap_or("bf16"))?;
    let wl = match method {
        Method::MeZo | Method::ZoSgdNaive => Workload::zo(b, l),
        Method::Addax => Workload::mixed(b, lt, k0, l),
        _ => Workload::fo(b, l),
    };
    let f = footprint(&g, method, wl, dtype);
    let dev = Device { name: "custom", capacity_bytes: hbm * 1e9, count: gpus };
    println!(
        "{} / {} ({}) b={b} l={l}: weights {:.1} GB, activations {:.1} GB, \
         logits {:.1} GB, grads {:.1} GB, state {:.1} GB => total {:.1} GB \
         ({} on {}x{:.0}GB)",
        g.name,
        method.label(),
        dtype.label(),
        f.weights / 1e9,
        f.activations / 1e9,
        f.logits / 1e9,
        f.gradients / 1e9,
        f.optimizer_state / 1e9,
        f.gb(),
        if dev.fits(&f) { "FITS" } else { "OOM" },
        gpus,
        hbm,
    );
    // grid search like App. D.6
    if matches!(method, Method::MeZo | Method::Sgd | Method::IpSgd) {
        let max = memory::max_batch_in_grid(&g, method, l, &dev, dtype);
        println!("max grid batch at L={l}: {max:?}");
    }
    Ok(())
}

fn cmd_list() -> Result<()> {
    println!("geometries (memory model):");
    for g in geometry::ALL {
        println!(
            "  {:<14} layers={:<3} d={:<5} V={:<6} params={:.2e}",
            g.name,
            g.n_layers,
            g.d_model,
            g.vocab,
            g.n_params() as f64
        );
    }
    println!("\nOPT tasks:");
    for t in data::OPT_TASKS {
        println!(
            "  {:<8} classes={} L_max={:<4} {}",
            t.name,
            t.n_classes,
            t.lengths.l_max,
            if t.long { "(long)" } else { "" }
        );
    }
    println!("\nRoBERTa tasks:");
    for t in data::ROBERTA_TASKS {
        println!("  {:<8} classes={} L_max={}", t.name, t.n_classes, t.lengths.l_max);
    }
    match Manifest::load(&default_artifacts_dir()) {
        Ok(m) => {
            println!("\nAOT models in {}:", m.dir.display());
            for (k, e) in &m.models {
                let fwd: Vec<usize> = e.buckets(addax::runtime::manifest::ArtifactKind::Forward);
                let grd: Vec<usize> = e.buckets(addax::runtime::manifest::ArtifactKind::Grads);
                println!(
                    "  {:<10} impl={:<6} params={:<9} fwd buckets {:?} grad buckets {:?}",
                    k, e.impl_, e.n_params, fwd, grd
                );
            }
        }
        Err(_) => println!("\n(no artifacts built yet — run `make artifacts`)"),
    }
    let _ = Json::Null; // keep import used even if sections change
    Ok(())
}
