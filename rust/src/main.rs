//! `addax` — the L3 coordinator CLI.
//!
//! ```text
//! addax train  [--config FILE] [--set k=v ...]     fine-tune one run
//! addax repro  <id|all> [--fast] [--model KEY]     regenerate a paper table/figure
//! addax memory --geometry G --method M [-b B] [-l L] [--gpus N] [--device D]
//! addax list                                       models, tasks, experiments
//! ```
//!
//! (CLI is hand-rolled: the offline vendored crate set has no clap.)

use anyhow::{bail, Context, Result};

use addax::config::Config;
use addax::coordinator::train;
use addax::data;
use addax::jsonlite::Json;
use addax::memory::{self, footprint, geometry, Device, Method, Workload};
use addax::repro::{self, Harness};
use addax::runtime::manifest::{default_artifacts_dir, Manifest};
use addax::runtime::XlaExec;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("train") => cmd_train(&args[1..]),
        Some("repro") => cmd_repro(&args[1..]),
        Some("memory") => cmd_memory(&args[1..]),
        Some("list") => cmd_list(),
        Some("help") | None => {
            print_help();
            Ok(())
        }
        Some(other) => {
            print_help();
            bail!("unknown subcommand {other:?}")
        }
    }
}

fn print_help() {
    println!(
        "addax — rust coordinator for the Addax reproduction\n\n\
         USAGE:\n  addax train  [--config FILE] [--set section.key=value ...]\n  \
         addax repro  <id|all> [--fast] [--model KEY]\n  \
         addax memory --geometry G --method M [--batch B] [--len L] [--gpus N] [--hbm GB]\n  \
         addax list\n\nEXPERIMENT IDS:\n  \
         fig3 fig4 fig5 fig6 fig8 fig11 theory table11 table12 table13 table14 table15 all"
    );
}

/// Parse `--flag value` pairs and bare flags from an arg slice.
fn flag<'a>(args: &'a [String], name: &str) -> Option<&'a str> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .map(String::as_str)
}

fn has(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

fn cmd_train(args: &[String]) -> Result<()> {
    let mut cfg = match flag(args, "--config") {
        Some(path) => Config::from_file(std::path::Path::new(path))?,
        None => Config::parse("")?,
    };
    let mut i = 0;
    while i < args.len() {
        if args[i] == "--set" {
            let kv = args.get(i + 1).context("--set wants key=value")?;
            cfg.set(kv)?;
            i += 2;
        } else {
            i += 1;
        }
    }

    let model_key = cfg.model_key();
    let task_name = cfg.task_name();
    let task = data::opt_task(&task_name)
        .or_else(|| data::roberta_task(&task_name))
        .with_context(|| format!("unknown task {task_name:?}"))?;

    let mut exec = XlaExec::new(&default_artifacts_dir(), &model_key)?;
    let entry = exec.entry().clone();
    let ds = data::Dataset::generate(
        task,
        entry.vocab,
        Some(entry.max_len),
        cfg.u64_or("data.seed", 0)?,
        cfg.usize_or("data.train", 1000)?,
        cfg.usize_or("data.val", 300)?,
        cfg.usize_or("data.test", 500)?,
    );
    let mut params = exec.load_initial_params()?;
    let mut opt = cfg.optimizer()?;
    let tc = cfg.train_config()?;
    println!(
        "train: model={model_key} task={} optimizer={} steps={} lt={}",
        task.name,
        opt.name(),
        tc.steps,
        if cfg.lt()? == usize::MAX { "inf".to_string() } else { cfg.lt()?.to_string() }
    );
    let r = train(&mut exec, &mut params, &mut *opt, &ds, cfg.lt()?, &tc)?;
    println!(
        "\nresult: best_val {:.3} @ step {} | test acc {:.3} f1 {:.3} | \
         time-to-best {:.1}s | total {:.1}s (compile {:.1}s excluded from steps)",
        r.best_val_acc,
        r.best_val_step,
        r.test_acc,
        r.test_f1,
        r.time_to_best_secs,
        r.total_secs,
        exec.compile_secs,
    );
    if let Some(out) = flag(args, "--out") {
        std::fs::write(out, r.to_json().dump())?;
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_repro(args: &[String]) -> Result<()> {
    let id = args
        .iter()
        .find(|a| !a.starts_with("--"))
        .context("repro wants an experiment id (or `all`)")?;
    let fast = has(args, "--fast");
    let model = flag(args, "--model").unwrap_or("tiny");
    let mut harness = Harness::new(model, fast);
    repro::run(id, &mut harness)
}

fn cmd_memory(args: &[String]) -> Result<()> {
    let gname = flag(args, "--geometry").unwrap_or("opt-13b");
    let g = geometry::by_name(gname).with_context(|| format!("unknown geometry {gname:?}"))?;
    let method = match flag(args, "--method").unwrap_or("addax") {
        "mezo" => Method::MeZo,
        "zo-sgd" => Method::ZoSgdNaive,
        "sgd" => Method::Sgd,
        "ip-sgd" => Method::IpSgd,
        "adam" => Method::Adam,
        "addax" => Method::Addax,
        "hybrid-zofo" => Method::HybridZoFo,
        m => bail!("unknown method {m:?}"),
    };
    let b: usize = flag(args, "--batch").unwrap_or("8").parse()?;
    let l: usize = flag(args, "--len").unwrap_or("300").parse()?;
    let k0: usize = flag(args, "--k0").unwrap_or("6").parse()?;
    let lt: usize = flag(args, "--lt").unwrap_or(&l.to_string()).parse()?;
    let gpus: usize = flag(args, "--gpus").unwrap_or("1").parse()?;
    let hbm: f64 = flag(args, "--hbm").unwrap_or("40").parse()?;
    let bytes: f64 = if method == Method::Adam { 4.0 } else { 2.0 };
    let wl = match method {
        Method::MeZo | Method::ZoSgdNaive => Workload::zo(b, l),
        Method::Addax => Workload::mixed(b, lt, k0, l),
        _ => Workload::fo(b, l),
    };
    let f = footprint(&g, method, wl, bytes);
    let dev = Device { name: "custom", capacity_bytes: hbm * 1e9, count: gpus };
    println!(
        "{} / {} b={b} l={l}: weights {:.1} GB, activations {:.1} GB, logits \
         {:.1} GB, grads {:.1} GB, state {:.1} GB => total {:.1} GB ({} on \
         {}x{:.0}GB)",
        g.name,
        method.label(),
        f.weights / 1e9,
        f.activations / 1e9,
        f.logits / 1e9,
        f.gradients / 1e9,
        f.optimizer_state / 1e9,
        f.gb(),
        if dev.fits(&f) { "FITS" } else { "OOM" },
        gpus,
        hbm,
    );
    // grid search like App. D.6
    if matches!(method, Method::MeZo | Method::Sgd | Method::IpSgd) {
        let max = memory::max_batch_in_grid(&g, method, l, &dev, bytes);
        println!("max grid batch at L={l}: {max:?}");
    }
    Ok(())
}

fn cmd_list() -> Result<()> {
    println!("geometries (memory model):");
    for g in geometry::ALL {
        println!(
            "  {:<14} layers={:<3} d={:<5} V={:<6} params={:.2e}",
            g.name,
            g.n_layers,
            g.d_model,
            g.vocab,
            g.n_params() as f64
        );
    }
    println!("\nOPT tasks:");
    for t in data::OPT_TASKS {
        println!(
            "  {:<8} classes={} L_max={:<4} {}",
            t.name,
            t.n_classes,
            t.lengths.l_max,
            if t.long { "(long)" } else { "" }
        );
    }
    println!("\nRoBERTa tasks:");
    for t in data::ROBERTA_TASKS {
        println!("  {:<8} classes={} L_max={}", t.name, t.n_classes, t.lengths.l_max);
    }
    match Manifest::load(&default_artifacts_dir()) {
        Ok(m) => {
            println!("\nAOT models in {}:", m.dir.display());
            for (k, e) in &m.models {
                let fwd: Vec<usize> = e.buckets(addax::runtime::manifest::ArtifactKind::Forward);
                let grd: Vec<usize> = e.buckets(addax::runtime::manifest::ArtifactKind::Grads);
                println!(
                    "  {:<10} impl={:<6} params={:<9} fwd buckets {:?} grad buckets {:?}",
                    k, e.impl_, e.n_params, fwd, grd
                );
            }
        }
        Err(_) => println!("\n(no artifacts built yet — run `make artifacts`)"),
    }
    let _ = Json::Null; // keep import used even if sections change
    Ok(())
}
