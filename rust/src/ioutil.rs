//! Small-file I/O hardening shared by the manifest, lease and checkpoint
//! layers: torn-line-tolerant reads, single-syscall line appends, and
//! bounded retry with deterministic jittered exponential backoff.
//!
//! Error taxonomy: *transient* kinds (`Interrupted`, `WouldBlock`,
//! `TimedOut`) are worth retrying — they describe the moment, not the
//! data. Everything else (NotFound, PermissionDenied, corruption
//! surfaced as InvalidData, ...) is *permanent* and fails fast: retrying
//! would at best waste the backoff budget and at worst paper over a bug.
//!
//! Backoff is deterministic: the jitter derives from an FNV hash of the
//! call-site label and the attempt index, never from wall-clock or a
//! thread-local RNG — retried sweeps stay reproducible down to their
//! sleep schedule.
//!
//! The chaos harness (`sched::chaos`) injects transient faults through
//! [`inject_transient_faults`]: the next N [`retry_io`]/[`retry_anyhow`]
//! attempts *on this thread* fail with `Interrupted` before the real
//! operation runs, which exercises every retry path deterministically.

use std::cell::Cell;
use std::io::{self, Write};
use std::path::Path;
use std::time::Duration;

use crate::zorng::{fnv1a, fnv1a_word};

/// Is this error kind worth retrying? (See the module docs' taxonomy.)
pub fn is_transient(kind: io::ErrorKind) -> bool {
    matches!(
        kind,
        io::ErrorKind::Interrupted | io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut
    )
}

thread_local! {
    /// Pending injected transient faults for this thread (chaos hook).
    static INJECTED: Cell<u32> = const { Cell::new(0) };
}

/// Arm `n` injected transient faults: the next `n` retryable operations
/// on this thread fail with `Interrupted` before touching the disk.
/// Thread-local on purpose — each in-process chaos "worker" is a thread,
/// so plans never bleed between workers.
pub fn inject_transient_faults(n: u32) {
    INJECTED.with(|c| c.set(c.get().saturating_add(n)));
}

fn take_injected_fault() -> bool {
    INJECTED.with(|c| {
        let n = c.get();
        if n > 0 {
            c.set(n - 1);
            true
        } else {
            false
        }
    })
}

/// Deterministic jittered exponential backoff for retry attempt
/// `attempt` (1-based) of the operation labelled `label`: the base
/// doubles per attempt (capped at 64×) and is scaled by a jitter factor
/// in [0.5, 1.5) hashed from (label, attempt).
pub fn backoff(label: &str, attempt: u32, base: Duration) -> Duration {
    let doubled = base.saturating_mul(1u32 << attempt.saturating_sub(1).min(6));
    let h = fnv1a_word(fnv1a(label), attempt as u64);
    doubled.mul_f64(0.5 + (h % 1024) as f64 / 1024.0)
}

/// Run `op`, retrying transient failures up to `attempts` times total
/// with [`backoff`] sleeps in between. Permanent errors return
/// immediately; the last transient error is returned when the budget is
/// exhausted.
pub fn retry_io<T>(
    label: &str,
    attempts: u32,
    base: Duration,
    mut op: impl FnMut() -> io::Result<T>,
) -> io::Result<T> {
    let attempts = attempts.max(1);
    let mut last: Option<io::Error> = None;
    for attempt in 1..=attempts {
        if attempt > 1 {
            std::thread::sleep(backoff(label, attempt - 1, base));
        }
        let res = if take_injected_fault() {
            Err(io::Error::new(io::ErrorKind::Interrupted, "injected transient fault"))
        } else {
            op()
        };
        match res {
            Ok(v) => return Ok(v),
            Err(e) if is_transient(e.kind()) => last = Some(e),
            Err(e) => return Err(e),
        }
    }
    Err(last.expect("attempts >= 1 and every attempt records its error"))
}

/// Does any link of this error chain carry a transient [`io::Error`]?
pub fn is_transient_anyhow(e: &anyhow::Error) -> bool {
    e.chain()
        .any(|c| c.downcast_ref::<io::Error>().is_some_and(|io| is_transient(io.kind())))
}

/// [`retry_io`] for `anyhow`-returning operations (e.g. a snapshot
/// write, whose context chain wraps the underlying `io::Error`).
pub fn retry_anyhow<T>(
    label: &str,
    attempts: u32,
    base: Duration,
    mut op: impl FnMut() -> anyhow::Result<T>,
) -> anyhow::Result<T> {
    let attempts = attempts.max(1);
    let mut last: Option<anyhow::Error> = None;
    for attempt in 1..=attempts {
        if attempt > 1 {
            std::thread::sleep(backoff(label, attempt - 1, base));
        }
        let res = if take_injected_fault() {
            Err(io::Error::new(io::ErrorKind::Interrupted, "injected transient fault").into())
        } else {
            op()
        };
        match res {
            Ok(v) => return Ok(v),
            Err(e) if is_transient_anyhow(&e) => last = Some(e),
            Err(e) => return Err(e),
        }
    }
    Err(last.expect("attempts >= 1 and every attempt records its error"))
}

/// Read a line-oriented file as raw bytes and decode each line lossily.
///
/// `read_to_string` would reject the *whole file* when a crash tears a
/// line mid-way through a multi-byte UTF-8 character; here only the torn
/// line decodes to replacement characters (and then fails its JSON
/// parse, exactly like any other torn line), while every intact line
/// survives.
pub fn read_lossy_lines(path: &Path) -> io::Result<Vec<String>> {
    let bytes = std::fs::read(path)?;
    Ok(bytes
        .split(|&b| b == b'\n')
        .map(|line| String::from_utf8_lossy(line).into_owned())
        .collect())
}

/// Append `line` + `\n` to `path` as ONE `write_all` on an `O_APPEND`
/// handle. Two syscalls (payload, then newline) could interleave with a
/// concurrent process's append; a single write of a short line cannot.
pub fn append_line(path: &Path, line: &str) -> io::Result<()> {
    let mut buf = String::with_capacity(line.len() + 1);
    buf.push_str(line);
    buf.push('\n');
    let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
    f.write_all(buf.as_bytes())?;
    f.flush()
}

/// [`append_line`] under the standard retry policy (4 attempts, 2 ms
/// base backoff) — the durable-append primitive every JSONL side file
/// goes through.
pub fn append_line_retry(path: &Path, line: &str, label: &str) -> io::Result<()> {
    retry_io(label, 4, Duration::from_millis(2), || append_line(path, line))
}

/// [`append_line`] + `fdatasync`: the line is on the platter (not just
/// in the page cache) before this returns. `append_line` alone survives
/// a process kill but NOT a power loss — a fencing record that vanishes
/// with the page cache could un-fence a zombie, so lease claims,
/// reclaims, releases and manifest row commits go through this variant.
/// High-frequency heartbeat renewals stay on the unsynced path: losing
/// one costs at most a premature (and confirmed) reclaim, never safety.
pub fn append_line_durable(path: &Path, line: &str) -> io::Result<()> {
    let mut buf = String::with_capacity(line.len() + 1);
    buf.push_str(line);
    buf.push('\n');
    let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
    f.write_all(buf.as_bytes())?;
    f.sync_data()
}

/// [`append_line_durable`] under the standard retry policy.
pub fn append_line_retry_durable(path: &Path, line: &str, label: &str) -> io::Result<()> {
    retry_io(label, 4, Duration::from_millis(2), || append_line_durable(path, line))
}

/// fsync a directory so a just-renamed (or just-created) entry inside it
/// survives power loss. A rename is only durable once its *parent
/// directory* is synced; file-level fsync does not cover the dirent.
/// No-op errors on platforms that refuse directory handles are surfaced
/// to the caller (callers on the rotation path treat them as fatal —
/// an unsynced rotation could resurrect pre-rotation ledger state).
pub fn fsync_dir(dir: &Path) -> io::Result<()> {
    std::fs::File::open(dir)?.sync_all()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transient_errors_are_retried_to_success() {
        let mut calls = 0u32;
        let out = retry_io("t", 4, Duration::ZERO, || {
            calls += 1;
            if calls < 3 {
                Err(io::Error::new(io::ErrorKind::Interrupted, "busy"))
            } else {
                Ok(42)
            }
        })
        .unwrap();
        assert_eq!(out, 42);
        assert_eq!(calls, 3);
    }

    #[test]
    fn permanent_errors_fail_fast() {
        let mut calls = 0u32;
        let err = retry_io::<()>("t", 5, Duration::ZERO, || {
            calls += 1;
            Err(io::Error::new(io::ErrorKind::InvalidData, "corrupt"))
        })
        .unwrap_err();
        assert_eq!(calls, 1, "corruption must not be retried");
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
    }

    #[test]
    fn exhausted_budget_returns_the_last_transient_error() {
        let mut calls = 0u32;
        let err = retry_io::<()>("t", 3, Duration::ZERO, || {
            calls += 1;
            Err(io::Error::new(io::ErrorKind::WouldBlock, "still busy"))
        })
        .unwrap_err();
        assert_eq!(calls, 3);
        assert_eq!(err.kind(), io::ErrorKind::WouldBlock);
    }

    #[test]
    fn injected_faults_are_consumed_then_the_real_op_runs() {
        inject_transient_faults(2);
        let mut calls = 0u32;
        let out = retry_io("t", 4, Duration::ZERO, || {
            calls += 1;
            Ok(7)
        })
        .unwrap();
        assert_eq!(out, 7);
        assert_eq!(calls, 1, "two injected faults, then one real call");
        // fully drained: the next retryable op sees no fault
        let ok = retry_io("t", 1, Duration::ZERO, || Ok(1)).unwrap();
        assert_eq!(ok, 1);
    }

    #[test]
    fn retry_anyhow_distinguishes_transient_chains() {
        let mut calls = 0u32;
        let out: i32 = retry_anyhow("t", 3, Duration::ZERO, || {
            calls += 1;
            if calls == 1 {
                Err(anyhow::Error::from(io::Error::new(
                    io::ErrorKind::Interrupted,
                    "flaky",
                ))
                .context("writing snapshot"))
            } else {
                Ok(9)
            }
        })
        .unwrap();
        assert_eq!(out, 9);
        // a permanent anyhow error is not retried
        let mut calls = 0u32;
        let err = retry_anyhow::<()>("t", 5, Duration::ZERO, || {
            calls += 1;
            anyhow::bail!("logic error")
        })
        .unwrap_err();
        assert_eq!(calls, 1);
        assert!(format!("{err}").contains("logic error"));
    }

    #[test]
    fn backoff_is_deterministic_jittered_and_grows() {
        let base = Duration::from_millis(2);
        let a1 = backoff("site", 1, base);
        assert_eq!(a1, backoff("site", 1, base), "same label+attempt, same sleep");
        assert_ne!(a1, backoff("other", 1, base), "label feeds the jitter");
        // doubling dominates the [0.5, 1.5) jitter by attempt + 2
        assert!(backoff("site", 3, base) > a1);
        // jitter stays in [0.5, 1.5) x doubled
        for attempt in 1..=6 {
            let d = backoff("site", attempt, base);
            let doubled = base * (1 << (attempt - 1).min(6));
            assert!(d >= doubled.mul_f64(0.5) && d < doubled.mul_f64(1.5));
        }
    }

    #[test]
    fn lossy_lines_survive_a_torn_multibyte_character() {
        let dir = std::env::temp_dir().join(format!("addax_ioutil_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("torn.jsonl");
        // valid line, then a line torn mid-way through a 2-byte char
        let mut bytes = b"{\"ok\":1}\n{\"name\":\"caf".to_vec();
        bytes.push(0xC3); // first byte of U+00E9, second byte lost to the kill
        std::fs::write(&path, &bytes).unwrap();
        assert!(std::fs::read_to_string(&path).is_err(), "the premise: strict read fails");
        let lines = read_lossy_lines(&path).unwrap();
        assert_eq!(lines[0], "{\"ok\":1}");
        assert!(lines[1].contains('\u{FFFD}'), "torn tail decodes lossily: {:?}", lines[1]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn durable_append_roundtrips_and_syncs_its_directory() {
        let dir = std::env::temp_dir().join(format!("addax_ioutil_d_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("durable.jsonl");
        std::fs::remove_file(&path).ok();
        append_line_retry_durable(&path, "{\"claim\":1}", "lease append").unwrap();
        append_line_retry_durable(&path, "{\"claim\":2}", "lease append").unwrap();
        let lines = read_lossy_lines(&path).unwrap();
        assert_eq!(&lines[..2], &["{\"claim\":1}".to_string(), "{\"claim\":2}".to_string()]);
        fsync_dir(&dir).unwrap();
        assert!(fsync_dir(&dir.join("missing")).is_err(), "missing dirs surface errors");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn append_line_is_one_write_and_roundtrips() {
        let dir = std::env::temp_dir().join(format!("addax_ioutil_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("append.jsonl");
        std::fs::remove_file(&path).ok();
        append_line_retry(&path, "{\"a\":1}", "test append").unwrap();
        append_line_retry(&path, "{\"b\":2}", "test append").unwrap();
        let lines = read_lossy_lines(&path).unwrap();
        assert_eq!(&lines[..2], &["{\"a\":1}".to_string(), "{\"b\":2}".to_string()]);
        std::fs::remove_file(&path).ok();
    }
}
