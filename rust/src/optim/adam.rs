//! Adam baseline (Kingma & Ba) with bias correction.
//!
//! Keeps first/second-moment state for every parameter — the O(2d)
//! optimizer-state memory the paper's Figure 1 charges Adam for (the
//! memory model additionally accounts its fp32 weights + full gradient).

use anyhow::{bail, Result};

use crate::memory::Method;
use crate::params::ParamStore;
use crate::runtime::ModelExec;

use super::{grad_global_norm, BatchNeeds, Optimizer, StepBatches, StepStats};

#[derive(Clone, Debug)]
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub batch: usize,
    t: u64,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl Adam {
    pub fn new(lr: f32, batch: usize) -> Self {
        Self {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            batch,
            t: 0,
            m: Vec::new(),
            v: Vec::new(),
        }
    }

    pub fn defaults() -> Self {
        Self::new(1e-5, 8)
    }

    fn ensure_state(&mut self, params: &ParamStore) {
        if self.m.is_empty() {
            self.m = params.tensors().map(|t| vec![0.0; t.len()]).collect();
            self.v = params.tensors().map(|t| vec![0.0; t.len()]).collect();
        }
    }

    /// Bytes of optimizer state currently held (telemetry/memory model).
    pub fn state_bytes(&self) -> usize {
        (self.m.iter().map(Vec::len).sum::<usize>()
            + self.v.iter().map(Vec::len).sum::<usize>())
            * 4
    }
}

impl Optimizer for Adam {
    fn name(&self) -> &'static str {
        "adam"
    }

    fn needs(&self) -> BatchNeeds {
        BatchNeeds { fo: self.batch, zo: 0 }
    }

    fn step(
        &mut self,
        params: &mut ParamStore,
        exec: &mut dyn ModelExec,
        batches: &StepBatches,
        _step_seed: u64,
    ) -> Result<StepStats> {
        let Some(fo_batch) = &batches.fo else { bail!("adam needs a FO batch") };
        let g = exec.grads(params, fo_batch)?;
        self.ensure_state(params);
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        let norm = grad_global_norm(&g.grads);
        let (beta1, beta2, lr, eps) = (self.beta1, self.beta2, self.lr, self.eps);
        for (idx, grad) in g.grads.iter().enumerate() {
            let m = &mut self.m[idx];
            let v = &mut self.v[idx];
            // The moments stay fp32 whatever the store's dtype — the
            // 2·d·4-byte state the memory model charges Adam for; only
            // the weight write re-encodes at storage precision.
            params.get_mut(idx).tensor.map_inplace(|i, w| {
                m[i] = beta1 * m[i] + (1.0 - beta1) * grad[i];
                v[i] = beta2 * v[i] + (1.0 - beta2) * grad[i] * grad[i];
                let mhat = m[i] / b1t;
                let vhat = v[i] / b2t;
                w - lr * mhat / (vhat.sqrt() + eps)
            });
        }
        Ok(StepStats {
            loss: g.loss as f64,
            g0: 0.0,
            grad_norm: norm,
            fwd_evals: 0,
            bwd_evals: 1,
        })
    }

    fn method(&self) -> Method {
        Method::Adam
    }

    fn lr(&self) -> f64 {
        self.lr as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::run_optimizer;

    #[test]
    fn adam_converges_on_quadratic() {
        let mut opt = Adam::new(0.05, 4);
        let sub = run_optimizer(&mut opt, 16, 0.02, 600);
        assert!(sub < 0.05, "suboptimality {sub}");
    }

    #[test]
    fn state_bytes_counts_two_moments() {
        use crate::optim::testutil::{quad, random_batch, store};
        use crate::zorng::Xoshiro256;
        let mut opt = Adam::new(0.01, 2);
        let mut exec = quad(10, 0.0);
        let mut p = store(10);
        let mut rng = Xoshiro256::new(1);
        let b = random_batch(2, &mut rng);
        assert_eq!(opt.state_bytes(), 0);
        opt.step(&mut p, &mut exec, &StepBatches { fo: Some(b), zo: None }, 0)
            .unwrap();
        assert_eq!(opt.state_bytes(), 2 * 10 * 4);
    }
}
