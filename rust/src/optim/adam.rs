//! Adam baseline (Kingma & Ba) with bias correction.
//!
//! Keeps first/second-moment state for every parameter — the O(2d)
//! optimizer-state memory the paper's Figure 1 charges Adam for (the
//! memory model additionally accounts its fp32 weights + full gradient).

use anyhow::{bail, Result};

use crate::memory::Method;
use crate::params::ParamStore;
use crate::runtime::ModelExec;

use super::{fmt_f32, grad_global_norm, BatchNeeds, OptState, Optimizer, StepBatches, StepStats};

#[derive(Clone, Debug)]
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub batch: usize,
    t: u64,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl Adam {
    pub fn new(lr: f32, batch: usize) -> Self {
        Self {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            batch,
            t: 0,
            m: Vec::new(),
            v: Vec::new(),
        }
    }

    pub fn defaults() -> Self {
        Self::new(1e-5, 8)
    }

    fn ensure_state(&mut self, params: &ParamStore) {
        if self.m.is_empty() {
            self.m = params.tensors().map(|t| vec![0.0; t.len()]).collect();
            self.v = params.tensors().map(|t| vec![0.0; t.len()]).collect();
        }
    }

    /// Bytes of optimizer state currently held (telemetry/memory model).
    pub fn state_bytes(&self) -> usize {
        (self.m.iter().map(Vec::len).sum::<usize>()
            + self.v.iter().map(Vec::len).sum::<usize>())
            * 4
    }
}

impl Optimizer for Adam {
    fn name(&self) -> &'static str {
        "adam"
    }

    fn needs(&self) -> BatchNeeds {
        BatchNeeds { fo: self.batch, zo: 0 }
    }

    fn step(
        &mut self,
        params: &mut ParamStore,
        exec: &mut dyn ModelExec,
        batches: &StepBatches,
        _step_seed: u64,
    ) -> Result<StepStats> {
        let Some(fo_batch) = &batches.fo else { bail!("adam needs a FO batch") };
        let g = exec.grads(params, fo_batch)?;
        self.ensure_state(params);
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        let norm = grad_global_norm(&g.grads);
        let (beta1, beta2, lr, eps) = (self.beta1, self.beta2, self.lr, self.eps);
        for (idx, grad) in g.grads.iter().enumerate() {
            let m = &mut self.m[idx];
            let v = &mut self.v[idx];
            // The moments stay fp32 whatever the store's dtype — the
            // 2·d·4-byte state the memory model charges Adam for; only
            // the weight write re-encodes at storage precision.
            params.get_mut(idx).tensor.map_inplace(|i, w| {
                m[i] = beta1 * m[i] + (1.0 - beta1) * grad[i];
                v[i] = beta2 * v[i] + (1.0 - beta2) * grad[i] * grad[i];
                let mhat = m[i] / b1t;
                let vhat = v[i] / b2t;
                w - lr * mhat / (vhat.sqrt() + eps)
            });
        }
        Ok(StepStats {
            loss: g.loss as f64,
            zo_loss: 0.0,
            g0: 0.0,
            grad_norm: norm,
            fwd_evals: 0,
            bwd_evals: 1,
        })
    }

    fn method(&self) -> Method {
        Method::Adam
    }

    fn lr(&self) -> f64 {
        self.lr as f64
    }

    fn ckpt_id(&self) -> String {
        format!(
            "adam~lr{}~b{}~b1{}~b2{}~e{}",
            fmt_f32(self.lr),
            self.batch,
            fmt_f32(self.beta1),
            fmt_f32(self.beta2),
            fmt_f32(self.eps)
        )
    }

    /// Checkpoint seam: `t` plus the moments, fixed order `m0..mN, v0..vN`
    /// (fp32 — exactly the in-memory representation, so a save/load
    /// round-trip is bit-exact and a resumed trajectory cannot drift).
    fn state(&self) -> OptState {
        let mut tensors = Vec::with_capacity(self.m.len() + self.v.len());
        for (i, m) in self.m.iter().enumerate() {
            tensors.push((format!("m{i}"), m.clone()));
        }
        for (i, v) in self.v.iter().enumerate() {
            tensors.push((format!("v{i}"), v.clone()));
        }
        OptState { t: self.t, tensors }
    }

    fn load_state(&mut self, state: &OptState) -> Result<()> {
        if state.is_empty() {
            // A pre-first-step snapshot: back to lazy initialization.
            self.t = 0;
            self.m.clear();
            self.v.clear();
            return Ok(());
        }
        let n = state.tensors.len();
        if n == 0 {
            // t > 0 with no moments (is_empty already handled t == 0):
            // accepting it would lazily re-zero m/v while the bias
            // correction continues from t — a silently wrong trajectory.
            bail!("adam state carries t={} but no moment tensors", state.t);
        }
        if state.t == 0 {
            bail!("adam state carries {n} moment tensor(s) but t=0");
        }
        if n % 2 != 0 {
            bail!("adam state wants paired m/v tensors, got {n}");
        }
        let (ms, vs) = state.tensors.split_at(n / 2);
        for (i, (name, _)) in ms.iter().enumerate() {
            if name != &format!("m{i}") {
                bail!("adam state tensor {i} is {name:?}, expected m{i}");
            }
        }
        for (i, (name, _)) in vs.iter().enumerate() {
            if name != &format!("v{i}") {
                bail!("adam state tensor {} is {name:?}, expected v{i}", i + n / 2);
            }
        }
        self.m = ms.iter().map(|(_, v)| v.clone()).collect();
        self.v = vs.iter().map(|(_, v)| v.clone()).collect();
        self.t = state.t;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::run_optimizer;

    #[test]
    fn adam_converges_on_quadratic() {
        let mut opt = Adam::new(0.05, 4);
        let sub = run_optimizer(&mut opt, 16, 0.02, 600);
        assert!(sub < 0.05, "suboptimality {sub}");
    }

    #[test]
    fn state_roundtrip_resumes_bit_identically() {
        use crate::optim::testutil::{quad, random_batch, store};
        use crate::zorng::Xoshiro256;
        let mut exec = quad(10, 0.05);
        let mut rng = Xoshiro256::new(4);
        let batches: Vec<_> = (0..6)
            .map(|_| StepBatches { fo: Some(random_batch(2, &mut rng)), zo: None })
            .collect();
        // Reference: 6 uninterrupted steps.
        let mut opt_a = Adam::new(0.05, 2);
        let mut p_a = store(10);
        for (s, b) in batches.iter().enumerate() {
            opt_a.step(&mut p_a, &mut exec, b, s as u64).unwrap();
        }
        // Checkpointed: 3 steps, state() -> fresh Adam -> load_state -> 3 more.
        let mut opt_b = Adam::new(0.05, 2);
        let mut p_b = store(10);
        for (s, b) in batches.iter().take(3).enumerate() {
            opt_b.step(&mut p_b, &mut exec, b, s as u64).unwrap();
        }
        let saved = opt_b.state();
        assert_eq!(saved.t, 3);
        assert_eq!(saved.tensors.len(), 4, "m0,m1,v0,v1");
        let mut opt_c = Adam::new(0.05, 2);
        opt_c.load_state(&saved).unwrap();
        for (s, b) in batches.iter().enumerate().skip(3) {
            opt_c.step(&mut p_b, &mut exec, b, s as u64).unwrap();
        }
        assert_eq!(p_a.dist_sq(&p_b), 0.0, "resumed Adam must replay bit-identically");
        assert_eq!(opt_c.state(), opt_a.state());
        // malformed states fail loudly
        let mut bad = saved.clone();
        bad.tensors.pop();
        assert!(opt_c.load_state(&bad).is_err());
        let bad = OptState { t: 5, tensors: vec![] };
        assert!(opt_c.load_state(&bad).is_err(), "t without moments must be refused");
        let bad = OptState { t: 0, tensors: saved.tensors.clone() };
        assert!(opt_c.load_state(&bad).is_err(), "moments without t must be refused");
        let mut bad = saved.clone();
        bad.tensors[0].0 = "x0".into();
        assert!(opt_c.load_state(&bad).is_err());
        // empty state resets to lazy init
        opt_c.load_state(&OptState::default()).unwrap();
        assert_eq!(opt_c.state_bytes(), 0);
    }

    #[test]
    fn state_bytes_counts_two_moments() {
        use crate::optim::testutil::{quad, random_batch, store};
        use crate::zorng::Xoshiro256;
        let mut opt = Adam::new(0.01, 2);
        let mut exec = quad(10, 0.0);
        let mut p = store(10);
        let mut rng = Xoshiro256::new(1);
        let b = random_batch(2, &mut rng);
        assert_eq!(opt.state_bytes(), 0);
        opt.step(&mut p, &mut exec, &StepBatches { fo: Some(b), zo: None }, 0)
            .unwrap();
        assert_eq!(opt.state_bytes(), 2 * 10 * 4);
    }
}
