//! Addax (Algorithm 1): the paper's optimizer.
//!
//! Per step:
//!   1. SPSA on the zeroth-order batch `B⁰` (drawn from the long-sequence
//!      partition `D⁰`) → directional derivative `g⁰` (Alg. 2, seed s).
//!   2. First-order gradients on `B¹` (short partition `D¹`), applied in
//!      place tensor-by-tensor with weight `(1−α)` (Alg. 1 lines 9-12).
//!   3. ZO update `θ ← θ − ηα·g⁰·z` with `z` replayed from s
//!      (Alg. 1 lines 13-17).
//!
//! Addax-WA ("without assignment") is the same optimizer; the coordinator
//! simply samples both batches from the whole dataset (`L_T ≥ L_max`).

use anyhow::{bail, Result};

use crate::memory::Method;
use crate::params::ParamStore;
use crate::runtime::ModelExec;

use super::{grad_global_norm, spsa_g0, BatchNeeds, Optimizer, StepBatches, StepStats};

/// Hyper-parameters follow Table 7: `(K¹, K⁰) = (4, 6)`, `η = 1e-4`,
/// `ε = 1e-3`, `α` tuned per task from a small grid.
#[derive(Clone, Debug)]
pub struct Addax {
    pub lr: f32,
    pub eps: f32,
    pub alpha: f32,
    /// `K⁰`: zeroth-order batch size.
    pub k0: usize,
    /// `K¹`: first-order batch size.
    pub k1: usize,
}

impl Addax {
    pub fn new(lr: f32, eps: f32, alpha: f32, k0: usize, k1: usize) -> Self {
        assert!((0.0..=1.0).contains(&alpha), "α must be in [0,1]");
        Self { lr, eps, alpha, k0, k1 }
    }

    /// Paper defaults (OPT experiments, Table 7).
    pub fn defaults() -> Self {
        Self::new(1e-4, 1e-3, 5e-4, 6, 4)
    }

    /// The theoretically optimal mixing weight `α* = K⁰/(K⁰ + d·K¹)`
    /// (Theorem 3.1).
    pub fn optimal_alpha(k0: usize, k1: usize, d: usize) -> f32 {
        k0 as f32 / (k0 as f32 + (d as f32) * k1 as f32)
    }
}

impl Optimizer for Addax {
    fn name(&self) -> &'static str {
        "addax"
    }

    fn needs(&self) -> BatchNeeds {
        BatchNeeds { fo: self.k1, zo: self.k0 }
    }

    fn step(
        &mut self,
        params: &mut ParamStore,
        exec: &mut dyn ModelExec,
        batches: &StepBatches,
        step_seed: u64,
    ) -> Result<StepStats> {
        let Some(zo_batch) = &batches.zo else { bail!("addax needs a ZO batch") };
        let Some(fo_batch) = &batches.fo else { bail!("addax needs a FO batch") };

        // (1) zeroth-order probe — two forward passes, O(1) extra memory.
        let (g0, zo_loss) = spsa_g0(params, exec, zo_batch, self.eps, step_seed)?;

        // (2) first-order half-step, in place per tensor (grad dropped
        // immediately after use — the IP discipline of App. B).
        let g = exec.grads(params, fo_batch)?;
        let grad_norm = grad_global_norm(&g.grads);
        for (idx, grad) in g.grads.iter().enumerate() {
            params.fo_update_tensor(idx, self.lr, 1.0 - self.alpha, grad);
        }

        // (3) zeroth-order half-step via seed replay.
        params.zo_update(step_seed, self.lr, self.alpha, g0 as f32);

        let _ = zo_loss;
        Ok(StepStats {
            loss: g.loss as f64,
            g0,
            grad_norm,
            fwd_evals: 2,
            bwd_evals: 1,
        })
    }

    fn method(&self) -> Method {
        Method::Addax
    }

    fn lr(&self) -> f64 {
        self.lr as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::run_optimizer;

    #[test]
    fn converges_on_quadratic() {
        let mut opt = Addax::new(0.05, 1e-3, 0.3, 6, 4);
        let sub = run_optimizer(&mut opt, 32, 0.05, 400);
        assert!(sub < 0.05, "suboptimality {sub}");
    }

    #[test]
    fn alpha_zero_reduces_to_ip_sgd_like_convergence() {
        // With α = 0 the ZO update is a no-op scaling; convergence should
        // match plain SGD closely.
        let mut opt = Addax::new(0.1, 1e-3, 0.0, 2, 4);
        let sub = run_optimizer(&mut opt, 16, 0.0, 200);
        assert!(sub < 1e-4, "suboptimality {sub}");
    }

    #[test]
    fn alpha_one_is_pure_zo_and_still_descends() {
        let mut opt = Addax::new(0.02, 1e-3, 1.0, 8, 1);
        let sub = run_optimizer(&mut opt, 8, 0.0, 800);
        // ZO-only is slower (d-dependent) but must make clear progress
        // from the initial suboptimality (≈ several units).
        assert!(sub < 1.0, "suboptimality {sub}");
    }

    #[test]
    fn optimal_alpha_formula() {
        let a = Addax::optimal_alpha(6, 4, 1000);
        assert!((a - 6.0 / 4006.0).abs() < 1e-9);
        assert!(a < 0.01); // large d => tiny alpha, as the paper notes
    }

    #[test]
    #[should_panic]
    fn rejects_bad_alpha() {
        Addax::new(0.1, 1e-3, 1.5, 1, 1);
    }
}
