//! Addax (Algorithm 1): the paper's optimizer.
//!
//! Per step (sweep fusion v2 — same math as Alg. 1, fewest O(d) passes):
//!   1. First-order gradients on `B¹` (short partition `D¹`) at θ
//!      (Alg. 1 lines 9-12; applied last, updates commute additively).
//!   2. SPSA probe on the zeroth-order batch `B⁰` (long partition `D⁰`)
//!      → directional derivative `g⁰` (Alg. 2, seed s). On a substrate
//!      with a fused probe path the params never leave θ; otherwise the
//!      materialized probes leave `θ − εz` (the [`ProbeEnd`] contract).
//!   3. One combined update sweep: ZO half-step `−ηα·g⁰·z` and FO
//!      half-step `−η(1−α)·g` applied together (Alg. 1 lines 13-17),
//!      folding in the SPSA restore when the probe ended at `θ − εz`.
//!      A fused-substrate step thus costs 2 noise sweeps (probe replay +
//!      combined update); the legacy path costs 3 — both down from the
//!      original 4-sweep schedule.
//!
//! Addax-WA ("without assignment") is the same optimizer; the coordinator
//! simply samples both batches from the whole dataset (`L_T ≥ L_max`).

use anyhow::{bail, Result};

use crate::memory::Method;
use crate::params::ParamStore;
use crate::runtime::ModelExec;

use super::{
    fmt_f32, grad_global_norm, spsa_probe, BatchNeeds, Optimizer, ProbeEnd, StepBatches, StepStats,
};

/// Hyper-parameters follow Table 7: `(K¹, K⁰) = (4, 6)`, `η = 1e-4`,
/// `ε = 1e-3`, `α` tuned per task from a small grid.
#[derive(Clone, Debug)]
pub struct Addax {
    pub lr: f32,
    pub eps: f32,
    pub alpha: f32,
    /// `K⁰`: zeroth-order batch size.
    pub k0: usize,
    /// `K¹`: first-order batch size.
    pub k1: usize,
}

impl Addax {
    pub fn new(lr: f32, eps: f32, alpha: f32, k0: usize, k1: usize) -> Self {
        assert!((0.0..=1.0).contains(&alpha), "α must be in [0,1]");
        Self { lr, eps, alpha, k0, k1 }
    }

    /// Paper defaults (OPT experiments, Table 7).
    pub fn defaults() -> Self {
        Self::new(1e-4, 1e-3, 5e-4, 6, 4)
    }

    /// The theoretically optimal mixing weight `α* = K⁰/(K⁰ + d·K¹)`
    /// (Theorem 3.1).
    pub fn optimal_alpha(k0: usize, k1: usize, d: usize) -> f32 {
        k0 as f32 / (k0 as f32 + (d as f32) * k1 as f32)
    }
}

impl Optimizer for Addax {
    fn name(&self) -> &'static str {
        "addax"
    }

    fn needs(&self) -> BatchNeeds {
        BatchNeeds { fo: self.k1, zo: self.k0 }
    }

    fn step(
        &mut self,
        params: &mut ParamStore,
        exec: &mut dyn ModelExec,
        batches: &StepBatches,
        step_seed: u64,
    ) -> Result<StepStats> {
        let Some(zo_batch) = &batches.zo else { bail!("addax needs a ZO batch") };
        let Some(fo_batch) = &batches.fo else { bail!("addax needs a FO batch") };

        // (1) first-order gradients at θ, before any perturbation; the
        // in-place application is deferred past the ZO sweeps (additive
        // updates commute, so the math of Alg. 1 is unchanged). Note the
        // gradient list stays resident through the ZO probes — a deliberate
        // trade for the fused 3-sweep schedule. The `ModelExec` seam
        // materializes the full list either way, so this substrate's peak
        // is unchanged; the analytic GPU model in `memory.rs` describes
        // the paper's streaming-backward system, where Addax would instead
        // run the probes first and forgo the fusion.
        let g = exec.grads(params, fo_batch)?;
        let grad_norm = grad_global_norm(&g.grads);

        // (2) zeroth-order probe — two forward passes, O(1) extra memory.
        let (g0, zo_loss, end) = spsa_probe(params, exec, zo_batch, self.eps, step_seed)?;

        // (3) one combined sweep applies the ZO half-step −ηα·g⁰·z and
        // the FO half-step −η(1−α)·g together, folding in the SPSA
        // restore when the probe left θ − εz.
        match end {
            ProbeEnd::AtTheta => {
                params.zo_fo_update(step_seed, self.lr, self.alpha, g0 as f32, &g.grads);
            }
            ProbeEnd::AtThetaMinusEps => {
                params.restore_zo_fo_update(
                    step_seed,
                    self.eps,
                    self.lr,
                    self.alpha,
                    g0 as f32,
                    &g.grads,
                );
            }
        }

        Ok(StepStats {
            loss: g.loss as f64,
            // The ZO-batch loss (mean of the two probe losses) — Addax's
            // view of the long-sequence partition D⁰, reported alongside
            // the FO loss so both halves of Alg. 1 are observable per step.
            zo_loss,
            g0,
            grad_norm,
            fwd_evals: 2,
            bwd_evals: 1,
        })
    }

    fn method(&self) -> Method {
        Method::Addax
    }

    fn lr(&self) -> f64 {
        self.lr as f64
    }

    fn ckpt_id(&self) -> String {
        format!(
            "addax~lr{}~e{}~a{}~k{}-{}",
            fmt_f32(self.lr),
            fmt_f32(self.eps),
            fmt_f32(self.alpha),
            self.k0,
            self.k1
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::run_optimizer;

    #[test]
    fn converges_on_quadratic() {
        let mut opt = Addax::new(0.05, 1e-3, 0.3, 6, 4);
        let sub = run_optimizer(&mut opt, 32, 0.05, 400);
        assert!(sub < 0.05, "suboptimality {sub}");
    }

    #[test]
    fn alpha_zero_reduces_to_ip_sgd_like_convergence() {
        // With α = 0 the ZO update is a no-op scaling; convergence should
        // match plain SGD closely.
        let mut opt = Addax::new(0.1, 1e-3, 0.0, 2, 4);
        let sub = run_optimizer(&mut opt, 16, 0.0, 200);
        assert!(sub < 1e-4, "suboptimality {sub}");
    }

    #[test]
    fn alpha_one_is_pure_zo_and_still_descends() {
        let mut opt = Addax::new(0.02, 1e-3, 1.0, 8, 1);
        let sub = run_optimizer(&mut opt, 8, 0.0, 800);
        // ZO-only is slower (d-dependent) but must make clear progress
        // from the initial suboptimality (≈ several units).
        assert!(sub < 1.0, "suboptimality {sub}");
    }

    #[test]
    fn step_uses_two_noise_sweeps_on_a_fused_substrate() {
        // Sweep fusion v2: the substrate's fused probe replays z once
        // without perturbing the store, and the combined ZO+FO update is
        // one more pass — 2 O(d) sweeps per step, down from 3 (legacy
        // fused restore+update) and the original 4 (+ε, −2ε, +ε, update).
        use crate::optim::testutil::{quad, random_batch, store};
        use crate::optim::StepBatches;
        use crate::zorng::Xoshiro256;
        let mut opt = Addax::new(0.05, 1e-3, 0.3, 2, 2);
        let mut exec = quad(16, 0.0);
        let mut p = store(16);
        p.perturb(1, 1.0);
        let mut rng = Xoshiro256::new(3);
        let before = p.noise_sweeps();
        let batches = StepBatches {
            fo: Some(random_batch(2, &mut rng)),
            zo: Some(random_batch(2, &mut rng)),
        };
        opt.step(&mut p, &mut exec, &batches, 11).unwrap();
        assert_eq!(p.noise_sweeps() - before, 2);
    }

    #[test]
    fn step_surfaces_the_zo_batch_loss() {
        // The probe loss must reach StepStats (it was previously dropped):
        // on the quadratic with params away from the optimum it is a
        // strictly positive mean of the two probe losses, distinct from
        // the FO-batch loss field.
        use crate::optim::testutil::{quad, random_batch, store};
        use crate::optim::StepBatches;
        use crate::zorng::Xoshiro256;
        let mut opt = Addax::new(0.01, 1e-3, 0.3, 2, 2);
        let mut exec = quad(16, 0.0);
        let mut p = store(16);
        p.perturb(2, 1.0);
        let mut rng = Xoshiro256::new(8);
        let batches = StepBatches {
            fo: Some(random_batch(2, &mut rng)),
            zo: Some(random_batch(2, &mut rng)),
        };
        let stats = opt.step(&mut p, &mut exec, &batches, 5).unwrap();
        assert!(stats.zo_loss.is_finite() && stats.zo_loss > 0.0, "{}", stats.zo_loss);
        assert!(stats.loss.is_finite());
    }

    #[test]
    fn optimal_alpha_formula() {
        let a = Addax::optimal_alpha(6, 4, 1000);
        assert!((a - 6.0 / 4006.0).abs() < 1e-9);
        assert!(a < 0.01); // large d => tiny alpha, as the paper notes
    }

    #[test]
    #[should_panic]
    fn rejects_bad_alpha() {
        Addax::new(0.1, 1e-3, 1.5, 1, 1);
    }
}
