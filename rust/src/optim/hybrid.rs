//! Layer-split hybrid ZO-FO baseline (Zhang et al. [69], discussed in §3.1
//! and App. C of the Addax paper).
//!
//! Backpropagation is restricted to the *deep* layers (the last
//! `1 − split_frac` fraction of parameter tensors); the shallow layers are
//! updated with zeroth-order SPSA estimates whose perturbation touches
//! only those shallow tensors. Unlike Addax it cannot exploit in-place
//! updates for the FO part (its memory model charges deep-layer gradient
//! residency) and both halves see the *same* batch — there is no
//! length-based data assignment.

use anyhow::{bail, Result};

use crate::memory::Method;
use crate::params::ParamStore;
use crate::runtime::ModelExec;

use super::{fmt_f32, grad_global_norm, BatchNeeds, Optimizer, StepBatches, StepStats};

#[derive(Clone, Debug)]
pub struct HybridZoFo {
    pub lr_fo: f32,
    pub lr_zo: f32,
    pub eps: f32,
    pub batch: usize,
    /// Fraction of tensors (from the front / shallow side) that use ZO.
    pub split_frac: f32,
}

impl HybridZoFo {
    pub fn new(lr_fo: f32, lr_zo: f32, eps: f32, batch: usize, split_frac: f32) -> Self {
        assert!((0.0..=1.0).contains(&split_frac));
        Self { lr_fo, lr_zo, eps, batch, split_frac }
    }

    pub fn defaults() -> Self {
        Self::new(1e-4, 1e-6, 1e-3, 4, 0.5)
    }

    fn split_index(&self, n_tensors: usize) -> usize {
        ((n_tensors as f32) * self.split_frac).round() as usize
    }
}

impl Optimizer for HybridZoFo {
    fn name(&self) -> &'static str {
        "hybrid-zofo"
    }

    fn needs(&self) -> BatchNeeds {
        // One batch, used by both halves (no data assignment).
        BatchNeeds { fo: self.batch, zo: 0 }
    }

    fn step(
        &mut self,
        params: &mut ParamStore,
        exec: &mut dyn ModelExec,
        batches: &StepBatches,
        step_seed: u64,
    ) -> Result<StepStats> {
        let Some(batch) = &batches.fo else { bail!("hybrid-zofo needs a batch") };
        let split = self.split_index(params.len());
        let shallow = move |idx: usize, _name: &str| idx < split;

        // FO gradients at θ (before any perturbation; applied after the ZO
        // sweeps — the updates commute additively).
        let g = exec.grads(params, batch)?;
        let norm = grad_global_norm(&g.grads[split..]);

        // ZO half on the shallow tensors (subset SPSA, counter-addressed
        // seed replay); leaves the shallow tensors at θ − εz.
        params.perturb_subset(step_seed, self.eps, shallow);
        let l_plus = exec.mean_loss(params, batch)?;
        params.perturb_subset(step_seed, -2.0 * self.eps, shallow);
        let l_minus = exec.mean_loss(params, batch)?;
        let g0 = (l_plus - l_minus) / (2.0 * self.eps as f64);

        // One combined sweep (sweep fusion v2): SPSA restore + ZO update
        // on the shallow tensors and the FO update on the deep tensors,
        // in a single O(d) pass instead of a noise sweep plus per-tensor
        // axpy passes.
        params.hybrid_zo_fo_update(
            step_seed,
            self.eps,
            self.lr_zo,
            g0 as f32,
            self.lr_fo,
            &g.grads,
            shallow,
        );

        Ok(StepStats {
            loss: g.loss as f64,
            // Probe-loss mean on the shared batch (no data assignment in
            // this baseline, unlike Addax's D⁰/D¹ split).
            zo_loss: 0.5 * (l_plus + l_minus),
            g0,
            grad_norm: norm,
            fwd_evals: 2,
            bwd_evals: 1,
        })
    }

    fn method(&self) -> Method {
        Method::HybridZoFo
    }

    fn lr(&self) -> f64 {
        self.lr_fo as f64
    }

    fn ckpt_id(&self) -> String {
        format!(
            "hybrid-zofo~lr{}-{}~e{}~b{}~s{}",
            fmt_f32(self.lr_fo),
            fmt_f32(self.lr_zo),
            fmt_f32(self.eps),
            self.batch,
            fmt_f32(self.split_frac)
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::{quad, random_batch, run_optimizer, store};
    use crate::zorng::Xoshiro256;

    #[test]
    fn hybrid_converges_on_quadratic() {
        let mut opt = HybridZoFo::new(0.1, 0.02, 1e-3, 4, 0.5);
        let sub = run_optimizer(&mut opt, 16, 0.0, 800);
        assert!(sub < 0.5, "suboptimality {sub}");
    }

    #[test]
    fn shallow_perturbation_leaves_deep_untouched() {
        let mut p = store(16); // 2 tensors: w1 (8), w2 (8)
        let before = p.clone();
        p.perturb_subset(7, 0.1, |idx, _| idx < 1);
        // tensor 0 changed, tensor 1 identical
        assert!(p.get(0).tensor != before.get(0).tensor);
        assert_eq!(p.get(1).tensor, before.get(1).tensor);
    }

    #[test]
    fn step_is_three_noise_sweeps() {
        // Two materialized subset probes + the combined
        // restore+ZO+FO sweep; the deep tensors' FO updates ride inside
        // that third pass instead of extra per-tensor passes.
        let mut opt = HybridZoFo::new(0.1, 0.02, 1e-3, 2, 0.5);
        let mut exec = quad(16, 0.0);
        let mut p = store(16);
        p.perturb(6, 1.0);
        let mut rng = Xoshiro256::new(12);
        let b = random_batch(2, &mut rng);
        let before = p.noise_sweeps();
        opt.step(&mut p, &mut exec, &super::StepBatches { fo: Some(b), zo: None }, 5)
            .unwrap();
        assert_eq!(p.noise_sweeps() - before, 3);
    }

    #[test]
    fn step_restores_shallow_exactly_before_update() {
        // With lr_zo = 0 and lr_fo = 0, a step must leave params unchanged.
        let mut opt = HybridZoFo::new(0.0, 0.0, 1e-3, 2, 0.5);
        let mut exec = quad(16, 0.0);
        let mut p = store(16);
        p.perturb(3, 1.0);
        let before = p.clone();
        let mut rng = Xoshiro256::new(4);
        let b = random_batch(2, &mut rng);
        opt.step(&mut p, &mut exec, &super::StepBatches { fo: Some(b), zo: None }, 5)
            .unwrap();
        assert!(p.dist_sq(&before) < 1e-10, "drift {}", p.dist_sq(&before));
    }
}
