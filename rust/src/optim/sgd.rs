//! SGD and IP-SGD baselines.
//!
//! The paper distinguishes them (App. B): **SGD** materializes the full
//! gradient so it can apply global-norm clipping/normalization before the
//! update (O(d) gradient memory); **IP-SGD** updates each tensor as soon
//! as its gradient is available and discards it, so no normalization is
//! possible but memory does not scale with model size.
//!
//! In this AOT substrate both receive the per-tensor gradients from the
//! grads artifact; the *semantic* difference (normalize-then-apply vs
//! apply-per-tensor) and the *memory-model* difference (Method::Sgd
//! charges full-gradient residency) are both preserved.

use anyhow::{bail, Result};

use crate::memory::Method;
use crate::params::ParamStore;
use crate::runtime::ModelExec;

use super::{fmt_f32, grad_global_norm, BatchNeeds, Optimizer, StepBatches, StepStats};

/// SGD with global-norm gradient clipping (`clip = 1.0` by default).
#[derive(Clone, Debug)]
pub struct Sgd {
    pub lr: f32,
    pub batch: usize,
    /// Clip threshold for the global gradient norm (None = no clipping).
    pub clip: Option<f32>,
}

impl Sgd {
    pub fn new(lr: f32, batch: usize, clip: Option<f32>) -> Self {
        Self { lr, batch, clip }
    }

    pub fn defaults() -> Self {
        Self::new(5e-3, 16, Some(1.0))
    }
}

impl Optimizer for Sgd {
    fn name(&self) -> &'static str {
        "sgd"
    }

    fn needs(&self) -> BatchNeeds {
        BatchNeeds { fo: self.batch, zo: 0 }
    }

    fn step(
        &mut self,
        params: &mut ParamStore,
        exec: &mut dyn ModelExec,
        batches: &StepBatches,
        _step_seed: u64,
    ) -> Result<StepStats> {
        let Some(fo_batch) = &batches.fo else { bail!("sgd needs a FO batch") };
        let g = exec.grads(params, fo_batch)?;
        let norm = grad_global_norm(&g.grads);
        // Global-norm clipping requires the WHOLE gradient first — this is
        // exactly why SGD cannot be done in place (App. B).
        let scale = match self.clip {
            Some(c) if norm > c as f64 => (c as f64 / norm) as f32,
            _ => 1.0,
        };
        for (idx, grad) in g.grads.iter().enumerate() {
            params.fo_update_tensor(idx, self.lr * scale, 1.0, grad);
        }
        Ok(StepStats {
            loss: g.loss as f64,
            zo_loss: 0.0,
            g0: 0.0,
            grad_norm: norm,
            fwd_evals: 0,
            bwd_evals: 1,
        })
    }

    fn method(&self) -> Method {
        Method::Sgd
    }

    fn lr(&self) -> f64 {
        self.lr as f64
    }

    fn ckpt_id(&self) -> String {
        let clip = match self.clip {
            Some(c) => fmt_f32(c),
            None => "none".to_string(),
        };
        format!("sgd~lr{}~b{}~c{clip}", fmt_f32(self.lr), self.batch)
    }
}

/// In-place SGD: per-tensor update, no normalization, no gradient storage.
#[derive(Clone, Debug)]
pub struct IpSgd {
    pub lr: f32,
    pub batch: usize,
}

impl IpSgd {
    pub fn new(lr: f32, batch: usize) -> Self {
        Self { lr, batch }
    }

    pub fn defaults() -> Self {
        Self::new(1e-4, 4)
    }
}

impl Optimizer for IpSgd {
    fn name(&self) -> &'static str {
        "ip-sgd"
    }

    fn needs(&self) -> BatchNeeds {
        BatchNeeds { fo: self.batch, zo: 0 }
    }

    fn step(
        &mut self,
        params: &mut ParamStore,
        exec: &mut dyn ModelExec,
        batches: &StepBatches,
        _step_seed: u64,
    ) -> Result<StepStats> {
        let Some(fo_batch) = &batches.fo else { bail!("ip-sgd needs a FO batch") };
        let g = exec.grads(params, fo_batch)?;
        let norm = grad_global_norm(&g.grads);
        for (idx, grad) in g.grads.iter().enumerate() {
            // update, then conceptually drop grad (in-place discipline)
            params.fo_update_tensor(idx, self.lr, 1.0, grad);
        }
        Ok(StepStats {
            loss: g.loss as f64,
            zo_loss: 0.0,
            g0: 0.0,
            grad_norm: norm,
            fwd_evals: 0,
            bwd_evals: 1,
        })
    }

    fn method(&self) -> Method {
        Method::IpSgd
    }

    fn lr(&self) -> f64 {
        self.lr as f64
    }

    fn ckpt_id(&self) -> String {
        format!("ip-sgd~lr{}~b{}", fmt_f32(self.lr), self.batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::run_optimizer;

    #[test]
    fn ip_sgd_converges_fast() {
        let mut opt = IpSgd::new(0.1, 4);
        let sub = run_optimizer(&mut opt, 16, 0.0, 200);
        assert!(sub < 1e-4, "suboptimality {sub}");
    }

    #[test]
    fn sgd_with_clip_converges() {
        let mut opt = Sgd::new(0.1, 4, Some(1.0));
        let sub = run_optimizer(&mut opt, 16, 0.05, 400);
        assert!(sub < 0.05, "suboptimality {sub}");
    }

    #[test]
    fn clipping_bounds_update_size() {
        use crate::optim::testutil::{quad, random_batch, store};
        use crate::zorng::Xoshiro256;
        let mut exec = quad(8, 0.0);
        let mut p = store(8);
        p.perturb(1, 100.0); // far from optimum => huge gradient
        let before = p.clone();
        let mut rng = Xoshiro256::new(2);
        let b = random_batch(2, &mut rng);
        let mut opt = Sgd::new(1.0, 2, Some(0.5));
        let stats = opt
            .step(&mut p, &mut exec, &StepBatches { fo: Some(b), zo: None }, 0)
            .unwrap();
        assert!(stats.grad_norm > 0.5);
        // ‖Δθ‖ = lr * clip = 0.5
        let dist = p.dist_sq(&before).sqrt();
        assert!((dist - 0.5).abs() < 1e-3, "dist {dist}");
    }
}
