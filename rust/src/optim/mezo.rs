//! MeZO (Malladi et al. [42]) and the naive ZO-SGD it improves on.
//!
//! MeZO = ZO-SGD with the in-place seed-replay trick: only the seed is
//! stored, so memory ≈ inference. Here it runs the fused sweep schedule:
//! on a substrate with a fused probe path (`ModelExec::probe_rows_fused`)
//! the whole step is **2** O(d) sweeps — the probe's internal z replay
//! plus one plain update from θ; on a legacy substrate the materialized
//! probe (+ε, −2ε) is followed by one restore+update pass — 3 sweeps,
//! still down from the naive 4. `ZoSgdNaive` materializes the full
//! perturbation vector `z ∈ R^d` — numerically identical updates, O(d)
//! extra memory — kept as the ablation the paper's §2.2 describes.

use anyhow::{bail, Result};

use crate::memory::Method;
use crate::params::ParamStore;
use crate::runtime::ModelExec;
use crate::zorng::BlockNoise;

use super::{fmt_f32, spsa_probe, BatchNeeds, Optimizer, ProbeEnd, StepBatches, StepStats};

/// MeZO: `θ ← θ − η·g⁰·z`, z replayed from the step seed.
#[derive(Clone, Debug)]
pub struct MeZo {
    pub lr: f32,
    pub eps: f32,
    pub batch: usize,
}

impl MeZo {
    pub fn new(lr: f32, eps: f32, batch: usize) -> Self {
        Self { lr, eps, batch }
    }

    /// Paper defaults (Table 7: η ∈ {1e-6, 1e-7}, ε = 1e-3).
    pub fn defaults() -> Self {
        Self::new(1e-6, 1e-3, 16)
    }
}

impl Optimizer for MeZo {
    fn name(&self) -> &'static str {
        "mezo"
    }

    fn needs(&self) -> BatchNeeds {
        BatchNeeds { fo: 0, zo: self.batch }
    }

    fn step(
        &mut self,
        params: &mut ParamStore,
        exec: &mut dyn ModelExec,
        batches: &StepBatches,
        step_seed: u64,
    ) -> Result<StepStats> {
        let Some(zo_batch) = &batches.zo else { bail!("mezo needs a ZO batch") };
        let (g0, loss, end) = spsa_probe(params, exec, zo_batch, self.eps, step_seed)?;
        match end {
            // fused probe never moved the store: plain ZO update from θ
            ProbeEnd::AtTheta => params.zo_update(step_seed, self.lr, 1.0, g0 as f32),
            // materialized probe left θ − εz: restore and update at once
            ProbeEnd::AtThetaMinusEps => {
                params.restore_and_zo_update(step_seed, self.eps, self.lr, 1.0, g0 as f32)
            }
        }
        // ZO-only: the probe mean IS the training loss, reported in both
        // fields so mixed and pure-ZO rows stay comparable.
        Ok(StepStats { loss, zo_loss: loss, g0, grad_norm: 0.0, fwd_evals: 2, bwd_evals: 0 })
    }

    fn method(&self) -> Method {
        Method::MeZo
    }

    fn lr(&self) -> f64 {
        self.lr as f64
    }

    fn ckpt_id(&self) -> String {
        format!("mezo~lr{}~e{}~b{}", fmt_f32(self.lr), fmt_f32(self.eps), self.batch)
    }
}

/// ZO-SGD without the seed trick: materializes `z` (O(d) memory).
///
/// Produces *identical* parameter trajectories to [`MeZo`] given the same
/// seeds — asserted by a test below — which is exactly the paper's point:
/// the seed trick changes memory, not mathematics. That bit-identity is
/// an **f32-store** statement: on a bf16 store the naive restore+update
/// rounds twice where the fused sweep rounds once, so the trajectories
/// agree only to quantization precision (EXPERIMENTS.md §Precision).
#[derive(Clone, Debug)]
pub struct ZoSgdNaive {
    pub lr: f32,
    pub eps: f32,
    pub batch: usize,
}

impl ZoSgdNaive {
    pub fn new(lr: f32, eps: f32, batch: usize) -> Self {
        Self { lr, eps, batch }
    }
}

impl Optimizer for ZoSgdNaive {
    fn name(&self) -> &'static str {
        "zo-sgd"
    }

    fn needs(&self) -> BatchNeeds {
        BatchNeeds { fo: 0, zo: self.batch }
    }

    fn step(
        &mut self,
        params: &mut ParamStore,
        exec: &mut dyn ModelExec,
        batches: &StepBatches,
        step_seed: u64,
    ) -> Result<StepStats> {
        let Some(zo_batch) = &batches.zo else { bail!("zo-sgd needs a ZO batch") };

        // Materialize z for the whole model — the memory cost MeZO avoids.
        // Same counter-addressed blocks as the replayed path, so the
        // trajectories match MeZO's bit for bit.
        let noise = BlockNoise::new(step_seed);
        let z: Vec<Vec<f32>> = params
            .tensors()
            .enumerate()
            .map(|(param_idx, t)| {
                let mut v = vec![0.0f32; t.len()];
                noise.fill_param(param_idx, &mut v);
                v
            })
            .collect();

        // θ ± εz without replay.
        for (idx, zt) in z.iter().enumerate() {
            params.get_mut(idx).tensor.axpy(self.eps, zt);
        }
        let l_plus = exec.mean_loss(params, zo_batch)?;
        for (idx, zt) in z.iter().enumerate() {
            params.get_mut(idx).tensor.axpy(-2.0 * self.eps, zt);
        }
        let l_minus = exec.mean_loss(params, zo_batch)?;
        let g0 = (l_plus - l_minus) / (2.0 * self.eps as f64);
        // restore + update as two axpys — elementwise identical to the
        // fused sweep's (v + εz) + δz, just with z held in memory.
        for (idx, zt) in z.iter().enumerate() {
            params.get_mut(idx).tensor.axpy(self.eps, zt);
        }
        for (idx, zt) in z.iter().enumerate() {
            params.get_mut(idx).tensor.axpy(-self.lr * g0 as f32, zt);
        }
        let loss = 0.5 * (l_plus + l_minus);
        Ok(StepStats {
            loss,
            zo_loss: loss,
            g0,
            grad_norm: 0.0,
            fwd_evals: 2,
            bwd_evals: 0,
        })
    }

    fn method(&self) -> Method {
        Method::ZoSgdNaive
    }

    fn lr(&self) -> f64 {
        self.lr as f64
    }

    fn ckpt_id(&self) -> String {
        format!("zo-sgd~lr{}~e{}~b{}", fmt_f32(self.lr), fmt_f32(self.eps), self.batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::{quad, random_batch, run_optimizer, store};
    use crate::optim::StepBatches;
    use crate::runtime::mock::QuadraticExec;
    use crate::runtime::{ExecStats, FwdOut, GradOut, TokenBatch};
    use crate::zorng::Xoshiro256;

    /// Wrapper hiding the mock's fused probe path so tests can pin MeZO
    /// to the legacy materialized probe schedule.
    struct Materialized(QuadraticExec);

    impl ModelExec for Materialized {
        fn forward(&mut self, params: &ParamStore, batch: &TokenBatch) -> Result<FwdOut> {
            self.0.forward(params, batch)
        }
        fn grads(&mut self, params: &ParamStore, batch: &TokenBatch) -> Result<GradOut> {
            self.0.grads(params, batch)
        }
        fn stats(&self) -> ExecStats {
            self.0.stats()
        }
    }

    #[test]
    fn mezo_descends_on_quadratic() {
        let mut opt = MeZo::new(0.02, 1e-3, 8);
        let sub = run_optimizer(&mut opt, 8, 0.0, 800);
        assert!(sub < 1.0, "suboptimality {sub}");
    }

    #[test]
    fn mezo_and_naive_trajectories_identical() {
        // Pin MeZO to the legacy materialized probe path: the naive
        // baseline perturbs the live store, so bit-identity is a
        // statement about that schedule (the fused path is separately
        // proven bit-equal to it at the probe and update layers).
        let d = 12;
        let mut exec = Materialized(quad(d, 0.05));
        let mut pa = store(d);
        pa.perturb(1, 1.0);
        let mut pb = pa.clone();
        let mut mezo = MeZo::new(0.05, 1e-3, 4);
        let mut naive = ZoSgdNaive::new(0.05, 1e-3, 4);
        let mut rng = Xoshiro256::new(5);
        for s in 0..50 {
            let b = random_batch(4, &mut rng);
            let sb = StepBatches { fo: None, zo: Some(b) };
            let sa = mezo.step(&mut pa, &mut exec, &sb, s).unwrap();
            let sn = naive.step(&mut pb, &mut exec, &sb, s).unwrap();
            assert!((sa.g0 - sn.g0).abs() < 1e-9);
        }
        // Identical math AND identical op order: the naive version applies
        // the same counter-addressed z blocks with the same elementwise
        // sequence as the fused replay path, so the trajectories agree
        // bit for bit — exactly the paper's point that the seed trick
        // changes memory, not mathematics.
        assert!(pa.dist_sq(&pb) == 0.0, "dist {}", pa.dist_sq(&pb));
    }

    #[test]
    fn mezo_step_is_two_sweeps_on_a_fused_substrate() {
        let mut opt = MeZo::new(0.05, 1e-3, 4);
        let mut exec = quad(8, 0.0);
        let mut p = store(8);
        let mut rng = Xoshiro256::new(9);
        let b = random_batch(4, &mut rng);
        let before = p.noise_sweeps();
        opt.step(&mut p, &mut exec, &StepBatches { fo: None, zo: Some(b) }, 3)
            .unwrap();
        assert_eq!(
            p.noise_sweeps() - before,
            2,
            "fused probe (1 replay) + plain update must be 2 O(d) sweeps"
        );
    }

    #[test]
    fn mezo_step_is_three_sweeps_on_a_legacy_substrate() {
        let mut opt = MeZo::new(0.05, 1e-3, 4);
        let mut exec = Materialized(quad(8, 0.0));
        let mut p = store(8);
        let mut rng = Xoshiro256::new(9);
        let b = random_batch(4, &mut rng);
        let before = p.noise_sweeps();
        opt.step(&mut p, &mut exec, &StepBatches { fo: None, zo: Some(b) }, 3)
            .unwrap();
        assert_eq!(
            p.noise_sweeps() - before,
            3,
            "materialized probe (2) + fused restore+update (1) must be 3 sweeps"
        );
    }

    #[test]
    fn mezo_needs_zo_batch() {
        let mut opt = MeZo::defaults();
        let mut exec = quad(4, 0.0);
        let mut p = store(4);
        let r = opt.step(&mut p, &mut exec, &StepBatches::default(), 0);
        assert!(r.is_err());
    }
}
