//! The optimizer zoo: Addax (the paper's contribution) and every baseline
//! it is compared against (MeZO, ZO-SGD, SGD, IP-SGD, Adam, and the
//! layer-split hybrid ZO-FO scheme of Zhang et al. [69]).
//!
//! All optimizers speak the same [`Optimizer`] trait: the coordinator
//! samples the batches each optimizer declares it needs (a first-order
//! batch from `D¹`, a zeroth-order batch from `D⁰`, or both) and calls
//! [`Optimizer::step`]. Updates are applied **in place** on the
//! [`ParamStore`]; gradients and noise are transient.

mod adam;
mod addax;
mod hybrid;
mod mezo;
mod sgd;

pub use adam::Adam;
pub use addax::Addax;
pub use hybrid::HybridZoFo;
pub use mezo::{MeZo, ZoSgdNaive};
pub use sgd::{IpSgd, Sgd};

use anyhow::{bail, Result};

use crate::jsonlite::{obj, Json};
use crate::memory::Method;
use crate::params::ParamStore;
use crate::runtime::{ModelExec, TokenBatch};

/// How many examples an optimizer wants per step from each partition.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BatchNeeds {
    /// First-order batch size `K¹` (drawn from `D¹`, short sequences).
    pub fo: usize,
    /// Zeroth-order batch size `K⁰` (drawn from `D⁰`, long sequences).
    pub zo: usize,
}

/// The batches the coordinator sampled for one step.
#[derive(Clone, Debug, Default)]
pub struct StepBatches {
    pub fo: Option<TokenBatch>,
    pub zo: Option<TokenBatch>,
}

/// Telemetry from a single optimizer step.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepStats {
    /// Training loss observed this step (FO loss if available, else the
    /// mean of the two ZO probe losses).
    pub loss: f64,
    /// Mean of the two SPSA probe losses on the ZO batch — the ZO-batch
    /// loss the paper's Algorithm 2 observes (0 if no ZO part). Distinct
    /// from `loss` for mixed optimizers like Addax, whose `loss` is the
    /// FO-batch loss; surfaced per step in the metrics JSONL rows.
    pub zo_loss: f64,
    /// SPSA directional-derivative estimate `g⁰` (0 if no ZO part).
    pub g0: f64,
    /// Global gradient norm of the FO part (0 if no FO part).
    pub grad_norm: f64,
    /// Forward executions used.
    pub fwd_evals: u32,
    /// Backward (grads) executions used.
    pub bwd_evals: u32,
}

/// Serialized mutable optimizer state — the checkpointing seam on
/// [`Optimizer`].
///
/// Adam carries its bias-correction counter in `t` and the first/second
/// moments in `tensors` (always fp32, matching the in-memory moments the
/// memory model charges Adam for). The ZO/SGD family is stateless — its
/// entire trajectory state is the step counter plus seeds (the MeZO
/// seed-replay property) — and serializes the default empty state.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct OptState {
    /// Scalar step counter (Adam's `t`); 0 for stateless optimizers.
    pub t: u64,
    /// Named fp32 state tensors in a fixed, optimizer-defined order.
    pub tensors: Vec<(String, Vec<f32>)>,
}

impl OptState {
    pub fn is_empty(&self) -> bool {
        self.t == 0 && self.tensors.is_empty()
    }
}

/// A fine-tuning optimizer with in-place updates.
pub trait Optimizer: Send {
    fn name(&self) -> &'static str;

    /// Batch sizes to sample for each step.
    fn needs(&self) -> BatchNeeds;

    /// Perform one in-place update of `params`.
    ///
    /// `step_seed` is the per-step seed used for ZO noise replay; the
    /// coordinator derives it as `derive_seed(run_seed, step)`.
    fn step(
        &mut self,
        params: &mut ParamStore,
        exec: &mut dyn ModelExec,
        batches: &StepBatches,
        step_seed: u64,
    ) -> Result<StepStats>;

    /// The memory-model method this optimizer corresponds to (drives the
    /// GPU footprint simulation, Figures 1-4).
    fn method(&self) -> Method;

    /// Learning rate accessor (for schedules / logging).
    fn lr(&self) -> f64;

    /// Snapshot the mutable optimizer state for checkpointing. The
    /// default (stateless) implementation returns the empty state; Adam
    /// overrides it with `t` and the moments.
    fn state(&self) -> OptState {
        OptState::default()
    }

    /// Hyper-parameter-complete identity fragment for checkpoint-resume
    /// validation: every knob that steers this optimizer's update rule,
    /// mirroring `OptSpec::id`. The coordinator folds it into the derived
    /// snapshot identity, so editing *any* hyper-parameter (not just lr)
    /// between a kill and a restart refuses the stale snapshots. The
    /// default covers name + lr only; every optimizer with more knobs
    /// overrides it.
    fn ckpt_id(&self) -> String {
        format!("{}~lr{}", self.name(), self.lr())
    }

    /// Restore state captured by [`Optimizer::state`]. The default
    /// implementation accepts only the empty state — a stateless
    /// optimizer handed Adam moments is a checkpoint/config mismatch and
    /// must fail loudly rather than silently drop state.
    fn load_state(&mut self, state: &OptState) -> Result<()> {
        if !state.is_empty() {
            bail!(
                "optimizer {} is stateless but the checkpoint carries state \
                 (t={}, {} tensor(s))",
                self.name(),
                state.t,
                state.tensors.len()
            );
        }
        Ok(())
    }
}

/// Declarative optimizer recipe: everything needed to (re)build an
/// optimizer, serializable into sweep specs and the run manifest.
///
/// One `OptSpec` is one column of the paper's hyper-parameter grids: the
/// sweep scheduler expands grids into `OptSpec`s, prices each with the
/// memory model (via [`OptSpec::method`]) and builds the live optimizer
/// on the assigned worker (via [`OptSpec::build`]). The repro harness
/// uses the same recipes, so every table/figure cell is reproducible from
/// its manifest row alone.
///
/// The pseudo-name `"zero-shot"` is accepted for evaluation-only runs
/// (steps = 0): it builds an inert optimizer and prices as inference.
#[derive(Clone, Debug, PartialEq)]
pub struct OptSpec {
    pub name: String,
    pub lr: f32,
    pub eps: f32,
    pub batch: usize,
    /// Addax ZO/FO mixing weight α.
    pub alpha: f32,
    /// Addax ZO batch `K⁰`.
    pub k0: usize,
    /// Addax FO batch `K¹`.
    pub k1: usize,
    /// SGD global-norm clip.
    pub clip: f32,
    /// Hybrid ZO-FO zeroth-order learning rate.
    pub lr_zo: f32,
    /// Hybrid ZO-FO layer split fraction.
    pub split: f32,
}

/// Shortest-round-trip float formatting (stable across platforms; used in
/// run ids and manifest rows so identical specs hash identically).
pub fn fmt_f32(v: f32) -> String {
    format!("{v}")
}

impl OptSpec {
    /// Recipe with the config-file defaults for `name` (same defaults as
    /// `Config::optimizer`); validity is checked at [`OptSpec::build`].
    pub fn named(name: &str) -> Self {
        Self {
            name: name.to_string(),
            lr: 1e-2,
            eps: 1e-3,
            batch: 8,
            alpha: 0.05,
            k0: 6,
            k1: 4,
            clip: 1.0,
            lr_zo: 1e-3,
            split: 0.5,
        }
    }

    /// Compact human-readable identity: only the fields the named
    /// optimizer actually consumes, so equivalent recipes share an id.
    pub fn id(&self) -> String {
        let mut s = self.name.clone();
        match self.name.as_str() {
            "zero-shot" => return s,
            "addax" => {
                s += &format!(
                    "~lr{}~e{}~a{}~k{}-{}",
                    fmt_f32(self.lr),
                    fmt_f32(self.eps),
                    fmt_f32(self.alpha),
                    self.k0,
                    self.k1
                );
            }
            "mezo" | "zo-sgd" => {
                s += &format!("~lr{}~e{}~b{}", fmt_f32(self.lr), fmt_f32(self.eps), self.batch);
            }
            "sgd" => {
                s += &format!("~lr{}~b{}~c{}", fmt_f32(self.lr), self.batch, fmt_f32(self.clip));
            }
            "hybrid-zofo" => {
                s += &format!(
                    "~lr{}-{}~e{}~b{}~s{}",
                    fmt_f32(self.lr),
                    fmt_f32(self.lr_zo),
                    fmt_f32(self.eps),
                    self.batch,
                    fmt_f32(self.split)
                );
            }
            _ => {
                // ip-sgd, adam, and anything future: lr + batch
                s += &format!("~lr{}~b{}", fmt_f32(self.lr), self.batch);
            }
        }
        s
    }

    /// ZO-only optimizers run `zo_mult ×` the FO step budget in sweeps
    /// (the paper's 20k-vs-1k step protocol).
    pub fn is_zo_only(&self) -> bool {
        matches!(self.name.as_str(), "mezo" | "zo-sgd")
    }

    /// The memory-model method this recipe prices as.
    pub fn method(&self) -> Result<Method> {
        Ok(match self.name.as_str() {
            "addax" => Method::Addax,
            "mezo" => Method::MeZo,
            "zo-sgd" => Method::ZoSgdNaive,
            "sgd" => Method::Sgd,
            "ip-sgd" => Method::IpSgd,
            "adam" => Method::Adam,
            "hybrid-zofo" => Method::HybridZoFo,
            // evaluation-only: inference footprint, same as MeZO's phase
            "zero-shot" => Method::MeZo,
            other => bail!("unknown optimizer {other:?}"),
        })
    }

    /// Instantiate the live optimizer.
    pub fn build(&self) -> Result<Box<dyn Optimizer>> {
        Ok(match self.name.as_str() {
            "addax" => Box::new(Addax::new(self.lr, self.eps, self.alpha, self.k0, self.k1)),
            "mezo" => Box::new(MeZo::new(self.lr, self.eps, self.batch)),
            "zo-sgd" => Box::new(ZoSgdNaive::new(self.lr, self.eps, self.batch)),
            "sgd" => Box::new(Sgd::new(self.lr, self.batch, Some(self.clip))),
            "ip-sgd" => Box::new(IpSgd::new(self.lr, self.batch)),
            "adam" => Box::new(Adam::new(self.lr, self.batch)),
            "hybrid-zofo" => Box::new(HybridZoFo::new(
                self.lr,
                self.lr_zo,
                self.eps,
                self.batch,
                self.split,
            )),
            // inert: lr 0, batch 1 — the executor never steps it anyway
            "zero-shot" => Box::new(IpSgd::new(0.0, 1)),
            other => bail!("unknown optimizer {other:?}"),
        })
    }

    /// Manifest/sweep-spec serialization. Floats go through [`fmt_f32`]
    /// strings so rows are canonical and round-trip exactly.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("name", Json::from(self.name.clone())),
            ("lr", Json::from(fmt_f32(self.lr))),
            ("eps", Json::from(fmt_f32(self.eps))),
            ("batch", Json::from(self.batch)),
            ("alpha", Json::from(fmt_f32(self.alpha))),
            ("k0", Json::from(self.k0)),
            ("k1", Json::from(self.k1)),
            ("clip", Json::from(fmt_f32(self.clip))),
            ("lr_zo", Json::from(fmt_f32(self.lr_zo))),
            ("split", Json::from(fmt_f32(self.split))),
        ])
    }
}

/// Where an SPSA probe left the parameter store — the contract the caller
/// needs to pick its restore/update sweep (sweep fusion v2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProbeEnd {
    /// The fused perturb+probe-eval path never touched the store: the
    /// params still sit at `θ`, bit for bit. The caller updates with
    /// [`ParamStore::zo_fo_update`] / `perturb(seed, −lr·coeff·g⁰)` —
    /// no restore sweep exists to fuse away.
    AtTheta,
    /// The materialized path's last perturb was `−2ε`: the params sit at
    /// `θ − εz`. The caller owns the restore — `perturb(seed, eps)` or
    /// one of the fused restore+update sweeps.
    AtThetaMinusEps,
}

/// SPSA zeroth-order probe (Algorithm 2, first two sweeps) via seed replay.
///
/// Returns `g⁰ = (L(θ+εz) − L(θ−εz)) / 2ε`, the mean of the two probe
/// losses, and a [`ProbeEnd`] telling the caller where the params ended:
///
/// - When the substrate has a fused perturb+probe-eval path
///   (`ModelExec::probe_rows_fused`), both probes evaluate in one
///   streaming pass that replays `z` internally — the store is never
///   perturbed ([`ProbeEnd::AtTheta`]) and the whole ZO step needs only
///   **one** more O(d) sweep (the update), down from 3 total.
/// - Otherwise the legacy schedule runs — perturb `+ε`, evaluate, perturb
///   `−2ε`, evaluate — leaving `θ − εz` ([`ProbeEnd::AtThetaMinusEps`]);
///   the caller's fused restore+update keeps that step at 3 sweeps.
///
/// Both paths produce bit-identical `g⁰` and losses (the fused substrate
/// is contractually bit-equal to the materialized schedule).
pub fn spsa_probe(
    params: &mut ParamStore,
    exec: &mut dyn ModelExec,
    batch: &TokenBatch,
    eps: f32,
    seed: u64,
) -> Result<(f64, f64, ProbeEnd)> {
    // Fleet tail work-stealing seam: when a `steal::StealCtx` is
    // installed on this thread AND a thief has advertised, the probe is
    // sharded across workers — bit-identically, so this branch is
    // invisible to everything downstream (see `sched::steal` docs). With
    // no context installed (every non-fleet caller) this is one
    // thread-local read.
    if let Some(out) = crate::sched::steal::sharded_probe(params, exec, batch, eps, seed)? {
        return Ok(out);
    }
    if let Some((plus, minus)) = exec.probe_rows_fused(params, batch, eps, seed)? {
        // One full pass of noise generation happened inside the executor;
        // keep the O(d)-traffic metric honest.
        params.tally_noise_sweep();
        let l_plus = plus.mean_loss();
        let l_minus = minus.mean_loss();
        let g0 = (l_plus - l_minus) / (2.0 * eps as f64);
        return Ok((g0, 0.5 * (l_plus + l_minus), ProbeEnd::AtTheta));
    }
    params.perturb(seed, eps);
    let l_plus = exec.mean_loss(params, batch)?;
    params.perturb(seed, -2.0 * eps);
    let l_minus = exec.mean_loss(params, batch)?;
    let g0 = (l_plus - l_minus) / (2.0 * eps as f64);
    Ok((g0, 0.5 * (l_plus + l_minus), ProbeEnd::AtThetaMinusEps))
}

/// [`spsa_probe`] that always hands the params back at `θ`: exact
/// (bit-wise) under the fused path (the store was never touched), and
/// exact under the materialized path too because the same `z` values are
/// added and subtracted. Used where the estimate is wanted without an
/// update (tests, diagnostics); the optimizers use the probe +
/// fused-update path instead.
pub fn spsa_g0(
    params: &mut ParamStore,
    exec: &mut dyn ModelExec,
    batch: &TokenBatch,
    eps: f32,
    seed: u64,
) -> Result<(f64, f64)> {
    let (g0, loss, end) = spsa_probe(params, exec, batch, eps, seed)?;
    if end == ProbeEnd::AtThetaMinusEps {
        params.perturb(seed, eps);
    }
    Ok((g0, loss))
}

/// `z · g` with `z` replayed from `seed` under the counter-addressed block
/// scheme, for a per-tensor gradient list laid out like the param store.
/// This is the true directional derivative SPSA estimates (tests, theory).
pub fn z_dot_grads(seed: u64, grads: &[Vec<f32>]) -> f64 {
    let noise = crate::zorng::BlockNoise::new(seed);
    grads
        .iter()
        .enumerate()
        .map(|(param_idx, g)| noise.dot_param(param_idx, g))
        .sum()
}

/// Global-norm of a gradient list.
pub fn grad_global_norm(grads: &[Vec<f32>]) -> f64 {
    grads
        .iter()
        .map(|t| t.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>())
        .sum::<f64>()
        .sqrt()
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::runtime::mock::QuadraticExec;
    use crate::runtime::TokenBatch;
    use crate::zorng::Xoshiro256;

    pub fn store(d: usize) -> ParamStore {
        ParamStore::zeros(&[
            ("w1".to_string(), vec![d / 2]),
            ("w2".to_string(), vec![d - d / 2]),
        ])
    }

    pub fn quad(d: usize, sigma: f32) -> QuadraticExec {
        QuadraticExec::new(d, 0.5, 2.0, sigma, 13)
    }

    pub fn random_batch(n: usize, rng: &mut Xoshiro256) -> TokenBatch {
        let rows: Vec<_> = (0..n)
            .map(|_| (vec![rng.next_below(1000) as i32 + 1, 7], vec![-1, -1]))
            .collect();
        TokenBatch::from_rows(&rows)
    }

    /// Run `opt` for `steps` on the quadratic and return final suboptimality.
    pub fn run_optimizer(
        opt: &mut dyn Optimizer,
        d: usize,
        sigma: f32,
        steps: usize,
    ) -> f64 {
        let mut exec = quad(d, sigma);
        let mut params = store(d);
        let mut rng = Xoshiro256::new(99);
        for s in 0..steps {
            let needs = opt.needs();
            let batches = StepBatches {
                fo: (needs.fo > 0).then(|| random_batch(needs.fo, &mut rng)),
                zo: (needs.zo > 0).then(|| random_batch(needs.zo, &mut rng)),
            };
            opt.step(&mut params, &mut exec, &batches, s as u64 * 7919 + 1)
                .unwrap();
        }
        exec.suboptimality(&params)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spsa_restores_params_exactly() {
        let mut params = testutil::store(16);
        params.perturb(3, 1.0);
        let before = params.clone();
        let mut exec = testutil::quad(16, 0.0);
        let mut rng = crate::zorng::Xoshiro256::new(1);
        let batch = testutil::random_batch(4, &mut rng);
        let (g0, loss) = spsa_g0(&mut params, &mut exec, &batch, 1e-3, 77).unwrap();
        assert!(g0.is_finite() && loss.is_finite());
        assert!(params.dist_sq(&before) < 1e-10, "restore drift {}", params.dist_sq(&before));
    }

    #[test]
    fn spsa_matches_directional_derivative_on_quadratic() {
        let mut params = testutil::store(16);
        params.perturb(5, 1.0);
        let mut exec = testutil::quad(16, 0.0);
        let mut rng = crate::zorng::Xoshiro256::new(2);
        let batch = testutil::random_batch(2, &mut rng);
        let seed = 31;
        let (g0, _) = spsa_g0(&mut params, &mut exec, &batch, 1e-4, seed).unwrap();
        // z·∇L with z replayed block-wise
        let g = exec.grads(&params, &batch).unwrap();
        let dir = z_dot_grads(seed, &g.grads);
        assert!((g0 - dir).abs() < 0.05 * dir.abs().max(1.0), "{g0} vs {dir}");
    }

    /// Shim hiding a substrate's fused probe path, forcing `spsa_probe`
    /// down the legacy materialized perturb → eval → perturb → eval
    /// schedule (the trait-default `probe_rows_fused` returns `None`).
    struct NoFused<'a>(&'a mut dyn ModelExec);

    impl ModelExec for NoFused<'_> {
        fn forward(
            &mut self,
            params: &ParamStore,
            batch: &TokenBatch,
        ) -> Result<crate::runtime::FwdOut> {
            self.0.forward(params, batch)
        }
        fn grads(
            &mut self,
            params: &ParamStore,
            batch: &TokenBatch,
        ) -> Result<crate::runtime::GradOut> {
            self.0.grads(params, batch)
        }
        fn stats(&self) -> crate::runtime::ExecStats {
            self.0.stats()
        }
    }

    #[test]
    fn fused_probe_leaves_params_at_theta() {
        let mut params = testutil::store(16);
        params.perturb(4, 1.0);
        let before = params.clone();
        let mut exec = testutil::quad(16, 0.0);
        let mut rng = crate::zorng::Xoshiro256::new(6);
        let batch = testutil::random_batch(2, &mut rng);
        let (g0, loss, end) = spsa_probe(&mut params, &mut exec, &batch, 1e-3, 55).unwrap();
        assert!(g0.is_finite() && loss.is_finite());
        assert_eq!(end, ProbeEnd::AtTheta);
        assert_eq!(params.dist_sq(&before), 0.0, "fused probe must not touch the store");
        // setup perturb (1) + the fused probe's internal z replay (1)
        assert_eq!(params.noise_sweeps(), 2);
    }

    #[test]
    fn legacy_probe_leaves_params_at_theta_minus_eps_z() {
        let mut params = testutil::store(16);
        params.perturb(4, 1.0);
        let before = params.clone();
        let mut exec = testutil::quad(16, 0.0);
        let mut rng = crate::zorng::Xoshiro256::new(6);
        let batch = testutil::random_batch(2, &mut rng);
        let (seed, eps) = (55u64, 1e-3f32);
        let (_, _, end) =
            spsa_probe(&mut params, &mut NoFused(&mut exec), &batch, eps, seed).unwrap();
        assert_eq!(end, ProbeEnd::AtThetaMinusEps);
        // manual θ − εz from the same replay (float tolerance: the probe
        // reaches it as (θ+εz)−2εz, the manual path in one add)
        let mut manual = before.clone();
        manual.perturb(seed, -eps);
        let drift = params.dist_sq(&manual);
        assert!(drift < 1e-10, "probe must leave θ − εz (drift {drift})");
        // the caller-owned restore brings them back
        params.perturb(seed, eps);
        assert!(params.dist_sq(&before) < 1e-10);
    }

    #[test]
    fn fused_and_legacy_probes_agree_bitwise() {
        let mut params = testutil::store(64);
        params.perturb(9, 1.0);
        let mut exec = testutil::quad(64, 0.5);
        let mut rng = crate::zorng::Xoshiro256::new(8);
        let batch = testutil::random_batch(3, &mut rng);
        let (seed, eps) = (123u64, 1e-3f32);
        let (g0_f, l_f, end_f) = spsa_probe(&mut params, &mut exec, &batch, eps, seed).unwrap();
        assert_eq!(end_f, ProbeEnd::AtTheta);
        let (g0_l, l_l, end_l) =
            spsa_probe(&mut params, &mut NoFused(&mut exec), &batch, eps, seed).unwrap();
        assert_eq!(end_l, ProbeEnd::AtThetaMinusEps);
        params.perturb(seed, eps); // caller-owned restore for the legacy path
        assert_eq!(g0_f.to_bits(), g0_l.to_bits(), "{g0_f} vs {g0_l}");
        assert_eq!(l_f.to_bits(), l_l.to_bits(), "{l_f} vs {l_l}");
    }

    #[test]
    fn grad_norm_helper() {
        let g = vec![vec![3.0f32], vec![4.0f32]];
        assert!((grad_global_norm(&g) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn opt_spec_builds_every_family() {
        for name in ["addax", "mezo", "zo-sgd", "sgd", "ip-sgd", "adam", "hybrid-zofo"] {
            let spec = OptSpec::named(name);
            let opt = spec.build().unwrap();
            assert_eq!(opt.name(), name);
            assert_eq!(opt.method(), spec.method().unwrap());
        }
        assert!(OptSpec::named("nope").build().is_err());
        assert!(OptSpec::named("nope").method().is_err());
        // zero-shot is the eval-only pseudo-optimizer
        let zs = OptSpec::named("zero-shot");
        assert!(zs.build().is_ok());
        assert_eq!(zs.method().unwrap(), Method::MeZo);
    }

    #[test]
    fn ckpt_id_covers_every_hyperparameter() {
        // Build each optimizer from a spec, tweak one hyper-parameter the
        // default name+lr id would miss, and demand the id changes —
        // this is what makes resume refuse a config edit beyond lr.
        let a = Addax::new(0.05, 1e-3, 0.3, 6, 4);
        assert_ne!(a.ckpt_id(), Addax::new(0.05, 2e-3, 0.3, 6, 4).ckpt_id(), "eps");
        assert_ne!(a.ckpt_id(), Addax::new(0.05, 1e-3, 0.9, 6, 4).ckpt_id(), "alpha");
        assert_ne!(a.ckpt_id(), Addax::new(0.05, 1e-3, 0.3, 8, 4).ckpt_id(), "k0");
        let m = MeZo::new(0.02, 1e-3, 8);
        assert_ne!(m.ckpt_id(), MeZo::new(0.02, 2e-3, 8).ckpt_id(), "mezo eps");
        assert_ne!(m.ckpt_id(), MeZo::new(0.02, 1e-3, 4).ckpt_id(), "mezo batch");
        let s = Sgd::new(0.1, 4, Some(1.0));
        assert_ne!(s.ckpt_id(), Sgd::new(0.1, 4, None).ckpt_id(), "clip");
        let ad = Adam::new(0.01, 4);
        let mut ad2 = Adam::new(0.01, 4);
        ad2.beta2 = 0.95;
        assert_ne!(ad.ckpt_id(), ad2.ckpt_id(), "beta2");
        let h = HybridZoFo::new(0.1, 1e-3, 1e-3, 4, 0.5);
        assert_ne!(h.ckpt_id(), HybridZoFo::new(0.1, 1e-3, 1e-3, 4, 0.25).ckpt_id(), "split");
        // every id leads with the optimizer name
        for name in ["addax", "mezo", "zo-sgd", "sgd", "ip-sgd", "adam", "hybrid-zofo"] {
            let opt = OptSpec::named(name).build().unwrap();
            assert!(opt.ckpt_id().starts_with(name), "{}", opt.ckpt_id());
        }
    }

    #[test]
    fn stateless_optimizers_have_empty_state_and_reject_foreign_state() {
        for name in ["addax", "mezo", "zo-sgd", "sgd", "ip-sgd", "hybrid-zofo"] {
            let mut opt = OptSpec::named(name).build().unwrap();
            assert!(opt.state().is_empty(), "{name} must serialize empty");
            opt.load_state(&OptState::default()).unwrap();
            let foreign = OptState { t: 1, tensors: vec![("m0".into(), vec![0.0; 4])] };
            assert!(opt.load_state(&foreign).is_err(), "{name} must refuse Adam state");
        }
        // Adam accepts its own shape back (full round-trip in adam.rs).
        let mut adam = OptSpec::named("adam").build().unwrap();
        assert!(adam.state().is_empty(), "pre-step Adam state is empty");
        let s = OptState {
            t: 2,
            tensors: vec![("m0".into(), vec![1.0; 4]), ("v0".into(), vec![1.0; 4])],
        };
        adam.load_state(&s).unwrap();
        assert_eq!(adam.state(), s);
    }

    #[test]
    fn opt_spec_id_tracks_relevant_fields_only() {
        let a = OptSpec { lr: 0.07, ..OptSpec::named("addax") };
        let b = OptSpec { lr: 0.07, batch: 99, ..OptSpec::named("addax") };
        // addax ignores `batch` (it uses k0/k1), so the ids agree
        assert_eq!(a.id(), b.id());
        let c = OptSpec { k0: 12, ..a.clone() };
        assert_ne!(a.id(), c.id());
        let m = OptSpec { batch: 99, ..OptSpec::named("mezo") };
        assert_ne!(OptSpec::named("mezo").id(), m.id());
        assert!(OptSpec::named("mezo").is_zo_only());
        assert!(!OptSpec::named("addax").is_zo_only());
    }

    #[test]
    fn fmt_f32_is_shortest_roundtrip() {
        for v in [0.07f32, 1e-3, 3e-4, 0.5, 1.0] {
            let s = fmt_f32(v);
            assert_eq!(s.parse::<f32>().unwrap(), v, "{s}");
        }
        assert_eq!(fmt_f32(0.07), "0.07");
    }
}
