//! The in-place parameter store: rust owns the model state (Alg. 1).
//!
//! All optimizer updates happen here, tensor by tensor, with gradients and
//! perturbation noise discarded immediately — the in-place discipline that
//! gives IP-SGD/MeZO/Addax their memory profile (paper §2.3, App. B).
//!
//! The ZO sweeps (`perturb`, `perturb_subset`, `restore_and_zo_update`)
//! are the hottest loops in the system: each touches all `d` parameters.
//! They run over a flat map of [`NOISE_BLOCK`]-element blocks whose noise
//! is counter-addressed (`zorng::block_seed`), so the blocks are
//! distributed across a scoped worker pool and the result is bit-identical
//! at every worker count — including the serial path (see
//! EXPERIMENTS.md §Perf for the scaling numbers).

use std::io::{Read, Write};
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};

use anyhow::{bail, Context, Result};

use crate::tensor::HostTensor;
use crate::zorng::{BlockNoise, NoiseStream, NOISE_BLOCK};

/// Worker-pool override for the noise sweeps; 0 = auto (env, then
/// `min(cores, 8)`). Set from config at run start.
static NOISE_WORKERS: AtomicUsize = AtomicUsize::new(0);

/// Pin the noise-sweep worker count (0 restores auto selection).
pub fn set_noise_workers(n: usize) {
    NOISE_WORKERS.store(n, Ordering::Relaxed);
}

/// `ADDAX_NOISE_WORKERS`, read once (0 = unset/invalid).
fn env_noise_workers() -> usize {
    use std::sync::OnceLock;
    static ENV: OnceLock<usize> = OnceLock::new();
    *ENV.get_or_init(|| {
        std::env::var("ADDAX_NOISE_WORKERS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .unwrap_or(0)
    })
}

/// Effective worker count for the noise sweeps: explicit override (last
/// `set_noise_workers` wins), then `ADDAX_NOISE_WORKERS`, then
/// `min(available cores, 8)`.
pub fn noise_workers() -> usize {
    let n = NOISE_WORKERS.load(Ordering::Relaxed);
    if n > 0 {
        return n;
    }
    let env = env_noise_workers();
    if env > 0 {
        return env;
    }
    std::thread::available_parallelism()
        .map(|c| c.get())
        .unwrap_or(1)
        .min(8)
}

/// One named parameter tensor.
#[derive(Clone, Debug)]
pub struct Param {
    pub name: String,
    pub tensor: HostTensor,
}

/// One unit of sweep work: a [`NOISE_BLOCK`]-element block of one tensor.
/// `(param_idx, block_idx)` is the noise address; the borrow is the
/// destination slice.
struct NoiseBlock<'a> {
    param_idx: usize,
    block_idx: usize,
    data: &'a mut [f32],
}

/// Flatten the included tensors into the block map the workers consume.
fn noise_blocks<'a>(
    params: &'a mut [Param],
    include: &dyn Fn(usize, &str) -> bool,
) -> Vec<NoiseBlock<'a>> {
    let mut blocks = Vec::new();
    for (param_idx, p) in params.iter_mut().enumerate() {
        if !include(param_idx, &p.name) {
            continue;
        }
        for (block_idx, data) in p.tensor.data.chunks_mut(NOISE_BLOCK).enumerate() {
            blocks.push(NoiseBlock { param_idx, block_idx, data });
        }
    }
    blocks
}

/// Minimum blocks per worker before spawning threads pays for itself
/// (thread startup is ~tens of µs; a block sweep is ~µs-scale).
const MIN_BLOCKS_PER_WORKER: usize = 2;

/// Run `op` once per block, on up to `workers` scoped threads (1 = serial,
/// same bits: every block's stream is independent of processing order).
/// Small stores fall back to the serial path — identical results, no
/// thread-spawn overhead.
fn run_block_sweep<Op>(seed: u64, mut blocks: Vec<NoiseBlock<'_>>, workers: usize, op: Op)
where
    Op: Fn(&mut NoiseStream, &mut [f32]) + Sync,
{
    let noise = BlockNoise::new(seed);
    let workers = workers.min(blocks.len() / MIN_BLOCKS_PER_WORKER);
    if workers <= 1 {
        for b in blocks.iter_mut() {
            let mut stream = noise.block_stream(b.param_idx, b.block_idx);
            op(&mut stream, &mut *b.data);
        }
        return;
    }
    let per_worker = blocks.len().div_ceil(workers);
    let op = &op;
    std::thread::scope(|s| {
        for part in blocks.chunks_mut(per_worker) {
            s.spawn(move || {
                for b in part.iter_mut() {
                    let mut stream = noise.block_stream(b.param_idx, b.block_idx);
                    op(&mut stream, &mut *b.data);
                }
            });
        }
    });
}

/// Ordered collection of model parameters.
///
/// The order is the canonical `param_specs` order from
/// `python/compile/model.py`, recorded in the manifest; ZO noise is
/// addressed by `(param_idx, block_idx)` in exactly this order so that
/// perturbation and update replay line up (Alg. 3 iterates layers in a
/// fixed order).
#[derive(Clone, Debug)]
pub struct ParamStore {
    params: Vec<Param>,
    /// Count of full O(d) noise sweeps performed (perturb / subset /
    /// fused restore+update) — the traffic metric the fused ZO step
    /// optimizes (4 → 3 sweeps per step; asserted in tests).
    noise_sweeps: u64,
}

impl ParamStore {
    pub fn new(params: Vec<Param>) -> Self {
        Self { params, noise_sweeps: 0 }
    }

    /// Build zero-initialized params from (name, shape) specs.
    pub fn zeros(specs: &[(String, Vec<usize>)]) -> Self {
        let params = specs
            .iter()
            .map(|(n, s)| Param { name: n.clone(), tensor: HostTensor::zeros(s) })
            .collect();
        Self::new(params)
    }

    /// Load from the AOT dump: concatenated little-endian f32 in spec order.
    pub fn load_bin(specs: &[(String, Vec<usize>)], path: &Path) -> Result<Self> {
        let mut file = std::fs::File::open(path)
            .with_context(|| format!("opening params file {}", path.display()))?;
        let mut params = Vec::with_capacity(specs.len());
        for (name, shape) in specs {
            let n: usize = shape.iter().product();
            let mut bytes = vec![0u8; n * 4];
            file.read_exact(&mut bytes)
                .with_context(|| format!("reading {name} ({n} f32)"))?;
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            params.push(Param { name: name.clone(), tensor: HostTensor::from_vec(shape, data) });
        }
        // The file must be fully consumed — a longer file means the specs
        // and the dump disagree.
        let mut extra = [0u8; 1];
        if file.read(&mut extra)? != 0 {
            bail!("params file {} longer than specs describe", path.display());
        }
        Ok(Self::new(params))
    }

    /// Save in the same binary format (checkpointing).
    pub fn save_bin(&self, path: &Path) -> Result<()> {
        let mut file = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        for p in &self.params {
            let mut bytes = Vec::with_capacity(p.tensor.len() * 4);
            for &v in &p.tensor.data {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
            file.write_all(&bytes)?;
        }
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.params.len()
    }

    pub fn is_empty(&self) -> bool {
        self.params.is_empty()
    }

    /// Total scalar parameter count `d`.
    pub fn n_scalars(&self) -> usize {
        self.params.iter().map(|p| p.tensor.len()).sum()
    }

    /// Full O(d) noise sweeps performed so far (perf accounting).
    pub fn noise_sweeps(&self) -> u64 {
        self.noise_sweeps
    }

    pub fn iter(&self) -> impl Iterator<Item = &Param> {
        self.params.iter()
    }

    pub fn tensors(&self) -> impl Iterator<Item = &HostTensor> {
        self.params.iter().map(|p| &p.tensor)
    }

    pub fn get(&self, idx: usize) -> &Param {
        &self.params[idx]
    }

    pub fn get_mut(&mut self, idx: usize) -> &mut Param {
        &mut self.params[idx]
    }

    pub fn by_name(&self, name: &str) -> Option<&Param> {
        self.params.iter().find(|p| p.name == name)
    }

    /// In-place Gaussian perturbation: `θ_m ← θ_m + scale·z_m` for every
    /// tensor, with `z_m` replayed block-wise from `seed` (Algorithm 3).
    /// Generation is fused with the apply loop — no transient noise buffer
    /// — and the blocks run on the configured worker pool.
    pub fn perturb(&mut self, seed: u64, scale: f32) {
        self.perturb_with_workers(seed, scale, noise_workers());
    }

    /// [`ParamStore::perturb`] with an explicit worker count (1 = serial).
    /// All worker counts produce bit-identical stores: each block's noise
    /// comes from its own counter-addressed stream, independent of which
    /// thread generates it or in what order.
    pub fn perturb_with_workers(&mut self, seed: u64, scale: f32, workers: usize) {
        self.noise_sweeps += 1;
        let blocks = noise_blocks(&mut self.params, &|_, _| true);
        run_block_sweep(seed, blocks, workers, move |stream, data| {
            for v in data.iter_mut() {
                *v += scale * stream.next_normal();
            }
        });
    }

    /// Perturb only the tensors for which `include(idx, name)` is true.
    ///
    /// Under counter addressing the noise for tensor `m` depends only on
    /// `(seed, m)` — not on which other tensors are included — so a
    /// matching `perturb_subset` with the same seed and filter replays the
    /// identical noise (used by the layer-split hybrid ZO-FO baseline of
    /// Zhang et al. [69]), and even agrees with a full `perturb` on the
    /// included tensors.
    pub fn perturb_subset<F: Fn(usize, &str) -> bool>(
        &mut self,
        seed: u64,
        scale: f32,
        include: F,
    ) {
        self.noise_sweeps += 1;
        let blocks = noise_blocks(&mut self.params, &include);
        run_block_sweep(seed, blocks, noise_workers(), move |stream, data| {
            for v in data.iter_mut() {
                *v += scale * stream.next_normal();
            }
        });
    }

    /// The ZO half of the Addax/MeZO update (Alg. 1 lines 13-17):
    /// `θ ← θ − lr·coeff·g⁰·z`, replaying `z` from `seed`.
    ///
    /// Equivalent to `perturb(seed, -lr*coeff*g0)`; kept as a named method
    /// because it is the algorithmically meaningful operation. The fused
    /// [`ParamStore::restore_and_zo_update`] subsumes it on the hot path.
    pub fn zo_update(&mut self, seed: u64, lr: f32, coeff: f32, g0: f32) {
        self.perturb(seed, -lr * coeff * g0);
    }

    /// Fused SPSA-restore + ZO-update sweep: from `θ − εz` (where the
    /// second probe leaves the params), produce `θ − lr·coeff·g⁰·z` in a
    /// single O(d) pass, replaying `z` once.
    ///
    /// Elementwise it computes `(v + ε·z) + (−lr·coeff·g⁰)·z` — two
    /// dependent adds, not one pre-combined scale — so the result is
    /// bit-identical to the unfused `perturb(seed, ε)` followed by
    /// `zo_update(seed, lr, coeff, g0)`, while touching parameter memory
    /// once instead of twice. This cuts the ZO step from 4 O(d) sweeps
    /// (+ε, −2ε, +ε restore, update) to 3 — ~25% of MeZO's dominant cost.
    pub fn restore_and_zo_update(&mut self, seed: u64, eps: f32, lr: f32, coeff: f32, g0: f32) {
        self.restore_and_zo_update_subset(seed, eps, lr, coeff, g0, |_, _| true);
    }

    /// Subset form of [`ParamStore::restore_and_zo_update`] (hybrid ZO-FO:
    /// only the shallow tensors carry ZO noise).
    pub fn restore_and_zo_update_subset<F: Fn(usize, &str) -> bool>(
        &mut self,
        seed: u64,
        eps: f32,
        lr: f32,
        coeff: f32,
        g0: f32,
        include: F,
    ) {
        self.noise_sweeps += 1;
        let delta = -lr * coeff * g0;
        let blocks = noise_blocks(&mut self.params, &include);
        run_block_sweep(seed, blocks, noise_workers(), move |stream, data| {
            for v in data.iter_mut() {
                let z = stream.next_normal();
                *v = (*v + eps * z) + delta * z;
            }
        });
    }

    /// The FO half: `θ_m ← θ_m − lr·coeff·g_m`, one tensor at a time
    /// (the caller drops each gradient right after — in-place SGD).
    pub fn fo_update_tensor(&mut self, idx: usize, lr: f32, coeff: f32, grad: &[f32]) {
        self.params[idx].tensor.axpy(-lr * coeff, grad);
    }

    /// Apply FO updates for all tensors from a gradient list.
    pub fn fo_update_all(&mut self, lr: f32, coeff: f32, grads: &[Vec<f32>]) {
        assert_eq!(grads.len(), self.params.len());
        for (i, g) in grads.iter().enumerate() {
            self.fo_update_tensor(i, lr, coeff, g);
        }
    }

    /// Squared L2 distance to another store (tests, theory experiments).
    pub fn dist_sq(&self, other: &ParamStore) -> f64 {
        self.params
            .iter()
            .zip(other.params.iter())
            .map(|(a, b)| {
                a.tensor
                    .data
                    .iter()
                    .zip(b.tensor.data.iter())
                    .map(|(&x, &y)| ((x - y) as f64).powi(2))
                    .sum::<f64>()
            })
            .sum()
    }

    pub fn all_finite(&self) -> bool {
        self.params.iter().all(|p| p.tensor.all_finite())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<(String, Vec<usize>)> {
        vec![
            ("a".into(), vec![3, 2]),
            ("b".into(), vec![5]),
            ("c".into(), vec![2, 2, 2]),
        ]
    }

    /// Shapes big enough to span several noise blocks per tensor.
    fn big_specs() -> Vec<(String, Vec<usize>)> {
        vec![
            ("w1".into(), vec![NOISE_BLOCK * 2 + 17]),
            ("w2".into(), vec![NOISE_BLOCK - 1]),
            ("w3".into(), vec![3 * NOISE_BLOCK + 5]),
        ]
    }

    #[test]
    fn zeros_and_counts() {
        let s = ParamStore::zeros(&specs());
        assert_eq!(s.len(), 3);
        assert_eq!(s.n_scalars(), 6 + 5 + 8);
    }

    #[test]
    fn perturb_roundtrip_restores_exactly_like_algorithm2() {
        // θ +ε z, then −2ε z, then +ε z must return exactly to θ when the
        // same seed replays the same z (floating error cancels exactly
        // because the identical z values are added/subtracted).
        let mut s = ParamStore::zeros(&specs());
        s.perturb(123, 0.5); // give θ nonzero values
        let before = s.clone();
        let seed = 777;
        let eps = 1e-3f32;
        s.perturb(seed, eps);
        s.perturb(seed, -2.0 * eps);
        s.perturb(seed, eps);
        for (a, b) in s.iter().zip(before.iter()) {
            for (x, y) in a.tensor.data.iter().zip(b.tensor.data.iter()) {
                assert!((x - y).abs() <= 1e-6, "{} vs {}", x, y);
            }
        }
    }

    #[test]
    fn zo_update_matches_manual_replay() {
        let mut s = ParamStore::zeros(&specs());
        let seed = 99;
        s.zo_update(seed, 0.1, 0.5, 2.0);
        // manual: θ = -0.1*0.5*2.0 * z, with z replayed block-wise
        let noise = BlockNoise::new(seed);
        for (pi, p) in s.iter().enumerate() {
            let mut z = vec![0.0f32; p.tensor.len()];
            noise.fill_param(pi, &mut z);
            for (&v, &zi) in p.tensor.data.iter().zip(z.iter()) {
                assert!((v - (-0.1 * 0.5 * 2.0 * zi)).abs() < 1e-7);
            }
        }
    }

    #[test]
    fn parallel_perturb_bit_identical_at_every_worker_count() {
        let mut serial = ParamStore::zeros(&big_specs());
        serial.perturb_with_workers(5, 0.7, 1);
        for workers in [2, 3, 4, 8, 16] {
            let mut par = ParamStore::zeros(&big_specs());
            par.perturb_with_workers(5, 0.7, workers);
            for (a, b) in par.iter().zip(serial.iter()) {
                assert_eq!(a.tensor.data, b.tensor.data, "workers={workers}");
            }
        }
    }

    #[test]
    fn fused_restore_update_matches_two_pass_exactly() {
        let (seed, eps, lr, coeff, g0) = (21u64, 1e-3f32, 0.07f32, 0.4f32, 1.7f32);
        let mut fused = ParamStore::zeros(&big_specs());
        fused.perturb(3, 1.0);
        let mut two_pass = fused.clone();
        // both start from θ − εz, as after the second SPSA probe
        fused.perturb(seed, eps);
        fused.perturb(seed, -2.0 * eps);
        two_pass.perturb(seed, eps);
        two_pass.perturb(seed, -2.0 * eps);

        fused.restore_and_zo_update(seed, eps, lr, coeff, g0);
        two_pass.perturb(seed, eps);
        two_pass.zo_update(seed, lr, coeff, g0);
        for (a, b) in fused.iter().zip(two_pass.iter()) {
            assert_eq!(a.tensor.data, b.tensor.data);
        }
    }

    #[test]
    fn subset_noise_agrees_with_full_perturb() {
        // Counter addressing: tensor m's noise is independent of the
        // filter, so a subset perturb equals the full perturb on the
        // included tensors.
        let mut full = ParamStore::zeros(&big_specs());
        full.perturb(9, 0.3);
        let mut sub = ParamStore::zeros(&big_specs());
        sub.perturb_subset(9, 0.3, |idx, _| idx != 1);
        assert_eq!(sub.get(0).tensor.data, full.get(0).tensor.data);
        assert!(sub.get(1).tensor.data.iter().all(|&v| v == 0.0));
        assert_eq!(sub.get(2).tensor.data, full.get(2).tensor.data);
    }

    #[test]
    fn noise_sweep_counter_counts_full_passes() {
        let mut s = ParamStore::zeros(&specs());
        assert_eq!(s.noise_sweeps(), 0);
        s.perturb(1, 0.1);
        s.perturb_subset(1, 0.1, |i, _| i == 0);
        s.restore_and_zo_update(1, 0.1, 0.01, 1.0, 0.5);
        assert_eq!(s.noise_sweeps(), 3);
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("addax_test_params");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("p.bin");
        let mut s = ParamStore::zeros(&specs());
        s.perturb(5, 1.0);
        s.save_bin(&path).unwrap();
        let loaded = ParamStore::load_bin(&specs(), &path).unwrap();
        assert!(s.dist_sq(&loaded) == 0.0);
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn load_rejects_wrong_size() {
        let dir = std::env::temp_dir().join("addax_test_params2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, vec![0u8; 10]).unwrap();
        assert!(ParamStore::load_bin(&specs(), &path).is_err());
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn fo_update_applies_per_tensor() {
        let mut s = ParamStore::zeros(&specs());
        let grads: Vec<Vec<f32>> = s.iter().map(|p| vec![1.0; p.tensor.len()]).collect();
        s.fo_update_all(0.1, 0.5, &grads);
        for p in s.iter() {
            for &v in &p.tensor.data {
                assert!((v + 0.05).abs() < 1e-7);
            }
        }
    }
}
