//! The in-place parameter store: rust owns the model state (Alg. 1).
//!
//! All optimizer updates happen here, tensor by tensor, with gradients and
//! perturbation noise discarded immediately — the in-place discipline that
//! gives IP-SGD/MeZO/Addax their memory profile (paper §2.3, App. B).

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::HostTensor;
use crate::zorng::NoiseStream;

/// One named parameter tensor.
#[derive(Clone, Debug)]
pub struct Param {
    pub name: String,
    pub tensor: HostTensor,
}

/// Ordered collection of model parameters.
///
/// The order is the canonical `param_specs` order from
/// `python/compile/model.py`, recorded in the manifest; the ZO noise
/// stream is consumed in exactly this order so that perturbation and
/// update replay line up (Alg. 3 iterates layers in a fixed order).
#[derive(Clone, Debug)]
pub struct ParamStore {
    params: Vec<Param>,
}

impl ParamStore {
    pub fn new(params: Vec<Param>) -> Self {
        Self { params }
    }

    /// Build zero-initialized params from (name, shape) specs.
    pub fn zeros(specs: &[(String, Vec<usize>)]) -> Self {
        let params = specs
            .iter()
            .map(|(n, s)| Param { name: n.clone(), tensor: HostTensor::zeros(s) })
            .collect();
        Self { params }
    }

    /// Load from the AOT dump: concatenated little-endian f32 in spec order.
    pub fn load_bin(specs: &[(String, Vec<usize>)], path: &Path) -> Result<Self> {
        let mut file = std::fs::File::open(path)
            .with_context(|| format!("opening params file {}", path.display()))?;
        let mut params = Vec::with_capacity(specs.len());
        for (name, shape) in specs {
            let n: usize = shape.iter().product();
            let mut bytes = vec![0u8; n * 4];
            file.read_exact(&mut bytes)
                .with_context(|| format!("reading {name} ({n} f32)"))?;
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            params.push(Param { name: name.clone(), tensor: HostTensor::from_vec(shape, data) });
        }
        // The file must be fully consumed — a longer file means the specs
        // and the dump disagree.
        let mut extra = [0u8; 1];
        if file.read(&mut extra)? != 0 {
            bail!("params file {} longer than specs describe", path.display());
        }
        Ok(Self { params })
    }

    /// Save in the same binary format (checkpointing).
    pub fn save_bin(&self, path: &Path) -> Result<()> {
        let mut file = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        for p in &self.params {
            let mut bytes = Vec::with_capacity(p.tensor.len() * 4);
            for &v in &p.tensor.data {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
            file.write_all(&bytes)?;
        }
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.params.len()
    }

    pub fn is_empty(&self) -> bool {
        self.params.is_empty()
    }

    /// Total scalar parameter count `d`.
    pub fn n_scalars(&self) -> usize {
        self.params.iter().map(|p| p.tensor.len()).sum()
    }

    pub fn iter(&self) -> impl Iterator<Item = &Param> {
        self.params.iter()
    }

    pub fn tensors(&self) -> impl Iterator<Item = &HostTensor> {
        self.params.iter().map(|p| &p.tensor)
    }

    pub fn get(&self, idx: usize) -> &Param {
        &self.params[idx]
    }

    pub fn get_mut(&mut self, idx: usize) -> &mut Param {
        &mut self.params[idx]
    }

    pub fn by_name(&self, name: &str) -> Option<&Param> {
        self.params.iter().find(|p| p.name == name)
    }

    /// In-place Gaussian perturbation: `θ_m ← θ_m + scale·z_m` for every
    /// tensor, with `z` replayed from `seed` (Algorithm 3). Generation is
    /// fused with the apply loop — no transient noise buffer at all.
    pub fn perturb(&mut self, seed: u64, scale: f32) {
        let mut stream = NoiseStream::new(seed);
        for p in self.params.iter_mut() {
            // fused generate+apply: one pass over the data (§Perf)
            for v in p.tensor.data.iter_mut() {
                *v += scale * stream.next_normal();
            }
        }
    }

    /// Perturb only the tensors for which `include(idx, name)` is true.
    ///
    /// The noise stream is consumed **only** for included tensors, so a
    /// matching `perturb_subset` with the same seed and filter replays the
    /// identical noise (used by the layer-split hybrid ZO-FO baseline of
    /// Zhang et al. [69]).
    pub fn perturb_subset<F: Fn(usize, &str) -> bool>(
        &mut self,
        seed: u64,
        scale: f32,
        include: F,
    ) {
        let mut stream = NoiseStream::new(seed);
        let mut chunk = [0.0f32; 4096];
        for (idx, p) in self.params.iter_mut().enumerate() {
            if !include(idx, &p.name) {
                continue;
            }
            let data = &mut p.tensor.data;
            let mut off = 0;
            while off < data.len() {
                let n = (data.len() - off).min(chunk.len());
                stream.fill_normal(&mut chunk[..n]);
                for i in 0..n {
                    data[off + i] += scale * chunk[i];
                }
                off += n;
            }
        }
    }

    /// The ZO half of the Addax/MeZO update (Alg. 1 lines 13-17):
    /// `θ ← θ − lr·coeff·g⁰·z`, replaying `z` from `seed`.
    ///
    /// Equivalent to `perturb(seed, -lr*coeff*g0)`; kept as a named method
    /// because it is the algorithmically meaningful operation.
    pub fn zo_update(&mut self, seed: u64, lr: f32, coeff: f32, g0: f32) {
        self.perturb(seed, -lr * coeff * g0);
    }

    /// The FO half: `θ_m ← θ_m − lr·coeff·g_m`, one tensor at a time
    /// (the caller drops each gradient right after — in-place SGD).
    pub fn fo_update_tensor(&mut self, idx: usize, lr: f32, coeff: f32, grad: &[f32]) {
        self.params[idx].tensor.axpy(-lr * coeff, grad);
    }

    /// Apply FO updates for all tensors from a gradient list.
    pub fn fo_update_all(&mut self, lr: f32, coeff: f32, grads: &[Vec<f32>]) {
        assert_eq!(grads.len(), self.params.len());
        for (i, g) in grads.iter().enumerate() {
            self.fo_update_tensor(i, lr, coeff, g);
        }
    }

    /// Squared L2 distance to another store (tests, theory experiments).
    pub fn dist_sq(&self, other: &ParamStore) -> f64 {
        self.params
            .iter()
            .zip(other.params.iter())
            .map(|(a, b)| {
                a.tensor
                    .data
                    .iter()
                    .zip(b.tensor.data.iter())
                    .map(|(&x, &y)| ((x - y) as f64).powi(2))
                    .sum::<f64>()
            })
            .sum()
    }

    pub fn all_finite(&self) -> bool {
        self.params.iter().all(|p| p.tensor.all_finite())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<(String, Vec<usize>)> {
        vec![
            ("a".into(), vec![3, 2]),
            ("b".into(), vec![5]),
            ("c".into(), vec![2, 2, 2]),
        ]
    }

    #[test]
    fn zeros_and_counts() {
        let s = ParamStore::zeros(&specs());
        assert_eq!(s.len(), 3);
        assert_eq!(s.n_scalars(), 6 + 5 + 8);
    }

    #[test]
    fn perturb_roundtrip_restores_exactly_like_algorithm2() {
        // θ +ε z, then −2ε z, then +ε z must return exactly to θ when the
        // same seed replays the same z (floating error cancels exactly
        // because the identical z values are added/subtracted).
        let mut s = ParamStore::zeros(&specs());
        s.perturb(123, 0.5); // give θ nonzero values
        let before = s.clone();
        let seed = 777;
        let eps = 1e-3f32;
        s.perturb(seed, eps);
        s.perturb(seed, -2.0 * eps);
        s.perturb(seed, eps);
        for (a, b) in s.iter().zip(before.iter()) {
            for (x, y) in a.tensor.data.iter().zip(b.tensor.data.iter()) {
                assert!((x - y).abs() <= 1e-6, "{} vs {}", x, y);
            }
        }
    }

    #[test]
    fn zo_update_matches_manual_replay() {
        let mut s = ParamStore::zeros(&specs());
        let seed = 99;
        s.zo_update(seed, 0.1, 0.5, 2.0);
        // manual: θ = -0.1*0.5*2.0 * z
        let mut stream = NoiseStream::new(seed);
        for p in s.iter() {
            for &v in &p.tensor.data {
                let z = stream.next_normal();
                assert!((v - (-0.1 * 0.5 * 2.0 * z)).abs() < 1e-7);
            }
        }
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("addax_test_params");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("p.bin");
        let mut s = ParamStore::zeros(&specs());
        s.perturb(5, 1.0);
        s.save_bin(&path).unwrap();
        let loaded = ParamStore::load_bin(&specs(), &path).unwrap();
        assert!(s.dist_sq(&loaded) == 0.0);
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn load_rejects_wrong_size() {
        let dir = std::env::temp_dir().join("addax_test_params2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, vec![0u8; 10]).unwrap();
        assert!(ParamStore::load_bin(&specs(), &path).is_err());
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn fo_update_applies_per_tensor() {
        let mut s = ParamStore::zeros(&specs());
        let grads: Vec<Vec<f32>> = s.iter().map(|p| vec![1.0; p.tensor.len()]).collect();
        s.fo_update_all(0.1, 0.5, &grads);
        for p in s.iter() {
            for &v in &p.tensor.data {
                assert!((v + 0.05).abs() < 1e-7);
            }
        }
    }
}
