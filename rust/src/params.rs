//! The in-place parameter store: rust owns the model state (Alg. 1).
//!
//! All optimizer updates happen here, tensor by tensor, with gradients and
//! perturbation noise discarded immediately — the in-place discipline that
//! gives IP-SGD/MeZO/Addax their memory profile (paper §2.3, App. B).
//!
//! The store is precision-polymorphic: every tensor holds either `f32` or
//! `bf16` elements ([`Dtype`], uniform across the store), while all sweep
//! math runs in f32 and rounds nearest-even on write (`tensor::Element`).
//! The ZO sweeps (`perturb`, `perturb_subset`, `restore_and_zo_update`)
//! are the hottest loops in the system: each touches all `d` parameters,
//! so bf16 storage halves the bytes they move (EXPERIMENTS.md §Precision).
//! They run over a flat map of [`NOISE_BLOCK`]-element blocks whose noise
//! is counter-addressed (`zorng::block_seed`), so the blocks are
//! distributed across a scoped worker pool and the result is bit-identical
//! at every worker count — in both precisions, because each element is
//! decoded, updated and re-encoded independently of every other (see
//! EXPERIMENTS.md §Perf for the scaling numbers).
//!
//! The sweep worker count is **per store** (`set_noise_workers`), not a
//! process global: concurrent runs on one process (the sweep scheduler)
//! each pin their own store without racing.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::{Bf16, Dtype, Element, HostTensor};
use crate::zorng::{BlockNoise, NoiseStream, NOISE_BLOCK};

/// `ADDAX_NOISE_WORKERS`, read once (0 = unset/invalid).
fn env_noise_workers() -> usize {
    use std::sync::OnceLock;
    static ENV: OnceLock<usize> = OnceLock::new();
    *ENV.get_or_init(|| {
        std::env::var("ADDAX_NOISE_WORKERS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .unwrap_or(0)
    })
}

/// Auto worker count: `ADDAX_NOISE_WORKERS`, then `min(cores, 8)`.
fn auto_noise_workers() -> usize {
    let env = env_noise_workers();
    if env > 0 {
        return env;
    }
    std::thread::available_parallelism()
        .map(|c| c.get())
        .unwrap_or(1)
        .min(8)
}

/// One named parameter tensor.
#[derive(Clone, Debug)]
pub struct Param {
    pub name: String,
    pub tensor: HostTensor,
}

/// One unit of sweep work: a [`NOISE_BLOCK`]-element block of one tensor.
/// `(param_idx, block_idx)` is the noise address; the borrow is the
/// destination slice in the store's native element type.
struct NoiseBlock<'a, E> {
    param_idx: usize,
    block_idx: usize,
    data: &'a mut [E],
}

/// Flatten the included tensors into the block map the workers consume.
fn noise_blocks<'a, E: Element>(
    params: &'a mut [Param],
    include: &dyn Fn(usize, &str) -> bool,
) -> Vec<NoiseBlock<'a, E>> {
    let mut blocks = Vec::new();
    for (param_idx, p) in params.iter_mut().enumerate() {
        if !include(param_idx, &p.name) {
            continue;
        }
        let slice = E::slice_mut(p.tensor.raw_mut());
        for (block_idx, data) in slice.chunks_mut(NOISE_BLOCK).enumerate() {
            blocks.push(NoiseBlock { param_idx, block_idx, data });
        }
    }
    blocks
}

/// Minimum blocks per worker before spawning threads pays for itself
/// (thread startup is ~tens of µs; a block sweep is ~µs-scale).
const MIN_BLOCKS_PER_WORKER: usize = 2;

/// Run `op` once per block, on up to `workers` scoped threads (1 = serial,
/// same bits: every block's stream is independent of processing order).
/// Small stores fall back to the serial path — identical results, no
/// thread-spawn overhead.
fn run_block_sweep<E, Op>(seed: u64, mut blocks: Vec<NoiseBlock<'_, E>>, workers: usize, op: Op)
where
    E: Element,
    Op: Fn(&mut NoiseStream, &mut [E]) + Sync,
{
    let noise = BlockNoise::new(seed);
    let workers = workers.min(blocks.len() / MIN_BLOCKS_PER_WORKER);
    if workers <= 1 {
        for b in blocks.iter_mut() {
            let mut stream = noise.block_stream(b.param_idx, b.block_idx);
            op(&mut stream, &mut *b.data);
        }
        return;
    }
    let per_worker = blocks.len().div_ceil(workers);
    let op = &op;
    std::thread::scope(|s| {
        for part in blocks.chunks_mut(per_worker) {
            s.spawn(move || {
                for b in part.iter_mut() {
                    let mut stream = noise.block_stream(b.param_idx, b.block_idx);
                    op(&mut stream, &mut *b.data);
                }
            });
        }
    });
}

/// Build the block map for `E` and apply `g(value, z)` elementwise:
/// decode → f32 math → encode. Per-element independence is what keeps
/// every worker count (and both precisions) bit-identical.
fn sweep_elements<E, G>(
    params: &mut [Param],
    seed: u64,
    workers: usize,
    include: &dyn Fn(usize, &str) -> bool,
    g: &G,
) where
    E: Element,
    G: Fn(f32, f32) -> f32 + Sync,
{
    let blocks = noise_blocks::<E>(params, include);
    run_block_sweep(seed, blocks, workers, move |stream, data: &mut [E]| {
        for v in data.iter_mut() {
            let z = stream.next_normal();
            *v = E::encode(g(v.decode(), z));
        }
    });
}

/// Ordered collection of model parameters.
///
/// The order is the canonical `param_specs` order from
/// `python/compile/model.py`, recorded in the manifest; ZO noise is
/// addressed by `(param_idx, block_idx)` in exactly this order so that
/// perturbation and update replay line up (Alg. 3 iterates layers in a
/// fixed order). All tensors share one [`Dtype`].
#[derive(Clone, Debug)]
pub struct ParamStore {
    params: Vec<Param>,
    /// Count of full O(d) noise sweeps performed (perturb / subset /
    /// fused restore+update) — the traffic metric the fused ZO step
    /// optimizes (4 → 3 sweeps per step; asserted in tests).
    noise_sweeps: u64,
    /// Uniform storage precision of every tensor.
    dtype: Dtype,
    /// Per-store worker override for the noise sweeps; 0 = auto
    /// (`ADDAX_NOISE_WORKERS`, then `min(cores, 8)`). Stored here — not
    /// in a process global — so concurrent runs cannot stomp each other.
    noise_workers: usize,
}

impl ParamStore {
    pub fn new(params: Vec<Param>) -> Self {
        let dtype = params.first().map(|p| p.tensor.dtype()).unwrap_or_default();
        for p in &params {
            assert_eq!(p.tensor.dtype(), dtype, "mixed-dtype store ({})", p.name);
        }
        Self { params, noise_sweeps: 0, dtype, noise_workers: 0 }
    }

    /// Build zero-initialized f32 params from (name, shape) specs.
    pub fn zeros(specs: &[(String, Vec<usize>)]) -> Self {
        Self::zeros_in(specs, Dtype::F32)
    }

    /// Build zero-initialized params stored at `dtype`.
    pub fn zeros_in(specs: &[(String, Vec<usize>)], dtype: Dtype) -> Self {
        let params = specs
            .iter()
            .map(|(n, s)| Param { name: n.clone(), tensor: HostTensor::zeros_in(s, dtype) })
            .collect();
        Self::new(params)
    }

    /// Storage precision of every tensor in the store.
    pub fn dtype(&self) -> Dtype {
        self.dtype
    }

    /// Re-encode the whole store at `dtype` (f32→bf16 rounds nearest-even;
    /// bf16→f32 is exact). A no-op when the dtype already matches.
    pub fn to_dtype(mut self, dtype: Dtype) -> Self {
        if self.dtype != dtype {
            for p in &mut self.params {
                p.tensor = p.tensor.to_dtype(dtype);
            }
            self.dtype = dtype;
        }
        self
    }

    /// Pin the sweep worker count for this store (0 restores auto).
    pub fn set_noise_workers(&mut self, n: usize) {
        self.noise_workers = n;
    }

    /// Effective worker count for the noise sweeps: this store's pin,
    /// then `ADDAX_NOISE_WORKERS`, then `min(available cores, 8)`.
    pub fn noise_workers(&self) -> usize {
        if self.noise_workers > 0 {
            self.noise_workers
        } else {
            auto_noise_workers()
        }
    }

    /// Load from an AOT/checkpoint dump: concatenated little-endian f32
    /// in spec order (the aot.py format).
    pub fn load_bin(specs: &[(String, Vec<usize>)], path: &Path) -> Result<Self> {
        Self::load_bin_in(specs, path, Dtype::F32)
    }

    /// Load a dump whose elements are stored at `dtype` (f32: 4 bytes
    /// little-endian, bf16: 2). Pairs with [`ParamStore::save_bin`],
    /// which writes the store's native precision.
    pub fn load_bin_in(
        specs: &[(String, Vec<usize>)],
        path: &Path,
        dtype: Dtype,
    ) -> Result<Self> {
        match dtype {
            Dtype::F32 => load_bin_typed::<f32>(specs, path),
            Dtype::Bf16 => load_bin_typed::<Bf16>(specs, path),
        }
    }

    /// Save in the binary dump format at the store's native precision
    /// (checkpointing; an f32 store writes the exact legacy format).
    pub fn save_bin(&self, path: &Path) -> Result<()> {
        let mut file = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        for p in &self.params {
            let mut bytes = Vec::with_capacity(p.tensor.len() * self.dtype.bytes());
            p.tensor.encode_le_into(&mut bytes);
            file.write_all(&bytes)?;
        }
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.params.len()
    }

    pub fn is_empty(&self) -> bool {
        self.params.is_empty()
    }

    /// Total scalar parameter count `d`.
    pub fn n_scalars(&self) -> usize {
        self.params.iter().map(|p| p.tensor.len()).sum()
    }

    /// Bytes of parameter storage actually held (dtype-dependent).
    pub fn storage_bytes(&self) -> usize {
        self.n_scalars() * self.dtype.bytes()
    }

    /// Full O(d) noise sweeps performed so far (perf accounting).
    pub fn noise_sweeps(&self) -> u64 {
        self.noise_sweeps
    }

    pub fn iter(&self) -> impl Iterator<Item = &Param> {
        self.params.iter()
    }

    pub fn tensors(&self) -> impl Iterator<Item = &HostTensor> {
        self.params.iter().map(|p| &p.tensor)
    }

    pub fn get(&self, idx: usize) -> &Param {
        &self.params[idx]
    }

    pub fn get_mut(&mut self, idx: usize) -> &mut Param {
        &mut self.params[idx]
    }

    pub fn by_name(&self, name: &str) -> Option<&Param> {
        self.params.iter().find(|p| p.name == name)
    }

    /// Dtype-dispatched counter-addressed sweep: apply `g(value, z)` to
    /// every included element, with `z` replayed block-wise from `seed`.
    fn noise_sweep<G>(
        &mut self,
        seed: u64,
        workers: usize,
        include: &dyn Fn(usize, &str) -> bool,
        g: G,
    ) where
        G: Fn(f32, f32) -> f32 + Sync,
    {
        self.noise_sweeps += 1;
        match self.dtype {
            Dtype::F32 => sweep_elements::<f32, G>(&mut self.params, seed, workers, include, &g),
            Dtype::Bf16 => sweep_elements::<Bf16, G>(&mut self.params, seed, workers, include, &g),
        }
    }

    /// In-place Gaussian perturbation: `θ_m ← θ_m + scale·z_m` for every
    /// tensor, with `z_m` replayed block-wise from `seed` (Algorithm 3).
    /// Generation is fused with the apply loop — no transient noise buffer
    /// — and the blocks run on this store's worker pool.
    pub fn perturb(&mut self, seed: u64, scale: f32) {
        self.perturb_with_workers(seed, scale, self.noise_workers());
    }

    /// [`ParamStore::perturb`] with an explicit worker count (1 = serial).
    /// All worker counts produce bit-identical stores: each block's noise
    /// comes from its own counter-addressed stream, independent of which
    /// thread generates it or in what order — and each element's
    /// decode/encode depends on nothing but that element.
    pub fn perturb_with_workers(&mut self, seed: u64, scale: f32, workers: usize) {
        self.noise_sweep(seed, workers, &|_, _| true, move |v, z| v + scale * z);
    }

    /// Perturb only the tensors for which `include(idx, name)` is true.
    ///
    /// Under counter addressing the noise for tensor `m` depends only on
    /// `(seed, m)` — not on which other tensors are included — so a
    /// matching `perturb_subset` with the same seed and filter replays the
    /// identical noise (used by the layer-split hybrid ZO-FO baseline of
    /// Zhang et al. [69]), and even agrees with a full `perturb` on the
    /// included tensors.
    pub fn perturb_subset<F: Fn(usize, &str) -> bool>(
        &mut self,
        seed: u64,
        scale: f32,
        include: F,
    ) {
        let workers = self.noise_workers();
        self.noise_sweep(seed, workers, &include, move |v, z| v + scale * z);
    }

    /// The ZO half of the Addax/MeZO update (Alg. 1 lines 13-17):
    /// `θ ← θ − lr·coeff·g⁰·z`, replaying `z` from `seed`.
    ///
    /// Equivalent to `perturb(seed, -lr*coeff*g0)`; kept as a named method
    /// because it is the algorithmically meaningful operation. The fused
    /// [`ParamStore::restore_and_zo_update`] subsumes it on the hot path.
    pub fn zo_update(&mut self, seed: u64, lr: f32, coeff: f32, g0: f32) {
        self.perturb(seed, -lr * coeff * g0);
    }

    /// Fused SPSA-restore + ZO-update sweep: from `θ − εz` (where the
    /// second probe leaves the params), produce `θ − lr·coeff·g⁰·z` in a
    /// single O(d) pass, replaying `z` once.
    ///
    /// Elementwise it computes `(v + ε·z) + (−lr·coeff·g⁰)·z` — two
    /// dependent adds, not one pre-combined scale — so on an f32 store the
    /// result is bit-identical to the unfused `perturb(seed, ε)` followed
    /// by `zo_update(seed, lr, coeff, g0)`, while touching parameter
    /// memory once instead of twice. This cuts the ZO step from 4 O(d)
    /// sweeps (+ε, −2ε, +ε restore, update) to 3 — ~25% of MeZO's
    /// dominant cost. On a bf16 store the fused form additionally rounds
    /// **once** instead of twice, so it is the *defining* semantics of
    /// the half-precision ZO step (EXPERIMENTS.md §Precision).
    pub fn restore_and_zo_update(&mut self, seed: u64, eps: f32, lr: f32, coeff: f32, g0: f32) {
        self.restore_and_zo_update_subset(seed, eps, lr, coeff, g0, |_, _| true);
    }

    /// Subset form of [`ParamStore::restore_and_zo_update`] (hybrid ZO-FO:
    /// only the shallow tensors carry ZO noise).
    pub fn restore_and_zo_update_subset<F: Fn(usize, &str) -> bool>(
        &mut self,
        seed: u64,
        eps: f32,
        lr: f32,
        coeff: f32,
        g0: f32,
        include: F,
    ) {
        let delta = -lr * coeff * g0;
        let workers = self.noise_workers();
        self.noise_sweep(seed, workers, &include, move |v, z| (v + eps * z) + delta * z);
    }

    /// The FO half: `θ_m ← θ_m − lr·coeff·g_m`, one tensor at a time
    /// (the caller drops each gradient right after — in-place SGD).
    pub fn fo_update_tensor(&mut self, idx: usize, lr: f32, coeff: f32, grad: &[f32]) {
        self.params[idx].tensor.axpy(-lr * coeff, grad);
    }

    /// Apply FO updates for all tensors from a gradient list.
    pub fn fo_update_all(&mut self, lr: f32, coeff: f32, grads: &[Vec<f32>]) {
        assert_eq!(grads.len(), self.params.len());
        for (i, g) in grads.iter().enumerate() {
            self.fo_update_tensor(i, lr, coeff, g);
        }
    }

    /// Squared L2 distance to another store (tests, theory experiments).
    /// Values compare in f32, so stores of different dtypes are
    /// commensurable (bf16 widens exactly).
    pub fn dist_sq(&self, other: &ParamStore) -> f64 {
        self.params
            .iter()
            .zip(other.params.iter())
            .map(|(a, b)| {
                a.tensor
                    .iter_f32()
                    .zip(b.tensor.iter_f32())
                    .map(|(x, y)| ((x - y) as f64).powi(2))
                    .sum::<f64>()
            })
            .sum()
    }

    pub fn all_finite(&self) -> bool {
        self.params.iter().all(|p| p.tensor.all_finite())
    }
}

fn load_bin_typed<E: Element>(specs: &[(String, Vec<usize>)], path: &Path) -> Result<ParamStore> {
    let mut file = std::fs::File::open(path)
        .with_context(|| format!("opening params file {}", path.display()))?;
    let mut params = Vec::with_capacity(specs.len());
    for (name, shape) in specs {
        let n: usize = shape.iter().product();
        let mut bytes = vec![0u8; n * E::BYTES];
        file.read_exact(&mut bytes).with_context(|| {
            format!("reading {name} ({n} x {} byte {})", E::BYTES, E::DTYPE.label())
        })?;
        let data: Vec<E> = bytes.chunks_exact(E::BYTES).map(E::read_le).collect();
        params.push(Param { name: name.clone(), tensor: HostTensor::from_elems(shape, data) });
    }
    // The file must be fully consumed — a longer file means the specs
    // and the dump disagree.
    let mut extra = [0u8; 1];
    if file.read(&mut extra)? != 0 {
        bail!("params file {} longer than specs describe", path.display());
    }
    Ok(ParamStore::new(params))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<(String, Vec<usize>)> {
        vec![
            ("a".into(), vec![3, 2]),
            ("b".into(), vec![5]),
            ("c".into(), vec![2, 2, 2]),
        ]
    }

    /// Shapes big enough to span several noise blocks per tensor.
    fn big_specs() -> Vec<(String, Vec<usize>)> {
        vec![
            ("w1".into(), vec![NOISE_BLOCK * 2 + 17]),
            ("w2".into(), vec![NOISE_BLOCK - 1]),
            ("w3".into(), vec![3 * NOISE_BLOCK + 5]),
        ]
    }

    #[test]
    fn zeros_and_counts() {
        let s = ParamStore::zeros(&specs());
        assert_eq!(s.len(), 3);
        assert_eq!(s.n_scalars(), 6 + 5 + 8);
        assert_eq!(s.dtype(), Dtype::F32);
        assert_eq!(s.storage_bytes(), 19 * 4);
        let b = ParamStore::zeros_in(&specs(), Dtype::Bf16);
        assert_eq!(b.dtype(), Dtype::Bf16);
        assert_eq!(b.storage_bytes(), 19 * 2);
    }

    #[test]
    #[should_panic(expected = "mixed-dtype store")]
    fn mixed_dtype_store_is_rejected() {
        ParamStore::new(vec![
            Param { name: "a".into(), tensor: HostTensor::zeros(&[2]) },
            Param { name: "b".into(), tensor: HostTensor::zeros_in(&[2], Dtype::Bf16) },
        ]);
    }

    #[test]
    fn perturb_roundtrip_restores_exactly_like_algorithm2() {
        // θ +ε z, then −2ε z, then +ε z must return exactly to θ when the
        // same seed replays the same z (floating error cancels exactly
        // because the identical z values are added/subtracted).
        let mut s = ParamStore::zeros(&specs());
        s.perturb(123, 0.5); // give θ nonzero values
        let before = s.clone();
        let seed = 777;
        let eps = 1e-3f32;
        s.perturb(seed, eps);
        s.perturb(seed, -2.0 * eps);
        s.perturb(seed, eps);
        for (a, b) in s.iter().zip(before.iter()) {
            for (x, y) in a.tensor.iter_f32().zip(b.tensor.iter_f32()) {
                assert!((x - y).abs() <= 1e-6, "{} vs {}", x, y);
            }
        }
    }

    #[test]
    fn bf16_probe_roundtrip_drift_is_quantization_bounded() {
        // On a bf16 store every sweep re-rounds, so +ε, −2ε, +ε is NOT
        // exact — the drift must stay within a few ulps of the stored
        // magnitudes (|θ| ≲ 2 here ⇒ ulp ≤ 2^-7; three roundings ⇒
        // well under 0.05 per element). Use an ε above the quantization
        // step so the probes actually move the stored values.
        let mut s = ParamStore::zeros_in(&big_specs(), Dtype::Bf16);
        s.perturb(123, 0.5);
        let before = s.clone();
        let seed = 777;
        let eps = 1e-2f32;
        s.perturb(seed, eps);
        s.perturb(seed, -2.0 * eps);
        s.perturb(seed, eps);
        for (a, b) in s.iter().zip(before.iter()) {
            for (x, y) in a.tensor.iter_f32().zip(b.tensor.iter_f32()) {
                assert!((x - y).abs() <= 0.05, "bf16 roundtrip drift {} vs {}", x, y);
            }
        }
    }

    #[test]
    fn zo_update_matches_manual_replay() {
        let mut s = ParamStore::zeros(&specs());
        let seed = 99;
        s.zo_update(seed, 0.1, 0.5, 2.0);
        // manual: θ = -0.1*0.5*2.0 * z, with z replayed block-wise
        let noise = BlockNoise::new(seed);
        for (pi, p) in s.iter().enumerate() {
            let mut z = vec![0.0f32; p.tensor.len()];
            noise.fill_param(pi, &mut z);
            for (v, &zi) in p.tensor.iter_f32().zip(z.iter()) {
                assert!((v - (-0.1 * 0.5 * 2.0 * zi)).abs() < 1e-7);
            }
        }
    }

    #[test]
    fn bf16_perturb_is_the_rounded_f32_sweep() {
        // The bf16 sweep is defined as encode(decode(v) + scale·z): check
        // it against the replayed z and explicit Bf16 rounding.
        let mut s = ParamStore::zeros_in(&big_specs(), Dtype::Bf16);
        s.perturb(7, 0.5);
        let reference = s.clone();
        let (seed, scale) = (41u64, 0.3f32);
        s.perturb(seed, scale);
        let noise = BlockNoise::new(seed);
        for (pi, (p, r)) in s.iter().zip(reference.iter()).enumerate() {
            let mut z = vec![0.0f32; p.tensor.len()];
            noise.fill_param(pi, &mut z);
            for ((got, prev), &zi) in
                p.tensor.iter_f32().zip(r.tensor.iter_f32()).zip(z.iter())
            {
                let want = crate::tensor::Bf16::from_f32(prev + scale * zi).to_f32();
                assert_eq!(got, want, "param {pi}");
            }
        }
    }

    #[test]
    fn parallel_perturb_bit_identical_at_every_worker_count() {
        for dtype in [Dtype::F32, Dtype::Bf16] {
            let mut serial = ParamStore::zeros_in(&big_specs(), dtype);
            serial.perturb_with_workers(5, 0.7, 1);
            for workers in [2, 3, 4, 8, 16] {
                let mut par = ParamStore::zeros_in(&big_specs(), dtype);
                par.perturb_with_workers(5, 0.7, workers);
                for (a, b) in par.iter().zip(serial.iter()) {
                    assert_eq!(a.tensor, b.tensor, "dtype={dtype:?} workers={workers}");
                }
            }
        }
    }

    #[test]
    fn bf16_fused_update_bit_identical_across_worker_counts() {
        // The satellite contract: perturb AND restore_and_zo_update on a
        // bf16 store agree bitwise at workers ∈ {1, 4, 8}.
        let (seed, eps, lr, coeff, g0) = (33u64, 1e-2f32, 0.05f32, 0.5f32, 1.3f32);
        let run = |workers: usize| -> ParamStore {
            let mut s = ParamStore::zeros_in(&big_specs(), Dtype::Bf16);
            s.set_noise_workers(workers);
            s.perturb(3, 1.0);
            s.perturb(seed, eps);
            s.perturb(seed, -2.0 * eps);
            s.restore_and_zo_update(seed, eps, lr, coeff, g0);
            s
        };
        let reference = run(1);
        for workers in [4usize, 8] {
            let par = run(workers);
            for (a, b) in par.iter().zip(reference.iter()) {
                assert_eq!(a.tensor, b.tensor, "workers={workers}");
            }
        }
    }

    #[test]
    fn fused_restore_update_matches_two_pass_exactly() {
        let (seed, eps, lr, coeff, g0) = (21u64, 1e-3f32, 0.07f32, 0.4f32, 1.7f32);
        let mut fused = ParamStore::zeros(&big_specs());
        fused.perturb(3, 1.0);
        let mut two_pass = fused.clone();
        // both start from θ − εz, as after the second SPSA probe
        fused.perturb(seed, eps);
        fused.perturb(seed, -2.0 * eps);
        two_pass.perturb(seed, eps);
        two_pass.perturb(seed, -2.0 * eps);

        fused.restore_and_zo_update(seed, eps, lr, coeff, g0);
        two_pass.perturb(seed, eps);
        two_pass.zo_update(seed, lr, coeff, g0);
        for (a, b) in fused.iter().zip(two_pass.iter()) {
            assert_eq!(a.tensor, b.tensor);
        }
    }

    #[test]
    fn subset_noise_agrees_with_full_perturb() {
        // Counter addressing: tensor m's noise is independent of the
        // filter, so a subset perturb equals the full perturb on the
        // included tensors.
        let mut full = ParamStore::zeros(&big_specs());
        full.perturb(9, 0.3);
        let mut sub = ParamStore::zeros(&big_specs());
        sub.perturb_subset(9, 0.3, |idx, _| idx != 1);
        assert_eq!(sub.get(0).tensor, full.get(0).tensor);
        assert!(sub.get(1).tensor.iter_f32().all(|v| v == 0.0));
        assert_eq!(sub.get(2).tensor, full.get(2).tensor);
    }

    #[test]
    fn noise_sweep_counter_counts_full_passes() {
        let mut s = ParamStore::zeros(&specs());
        assert_eq!(s.noise_sweeps(), 0);
        s.perturb(1, 0.1);
        s.perturb_subset(1, 0.1, |i, _| i == 0);
        s.restore_and_zo_update(1, 0.1, 0.01, 1.0, 0.5);
        assert_eq!(s.noise_sweeps(), 3);
    }

    #[test]
    fn per_store_noise_workers_do_not_leak_across_stores() {
        let mut a = ParamStore::zeros(&specs());
        let b = ParamStore::zeros(&specs());
        a.set_noise_workers(3);
        assert_eq!(a.noise_workers(), 3);
        // The pin is store-local (the old process-global raced here).
        assert_ne!(b.noise_workers(), 0, "auto resolution must yield ≥ 1");
        let mut c = a.clone();
        c.set_noise_workers(0);
        assert_ne!(c.noise_workers(), 0);
        assert_eq!(a.noise_workers(), 3);
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("addax_test_params");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("p.bin");
        let mut s = ParamStore::zeros(&specs());
        s.perturb(5, 1.0);
        s.save_bin(&path).unwrap();
        let loaded = ParamStore::load_bin(&specs(), &path).unwrap();
        assert!(s.dist_sq(&loaded) == 0.0);
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn save_load_roundtrip_bf16() {
        // A bf16 store writes 2-byte elements and loads back bit-exactly.
        let dir = std::env::temp_dir().join("addax_test_params_bf16");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("p16.bin");
        let mut s = ParamStore::zeros_in(&specs(), Dtype::Bf16);
        s.perturb(5, 1.0);
        s.save_bin(&path).unwrap();
        assert_eq!(
            std::fs::metadata(&path).unwrap().len(),
            (s.n_scalars() * 2) as u64,
            "bf16 dump must be 2 bytes per element"
        );
        let loaded = ParamStore::load_bin_in(&specs(), &path, Dtype::Bf16).unwrap();
        assert_eq!(loaded.dtype(), Dtype::Bf16);
        for (a, b) in s.iter().zip(loaded.iter()) {
            assert_eq!(a.tensor, b.tensor);
        }
        // An f32 read of a bf16 dump must fail loudly (wrong size).
        assert!(ParamStore::load_bin(&specs(), &path).is_err());
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn to_dtype_roundtrips_and_rounds() {
        let mut s = ParamStore::zeros(&specs());
        s.perturb(11, 1.0);
        let b = s.clone().to_dtype(Dtype::Bf16);
        assert_eq!(b.dtype(), Dtype::Bf16);
        // Widening back is exact.
        let wide = b.clone().to_dtype(Dtype::F32);
        assert_eq!(wide.dist_sq(&b), 0.0);
        // Quantization error is bounded by ~2^-8 relative.
        let err = s.dist_sq(&b).sqrt();
        let norm = crate::tensor::global_norm(&s.tensors().cloned().collect::<Vec<_>>());
        assert!(err <= 0.01 * norm.max(1.0), "err {err} vs norm {norm}");
    }

    #[test]
    fn load_rejects_wrong_size() {
        let dir = std::env::temp_dir().join("addax_test_params2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, vec![0u8; 10]).unwrap();
        assert!(ParamStore::load_bin(&specs(), &path).is_err());
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn fo_update_applies_per_tensor() {
        for dtype in [Dtype::F32, Dtype::Bf16] {
            let mut s = ParamStore::zeros_in(&specs(), dtype);
            let grads: Vec<Vec<f32>> = s.iter().map(|p| vec![1.0; p.tensor.len()]).collect();
            s.fo_update_all(0.1, 0.5, &grads);
            for p in s.iter() {
                for v in p.tensor.iter_f32() {
                    assert!((v + 0.05).abs() < 1e-3, "{dtype:?}: {v}");
                }
            }
        }
    }
}
