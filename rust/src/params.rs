//! The in-place parameter store: rust owns the model state (Alg. 1).
//!
//! All optimizer updates happen here, tensor by tensor, with gradients and
//! perturbation noise discarded immediately — the in-place discipline that
//! gives IP-SGD/MeZO/Addax their memory profile (paper §2.3, App. B).
//!
//! The store is precision-polymorphic: every tensor holds either `f32` or
//! `bf16` elements ([`Dtype`], uniform across the store), while all sweep
//! math runs in f32 and rounds nearest-even on write (`tensor::Element`).
//! The ZO sweeps (`perturb`, `perturb_subset`, `restore_and_zo_update`)
//! are the hottest loops in the system: each touches all `d` parameters,
//! so bf16 storage halves the bytes they move (EXPERIMENTS.md §Precision).
//! They run over a flat map of [`NOISE_BLOCK`]-element blocks whose noise
//! is counter-addressed (`zorng::block_seed`), so the blocks are
//! distributed across a scoped worker pool and the result is bit-identical
//! at every worker count — in both precisions, because each element is
//! decoded, updated and re-encoded independently of every other (see
//! EXPERIMENTS.md §Perf for the scaling numbers).
//!
//! The sweep worker count is **per store** (`set_noise_workers`), not a
//! process global: concurrent runs on one process (the sweep scheduler)
//! each pin their own store without racing.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::{Bf16, Dtype, Element, HostTensor};
use crate::zorng::{block_seed, fill_block, NOISE_BLOCK};

/// `ADDAX_NOISE_WORKERS`, read once (0 = unset/invalid).
fn env_noise_workers() -> usize {
    use std::sync::OnceLock;
    static ENV: OnceLock<usize> = OnceLock::new();
    *ENV.get_or_init(|| {
        std::env::var("ADDAX_NOISE_WORKERS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .unwrap_or(0)
    })
}

/// Auto worker count: `ADDAX_NOISE_WORKERS`, then `min(cores, 8)`.
fn auto_noise_workers() -> usize {
    let env = env_noise_workers();
    if env > 0 {
        return env;
    }
    std::thread::available_parallelism()
        .map(|c| c.get())
        .unwrap_or(1)
        .min(8)
}

/// One named parameter tensor.
#[derive(Clone, Debug)]
pub struct Param {
    pub name: String,
    pub tensor: HostTensor,
}

/// One unit of sweep work: a [`NOISE_BLOCK`]-element block of one tensor.
/// `(param_idx, block_idx)` is the noise address; the borrow is the
/// destination slice in the store's native element type. A block may also
/// carry the matching slice of a first-order gradient (`grad`) so combined
/// FO+ZO updates ride one pass, and may opt out of noise entirely
/// (`noisy = false`: pure-FO work sharing the same worker pool).
struct NoiseBlock<'a, E> {
    param_idx: usize,
    block_idx: usize,
    data: &'a mut [E],
    grad: Option<&'a [f32]>,
    noisy: bool,
}

/// Flatten the included tensors into the block map the workers consume
/// (noise-only sweeps: every block noisy, no gradient).
fn noise_blocks<'a, E: Element>(
    params: &'a mut [Param],
    include: &dyn Fn(usize, &str) -> bool,
) -> Vec<NoiseBlock<'a, E>> {
    let mut blocks = Vec::new();
    for (param_idx, p) in params.iter_mut().enumerate() {
        if !include(param_idx, &p.name) {
            continue;
        }
        let slice = E::slice_mut(p.tensor.raw_mut());
        for (block_idx, data) in slice.chunks_mut(NOISE_BLOCK).enumerate() {
            blocks.push(NoiseBlock { param_idx, block_idx, data, grad: None, noisy: true });
        }
    }
    blocks
}

/// Block map for a combined FO+ZO pass: `noisy` selects which tensors draw
/// replay noise, `with_grad` which carry their gradient slices. Tensors in
/// neither set are untouched.
fn mixed_blocks<'a, E: Element>(
    params: &'a mut [Param],
    grads: &'a [Vec<f32>],
    noisy: &dyn Fn(usize, &str) -> bool,
    with_grad: &dyn Fn(usize, &str) -> bool,
) -> Vec<NoiseBlock<'a, E>> {
    assert_eq!(grads.len(), params.len(), "combined update needs one gradient per tensor");
    let mut blocks = Vec::new();
    for ((param_idx, p), grad) in params.iter_mut().enumerate().zip(grads.iter()) {
        let is_noisy = noisy(param_idx, &p.name);
        let use_grad = with_grad(param_idx, &p.name);
        if !is_noisy && !use_grad {
            continue;
        }
        let slice = E::slice_mut(p.tensor.raw_mut());
        if use_grad {
            assert_eq!(grad.len(), slice.len(), "gradient/tensor length mismatch at {}", p.name);
            let spans = slice.chunks_mut(NOISE_BLOCK).zip(grad.chunks(NOISE_BLOCK));
            for (block_idx, (data, gchunk)) in spans.enumerate() {
                blocks.push(NoiseBlock {
                    param_idx,
                    block_idx,
                    data,
                    grad: Some(gchunk),
                    noisy: is_noisy,
                });
            }
        } else {
            for (block_idx, data) in slice.chunks_mut(NOISE_BLOCK).enumerate() {
                blocks.push(NoiseBlock { param_idx, block_idx, data, grad: None, noisy: is_noisy });
            }
        }
    }
    blocks
}

/// Minimum blocks per worker before spawning threads pays for itself
/// (thread startup is ~tens of µs; a block sweep is ~µs-scale).
const MIN_BLOCKS_PER_WORKER: usize = 2;

/// Apply `op(value, z, g)` to one block: lane-batched noise generation
/// into the worker's stack-resident block buffer (`zorng::fill_block`),
/// then one decode → f32 math → encode pass. Blocks without noise (or
/// without a gradient) see exact `0.0` for the missing operand.
fn apply_block<E: Element, Op: Fn(f32, f32, f32) -> f32>(
    seed: u64,
    b: &mut NoiseBlock<'_, E>,
    zbuf: &mut [f32; NOISE_BLOCK],
    op: &Op,
) {
    let n = b.data.len();
    if b.noisy {
        fill_block(block_seed(seed, b.param_idx, b.block_idx), &mut zbuf[..n]);
    }
    match (b.noisy, b.grad) {
        (true, Some(g)) => {
            for ((v, &z), &gi) in b.data.iter_mut().zip(zbuf.iter()).zip(g.iter()) {
                *v = E::encode(op(v.decode(), z, gi));
            }
        }
        (true, None) => {
            for (v, &z) in b.data.iter_mut().zip(zbuf.iter()) {
                *v = E::encode(op(v.decode(), z, 0.0));
            }
        }
        (false, Some(g)) => {
            for (v, &gi) in b.data.iter_mut().zip(g.iter()) {
                *v = E::encode(op(v.decode(), 0.0, gi));
            }
        }
        (false, None) => {}
    }
}

/// Run `op` once per block, on up to `workers` scoped threads (1 = serial,
/// same bits: every block's noise is independent of processing order).
/// Small stores fall back to the serial path — identical results, no
/// thread-spawn overhead. Each worker owns one [`NOISE_BLOCK`]-sized f32
/// noise buffer, reused across its blocks.
fn run_block_sweep<E, Op>(seed: u64, mut blocks: Vec<NoiseBlock<'_, E>>, workers: usize, op: Op)
where
    E: Element,
    Op: Fn(f32, f32, f32) -> f32 + Sync,
{
    let workers = workers.min(blocks.len() / MIN_BLOCKS_PER_WORKER);
    if workers <= 1 {
        let mut zbuf = [0.0f32; NOISE_BLOCK];
        for b in blocks.iter_mut() {
            apply_block(seed, b, &mut zbuf, &op);
        }
        return;
    }
    let per_worker = blocks.len().div_ceil(workers);
    let op = &op;
    std::thread::scope(|s| {
        for part in blocks.chunks_mut(per_worker) {
            s.spawn(move || {
                let mut zbuf = [0.0f32; NOISE_BLOCK];
                for b in part.iter_mut() {
                    apply_block(seed, b, &mut zbuf, op);
                }
            });
        }
    });
}

/// Build the block map for `E` and apply `g(value, z)` elementwise:
/// decode → f32 math → encode. Per-element independence is what keeps
/// every worker count (and both precisions) bit-identical.
fn sweep_elements<E, G>(
    params: &mut [Param],
    seed: u64,
    workers: usize,
    include: &dyn Fn(usize, &str) -> bool,
    g: &G,
) where
    E: Element,
    G: Fn(f32, f32) -> f32 + Sync,
{
    let blocks = noise_blocks::<E>(params, include);
    run_block_sweep(seed, blocks, workers, move |v, z, _| g(v, z));
}

/// [`sweep_elements`] with gradients: apply `g(value, z, grad)`.
fn mixed_elements<E, G>(
    params: &mut [Param],
    seed: u64,
    workers: usize,
    grads: &[Vec<f32>],
    noisy: &dyn Fn(usize, &str) -> bool,
    with_grad: &dyn Fn(usize, &str) -> bool,
    g: &G,
) where
    E: Element,
    G: Fn(f32, f32, f32) -> f32 + Sync,
{
    let blocks = mixed_blocks::<E>(params, grads, noisy, with_grad);
    run_block_sweep(seed, blocks, workers, g);
}

/// Ordered collection of model parameters.
///
/// The order is the canonical `param_specs` order from
/// `python/compile/model.py`, recorded in the manifest; ZO noise is
/// addressed by `(param_idx, block_idx)` in exactly this order so that
/// perturbation and update replay line up (Alg. 3 iterates layers in a
/// fixed order). All tensors share one [`Dtype`].
#[derive(Clone, Debug)]
pub struct ParamStore {
    params: Vec<Param>,
    /// Count of full O(d) noise sweeps performed (perturb / subset /
    /// fused restore+update / combined FO+ZO update / fused-probe noise
    /// generation) — the traffic metric the fused ZO step optimizes
    /// (4 → 3 sweeps in PR 2; 3 → 2 under sweep fusion v2 where the
    /// substrate supports fused probes; asserted in tests).
    noise_sweeps: u64,
    /// Uniform storage precision of every tensor.
    dtype: Dtype,
    /// Per-store worker override for the noise sweeps; 0 = auto
    /// (`ADDAX_NOISE_WORKERS`, then `min(cores, 8)`). Stored here — not
    /// in a process global — so concurrent runs cannot stomp each other.
    noise_workers: usize,
}

impl ParamStore {
    pub fn new(params: Vec<Param>) -> Self {
        let dtype = params.first().map(|p| p.tensor.dtype()).unwrap_or_default();
        for p in &params {
            assert_eq!(p.tensor.dtype(), dtype, "mixed-dtype store ({})", p.name);
        }
        Self { params, noise_sweeps: 0, dtype, noise_workers: 0 }
    }

    /// Build zero-initialized f32 params from (name, shape) specs.
    pub fn zeros(specs: &[(String, Vec<usize>)]) -> Self {
        Self::zeros_in(specs, Dtype::F32)
    }

    /// Build zero-initialized params stored at `dtype`.
    pub fn zeros_in(specs: &[(String, Vec<usize>)], dtype: Dtype) -> Self {
        let params = specs
            .iter()
            .map(|(n, s)| Param { name: n.clone(), tensor: HostTensor::zeros_in(s, dtype) })
            .collect();
        Self::new(params)
    }

    /// Storage precision of every tensor in the store.
    pub fn dtype(&self) -> Dtype {
        self.dtype
    }

    /// Re-encode the whole store at `dtype` (f32→bf16 rounds nearest-even;
    /// bf16→f32 is exact). A no-op when the dtype already matches.
    pub fn to_dtype(mut self, dtype: Dtype) -> Self {
        if self.dtype != dtype {
            for p in &mut self.params {
                p.tensor = p.tensor.to_dtype(dtype);
            }
            self.dtype = dtype;
        }
        self
    }

    /// Pin the sweep worker count for this store (0 restores auto).
    pub fn set_noise_workers(&mut self, n: usize) {
        self.noise_workers = n;
    }

    /// Effective worker count for the noise sweeps: this store's pin,
    /// then `ADDAX_NOISE_WORKERS`, then `min(available cores, 8)`.
    pub fn noise_workers(&self) -> usize {
        if self.noise_workers > 0 {
            self.noise_workers
        } else {
            auto_noise_workers()
        }
    }

    /// Load from an AOT/checkpoint dump: concatenated little-endian f32
    /// in spec order (the aot.py format).
    pub fn load_bin(specs: &[(String, Vec<usize>)], path: &Path) -> Result<Self> {
        Self::load_bin_in(specs, path, Dtype::F32)
    }

    /// Load a dump whose elements are stored at `dtype` (f32: 4 bytes
    /// little-endian, bf16: 2). Pairs with [`ParamStore::save_bin`],
    /// which writes the store's native precision.
    pub fn load_bin_in(
        specs: &[(String, Vec<usize>)],
        path: &Path,
        dtype: Dtype,
    ) -> Result<Self> {
        match dtype {
            Dtype::F32 => load_bin_typed::<f32>(specs, path),
            Dtype::Bf16 => load_bin_typed::<Bf16>(specs, path),
        }
    }

    /// Save in the binary dump format at the store's native precision
    /// (checkpointing; an f32 store writes the exact legacy format).
    pub fn save_bin(&self, path: &Path) -> Result<()> {
        let mut file = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        for p in &self.params {
            let mut bytes = Vec::with_capacity(p.tensor.len() * self.dtype.bytes());
            p.tensor.encode_le_into(&mut bytes);
            file.write_all(&bytes)?;
        }
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.params.len()
    }

    pub fn is_empty(&self) -> bool {
        self.params.is_empty()
    }

    /// Total scalar parameter count `d`.
    pub fn n_scalars(&self) -> usize {
        self.params.iter().map(|p| p.tensor.len()).sum()
    }

    /// Bytes of parameter storage actually held (dtype-dependent).
    pub fn storage_bytes(&self) -> usize {
        self.n_scalars() * self.dtype.bytes()
    }

    /// Full O(d) noise sweeps performed so far (perf accounting).
    pub fn noise_sweeps(&self) -> u64 {
        self.noise_sweeps
    }

    /// Account one O(d) noise generation performed outside the store's
    /// own sweep machinery — the fused perturb+probe-eval path replays
    /// `z` inside the executor without ever touching parameter memory,
    /// but it is still one full pass of noise generation and must show
    /// up in the traffic metric.
    pub(crate) fn tally_noise_sweep(&mut self) {
        self.noise_sweeps += 1;
    }

    pub fn iter(&self) -> impl Iterator<Item = &Param> {
        self.params.iter()
    }

    pub fn tensors(&self) -> impl Iterator<Item = &HostTensor> {
        self.params.iter().map(|p| &p.tensor)
    }

    pub fn get(&self, idx: usize) -> &Param {
        &self.params[idx]
    }

    pub fn get_mut(&mut self, idx: usize) -> &mut Param {
        &mut self.params[idx]
    }

    pub fn by_name(&self, name: &str) -> Option<&Param> {
        self.params.iter().find(|p| p.name == name)
    }

    /// Dtype-dispatched counter-addressed sweep: apply `g(value, z)` to
    /// every included element, with `z` replayed block-wise from `seed`.
    fn noise_sweep<G>(
        &mut self,
        seed: u64,
        workers: usize,
        include: &dyn Fn(usize, &str) -> bool,
        g: G,
    ) where
        G: Fn(f32, f32) -> f32 + Sync,
    {
        self.noise_sweeps += 1;
        match self.dtype {
            Dtype::F32 => sweep_elements::<f32, G>(&mut self.params, seed, workers, include, &g),
            Dtype::Bf16 => sweep_elements::<Bf16, G>(&mut self.params, seed, workers, include, &g),
        }
    }

    /// In-place Gaussian perturbation: `θ_m ← θ_m + scale·z_m` for every
    /// tensor, with `z_m` replayed block-wise from `seed` (Algorithm 3).
    /// Generation is fused with the apply loop — no transient noise buffer
    /// — and the blocks run on this store's worker pool.
    pub fn perturb(&mut self, seed: u64, scale: f32) {
        self.perturb_with_workers(seed, scale, self.noise_workers());
    }

    /// [`ParamStore::perturb`] with an explicit worker count (1 = serial).
    /// All worker counts produce bit-identical stores: each block's noise
    /// comes from its own counter-addressed stream, independent of which
    /// thread generates it or in what order — and each element's
    /// decode/encode depends on nothing but that element.
    pub fn perturb_with_workers(&mut self, seed: u64, scale: f32, workers: usize) {
        self.noise_sweep(seed, workers, &|_, _| true, move |v, z| v + scale * z);
    }

    /// Perturb only the tensors for which `include(idx, name)` is true.
    ///
    /// Under counter addressing the noise for tensor `m` depends only on
    /// `(seed, m)` — not on which other tensors are included — so a
    /// matching `perturb_subset` with the same seed and filter replays the
    /// identical noise (used by the layer-split hybrid ZO-FO baseline of
    /// Zhang et al. [69]), and even agrees with a full `perturb` on the
    /// included tensors.
    pub fn perturb_subset<F: Fn(usize, &str) -> bool>(
        &mut self,
        seed: u64,
        scale: f32,
        include: F,
    ) {
        let workers = self.noise_workers();
        self.noise_sweep(seed, workers, &include, move |v, z| v + scale * z);
    }

    /// The ZO half of the Addax/MeZO update (Alg. 1 lines 13-17):
    /// `θ ← θ − lr·coeff·g⁰·z`, replaying `z` from `seed`.
    ///
    /// Equivalent to `perturb(seed, -lr*coeff*g0)`; kept as a named method
    /// because it is the algorithmically meaningful operation. The fused
    /// [`ParamStore::restore_and_zo_update`] subsumes it on the hot path.
    pub fn zo_update(&mut self, seed: u64, lr: f32, coeff: f32, g0: f32) {
        self.perturb(seed, -lr * coeff * g0);
    }

    /// Fused SPSA-restore + ZO-update sweep: from `θ − εz` (where the
    /// second probe leaves the params), produce `θ − lr·coeff·g⁰·z` in a
    /// single O(d) pass, replaying `z` once.
    ///
    /// Elementwise it computes `(v + ε·z) + (−lr·coeff·g⁰)·z` — two
    /// dependent adds, not one pre-combined scale — so on an f32 store the
    /// result is bit-identical to the unfused `perturb(seed, ε)` followed
    /// by `zo_update(seed, lr, coeff, g0)`, while touching parameter
    /// memory once instead of twice. This cuts the ZO step from 4 O(d)
    /// sweeps (+ε, −2ε, +ε restore, update) to 3 — ~25% of MeZO's
    /// dominant cost. On a bf16 store the fused form additionally rounds
    /// **once** instead of twice, so it is the *defining* semantics of
    /// the half-precision ZO step (EXPERIMENTS.md §Precision).
    pub fn restore_and_zo_update(&mut self, seed: u64, eps: f32, lr: f32, coeff: f32, g0: f32) {
        self.restore_and_zo_update_subset(seed, eps, lr, coeff, g0, |_, _| true);
    }

    /// Subset form of [`ParamStore::restore_and_zo_update`] (hybrid ZO-FO:
    /// only the shallow tensors carry ZO noise).
    pub fn restore_and_zo_update_subset<F: Fn(usize, &str) -> bool>(
        &mut self,
        seed: u64,
        eps: f32,
        lr: f32,
        coeff: f32,
        g0: f32,
        include: F,
    ) {
        let delta = -lr * coeff * g0;
        let workers = self.noise_workers();
        self.noise_sweep(seed, workers, &include, move |v, z| (v + eps * z) + delta * z);
    }

    /// Dtype-dispatched combined sweep over values, replay noise and
    /// first-order gradients: apply `g(value, z, grad)` with `z` drawn
    /// only for `noisy` tensors and `grad` bound only for `with_grad`
    /// tensors (exact `0.0` otherwise). One O(d) pass, one counter tick.
    fn mixed_sweep<G>(
        &mut self,
        seed: u64,
        grads: &[Vec<f32>],
        noisy: &dyn Fn(usize, &str) -> bool,
        with_grad: &dyn Fn(usize, &str) -> bool,
        g: G,
    ) where
        G: Fn(f32, f32, f32) -> f32 + Sync,
    {
        self.noise_sweeps += 1;
        let workers = self.noise_workers();
        match self.dtype {
            Dtype::F32 => mixed_elements::<f32, G>(
                &mut self.params,
                seed,
                workers,
                grads,
                noisy,
                with_grad,
                &g,
            ),
            Dtype::Bf16 => mixed_elements::<Bf16, G>(
                &mut self.params,
                seed,
                workers,
                grads,
                noisy,
                with_grad,
                &g,
            ),
        }
    }

    /// Sweep fusion v2, from `θ`: Addax's mixed update
    /// `θ ← θ − lr·α·g⁰·z − lr·(1−α)·g` in a **single** O(d) pass, fusing
    /// the ZO direction (replayed `z`) and the FO gradient into one
    /// read-modify-write of parameter memory. Used when the fused
    /// perturb+probe-eval path left the parameters at `θ` (never
    /// perturbed). Elementwise: `(v + δ·z) + a·g` with `δ = −lr·α·g⁰`,
    /// `a = −lr·(1−α)` — the same two dependent adds as the unfused
    /// `zo_update` followed by `fo_update_tensor`, so an f32 store is
    /// bit-identical to the legacy pair; a bf16 store rounds once instead
    /// of twice (the defining semantics, as for
    /// [`ParamStore::restore_and_zo_update`]).
    pub fn zo_fo_update(&mut self, seed: u64, lr: f32, alpha: f32, g0: f32, grads: &[Vec<f32>]) {
        let delta = -lr * alpha * g0;
        let a = -lr * (1.0 - alpha);
        self.mixed_sweep(seed, grads, &|_, _| true, &|_, _| true, move |v, z, g| {
            (v + delta * z) + a * g
        });
    }

    /// Sweep fusion v2, from `θ − εz`: SPSA restore + ZO update + FO
    /// update in one pass — `((v + ε·z) + δ·z) + a·g`. Used when the
    /// probe ran through the legacy materialized perturbs (no fused
    /// substrate), which leave the parameters at `θ − εz`. Same
    /// bit-parity contract vs `restore_and_zo_update` + `fo_update_all`
    /// as [`ParamStore::zo_fo_update`].
    pub fn restore_zo_fo_update(
        &mut self,
        seed: u64,
        eps: f32,
        lr: f32,
        alpha: f32,
        g0: f32,
        grads: &[Vec<f32>],
    ) {
        let delta = -lr * alpha * g0;
        let a = -lr * (1.0 - alpha);
        self.mixed_sweep(seed, grads, &|_, _| true, &|_, _| true, move |v, z, g| {
            ((v + eps * z) + delta * z) + a * g
        });
    }

    /// Sweep fusion v2 for the layer-split hybrid: shallow tensors get the
    /// fused SPSA restore + ZO update (`(v + ε·z) + δ·z`, `δ = −lr_zo·g⁰`),
    /// deep tensors get the FO update (`v − lr_fo·g`), all in one pass of
    /// the worker pool. Noise is only generated for shallow blocks; deep
    /// blocks see exact-zero `z` (and shallow blocks exact-zero `g`), so
    /// each side reduces to its unfused formula up to `+ 0.0` terms.
    #[allow(clippy::too_many_arguments)]
    pub fn hybrid_zo_fo_update<F: Fn(usize, &str) -> bool>(
        &mut self,
        seed: u64,
        eps: f32,
        lr_zo: f32,
        g0: f32,
        lr_fo: f32,
        grads: &[Vec<f32>],
        shallow: F,
    ) {
        let delta = -lr_zo * g0;
        let a = -lr_fo;
        let deep = |idx: usize, name: &str| !shallow(idx, name);
        self.mixed_sweep(seed, grads, &shallow, &deep, move |v, z, g| {
            ((v + eps * z) + delta * z) + a * g
        });
    }

    /// The FO half: `θ_m ← θ_m − lr·coeff·g_m`, one tensor at a time
    /// (the caller drops each gradient right after — in-place SGD).
    pub fn fo_update_tensor(&mut self, idx: usize, lr: f32, coeff: f32, grad: &[f32]) {
        self.params[idx].tensor.axpy(-lr * coeff, grad);
    }

    /// Apply FO updates for all tensors from a gradient list.
    pub fn fo_update_all(&mut self, lr: f32, coeff: f32, grads: &[Vec<f32>]) {
        assert_eq!(grads.len(), self.params.len());
        for (i, g) in grads.iter().enumerate() {
            self.fo_update_tensor(i, lr, coeff, g);
        }
    }

    /// Squared L2 distance to another store (tests, theory experiments).
    /// Values compare in f32, so stores of different dtypes are
    /// commensurable (bf16 widens exactly).
    pub fn dist_sq(&self, other: &ParamStore) -> f64 {
        self.params
            .iter()
            .zip(other.params.iter())
            .map(|(a, b)| {
                a.tensor
                    .iter_f32()
                    .zip(b.tensor.iter_f32())
                    .map(|(x, y)| ((x - y) as f64).powi(2))
                    .sum::<f64>()
            })
            .sum()
    }

    pub fn all_finite(&self) -> bool {
        self.params.iter().all(|p| p.tensor.all_finite())
    }
}

fn load_bin_typed<E: Element>(specs: &[(String, Vec<usize>)], path: &Path) -> Result<ParamStore> {
    let mut file = std::fs::File::open(path)
        .with_context(|| format!("opening params file {}", path.display()))?;
    let mut params = Vec::with_capacity(specs.len());
    for (name, shape) in specs {
        let n: usize = shape.iter().product();
        let mut bytes = vec![0u8; n * E::BYTES];
        file.read_exact(&mut bytes).with_context(|| {
            format!("reading {name} ({n} x {} byte {})", E::BYTES, E::DTYPE.label())
        })?;
        let data: Vec<E> = bytes.chunks_exact(E::BYTES).map(E::read_le).collect();
        params.push(Param { name: name.clone(), tensor: HostTensor::from_elems(shape, data) });
    }
    // The file must be fully consumed — a longer file means the specs
    // and the dump disagree.
    let mut extra = [0u8; 1];
    if file.read(&mut extra)? != 0 {
        bail!("params file {} longer than specs describe", path.display());
    }
    Ok(ParamStore::new(params))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::zorng::{fill_block_scalar, BlockNoise};

    fn specs() -> Vec<(String, Vec<usize>)> {
        vec![
            ("a".into(), vec![3, 2]),
            ("b".into(), vec![5]),
            ("c".into(), vec![2, 2, 2]),
        ]
    }

    /// Shapes big enough to span several noise blocks per tensor.
    fn big_specs() -> Vec<(String, Vec<usize>)> {
        vec![
            ("w1".into(), vec![NOISE_BLOCK * 2 + 17]),
            ("w2".into(), vec![NOISE_BLOCK - 1]),
            ("w3".into(), vec![3 * NOISE_BLOCK + 5]),
        ]
    }

    #[test]
    fn zeros_and_counts() {
        let s = ParamStore::zeros(&specs());
        assert_eq!(s.len(), 3);
        assert_eq!(s.n_scalars(), 6 + 5 + 8);
        assert_eq!(s.dtype(), Dtype::F32);
        assert_eq!(s.storage_bytes(), 19 * 4);
        let b = ParamStore::zeros_in(&specs(), Dtype::Bf16);
        assert_eq!(b.dtype(), Dtype::Bf16);
        assert_eq!(b.storage_bytes(), 19 * 2);
    }

    #[test]
    #[should_panic(expected = "mixed-dtype store")]
    fn mixed_dtype_store_is_rejected() {
        ParamStore::new(vec![
            Param { name: "a".into(), tensor: HostTensor::zeros(&[2]) },
            Param { name: "b".into(), tensor: HostTensor::zeros_in(&[2], Dtype::Bf16) },
        ]);
    }

    #[test]
    fn perturb_roundtrip_restores_exactly_like_algorithm2() {
        // θ +ε z, then −2ε z, then +ε z must return exactly to θ when the
        // same seed replays the same z (floating error cancels exactly
        // because the identical z values are added/subtracted).
        let mut s = ParamStore::zeros(&specs());
        s.perturb(123, 0.5); // give θ nonzero values
        let before = s.clone();
        let seed = 777;
        let eps = 1e-3f32;
        s.perturb(seed, eps);
        s.perturb(seed, -2.0 * eps);
        s.perturb(seed, eps);
        for (a, b) in s.iter().zip(before.iter()) {
            for (x, y) in a.tensor.iter_f32().zip(b.tensor.iter_f32()) {
                assert!((x - y).abs() <= 1e-6, "{} vs {}", x, y);
            }
        }
    }

    #[test]
    fn bf16_probe_roundtrip_drift_is_quantization_bounded() {
        // On a bf16 store every sweep re-rounds, so +ε, −2ε, +ε is NOT
        // exact — the drift must stay within a few ulps of the stored
        // magnitudes (|θ| ≲ 2 here ⇒ ulp ≤ 2^-7; three roundings ⇒
        // well under 0.05 per element). Use an ε above the quantization
        // step so the probes actually move the stored values.
        let mut s = ParamStore::zeros_in(&big_specs(), Dtype::Bf16);
        s.perturb(123, 0.5);
        let before = s.clone();
        let seed = 777;
        let eps = 1e-2f32;
        s.perturb(seed, eps);
        s.perturb(seed, -2.0 * eps);
        s.perturb(seed, eps);
        for (a, b) in s.iter().zip(before.iter()) {
            for (x, y) in a.tensor.iter_f32().zip(b.tensor.iter_f32()) {
                assert!((x - y).abs() <= 0.05, "bf16 roundtrip drift {} vs {}", x, y);
            }
        }
    }

    #[test]
    fn zo_update_matches_manual_replay() {
        let mut s = ParamStore::zeros(&specs());
        let seed = 99;
        s.zo_update(seed, 0.1, 0.5, 2.0);
        // manual: θ = -0.1*0.5*2.0 * z, with z replayed block-wise
        let noise = BlockNoise::new(seed);
        for (pi, p) in s.iter().enumerate() {
            let mut z = vec![0.0f32; p.tensor.len()];
            noise.fill_param(pi, &mut z);
            for (v, &zi) in p.tensor.iter_f32().zip(z.iter()) {
                assert!((v - (-0.1 * 0.5 * 2.0 * zi)).abs() < 1e-7);
            }
        }
    }

    #[test]
    fn bf16_perturb_is_the_rounded_f32_sweep() {
        // The bf16 sweep is defined as encode(decode(v) + scale·z): check
        // it against the replayed z and explicit Bf16 rounding.
        let mut s = ParamStore::zeros_in(&big_specs(), Dtype::Bf16);
        s.perturb(7, 0.5);
        let reference = s.clone();
        let (seed, scale) = (41u64, 0.3f32);
        s.perturb(seed, scale);
        let noise = BlockNoise::new(seed);
        for (pi, (p, r)) in s.iter().zip(reference.iter()).enumerate() {
            let mut z = vec![0.0f32; p.tensor.len()];
            noise.fill_param(pi, &mut z);
            for ((got, prev), &zi) in
                p.tensor.iter_f32().zip(r.tensor.iter_f32()).zip(z.iter())
            {
                let want = crate::tensor::Bf16::from_f32(prev + scale * zi).to_f32();
                assert_eq!(got, want, "param {pi}");
            }
        }
    }

    #[test]
    fn parallel_perturb_bit_identical_at_every_worker_count() {
        for dtype in [Dtype::F32, Dtype::Bf16] {
            let mut serial = ParamStore::zeros_in(&big_specs(), dtype);
            serial.perturb_with_workers(5, 0.7, 1);
            for workers in [2, 3, 4, 8, 16] {
                let mut par = ParamStore::zeros_in(&big_specs(), dtype);
                par.perturb_with_workers(5, 0.7, workers);
                for (a, b) in par.iter().zip(serial.iter()) {
                    assert_eq!(a.tensor, b.tensor, "dtype={dtype:?} workers={workers}");
                }
            }
        }
    }

    #[test]
    fn bf16_fused_update_bit_identical_across_worker_counts() {
        // The satellite contract: perturb AND restore_and_zo_update on a
        // bf16 store agree bitwise at workers ∈ {1, 4, 8}.
        let (seed, eps, lr, coeff, g0) = (33u64, 1e-2f32, 0.05f32, 0.5f32, 1.3f32);
        let run = |workers: usize| -> ParamStore {
            let mut s = ParamStore::zeros_in(&big_specs(), Dtype::Bf16);
            s.set_noise_workers(workers);
            s.perturb(3, 1.0);
            s.perturb(seed, eps);
            s.perturb(seed, -2.0 * eps);
            s.restore_and_zo_update(seed, eps, lr, coeff, g0);
            s
        };
        let reference = run(1);
        for workers in [4usize, 8] {
            let par = run(workers);
            for (a, b) in par.iter().zip(reference.iter()) {
                assert_eq!(a.tensor, b.tensor, "workers={workers}");
            }
        }
    }

    #[test]
    fn fused_restore_update_matches_two_pass_exactly() {
        let (seed, eps, lr, coeff, g0) = (21u64, 1e-3f32, 0.07f32, 0.4f32, 1.7f32);
        let mut fused = ParamStore::zeros(&big_specs());
        fused.perturb(3, 1.0);
        let mut two_pass = fused.clone();
        // both start from θ − εz, as after the second SPSA probe
        fused.perturb(seed, eps);
        fused.perturb(seed, -2.0 * eps);
        two_pass.perturb(seed, eps);
        two_pass.perturb(seed, -2.0 * eps);

        fused.restore_and_zo_update(seed, eps, lr, coeff, g0);
        two_pass.perturb(seed, eps);
        two_pass.zo_update(seed, lr, coeff, g0);
        for (a, b) in fused.iter().zip(two_pass.iter()) {
            assert_eq!(a.tensor, b.tensor);
        }
    }

    #[test]
    fn subset_noise_agrees_with_full_perturb() {
        // Counter addressing: tensor m's noise is independent of the
        // filter, so a subset perturb equals the full perturb on the
        // included tensors.
        let mut full = ParamStore::zeros(&big_specs());
        full.perturb(9, 0.3);
        let mut sub = ParamStore::zeros(&big_specs());
        sub.perturb_subset(9, 0.3, |idx, _| idx != 1);
        assert_eq!(sub.get(0).tensor, full.get(0).tensor);
        assert!(sub.get(1).tensor.iter_f32().all(|v| v == 0.0));
        assert_eq!(sub.get(2).tensor, full.get(2).tensor);
    }

    #[test]
    fn noise_sweep_counter_counts_full_passes() {
        let mut s = ParamStore::zeros(&specs());
        assert_eq!(s.noise_sweeps(), 0);
        s.perturb(1, 0.1);
        s.perturb_subset(1, 0.1, |i, _| i == 0);
        s.restore_and_zo_update(1, 0.1, 0.01, 1.0, 0.5);
        assert_eq!(s.noise_sweeps(), 3);
        // Combined FO+ZO passes are one sweep each, not two.
        let grads: Vec<Vec<f32>> = s.iter().map(|p| vec![0.1; p.tensor.len()]).collect();
        s.zo_fo_update(1, 0.01, 0.7, 0.5, &grads);
        assert_eq!(s.noise_sweeps(), 4);
        s.restore_zo_fo_update(1, 0.1, 0.01, 0.7, 0.5, &grads);
        assert_eq!(s.noise_sweeps(), 5);
        s.hybrid_zo_fo_update(1, 0.1, 0.01, 0.5, 0.01, &grads, |i, _| i == 0);
        assert_eq!(s.noise_sweeps(), 6);
    }

    #[test]
    fn sweeps_match_the_scalar_noise_oracle_bitwise() {
        // The tentpole contract at the store level: the (lane-batched)
        // perturb sweep equals a manual elementwise apply of the *scalar
        // oracle* noise — at workers {1, 4, 8}, both dtypes, full and
        // subset perturbs.
        let (seed, scale) = (41u64, 0.3f32);
        for dtype in [Dtype::F32, Dtype::Bf16] {
            for workers in [1usize, 4, 8] {
                for subset in [false, true] {
                    let mut s = ParamStore::zeros_in(&big_specs(), dtype);
                    s.set_noise_workers(workers);
                    s.perturb(7, 0.5); // nonzero starting point
                    let reference = s.clone();
                    if subset {
                        s.perturb_subset(seed, scale, |idx, _| idx != 1);
                    } else {
                        s.perturb(seed, scale);
                    }
                    for (pi, (p, r)) in s.iter().zip(reference.iter()).enumerate() {
                        if subset && pi == 1 {
                            assert_eq!(p.tensor, r.tensor, "excluded tensor must not move");
                            continue;
                        }
                        let mut z = vec![0.0f32; p.tensor.len()];
                        for (bi, chunk) in z.chunks_mut(NOISE_BLOCK).enumerate() {
                            fill_block_scalar(block_seed(seed, pi, bi), chunk);
                        }
                        for ((got, prev), &zi) in
                            p.tensor.iter_f32().zip(r.tensor.iter_f32()).zip(z.iter())
                        {
                            let want = match dtype {
                                Dtype::F32 => prev + scale * zi,
                                Dtype::Bf16 => {
                                    crate::tensor::Bf16::from_f32(prev + scale * zi).to_f32()
                                }
                            };
                            assert_eq!(
                                got.to_bits(),
                                want.to_bits(),
                                "dtype={dtype:?} workers={workers} subset={subset} param={pi}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn combined_zo_fo_update_matches_legacy_pair_exactly() {
        // f32: the one-pass Addax update from θ equals zo_update followed
        // by fo_update_all, bit for bit (same add sequence per element).
        let (seed, lr, alpha, g0) = (17u64, 0.05f32, 0.6f32, 1.3f32);
        let mut fused = ParamStore::zeros(&big_specs());
        fused.perturb(3, 1.0);
        let grads: Vec<Vec<f32>> = fused
            .iter()
            .map(|p| (0..p.tensor.len()).map(|i| (i as f32 * 0.01).cos()).collect())
            .collect();
        let mut legacy = fused.clone();
        fused.zo_fo_update(seed, lr, alpha, g0, &grads);
        legacy.zo_update(seed, lr, alpha, g0);
        legacy.fo_update_all(lr, 1.0 - alpha, &grads);
        for (a, b) in fused.iter().zip(legacy.iter()) {
            assert_eq!(a.tensor, b.tensor);
        }
    }

    #[test]
    fn combined_restore_zo_fo_update_matches_legacy_pair_exactly() {
        // f32, starting from θ − εz as the legacy probe leaves it.
        let (seed, eps, lr, alpha, g0) = (23u64, 1e-3f32, 0.05f32, 0.6f32, 1.3f32);
        let mut fused = ParamStore::zeros(&big_specs());
        fused.perturb(3, 1.0);
        let grads: Vec<Vec<f32>> = fused
            .iter()
            .map(|p| (0..p.tensor.len()).map(|i| (i as f32 * 0.02).sin()).collect())
            .collect();
        let mut legacy = fused.clone();
        for s in [&mut fused, &mut legacy] {
            s.perturb(seed, eps);
            s.perturb(seed, -2.0 * eps);
        }
        fused.restore_zo_fo_update(seed, eps, lr, alpha, g0, &grads);
        legacy.restore_and_zo_update(seed, eps, lr, alpha, g0);
        legacy.fo_update_all(lr, 1.0 - alpha, &grads);
        for (a, b) in fused.iter().zip(legacy.iter()) {
            assert_eq!(a.tensor, b.tensor);
        }
    }

    #[test]
    fn combined_update_bit_identical_across_worker_counts() {
        // Both dtypes (bf16 tensor equality is bitwise), workers {1,4,8}.
        let (seed, eps, lr, alpha, g0) = (29u64, 1e-2f32, 0.05f32, 0.4f32, 0.9f32);
        for dtype in [Dtype::F32, Dtype::Bf16] {
            let run = |workers: usize| -> ParamStore {
                let mut s = ParamStore::zeros_in(&big_specs(), dtype);
                s.set_noise_workers(workers);
                s.perturb(3, 1.0);
                let grads: Vec<Vec<f32>> = s
                    .iter()
                    .map(|p| (0..p.tensor.len()).map(|i| (i as f32 * 0.03).sin()).collect())
                    .collect();
                s.perturb(seed, eps);
                s.perturb(seed, -2.0 * eps);
                s.restore_zo_fo_update(seed, eps, lr, alpha, g0, &grads);
                s
            };
            let reference = run(1);
            for workers in [4usize, 8] {
                let par = run(workers);
                for (a, b) in par.iter().zip(reference.iter()) {
                    assert_eq!(a.tensor, b.tensor, "dtype={dtype:?} workers={workers}");
                }
            }
        }
    }

    #[test]
    fn hybrid_combined_update_matches_split_legacy() {
        // One fused pass = shallow restore+ZO-update + deep FO update.
        // f32 value equality is exact (the zero-padded `+ 0.0` terms can
        // at most flip a −0.0, which f32 == treats as equal).
        let (seed, eps, lr_zo, g0, lr_fo) = (31u64, 1e-3f32, 0.03f32, 1.1f32, 0.07f32);
        let shallow = |idx: usize, _: &str| idx < 2;
        let mut fused = ParamStore::zeros(&big_specs());
        fused.perturb(3, 1.0);
        let grads: Vec<Vec<f32>> = fused
            .iter()
            .map(|p| (0..p.tensor.len()).map(|i| (i as f32 * 0.04).cos()).collect())
            .collect();
        let mut legacy = fused.clone();
        for s in [&mut fused, &mut legacy] {
            s.perturb_subset(seed, eps, shallow);
            s.perturb_subset(seed, -2.0 * eps, shallow);
        }
        fused.hybrid_zo_fo_update(seed, eps, lr_zo, g0, lr_fo, &grads, shallow);
        legacy.restore_and_zo_update_subset(seed, eps, lr_zo, 1.0, g0, shallow);
        legacy.fo_update_tensor(2, lr_fo, 1.0, &grads[2]);
        for (pi, (a, b)) in fused.iter().zip(legacy.iter()).enumerate() {
            for (x, y) in a.tensor.iter_f32().zip(b.tensor.iter_f32()) {
                assert_eq!(x, y, "param {pi}");
            }
        }
    }

    #[test]
    fn per_store_noise_workers_do_not_leak_across_stores() {
        let mut a = ParamStore::zeros(&specs());
        let b = ParamStore::zeros(&specs());
        a.set_noise_workers(3);
        assert_eq!(a.noise_workers(), 3);
        // The pin is store-local (the old process-global raced here).
        assert_ne!(b.noise_workers(), 0, "auto resolution must yield ≥ 1");
        let mut c = a.clone();
        c.set_noise_workers(0);
        assert_ne!(c.noise_workers(), 0);
        assert_eq!(a.noise_workers(), 3);
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("addax_test_params");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("p.bin");
        let mut s = ParamStore::zeros(&specs());
        s.perturb(5, 1.0);
        s.save_bin(&path).unwrap();
        let loaded = ParamStore::load_bin(&specs(), &path).unwrap();
        assert!(s.dist_sq(&loaded) == 0.0);
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn save_load_roundtrip_bf16() {
        // A bf16 store writes 2-byte elements and loads back bit-exactly.
        let dir = std::env::temp_dir().join("addax_test_params_bf16");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("p16.bin");
        let mut s = ParamStore::zeros_in(&specs(), Dtype::Bf16);
        s.perturb(5, 1.0);
        s.save_bin(&path).unwrap();
        assert_eq!(
            std::fs::metadata(&path).unwrap().len(),
            (s.n_scalars() * 2) as u64,
            "bf16 dump must be 2 bytes per element"
        );
        let loaded = ParamStore::load_bin_in(&specs(), &path, Dtype::Bf16).unwrap();
        assert_eq!(loaded.dtype(), Dtype::Bf16);
        for (a, b) in s.iter().zip(loaded.iter()) {
            assert_eq!(a.tensor, b.tensor);
        }
        // An f32 read of a bf16 dump must fail loudly (wrong size).
        assert!(ParamStore::load_bin(&specs(), &path).is_err());
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn to_dtype_roundtrips_and_rounds() {
        let mut s = ParamStore::zeros(&specs());
        s.perturb(11, 1.0);
        let b = s.clone().to_dtype(Dtype::Bf16);
        assert_eq!(b.dtype(), Dtype::Bf16);
        // Widening back is exact.
        let wide = b.clone().to_dtype(Dtype::F32);
        assert_eq!(wide.dist_sq(&b), 0.0);
        // Quantization error is bounded by ~2^-8 relative.
        let err = s.dist_sq(&b).sqrt();
        let norm = crate::tensor::global_norm(&s.tensors().cloned().collect::<Vec<_>>());
        assert!(err <= 0.01 * norm.max(1.0), "err {err} vs norm {norm}");
    }

    #[test]
    fn load_rejects_wrong_size() {
        let dir = std::env::temp_dir().join("addax_test_params2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, vec![0u8; 10]).unwrap();
        assert!(ParamStore::load_bin(&specs(), &path).is_err());
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn fo_update_applies_per_tensor() {
        for dtype in [Dtype::F32, Dtype::Bf16] {
            let mut s = ParamStore::zeros_in(&specs(), dtype);
            let grads: Vec<Vec<f32>> = s.iter().map(|p| vec![1.0; p.tensor.len()]).collect();
            s.fo_update_all(0.1, 0.5, &grads);
            for p in s.iter() {
                for v in p.tensor.iter_f32() {
                    assert!((v + 0.05).abs() < 1e-3, "{dtype:?}: {v}");
                }
            }
        }
    }
}
