//! `repro theory`: empirical checks of Theorems 3.1 / 3.2 on the
//! closed-form quadratic (assumptions hold exactly; no XLA involved).

use anyhow::Result;

use crate::jsonlite::{obj, Json};
use crate::metrics::Table;
use crate::optim::Addax;
use crate::theory::{fit_rate_exponent, run_synthetic, variance_factor};

use super::emit;

pub fn run(fast: bool) -> Result<()> {
    let mut raw = Vec::new();

    // --- Thm 3.2: strongly convex rate ~ ln(T)/T --------------------------
    let ts: &[usize] = if fast { &[200, 400, 800] } else { &[200, 400, 800, 1600, 3200] };
    let mut pts = Vec::new();
    let mut t_tbl = Table::new(&["T", "E||θ_T − θ*||²"]);
    for &t in ts {
        let lr = ((t as f32).ln() / (0.25 * t as f32)).min(0.4);
        let r = run_synthetic(16, t, 0.2, 4, 4, lr, 0.3, false, 11)?;
        t_tbl.row(vec![t.to_string(), format!("{:.3e}", r.dist_sq)]);
        pts.push((t, r.dist_sq));
    }
    let p_sc = fit_rate_exponent(&pts);
    raw.push(obj(vec![
        ("experiment", Json::from("thm3.2")),
        ("fitted_exponent", Json::from(p_sc)),
    ]));

    // --- Thm 3.1: variance factor and optimal α ---------------------------
    let (k0, k1) = (6usize, 4usize);
    let mut a_tbl = Table::new(&["d", "α*", "var(α*)", "var(0)", "var(1)"]);
    for &d in &[16usize, 256, 4096] {
        let a = Addax::optimal_alpha(k0, k1, d) as f64;
        a_tbl.row(vec![
            d.to_string(),
            format!("{a:.2e}"),
            format!("{:.4}", variance_factor(a, k0, k1, d)),
            format!("{:.4}", variance_factor(0.0, k0, k1, d)),
            format!("{:.1}", variance_factor(1.0, k0, k1, d)),
        ]);
    }

    // --- dimension dependence: Addax vs MeZO ------------------------------
    let t = if fast { 400 } else { 800 };
    let mut d_tbl = Table::new(&["d", "Addax ||θ−θ*||²/d", "MeZO ||θ−θ*||²/d"]);
    let mut addax_col = Vec::new();
    let mut mezo_col = Vec::new();
    for &d in &[8usize, 32, 128] {
        let alpha = Addax::optimal_alpha(4, 4, d);
        let a = run_synthetic(d, t, alpha, 4, 4, 0.05, 0.2, false, 5)?;
        let m = run_synthetic(d, t, 1.0, 4, 4, 0.05 / (d as f32).sqrt(), 0.2, true, 5)?;
        d_tbl.row(vec![
            d.to_string(),
            format!("{:.3e}", a.dist_sq / d as f64),
            format!("{:.3e}", m.dist_sq / d as f64),
        ]);
        addax_col.push(a.dist_sq / d as f64);
        mezo_col.push(m.dist_sq / d as f64);
        raw.push(obj(vec![
            ("experiment", Json::from("dim-dependence")),
            ("d", Json::from(d)),
            ("addax_per_coord", Json::from(a.dist_sq / d as f64)),
            ("mezo_per_coord", Json::from(m.dist_sq / d as f64)),
        ]));
    }

    let md = format!(
        "# theory — empirical validation of Theorems 3.1 / 3.2\n\n\
         ## Thm 3.2 (strongly convex, η ∝ ln T / T)\n{}\nFitted decay \
         exponent p in err ∝ T^-p: **{:.2}** (theory: 1 up to the ln T \
         factor).\n\n## Thm 3.1 variance factor (1−α)²/K¹ + α²d/K⁰ and the \
         optimal α* = K⁰/(K⁰+dK¹)\n{}\n\n## Dimension dependence at fixed \
         T={} (Remark 1: Addax nearly dimension-free, MeZO degrades)\n{}\n",
        t_tbl.render(),
        p_sc,
        a_tbl.render(),
        t,
        d_tbl.render()
    );
    emit("theory", &md, Json::Arr(raw))
}
