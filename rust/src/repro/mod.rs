//! The experiment harness: one subcommand per paper table/figure.
//!
//! Control flow is inverted relative to the original harness: experiments
//! no longer own training loops. Each table/figure expands its cells into
//! [`RunSpec`]s, hands the whole batch to the sweep scheduler (`sched/`),
//! and then renders as a *pure aggregation over manifest rows*. The
//! scheduler prices every run with the analytic memory model, packs the
//! ones that co-fit onto the simulated device budget, executes them
//! concurrently, and records each result once in the resumable manifest —
//! so cells shared between experiments (fig3's IP-SGD cells are table12's)
//! train exactly once, and a finished manifest regenerates every report
//! with zero training.
//!
//! Accuracy/time cells run at laptop scale (`tiny` mock/artifacts;
//! DESIGN.md §3 records the substitution); memory and batch-size columns
//! come from the analytic model at the paper's geometries.

pub mod figures;
pub mod tables;
pub mod theory_exp;

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::jsonlite::Json;
use crate::metrics::write_result;
use crate::optim::OptSpec;
use crate::sched::{run_sweep_collect, Backend, ManifestRow, RunSpec, SweepManifest, SweepOptions};

/// Methods compared in the OPT tables.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MethodKind {
    ZeroShot,
    MeZo,
    Sgd,
    IpSgd,
    Adam,
    Addax,
}

impl MethodKind {
    pub fn label(&self) -> &'static str {
        match self {
            MethodKind::ZeroShot => "Zero-shot",
            MethodKind::MeZo => "MeZO",
            MethodKind::Sgd => "SGD",
            MethodKind::IpSgd => "IP-SGD",
            MethodKind::Adam => "Adam",
            MethodKind::Addax => "Addax",
        }
    }
}

/// Per-method run shape at laptop scale.
pub struct RunPlan {
    pub steps: usize,
    pub opt: OptSpec,
}

/// Build the per-method plan. `base_steps` is the FO-method step count;
/// MeZO runs `zo_mult ×` that (paper: 20k vs 1k). The hyper-parameters
/// are the tuned `tiny`-preset values; the *relative* settings mirror
/// App. D.5 (MeZO: much smaller lr, many more steps; Addax:
/// (K¹,K⁰) = (4,6)).
pub fn plan_for(method: MethodKind, base_steps: usize, zo_mult: usize) -> RunPlan {
    match method {
        MethodKind::ZeroShot => RunPlan { steps: 0, opt: OptSpec::named("zero-shot") },
        MethodKind::MeZo => RunPlan {
            steps: base_steps * zo_mult,
            opt: OptSpec { lr: 3e-4, eps: 1e-3, batch: 16, ..OptSpec::named("mezo") },
        },
        MethodKind::Sgd => RunPlan {
            steps: base_steps,
            opt: OptSpec { lr: 7e-2, batch: 16, clip: 1.0, ..OptSpec::named("sgd") },
        },
        MethodKind::IpSgd => RunPlan {
            steps: base_steps,
            opt: OptSpec { lr: 7e-2, batch: 4, ..OptSpec::named("ip-sgd") },
        },
        MethodKind::Adam => RunPlan {
            steps: base_steps,
            opt: OptSpec { lr: 5e-3, batch: 8, ..OptSpec::named("adam") },
        },
        MethodKind::Addax => RunPlan {
            steps: base_steps,
            opt: OptSpec {
                lr: 7e-2,
                eps: 1e-3,
                alpha: 0.03,
                k0: 6,
                k1: 4,
                ..OptSpec::named("addax")
            },
        },
    }
}

/// Shared experiment context: which backend/model cells execute on, and
/// the sweep-scheduler knobs every experiment's batch runs under.
pub struct Harness {
    pub fast: bool,
    pub model_key: String,
    pub backend: Backend,
    /// Concurrent runs per packing wave.
    pub workers: usize,
    /// Simulated per-device budget for packing (GB) × device count.
    pub budget_gb: f64,
    pub gpus: usize,
    pub manifest_path: std::path::PathBuf,
}

impl Harness {
    pub fn new(model_key: &str, fast: bool) -> Self {
        Self {
            fast,
            model_key: model_key.to_string(),
            // Xla when artifacts exist, the quadratic mock otherwise — so
            // `repro` runs end-to-end (and in CI) without `make artifacts`.
            backend: Backend::auto(),
            workers: 4,
            // 8×80 GB: the packing budget must admit the *largest single
            // priced run*. Cells are vetted against the paper device at
            // the fp16 profile (`tables::FP16`), but the laptop-scale
            // runs train f32 stores and now price at their real dtype —
            // the biggest (Llama-2-70B IP-SGD on a long task) is ~460 GB
            // at 4 B/param, and Adam-on-OPT-13B is ~325 GB fp32, so
            // 640 GB covers every table with headroom. This knob only
            // shapes concurrency waves; paper-device OOM verdicts come
            // from `memory_cell`, not from this budget.
            budget_gb: 80.0,
            gpus: 8,
            manifest_path: std::path::PathBuf::from("results/sweep/manifest.jsonl"),
        }
    }

    /// A sealed cell spec on this harness's backend/model.
    ///
    /// `geometry`/`price_lt` parameterize memory pricing (the table's
    /// paper-scale device); `lt_auto` switches on the Addax 60th-percentile
    /// partition for long tasks; `catalog` picks the task table.
    pub fn cell_spec(&self, cell: &CellSpec<'_>) -> RunSpec {
        let mut s = RunSpec::new(
            self.backend,
            cell.task,
            cell.plan.opt.clone(),
            cell.plan.steps,
            cell.seed,
        );
        s.model_key = self.model_key.clone();
        s.geometry = cell.geometry.to_string();
        s.catalog = cell.catalog.to_string();
        s.eval_examples = 120;
        s.lt_auto = cell.lt_auto;
        s.price_lt = cell.price_lt;
        s.sealed()
    }

    /// Execute every spec not yet in the manifest (one packed, concurrent
    /// sweep), then return the rows for all of them, keyed by run id.
    pub fn runs(&mut self, specs: Vec<RunSpec>) -> Result<BTreeMap<String, ManifestRow>> {
        let wanted: Vec<String> = specs.iter().map(|s| s.run_id.clone()).collect();
        let opts = SweepOptions {
            budget_gb: self.budget_gb,
            gpus: self.gpus,
            workers: self.workers,
            resume: true,
            manifest_path: self.manifest_path.clone(),
            verbose: false,
            // Repro cells are seconds-long mock runs: the manifest's
            // run-level skip-completed already makes them resumable, and
            // step-level snapshots would only add fsync traffic that is
            // deleted the moment each row lands.
            ckpt: false,
            ..SweepOptions::default()
        };
        let (summary, manifest) = run_sweep_collect(specs, &opts)?;
        println!("[repro] {}", summary.line());
        let mut out = BTreeMap::new();
        for id in wanted {
            match manifest.get(&id) {
                Some(row) => {
                    out.insert(id, row.clone());
                }
                None => bail!("run {id} missing from manifest after sweep"),
            }
        }
        Ok(out)
    }

    /// Wall-clock telemetry (side file; empty when regenerating from a
    /// manifest alone — time columns then render as `-`).
    pub fn times(&self) -> BTreeMap<String, (f64, f64)> {
        SweepManifest::load_times(&self.manifest_path)
    }
}

/// One experiment cell, declaratively.
pub struct CellSpec<'a> {
    pub task: &'a str,
    pub plan: &'a RunPlan,
    pub seed: u64,
    pub geometry: &'a str,
    pub catalog: &'a str,
    pub lt_auto: bool,
    pub price_lt: usize,
}

/// Write a report (markdown) + raw JSON under results/, echo to stdout.
pub fn emit(id: &str, markdown: &str, raw: Json) -> Result<()> {
    std::fs::create_dir_all("results")?;
    std::fs::write(format!("results/{id}.md"), markdown)?;
    write_result(id, &raw)?;
    println!("{markdown}");
    println!("[repro] wrote results/{id}.md and results/{id}.json");
    Ok(())
}

/// Dispatch one experiment id.
pub fn run(id: &str, harness: &mut Harness) -> Result<()> {
    match id {
        "fig3" => figures::fig3(harness),
        "fig4" => figures::fig4(),
        "fig5" => figures::fig5(harness),
        "fig6" => figures::fig6(),
        "fig8" => figures::fig8(harness),
        "fig11" => figures::fig11(harness),
        "table11" => tables::table11(harness),
        "table12" | "fig1" => tables::table12(harness),
        "table13" | "fig2" | "table1" => tables::table13(harness),
        "table14" | "fig10" | "table2" => tables::table14(harness),
        "table15" | "table3" => tables::table15(harness),
        "theory" => theory_exp::run(harness.fast),
        "all" => {
            for id in [
                "fig3", "fig4", "fig5", "fig6", "fig8", "fig11", "theory", "table11",
                "table12", "table13", "table14", "table15",
            ] {
                println!("\n===== repro {id} =====");
                run(id, harness)?;
            }
            Ok(())
        }
        other => anyhow::bail!(
            "unknown experiment {other:?}; see DESIGN.md §5 for the index"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plans_mirror_the_paper_protocol() {
        let mezo = plan_for(MethodKind::MeZo, 100, 5);
        assert_eq!(mezo.steps, 500, "MeZO runs zo_mult x the FO budget");
        assert!(mezo.opt.lr < 1e-3);
        let addax = plan_for(MethodKind::Addax, 100, 5);
        assert_eq!(addax.steps, 100);
        assert_eq!((addax.opt.k0, addax.opt.k1), (6, 4));
        let zs = plan_for(MethodKind::ZeroShot, 100, 5);
        assert_eq!(zs.steps, 0);
    }

    #[test]
    fn shared_cells_share_run_ids() {
        // The same (method, task, seed) cell requested by two experiments
        // must resolve to the same run id — that is the dedup/caching
        // contract of the manifest.
        let h = Harness::new("tiny", true);
        let plan = plan_for(MethodKind::IpSgd, 300, 1);
        let cell = CellSpec {
            task: "rte",
            plan: &plan,
            seed: 0,
            geometry: "opt-13b",
            catalog: "opt",
            lt_auto: false,
            price_lt: 0,
        };
        let a = h.cell_spec(&cell);
        let b = h.cell_spec(&cell);
        assert_eq!(a.run_id, b.run_id);
    }
}
