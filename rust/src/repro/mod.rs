//! The experiment harness: one subcommand per paper table/figure.
//!
//! Every harness prints the same rows/series the paper reports and writes
//! `results/<id>.json` + `results/<id>.md`. Large-model memory columns
//! come from the analytic memory model at the paper's geometries; accuracy
//! and wall-clock columns come from real training runs of the same
//! algorithms at laptop scale (DESIGN.md §3 records the substitution).

pub mod figures;
pub mod tables;
pub mod theory_exp;

use std::collections::BTreeMap;

use anyhow::Result;

use crate::coordinator::{evaluate, train, RunResult, TrainConfig};
use crate::data::{Dataset, TaskDef};
use crate::jsonlite::{obj, Json};
use crate::metrics::write_result;
use crate::optim::{Adam, Addax, IpSgd, MeZo, Optimizer, Sgd};
use crate::runtime::manifest::default_artifacts_dir;
use crate::runtime::XlaExec;

/// Methods compared in the OPT tables.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MethodKind {
    ZeroShot,
    MeZo,
    Sgd,
    IpSgd,
    Adam,
    Addax,
}

impl MethodKind {
    pub fn label(&self) -> &'static str {
        match self {
            MethodKind::ZeroShot => "Zero-shot",
            MethodKind::MeZo => "MeZO",
            MethodKind::Sgd => "SGD",
            MethodKind::IpSgd => "IP-SGD",
            MethodKind::Adam => "Adam",
            MethodKind::Addax => "Addax",
        }
    }
}

/// Laptop-scale hyper-parameters per method (tuned on the `tiny` preset;
/// the *relative* settings mirror App. D.5: MeZO gets a much smaller lr
/// and many more steps, Addax uses (K¹,K⁰) = (4,6)).
pub struct RunPlan {
    pub steps: usize,
    pub make: Box<dyn Fn() -> Box<dyn Optimizer>>,
}

/// Build the per-method plan. `base_steps` is the FO-method step count;
/// MeZO runs `zo_mult ×` that (paper: 20k vs 1k).
pub fn plan_for(method: MethodKind, base_steps: usize, zo_mult: usize) -> RunPlan {
    match method {
        MethodKind::ZeroShot => RunPlan { steps: 0, make: Box::new(|| Box::new(IpSgd::new(0.0, 1))) },
        MethodKind::MeZo => RunPlan {
            steps: base_steps * zo_mult,
            make: Box::new(|| Box::new(MeZo::new(3e-4, 1e-3, 16))),
        },
        MethodKind::Sgd => RunPlan {
            steps: base_steps,
            make: Box::new(|| Box::new(Sgd::new(7e-2, 16, Some(1.0)))),
        },
        MethodKind::IpSgd => RunPlan {
            steps: base_steps,
            make: Box::new(|| Box::new(IpSgd::new(7e-2, 4))),
        },
        MethodKind::Adam => RunPlan {
            steps: base_steps,
            make: Box::new(|| Box::new(Adam::new(5e-3, 8))),
        },
        MethodKind::Addax => RunPlan {
            steps: base_steps,
            make: Box::new(|| Box::new(Addax::new(7e-2, 1e-3, 0.03, 6, 4))),
        },
    }
}

/// A lazily-created, shared XLA execution context per model key.
pub struct Harness {
    execs: BTreeMap<String, XlaExec>,
    pub fast: bool,
    pub model_key: String,
    cache: BTreeMap<String, Json>,
    cache_path: std::path::PathBuf,
}

impl Harness {
    pub fn new(model_key: &str, fast: bool) -> Self {
        let cache_path = std::path::PathBuf::from("results/runs_cache.json");
        let cache = std::fs::read_to_string(&cache_path)
            .ok()
            .and_then(|t| Json::parse(&t).ok())
            .and_then(|j| j.as_obj().ok().cloned())
            .unwrap_or_default();
        Self { execs: BTreeMap::new(), fast, model_key: model_key.to_string(), cache, cache_path }
    }

    pub fn exec(&mut self, key: &str) -> Result<&mut XlaExec> {
        if !self.execs.contains_key(key) {
            let e = XlaExec::new(&default_artifacts_dir(), key)?;
            self.execs.insert(key.to_string(), e);
        }
        Ok(self.execs.get_mut(key).unwrap())
    }

    fn save_cache(&self) {
        std::fs::create_dir_all("results").ok();
        let j = Json::Obj(self.cache.clone());
        std::fs::write(&self.cache_path, j.dump()).ok();
    }

    /// Train (or fetch cached) one (model, task, method) cell and return
    /// (test_acc, test_f1, time_to_best_secs, steps, best_val_step).
    pub fn run_cell(
        &mut self,
        model_key: &str,
        task: &TaskDef,
        method: MethodKind,
        base_steps: usize,
        zo_mult: usize,
        seed: u64,
    ) -> Result<CellResult> {
        // `rngv2` = counter-addressed block noise + Lemire next_below:
        // trajectories differ from the original sequential-stream scheme,
        // so pre-rework cache entries must miss, not be served as current.
        let cache_key = format!(
            "rngv2|{model_key}|{}|{:?}|{base_steps}|{zo_mult}|{seed}",
            task.name, method
        );
        if let Some(v) = self.cache.get(&cache_key) {
            if let Ok(c) = CellResult::from_json(v) {
                return Ok(c);
            }
        }
        let plan = plan_for(method, base_steps, zo_mult);
        let exec = self.exec(model_key)?;
        let entry = exec.entry().clone();
        let ds = Dataset::generate(task, entry.vocab, Some(entry.max_len), seed, 1000, 300, 500);
        let mut params = exec.load_initial_params()?;
        let cell = if method == MethodKind::ZeroShot {
            let ev = evaluate(exec, &params, &ds.test, 500)?;
            CellResult {
                test_acc: ev.accuracy,
                test_f1: ev.macro_f1,
                time_to_best: 0.0,
                steps: 0,
                best_val_step: 0,
            }
        } else {
            let mut opt = (plan.make)();
            let cfg = TrainConfig {
                steps: plan.steps,
                eval_every: (plan.steps / 20).max(1),
                seed,
                eval_examples: 120,
                log_path: None,
                verbose: false,
                noise_workers: 0,
            };
            // L_T: Addax partitions at the task's scaled 60th percentile
            // when the task is long; others never partition.
            let lt = if method == MethodKind::Addax && task.long {
                let mut lens: Vec<usize> =
                    ds.train.iter().map(|e| e.context.len() + 1).collect();
                lens.sort_unstable();
                lens[lens.len() * 6 / 10]
            } else {
                usize::MAX
            };
            let r = train(exec, &mut params, &mut *opt, &ds, lt, &cfg)?;
            CellResult {
                test_acc: r.test_acc,
                test_f1: r.test_f1,
                time_to_best: r.time_to_best_secs,
                steps: r.steps,
                best_val_step: r.best_val_step,
            }
        };
        self.cache.insert(cache_key, cell.to_json());
        self.save_cache();
        Ok(cell)
    }

    /// Full RunResult (uncached) for curve experiments.
    pub fn run_curves(
        &mut self,
        model_key: &str,
        task: &TaskDef,
        opt: &mut dyn Optimizer,
        steps: usize,
        lt: usize,
        seed: u64,
    ) -> Result<RunResult> {
        let exec = self.exec(model_key)?;
        let entry = exec.entry().clone();
        let ds = Dataset::generate(task, entry.vocab, Some(entry.max_len), seed, 1000, 300, 500);
        let mut params = exec.load_initial_params()?;
        let cfg = TrainConfig {
            steps,
            eval_every: (steps / 20).max(1),
            seed,
            eval_examples: 120,
            log_path: None,
            verbose: false,
            noise_workers: 0,
        };
        train(exec, &mut params, &mut *opt, &ds, lt, &cfg)
    }
}

/// One accuracy/time cell of a results table.
#[derive(Clone, Copy, Debug)]
pub struct CellResult {
    pub test_acc: f64,
    pub test_f1: f64,
    pub time_to_best: f64,
    pub steps: usize,
    pub best_val_step: usize,
}

impl CellResult {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("test_acc", Json::from(self.test_acc)),
            ("test_f1", Json::from(self.test_f1)),
            ("time_to_best", Json::from(self.time_to_best)),
            ("steps", Json::from(self.steps)),
            ("best_val_step", Json::from(self.best_val_step)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            test_acc: v.get("test_acc")?.as_f64()?,
            test_f1: v.get("test_f1")?.as_f64()?,
            time_to_best: v.get("time_to_best")?.as_f64()?,
            steps: v.get("steps")?.as_usize()?,
            best_val_step: v.get("best_val_step")?.as_usize()?,
        })
    }
}

/// Write a report (markdown) + raw JSON under results/, echo to stdout.
pub fn emit(id: &str, markdown: &str, raw: Json) -> Result<()> {
    std::fs::create_dir_all("results")?;
    std::fs::write(format!("results/{id}.md"), markdown)?;
    write_result(id, &raw)?;
    println!("{markdown}");
    println!("[repro] wrote results/{id}.md and results/{id}.json");
    Ok(())
}

/// Dispatch one experiment id.
pub fn run(id: &str, harness: &mut Harness) -> Result<()> {
    match id {
        "fig3" => figures::fig3(harness),
        "fig4" => figures::fig4(),
        "fig5" => figures::fig5(harness),
        "fig6" => figures::fig6(),
        "fig8" => figures::fig8(harness),
        "fig11" => figures::fig11(harness),
        "table11" => tables::table11(harness),
        "table12" | "fig1" => tables::table12(harness),
        "table13" | "fig2" | "table1" => tables::table13(harness),
        "table14" | "fig10" | "table2" => tables::table14(harness),
        "table15" | "table3" => tables::table15(harness),
        "theory" => theory_exp::run(harness.fast),
        "all" => {
            for id in [
                "fig3", "fig4", "fig5", "fig6", "fig8", "fig11", "theory", "table11",
                "table12", "table13", "table14", "table15",
            ] {
                println!("\n===== repro {id} =====");
                run(id, harness)?;
            }
            Ok(())
        }
        other => anyhow::bail!(
            "unknown experiment {other:?}; see DESIGN.md §5 for the index"
        ),
    }
}
