//! Figures 3, 4, 5, 6, 8, 11.
//!
//! Measured panels go through the sweep scheduler like the tables: cells
//! expand to `RunSpec`s, one packed sweep executes whatever the manifest
//! is missing, and the figure renders from manifest rows (curves
//! included — they are stored per run id). Analytic panels (fig4, fig6,
//! fig5-left) stay closed-form.

use anyhow::Result;

use crate::data::{self, generate, Example};
use crate::jsonlite::{obj, Json};
use crate::memory::{footprint, geometry, Dtype, Method, Workload, BS_GRID};
use crate::metrics::Table;
use crate::optim::OptSpec;
use crate::sched::RunSpec;
use crate::zorng::NoiseStream;

use super::{emit, plan_for, CellSpec, Harness, MethodKind, RunPlan};

/// The paper's fp16 weight-storage profile: 2 bytes/element (bf16 here).
const FP16: Dtype = Dtype::Bf16;

/// Shorthand: a sealed spec for one figure cell on the harness backend.
fn fig_cell(h: &Harness, task: &str, opt: OptSpec, steps: usize, seed: u64) -> RunSpec {
    let plan = RunPlan { steps, opt };
    h.cell_spec(&CellSpec {
        task,
        plan: &plan,
        seed,
        geometry: "opt-13b",
        catalog: "opt",
        lt_auto: false,
        price_lt: 0,
    })
}

/// Figure 3. Left: memory vs batch size (OPT-13B, L=300) for IP-SGD vs
/// MeZO. Right: IP-SGD with small batches vs Adam on RTE/CB/COPA.
pub fn fig3(h: &mut Harness) -> Result<()> {
    // Left panel: the memory sweep.
    let mut left = Table::new(&["batch", "IP-SGD GB", "MeZO GB"]);
    let mut raw_left = Vec::new();
    for &b in BS_GRID {
        let ip = footprint(&geometry::OPT_13B, Method::IpSgd, Workload::fo(b, 300), FP16);
        let mz = footprint(&geometry::OPT_13B, Method::MeZo, Workload::zo(b, 300), FP16);
        left.row(vec![b.to_string(), format!("{:.1}", ip.gb()), format!("{:.1}", mz.gb())]);
        raw_left.push(obj(vec![
            ("batch", Json::from(b)),
            ("ip_sgd_gb", Json::from(ip.gb())),
            ("mezo_gb", Json::from(mz.gb())),
        ]));
    }
    // Paper anchor: with a 30 GB budget, MeZO can run BS=18 while IP-SGD
    // only BS=2.
    let budget = 30e9;
    let max_ip = BS_GRID
        .iter()
        .rev()
        .find(|&&b| {
            footprint(&geometry::OPT_13B, Method::IpSgd, Workload::fo(b, 300), FP16).total
                <= budget
        })
        .copied();
    let max_mz = BS_GRID
        .iter()
        .rev()
        .find(|&&b| {
            footprint(&geometry::OPT_13B, Method::MeZo, Workload::zo(b, 300), FP16).total
                <= budget
        })
        .copied();

    // Right panel: IP-SGD (small batch, fp16) vs Adam (fp32) accuracy —
    // cells shared with table12 via the manifest.
    let base_steps = if h.fast { 300 } else { 600 };
    let tasks = ["rte", "cb", "copa"];
    let ip_plan = plan_for(MethodKind::IpSgd, base_steps, 1);
    let adam_plan = plan_for(MethodKind::Adam, base_steps, 1);
    let mut specs: Vec<RunSpec> = Vec::new();
    for tname in tasks {
        specs.push(fig_cell(h, tname, ip_plan.opt.clone(), ip_plan.steps, 0));
        specs.push(fig_cell(h, tname, adam_plan.opt.clone(), adam_plan.steps, 0));
    }
    let rows = h.runs(specs.clone())?;

    let mut right = Table::new(&["task", "IP-SGD acc", "Adam acc", "IP-SGD GB", "Adam GB"]);
    let mut raw_right = Vec::new();
    for (i, tname) in tasks.iter().enumerate() {
        let task = *data::opt_task(tname).unwrap();
        let ip_acc = rows[&specs[2 * i].run_id].outcome.test_acc;
        let adam_acc = rows[&specs[2 * i + 1].run_id].outcome.test_acc;
        let l = task.lengths.l_max;
        let ip_mem = footprint(&geometry::OPT_13B, Method::IpSgd, Workload::fo(2, l), FP16);
        let adam_mem = footprint(&geometry::OPT_13B, Method::Adam, Workload::fo(8, l), Dtype::F32);
        right.row(vec![
            tname.to_string(),
            format!("{:.1}", 100.0 * ip_acc),
            format!("{:.1}", 100.0 * adam_acc),
            format!("{:.1}", ip_mem.gb()),
            format!("{:.0}", adam_mem.gb()),
        ]);
        raw_right.push(obj(vec![
            ("task", Json::from(*tname)),
            ("ip_sgd_acc", Json::from(ip_acc)),
            ("adam_acc", Json::from(adam_acc)),
        ]));
    }
    let md = format!(
        "# fig3 — memory vs batch size; IP-SGD vs Adam\n\n## Left: OPT-13B, \
         L=300\n{}\nWith a 30 GB budget: max MeZO batch = {:?}, max IP-SGD \
         batch = {:?} (paper: 18 vs 2).\n\n## Right: small-batch IP-SGD vs \
         Adam (accuracy measured at laptop scale, memory simulated at \
         OPT-13B scale)\n{}\n",
        left.render(),
        max_mz,
        max_ip,
        right.render()
    );
    emit(
        "fig3",
        &md,
        obj(vec![("left", Json::Arr(raw_left)), ("right", Json::Arr(raw_right))]),
    )
}

/// Figure 4: memory vs sequence length at fixed batch 8 (OPT-13B).
pub fn fig4() -> Result<()> {
    let mut tbl = Table::new(&["seq len", "SGD GB", "IP-SGD GB", "MeZO GB"]);
    let mut raw = Vec::new();
    for l in (100..=700).step_by(100) {
        let sgd = footprint(&geometry::OPT_13B, Method::Sgd, Workload::fo(8, l), FP16);
        let ip = footprint(&geometry::OPT_13B, Method::IpSgd, Workload::fo(8, l), FP16);
        let mz = footprint(&geometry::OPT_13B, Method::MeZo, Workload::zo(8, l), FP16);
        tbl.row(vec![
            l.to_string(),
            format!("{:.1}", sgd.gb()),
            format!("{:.1}", ip.gb()),
            format!("{:.1}", mz.gb()),
        ]);
        raw.push(obj(vec![
            ("len", Json::from(l)),
            ("sgd_gb", Json::from(sgd.gb())),
            ("ip_sgd_gb", Json::from(ip.gb())),
            ("mezo_gb", Json::from(mz.gb())),
        ]));
    }
    let md = format!(
        "# fig4 — memory vs sequence length (OPT-13B, batch 8)\n\nIP-SGD \
         grows superlinearly (stored activations + attention matrices), \
         MeZO grows gently — the observation behind Addax's data \
         assignment.\n\n{}\n",
        tbl.render()
    );
    emit("fig4", &md, Json::Arr(raw))
}

/// Figure 5. Left: a double-well loss and its Gaussian smoothing (the
/// regularization view of §3.3). Right: accuracy vs K⁰ at fixed K¹=4
/// (K⁰=0 is plain IP-SGD).
pub fn fig5(h: &mut Harness) -> Result<()> {
    // Left: f(x) = x⁴ − 3x² + 0.5x has a sharp spurious minimum; its
    // smoothing E_z f(x+εz) lifts/flattens it. Monte-Carlo smoothing.
    let f = |x: f64| x.powi(4) - 3.0 * x * x + 0.5 * x;
    let mut left = Table::new(&["x", "f(x)", "smoothed (eps=0.6)"]);
    let mut raw_left = Vec::new();
    let mut noise = NoiseStream::new(17);
    let zs: Vec<f64> = (0..4000).map(|_| noise.next_normal() as f64).collect();
    let mut x = -2.2;
    while x <= 2.2 + 1e-9 {
        let smooth: f64 =
            zs.iter().map(|z| f(x + 0.6 * z)).sum::<f64>() / zs.len() as f64;
        left.row(vec![format!("{x:.1}"), format!("{:.2}", f(x)), format!("{smooth:.2}")]);
        raw_left.push(obj(vec![
            ("x", Json::from(x)),
            ("f", Json::from(f(x))),
            ("smoothed", Json::from(smooth)),
        ]));
        x += 0.2;
    }

    // Right: K⁰ sweep at fixed K¹ = 4 on sst2 + rte.
    let steps = if h.fast { 300 } else { 600 };
    let k0s = [0usize, 2, 4, 8, 16];
    let tasks = ["sst2", "rte"];
    let opt_for = |k0: usize| -> OptSpec {
        if k0 == 0 {
            // Addax with K⁰=0 degenerates to IP-SGD (paper Fig. 5).
            OptSpec { lr: 7e-2, batch: 4, ..OptSpec::named("ip-sgd") }
        } else {
            OptSpec { lr: 7e-2, eps: 1e-3, alpha: 0.03, k0, k1: 4, ..OptSpec::named("addax") }
        }
    };
    let mut specs = Vec::new();
    for &k0 in &k0s {
        for tname in tasks {
            specs.push((k0, tname, fig_cell(h, tname, opt_for(k0), steps, 1)));
        }
    }
    let rows = h.runs(specs.iter().map(|(_, _, r)| r.clone()).collect())?;

    let mut right = Table::new(&["K0", "sst2 acc", "rte acc"]);
    let mut raw_right = Vec::new();
    for &k0 in &k0s {
        let mut accs = Vec::new();
        for (_, _, rs) in specs.iter().filter(|(k, _, _)| *k == k0) {
            accs.push(rows[&rs.run_id].outcome.test_acc);
        }
        right.row(vec![
            k0.to_string(),
            format!("{:.1}", 100.0 * accs[0]),
            format!("{:.1}", 100.0 * accs[1]),
        ]);
        raw_right.push(obj(vec![
            ("k0", Json::from(k0)),
            ("sst2", Json::from(accs[0])),
            ("rte", Json::from(accs[1])),
        ]));
    }
    let md = format!(
        "# fig5 — ZO gradients as regularization\n\n## Left: Gaussian \
         smoothing of a double-well objective\n{}\n## Right: accuracy vs \
         K⁰ (K¹=4 fixed; K⁰=0 ⇒ IP-SGD)\n{}\n",
        left.render(),
        right.render()
    );
    emit(
        "fig5",
        &md,
        obj(vec![("left", Json::Arr(raw_left)), ("right", Json::Arr(raw_right))]),
    )
}

/// Figure 6: sequence-length histograms per dataset (unscaled lengths).
pub fn fig6() -> Result<()> {
    let mut raw = Vec::new();
    let mut md = String::from(
        "# fig6 — sequence-length histograms (synthetic tasks, unscaled)\n\n",
    );
    for t in data::OPT_TASKS {
        let ex = generate(t, 2000, 65536, None, 42);
        let lens: Vec<usize> = ex.iter().map(Example::len).collect();
        let max = *lens.iter().max().unwrap();
        let mut hist = vec![0usize; 10];
        for &l in &lens {
            let b = ((l * 10) / (max + 1)).min(9);
            hist[b] += 1;
        }
        let mut sorted = lens.clone();
        sorted.sort_unstable();
        let med = sorted[sorted.len() / 2];
        md.push_str(&format!(
            "- **{}**: L_max={}, median={}, histogram {:?}\n",
            t.name, max, med, hist
        ));
        raw.push(obj(vec![
            ("task", Json::from(t.name)),
            ("l_max", Json::from(max)),
            ("median", Json::from(med)),
            ("hist", Json::from(hist.clone())),
        ]));
    }
    md.push_str(
        "\nAll distributions are right-skewed log-normals: few long \
         examples dominate the memory budget (MultiRC L_max=739 as in the \
         paper).\n",
    );
    emit("fig6", &md, Json::Arr(raw))
}

/// Figures 8/9: accuracy heatmap over (α, K¹/(K⁰+K¹)).
pub fn fig8(h: &mut Harness) -> Result<()> {
    let steps = if h.fast { 200 } else { 400 };
    let alphas: &[f32] = if h.fast {
        &[1e-3, 1e-2, 1e-1]
    } else {
        &[3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 1e-1]
    };
    let ratios: &[f64] = if h.fast { &[0.125, 0.25, 0.5] } else { &[0.1, 0.2, 0.3, 0.4, 0.5] };
    let total = 16usize; // K⁰ + K¹ fixed (paper uses 64 on RoBERTa)

    let mut specs = Vec::new();
    for &a in alphas {
        for &r in ratios {
            let k1 = ((total as f64 * r).round() as usize).max(1);
            let k0 = (total - k1).max(1);
            let opt = OptSpec { lr: 7e-2, eps: 1e-3, alpha: a, k0, k1, ..OptSpec::named("addax") };
            specs.push((a, r, fig_cell(h, "sst2", opt, steps, 2)));
        }
    }
    let rows = h.runs(specs.iter().map(|(_, _, r)| r.clone()).collect())?;

    let ratio_labels: Vec<String> = ratios.iter().map(|r| format!("{r:.2}")).collect();
    let header: Vec<&str> = std::iter::once("alpha \\ K1/(K0+K1)")
        .chain(ratio_labels.iter().map(String::as_str))
        .collect();
    let mut tbl = Table::new(&header);
    let mut raw = Vec::new();
    for &a in alphas {
        let mut row = vec![format!("{a:.0e}")];
        for (_, r, rs) in specs.iter().filter(|(sa, _, _)| *sa == a) {
            let acc = rows[&rs.run_id].outcome.test_acc;
            row.push(format!("{:.1}", 100.0 * acc));
            raw.push(obj(vec![
                ("alpha", Json::from(a as f64)),
                ("ratio", Json::from(*r)),
                ("acc", Json::from(acc)),
            ]));
        }
        tbl.row(row);
    }
    let md = format!(
        "# fig8 — Addax accuracy vs (α, K¹/(K⁰+K¹)) on sst2 (K⁰+K¹ = {total})\n\n{}\n\
         Paper finding to compare: accuracy improves with the K¹ ratio; no \
         consistent trend in α.\n",
        tbl.render()
    );
    emit("fig8", &md, Json::Arr(raw))
}

/// Figure 11: convergence curves — Addax (K¹,K⁰)=(4,12) vs MeZO / SGD
/// with batch 16. Curves come straight off the manifest rows.
pub fn fig11(h: &mut Harness) -> Result<()> {
    let steps = if h.fast { 300 } else { 600 };
    let zo_mult = if h.fast { 3 } else { 5 };
    let tasks = ["sst2", "boolq"];

    let mut specs = Vec::new();
    for tname in tasks {
        let addax =
            OptSpec { lr: 7e-2, eps: 1e-3, alpha: 0.03, k0: 12, k1: 4, ..OptSpec::named("addax") };
        let sgd = OptSpec { lr: 7e-2, batch: 16, clip: 1.0, ..OptSpec::named("sgd") };
        let mezo = OptSpec { lr: 3e-4, eps: 1e-3, batch: 16, ..OptSpec::named("mezo") };
        specs.push((tname, "addax", fig_cell(h, tname, addax, steps, 3)));
        specs.push((tname, "sgd", fig_cell(h, tname, sgd, steps, 3)));
        specs.push((tname, "mezo", fig_cell(h, tname, mezo, steps * zo_mult, 3)));
    }
    let rows = h.runs(specs.iter().map(|(_, _, r)| r.clone()).collect())?;
    let curve = |task: &str, opt: &str| {
        let rs = specs.iter().find(|(t, o, _)| *t == task && *o == opt).unwrap();
        &rows[&rs.2.run_id].outcome
    };

    let mut raw = Vec::new();
    let mut md = String::from("# fig11 — convergence speed (loss vs step)\n\n");
    for tname in tasks {
        let r_addax = curve(tname, "addax");
        let r_sgd = curve(tname, "sgd");
        let r_mezo = curve(tname, "mezo");
        // loss threshold = halfway between init and Addax's floor
        let init = r_addax.loss_curve.points.first().map(|&(_, v)| v).unwrap_or(0.0);
        let floor = r_addax.final_train_loss;
        let thr = floor + 0.3 * (init - floor);
        let s_addax = r_addax.loss_curve.first_below(thr);
        let s_sgd = r_sgd.loss_curve.first_below(thr);
        let s_mezo = r_mezo.loss_curve.first_below(thr);
        md.push_str(&format!(
            "## {tname}\n- init loss {init:.3}, threshold {thr:.3}\n\
             - steps to threshold: Addax(4,12) = {s_addax:?}, SGD(bs16) = \
             {s_sgd:?}, MeZO(bs16) = {s_mezo:?}\n- final loss: Addax {:.3}, \
             SGD {:.3}, MeZO {:.3} (MeZO ran {}x steps)\n\n",
            r_addax.final_train_loss,
            r_sgd.final_train_loss,
            r_mezo.final_train_loss,
            zo_mult
        ));
        raw.push(obj(vec![
            ("task", Json::from(tname)),
            ("threshold", Json::from(thr)),
            ("addax_curve", r_addax.loss_curve.to_json()),
            ("sgd_curve", r_sgd.loss_curve.to_json()),
            ("mezo_curve", r_mezo.loss_curve.to_json()),
        ]));
    }
    md.push_str(
        "Expected shape (paper): Addax with 4× fewer FO samples tracks SGD's \
         convergence; MeZO needs orders of magnitude more steps.\n",
    );
    emit("fig11", &md, Json::Arr(raw))
}
