//! Tables 11-15 (and their figure twins 1, 2, 7, 10).
//!
//! Each table combines:
//!  * **memory + batch-size columns** — analytic footprints at the paper's
//!    model geometry and device, with the App. D.6 grid search (OOM = `*`);
//!  * **accuracy / time columns** — measured runs of the same algorithms
//!    at laptop scale, executed by the sweep scheduler: every non-OOM cell
//!    becomes a `RunSpec`, the whole batch is packed onto the simulated
//!    device budget and run concurrently, and the table renders from the
//!    resulting manifest rows. A complete manifest regenerates the table
//!    with zero training; wall-clock columns come from the timing side
//!    file and render `-` when only the manifest is available.

use anyhow::Result;

use crate::data::{self, TaskDef};
use crate::jsonlite::{obj, Json};
use crate::memory::{
    footprint, geometry, max_batch_in_grid, Device, Dtype, Method, Workload,
};
use crate::metrics::Table;
use crate::sched::RunSpec;

use super::{emit, plan_for, CellSpec, Harness, MethodKind};

/// The paper's fp16 weight-storage profile: 2 bytes/element (bf16 here).
const FP16: Dtype = Dtype::Bf16;

/// Addax's (K¹, K⁰) across all OPT tables (App. D.6).
const K1: usize = 4;
const K0: usize = 6;

struct TableSpec {
    id: &'static str,
    title: &'static str,
    geometry: geometry::ModelGeometry,
    device: Device,
    tasks: &'static [&'static str],
    /// Addax L_T at the paper scale.
    lt: usize,
    include_adam: bool,
}

fn memory_cell(
    spec: &TableSpec,
    task: &TaskDef,
    method: MethodKind,
) -> (String, String) {
    // returns (memory GB or "*", batch size string)
    let g = &spec.geometry;
    let l = task.lengths.l_max;
    match method {
        MethodKind::ZeroShot => ("-".into(), "-".into()),
        MethodKind::Adam => {
            let f = footprint(g, Method::Adam, Workload::fo(8, l), Dtype::F32);
            (format!("{:.0}", f.gb()), "8".into())
        }
        MethodKind::Addax => {
            let zo_len = l;
            let fo_len = spec.lt.min(l);
            let wl = Workload::mixed(K1, fo_len, K0, zo_len);
            let f = footprint(g, Method::Addax, wl, FP16);
            if f.total <= spec.device.total_bytes() {
                (format!("{:.1}", f.gb()), format!("({K1},{K0})"))
            } else {
                ("*".into(), "*".into())
            }
        }
        _ => {
            let m = match method {
                MethodKind::MeZo => Method::MeZo,
                MethodKind::Sgd => Method::Sgd,
                MethodKind::IpSgd => Method::IpSgd,
                _ => unreachable!(),
            };
            match max_batch_in_grid(g, m, l, &spec.device, FP16) {
                None => ("*".into(), "*".into()),
                Some(b) => {
                    let wl = match m {
                        Method::MeZo => Workload::zo(b, l),
                        _ => Workload::fo(b, l),
                    };
                    let f = footprint(g, m, wl, FP16);
                    (format!("{:.1}", f.gb()), b.to_string())
                }
            }
        }
    }
}

/// One rendered cell: the analytic columns plus (for non-OOM cells) the
/// sealed run spec whose manifest row supplies accuracy/time.
struct Cell {
    method: MethodKind,
    task: &'static str,
    mem: String,
    bs: String,
    run: Option<RunSpec>,
}

fn render_opt_table(spec: &TableSpec, h: &mut Harness) -> Result<()> {
    let base_steps = if h.fast { 300 } else { 600 };
    let zo_mult = if h.fast { 3 } else { 5 };
    let methods = if spec.include_adam {
        vec![
            MethodKind::ZeroShot,
            MethodKind::MeZo,
            MethodKind::Sgd,
            MethodKind::IpSgd,
            MethodKind::Adam,
            MethodKind::Addax,
        ]
    } else {
        vec![
            MethodKind::ZeroShot,
            MethodKind::MeZo,
            MethodKind::Sgd,
            MethodKind::IpSgd,
            MethodKind::Addax,
        ]
    };

    // Phase 1: analytic columns + the run list (OOM cells never run —
    // that is the paper's `*`).
    let mut cells: Vec<Cell> = Vec::new();
    for method in &methods {
        for tname in spec.tasks {
            let task = *data::opt_task(tname).expect("task");
            let (mem, bs) = memory_cell(spec, &task, *method);
            let run = if mem == "*" {
                None
            } else {
                let plan = plan_for(*method, base_steps, zo_mult);
                Some(h.cell_spec(&CellSpec {
                    task: tname,
                    plan: &plan,
                    seed: 0,
                    geometry: spec.geometry.name,
                    catalog: "opt",
                    lt_auto: *method == MethodKind::Addax && task.long,
                    price_lt: spec.lt,
                }))
            };
            cells.push(Cell { method: *method, task: tname, mem, bs, run });
        }
    }

    // Phase 2: one packed, concurrent sweep over every missing cell.
    let specs: Vec<RunSpec> = cells.iter().filter_map(|c| c.run.clone()).collect();
    let rows = h.runs(specs)?;
    let times = h.times();

    // Phase 3: pure aggregation over manifest rows.
    let header: Vec<&str> = [&["method"][..], spec.tasks].concat();
    let mut acc_tbl = Table::new(&header);
    let mut mem_tbl = Table::new(&header);
    let mut bs_tbl = Table::new(&header);
    let mut time_tbl = Table::new(&header);
    let mut raw_rows = Vec::new();

    for method in &methods {
        let mut acc_row = vec![method.label().to_string()];
        let mut mem_row = acc_row.clone();
        let mut bs_row = acc_row.clone();
        let mut time_row = acc_row.clone();
        for cell in cells.iter().filter(|c| c.method == *method) {
            mem_row.push(cell.mem.clone());
            bs_row.push(cell.bs.clone());
            let Some(run) = &cell.run else {
                acc_row.push("*".into());
                time_row.push("*".into());
                raw_rows.push(obj(vec![
                    ("method", Json::from(method.label())),
                    ("task", Json::from(cell.task)),
                    ("oom", Json::from(true)),
                ]));
                continue;
            };
            let row = &rows[&run.run_id];
            let time_to_best = times.get(&run.run_id).map(|&(_, b)| b);
            acc_row.push(format!("{:.1}", 100.0 * row.outcome.test_acc));
            time_row.push(match (*method, time_to_best) {
                (MethodKind::ZeroShot, _) | (_, None) => "-".into(),
                (_, Some(b)) => format!("{:.1}m", b / 60.0),
            });
            raw_rows.push(obj(vec![
                ("method", Json::from(method.label())),
                ("task", Json::from(cell.task)),
                ("run_id", Json::from(run.run_id.clone())),
                ("acc", Json::from(row.outcome.test_acc)),
                ("f1", Json::from(row.outcome.test_f1)),
                ("time_to_best_secs", Json::from(time_to_best.unwrap_or(0.0))),
                ("steps", Json::from(row.outcome.steps)),
                ("mem_gb", Json::from(cell.mem.clone())),
                ("bs", Json::from(cell.bs.clone())),
            ]));
        }
        acc_tbl.row(acc_row);
        mem_tbl.row(mem_row);
        bs_tbl.row(bs_row);
        time_tbl.row(time_row);
    }

    let md = format!(
        "# {} — {}\n\nGeometry: {} on {}×{} ({} GB total). Memory/BS from the \
         analytic model + App. D.6 grid; accuracy & time measured at laptop \
         scale (model `{}`, {} backend, {} FO steps, MeZO ×{}) via the sweep \
         scheduler's manifest. Precision: memory columns price the paper's \
         fp16 profile — `{}` weight storage, {} B/param (Adam fp32); the \
         laptop-scale cells train `{}` stores. `*` = OOM even at the \
         smallest grid batch; time `-` = no timing telemetry (table \
         regenerated from the manifest alone).\n\n## Accuracy / F1 (%)\n{}\n\
         ## Simulated memory (GB)\n{}\n\
         ## Batch size (grid-searched)\n{}\n## Wall-clock to best validation\n{}\n",
        spec.id,
        spec.title,
        spec.geometry.name,
        spec.device.count,
        spec.device.name,
        spec.device.total_bytes() / 1e9,
        h.model_key,
        h.backend.label(),
        base_steps,
        zo_mult,
        FP16.label(),
        FP16.bytes(),
        Dtype::F32.label(),
        acc_tbl.render(),
        mem_tbl.render(),
        bs_tbl.render(),
        time_tbl.render()
    );
    emit(spec.id, &md, Json::Arr(raw_rows))
}

/// Table 12 / Figure 1: OPT-13B on one A100-40GB, nine tasks.
pub fn table12(h: &mut Harness) -> Result<()> {
    render_opt_table(
        &TableSpec {
            id: "table12",
            title: "OPT-13B, 1×A100-40GB (Fig. 1)",
            geometry: geometry::OPT_13B,
            device: Device::a100_40(1),
            tasks: &["sst2", "rte", "cb", "boolq", "wsc", "wic", "multirc", "record", "squad"],
            lt: 170,
            include_adam: true,
        },
        h,
    )
}

/// Table 13 / Figure 2 / Table 1: OPT-30B on one H100-80GB.
pub fn table13(h: &mut Harness) -> Result<()> {
    render_opt_table(
        &TableSpec {
            id: "table13",
            title: "OPT-30B, 1×H100-80GB (Fig. 2, Table 1 aggregates below)",
            geometry: geometry::OPT_30B,
            device: Device::h100_80(1),
            tasks: &["sst2", "rte", "boolq", "wsc", "wic", "multirc", "squad"],
            lt: 180,
            include_adam: false,
        },
        h,
    )?;
    summarize_short_long("table1", "OPT-30B summary (Table 1)", "table13")
}

/// Table 14 / Figure 10 / Table 2: OPT-66B on three H100s.
pub fn table14(h: &mut Harness) -> Result<()> {
    render_opt_table(
        &TableSpec {
            id: "table14",
            title: "OPT-66B, 3×H100-80GB (Fig. 10, Table 2 aggregates below)",
            geometry: geometry::OPT_66B,
            device: Device::h100_80(3),
            tasks: &["sst2", "rte", "boolq", "wsc", "wic", "multirc", "squad"],
            lt: 260,
            include_adam: false,
        },
        h,
    )?;
    summarize_short_long("table2", "OPT-66B summary (Table 2)", "table14")
}

/// Table 15 / Table 3: Llama-2-70B on three H100s.
pub fn table15(h: &mut Harness) -> Result<()> {
    render_opt_table(
        &TableSpec {
            id: "table15",
            title: "Llama-2-70B, 3×H100-80GB (Table 3 aggregates below)",
            geometry: geometry::LLAMA2_70B,
            device: Device::h100_80(3),
            tasks: &["rte", "boolq", "wsc", "wic", "multirc", "squad"],
            lt: 240,
            include_adam: false,
        },
        h,
    )?;
    summarize_short_long("table3", "Llama-2-70B summary (Table 3)", "table15")
}

/// Tables 1-3 are short/long-dataset aggregates of the detail tables.
fn summarize_short_long(id: &str, title: &str, detail_id: &str) -> Result<()> {
    let raw = std::fs::read_to_string(format!("results/{detail_id}.json"))?;
    let rows = Json::parse(&raw)?;
    let mut agg: std::collections::BTreeMap<(String, bool), (f64, f64, usize)> =
        Default::default();
    for r in rows.as_arr()? {
        if r.opt("oom").is_some() {
            continue;
        }
        let method = r.get("method")?.as_str()?.to_string();
        let task = r.get("task")?.as_str()?;
        let long = data::opt_task(task).map(|t| t.long).unwrap_or(false);
        let e = agg.entry((method, long)).or_insert((0.0, 0.0, 0));
        e.0 += r.get("acc")?.as_f64()? * 100.0;
        e.1 += r.get("time_to_best_secs")?.as_f64()?;
        e.2 += 1;
    }
    let mut tbl = Table::new(&["method", "short acc", "short time", "long acc", "long time"]);
    let methods: Vec<String> = {
        let mut v: Vec<String> = agg.keys().map(|(m, _)| m.clone()).collect();
        v.dedup();
        v
    };
    let mut raw_out = Vec::new();
    for m in methods {
        let s = agg.get(&(m.clone(), false));
        let l = agg.get(&(m.clone(), true));
        let fmt = |x: Option<&(f64, f64, usize)>, acc: bool| match x {
            None => "*".to_string(),
            Some((a, t, n)) => {
                if acc {
                    format!("{:.1}", a / *n as f64)
                } else {
                    format!("{:.1}m", t / *n as f64 / 60.0)
                }
            }
        };
        tbl.row(vec![m.clone(), fmt(s, true), fmt(s, false), fmt(l, true), fmt(l, false)]);
        raw_out.push(obj(vec![
            ("method", Json::from(m.clone())),
            ("short_acc", Json::from(fmt(s, true))),
            ("long_acc", Json::from(fmt(l, true))),
        ]));
    }
    let md = format!(
        "# {id} — {title}\n\nAverages over the short vs long datasets of \
         {detail_id} (paper's Table 1-3 split; OOM cells excluded).\n\n{}\n",
        tbl.render()
    );
    emit(id, &md, Json::Arr(raw_out))
}

/// Table 11 / Figure 7: RoBERTa-large-style (mlm preset), six tasks.
pub fn table11(h: &mut Harness) -> Result<()> {
    let base_steps = if h.fast { 300 } else { 600 };
    let zo_mult = if h.fast { 3 } else { 5 };
    let tasks = ["sst2", "sst5", "snli", "mnli", "rte", "trec"];
    let methods = [
        MethodKind::ZeroShot,
        MethodKind::MeZo,
        MethodKind::Addax,
        MethodKind::Adam,
    ];

    // The mlm preset runs on the roberta catalog; keep the harness's
    // backend but pin the model key for these cells.
    let saved_model = h.model_key.clone();
    h.model_key = "mlm".to_string();
    let mut cell_specs: Vec<(MethodKind, &str, RunSpec)> = Vec::new();
    for method in methods {
        let plan = plan_for(method, base_steps, zo_mult);
        for tname in tasks {
            let rs = h.cell_spec(&CellSpec {
                task: tname,
                plan: &plan,
                seed: 0,
                geometry: "roberta-large",
                catalog: "roberta",
                lt_auto: false,
                price_lt: 0,
            });
            cell_specs.push((method, tname, rs));
        }
    }
    let rows = h.runs(cell_specs.iter().map(|(_, _, r)| r.clone()).collect());
    h.model_key = saved_model;
    let rows = rows?;

    let header: Vec<&str> = [&["method"][..], &tasks[..]].concat();
    let mut tbl = Table::new(&header);
    let mut raw = Vec::new();
    for method in methods {
        let mut row = vec![method.label().to_string()];
        for (_, tname, rs) in cell_specs.iter().filter(|(m, _, _)| *m == method) {
            let r = &rows[&rs.run_id];
            row.push(format!("{:.1}", 100.0 * r.outcome.test_acc));
            raw.push(obj(vec![
                ("method", Json::from(method.label())),
                ("task", Json::from(*tname)),
                ("run_id", Json::from(rs.run_id.clone())),
                ("acc", Json::from(r.outcome.test_acc)),
            ]));
        }
        tbl.row(row);
    }
    // RoBERTa-large memory footprint context (fp32, fits any GPU).
    let g = geometry::ROBERTA_LARGE;
    let mezo = footprint(&g, Method::MeZo, Workload::zo(64, 60), Dtype::F32);
    let adam = footprint(&g, Method::Adam, Workload::fo(8, 60), Dtype::F32);
    let md = format!(
        "# table11 — RoBERTa-large track (Fig. 7)\n\nMasked-LM preset `mlm` \
         (bidirectional), k-shot style tasks. RoBERTa-large simulated \
         footprints (f32 storage, the paper's RoBERTa precision): MeZO bs64 \
         {:.1} GB, Adam bs8 {:.1} GB.\n\n{}\n",
        mezo.gb(),
        adam.gb(),
        tbl.render()
    );
    emit("table11", &md, Json::Arr(raw))
}
