//! Config system: a TOML-subset parser + typed run configuration.
//!
//! The offline vendored crate set has no `toml`/`serde`, so we parse the
//! subset we need: `[section]` headers, `key = value` with string, float,
//! integer and boolean values, `#` comments. Keys flatten to
//! `section.key`. CLI `--set section.key=value` overrides files.
//!
//! Example (`configs/addax_small.toml`):
//! ```toml
//! [model]
//! key = "small"
//! [task]
//! name = "sst2"
//! [optim]
//! name = "addax"
//! lr = 3e-2
//! alpha = 0.05
//! k0 = 6
//! k1 = 4
//! lt = 48
//! [train]
//! steps = 400
//! seed = 0
//! ```

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::TrainConfig;
use crate::optim::{OptSpec, Optimizer};
use crate::tensor::Dtype;

/// Flat `section.key -> raw string value` map.
#[derive(Clone, Debug, Default)]
pub struct Config {
    map: BTreeMap<String, String>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Self> {
        let mut map = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let value = v.trim().trim_matches('"').to_string();
            map.insert(key, value);
        }
        Ok(Self { map })
    }

    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::parse(&text)
    }

    /// Apply a `--set key=value` override.
    pub fn set(&mut self, kv: &str) -> Result<()> {
        let (k, v) = kv.split_once('=').ok_or_else(|| anyhow!("--set wants key=value"))?;
        self.map.insert(k.trim().to_string(), v.trim().trim_matches('"').to_string());
        Ok(())
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.map.get(key).map(String::as_str)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn f32_or(&self, key: &str, default: f32) -> Result<f32> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s.parse().with_context(|| format!("{key} = {s:?} is not a float")),
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s.parse().with_context(|| format!("{key} = {s:?} is not an int")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s.parse().with_context(|| format!("{key} = {s:?} is not an int")),
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some("true") => Ok(true),
            Some("false") => Ok(false),
            Some(s) => bail!("{key} = {s:?} is not a bool"),
        }
    }

    /// Comma-separated string list (whitespace-trimmed, empties dropped).
    /// Sweep grids use these: `optimizers = "addax, mezo"`.
    pub fn list_or(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.get(key) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(s) => s
                .split(',')
                .map(str::trim)
                .filter(|t| !t.is_empty())
                .map(str::to_string)
                .collect(),
        }
    }

    /// Comma-separated f32 list.
    pub fn f32_list_or(&self, key: &str, default: &[f32]) -> Result<Vec<f32>> {
        self.list_or(key, &[])
            .iter()
            .map(|s| {
                s.parse()
                    .with_context(|| format!("{key}: {s:?} is not a float"))
            })
            .collect::<Result<Vec<f32>>>()
            .map(|v| if v.is_empty() { default.to_vec() } else { v })
    }

    /// Comma-separated u64 list.
    pub fn u64_list_or(&self, key: &str, default: &[u64]) -> Result<Vec<u64>> {
        self.list_or(key, &[])
            .iter()
            .map(|s| {
                s.parse()
                    .with_context(|| format!("{key}: {s:?} is not an int"))
            })
            .collect::<Result<Vec<u64>>>()
            .map(|v| if v.is_empty() { default.to_vec() } else { v })
    }

    // -- typed views -------------------------------------------------------

    pub fn model_key(&self) -> String {
        self.str_or("model.key", "tiny")
    }

    pub fn task_name(&self) -> String {
        self.str_or("task.name", "sst2")
    }

    /// Parameter-store precision: `[model] dtype = "f32" | "bf16"`.
    /// Defaults to f32 (the AOT dump precision); bf16 stores weights at
    /// 2 bytes with all math still in f32.
    pub fn dtype(&self) -> Result<Dtype> {
        Dtype::parse(&self.str_or("model.dtype", "f32"))
    }

    /// `L_T` threshold; 0 / absent means "no partitioning" (Addax-WA).
    pub fn lt(&self) -> Result<usize> {
        self.usize_or("optim.lt", usize::MAX)
    }

    pub fn train_config(&self) -> Result<TrainConfig> {
        Ok(TrainConfig {
            steps: self.usize_or("train.steps", 400)?,
            eval_every: self.usize_or("train.eval_every", 0)?,
            seed: self.u64_or("train.seed", 0)?,
            eval_examples: self.usize_or("train.eval_examples", 100)?,
            log_path: self.get("train.log").map(std::path::PathBuf::from),
            verbose: self.bool_or("train.verbose", true)?,
            // `[perf] noise_workers = N` pins the ZO sweep pool; 0 = auto.
            noise_workers: self.usize_or("perf.noise_workers", 0)?,
            // `[train] ckpt_dir` enables crash-safe snapshots + resume;
            // `ckpt_every` 0 = snapshot at the eval cadence.
            ckpt_dir: self
                .get("train.ckpt_dir")
                .filter(|s| !s.is_empty())
                .map(std::path::PathBuf::from),
            ckpt_every: self.usize_or("train.ckpt_every", 0)?,
            ckpt_keep: self.usize_or("train.ckpt_keep", 3)?,
            ckpt_identity: String::new(),
            halt_after: self.usize_or("train.halt_after", 0)?,
            // The probe registration is process-level wiring (`--probe-port`
            // in main.rs), not per-run config.
            probe: None,
        })
    }

    /// The declarative optimizer recipe configured under `[optim]`.
    /// Defaults are [`OptSpec::named`]'s (unchanged from the historical
    /// inline construction).
    pub fn opt_spec(&self) -> Result<OptSpec> {
        let mut o = OptSpec::named(&self.str_or("optim.name", "addax"));
        o.lr = self.f32_or("optim.lr", o.lr)?;
        o.eps = self.f32_or("optim.eps", o.eps)?;
        o.batch = self.usize_or("optim.batch", o.batch)?;
        o.alpha = self.f32_or("optim.alpha", o.alpha)?;
        o.k0 = self.usize_or("optim.k0", o.k0)?;
        o.k1 = self.usize_or("optim.k1", o.k1)?;
        o.clip = self.f32_or("optim.clip", o.clip)?;
        o.lr_zo = self.f32_or("optim.lr_zo", o.lr_zo)?;
        o.split = self.f32_or("optim.split", o.split)?;
        Ok(o)
    }

    /// Instantiate the configured optimizer.
    pub fn optimizer(&self) -> Result<Box<dyn Optimizer>> {
        self.opt_spec()?.build()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# a comment
[model]
key = "small"
[optim]
name = "addax"
lr = 3e-2
alpha = 0.05
k0 = 6
k1 = 4
lt = 48
[train]
steps = 400
verbose = false
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.model_key(), "small");
        assert_eq!(c.f32_or("optim.lr", 0.0).unwrap(), 3e-2);
        assert_eq!(c.usize_or("train.steps", 0).unwrap(), 400);
        assert!(!c.bool_or("train.verbose", true).unwrap());
        assert_eq!(c.lt().unwrap(), 48);
    }

    #[test]
    fn builds_each_optimizer() {
        for name in ["addax", "mezo", "zo-sgd", "sgd", "ip-sgd", "adam", "hybrid-zofo"] {
            let mut c = Config::parse(SAMPLE).unwrap();
            c.set(&format!("optim.name={name}")).unwrap();
            let opt = c.optimizer().unwrap();
            assert_eq!(opt.name(), name);
        }
        let mut c = Config::parse(SAMPLE).unwrap();
        c.set("optim.name=nope").unwrap();
        assert!(c.optimizer().is_err());
    }

    #[test]
    fn overrides_win() {
        let mut c = Config::parse(SAMPLE).unwrap();
        c.set("optim.lr=0.5").unwrap();
        assert_eq!(c.f32_or("optim.lr", 0.0).unwrap(), 0.5);
    }

    #[test]
    fn dtype_parses_and_defaults() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.dtype().unwrap(), Dtype::F32);
        let mut c = Config::parse("[model]\ndtype = \"bf16\"").unwrap();
        assert_eq!(c.dtype().unwrap(), Dtype::Bf16);
        c.set("model.dtype=f32").unwrap();
        assert_eq!(c.dtype().unwrap(), Dtype::F32);
        c.set("model.dtype=fp16").unwrap();
        assert!(c.dtype().is_err());
    }

    #[test]
    fn defaults_when_absent() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.model_key(), "tiny");
        assert_eq!(c.lt().unwrap(), usize::MAX);
        let t = c.train_config().unwrap();
        assert_eq!(t.steps, 400);
        assert_eq!(t.noise_workers, 0); // auto
    }

    #[test]
    fn perf_noise_workers_parses() {
        let c = Config::parse("[perf]\nnoise_workers = 4").unwrap();
        assert_eq!(c.train_config().unwrap().noise_workers, 4);
    }

    #[test]
    fn ckpt_keys_parse_and_default_off() {
        let c = Config::parse("").unwrap();
        let t = c.train_config().unwrap();
        assert_eq!(t.ckpt_dir, None);
        assert_eq!(t.ckpt_every, 0);
        assert_eq!(t.ckpt_keep, 3);
        assert_eq!(t.halt_after, 0);
        let c = Config::parse(
            "[train]\nckpt_dir = \"results/ck\"\nckpt_every = 5\nckpt_keep = 2\nhalt_after = 9",
        )
        .unwrap();
        let t = c.train_config().unwrap();
        assert_eq!(t.ckpt_dir.as_deref(), Some(std::path::Path::new("results/ck")));
        assert_eq!(t.ckpt_every, 5);
        assert_eq!(t.ckpt_keep, 2);
        assert_eq!(t.halt_after, 9);
    }

    #[test]
    fn list_helpers_split_and_default() {
        let c = Config::parse("[grid]\noptimizers = \"addax, mezo ,ip-sgd\"\nlrs = 0.07,1e-3")
            .unwrap();
        assert_eq!(c.list_or("grid.optimizers", &[]), vec!["addax", "mezo", "ip-sgd"]);
        assert_eq!(c.f32_list_or("grid.lrs", &[]).unwrap(), vec![0.07, 1e-3]);
        assert_eq!(c.list_or("grid.tasks", &["sst2"]), vec!["sst2"]);
        assert_eq!(c.u64_list_or("grid.seeds", &[0, 1]).unwrap(), vec![0, 1]);
        assert!(c.f32_list_or("grid.optimizers", &[]).is_err());
    }

    #[test]
    fn opt_spec_reads_overrides() {
        let c = Config::parse("[optim]\nname = \"addax\"\nlr = 0.07\nk0 = 12").unwrap();
        let o = c.opt_spec().unwrap();
        assert_eq!(o.name, "addax");
        assert_eq!(o.lr, 0.07);
        assert_eq!(o.k0, 12);
        assert_eq!(o.k1, 4); // default preserved
    }

    #[test]
    fn rejects_bad_lines_and_values() {
        assert!(Config::parse("not a kv line").is_err());
        let c = Config::parse("[a]\nx = zzz").unwrap();
        assert!(c.f32_or("a.x", 0.0).is_err());
        assert!(c.bool_or("a.x", false).is_err());
    }
}
