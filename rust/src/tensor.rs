//! Host-side tensors: precision-polymorphic flat buffers with shapes.
//!
//! Storage is either `f32` or `bf16` (selected per store by [`Dtype`]);
//! **all math is performed in f32** and results are rounded
//! nearest-even back to the storage precision on write — the classic
//! half-storage/full-math discipline the paper's fp16 memory profiles
//! assume. The [`Element`] trait is the codec seam: every update kernel
//! is written once, generically, as decode → f32 op → encode, and for
//! `f32` the codec compiles to the identity so the historical kernels
//! (and their bit-exact trajectories) are unchanged.
//!
//! These buffers back the parameter store and every in-place update on
//! the L3 hot path (perturbation, ZO/FO updates). The update kernels are
//! tight slice loops so LLVM auto-vectorizes them; see
//! `benches/hotpath.rs` for measured throughput and EXPERIMENTS.md
//! §Perf / §Precision. Because each element is encoded independently,
//! the parallel noise sweeps stay bit-identical at every worker count in
//! *both* precisions.

use std::borrow::Cow;

use anyhow::{bail, Result};

/// Storage precision of a [`HostTensor`] / parameter store.
///
/// `Bf16` stores bfloat16 (2 bytes/element); `F32` stores IEEE single
/// (4 bytes). The analytic memory model prices weights at
/// [`Dtype::bytes`], so the store the simulator describes is exactly the
/// store that runs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Dtype {
    #[default]
    F32,
    Bf16,
}

impl Dtype {
    /// Parse a config/CLI spelling.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" | "fp32" | "float32" => Dtype::F32,
            "bf16" | "bfloat16" => Dtype::Bf16,
            other => bail!("unknown dtype {other:?} (want f32 | bf16)"),
        })
    }

    /// Canonical label (run ids, manifests, tables).
    pub fn label(&self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::Bf16 => "bf16",
        }
    }

    /// Bytes per stored element.
    pub fn bytes(&self) -> usize {
        match self {
            Dtype::F32 => 4,
            Dtype::Bf16 => 2,
        }
    }
}

/// A bfloat16 storage element: the top 16 bits of an IEEE f32.
///
/// Same exponent range as f32 (no overflow surprises when narrowing),
/// 8 significand bits. Encoding rounds nearest, ties to even; decoding
/// is exact (bit shift). NaNs are quieted on encode so a payload can
/// never truncate to an infinity pattern.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Bf16(pub u16);

impl Bf16 {
    /// Round-to-nearest-even conversion from f32.
    #[inline]
    pub fn from_f32(v: f32) -> Self {
        let bits = v.to_bits();
        if v.is_nan() {
            // Preserve sign + payload MSBs; force the quiet bit so the
            // truncated payload cannot collapse to the inf pattern.
            return Bf16((bits >> 16) as u16 | 0x0040);
        }
        // Classic RNE on the discarded low half: adding 0x7FFF plus the
        // keep-LSB rounds halfway cases to even; the carry ripples into
        // the exponent, saturating to ±inf past the largest bf16 finite.
        let rounded = bits.wrapping_add(0x7FFF + ((bits >> 16) & 1));
        Bf16((rounded >> 16) as u16)
    }

    /// Exact widening to f32.
    #[inline]
    pub fn to_f32(self) -> f32 {
        f32::from_bits((self.0 as u32) << 16)
    }
}

/// Storage codec behind [`HostTensor`]: an element type that holds an
/// f32 value at some precision. Math happens in f32 between
/// [`Element::decode`] and [`Element::encode`]; for `f32` both are the
/// identity and the generic kernels compile to the historical code.
pub trait Element: Copy + Send + Sync + PartialEq + std::fmt::Debug + 'static {
    const DTYPE: Dtype;
    /// Bytes per element in the binary dump format.
    const BYTES: usize;

    fn encode(v: f32) -> Self;
    fn decode(self) -> f32;

    /// Read one element from `Self::BYTES` little-endian bytes.
    fn read_le(bytes: &[u8]) -> Self;
    /// Append the little-endian bytes of one element.
    fn write_le(self, out: &mut Vec<u8>);

    /// Wrap a typed buffer into the dynamic storage enum.
    fn into_data(v: Vec<Self>) -> TensorData;
    /// Typed view of dynamic storage (panics on dtype mismatch — the
    /// dispatch sites always pair matching types).
    fn slice(data: &TensorData) -> &[Self];
    fn slice_mut(data: &mut TensorData) -> &mut [Self];
}

impl Element for f32 {
    const DTYPE: Dtype = Dtype::F32;
    const BYTES: usize = 4;

    #[inline]
    fn encode(v: f32) -> Self {
        v
    }

    #[inline]
    fn decode(self) -> f32 {
        self
    }

    fn read_le(bytes: &[u8]) -> Self {
        f32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]])
    }

    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }

    fn into_data(v: Vec<Self>) -> TensorData {
        TensorData::F32(v)
    }

    fn slice(data: &TensorData) -> &[Self] {
        match data {
            TensorData::F32(v) => v,
            TensorData::Bf16(_) => panic!("dtype mismatch: wanted f32 storage"),
        }
    }

    fn slice_mut(data: &mut TensorData) -> &mut [Self] {
        match data {
            TensorData::F32(v) => v,
            TensorData::Bf16(_) => panic!("dtype mismatch: wanted f32 storage"),
        }
    }
}

impl Element for Bf16 {
    const DTYPE: Dtype = Dtype::Bf16;
    const BYTES: usize = 2;

    #[inline]
    fn encode(v: f32) -> Self {
        Bf16::from_f32(v)
    }

    #[inline]
    fn decode(self) -> f32 {
        self.to_f32()
    }

    fn read_le(bytes: &[u8]) -> Self {
        Bf16(u16::from_le_bytes([bytes[0], bytes[1]]))
    }

    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.0.to_le_bytes());
    }

    fn into_data(v: Vec<Self>) -> TensorData {
        TensorData::Bf16(v)
    }

    fn slice(data: &TensorData) -> &[Self] {
        match data {
            TensorData::Bf16(v) => v,
            TensorData::F32(_) => panic!("dtype mismatch: wanted bf16 storage"),
        }
    }

    fn slice_mut(data: &mut TensorData) -> &mut [Self] {
        match data {
            TensorData::Bf16(v) => v,
            TensorData::F32(_) => panic!("dtype mismatch: wanted bf16 storage"),
        }
    }
}

/// Dynamically-typed flat storage. Equality is bitwise per element —
/// exactly what the worker-count determinism tests assert.
#[derive(Clone, Debug, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    Bf16(Vec<Bf16>),
}

/// Dispatch a generic-`Element` expression over both storage variants.
macro_rules! with_data {
    ($data:expr, $v:ident => $body:expr) => {
        match $data {
            TensorData::F32($v) => $body,
            TensorData::Bf16($v) => $body,
        }
    };
}

/// A dense row-major tensor on the host, stored at [`HostTensor::dtype`]
/// precision with all arithmetic in f32 (see module docs).
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    data: TensorData,
}

// -- generic kernels (monomorphized per storage type) ---------------------

fn axpy_impl<E: Element>(data: &mut [E], alpha: f32, other: &[f32]) {
    for (a, b) in data.iter_mut().zip(other.iter()) {
        *a = E::encode(a.decode() + alpha * *b);
    }
}

fn scale_impl<E: Element>(data: &mut [E], c: f32) {
    for a in data.iter_mut() {
        *a = E::encode(a.decode() * c);
    }
}

fn norm_sq_impl<E: Element>(data: &[E]) -> f64 {
    data.iter()
        .map(|&x| {
            let v = x.decode() as f64;
            v * v
        })
        .sum()
}

fn dot_impl<E: Element>(data: &[E], other: &[f32]) -> f64 {
    data.iter()
        .zip(other.iter())
        .map(|(&a, &b)| (a.decode() as f64) * (b as f64))
        .sum()
}

impl HostTensor {
    /// Zero-filled f32 tensor (the historical default precision).
    pub fn zeros(shape: &[usize]) -> Self {
        Self::zeros_in(shape, Dtype::F32)
    }

    /// Zero-filled tensor stored at `dtype`.
    pub fn zeros_in(shape: &[usize], dtype: Dtype) -> Self {
        let n = shape.iter().product();
        let data = match dtype {
            Dtype::F32 => TensorData::F32(vec![0.0; n]),
            Dtype::Bf16 => TensorData::Bf16(vec![Bf16(0); n]),
        };
        Self { shape: shape.to_vec(), data }
    }

    /// Build f32 storage from raw data; panics on element-count mismatch.
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Self { shape: shape.to_vec(), data: TensorData::F32(data) }
    }

    /// Build at `dtype` from f32 values (rounded nearest-even for bf16).
    pub fn from_f32_in(shape: &[usize], values: &[f32], dtype: Dtype) -> Self {
        assert_eq!(shape.iter().product::<usize>(), values.len(), "shape/data mismatch");
        let data = match dtype {
            Dtype::F32 => TensorData::F32(values.to_vec()),
            Dtype::Bf16 => TensorData::Bf16(values.iter().map(|&v| Bf16::from_f32(v)).collect()),
        };
        Self { shape: shape.to_vec(), data }
    }

    /// Build from typed elements (binary dump loading).
    pub(crate) fn from_elems<E: Element>(shape: &[usize], elems: Vec<E>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), elems.len(), "shape/data mismatch");
        Self { shape: shape.to_vec(), data: E::into_data(elems) }
    }

    /// Storage precision.
    pub fn dtype(&self) -> Dtype {
        match &self.data {
            TensorData::F32(_) => Dtype::F32,
            TensorData::Bf16(_) => Dtype::Bf16,
        }
    }

    pub(crate) fn raw(&self) -> &TensorData {
        &self.data
    }

    /// Append this tensor's elements as little-endian bytes at the native
    /// storage width — the one encode loop shared by the binary param
    /// dumps (`ParamStore::save_bin`) and the checkpoint chunks.
    pub(crate) fn encode_le_into(&self, out: &mut Vec<u8>) {
        out.reserve(self.len() * self.dtype().bytes());
        match &self.data {
            TensorData::F32(v) => {
                for &x in v {
                    x.write_le(out);
                }
            }
            TensorData::Bf16(v) => {
                for &x in v {
                    x.write_le(out);
                }
            }
        }
    }

    pub(crate) fn raw_mut(&mut self) -> &mut TensorData {
        &mut self.data
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        with_data!(&self.data, v => v.len())
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Element `i` widened to f32 (exact for both precisions).
    pub fn get(&self, i: usize) -> f32 {
        with_data!(&self.data, v => v[i].decode())
    }

    /// Store `value` at `i` (rounded nearest-even for bf16).
    pub fn set(&mut self, i: usize, value: f32) {
        with_data!(&mut self.data, v => v[i] = Element::encode(value));
    }

    /// Elementwise in-place rewrite: `x_i ← f(i, x_i)` in f32 math.
    pub fn map_inplace<F: FnMut(usize, f32) -> f32>(&mut self, mut f: F) {
        with_data!(&mut self.data, v => {
            for (i, x) in v.iter_mut().enumerate() {
                *x = Element::encode(f(i, x.decode()));
            }
        });
    }

    /// Iterate the values widened to f32.
    pub fn iter_f32(&self) -> IterF32<'_> {
        let inner = match &self.data {
            TensorData::F32(v) => IterInner::F32(v.iter()),
            TensorData::Bf16(v) => IterInner::Bf16(v.iter()),
        };
        IterF32 { inner }
    }

    /// The values as an f32 slice: borrowed for f32 storage, decoded
    /// into a fresh buffer for bf16 (device upload, interop).
    pub fn as_f32(&self) -> Cow<'_, [f32]> {
        match &self.data {
            TensorData::F32(v) => Cow::Borrowed(v.as_slice()),
            TensorData::Bf16(v) => Cow::Owned(v.iter().map(|b| b.to_f32()).collect()),
        }
    }

    /// The values decoded into an owned f32 vector.
    pub fn to_f32_vec(&self) -> Vec<f32> {
        self.as_f32().into_owned()
    }

    /// Overwrite every element from f32 values (encoded on write).
    pub fn copy_from_f32(&mut self, values: &[f32]) {
        assert_eq!(self.len(), values.len(), "copy_from_f32 length mismatch");
        with_data!(&mut self.data, v => {
            for (a, &b) in v.iter_mut().zip(values.iter()) {
                *a = Element::encode(b);
            }
        });
    }

    /// Re-encode at `dtype` (no-op clone of the buffer when equal; the
    /// f32→bf16 direction rounds nearest-even, bf16→f32 is exact).
    pub fn to_dtype(&self, dtype: Dtype) -> Self {
        if self.dtype() == dtype {
            return self.clone();
        }
        Self::from_f32_in(&self.shape, &self.as_f32(), dtype)
    }

    /// `self += alpha * other` (in place, f32 math).
    ///
    /// Length mismatches panic in release builds too: `zip` would silently
    /// truncate and corrupt an update. One compare per call (not per
    /// element) — unmeasurable against the O(n) loop (EXPERIMENTS.md §Perf).
    pub fn axpy(&mut self, alpha: f32, other: &[f32]) {
        assert_eq!(self.len(), other.len(), "axpy length mismatch");
        with_data!(&mut self.data, v => axpy_impl(v, alpha, other));
    }

    /// `self *= c` (in place, f32 math).
    pub fn scale(&mut self, c: f32) {
        with_data!(&mut self.data, v => scale_impl(v, c));
    }

    /// Squared L2 norm (f64 accumulation).
    pub fn norm_sq(&self) -> f64 {
        with_data!(&self.data, v => norm_sq_impl(v))
    }

    /// Dot product with a slice of the same length (loud on mismatch, like
    /// [`HostTensor::axpy`]).
    pub fn dot(&self, other: &[f32]) -> f64 {
        assert_eq!(self.len(), other.len(), "dot length mismatch");
        with_data!(&self.data, v => dot_impl(v, other))
    }

    /// True iff every element is finite.
    pub fn all_finite(&self) -> bool {
        self.iter_f32().all(|x| x.is_finite())
    }
}

/// Iterator over a tensor's values widened to f32.
pub struct IterF32<'a> {
    inner: IterInner<'a>,
}

enum IterInner<'a> {
    F32(std::slice::Iter<'a, f32>),
    Bf16(std::slice::Iter<'a, Bf16>),
}

impl Iterator for IterF32<'_> {
    type Item = f32;

    #[inline]
    fn next(&mut self) -> Option<f32> {
        match &mut self.inner {
            IterInner::F32(it) => it.next().copied(),
            IterInner::Bf16(it) => it.next().map(|b| b.to_f32()),
        }
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        match &self.inner {
            IterInner::F32(it) => it.size_hint(),
            IterInner::Bf16(it) => it.size_hint(),
        }
    }
}

/// Euclidean norm of a set of tensors viewed as one flat vector.
pub fn global_norm(tensors: &[HostTensor]) -> f64 {
    tensors.iter().map(|t| t.norm_sq()).sum::<f64>().sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_len() {
        let t = HostTensor::zeros(&[2, 3]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.dtype(), Dtype::F32);
        assert!(t.iter_f32().all(|x| x == 0.0));
        let b = HostTensor::zeros_in(&[2, 3], Dtype::Bf16);
        assert_eq!(b.len(), 6);
        assert_eq!(b.dtype(), Dtype::Bf16);
        assert!(b.iter_f32().all(|x| x == 0.0));
    }

    #[test]
    #[should_panic]
    fn from_vec_checks_len() {
        HostTensor::from_vec(&[2, 2], vec![1.0; 3]);
    }

    #[test]
    fn axpy_scale_dot() {
        let mut t = HostTensor::from_vec(&[3], vec![1.0, 2.0, 3.0]);
        t.axpy(2.0, &[1.0, 1.0, 1.0]);
        assert_eq!(t.to_f32_vec(), vec![3.0, 4.0, 5.0]);
        t.scale(0.5);
        assert_eq!(t.to_f32_vec(), vec![1.5, 2.0, 2.5]);
        assert!((t.dot(&[2.0, 0.0, 2.0]) - 8.0).abs() < 1e-9);
    }

    #[test]
    fn axpy_scale_dot_bf16_rounds_on_write() {
        // Exactly representable values stay exact through bf16 math.
        let mut t = HostTensor::from_f32_in(&[3], &[1.0, 2.0, 3.0], Dtype::Bf16);
        t.axpy(2.0, &[1.0, 1.0, 1.0]);
        assert_eq!(t.to_f32_vec(), vec![3.0, 4.0, 5.0]);
        t.scale(0.5);
        assert_eq!(t.to_f32_vec(), vec![1.5, 2.0, 2.5]);
        assert!((t.dot(&[2.0, 0.0, 2.0]) - 8.0).abs() < 1e-9);
        // A value needing more than 8 significand bits rounds on write
        // (bf16 ulp in [1,2) is 2^-7).
        let mut u = HostTensor::zeros_in(&[1], Dtype::Bf16);
        u.set(0, 1.0 + 1.0 / 512.0); // below the 2^-8 midpoint: down
        assert_eq!(u.get(0), 1.0);
        u.set(0, 1.0 + 3.0 / 512.0); // above the midpoint: up
        assert_eq!(u.get(0), 1.0 + 1.0 / 128.0);
    }

    #[test]
    fn norms() {
        let t = HostTensor::from_vec(&[2], vec![3.0, 4.0]);
        assert!((t.norm_sq() - 25.0).abs() < 1e-9);
        let u = HostTensor::from_vec(&[1], vec![0.0]);
        assert!((global_norm(&[t, u]) - 5.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "axpy length mismatch")]
    fn axpy_rejects_length_mismatch_in_release() {
        let mut t = HostTensor::zeros(&[4]);
        t.axpy(1.0, &[1.0, 2.0]);
    }

    #[test]
    #[should_panic(expected = "dot length mismatch")]
    fn dot_rejects_length_mismatch_in_release() {
        let t = HostTensor::zeros(&[4]);
        t.dot(&[1.0]);
    }

    #[test]
    fn finite_check() {
        let mut t = HostTensor::zeros(&[2]);
        assert!(t.all_finite());
        t.set(1, f32::NAN);
        assert!(!t.all_finite());
        let mut b = HostTensor::zeros_in(&[2], Dtype::Bf16);
        assert!(b.all_finite());
        b.set(0, f32::INFINITY);
        assert!(!b.all_finite());
    }

    #[test]
    fn dtype_parse_and_bytes() {
        assert_eq!(Dtype::parse("f32").unwrap(), Dtype::F32);
        assert_eq!(Dtype::parse("bf16").unwrap(), Dtype::Bf16);
        assert_eq!(Dtype::parse("bfloat16").unwrap(), Dtype::Bf16);
        assert!(Dtype::parse("fp16").is_err());
        assert_eq!(Dtype::F32.bytes(), 4);
        assert_eq!(Dtype::Bf16.bytes(), 2);
        assert_eq!(Dtype::Bf16.label(), "bf16");
    }

    #[test]
    fn bf16_roundtrip_is_exact_for_every_pattern() {
        // decode → encode must be the identity on all 65536 bf16 bit
        // patterns, except signaling NaNs which are quieted (still NaN).
        for bits in 0..=u16::MAX {
            let b = Bf16(bits);
            let f = b.to_f32();
            let back = Bf16::from_f32(f);
            if f.is_nan() {
                assert!(back.to_f32().is_nan(), "{bits:#06x} must stay NaN");
            } else {
                assert_eq!(back, b, "{bits:#06x} must round-trip exactly");
            }
        }
    }

    #[test]
    fn bf16_ties_round_to_even() {
        // bf16 ulp in [1,2) is 2^-7: 1.0 + 2^-8 sits exactly between
        // 1.0 (mantissa 0x00, even) and 1.0 + 2^-7 (0x01, odd) → down.
        assert_eq!(Bf16::from_f32(1.0 + 1.0 / 256.0).to_f32(), 1.0);
        // 1.0 + 3·2^-8 sits between 1+2^-7 (odd) and 1+2^-6 (even) → up.
        assert_eq!(Bf16::from_f32(1.0 + 3.0 / 256.0).to_f32(), 1.0 + 1.0 / 64.0);
        // Just past the midpoint rounds up regardless of parity.
        assert_eq!(
            Bf16::from_f32(f32::from_bits((1.0f32 + 1.0 / 256.0).to_bits() + 1)).to_f32(),
            1.0 + 1.0 / 128.0
        );
        // Negative ties mirror.
        assert_eq!(Bf16::from_f32(-(1.0 + 1.0 / 256.0)).to_f32(), -1.0);
    }

    #[test]
    fn bf16_saturates_to_inf_and_quiets_nan() {
        assert_eq!(Bf16::from_f32(f32::INFINITY).to_f32(), f32::INFINITY);
        assert_eq!(Bf16::from_f32(f32::NEG_INFINITY).to_f32(), f32::NEG_INFINITY);
        // Above the last bf16 finite (≈3.39e38) rounds to +inf.
        assert_eq!(Bf16::from_f32(f32::MAX).to_f32(), f32::INFINITY);
        assert_eq!(Bf16::from_f32(-f32::MAX).to_f32(), f32::NEG_INFINITY);
        let n = Bf16::from_f32(f32::NAN);
        assert!(n.to_f32().is_nan());
        assert_ne!(n.0 & 0x7FFF, 0x7F80, "NaN must not encode as inf");
    }

    #[test]
    fn bf16_handles_subnormals_and_zeros() {
        // Signed zeros survive.
        assert_eq!(Bf16::from_f32(0.0).0, 0x0000);
        assert_eq!(Bf16::from_f32(-0.0).0, 0x8000);
        // The smallest bf16 subnormal (2^-133) round-trips.
        let tiny = f32::from_bits(0x0001 << 16);
        assert_eq!(Bf16::from_f32(tiny).to_f32(), tiny);
        // f32 values far below the bf16 subnormal range round to zero.
        assert_eq!(Bf16::from_f32(f32::from_bits(1)).to_f32(), 0.0);
        // f32::MIN_POSITIVE (2^-126) is a bf16 normal and survives.
        assert_eq!(Bf16::from_f32(f32::MIN_POSITIVE).to_f32(), f32::MIN_POSITIVE);
    }

    #[test]
    fn get_set_map_and_copy() {
        let mut t = HostTensor::zeros_in(&[4], Dtype::Bf16);
        t.copy_from_f32(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(t.get(2), 3.0);
        t.map_inplace(|i, x| x + i as f32);
        assert_eq!(t.to_f32_vec(), vec![1.0, 3.0, 5.0, 7.0]);
        t.set(0, 9.0);
        assert_eq!(t.get(0), 9.0);
    }

    #[test]
    fn as_f32_borrows_for_f32_storage() {
        let t = HostTensor::from_vec(&[2], vec![1.0, 2.0]);
        assert!(matches!(t.as_f32(), Cow::Borrowed(_)));
        let b = t.to_dtype(Dtype::Bf16);
        assert!(matches!(b.as_f32(), Cow::Owned(_)));
        assert_eq!(b.as_f32().as_ref(), &[1.0, 2.0]);
    }

    #[test]
    fn to_dtype_roundtrip() {
        let t = HostTensor::from_vec(&[3], vec![0.1, -2.5, 1e-4]);
        let b = t.to_dtype(Dtype::Bf16);
        assert_eq!(b.dtype(), Dtype::Bf16);
        // bf16 → f32 is exact, so a second conversion is lossless.
        let back = b.to_dtype(Dtype::F32);
        assert_eq!(back.to_f32_vec(), b.to_f32_vec());
        // Same-dtype conversion is an identical clone.
        assert_eq!(t.to_dtype(Dtype::F32), t);
        // And the bf16 values are the RNE roundings of the originals.
        for (orig, enc) in t.iter_f32().zip(b.iter_f32()) {
            assert_eq!(Bf16::from_f32(orig).to_f32(), enc);
        }
    }
}
