//! Host-side tensors: flat `f32` buffers with shapes.
//!
//! These back the parameter store and every in-place update on the L3 hot
//! path (perturbation, ZO/FO updates). The update kernels are written as
//! tight slice loops so LLVM auto-vectorizes them; see `benches/hotpath.rs`
//! for the measured throughput and EXPERIMENTS.md §Perf.

/// A dense row-major `f32` tensor on the host.
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    /// Zero-filled tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    /// Build from raw data; panics if the element count mismatches.
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Self { shape: shape.to_vec(), data }
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// `self += alpha * other` (in place).
    ///
    /// Length mismatches panic in release builds too: `zip` would silently
    /// truncate and corrupt an update. One compare per call (not per
    /// element) — unmeasurable against the O(n) loop (EXPERIMENTS.md §Perf).
    pub fn axpy(&mut self, alpha: f32, other: &[f32]) {
        assert_eq!(self.data.len(), other.len(), "axpy length mismatch");
        for (a, b) in self.data.iter_mut().zip(other.iter()) {
            *a += alpha * *b;
        }
    }

    /// `self *= c` (in place).
    pub fn scale(&mut self, c: f32) {
        for a in self.data.iter_mut() {
            *a *= c;
        }
    }

    /// Squared L2 norm.
    pub fn norm_sq(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    /// Dot product with a slice of the same length (loud on mismatch, like
    /// [`HostTensor::axpy`]).
    pub fn dot(&self, other: &[f32]) -> f64 {
        assert_eq!(self.data.len(), other.len(), "dot length mismatch");
        self.data
            .iter()
            .zip(other.iter())
            .map(|(&a, &b)| (a as f64) * (b as f64))
            .sum()
    }

    /// True iff every element is finite.
    pub fn all_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }
}

/// Euclidean norm of a set of tensors viewed as one flat vector.
pub fn global_norm(tensors: &[HostTensor]) -> f64 {
    tensors.iter().map(|t| t.norm_sq()).sum::<f64>().sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_len() {
        let t = HostTensor::zeros(&[2, 3]);
        assert_eq!(t.len(), 6);
        assert!(t.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    #[should_panic]
    fn from_vec_checks_len() {
        HostTensor::from_vec(&[2, 2], vec![1.0; 3]);
    }

    #[test]
    fn axpy_scale_dot() {
        let mut t = HostTensor::from_vec(&[3], vec![1.0, 2.0, 3.0]);
        t.axpy(2.0, &[1.0, 1.0, 1.0]);
        assert_eq!(t.data, vec![3.0, 4.0, 5.0]);
        t.scale(0.5);
        assert_eq!(t.data, vec![1.5, 2.0, 2.5]);
        assert!((t.dot(&[2.0, 0.0, 2.0]) - 8.0).abs() < 1e-9);
    }

    #[test]
    fn norms() {
        let t = HostTensor::from_vec(&[2], vec![3.0, 4.0]);
        assert!((t.norm_sq() - 25.0).abs() < 1e-9);
        let u = HostTensor::from_vec(&[1], vec![0.0]);
        assert!((global_norm(&[t, u]) - 5.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "axpy length mismatch")]
    fn axpy_rejects_length_mismatch_in_release() {
        let mut t = HostTensor::zeros(&[4]);
        t.axpy(1.0, &[1.0, 2.0]);
    }

    #[test]
    #[should_panic(expected = "dot length mismatch")]
    fn dot_rejects_length_mismatch_in_release() {
        let t = HostTensor::zeros(&[4]);
        t.dot(&[1.0]);
    }

    #[test]
    fn finite_check() {
        let mut t = HostTensor::zeros(&[2]);
        assert!(t.all_finite());
        t.data[1] = f32::NAN;
        assert!(!t.all_finite());
    }
}
