//! Transformer geometries for the analytic memory model.
//!
//! The big-model geometries (OPT-13B/30B/66B, Llama-2-70B, RoBERTa-large)
//! never run on this machine; they parameterize the closed-form footprint
//! that reproduces the paper's memory columns and OOM verdicts. The
//! laptop-scale presets mirror `python/compile/model.py`.

/// Shape of a transformer LM for memory accounting.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ModelGeometry {
    pub name: &'static str,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    /// Key/value heads (GQA); equal to `n_heads` for classic MHA.
    pub kv_heads: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub max_pos: usize,
    /// MLP matrices per layer: 2 (GELU) or 3 (SwiGLU).
    pub ffn_mats: usize,
}

impl ModelGeometry {
    /// Total parameter count (embeddings + per-layer attn/MLP/LN + final LN,
    /// tied LM head).
    pub fn n_params(&self) -> u64 {
        let d = self.d_model as u64;
        let f = self.d_ff as u64;
        let v = self.vocab as u64;
        let m = self.max_pos as u64;
        let kv = (d * self.kv_heads as u64) / self.n_heads as u64;
        let per_layer = 2 * d * d + 2 * d * kv + 4 * d   // q,o full; k,v GQA-scaled
            + self.ffn_mats as u64 * (d * f) + f + d     // mlp (2 mats, 3 for SwiGLU)
            + 4 * d; // two layernorms
        v * d + m * d + self.n_layers as u64 * per_layer + 2 * d
    }

    /// Largest single weight tensor (elements) — the transient gradient
    /// that even in-place methods hold momentarily.
    pub fn largest_tensor(&self) -> u64 {
        let d = self.d_model as u64;
        (self.vocab as u64 * d).max(d * self.d_ff as u64)
    }
}

/// OPT-13B (Zhang et al. 2022 geometry).
pub const OPT_13B: ModelGeometry = ModelGeometry {
    name: "opt-13b",
    n_layers: 40,
    d_model: 5120,
    n_heads: 40,
    kv_heads: 40,
    d_ff: 20480,
    vocab: 50272,
    max_pos: 2048,
    ffn_mats: 2,
};

/// OPT-30B.
pub const OPT_30B: ModelGeometry = ModelGeometry {
    name: "opt-30b",
    n_layers: 48,
    d_model: 7168,
    n_heads: 56,
    kv_heads: 56,
    d_ff: 28672,
    vocab: 50272,
    max_pos: 2048,
    ffn_mats: 2,
};

/// OPT-66B.
pub const OPT_66B: ModelGeometry = ModelGeometry {
    name: "opt-66b",
    n_layers: 64,
    d_model: 9216,
    n_heads: 72,
    kv_heads: 72,
    d_ff: 36864,
    vocab: 50272,
    max_pos: 2048,
    ffn_mats: 2,
};

/// Llama-2-70B (GQA with 8 kv heads, SwiGLU ffn 28672).
pub const LLAMA2_70B: ModelGeometry = ModelGeometry {
    name: "llama2-70b",
    n_layers: 80,
    d_model: 8192,
    n_heads: 64,
    kv_heads: 8,
    d_ff: 28672,
    vocab: 32000,
    max_pos: 4096,
    ffn_mats: 3,
};

/// RoBERTa-large (355M).
pub const ROBERTA_LARGE: ModelGeometry = ModelGeometry {
    name: "roberta-large",
    n_layers: 24,
    d_model: 1024,
    n_heads: 16,
    kv_heads: 16,
    d_ff: 4096,
    vocab: 50265,
    max_pos: 514,
    ffn_mats: 2,
};

/// Laptop-scale presets (must mirror python/compile/model.py PRESETS).
pub const TINY: ModelGeometry = ModelGeometry {
    name: "tiny",
    n_layers: 2,
    d_model: 64,
    n_heads: 2,
    kv_heads: 2,
    d_ff: 256,
    vocab: 512,
    max_pos: 128,
    ffn_mats: 2,
};

pub const SMALL: ModelGeometry = ModelGeometry {
    name: "small",
    n_layers: 4,
    d_model: 128,
    n_heads: 4,
    kv_heads: 4,
    d_ff: 512,
    vocab: 2048,
    max_pos: 256,
    ffn_mats: 2,
};

pub const BASE: ModelGeometry = ModelGeometry {
    name: "base",
    n_layers: 6,
    d_model: 256,
    n_heads: 8,
    kv_heads: 8,
    d_ff: 1024,
    vocab: 4096,
    max_pos: 512,
    ffn_mats: 2,
};

pub const ALL: &[ModelGeometry] =
    &[OPT_13B, OPT_30B, OPT_66B, LLAMA2_70B, ROBERTA_LARGE, TINY, SMALL, BASE];

/// Look up a geometry by name.
pub fn by_name(name: &str) -> Option<ModelGeometry> {
    ALL.iter().find(|g| g.name == name).copied()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_match_published_sizes() {
        // within 6% of the nominal sizes
        let cases = [
            (OPT_13B, 13.0e9),
            (OPT_30B, 30.0e9),
            (OPT_66B, 66.0e9),
            (LLAMA2_70B, 70.0e9),
            (ROBERTA_LARGE, 0.355e9),
        ];
        for (g, nominal) in cases {
            let p = g.n_params() as f64;
            let rel = (p - nominal).abs() / nominal;
            assert!(rel < 0.08, "{}: {p:.3e} vs {nominal:.1e} (rel {rel:.3})", g.name);
        }
    }

    #[test]
    fn weights_fp16_match_paper_inference_footprints() {
        // Paper: OPT-13B inference ≈ 25-26 GB in fp16.
        let gb = OPT_13B.n_params() as f64 * 2.0 / 1e9;
        assert!((24.0..28.0).contains(&gb), "{gb}");
        // Llama-2-70B fp16 ≈ 135-140 GB.
        let gb = LLAMA2_70B.n_params() as f64 * 2.0 / 1e9;
        assert!((130.0..145.0).contains(&gb), "{gb}");
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(by_name("opt-13b").unwrap().d_model, 5120);
        assert!(by_name("gpt-5").is_none());
    }

    #[test]
    fn largest_tensor_is_lm_head_for_opt() {
        assert_eq!(OPT_13B.largest_tensor(), 50272 * 5120);
    }
}
