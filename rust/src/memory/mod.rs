//! Analytic GPU-memory model: the substrate behind the paper's memory
//! columns, OOM verdicts, and Figures 3-4.
//!
//! The paper profiles peak `nvidia-smi` memory of fp16 fine-tuning with
//! the stock PyTorch/transformers stack (App. D.7, no FlashAttention, no
//! gradient checkpointing). We reproduce that accounting from first
//! principles:
//!
//! * **weights** — `P · bytes` (sharded across GPUs under FSDP);
//! * **backward activations** (FO methods) — every layer stores its
//!   matmul inputs (`C_ACT·d` floats per token per layer) *plus* the
//!   materialized attention probabilities `B·H·L²` per layer (the paper
//!   explicitly does not use FlashAttention — this quadratic term is why
//!   Figure 4's IP-SGD curve bends);
//! * **inference activations** (ZO methods) — a constant number of
//!   transient layer buffers (`C_INF·d` per token) plus ONE layer's
//!   attention matrix;
//! * **logits** — computed in fp32 by the loss head (autocast), two
//!   copies (logits + log-softmax): `B·L·V·8` bytes;
//! * **gradients** — full-model for SGD (global-norm clipping needs the
//!   whole gradient, App. B), one-largest-tensor transient for in-place
//!   methods, full-model fp32 for Adam;
//! * **optimizer state** — Adam's two fp32 moments.
//!
//! Addax peaks at `max(ZO phase, FO phase)` because the two phases of
//! Algorithm 1 do not overlap. Calibration tests at the bottom pin the
//! model against the paper's published anchors (e.g. IP-SGD ≈ 30 GB at
//! BS=2, L=300 on OPT-13B — Figure 3-left).
//!
//! Precision comes from the configured storage [`Dtype`], not a
//! free-floating byte count: [`footprint`] prices weights at
//! `dtype.bytes()`, which since the precision-polymorphic `ParamStore`
//! refactor is exactly what the running store allocates
//! (`ParamStore::storage_bytes`). The store the simulator describes *is*
//! the store we run — `Dtype::Bf16` (2 B) reproduces the paper's
//! fp16-storage profiles, `Dtype::F32` (4 B) the full-precision ones.
//! Adam is the one exception and prices fp32 throughout, matching the
//! paper's fp32 Adam runs regardless of the store's dtype.
//!
//! Absolute peaks of the paper additionally include allocator caching and
//! fragmentation, which we do not model; DESIGN.md §3 records this
//! substitution. Feasibility boundaries (what OOMs where) are the
//! quantity the experiments depend on, and those are reproduced.

pub mod geometry;

pub use crate::tensor::Dtype;
pub use geometry::ModelGeometry;

/// Stored-activation coefficient per token per layer (fp16 floats):
/// inputs of the matmuls + LN/GELU/residual saves ≈ 18·d (calibrated
/// against the Figure 3 / Table 12 anchors, see tests below).
const C_ACT: f64 = 18.0;
/// Transient inference buffers per token (a few layer outputs in flight).
const C_INF: f64 = 6.0;
/// fp32 logits + log-softmax copies in the loss head.
const LOGITS_BYTES: f64 = 8.0;

/// Fine-tuning method, as the memory model sees it.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    MeZo,
    /// ZO-SGD materializing `z` (the O(d) ablation).
    ZoSgdNaive,
    /// SGD with full-gradient storage (normalization).
    Sgd,
    IpSgd,
    /// 32-bit Adam.
    Adam,
    Addax,
    /// Layer-split hybrid of Zhang et al. [69] (FO on deep half).
    HybridZoFo,
}

impl Method {
    pub fn label(&self) -> &'static str {
        match self {
            Method::MeZo => "MeZO",
            Method::ZoSgdNaive => "ZO-SGD",
            Method::Sgd => "SGD",
            Method::IpSgd => "IP-SGD",
            Method::Adam => "Adam",
            Method::Addax => "Addax",
            Method::HybridZoFo => "Hybrid ZO-FO",
        }
    }
}

/// Per-step workload: what each phase of the optimizer sees.
///
/// For single-phase methods only the `fo_*` (FO methods) or `zo_*`
/// (ZO methods) half is read. For Addax, `fo_len` is capped by `L_T` and
/// `zo_len` is the partition's `L_max` (data assignment, §3.1).
#[derive(Clone, Copy, Debug, Default)]
pub struct Workload {
    pub fo_batch: usize,
    pub fo_len: usize,
    pub zo_batch: usize,
    pub zo_len: usize,
}

impl Workload {
    pub fn fo(batch: usize, len: usize) -> Self {
        Self { fo_batch: batch, fo_len: len, ..Default::default() }
    }
    pub fn zo(batch: usize, len: usize) -> Self {
        Self { zo_batch: batch, zo_len: len, ..Default::default() }
    }
    pub fn mixed(fo_batch: usize, fo_len: usize, zo_batch: usize, zo_len: usize) -> Self {
        Self { fo_batch, fo_len, zo_batch, zo_len }
    }
}

/// Byte-level breakdown of a step's peak footprint.
#[derive(Clone, Copy, Debug, Default)]
pub struct Footprint {
    pub weights: f64,
    pub activations: f64,
    pub logits: f64,
    pub gradients: f64,
    pub optimizer_state: f64,
    pub total: f64,
}

impl Footprint {
    pub fn gb(&self) -> f64 {
        self.total / 1e9
    }
}

fn act_backward(g: &ModelGeometry, b: usize, l: usize, bytes: f64) -> f64 {
    let tokens = (b * l) as f64;
    let layers = g.n_layers as f64;
    let stored = tokens * layers * C_ACT * g.d_model as f64 * bytes;
    let attn = (b * g.n_heads) as f64 * (l * l) as f64 * layers as f64 * bytes;
    stored + attn
}

fn act_inference(g: &ModelGeometry, b: usize, l: usize, bytes: f64) -> f64 {
    let tokens = (b * l) as f64;
    let stored = tokens * C_INF * g.d_model as f64 * bytes;
    // one layer's attention matrix in flight
    let attn = (b * g.n_heads) as f64 * (l * l) as f64 * bytes;
    stored + attn
}

fn logits_bytes(g: &ModelGeometry, b: usize, l: usize) -> f64 {
    (b * l) as f64 * g.vocab as f64 * LOGITS_BYTES
}

/// Peak footprint of one fine-tuning step at the store's precision.
///
/// `dtype` is the storage precision of weights/activations (bf16 = the
/// paper's 2-byte fp16 profile, f32 = 4 bytes); Adam always prices fp32
/// (see module docs).
pub fn footprint(g: &ModelGeometry, method: Method, wl: Workload, dtype: Dtype) -> Footprint {
    let bytes = dtype.bytes() as f64;
    let p = g.n_params() as f64;
    let largest = g.largest_tensor() as f64;
    let mut f = Footprint { weights: p * bytes, ..Default::default() };
    match method {
        Method::MeZo => {
            f.activations = act_inference(g, wl.zo_batch, wl.zo_len, bytes);
            f.logits = logits_bytes(g, wl.zo_batch, wl.zo_len);
        }
        Method::ZoSgdNaive => {
            f.activations = act_inference(g, wl.zo_batch, wl.zo_len, bytes);
            f.logits = logits_bytes(g, wl.zo_batch, wl.zo_len);
            // materialized z
            f.gradients = p * bytes;
        }
        Method::Sgd => {
            f.activations = act_backward(g, wl.fo_batch, wl.fo_len, bytes);
            f.logits = logits_bytes(g, wl.fo_batch, wl.fo_len);
            f.gradients = p * bytes; // full gradient for normalization
        }
        Method::IpSgd => {
            f.activations = act_backward(g, wl.fo_batch, wl.fo_len, bytes);
            f.logits = logits_bytes(g, wl.fo_batch, wl.fo_len);
            f.gradients = largest * bytes; // one tensor in flight
        }
        Method::Adam => {
            // 32-bit everything (paper's Adam runs fp32).
            f.weights = p * 4.0;
            f.activations = act_backward(g, wl.fo_batch, wl.fo_len, 4.0);
            f.logits = logits_bytes(g, wl.fo_batch, wl.fo_len);
            f.gradients = p * 4.0;
            f.optimizer_state = 2.0 * p * 4.0;
        }
        Method::Addax => {
            // ZO and FO phases are sequential: peak is the max.
            let zo = act_inference(g, wl.zo_batch, wl.zo_len, bytes)
                + logits_bytes(g, wl.zo_batch, wl.zo_len);
            let fo = act_backward(g, wl.fo_batch, wl.fo_len, bytes)
                + logits_bytes(g, wl.fo_batch, wl.fo_len)
                + largest * bytes;
            if zo >= fo {
                f.activations = zo;
            } else {
                f.activations = act_backward(g, wl.fo_batch, wl.fo_len, bytes);
                f.logits = logits_bytes(g, wl.fo_batch, wl.fo_len);
                f.gradients = largest * bytes;
            }
        }
        Method::HybridZoFo => {
            // FO on the deep half without in-place updates: stores the
            // deep half's gradients; ZO probe on the same batch.
            let half_layers = ModelGeometry { n_layers: g.n_layers / 2, ..*g };
            f.activations = act_backward(&half_layers, wl.fo_batch, wl.fo_len, bytes)
                + act_inference(g, wl.fo_batch, wl.fo_len, bytes);
            f.logits = logits_bytes(g, wl.fo_batch, wl.fo_len);
            f.gradients = 0.5 * p * bytes;
        }
    }
    f.total = f.weights + f.activations + f.logits + f.gradients + f.optimizer_state;
    f
}

/// A GPU budget (possibly multiple devices; FSDP shards everything).
#[derive(Clone, Copy, Debug)]
pub struct Device {
    pub name: &'static str,
    pub capacity_bytes: f64,
    pub count: usize,
}

impl Device {
    pub const fn a100_40(count: usize) -> Self {
        Self { name: "A100-40GB", capacity_bytes: 40e9, count }
    }
    pub const fn h100_80(count: usize) -> Self {
        Self { name: "H100-80GB", capacity_bytes: 80e9, count }
    }
    pub fn total_bytes(&self) -> f64 {
        self.capacity_bytes * self.count as f64
    }
    /// Does the footprint fit?
    pub fn fits(&self, f: &Footprint) -> bool {
        f.total <= self.total_bytes()
    }
}

/// The paper's batch-size grid (App. D.6.1).
pub const BS_GRID: &[usize] = &[2, 4, 6, 8, 10, 12, 14, 16, 20, 24, 28, 32];

/// App. D.6 procedure: largest grid batch size that fits the device for a
/// single-phase method at sequence length `l`. `None` = OOM even at the
/// smallest grid entry (the `*` rows of Tables 12-15).
pub fn max_batch_in_grid(
    g: &ModelGeometry,
    method: Method,
    l: usize,
    device: &Device,
    dtype: Dtype,
) -> Option<usize> {
    BS_GRID
        .iter()
        .rev()
        .find(|&&b| {
            let wl = match method {
                Method::MeZo | Method::ZoSgdNaive => Workload::zo(b, l),
                _ => Workload::fo(b, l),
            };
            device.fits(&footprint(g, method, wl, dtype))
        })
        .copied()
}

#[cfg(test)]
mod tests {
    use super::geometry::*;
    use super::*;

    /// The paper's fp16 storage profile: 2 bytes/element, i.e. bf16 here.
    const FP16: Dtype = Dtype::Bf16;

    /// Figure 3-left anchor: OPT-13B, L=300 — IP-SGD at BS=2 ≈ 30 GB.
    #[test]
    fn fig3_ip_sgd_anchor() {
        let f = footprint(&OPT_13B, Method::IpSgd, Workload::fo(2, 300), FP16);
        assert!((28.0..33.0).contains(&f.gb()), "{}", f.gb());
    }

    /// Figure 3-left anchor: MeZO at BS=18, L=300 fits in 30 GB.
    #[test]
    fn fig3_mezo_anchor() {
        let f = footprint(&OPT_13B, Method::MeZo, Workload::zo(18, 300), FP16);
        assert!(f.gb() <= 30.5, "{}", f.gb());
    }

    /// Table 12: SGD OOMs on a single A100-40GB even at BS=2 for any task.
    #[test]
    fn sgd_always_oom_on_a100() {
        let dev = Device::a100_40(1);
        for l in [60, 120, 300, 739] {
            assert_eq!(max_batch_in_grid(&OPT_13B, Method::Sgd, l, &dev, FP16), None);
        }
    }

    /// Table 12: IP-SGD fits short tasks but OOMs on the long ones
    /// (BoolQ/MultiRC/SQuAD-scale lengths) at BS=2.
    #[test]
    fn ip_sgd_oom_pattern_matches_table12() {
        let dev = Device::a100_40(1);
        // short tasks fit
        for l in [60, 110, 280] {
            assert!(max_batch_in_grid(&OPT_13B, Method::IpSgd, l, &dev, FP16).is_some(), "L={l}");
        }
        // long tasks OOM even at BS=2
        for l in [700, 739] {
            assert_eq!(max_batch_in_grid(&OPT_13B, Method::IpSgd, l, &dev, FP16), None, "L={l}");
        }
    }

    /// MeZO fits everywhere on the A100 with a healthy batch size.
    #[test]
    fn mezo_fits_all_lengths() {
        let dev = Device::a100_40(1);
        for l in [60, 300, 739] {
            let b = max_batch_in_grid(&OPT_13B, Method::MeZo, l, &dev, FP16).unwrap();
            assert!(b >= 6, "L={l} -> B={b}");
        }
    }

    /// Addax with the paper's (K¹,K⁰) = (4,6), L_T = 170 fits MultiRC
    /// (L_max = 739) on one A100-40GB — the headline memory claim.
    #[test]
    fn addax_fits_multirc_on_a100() {
        let dev = Device::a100_40(1);
        let wl = Workload::mixed(4, 170, 6, 739);
        let f = footprint(&OPT_13B, Method::Addax, wl, FP16);
        assert!(dev.fits(&f), "{} GB", f.gb());
        // and is comparable to MeZO (within ~1.3x)
        let mezo = footprint(&OPT_13B, Method::MeZo, Workload::zo(6, 739), FP16);
        assert!(f.total < 1.35 * mezo.total);
    }

    /// Adam needs ~16 bytes/param: OPT-13B ≈ 205+ GB ⇒ 5 GPUs (Table 12).
    #[test]
    fn adam_needs_many_gpus() {
        let f = footprint(&OPT_13B, Method::Adam, Workload::fo(8, 300), Dtype::F32);
        assert!(f.gb() > 200.0, "{}", f.gb());
        assert!(!Device::a100_40(1).fits(&f));
        assert!(Device::h100_80(5).fits(&f));
    }

    /// Figure 4 shape: IP-SGD memory grows superlinearly in L, MeZO's
    /// grows slowly; the gap at L=700 is much larger than at L=100.
    #[test]
    fn fig4_growth_shapes() {
        let m = |method, l| footprint(&OPT_13B, method, match method {
            Method::MeZo => Workload::zo(8, l),
            _ => Workload::fo(8, l),
        }, FP16).total;
        let gap_small = m(Method::IpSgd, 100) - m(Method::MeZo, 100);
        let gap_large = m(Method::IpSgd, 500) - m(Method::MeZo, 500);
        assert!(gap_large > 4.0 * gap_small);
        // and MeZO itself grows gently
        assert!(m(Method::MeZo, 700) < 1.5 * m(Method::MeZo, 100));
    }

    /// OPT-30B on one H100-80: IP-SGD fits short tasks at small BS but
    /// OOMs on long ones; Addax(L_T=180) fits everything (Table 13).
    #[test]
    fn table13_opt30b_pattern() {
        let dev = Device::h100_80(1);
        assert!(max_batch_in_grid(&OPT_30B, Method::IpSgd, 60, &dev, FP16).is_some());
        assert_eq!(max_batch_in_grid(&OPT_30B, Method::IpSgd, 700, &dev, FP16), None);
        let wl = Workload::mixed(4, 180, 6, 739);
        assert!(dev.fits(&footprint(&OPT_30B, Method::Addax, wl, FP16)));
    }

    /// Llama-2-70B on 3×H100 (Table 15): MeZO fits, SGD does not, Addax
    /// with L_T=240 fits long tasks.
    #[test]
    fn table15_llama70b_pattern() {
        let dev = Device::h100_80(3);
        assert!(dev.fits(&footprint(&LLAMA2_70B, Method::MeZo, Workload::zo(16, 600), FP16)));
        assert!(!dev.fits(&footprint(&LLAMA2_70B, Method::Sgd, Workload::fo(2, 600), FP16)));
        let wl = Workload::mixed(4, 240, 6, 700);
        assert!(dev.fits(&footprint(&LLAMA2_70B, Method::Addax, wl, FP16)));
    }

    /// ZO-SGD without the seed trick pays a full extra model copy.
    #[test]
    fn naive_zo_pays_o_d() {
        let mezo = footprint(&OPT_13B, Method::MeZo, Workload::zo(8, 300), FP16);
        let naive = footprint(&OPT_13B, Method::ZoSgdNaive, Workload::zo(8, 300), FP16);
        let extra = naive.total - mezo.total;
        let weights = OPT_13B.n_params() as f64 * 2.0;
        assert!((extra - weights).abs() / weights < 1e-9);
    }

    /// The dtype prices exactly the bytes the polymorphic store
    /// allocates: bf16 weights are half the f32 weights, and both equal
    /// `n_params × dtype.bytes()`.
    #[test]
    fn dtype_prices_the_bytes_the_store_allocates() {
        let half = footprint(&OPT_13B, Method::MeZo, Workload::zo(1, 60), FP16);
        let full = footprint(&OPT_13B, Method::MeZo, Workload::zo(1, 60), Dtype::F32);
        assert_eq!(half.weights * 2.0, full.weights);
        assert_eq!(half.weights, OPT_13B.n_params() as f64 * Dtype::Bf16.bytes() as f64);
        assert_eq!(full.weights, OPT_13B.n_params() as f64 * Dtype::F32.bytes() as f64);
        // Adam ignores the store dtype: it trains fp32 either way.
        let a16 = footprint(&OPT_13B, Method::Adam, Workload::fo(8, 300), FP16);
        let a32 = footprint(&OPT_13B, Method::Adam, Workload::fo(8, 300), Dtype::F32);
        assert_eq!(a16.total, a32.total);
    }

    /// Footprint is monotone in batch and length.
    #[test]
    fn monotonicity() {
        for method in [Method::MeZo, Method::IpSgd, Method::Sgd, Method::Adam] {
            let wl_small = match method {
                Method::MeZo => Workload::zo(2, 100),
                _ => Workload::fo(2, 100),
            };
            let wl_big = match method {
                Method::MeZo => Workload::zo(4, 200),
                _ => Workload::fo(4, 200),
            };
            let a = footprint(&OPT_13B, method, wl_small, FP16).total;
            let b = footprint(&OPT_13B, method, wl_big, FP16).total;
            assert!(b > a, "{method:?}");
        }
    }
}
