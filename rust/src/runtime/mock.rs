//! Closed-form mock objective implementing [`ModelExec`].
//!
//! A strongly convex quadratic with per-example gradient noise:
//!
//! ```text
//! ℓ(θ; x) = ½ Σᵢ aᵢ (θᵢ − tᵢ)²  +  σ ξ(x)ᵀ θ
//! ```
//!
//! with `ξ(x)` a deterministic pseudo-random unit-variance vector hashed
//! from the example's tokens, so `E[∇ℓ] = ∇L` and `Var ≤ σ²` hold exactly
//! (Assumptions G.1/G.2/G.4 of the paper). Used by the optimizer unit
//! tests, the proptest invariants, and the Theorem 3.1/3.2 rate
//! experiments — no artifacts or PJRT needed.

use anyhow::Result;

use crate::params::ParamStore;
use crate::tensor::{Bf16, Dtype};
use crate::zorng::{block_seed, fill_block, NoiseStream, NOISE_BLOCK};

use super::{ExecStats, FwdOut, GradOut, ModelExec, TokenBatch};

/// See module docs.
pub struct QuadraticExec {
    /// Per-coordinate curvatures `aᵢ` (log-spaced in `[mu, lip]`).
    pub curvature: Vec<f32>,
    /// Optimum `t` (same layout as the flattened params).
    pub target: Vec<f32>,
    /// Gradient noise scale σ.
    pub sigma: f32,
    stats: ExecStats,
}

impl QuadraticExec {
    /// Build for a `d`-dimensional problem with curvatures in `[mu, lip]`.
    pub fn new(d: usize, mu: f32, lip: f32, sigma: f32, seed: u64) -> Self {
        assert!(mu > 0.0 && lip >= mu);
        let mut rng = NoiseStream::new(seed);
        let curvature = (0..d)
            .map(|i| {
                let frac = if d > 1 { i as f32 / (d - 1) as f32 } else { 0.0 };
                mu * (lip / mu).powf(frac)
            })
            .collect();
        let target = (0..d).map(|_| rng.next_normal()).collect();
        Self { curvature, target, sigma, stats: ExecStats::default() }
    }

    /// The deterministic (noise-free) loss `L(θ) − L*`.
    pub fn suboptimality(&self, params: &ParamStore) -> f64 {
        let mut i = 0;
        let mut acc = 0.0f64;
        for t in params.tensors() {
            for v in t.iter_f32() {
                let d = (v - self.target[i]) as f64;
                acc += 0.5 * self.curvature[i] as f64 * d * d;
                i += 1;
            }
        }
        acc
    }

    /// ‖∇L(θ)‖² of the noise-free loss.
    pub fn grad_norm_sq(&self, params: &ParamStore) -> f64 {
        let mut i = 0;
        let mut acc = 0.0f64;
        for t in params.tensors() {
            for v in t.iter_f32() {
                let g = self.curvature[i] as f64 * (v - self.target[i]) as f64;
                acc += g * g;
                i += 1;
            }
        }
        acc
    }

    /// Distance to the optimum ‖θ − θ*‖².
    pub fn dist_sq(&self, params: &ParamStore) -> f64 {
        let mut i = 0;
        let mut acc = 0.0f64;
        for t in params.tensors() {
            for v in t.iter_f32() {
                let d = (v - self.target[i]) as f64;
                acc += d * d;
                i += 1;
            }
        }
        acc
    }

    /// True directional derivative `z·∇L(θ)` of the noise-free loss, with
    /// `z` replayed under the counter-addressed block scheme — the exact
    /// quantity SPSA estimates at σ = 0 (tests, theory experiments).
    pub fn directional_derivative(&self, params: &ParamStore, seed: u64) -> f64 {
        let noise = crate::zorng::BlockNoise::new(seed);
        let mut i = 0;
        let mut acc = 0.0f64;
        let mut g = Vec::new();
        for (param_idx, t) in params.tensors().enumerate() {
            g.clear();
            for v in t.iter_f32() {
                g.push(self.curvature[i] * (v - self.target[i]));
                i += 1;
            }
            acc += noise.dot_param(param_idx, &g);
        }
        acc
    }

    fn example_seed(&self, batch: &TokenBatch, row: usize) -> u64 {
        let mut h = 0xcbf29ce484222325u64;
        for &t in &batch.ids[row * batch.seq..(row + 1) * batch.seq] {
            h = (h ^ t as u64).wrapping_mul(0x100000001b3);
        }
        h
    }

    fn row_loss(&self, params: &ParamStore, batch: &TokenBatch, row: usize) -> f64 {
        let mut noise = NoiseStream::new(self.example_seed(batch, row));
        let mut i = 0;
        let mut acc = 0.0f64;
        for t in params.tensors() {
            for v in t.iter_f32() {
                let d = (v - self.target[i]) as f64;
                acc += 0.5 * self.curvature[i] as f64 * d * d;
                acc += self.sigma as f64 * noise.next_normal() as f64 * v as f64;
                i += 1;
            }
        }
        acc
    }
}

impl ModelExec for QuadraticExec {
    fn forward(&mut self, params: &ParamStore, batch: &TokenBatch) -> Result<FwdOut> {
        self.stats.forward_calls += 1;
        let sums = (0..batch.batch)
            .map(|r| self.row_loss(params, batch, r) as f32)
            .collect();
        Ok(FwdOut { sums, counts: vec![1.0; batch.batch] })
    }

    fn grads(&mut self, params: &ParamStore, batch: &TokenBatch) -> Result<GradOut> {
        self.stats.grad_calls += 1;
        let d = params.n_scalars();
        let mut flat = vec![0.0f32; d];
        let inv_b = 1.0 / batch.batch as f32;
        let mut loss = 0.0f64;
        for r in 0..batch.batch {
            loss += self.row_loss(params, batch, r);
            let mut noise = NoiseStream::new(self.example_seed(batch, r));
            let mut i = 0;
            for t in params.tensors() {
                for v in t.iter_f32() {
                    let g = self.curvature[i] * (v - self.target[i])
                        + self.sigma * noise.next_normal();
                    flat[i] += g * inv_b;
                    i += 1;
                }
            }
        }
        // Split the flat gradient back into per-tensor pieces.
        let mut grads = Vec::with_capacity(params.len());
        let mut off = 0;
        for t in params.tensors() {
            grads.push(flat[off..off + t.len()].to_vec());
            off += t.len();
        }
        Ok(GradOut {
            loss: (loss / batch.batch as f64) as f32,
            count: batch.batch as f32,
            grads,
        })
    }

    /// Sweep fusion v2 on the mock: both SPSA probes in one streaming
    /// pass over the parameters, without perturbing the store.
    ///
    /// Bit-parity contract with the materialized schedule
    /// (`perturb(+ε) → forward → perturb(−2ε) → forward`), per element:
    /// `v₊ = round(v + ε·z)`, `v₋ = round(v₊ + (−2ε)·z)` with `round`
    /// the store dtype's write rounding, `z` replayed per (tensor,
    /// block) exactly as the store sweeps replay it, and each row's
    /// f64 loss accumulated in the same element order with the same ξ
    /// draws as [`QuadraticExec::row_loss`] — so the returned rows are
    /// bit-identical to the two materialized forwards (the steal
    /// subsystem's byte-identity proofs depend on this).
    fn probe_rows_fused(
        &mut self,
        params: &ParamStore,
        batch: &TokenBatch,
        eps: f32,
        seed: u64,
    ) -> Result<Option<(FwdOut, FwdOut)>> {
        self.stats.forward_calls += 2;
        let round: fn(f32) -> f32 = match params.dtype() {
            Dtype::F32 => |x| x,
            Dtype::Bf16 => |x| Bf16::from_f32(x).to_f32(),
        };
        let m2eps = -2.0 * eps;
        let mut streams: Vec<NoiseStream> = (0..batch.batch)
            .map(|r| NoiseStream::new(self.example_seed(batch, r)))
            .collect();
        let mut acc_p = vec![0.0f64; batch.batch];
        let mut acc_m = vec![0.0f64; batch.batch];
        let mut z = [0.0f32; NOISE_BLOCK];
        let mut i = 0usize;
        for (param_idx, t) in params.tensors().enumerate() {
            let vals = t.as_f32();
            for (block_idx, chunk) in vals.chunks(NOISE_BLOCK).enumerate() {
                let zb = &mut z[..chunk.len()];
                fill_block(block_seed(seed, param_idx, block_idx), zb);
                for (&v, &zi) in chunk.iter().zip(zb.iter()) {
                    let v_p = round(v + eps * zi);
                    let v_m = round(v_p + m2eps * zi);
                    let d_p = (v_p - self.target[i]) as f64;
                    let d_m = (v_m - self.target[i]) as f64;
                    let quad_p = 0.5 * self.curvature[i] as f64 * d_p * d_p;
                    let quad_m = 0.5 * self.curvature[i] as f64 * d_m * d_m;
                    for (r, stream) in streams.iter_mut().enumerate() {
                        let xi = stream.next_normal() as f64;
                        acc_p[r] += quad_p;
                        acc_p[r] += self.sigma as f64 * xi * v_p as f64;
                        acc_m[r] += quad_m;
                        acc_m[r] += self.sigma as f64 * xi * v_m as f64;
                    }
                    i += 1;
                }
            }
        }
        let plus = FwdOut {
            sums: acc_p.iter().map(|&x| x as f32).collect(),
            counts: vec![1.0; batch.batch],
        };
        let minus = FwdOut {
            sums: acc_m.iter().map(|&x| x as f32).collect(),
            counts: vec![1.0; batch.batch],
        };
        Ok(Some((plus, minus)))
    }

    fn stats(&self) -> ExecStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::ParamStore;

    fn store(d: usize) -> ParamStore {
        ParamStore::zeros(&[("w".to_string(), vec![d])])
    }

    fn batch(b: usize) -> TokenBatch {
        let rows: Vec<_> = (0..b).map(|i| (vec![i as i32 + 1, 17], vec![-1, -1])).collect();
        TokenBatch::from_rows(&rows)
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let mut exec = QuadraticExec::new(4, 0.5, 2.0, 0.1, 3);
        let mut p = store(4);
        p.perturb(11, 1.0);
        let b = batch(2);
        let g = exec.grads(&p, &b).unwrap();
        let eps = 1e-3f32;
        for i in 0..4 {
            let mut p_plus = p.clone();
            let t = &mut p_plus.get_mut(0).tensor;
            t.set(i, t.get(i) + eps);
            let mut p_minus = p.clone();
            let t = &mut p_minus.get_mut(0).tensor;
            t.set(i, t.get(i) - eps);
            let lp = exec.forward(&p_plus, &b).unwrap().mean_loss();
            let lm = exec.forward(&p_minus, &b).unwrap().mean_loss();
            let fd = (lp - lm) / (2.0 * eps as f64);
            assert!(
                (fd - g.grads[0][i] as f64).abs() < 1e-2,
                "coord {i}: fd {fd} vs {}", g.grads[0][i]
            );
        }
    }

    #[test]
    fn noise_is_mean_zero_over_many_examples() {
        let mut exec = QuadraticExec::new(3, 1.0, 1.0, 1.0, 5);
        let mut p = store(3);
        p.perturb(2, 1.0);
        let noise_free: f64 = exec.suboptimality(&p)
            + {
                // deterministic part of ξᵀθ has mean 0, so the mean row
                // loss over many rows approaches the quadratic part.
                0.0
            };
        let rows: Vec<_> = (0..4000).map(|i| (vec![i as i32], vec![-1])).collect();
        let b = TokenBatch::from_rows(&rows);
        let mean = exec.forward(&p, &b).unwrap().mean_loss();
        assert!((mean - noise_free).abs() < 0.1, "{mean} vs {noise_free}");
    }

    #[test]
    fn gd_converges_on_noise_free_problem() {
        let mut exec = QuadraticExec::new(8, 0.5, 2.0, 0.0, 1);
        let mut p = store(8);
        let b = batch(1);
        for _ in 0..200 {
            let g = exec.grads(&p, &b).unwrap();
            p.fo_update_all(0.4, 1.0, &g.grads);
        }
        assert!(exec.suboptimality(&p) < 1e-6);
    }

    #[test]
    fn directional_derivative_matches_spsa_estimate() {
        let mut exec = QuadraticExec::new(6, 0.5, 2.0, 0.0, 4);
        let mut p = store(6);
        p.perturb(9, 1.0);
        let b = batch(2);
        let seed = 21;
        let (g0, _) = crate::optim::spsa_g0(&mut p, &mut exec, &b, 1e-4, seed).unwrap();
        let dir = exec.directional_derivative(&p, seed);
        assert!(
            (g0 - dir).abs() < 0.05 * dir.abs().max(1.0),
            "spsa {g0} vs directional {dir}"
        );
    }

    #[test]
    fn fused_probe_is_bit_identical_to_materialized_probes() {
        // The fusion-v2 contract: probe_rows_fused's per-row sums equal
        // the materialized perturb→forward→perturb→forward schedule bit
        // for bit, in both dtypes, spanning a block boundary (tail block
        // shorter than NOISE_BLOCK).
        let d = NOISE_BLOCK + 293;
        let (seed, eps) = (77u64, 1e-2f32);
        for dtype in [Dtype::F32, Dtype::Bf16] {
            let mut exec = QuadraticExec::new(d, 0.5, 2.0, 0.3, 13);
            let mut p = ParamStore::zeros(&[("w".to_string(), vec![d])]).to_dtype(dtype);
            p.perturb(11, 1.0);
            let b = batch(3);
            let mut ctrl = p.clone();
            ctrl.perturb(seed, eps);
            let plus = exec.forward(&ctrl, &b).unwrap();
            ctrl.perturb(seed, -2.0 * eps);
            let minus = exec.forward(&ctrl, &b).unwrap();
            let before = exec.stats().forward_calls;
            let (fp, fm) = exec.probe_rows_fused(&p, &b, eps, seed).unwrap().unwrap();
            assert_eq!(exec.stats().forward_calls, before + 2, "fused probe = 2 evals");
            for r in 0..b.batch {
                assert_eq!(
                    fp.sums[r].to_bits(),
                    plus.sums[r].to_bits(),
                    "dtype={dtype:?} plus row {r}"
                );
                assert_eq!(
                    fm.sums[r].to_bits(),
                    minus.sums[r].to_bits(),
                    "dtype={dtype:?} minus row {r}"
                );
            }
            assert_eq!(fp.counts, plus.counts);
            assert_eq!(fm.counts, minus.counts);
        }
    }

    #[test]
    fn suboptimality_zero_at_target() {
        let exec = QuadraticExec::new(5, 1.0, 4.0, 0.0, 2);
        let mut p = store(5);
        p.get_mut(0).tensor.copy_from_f32(&exec.target);
        assert!(exec.suboptimality(&p) < 1e-12);
        assert!(exec.grad_norm_sq(&p) < 1e-12);
    }
}
