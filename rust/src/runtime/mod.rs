//! L3 runtime: load AOT HLO-text artifacts and execute them via PJRT.
//!
//! Pattern (see /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute_b`.
//! Executables are compiled lazily per (kind, bucket) and cached; the
//! training hot path then only pays host→device copies + execution.
//!
//! The [`ModelExec`] trait is the seam between the optimizers and the
//! substrate: the real [`XlaExec`] runs the transformer artifacts, while
//! [`mock::QuadraticExec`] provides a closed-form objective for unit tests
//! and the theory experiments.

pub mod manifest;
pub mod mock;

use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::params::ParamStore;
use manifest::{ArtifactKind, Manifest, ModelEntry};

/// A tokenized batch, ids/labels row-major `[batch, seq]`.
///
/// Convention (matches `python/compile/model.py`): id 0 is padding,
/// label < 0 is "no loss at this position".
#[derive(Clone, Debug)]
pub struct TokenBatch {
    pub ids: Vec<i32>,
    pub labels: Vec<i32>,
    pub batch: usize,
    pub seq: usize,
}

impl TokenBatch {
    pub fn new(batch: usize, seq: usize) -> Self {
        Self { ids: vec![0; batch * seq], labels: vec![-1; batch * seq], batch, seq }
    }

    /// Build from per-example (ids, labels) rows, padding to the longest.
    pub fn from_rows(rows: &[(Vec<i32>, Vec<i32>)]) -> Self {
        let batch = rows.len();
        let seq = rows.iter().map(|(i, _)| i.len()).max().unwrap_or(1).max(1);
        let mut out = Self::new(batch, seq);
        for (b, (ids, labels)) in rows.iter().enumerate() {
            assert_eq!(ids.len(), labels.len());
            out.ids[b * seq..b * seq + ids.len()].copy_from_slice(ids);
            out.labels[b * seq..b * seq + labels.len()].copy_from_slice(labels);
        }
        out
    }

    /// Pad (rows and/or columns) up to an artifact's (batch, seq) shape.
    pub fn padded_to(&self, batch: usize, seq: usize) -> TokenBatch {
        assert!(batch >= self.batch && seq >= self.seq, "cannot shrink a batch");
        let mut out = TokenBatch::new(batch, seq);
        for b in 0..self.batch {
            out.ids[b * seq..b * seq + self.seq]
                .copy_from_slice(&self.ids[b * self.seq..(b + 1) * self.seq]);
            out.labels[b * seq..b * seq + self.seq]
                .copy_from_slice(&self.labels[b * self.seq..(b + 1) * self.seq]);
        }
        out
    }

    /// Split into chunks of at most `max_batch` rows.
    pub fn chunks(&self, max_batch: usize) -> Vec<TokenBatch> {
        (0..self.batch)
            .step_by(max_batch)
            .map(|start| {
                let n = (self.batch - start).min(max_batch);
                TokenBatch {
                    ids: self.ids[start * self.seq..(start + n) * self.seq].to_vec(),
                    labels: self.labels[start * self.seq..(start + n) * self.seq].to_vec(),
                    batch: n,
                    seq: self.seq,
                }
            })
            .collect()
    }

    /// Number of labeled (loss-bearing) tokens.
    pub fn labeled_tokens(&self) -> usize {
        self.labels.iter().filter(|&&l| l >= 0).count()
    }
}

/// Per-example forward output.
#[derive(Clone, Debug)]
pub struct FwdOut {
    /// Sum of token losses per example.
    pub sums: Vec<f32>,
    /// Count of labeled tokens per example.
    pub counts: Vec<f32>,
}

impl FwdOut {
    /// Batch-mean token loss.
    pub fn mean_loss(&self) -> f64 {
        let s: f64 = self.sums.iter().map(|&x| x as f64).sum();
        let c: f64 = self.counts.iter().map(|&x| x as f64).sum();
        if c > 0.0 {
            s / c
        } else {
            0.0
        }
    }
}

/// First-order output: mean loss + per-tensor gradients (canonical order).
#[derive(Clone, Debug)]
pub struct GradOut {
    pub loss: f32,
    pub count: f32,
    pub grads: Vec<Vec<f32>>,
}

/// Execution counters for the wall-clock/efficiency reporting.
#[derive(Clone, Copy, Debug, Default)]
pub struct ExecStats {
    pub forward_calls: u64,
    pub grad_calls: u64,
    pub forward_secs: f64,
    pub grad_secs: f64,
}

/// The seam between optimizers and the compute substrate.
pub trait ModelExec {
    /// Per-example (sum, count) of token losses.
    fn forward(&mut self, params: &ParamStore, batch: &TokenBatch) -> Result<FwdOut>;
    /// Mean loss + gradients of the mean loss.
    fn grads(&mut self, params: &ParamStore, batch: &TokenBatch) -> Result<GradOut>;
    /// Scalar mean loss (default: via `forward`).
    fn mean_loss(&mut self, params: &ParamStore, batch: &TokenBatch) -> Result<f64> {
        Ok(self.forward(params, batch)?.mean_loss())
    }
    /// Sweep fusion v2: evaluate both SPSA probes `L(θ + εz)` and
    /// `L(θ − εz)` per example **without the caller perturbing the
    /// parameter store** — the substrate replays the counter-addressed
    /// `z` itself while streaming over the parameters.
    ///
    /// Returns `Ok(None)` when the substrate has no fused path (the
    /// default; the caller falls back to the materialized
    /// perturb → forward → perturb → forward schedule). A substrate that
    /// returns `Some((plus, minus))` must produce per-row sums/counts
    /// **bit-identical** to the materialized schedule at the store's
    /// dtype (round-to-storage after each perturb, same accumulation
    /// order) — the steal subsystem's stolen-probe byte-identity proof
    /// rests on the two paths being interchangeable.
    fn probe_rows_fused(
        &mut self,
        _params: &ParamStore,
        _batch: &TokenBatch,
        _eps: f32,
        _seed: u64,
    ) -> Result<Option<(FwdOut, FwdOut)>> {
        Ok(None)
    }
    fn stats(&self) -> ExecStats;
}

/// XLA/PJRT-backed execution of the AOT artifacts for one model key.
pub struct XlaExec {
    client: xla::PjRtClient,
    manifest: Manifest,
    model_key: String,
    executables: HashMap<(ArtifactKind, usize), xla::PjRtLoadedExecutable>,
    stats: ExecStats,
    /// Wall-clock spent compiling artifacts (excluded from step timing).
    pub compile_secs: f64,
}

impl XlaExec {
    /// Create against an artifacts dir; compiles nothing yet.
    pub fn new(artifacts_dir: &Path, model_key: &str) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        manifest.model(model_key)?; // validate early
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            client,
            manifest,
            model_key: model_key.to_string(),
            executables: HashMap::new(),
            stats: ExecStats::default(),
            compile_secs: 0.0,
        })
    }

    pub fn entry(&self) -> &ModelEntry {
        self.manifest.model(&self.model_key).expect("validated in new()")
    }

    /// Canonical `(name, shape)` specs for `ParamStore`.
    pub fn param_specs(&self) -> Vec<(String, Vec<usize>)> {
        self.entry().param_specs()
    }

    /// Load the deterministic initial parameters dumped by aot.py.
    pub fn load_initial_params(&self) -> Result<ParamStore> {
        let entry = self.entry();
        ParamStore::load_bin(&entry.param_specs(), &self.manifest.params_path(entry))
    }

    /// Largest seq bucket for which a `kind` artifact exists.
    pub fn max_bucket(&self, kind: ArtifactKind) -> Option<usize> {
        self.entry().buckets(kind).last().copied()
    }

    fn ensure_compiled(&mut self, kind: ArtifactKind, seq: usize) -> Result<(usize, usize)> {
        let entry = self.entry().clone();
        let spec = match entry.pick_artifact(kind, seq) {
            Some(s) => s.clone(),
            None => bail!(
                "no {:?} artifact covers seq_len {} for model {} (buckets: {:?}) — \
                 this is the artifact-level analogue of the paper's OOM: long \
                 sequences only have a forward path",
                kind,
                seq,
                self.model_key,
                entry.buckets(kind)
            ),
        };
        let key = (kind, spec.seq_len);
        if !self.executables.contains_key(&key) {
            let t0 = Instant::now();
            let path = self.manifest.artifact_path(&spec);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?;
            self.compile_secs += t0.elapsed().as_secs_f64();
            self.executables.insert(key, exe);
        }
        Ok((spec.batch, spec.seq_len))
    }

    /// Upload params + batch and execute; returns the output tuple parts.
    fn run(
        &mut self,
        kind: ArtifactKind,
        params: &ParamStore,
        batch: &TokenBatch,
    ) -> Result<Vec<xla::Literal>> {
        let (art_batch, art_seq) = self.ensure_compiled(kind, batch.seq)?;
        if batch.batch > art_batch {
            bail!("batch {} exceeds artifact batch {art_batch}; chunk first", batch.batch);
        }
        let padded = if batch.batch == art_batch && batch.seq == art_seq {
            None
        } else {
            Some(batch.padded_to(art_batch, art_seq))
        };
        let b: &TokenBatch = padded.as_ref().unwrap_or(batch);

        let mut args: Vec<xla::PjRtBuffer> = Vec::with_capacity(params.len() + 2);
        for p in params.iter() {
            // The artifacts compute in f32: widen on upload (borrowed,
            // zero-copy for an f32 store; decoded for bf16 — the f32
            // staging buffer is transient, one tensor at a time, so the
            // resident store keeps its dtype's footprint).
            let host = p.tensor.as_f32();
            args.push(self.client.buffer_from_host_buffer(
                host.as_ref(),
                &p.tensor.shape,
                None,
            )?);
        }
        let dims = [art_batch, art_seq];
        args.push(self.client.buffer_from_host_buffer(&b.ids, &dims, None)?);
        args.push(self.client.buffer_from_host_buffer(&b.labels, &dims, None)?);

        let exe = &self.executables[&(kind, art_seq)];
        let result = exe.execute_b::<xla::PjRtBuffer>(&args)?;
        let tuple = result[0][0].to_literal_sync()?;
        Ok(tuple.to_tuple()?)
    }
}

impl ModelExec for XlaExec {
    fn forward(&mut self, params: &ParamStore, batch: &TokenBatch) -> Result<FwdOut> {
        let t0 = Instant::now();
        let mut sums = Vec::with_capacity(batch.batch);
        let mut counts = Vec::with_capacity(batch.batch);
        let art_batch = self
            .entry()
            .pick_artifact(ArtifactKind::Forward, batch.seq)
            .map(|a| a.batch)
            .unwrap_or(batch.batch.max(1));
        for chunk in batch.chunks(art_batch) {
            let parts = self.run(ArtifactKind::Forward, params, &chunk)?;
            let s: Vec<f32> = parts[0].to_vec()?;
            let c: Vec<f32> = parts[1].to_vec()?;
            sums.extend_from_slice(&s[..chunk.batch]);
            counts.extend_from_slice(&c[..chunk.batch]);
        }
        self.stats.forward_calls += 1;
        self.stats.forward_secs += t0.elapsed().as_secs_f64();
        Ok(FwdOut { sums, counts })
    }

    fn grads(&mut self, params: &ParamStore, batch: &TokenBatch) -> Result<GradOut> {
        let t0 = Instant::now();
        let art_batch = self
            .entry()
            .pick_artifact(ArtifactKind::Grads, batch.seq)
            .map(|a| a.batch)
            .unwrap_or(batch.batch.max(1));
        let mut total_count = 0.0f64;
        let mut loss_weighted = 0.0f64;
        let mut acc: Option<Vec<Vec<f32>>> = None;
        for chunk in batch.chunks(art_batch) {
            let parts = self.run(ArtifactKind::Grads, params, &chunk)?;
            let loss = parts[0].to_vec::<f32>()?[0] as f64;
            let count = parts[1].to_vec::<f32>()?[0] as f64;
            let grads: Vec<Vec<f32>> =
                parts[2..].iter().map(|l| l.to_vec::<f32>()).collect::<Result<_, _>>()?;
            // Combine chunks into the exact big-batch gradient:
            // g = Σ count_i·g_i / Σ count_i  (model.py normalizes per chunk).
            match &mut acc {
                None => {
                    let mut g = grads;
                    for t in g.iter_mut() {
                        for v in t.iter_mut() {
                            *v *= count as f32;
                        }
                    }
                    acc = Some(g);
                }
                Some(a) => {
                    for (t, g) in a.iter_mut().zip(grads.iter()) {
                        for (x, &y) in t.iter_mut().zip(g.iter()) {
                            *x += count as f32 * y;
                        }
                    }
                }
            }
            loss_weighted += loss * count;
            total_count += count;
        }
        let mut grads = acc.unwrap_or_default();
        let denom = total_count.max(1.0) as f32;
        for t in grads.iter_mut() {
            for v in t.iter_mut() {
                *v /= denom;
            }
        }
        self.stats.grad_calls += 1;
        self.stats.grad_secs += t0.elapsed().as_secs_f64();
        Ok(GradOut {
            loss: (loss_weighted / total_count.max(1.0)) as f32,
            count: total_count as f32,
            grads,
        })
    }

    fn stats(&self) -> ExecStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_batch_from_rows_pads() {
        let rows = vec![
            (vec![1, 2, 3], vec![-1, 3, 4]),
            (vec![5], vec![6]),
        ];
        let b = TokenBatch::from_rows(&rows);
        assert_eq!((b.batch, b.seq), (2, 3));
        assert_eq!(b.ids, vec![1, 2, 3, 5, 0, 0]);
        assert_eq!(b.labels, vec![-1, 3, 4, 6, -1, -1]);
        assert_eq!(b.labeled_tokens(), 3);
    }

    #[test]
    fn padded_to_grows_rows_and_cols() {
        let b = TokenBatch::from_rows(&[(vec![1, 2], vec![2, -1])]);
        let p = b.padded_to(3, 4);
        assert_eq!((p.batch, p.seq), (3, 4));
        assert_eq!(p.ids[..4], [1, 2, 0, 0]);
        assert_eq!(p.labels[4..8], [-1, -1, -1, -1]);
    }

    #[test]
    fn chunking_covers_all_rows() {
        let rows: Vec<_> = (0..10).map(|i| (vec![i as i32 + 1], vec![i as i32])).collect();
        let b = TokenBatch::from_rows(&rows);
        let chunks = b.chunks(4);
        assert_eq!(chunks.len(), 3);
        assert_eq!(chunks.iter().map(|c| c.batch).sum::<usize>(), 10);
        assert_eq!(chunks[2].batch, 2);
    }

    #[test]
    fn fwd_out_mean() {
        let f = FwdOut { sums: vec![2.0, 4.0], counts: vec![1.0, 2.0] };
        assert!((f.mean_loss() - 2.0).abs() < 1e-9);
        let empty = FwdOut { sums: vec![0.0], counts: vec![0.0] };
        assert_eq!(empty.mean_loss(), 0.0);
    }
}
