//! Parsing of `artifacts/manifest.json` produced by `python/compile/aot.py`.
//!
//! The manifest is the single source of truth for parameter order/shapes
//! and for which (kind, batch, seq-len) HLO artifacts exist. Parsed with
//! the in-tree [`crate::jsonlite`] parser (offline build, no serde).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::jsonlite::Json;

/// Kind of an AOT artifact.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    /// `(params…, ids, labels) -> (sum_loss[B], count[B])`
    Forward,
    /// `(params…, ids, labels) -> (loss, count, grads…)`
    Grads,
}

impl ArtifactKind {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "forward" => Ok(Self::Forward),
            "grads" => Ok(Self::Grads),
            other => bail!("unknown artifact kind {other:?}"),
        }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub kind: ArtifactKind,
    pub batch: usize,
    pub seq_len: usize,
    pub file: String,
}

#[derive(Clone, Debug)]
pub struct ModelEntry {
    pub impl_: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub max_len: usize,
    pub causal: bool,
    pub n_params: usize,
    pub init_seed: u64,
    pub params_file: String,
    pub params: Vec<ParamSpec>,
    pub artifacts: Vec<ArtifactSpec>,
}

impl ModelEntry {
    fn from_json(v: &Json) -> Result<Self> {
        let params = v
            .get("params")?
            .as_arr()?
            .iter()
            .map(|p| {
                Ok(ParamSpec {
                    name: p.get("name")?.as_str()?.to_string(),
                    shape: p
                        .get("shape")?
                        .as_arr()?
                        .iter()
                        .map(|d| d.as_usize())
                        .collect::<Result<_>>()?,
                })
            })
            .collect::<Result<_>>()?;
        let artifacts = v
            .get("artifacts")?
            .as_arr()?
            .iter()
            .map(|a| {
                Ok(ArtifactSpec {
                    kind: ArtifactKind::parse(a.get("kind")?.as_str()?)?,
                    batch: a.get("batch")?.as_usize()?,
                    seq_len: a.get("seq_len")?.as_usize()?,
                    file: a.get("file")?.as_str()?.to_string(),
                })
            })
            .collect::<Result<_>>()?;
        Ok(Self {
            impl_: v.get("impl")?.as_str()?.to_string(),
            vocab: v.get("vocab")?.as_usize()?,
            d_model: v.get("d_model")?.as_usize()?,
            n_heads: v.get("n_heads")?.as_usize()?,
            n_layers: v.get("n_layers")?.as_usize()?,
            d_ff: v.get("d_ff")?.as_usize()?,
            max_len: v.get("max_len")?.as_usize()?,
            causal: v.get("causal")?.as_bool()?,
            n_params: v.get("n_params")?.as_usize()?,
            init_seed: v.get("init_seed")?.as_u64()?,
            params_file: v.get("params_file")?.as_str()?.to_string(),
            params,
            artifacts,
        })
    }

    /// `(name, shape)` pairs in canonical order, as `ParamStore` wants them.
    pub fn param_specs(&self) -> Vec<(String, Vec<usize>)> {
        self.params.iter().map(|p| (p.name.clone(), p.shape.clone())).collect()
    }

    /// Smallest artifact of `kind` whose bucket fits `seq_len`, if any.
    pub fn pick_artifact(&self, kind: ArtifactKind, seq_len: usize) -> Option<&ArtifactSpec> {
        self.artifacts
            .iter()
            .filter(|a| a.kind == kind && a.seq_len >= seq_len)
            .min_by_key(|a| a.seq_len)
    }

    /// All seq-len buckets available for `kind`, ascending.
    pub fn buckets(&self, kind: ArtifactKind) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.kind == kind)
            .map(|a| a.seq_len)
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub format_version: usize,
    pub models: BTreeMap<String, ModelEntry>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!("reading {} — run `make artifacts` first", path.display())
        })?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (separated out for tests).
    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let v = Json::parse(text).context("parsing manifest.json")?;
        let format_version = v.get("format_version")?.as_usize()?;
        if format_version != 1 {
            bail!("unsupported manifest format_version {format_version}");
        }
        let models = v
            .get("models")?
            .as_obj()?
            .iter()
            .map(|(k, m)| {
                Ok((
                    k.clone(),
                    ModelEntry::from_json(m).with_context(|| format!("model {k:?}"))?,
                ))
            })
            .collect::<Result<_>>()?;
        Ok(Self { format_version, models, dir: dir.to_path_buf() })
    }

    pub fn model(&self, key: &str) -> Result<&ModelEntry> {
        self.models.get(key).with_context(|| {
            format!(
                "model {key:?} not in manifest; available: {:?}",
                self.models.keys().collect::<Vec<_>>()
            )
        })
    }

    pub fn artifact_path(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }

    pub fn params_path(&self, entry: &ModelEntry) -> PathBuf {
        self.dir.join(&entry.params_file)
    }
}

/// Default artifacts directory: `$ADDAX_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("ADDAX_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "format_version": 1,
        "models": {
            "tiny": {
                "impl": "pallas", "vocab": 8, "d_model": 4, "n_heads": 2,
                "n_layers": 1, "d_ff": 8, "max_len": 64, "causal": true,
                "n_params": 10, "init_seed": 0, "params_file": "p.bin",
                "params": [{"name": "w", "shape": [2, 5]}],
                "artifacts": [
                    {"kind": "forward", "batch": 8, "seq_len": 32, "file": "f32.hlo.txt"},
                    {"kind": "forward", "batch": 8, "seq_len": 64, "file": "f64.hlo.txt"},
                    {"kind": "grads", "batch": 8, "seq_len": 32, "file": "g32.hlo.txt"}
                ]
            }
        }
    }"#;

    fn sample() -> Manifest {
        Manifest::parse(SAMPLE, Path::new("/tmp/none")).unwrap()
    }

    #[test]
    fn parses_sample() {
        let m = sample();
        let e = m.model("tiny").unwrap();
        assert_eq!(e.vocab, 8);
        assert_eq!(e.params[0].shape, vec![2, 5]);
        assert_eq!(e.artifacts.len(), 3);
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn pick_smallest_fitting_bucket() {
        let m = sample();
        let e = m.model("tiny").unwrap();
        assert_eq!(e.pick_artifact(ArtifactKind::Forward, 10).unwrap().seq_len, 32);
        assert_eq!(e.pick_artifact(ArtifactKind::Forward, 33).unwrap().seq_len, 64);
        assert!(e.pick_artifact(ArtifactKind::Forward, 65).is_none());
        assert!(e.pick_artifact(ArtifactKind::Grads, 40).is_none());
    }

    #[test]
    fn buckets_sorted() {
        let m = sample();
        let e = m.model("tiny").unwrap();
        assert_eq!(e.buckets(ArtifactKind::Forward), vec![32, 64]);
        assert_eq!(e.buckets(ArtifactKind::Grads), vec![32]);
    }

    #[test]
    fn rejects_wrong_version() {
        let bad = SAMPLE.replace("\"format_version\": 1", "\"format_version\": 9");
        assert!(Manifest::parse(&bad, Path::new("/tmp")).is_err());
    }
}
