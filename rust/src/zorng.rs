//! Seed-replay Gaussian noise: the O(1)-memory trick of MeZO/Addax (Alg. 3),
//! counter-addressed so any block of `z` is regenerable independently.
//!
//! The perturbation direction `z ~ N(0, I_d)` is never materialized.
//! Instead, every place that needs `z` (perturb +ε, perturb −2ε, the fused
//! restore-and-update sweep `θ ← θ + (ε − ηαg⁰)z`) re-creates the identical
//! normals from the step seed. This reproduces lines 13-17 of Algorithm 1
//! and all of Algorithms 2-3 from the paper.
//!
//! Addressing: `z` is split into [`NOISE_BLOCK`]-element blocks per tensor,
//! and block `b` of tensor `m` is seeded by `block_seed(step_seed, m, b)`
//! (a splitmix64 hash). Unlike the original single sequential stream —
//! where block N could not be generated before blocks 0..N−1 were
//! consumed — any block is regenerable in any order on any thread, so the
//! perturb/update sweeps parallelize while staying bit-exact at every
//! worker count (see `ParamStore::perturb`).
//!
//! Within a block, generation is **lane-batched** (the §Perf roofline
//! pass): the block seed expands into [`NOISE_LANES`] independent
//! xoshiro256++ lanes via sequential splitmix64, lane `j` owning the
//! `j`-th quarter of the block. The uniform u64 draws for all lanes are
//! produced in struct-of-arrays batches (autovectorizable on stable Rust;
//! explicit AVX2 under the optional `simd` cargo feature), then folded
//! through the Ziggurat per lane in stream order. Because the Ziggurat
//! consumes a *variable* number of draws per normal (~1.2% of draws hit
//! the wedge/tail), each lane buffers exactly `ceil(n/4)` batched draws
//! and falls back to a live scalar continuation of the same lane stream
//! for the rare spill — making [`fill_block_batched`] bit-identical to
//! [`fill_block_scalar`] by construction. The scalar path is retained as
//! the oracle (property-tested in this module and in `params.rs`) and can
//! be forced at runtime with `ADDAX_NOISE_SCALAR=1`.
//!
//! Generator: splitmix64 seeding xoshiro256++, Ziggurat for normals
//! (Marsaglia-Tsang; replaced Box-Muller in the §Perf pass for a 4.7x
//! speedup) — deterministic across platforms, no external deps (see
//! `benches/hotpath.rs` and EXPERIMENTS.md §Perf).

/// Block granularity of the counter-addressed noise scheme, in f32 elements
/// (16 KiB per block: big enough to amortize stream setup, small enough to
/// load-balance across workers).
pub const NOISE_BLOCK: usize = 4096;

/// Number of independent xoshiro256++ lanes a block's noise is generated
/// on. Lane `j` owns the `j`-th `ceil(n/NOISE_LANES)`-element chunk of the
/// block; 4 u64 lanes fill one 256-bit vector register.
pub const NOISE_LANES: usize = 4;

/// splitmix64 — used to expand a u64 seed into xoshiro state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Per-block seed derivation: `h(step_seed, param_idx, block_idx)`.
///
/// Two splitmix64 rounds over the (seed, param, block) triple: the first
/// decorrelates the param/block counters (which are small, structured
/// integers), the second whitens the result into xoshiro-quality state.
#[inline]
pub fn block_seed(step_seed: u64, param_idx: usize, block_idx: usize) -> u64 {
    let mut s = step_seed
        ^ (param_idx as u64).wrapping_mul(0xD1B54A32D192ED03)
        ^ (block_idx as u64).wrapping_mul(0x8CB92BA72F3D8DD7);
    let a = splitmix64(&mut s);
    let mut t = a ^ step_seed.rotate_left(32);
    splitmix64(&mut t)
}

/// Uniform in `(0, 1]` from a raw u64 (never exactly 0, safe for `ln`).
#[inline]
fn u64_to_f64_open(u: u64) -> f64 {
    ((u >> 11) as f64 + 1.0) * (1.0 / 9007199254740992.0)
}

/// Uniform in `[0, 1)` from a raw u64.
#[inline]
fn u64_to_f64(u: u64) -> f64 {
    (u >> 11) as f64 * (1.0 / 9007199254740992.0)
}

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Self { s }
    }

    /// The full generator state — everything needed to continue this
    /// stream exactly where it is (checkpointing; pairs with
    /// [`Xoshiro256::from_state`]).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator mid-stream from a captured [`Xoshiro256::state`].
    /// The all-zero state is xoshiro's absorbing fixed point and can never
    /// come from a real stream — reject it loudly (a checkpoint that
    /// decodes to it is corrupt in a way the CRC did not catch).
    pub fn from_state(s: [u64; 4]) -> Self {
        assert!(s != [0u64; 4], "all-zero xoshiro256 state is degenerate");
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `(0, 1]` (never exactly 0, safe for `ln`).
    #[inline]
    pub fn next_f64_open(&mut self) -> f64 {
        u64_to_f64_open(self.next_u64())
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        u64_to_f64(self.next_u64())
    }

    /// Unbiased uniform integer in `[0, n)` via Lemire's widening-multiply
    /// reduction: `(x · n) >> 64` maps a uniform u64 into `[0, n)` with a
    /// single multiply, rejecting only the (at most `2⁶⁴ mod n` per `2⁶⁴`)
    /// low-product draws that would bias the split.
    #[inline]
    pub fn next_below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut low = m as u64;
        if low < n {
            // threshold = 2^64 mod n; draws with low-half below it are the
            // overrepresented residues and must be rejected.
            let threshold = n.wrapping_neg() % n;
            while low < threshold {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                low = m as u64;
            }
        }
        (m >> 64) as usize
    }
}

/// Ziggurat tables for the standard normal (Marsaglia-Tsang, 128 layers).
///
/// Computed once at first use; pure function of the published constants,
/// so streams stay deterministic across runs and platforms.
struct ZigTables {
    /// Layer x-coordinates, x[0] (base) .. x[128] = 0. Kept for the
    /// wedge/tail math via `wn`; only read at table-build time.
    #[allow(dead_code)]
    x: [f64; 129],
    /// f(x[i]) = exp(-x[i]²/2).
    f: [f64; 129],
    /// Integer fast-path acceptance bound: |hz| < kn[i] accepts directly
    /// (hz is a signed 31-bit uniform), avoiding all float compares.
    kn: [u32; 128],
    /// Scale hz -> x: wn[i] = x[i] / 2³¹.
    wn: [f64; 128],
}

fn zig_tables() -> &'static ZigTables {
    use std::sync::OnceLock;
    static TABLES: OnceLock<ZigTables> = OnceLock::new();
    TABLES.get_or_init(|| {
        const R: f64 = 3.442619855899;
        const V: f64 = 9.91256303526217e-3;
        let mut x = [0.0f64; 129];
        x[0] = V / (-0.5 * R * R).exp(); // pseudo-base so area(strip 0) = V
        x[1] = R;
        for i in 1..128 {
            let prev = x[i];
            x[i + 1] = (-2.0 * (V / prev + (-0.5 * prev * prev).exp()).ln()).sqrt();
        }
        x[128] = 0.0;
        let mut f = [0.0f64; 129];
        for i in 0..129 {
            f[i] = (-0.5 * x[i] * x[i]).exp();
        }
        let m31 = (1u64 << 31) as f64;
        let mut kn = [0u32; 128];
        let mut wn = [0.0f64; 128];
        for i in 0..128 {
            wn[i] = x[i] / m31;
            kn[i] = ((x[i + 1] / x[i]) * m31) as u32;
        }
        ZigTables { x, f, kn, wn }
    })
}

/// One standard normal from an arbitrary u64 source (the Ziggurat core,
/// factored out of [`NoiseStream`] so the lane-batched block generator can
/// feed it pre-generated uniform draws). Consumes a *variable* number of
/// draws: 1 on the ~98.8% fast path, more on the wedge/tail.
#[inline]
fn normal_from(next: &mut impl FnMut() -> u64) -> f32 {
    let t = zig_tables();
    const R: f64 = 3.442619855899;
    loop {
        let bits = next();
        let i = (bits & 127) as usize;
        // signed 31-bit uniform
        let hz = ((bits >> 32) as u32 as i64) - (1i64 << 31);
        // fast path: one integer compare + one multiply (~98.8% of draws)
        if (hz.unsigned_abs() as u32) < t.kn[i] {
            return (hz as f64 * t.wn[i]) as f32;
        }
        let x = hz as f64 * t.wn[i];
        if i == 0 {
            // tail (Marsaglia's method)
            loop {
                let x_tail = -u64_to_f64_open(next()).ln() / R;
                let y = -u64_to_f64_open(next()).ln();
                if 2.0 * y > x_tail * x_tail {
                    return (if hz < 0 { -(R + x_tail) } else { R + x_tail }) as f32;
                }
            }
        }
        // wedge: accept with probability proportional to the density gap
        let y = u64_to_f64(next());
        if t.f[i + 1] + y * (t.f[i] - t.f[i + 1]) < (-0.5 * x * x).exp() {
            return x as f32;
        }
    }
}

/// A replayable stream of standard normals (Ziggurat sampler; the §Perf
/// pass replaced Box-Muller, which was 70x off memory bandwidth on the
/// perturbation hot path — see EXPERIMENTS.md §Perf).
///
/// Two `NoiseStream::new(seed)` instances produce bit-identical sequences;
/// that is the entire memory-saving contract of Algorithm 3. The
/// counter-addressed block scheme no longer routes through this type
/// (blocks are lane-batched, see [`fill_block`]); it remains the
/// sequential-stream front-end for per-example mock noise, data sampling,
/// and diagnostics.
#[derive(Clone, Debug)]
pub struct NoiseStream {
    rng: Xoshiro256,
}

impl NoiseStream {
    pub fn new(seed: u64) -> Self {
        Self { rng: Xoshiro256::new(seed) }
    }

    /// Next standard normal.
    #[inline]
    pub fn next_normal(&mut self) -> f32 {
        let rng = &mut self.rng;
        normal_from(&mut || rng.next_u64())
    }

    /// Fill a slice with normals.
    pub fn fill_normal(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.next_normal();
        }
    }
}

/// Expand a block seed into [`NOISE_LANES`] xoshiro256++ lane states via
/// 16 sequential splitmix64 outputs (lane 0 coincides with
/// `Xoshiro256::new(block_seed)`'s state).
#[inline]
fn lane_states(block_seed: u64) -> [[u64; 4]; NOISE_LANES] {
    let mut sm = block_seed;
    let mut lanes = [[0u64; 4]; NOISE_LANES];
    for lane in lanes.iter_mut() {
        for w in lane.iter_mut() {
            *w = splitmix64(&mut sm);
        }
    }
    lanes
}

/// Four xoshiro256++ generators stepped in lockstep, stored
/// struct-of-arrays so every state update is a straight-line 4-wide u64
/// op (autovectorizes to AVX2 on stable Rust without any feature flag).
struct Xoshiro256x4 {
    s0: [u64; NOISE_LANES],
    s1: [u64; NOISE_LANES],
    s2: [u64; NOISE_LANES],
    s3: [u64; NOISE_LANES],
}

impl Xoshiro256x4 {
    fn from_lanes(lanes: [[u64; 4]; NOISE_LANES]) -> Self {
        let mut s0 = [0u64; NOISE_LANES];
        let mut s1 = [0u64; NOISE_LANES];
        let mut s2 = [0u64; NOISE_LANES];
        let mut s3 = [0u64; NOISE_LANES];
        for (j, lane) in lanes.iter().enumerate() {
            s0[j] = lane[0];
            s1[j] = lane[1];
            s2[j] = lane[2];
            s3[j] = lane[3];
        }
        Self { s0, s1, s2, s3 }
    }

    fn lanes(&self) -> [[u64; 4]; NOISE_LANES] {
        let mut out = [[0u64; 4]; NOISE_LANES];
        for (j, lane) in out.iter_mut().enumerate() {
            *lane = [self.s0[j], self.s1[j], self.s2[j], self.s3[j]];
        }
        out
    }

    /// One xoshiro256++ step on all four lanes; returns each lane's draw.
    #[inline]
    fn next4(&mut self) -> [u64; NOISE_LANES] {
        let mut out = [0u64; NOISE_LANES];
        for j in 0..NOISE_LANES {
            out[j] = self.s0[j]
                .wrapping_add(self.s3[j])
                .rotate_left(23)
                .wrapping_add(self.s0[j]);
        }
        let mut t = [0u64; NOISE_LANES];
        for j in 0..NOISE_LANES {
            t[j] = self.s1[j] << 17;
        }
        for j in 0..NOISE_LANES {
            self.s2[j] ^= self.s0[j];
        }
        for j in 0..NOISE_LANES {
            self.s3[j] ^= self.s1[j];
        }
        for j in 0..NOISE_LANES {
            self.s1[j] ^= self.s2[j];
        }
        for j in 0..NOISE_LANES {
            self.s0[j] ^= self.s3[j];
        }
        for j in 0..NOISE_LANES {
            self.s2[j] ^= t[j];
        }
        for j in 0..NOISE_LANES {
            self.s3[j] = self.s3[j].rotate_left(45);
        }
        out
    }
}

/// Portable lane-major batch fill: `q` draws per lane into
/// `buf[j*q .. (j+1)*q]`; returns the post-`q`-step lane states (the live
/// continuation point for Ziggurat draw spill).
fn fill_lane_major_portable(
    lanes: [[u64; 4]; NOISE_LANES],
    q: usize,
    buf: &mut [u64],
) -> [[u64; 4]; NOISE_LANES] {
    let mut x = Xoshiro256x4::from_lanes(lanes);
    for i in 0..q {
        let r = x.next4();
        for (j, &w) in r.iter().enumerate() {
            buf[j * q + i] = w;
        }
    }
    x.lanes()
}

/// Explicit AVX2 lane step for the `simd` cargo feature. Bit-identical to
/// the portable struct-of-arrays path (same lane layout, same draws); the
/// dispatcher falls back to the portable loop when AVX2 is absent.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod avx2 {
    use super::NOISE_LANES;
    use std::arch::x86_64::*;

    /// # Safety
    /// Caller must ensure the CPU supports AVX2.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn rotl23(v: __m256i) -> __m256i {
        _mm256_or_si256(_mm256_slli_epi64::<23>(v), _mm256_srli_epi64::<41>(v))
    }

    /// # Safety
    /// Caller must ensure the CPU supports AVX2.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn rotl45(v: __m256i) -> __m256i {
        _mm256_or_si256(_mm256_slli_epi64::<45>(v), _mm256_srli_epi64::<19>(v))
    }

    /// AVX2 edition of `fill_lane_major_portable`: one 256-bit register
    /// per xoshiro state word, four lanes per step.
    ///
    /// # Safety
    /// Caller must ensure the CPU supports AVX2 (runtime-detected by the
    /// dispatcher) and `buf.len() >= NOISE_LANES * q`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn fill_lane_major(
        lanes: [[u64; 4]; NOISE_LANES],
        q: usize,
        buf: &mut [u64],
    ) -> [[u64; 4]; NOISE_LANES] {
        let mut s0 = _mm256_setr_epi64x(
            lanes[0][0] as i64,
            lanes[1][0] as i64,
            lanes[2][0] as i64,
            lanes[3][0] as i64,
        );
        let mut s1 = _mm256_setr_epi64x(
            lanes[0][1] as i64,
            lanes[1][1] as i64,
            lanes[2][1] as i64,
            lanes[3][1] as i64,
        );
        let mut s2 = _mm256_setr_epi64x(
            lanes[0][2] as i64,
            lanes[1][2] as i64,
            lanes[2][2] as i64,
            lanes[3][2] as i64,
        );
        let mut s3 = _mm256_setr_epi64x(
            lanes[0][3] as i64,
            lanes[1][3] as i64,
            lanes[2][3] as i64,
            lanes[3][3] as i64,
        );
        let mut tmp = [0u64; NOISE_LANES];
        for i in 0..q {
            let r = _mm256_add_epi64(rotl23(_mm256_add_epi64(s0, s3)), s0);
            _mm256_storeu_si256(tmp.as_mut_ptr().cast(), r);
            for (j, &w) in tmp.iter().enumerate() {
                buf[j * q + i] = w;
            }
            let t = _mm256_slli_epi64::<17>(s1);
            s2 = _mm256_xor_si256(s2, s0);
            s3 = _mm256_xor_si256(s3, s1);
            s1 = _mm256_xor_si256(s1, s2);
            s0 = _mm256_xor_si256(s0, s3);
            s2 = _mm256_xor_si256(s2, t);
            s3 = rotl45(s3);
        }
        let mut w0 = [0u64; NOISE_LANES];
        let mut w1 = [0u64; NOISE_LANES];
        let mut w2 = [0u64; NOISE_LANES];
        let mut w3 = [0u64; NOISE_LANES];
        _mm256_storeu_si256(w0.as_mut_ptr().cast(), s0);
        _mm256_storeu_si256(w1.as_mut_ptr().cast(), s1);
        _mm256_storeu_si256(w2.as_mut_ptr().cast(), s2);
        _mm256_storeu_si256(w3.as_mut_ptr().cast(), s3);
        let mut out = [[0u64; 4]; NOISE_LANES];
        for (j, lane) in out.iter_mut().enumerate() {
            *lane = [w0[j], w1[j], w2[j], w3[j]];
        }
        out
    }
}

/// Batched uniform generation for one block: intrinsics when the `simd`
/// feature is on and the CPU has AVX2, portable struct-of-arrays
/// otherwise. Both produce identical draws.
#[inline]
fn fill_lane_major(
    lanes: [[u64; 4]; NOISE_LANES],
    q: usize,
    buf: &mut [u64],
) -> [[u64; 4]; NOISE_LANES] {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            // SAFETY: AVX2 availability was just checked; buf is sized by
            // the callers to hold NOISE_LANES * q draws.
            return unsafe { avx2::fill_lane_major(lanes, q, buf) };
        }
    }
    fill_lane_major_portable(lanes, q, buf)
}

/// `true` when `ADDAX_NOISE_SCALAR` is set (non-empty, not `"0"`): every
/// [`fill_block`] routes through the scalar oracle. Safe to flip at any
/// time — the two paths are bit-identical; this exists for perf A/B runs
/// and for pinning down a miscompiled vector path in the field.
fn force_scalar_noise() -> bool {
    use std::sync::OnceLock;
    static FORCE: OnceLock<bool> = OnceLock::new();
    *FORCE.get_or_init(|| {
        std::env::var("ADDAX_NOISE_SCALAR")
            .map(|v| !v.is_empty() && v != "0")
            .unwrap_or(false)
    })
}

/// Scalar oracle for one block's noise: each lane's chunk generated by a
/// plain sequential xoshiro256++ stream through the shared Ziggurat core.
/// The lane-batched path must reproduce these bits exactly.
pub fn fill_block_scalar(block_seed: u64, out: &mut [f32]) {
    if out.is_empty() {
        return;
    }
    assert!(out.len() <= NOISE_BLOCK, "noise blocks are at most NOISE_BLOCK elements");
    let lanes = lane_states(block_seed);
    let q = out.len().div_ceil(NOISE_LANES);
    for (lane, chunk) in out.chunks_mut(q).enumerate() {
        let mut rng = Xoshiro256::from_state(lanes[lane]);
        let mut next = || rng.next_u64();
        for v in chunk.iter_mut() {
            *v = normal_from(&mut next);
        }
    }
}

/// Lane-batched block noise: pre-generates `q = ceil(n/4)` uniform draws
/// per lane in one struct-of-arrays pass, then folds each lane's chunk
/// through the Ziggurat consuming the buffered draws in stream order.
/// A lane that needs more than `q` draws (Ziggurat wedge/tail rejection)
/// continues on a live scalar stream restored from the lane's post-batch
/// state — so the output is bit-identical to [`fill_block_scalar`].
pub fn fill_block_batched(block_seed: u64, out: &mut [f32]) {
    if out.is_empty() {
        return;
    }
    assert!(out.len() <= NOISE_BLOCK, "noise blocks are at most NOISE_BLOCK elements");
    let lanes = lane_states(block_seed);
    let q = out.len().div_ceil(NOISE_LANES);
    // q * NOISE_LANES <= NOISE_BLOCK for every out.len() <= NOISE_BLOCK.
    let mut buf = [0u64; NOISE_BLOCK];
    let end_states = fill_lane_major(lanes, q, &mut buf);
    for (lane, chunk) in out.chunks_mut(q).enumerate() {
        let draws = &buf[lane * q..(lane + 1) * q];
        let mut live = Xoshiro256::from_state(end_states[lane]);
        let mut pos = 0usize;
        let mut next = || {
            if pos < q {
                let u = draws[pos];
                pos += 1;
                u
            } else {
                live.next_u64()
            }
        };
        for v in chunk.iter_mut() {
            *v = normal_from(&mut next);
        }
    }
}

/// Fill one [`NOISE_BLOCK`]-sized (or shorter, for a tensor's tail block)
/// slice with the noise for `block_seed` — the single entry point every
/// perturb/update/probe sweep uses. Lane-batched by default; the scalar
/// oracle when `ADDAX_NOISE_SCALAR` is set.
#[inline]
pub fn fill_block(block_seed: u64, out: &mut [f32]) {
    if force_scalar_noise() {
        fill_block_scalar(block_seed, out);
    } else {
        fill_block_batched(block_seed, out);
    }
}

/// Counter-addressed view of one step's perturbation `z` (the replay
/// contract of Algorithms 2-3, parallel edition).
///
/// `z` for tensor `m` is the concatenation of its [`NOISE_BLOCK`]-element
/// blocks, block `b` generated by `fill_block(block_seed(seed, m, b))`.
/// Because every block owns independent lane streams, regeneration is
/// order-free: workers can produce blocks in any interleaving and the bits
/// are identical to a serial left-to-right pass. The noise for tensor `m`
/// also does not depend on which *other* tensors participate in a sweep,
/// which is what keeps `perturb_subset` replay aligned for the hybrid
/// ZO-FO baseline.
#[derive(Clone, Copy, Debug)]
pub struct BlockNoise {
    step_seed: u64,
}

impl BlockNoise {
    pub fn new(step_seed: u64) -> Self {
        Self { step_seed }
    }

    /// Materialize the full `z` for tensor `param_idx` into `out`
    /// (tests and the O(d)-memory ZO-SGD ablation; the training hot path
    /// never calls this).
    pub fn fill_param(&self, param_idx: usize, out: &mut [f32]) {
        for (block_idx, chunk) in out.chunks_mut(NOISE_BLOCK).enumerate() {
            fill_block(block_seed(self.step_seed, param_idx, block_idx), chunk);
        }
    }

    /// `z_m · values` for tensor `param_idx` without materializing all of
    /// `z_m` — the replayed directional-derivative inner product (tests,
    /// theory, diagnostics). One stack-resident block buffer.
    pub fn dot_param(&self, param_idx: usize, values: &[f32]) -> f64 {
        let mut z = [0.0f32; NOISE_BLOCK];
        let mut acc = 0.0f64;
        for (block_idx, chunk) in values.chunks(NOISE_BLOCK).enumerate() {
            let zb = &mut z[..chunk.len()];
            fill_block(block_seed(self.step_seed, param_idx, block_idx), zb);
            for (&v, &n) in chunk.iter().zip(zb.iter()) {
                acc += v as f64 * n as f64;
            }
        }
        acc
    }
}

/// Deterministic per-step seed derivation: `step_seed = h(run_seed, step)`.
pub fn derive_seed(run_seed: u64, step: u64) -> u64 {
    let mut s = run_seed ^ step.wrapping_mul(0x2545F4914F6CDD1D);
    splitmix64(&mut s)
}

/// FNV-1a offset basis (the hash's initial state).
pub const FNV_OFFSET: u64 = 0xcbf29ce484222325;

/// One FNV-1a absorption step over a u64 word — the streaming form used
/// by e.g. the coordinator's dataset fingerprint.
#[inline]
pub fn fnv1a_word(h: u64, x: u64) -> u64 {
    (h ^ x).wrapping_mul(0x100000001b3)
}

/// FNV-1a over a string — the stable hash behind run-id → seed
/// derivation (`sched::spec`) and snapshot identity hashes (`ckpt`).
pub fn fnv1a(s: &str) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in s.as_bytes() {
        h = fnv1a_word(h, b as u64);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn replay_is_bit_identical() {
        let mut a = NoiseStream::new(42);
        let seq: Vec<f32> = (0..1000).map(|_| a.next_normal()).collect();
        let mut b = NoiseStream::new(42);
        let seq2: Vec<f32> = (0..1000).map(|_| b.next_normal()).collect();
        assert_eq!(seq, seq2);
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = NoiseStream::new(1);
        let mut b = NoiseStream::new(2);
        let same = (0..100).filter(|_| a.next_normal() == b.next_normal()).count();
        assert!(same < 5);
    }

    #[test]
    fn normals_have_unit_moments() {
        let mut s = NoiseStream::new(7);
        let n = 200_000;
        let mut sum = 0.0f64;
        let mut sq = 0.0f64;
        for _ in 0..n {
            let x = s.next_normal() as f64;
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn fill_matches_scalar_path() {
        let mut a = NoiseStream::new(9);
        let mut buf = vec![0.0f32; 17];
        a.fill_normal(&mut buf);
        let mut b = NoiseStream::new(9);
        for &x in &buf {
            assert_eq!(x, b.next_normal());
        }
    }

    #[test]
    fn derive_seed_is_deterministic_and_spread() {
        assert_eq!(derive_seed(5, 10), derive_seed(5, 10));
        assert_ne!(derive_seed(5, 10), derive_seed(5, 11));
        assert_ne!(derive_seed(5, 10), derive_seed(6, 10));
    }

    #[test]
    fn state_roundtrip_continues_the_stream_exactly() {
        let mut a = Xoshiro256::new(77);
        for _ in 0..123 {
            a.next_u64();
        }
        let snap = a.state();
        let tail_a: Vec<u64> = (0..50).map(|_| a.next_u64()).collect();
        let mut b = Xoshiro256::from_state(snap);
        let tail_b: Vec<u64> = (0..50).map(|_| b.next_u64()).collect();
        assert_eq!(tail_a, tail_b, "restored stream must continue bit-identically");
    }

    #[test]
    #[should_panic(expected = "degenerate")]
    fn zero_state_is_rejected() {
        Xoshiro256::from_state([0; 4]);
    }

    #[test]
    fn uniform_below_in_range() {
        let mut r = Xoshiro256::new(3);
        for _ in 0..1000 {
            assert!(r.next_below(7) < 7);
        }
    }

    #[test]
    fn uniform_below_is_deterministic_and_roughly_uniform() {
        let draw = |seed: u64| -> Vec<usize> {
            let mut r = Xoshiro256::new(seed);
            (0..30_000).map(|_| r.next_below(10)).collect()
        };
        assert_eq!(draw(11), draw(11));
        let mut counts = [0usize; 10];
        for v in draw(11) {
            counts[v] += 1;
        }
        for &c in &counts {
            // each bucket expects 3000; 4 sigma ≈ 207
            assert!((c as i64 - 3000).abs() < 300, "counts {counts:?}");
        }
    }

    #[test]
    fn uniform_below_handles_edge_sizes() {
        let mut r = Xoshiro256::new(8);
        for _ in 0..100 {
            assert_eq!(r.next_below(1), 0);
        }
        // n near u64 range: the widening multiply must not overflow-bias.
        let big = usize::MAX / 2 + 3;
        for _ in 0..100 {
            assert!(r.next_below(big) < big);
        }
    }

    #[test]
    fn block_seed_spreads_over_params_and_blocks() {
        use std::collections::BTreeSet;
        let mut seen = BTreeSet::new();
        for p in 0..64 {
            for b in 0..64 {
                seen.insert(block_seed(1234, p, b));
            }
        }
        assert_eq!(seen.len(), 64 * 64, "block seeds must not collide");
        assert_ne!(block_seed(1, 2, 3), block_seed(2, 2, 3));
        assert_ne!(block_seed(1, 2, 3), block_seed(1, 3, 2));
        assert_eq!(block_seed(9, 4, 5), block_seed(9, 4, 5));
    }

    #[test]
    fn batched_matches_scalar_oracle_at_every_length() {
        // The roofline contract: lane-batched block noise is bit-identical
        // to the scalar oracle at every block length, including ragged
        // tails that leave lanes partially (or completely) unused. The
        // seed sweep is wide enough to exercise the Ziggurat wedge
        // (~1.2% of draws) and the buffered-draw spill into the live
        // continuation stream (near-certain per full block).
        for &n in &[1usize, 2, 3, 4, 5, 7, 31, 257, 1023, 2048, 4093, 4095, NOISE_BLOCK] {
            for seed in 0..48u64 {
                let bseed = block_seed(seed, 3, 7);
                let mut scalar = vec![0.0f32; n];
                fill_block_scalar(bseed, &mut scalar);
                let mut batched = vec![0.0f32; n];
                fill_block_batched(bseed, &mut batched);
                let sb: Vec<u32> = scalar.iter().map(|v| v.to_bits()).collect();
                let bb: Vec<u32> = batched.iter().map(|v| v.to_bits()).collect();
                assert_eq!(sb, bb, "scalar/batched divergence at n={n} seed={seed}");
            }
        }
    }

    #[test]
    fn oracle_sweep_exercises_the_ziggurat_tail() {
        // Keep the parity test above honest: the compared outputs must
        // actually contain tail samples (|x| > R), the rarest Ziggurat
        // branch and the one with the most draw-consumption variance.
        const R: f32 = 3.442_619_9;
        let mut tail_hits = 0usize;
        let mut block = vec![0.0f32; NOISE_BLOCK];
        for seed in 0..48u64 {
            fill_block_scalar(block_seed(seed, 3, 7), &mut block);
            tail_hits += block.iter().filter(|v| v.abs() > R).count();
        }
        assert!(tail_hits > 0, "seed sweep never reached the Ziggurat tail");
    }

    #[test]
    fn dispatcher_agrees_with_the_oracle() {
        // Whatever path ADDAX_NOISE_SCALAR selects, fill_block's bits are
        // the oracle's bits.
        let bseed = block_seed(99, 0, 0);
        let mut via_dispatch = vec![0.0f32; 1531];
        fill_block(bseed, &mut via_dispatch);
        let mut via_oracle = vec![0.0f32; 1531];
        fill_block_scalar(bseed, &mut via_oracle);
        assert_eq!(via_dispatch, via_oracle);
    }

    #[test]
    fn empty_and_full_blocks_are_handled() {
        let mut empty: [f32; 0] = [];
        fill_block(1, &mut empty);
        fill_block_scalar(1, &mut empty);
        fill_block_batched(1, &mut empty);
        let mut full = vec![0.0f32; NOISE_BLOCK];
        fill_block(1, &mut full);
        assert!(full.iter().all(|v| v.is_finite()));
    }

    #[test]
    #[should_panic(expected = "at most NOISE_BLOCK")]
    fn oversized_block_is_rejected() {
        let mut too_big = vec![0.0f32; NOISE_BLOCK + 1];
        fill_block(1, &mut too_big);
    }

    #[test]
    fn lane_zero_extends_the_classic_stream_seeding() {
        // Lane 0's state is exactly Xoshiro256::new(block_seed)'s state —
        // the lane expansion is a superset of the original single-stream
        // seeding, not a new scheme bolted beside it.
        let bseed = block_seed(7, 1, 2);
        let lanes = lane_states(bseed);
        assert_eq!(lanes[0], Xoshiro256::new(bseed).state());
        // And the four lanes are pairwise distinct.
        for a in 0..NOISE_LANES {
            for b in (a + 1)..NOISE_LANES {
                assert_ne!(lanes[a], lanes[b]);
            }
        }
    }

    #[test]
    fn block_noise_is_order_free() {
        // Generating blocks in reverse order yields the same bits as
        // forward order — the property the parallel sweeps rest on.
        let noise = BlockNoise::new(77);
        let n = NOISE_BLOCK * 2 + 123;
        let mut fwd = vec![0.0f32; n];
        noise.fill_param(3, &mut fwd);
        let mut rev = vec![0.0f32; n];
        let mut spans: Vec<(usize, &mut [f32])> =
            rev.chunks_mut(NOISE_BLOCK).enumerate().collect();
        spans.reverse();
        for (block_idx, chunk) in spans {
            fill_block(block_seed(77, 3, block_idx), chunk);
        }
        assert_eq!(fwd, rev);
    }

    #[test]
    fn block_noise_params_are_independent() {
        let noise = BlockNoise::new(5);
        let mut a = vec![0.0f32; 64];
        let mut b = vec![0.0f32; 64];
        noise.fill_param(0, &mut a);
        noise.fill_param(1, &mut b);
        assert_ne!(a, b);
        // tensor 0's noise is the same whatever else was generated
        let mut a2 = vec![0.0f32; 64];
        noise.fill_param(0, &mut a2);
        assert_eq!(a, a2);
    }

    #[test]
    fn dot_param_matches_materialized_fill() {
        let noise = BlockNoise::new(21);
        let n = NOISE_BLOCK + 321;
        let values: Vec<f32> = (0..n).map(|i| (i as f32 * 0.01).sin()).collect();
        let mut z = vec![0.0f32; n];
        noise.fill_param(4, &mut z);
        let manual: f64 = values
            .iter()
            .zip(z.iter())
            .map(|(&v, &zz)| v as f64 * zz as f64)
            .sum();
        let dotted = noise.dot_param(4, &values);
        assert!((dotted - manual).abs() < 1e-9, "{dotted} vs {manual}");
    }

    #[test]
    fn block_noise_moments_still_unit() {
        // Hash-derived per-block seeds and the lane split must not
        // correlate the normals.
        let noise = BlockNoise::new(31);
        let n = NOISE_BLOCK * 48;
        let mut z = vec![0.0f32; n];
        noise.fill_param(0, &mut z);
        let mean = z.iter().map(|&x| x as f64).sum::<f64>() / n as f64;
        let var = z.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>() / n as f64
            - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
        // adjacent-block correlation
        let mut corr = 0.0f64;
        for i in 0..n - NOISE_BLOCK {
            corr += z[i] as f64 * z[i + NOISE_BLOCK] as f64;
        }
        corr /= (n - NOISE_BLOCK) as f64;
        assert!(corr.abs() < 0.01, "block-lag correlation {corr}");
        // adjacent-lane correlation within one block (lag q)
        let q = NOISE_BLOCK / NOISE_LANES;
        let mut lane_corr = 0.0f64;
        for i in 0..n - q {
            lane_corr += z[i] as f64 * z[i + q] as f64;
        }
        lane_corr /= (n - q) as f64;
        assert!(lane_corr.abs() < 0.01, "lane-lag correlation {lane_corr}");
    }
}
