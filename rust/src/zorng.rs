//! Seed-replay Gaussian noise: the O(1)-memory trick of MeZO/Addax (Alg. 3).
//!
//! The perturbation direction `z ~ N(0, I_d)` is never materialized.
//! Instead, every place that needs `z` (perturb +ε, perturb −2ε, restore
//! +ε, and the final ZO update `θ ← θ − ηαg⁰z`) re-creates a
//! [`NoiseStream`] from the same step seed and regenerates the identical
//! sequence of normals. This reproduces lines 13-17 of Algorithm 1 and all
//! of Algorithms 2-3 from the paper.
//!
//! Generator: splitmix64 seeding xoshiro256++, Ziggurat for normals
//! (Marsaglia-Tsang; replaced Box-Muller in the §Perf pass for a 4.7x
//! speedup) — deterministic across platforms, no external deps (see
//! `benches/hotpath.rs` and EXPERIMENTS.md §Perf).

/// splitmix64 — used to expand a u64 seed into xoshiro state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `(0, 1]` (never exactly 0, safe for `ln`).
    #[inline]
    pub fn next_f64_open(&mut self) -> f64 {
        let u = self.next_u64() >> 11; // 53 bits
        (u as f64 + 1.0) * (1.0 / 9007199254740992.0)
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        let u = self.next_u64() >> 11;
        u as f64 * (1.0 / 9007199254740992.0)
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection-free for our use).
    #[inline]
    pub fn next_below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }
}

/// Ziggurat tables for the standard normal (Marsaglia-Tsang, 128 layers).
///
/// Computed once at first use; pure function of the published constants,
/// so streams stay deterministic across runs and platforms.
struct ZigTables {
    /// Layer x-coordinates, x[0] (base) .. x[128] = 0. Kept for the
    /// wedge/tail math via `wn`; only read at table-build time.
    #[allow(dead_code)]
    x: [f64; 129],
    /// f(x[i]) = exp(-x[i]²/2).
    f: [f64; 129],
    /// Integer fast-path acceptance bound: |hz| < kn[i] accepts directly
    /// (hz is a signed 31-bit uniform), avoiding all float compares.
    kn: [u32; 128],
    /// Scale hz -> x: wn[i] = x[i] / 2³¹.
    wn: [f64; 128],
}

fn zig_tables() -> &'static ZigTables {
    use std::sync::OnceLock;
    static TABLES: OnceLock<ZigTables> = OnceLock::new();
    TABLES.get_or_init(|| {
        const R: f64 = 3.442619855899;
        const V: f64 = 9.91256303526217e-3;
        let mut x = [0.0f64; 129];
        x[0] = V / (-0.5 * R * R).exp(); // pseudo-base so area(strip 0) = V
        x[1] = R;
        for i in 1..128 {
            let prev = x[i];
            x[i + 1] = (-2.0 * (V / prev + (-0.5 * prev * prev).exp()).ln()).sqrt();
        }
        x[128] = 0.0;
        let mut f = [0.0f64; 129];
        for i in 0..129 {
            f[i] = (-0.5 * x[i] * x[i]).exp();
        }
        let m31 = (1u64 << 31) as f64;
        let mut kn = [0u32; 128];
        let mut wn = [0.0f64; 128];
        for i in 0..128 {
            wn[i] = x[i] / m31;
            kn[i] = ((x[i + 1] / x[i]) * m31) as u32;
        }
        ZigTables { x, f, kn, wn }
    })
}

/// A replayable stream of standard normals (Ziggurat sampler; the §Perf
/// pass replaced Box-Muller, which was 70x off memory bandwidth on the
/// perturbation hot path — see EXPERIMENTS.md §Perf).
///
/// Two `NoiseStream::new(seed)` instances produce bit-identical sequences;
/// that is the entire memory-saving contract of Algorithm 3.
#[derive(Clone, Debug)]
pub struct NoiseStream {
    rng: Xoshiro256,
}

impl NoiseStream {
    pub fn new(seed: u64) -> Self {
        Self { rng: Xoshiro256::new(seed) }
    }

    /// Next standard normal.
    #[inline]
    pub fn next_normal(&mut self) -> f32 {
        let t = zig_tables();
        const R: f64 = 3.442619855899;
        loop {
            let bits = self.rng.next_u64();
            let i = (bits & 127) as usize;
            // signed 31-bit uniform
            let hz = ((bits >> 32) as u32 as i64) - (1i64 << 31);
            // fast path: one integer compare + one multiply (~98.8% of draws)
            if (hz.unsigned_abs() as u32) < t.kn[i] {
                return (hz as f64 * t.wn[i]) as f32;
            }
            let x = hz as f64 * t.wn[i];
            if i == 0 {
                // tail (Marsaglia's method)
                loop {
                    let x_tail = -self.rng.next_f64_open().ln() / R;
                    let y = -self.rng.next_f64_open().ln();
                    if 2.0 * y > x_tail * x_tail {
                        return (if hz < 0 { -(R + x_tail) } else { R + x_tail }) as f32;
                    }
                }
            }
            // wedge: accept with probability proportional to the density gap
            let y = self.rng.next_f64();
            if t.f[i + 1] + y * (t.f[i] - t.f[i + 1]) < (-0.5 * x * x).exp() {
                return x as f32;
            }
        }
    }

    /// Fill a slice with normals (the hot path used by perturb/update).
    pub fn fill_normal(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.next_normal();
        }
    }
}

/// Deterministic per-step seed derivation: `step_seed = h(run_seed, step)`.
pub fn derive_seed(run_seed: u64, step: u64) -> u64 {
    let mut s = run_seed ^ step.wrapping_mul(0x2545F4914F6CDD1D);
    splitmix64(&mut s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn replay_is_bit_identical() {
        let mut a = NoiseStream::new(42);
        let seq: Vec<f32> = (0..1000).map(|_| a.next_normal()).collect();
        let mut b = NoiseStream::new(42);
        let seq2: Vec<f32> = (0..1000).map(|_| b.next_normal()).collect();
        assert_eq!(seq, seq2);
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = NoiseStream::new(1);
        let mut b = NoiseStream::new(2);
        let same = (0..100).filter(|_| a.next_normal() == b.next_normal()).count();
        assert!(same < 5);
    }

    #[test]
    fn normals_have_unit_moments() {
        let mut s = NoiseStream::new(7);
        let n = 200_000;
        let mut sum = 0.0f64;
        let mut sq = 0.0f64;
        for _ in 0..n {
            let x = s.next_normal() as f64;
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn fill_matches_scalar_path() {
        let mut a = NoiseStream::new(9);
        let mut buf = vec![0.0f32; 17];
        a.fill_normal(&mut buf);
        let mut b = NoiseStream::new(9);
        for &x in &buf {
            assert_eq!(x, b.next_normal());
        }
    }

    #[test]
    fn derive_seed_is_deterministic_and_spread() {
        assert_eq!(derive_seed(5, 10), derive_seed(5, 10));
        assert_ne!(derive_seed(5, 10), derive_seed(5, 11));
        assert_ne!(derive_seed(5, 10), derive_seed(6, 10));
    }

    #[test]
    fn uniform_below_in_range() {
        let mut r = Xoshiro256::new(3);
        for _ in 0..1000 {
            assert!(r.next_below(7) < 7);
        }
    }
}
