//! The training coordinator: the L3 event loop that owns the request path.
//!
//! Per run it:
//!  1. partitions the training set by sequence length into `D⁰`/`D¹`
//!     (Alg. 1 lines 2-5) according to the optimizer's needs,
//!  2. prefetches step batches on a feeder thread (deterministic in the
//!     run seed, independent of consumer timing),
//!  3. drives the optimizer's in-place updates through the [`ModelExec`]
//!     seam (PJRT artifacts in production, the quadratic mock in tests),
//!  4. evaluates validation accuracy every `eval_every` steps (the paper
//!     checks 1/20 of total steps, App. D.5), tracks the best checkpoint,
//!     and reports the paper's headline metrics: best-validation accuracy,
//!     test accuracy at best validation, and wall-clock time to best,
//!  5. optionally snapshots the full training state into a `ckpt`
//!     directory (cadence: `ckpt_every` steps, or the eval cadence when
//!     unset) and, on restart, **resumes from the latest valid snapshot**
//!     — byte-identically to the uninterrupted run, because every input
//!     of a step is either restored exactly (params, optimizer state,
//!     sampler RNG streams, curves, best-val tracker) or a pure function
//!     of `(run seed, step)` (step seeds, and through them the replayed
//!     ZO noise).

pub mod eval;

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::ckpt::{Checkpointer, ResumeCheck, TrainState};
use crate::data::{partition, Dataset, Example, Sampler};
use crate::jsonlite::{obj, Json};
use crate::metrics::{Curve, JsonlLogger};
use crate::optim::{Optimizer, StepBatches};
use crate::params::ParamStore;
use crate::runtime::ModelExec;
use crate::zorng::derive_seed;

pub use eval::{evaluate, EvalOut};

/// Typed early-exit raised by [`train`] when `halt_after` preempts the
/// run: deterministic in-process stand-in for a mid-run SIGKILL (the
/// on-disk state is the same — the latest checkpoint — since snapshot
/// writes are atomic). The sweep worker downcasts it to count a run as
/// halted rather than failed.
#[derive(Clone, Copy, Debug)]
pub struct Halted {
    /// Completed steps at the moment of preemption.
    pub at_step: usize,
}

impl std::fmt::Display for Halted {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "training halted after step {} (session step budget)", self.at_step)
    }
}

impl std::error::Error for Halted {}

/// Training-run configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub steps: usize,
    /// Validation cadence; 0 = `steps/20` (paper default).
    pub eval_every: usize,
    pub seed: u64,
    /// Cap on examples scored per evaluation (cost control).
    pub eval_examples: usize,
    /// Optional JSONL telemetry path.
    pub log_path: Option<std::path::PathBuf>,
    /// Print progress lines.
    pub verbose: bool,
    /// Worker threads for the ZO noise sweeps, pinned per run on the
    /// parameter store; 0 = auto (`ADDAX_NOISE_WORKERS`, then
    /// `min(cores, 8)`). Bit-exact at any value — the block noise is
    /// counter-addressed.
    pub noise_workers: usize,
    /// Checkpoint directory; None disables checkpointing entirely.
    pub ckpt_dir: Option<std::path::PathBuf>,
    /// Snapshot cadence in steps; 0 = at the eval cadence. Snapshots are
    /// additionally always written at best-validation improvements (so
    /// the best parameters are reloadable) and at a `halt_after` stop.
    pub ckpt_every: usize,
    /// Keep-last-K snapshot retention (best-referenced snapshots are
    /// always kept on top); clamped to ≥ 1.
    pub ckpt_keep: usize,
    /// Identity string stamped into (and demanded of) every snapshot.
    /// Empty = derived from optimizer/task/seed/steps/dtype; the sweep
    /// worker passes the run id.
    pub ckpt_identity: String,
    /// Preemption budget: stop with a [`Halted`] error after this many
    /// steps *this session* (0 = never). With checkpointing enabled the
    /// halt step is snapshotted first, so a later call resumes exactly
    /// there — the deterministic mid-run-kill used by tests and CI.
    pub halt_after: usize,
    /// Live status probe for this run (`obs` module). When set, the loop
    /// publishes step telemetry at step boundaries and honors the probe's
    /// control flags: `checkpoint` forces one extra snapshot, `pause`
    /// parks the loop between steps, `abort` rides the `halt_after` rail
    /// (snapshot, then [`Halted`]). None of these can change a
    /// deterministic byte — see the `obs` module docs.
    pub probe: Option<Arc<crate::obs::RunProbe>>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            steps: 200,
            eval_every: 0,
            seed: 0,
            eval_examples: 100,
            log_path: None,
            verbose: false,
            noise_workers: 0,
            ckpt_dir: None,
            ckpt_every: 0,
            ckpt_keep: 3,
            ckpt_identity: String::new(),
            halt_after: 0,
            probe: None,
        }
    }
}

/// Everything the paper reports about one fine-tuning run.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub optimizer: String,
    pub task: String,
    pub steps: usize,
    pub best_val_acc: f64,
    pub best_val_step: usize,
    /// Wall-clock seconds from step 0 to the best-validation checkpoint
    /// (the paper's "time to best validation", compile time excluded).
    /// Session-local: on a checkpoint-resumed run the clock restarts, so
    /// this is 0.0 when the best predates the resume — like `val_times`,
    /// wall-clock is telemetry outside the byte-identity contract, and
    /// the sweep worker stamps resumed runs' times rows with a note.
    pub time_to_best_secs: f64,
    pub test_acc: f64,
    pub test_f1: f64,
    pub total_secs: f64,
    pub final_train_loss: f64,
    pub loss_curve: Curve,
    pub val_curve: Curve,
    /// Wall-clock at each eval point (for loss-vs-time plots, Fig. 11).
    /// Points restored from a checkpoint carry 0.0 — wall-clock is
    /// telemetry, outside the byte-identical resume contract.
    pub val_times: Vec<f64>,
    /// Step the run resumed from, when it restarted off a checkpoint
    /// (None for an uninterrupted run). Telemetry: the sweep worker
    /// surfaces it in the manifest *times* side file, never in the
    /// deterministic manifest row.
    pub resumed_from_step: Option<usize>,
    /// Checkpoint anomalies worth surfacing (e.g. corrupt snapshots
    /// skipped before a from-scratch restart); empty when clean.
    pub ckpt_note: String,
}

impl RunResult {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("optimizer", Json::from(self.optimizer.clone())),
            ("task", Json::from(self.task.clone())),
            ("steps", Json::from(self.steps)),
            ("best_val_acc", Json::from(self.best_val_acc)),
            ("best_val_step", Json::from(self.best_val_step)),
            ("time_to_best_secs", Json::from(self.time_to_best_secs)),
            ("test_acc", Json::from(self.test_acc)),
            ("test_f1", Json::from(self.test_f1)),
            ("total_secs", Json::from(self.total_secs)),
            ("final_train_loss", Json::from(self.final_train_loss)),
            ("loss_curve", self.loss_curve.to_json()),
            ("val_curve", self.val_curve.to_json()),
            (
                "resumed_from_step",
                match self.resumed_from_step {
                    Some(s) => Json::from(s),
                    None => Json::Null,
                },
            ),
            ("ckpt_note", Json::from(self.ckpt_note.clone())),
        ])
    }
}

/// One prefetched step: the batches plus the sampler RNG states *after*
/// this step's draws. The states ride with the batches (instead of being
/// read off the live samplers) because the feeder runs ahead of the
/// consumer — a checkpoint taken after step `s` must serialize the
/// streams as of step `s`, not as of wherever prefetch has reached.
struct FeedItem {
    batches: StepBatches,
    fo_rng: [u64; 4],
    zo_rng: [u64; 4],
}

/// Deterministic batch feeder running on its own thread.
///
/// Produces the `StepBatches` stream for the whole run up front-of-need
/// (bounded channel, depth 4) so batch construction overlaps XLA
/// execution — the L3 analogue of an input pipeline. On resume the
/// samplers are rebuilt mid-stream from checkpointed RNG states, so the
/// continued batch sequence is bit-identical to the uninterrupted one.
struct BatchFeeder {
    rx: mpsc::Receiver<FeedItem>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl BatchFeeder {
    #[allow(clippy::too_many_arguments)]
    fn spawn(
        examples: Arc<Vec<Example>>,
        d0: Vec<usize>,
        d1: Vec<usize>,
        needs_fo: usize,
        needs_zo: usize,
        steps_remaining: usize,
        seed: u64,
        resume_states: Option<([u64; 4], [u64; 4])>,
    ) -> Self {
        let (tx, rx) = mpsc::sync_channel(4);
        let handle = std::thread::spawn(move || {
            let (mut s_fo, mut s_zo) = match resume_states {
                Some((fo, zo)) => (Sampler::from_state(&d1, fo), Sampler::from_state(&d0, zo)),
                None => (
                    Sampler::new(&d1, derive_seed(seed, 0xF0)),
                    Sampler::new(&d0, derive_seed(seed, 0x20)),
                ),
            };
            for _ in 0..steps_remaining {
                let fo = (needs_fo > 0).then(|| {
                    crate::data::training_batch(&examples, &s_fo.draw(needs_fo))
                });
                let zo = (needs_zo > 0).then(|| {
                    crate::data::training_batch(&examples, &s_zo.draw(needs_zo))
                });
                let item = FeedItem {
                    batches: StepBatches { fo, zo },
                    fo_rng: s_fo.rng_state(),
                    zo_rng: s_zo.rng_state(),
                };
                if tx.send(item).is_err() {
                    break; // consumer dropped (early stop)
                }
            }
        });
        Self { rx, handle: Some(handle) }
    }

    fn next(&self) -> Option<FeedItem> {
        self.rx.recv().ok()
    }
}

impl Drop for BatchFeeder {
    fn drop(&mut self) {
        // Close the channel first so the producer unblocks, then join.
        // (rx is dropped by struct drop order after this; join via take.)
        if let Some(h) = self.handle.take() {
            // Drain anything pending so the producer can finish/send-fail.
            while self.rx.try_recv().is_ok() {}
            drop(std::mem::replace(&mut self.rx, mpsc::channel().1));
            let _ = h.join();
        }
    }
}

/// Deterministic content fingerprint of a dataset (all three splits:
/// sizes, answers, token streams). Folded into the derived checkpoint
/// identity so a resume is refused when the dataset changed — a
/// different generation seed or split size yields different batches and
/// eval sets, and grafting old state onto them would produce a
/// trajectory that is byte-identical to nothing. Costs one FNV pass over
/// the tokens, noise next to a single training step.
fn dataset_fingerprint(ds: &Dataset) -> u64 {
    use crate::zorng::{fnv1a_word, FNV_OFFSET};
    let mut h = FNV_OFFSET;
    for split in [&ds.train, &ds.val, &ds.test] {
        h = fnv1a_word(h, split.len() as u64);
        for e in split.iter() {
            h = fnv1a_word(h, e.answer as u64);
            h = fnv1a_word(h, e.context.len() as u64);
            for &t in &e.context {
                h = fnv1a_word(h, t as u64);
            }
        }
    }
    h
}

/// The `"step"` value of one telemetry row. Rows of a *diverged* run
/// hold `NaN` losses, which jsonlite's writer emits but its parser
/// rejects — those rows must still be trimmable, so fall back to a
/// textual scan of the (BTreeMap-ordered, verbatim) `"step":` field.
fn log_row_step(line: &str) -> Option<usize> {
    if let Ok(v) = Json::parse(line) {
        return v.get("step").ok()?.as_usize().ok();
    }
    let rest = &line[line.find("\"step\":")? + 7..];
    let digits: &str = &rest[..rest.find(|c: char| !c.is_ascii_digit()).unwrap_or(rest.len())];
    digits.parse().ok()
}

/// Drop telemetry rows the resumed session will re-log: step rows with
/// `step >= start_step`, and eval rows (they carry `val_acc`) past the
/// resume point — the eval *at* `start_step` belongs to the previous
/// session (it ran after the step the snapshot captured) and is kept.
/// Rows whose step cannot be determined are kept (never destroy
/// telemetry we don't understand). Telemetry only; failures swallowed.
fn trim_log_for_resume(path: &std::path::Path, start_step: usize) {
    let Ok(text) = std::fs::read_to_string(path) else {
        return;
    };
    let kept: String = text
        .lines()
        .filter(|line| {
            let Some(step) = log_row_step(line) else { return true };
            if step < start_step {
                return true;
            }
            // Eval rows always parse (accuracies are finite); the one at
            // exactly start_step belongs to the previous session.
            step == start_step
                && Json::parse(line).map(|v| v.opt("val_acc").is_some()).unwrap_or(false)
        })
        .map(|l| format!("{l}\n"))
        .collect();
    // Atomic rewrite: a kill mid-write must not destroy the surviving
    // rows this function exists to preserve.
    let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("log");
    let tmp = path.with_file_name(format!("{name}.trim.tmp"));
    if std::fs::write(&tmp, kept).is_ok() {
        let _ = std::fs::rename(&tmp, path);
    }
}

/// Fine-tune `params` with `opt` on `dataset`, partitioned at `lt`.
///
/// This is Algorithm 1 at system level: the partition, the per-step
/// sampling of `B⁰`/`B¹`, the in-place update, and the validation loop.
/// With `cfg.ckpt_dir` set it is also crash-safe: the run resumes from
/// its latest valid snapshot and finishes byte-identically to an
/// uninterrupted run (see the module docs and `tests/ckpt_resume.rs`).
pub fn train(
    exec: &mut dyn ModelExec,
    params: &mut ParamStore,
    opt: &mut dyn Optimizer,
    dataset: &Dataset,
    lt: usize,
    cfg: &TrainConfig,
) -> Result<RunResult> {
    let needs = opt.needs();
    // Pin the noise-sweep pool for the whole run (0 keeps auto selection).
    // The pin lives on the store itself, so concurrent runs in one
    // process (the sweep scheduler) cannot race each other's setting.
    params.set_noise_workers(cfg.noise_workers);
    // Paper cadence is steps/20 (App. D.5); for step budgets under 20 the
    // division truncates to 0, which would be a modulo-by-zero below — it
    // must fall back to evaluating every step.
    let eval_every = if cfg.eval_every == 0 {
        (cfg.steps / 20).max(1)
    } else {
        cfg.eval_every
    };

    // Partition (only meaningful when both batch kinds are needed; single
    // -phase optimizers sample from the full dataset, like the paper's
    // baselines which know nothing of L_T).
    let (d0, d1) = if needs.fo > 0 && needs.zo > 0 {
        partition(&dataset.train, lt)
    } else {
        let all: Vec<usize> = (0..dataset.train.len()).collect();
        (all.clone(), all)
    };

    let mut loss_curve = Curve::default();
    let mut val_curve = Curve::default();
    let mut val_times = Vec::new();
    let mut best_val = f64::NEG_INFINITY;
    let mut best_step = 0;
    let mut best_params: Option<ParamStore> = None;
    let mut time_to_best = 0.0;

    // -- checkpointing: open the directory, try to resume ----------------
    // The derived fallback identity folds in everything that steers the
    // trajectory: the optimizer's hyper-parameter-complete `ckpt_id`
    // (lr, eps, alpha, moments config, …), batch needs, task + a content
    // fingerprint of all three data splits, partition threshold, seeds,
    // budgets, dtype — so an edit to any of them between kill and
    // restart is refused rather than silently grafted. Callers with an
    // externally defined identity (the sweep's run_id, `addax train`'s
    // model/config identity) pass `ckpt_identity` instead. Computed only
    // when checkpointing is on: the fingerprint walks every token of the
    // dataset, which a non-checkpointing run should not pay for.
    let ckpt = match &cfg.ckpt_dir {
        Some(dir) => {
            let identity = if cfg.ckpt_identity.is_empty() {
                format!(
                    "{}~b{}-{}.{}.d{:016x}.l{}.s{}.t{}.e{}.x{}.{}",
                    opt.ckpt_id(),
                    needs.fo,
                    needs.zo,
                    dataset.task.name,
                    dataset_fingerprint(dataset),
                    // The partition threshold steers which examples feed
                    // D⁰/D¹ — an lt edit must refuse stale snapshots too.
                    lt,
                    cfg.seed,
                    cfg.steps,
                    // The resolved cadence: a cadence edit must change the
                    // identity (not just fail ResumeCheck), or the stale
                    // snapshots would squat keep-last-K as same-identity
                    // files GC refuses to evict.
                    eval_every,
                    cfg.eval_examples,
                    params.dtype().label()
                )
            } else {
                cfg.ckpt_identity.clone()
            };
            Some((Checkpointer::new(dir, &identity, opt.name(), cfg.ckpt_keep)?, identity))
        }
        None => None,
    };
    if cfg.halt_after > 0 && ckpt.is_none() {
        // Without a snapshot the halted run restarts from step 0 and
        // halts at the same step forever — same refusal as the sweep's
        // `--halt-after` + `--no-ckpt` guard.
        bail!("halt_after needs checkpointing (set ckpt_dir), or the run can never finish");
    }
    let mut start_step = 0usize;
    let mut resumed_from_step = None;
    let mut ckpt_note = String::new();
    let mut resume_states: Option<([u64; 4], [u64; 4])> = None;
    if let Some((ck, identity)) = &ckpt {
        let specs: Vec<(String, Vec<usize>)> =
            params.iter().map(|p| (p.name.clone(), p.tensor.shape.clone())).collect();
        let scan = ck.resume(&ResumeCheck {
            identity: identity.as_str(),
            dtype: params.dtype(),
            specs: &specs,
            eval_every,
            max_steps: cfg.steps,
        });
        if scan.rejected > 0 {
            ckpt_note = format!("{} invalid snapshot(s) skipped", scan.rejected);
        }
        if let Some(point) = scan.point {
            *params = point.params;
            params.set_noise_workers(cfg.noise_workers);
            opt.load_state(&point.state.opt)?;
            loss_curve = point.state.loss_curve;
            val_curve = point.state.val_curve;
            val_times = vec![0.0; val_curve.points.len()];
            best_val = point.state.best_val;
            best_step = point.state.best_step;
            best_params = point.best_params;
            start_step = point.state.step;
            resumed_from_step = Some(start_step);
            resume_states = Some((point.state.fo_rng, point.state.zo_rng));
            if cfg.verbose {
                println!("[{}] resuming from checkpoint at step {}", opt.name(), start_step);
            }
        } else if scan.rejected > 0 {
            ckpt_note.push_str("; restarted from scratch");
        }
    }
    if let Some(p) = &cfg.probe {
        p.set_running(cfg.steps);
        if let Some(s) = resumed_from_step {
            p.set_resumed_from(s);
        }
    }

    let examples = Arc::new(dataset.train.clone());
    let feeder = BatchFeeder::spawn(
        examples,
        d0,
        d1,
        needs.fo,
        needs.zo,
        cfg.steps - start_step,
        cfg.seed,
        resume_states,
    );

    // A resumed run appends to the telemetry log — truncating would
    // destroy the first session's rows for steps 0..start_step. Rows for
    // steps the resumed session will replay (resume from an *older*
    // snapshot re-executes the gap) are dropped first, so the combined
    // log keeps exactly one row per step / eval point.
    let mut logger = if start_step > 0 {
        if let Some(path) = cfg.log_path.as_deref() {
            trim_log_for_resume(path, start_step);
        }
        JsonlLogger::append(cfg.log_path.as_deref())?
    } else {
        JsonlLogger::new(cfg.log_path.as_deref())?
    };
    let mut steps_this_session = 0usize;
    let t0 = Instant::now();

    for step in start_step..cfg.steps {
        // `item` carries the sampler RNG states as of *this* step's draws
        // (attached by the feeder, since prefetch runs ahead) — exactly
        // what a snapshot taken after this step must serialize.
        let item = feeder.next().expect("feeder ended early");
        let step_seed = derive_seed(cfg.seed, step as u64);
        let stats = opt.step(params, exec, &item.batches, step_seed)?;
        loss_curve.push(step, stats.loss);
        let step_row = obj(vec![
            ("step", Json::from(step)),
            ("loss", Json::from(stats.loss)),
            ("zo_loss", Json::from(stats.zo_loss)),
            ("g0", Json::from(stats.g0)),
            ("grad_norm", Json::from(stats.grad_norm)),
            ("elapsed", Json::from(t0.elapsed().as_secs_f64())),
        ]);
        if let Some(p) = &cfg.probe {
            p.record_step(step, stats.loss, stats.zo_loss, step_row.clone());
        }
        logger.log(step_row);

        let is_eval = (step + 1) % eval_every == 0 || step + 1 == cfg.steps;
        let mut improved = false;
        if is_eval {
            let ev = evaluate(exec, params, &dataset.val, cfg.eval_examples)?;
            val_curve.push(step + 1, ev.accuracy);
            val_times.push(t0.elapsed().as_secs_f64());
            if ev.accuracy > best_val {
                improved = true;
                best_val = ev.accuracy;
                best_step = step + 1;
                best_params = Some(params.clone());
                time_to_best = t0.elapsed().as_secs_f64();
            }
            if cfg.verbose {
                println!(
                    "[{}] step {:>5}/{} loss {:.4} val_acc {:.3} (best {:.3} @ {})",
                    opt.name(),
                    step + 1,
                    cfg.steps,
                    loss_curve.tail_mean(eval_every),
                    ev.accuracy,
                    best_val,
                    best_step
                );
            }
            let eval_row = obj(vec![
                ("step", Json::from(step + 1)),
                ("val_acc", Json::from(ev.accuracy)),
            ]);
            if let Some(p) = &cfg.probe {
                p.record_eval(step + 1, ev.accuracy, best_val, eval_row.clone());
            }
            logger.log(eval_row);
        }

        steps_this_session += 1;
        let mut probe_ckpt = false;
        let mut probe_abort = false;
        if let Some(p) = &cfg.probe {
            // `pause` parks the loop at this step boundary — pure
            // wall-clock, which lives outside the byte-identity contract.
            while p.paused() && !p.abort_requested() {
                std::thread::sleep(std::time::Duration::from_millis(20));
            }
            probe_ckpt = p.take_checkpoint_request() && ckpt.is_some();
            // An abort landing on the final step is a no-op: the run
            // completes normally and its row commits.
            probe_abort = p.take_abort_request() && step + 1 < cfg.steps;
        }
        let halting = probe_abort
            || (cfg.halt_after > 0 && steps_this_session >= cfg.halt_after && step + 1 < cfg.steps);
        if let Some((ck, _)) = &ckpt {
            let step_no = step + 1;
            // Cadence: `ckpt_every` steps when set, else every eval. A
            // best-val improvement always snapshots (the best params must
            // stay reloadable), as does a preemption stop.
            let on_cadence = if cfg.ckpt_every > 0 {
                step_no % cfg.ckpt_every == 0
            } else {
                is_eval
            };
            // A probe `checkpoint` verb forces one extra snapshot here —
            // snapshots record the trajectory, they never steer it.
            if on_cadence || improved || halting || probe_ckpt {
                let state = TrainState {
                    step: step_no,
                    eval_every,
                    best_val,
                    best_step,
                    loss_curve: loss_curve.clone(),
                    val_curve: val_curve.clone(),
                    fo_rng: item.fo_rng,
                    zo_rng: item.zo_rng,
                    opt: opt.state(),
                };
                ck.save(params, &state)?;
                if improved {
                    ck.mark_best(step_no, best_val)?;
                }
            }
        }
        if halting {
            if let Some(p) = &cfg.probe {
                p.set_halted(step + 1);
            }
            logger.flush();
            return Err(Halted { at_step: step + 1 }.into());
        }
    }
    logger.flush();
    if let Some(p) = &cfg.probe {
        p.set_done();
    }

    // Test accuracy at the best-validation checkpoint (paper protocol).
    let eval_params = best_params.as_ref().unwrap_or(params);
    let test =
        evaluate(exec, eval_params, &dataset.test, cfg.eval_examples.max(200))?;

    Ok(RunResult {
        optimizer: opt.name().to_string(),
        task: dataset.task.name.to_string(),
        steps: cfg.steps,
        best_val_acc: best_val.max(0.0),
        best_val_step: best_step,
        time_to_best_secs: time_to_best,
        test_acc: test.accuracy,
        test_f1: test.macro_f1,
        total_secs: t0.elapsed().as_secs_f64(),
        final_train_loss: loss_curve.tail_mean(10),
        loss_curve,
        val_curve,
        val_times,
        resumed_from_step,
        ckpt_note,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::opt_task;
    use crate::optim::{Addax, IpSgd, MeZo};
    use crate::runtime::mock::QuadraticExec;

    fn quad_setup(d: usize) -> (QuadraticExec, ParamStore, Dataset) {
        let exec = QuadraticExec::new(d, 0.5, 2.0, 0.1, 3);
        let params = ParamStore::zeros(&[("w".to_string(), vec![d])]);
        let ds = Dataset::generate(opt_task("sst2").unwrap(), 512, Some(64), 1, 200, 50, 50);
        (exec, params, ds)
    }

    #[test]
    fn train_loop_runs_and_reports() {
        let (mut exec, mut params, ds) = quad_setup(16);
        let mut opt = IpSgd::new(0.1, 4);
        let cfg = TrainConfig { steps: 50, eval_every: 10, ..Default::default() };
        let r = train(&mut exec, &mut params, &mut opt, &ds, 9999, &cfg).unwrap();
        assert_eq!(r.steps, 50);
        assert_eq!(r.loss_curve.points.len(), 50);
        assert!(r.val_curve.points.len() >= 5);
        // quadratic mock: loss decreases
        assert!(r.final_train_loss < r.loss_curve.points[0].1);
    }

    #[test]
    fn eval_cadence_falls_back_to_one_below_twenty_steps() {
        // eval_every = 0 with steps < 20: steps/20 truncates to 0 and must
        // fall back to a cadence of 1, not divide-by-zero in the modulo.
        let (mut exec, mut params, ds) = quad_setup(8);
        let mut opt = IpSgd::new(0.1, 2);
        let cfg = TrainConfig { steps: 5, eval_every: 0, ..Default::default() };
        let r = train(&mut exec, &mut params, &mut opt, &ds, 9999, &cfg).unwrap();
        assert_eq!(r.loss_curve.points.len(), 5);
        // cadence 1 ⇒ an eval point after every step
        assert_eq!(r.val_curve.points.len(), 5);
        assert_eq!(r.val_curve.points.first().map(|&(s, _)| s), Some(1));
    }

    #[test]
    fn addax_gets_both_batches_and_trains() {
        let (mut exec, mut params, ds) = quad_setup(16);
        let mut opt = Addax::new(0.05, 1e-3, 0.3, 4, 4);
        let cfg = TrainConfig { steps: 40, eval_every: 20, ..Default::default() };
        let r = train(&mut exec, &mut params, &mut opt, &ds, 40, &cfg).unwrap();
        assert!(r.final_train_loss.is_finite());
        assert!(exec.stats().grad_calls >= 40);
        assert!(exec.stats().forward_calls >= 80);
    }

    #[test]
    fn mezo_runs_without_fo_batches() {
        let (mut exec, mut params, ds) = quad_setup(8);
        let mut opt = MeZo::new(0.02, 1e-3, 4);
        let cfg = TrainConfig { steps: 30, ..Default::default() };
        let r = train(&mut exec, &mut params, &mut opt, &ds, 9999, &cfg).unwrap();
        assert_eq!(exec.stats().grad_calls, 0);
        assert!(r.total_secs >= 0.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let run = || {
            let (mut exec, mut params, ds) = quad_setup(12);
            let mut opt = Addax::new(0.05, 1e-3, 0.3, 2, 2);
            let cfg = TrainConfig { steps: 20, seed: 7, ..Default::default() };
            let r = train(&mut exec, &mut params, &mut opt, &ds, 40, &cfg).unwrap();
            (r.final_train_loss, params.dist_sq(&ParamStore::zeros(&[("w".to_string(), vec![12])])))
        };
        let a = run();
        let b = run();
        assert_eq!(a.0, b.0);
        assert_eq!(a.1, b.1);
    }

    #[test]
    fn jsonl_log_written() {
        let dir = std::env::temp_dir().join("addax_train_log_test");
        std::fs::create_dir_all(&dir).unwrap();
        let log = dir.join("run.jsonl");
        let (mut exec, mut params, ds) = quad_setup(8);
        let mut opt = IpSgd::new(0.1, 2);
        let cfg = TrainConfig {
            steps: 10,
            eval_every: 5,
            log_path: Some(log.clone()),
            ..Default::default()
        };
        train(&mut exec, &mut params, &mut opt, &ds, 9999, &cfg).unwrap();
        let text = std::fs::read_to_string(&log).unwrap();
        assert!(text.lines().count() >= 10);
        // each line parses as JSON; step rows carry the ZO-batch loss
        // (surfaced instead of discarded — 0.0 for this FO-only run)
        for line in text.lines() {
            crate::jsonlite::Json::parse(line).unwrap();
        }
        assert!(text.contains("\"zo_loss\""), "step rows must surface zo_loss");
        std::fs::remove_file(log).ok();
    }

    #[test]
    fn halted_run_resumes_byte_identically() {
        let dir = std::env::temp_dir()
            .join(format!("addax_coord_halt_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let cfg = TrainConfig { steps: 30, eval_every: 5, seed: 3, ..Default::default() };

        // Control: uninterrupted, no checkpointing at all.
        let (mut exec, mut params, ds) = quad_setup(12);
        let mut opt = Addax::new(0.05, 1e-3, 0.3, 2, 2);
        let control = train(&mut exec, &mut params, &mut opt, &ds, 40, &cfg).unwrap();
        assert_eq!(control.resumed_from_step, None);

        // Preempted at step 7 (mid eval cadence), then resumed. The JSONL
        // telemetry log must accumulate across the two sessions.
        let log = dir.join("run.jsonl");
        let (mut exec2, mut params2, ds2) = quad_setup(12);
        let mut opt2 = Addax::new(0.05, 1e-3, 0.3, 2, 2);
        let halt_cfg = TrainConfig {
            ckpt_dir: Some(dir.clone()),
            halt_after: 7,
            log_path: Some(log.clone()),
            ..cfg.clone()
        };
        let err = train(&mut exec2, &mut params2, &mut opt2, &ds2, 40, &halt_cfg).unwrap_err();
        let halted = err.downcast_ref::<Halted>().expect("typed Halted error");
        assert_eq!(halted.at_step, 7);

        let (mut exec3, mut params3, ds3) = quad_setup(12);
        let mut opt3 = Addax::new(0.05, 1e-3, 0.3, 2, 2);
        let resume_cfg = TrainConfig {
            ckpt_dir: Some(dir.clone()),
            log_path: Some(log.clone()),
            ..cfg.clone()
        };
        let resumed = train(&mut exec3, &mut params3, &mut opt3, &ds3, 40, &resume_cfg).unwrap();

        assert_eq!(resumed.resumed_from_step, Some(7));
        // Resume appended: the first session's rows (steps 0..7) survive
        // alongside the second's — and the combined log holds EXACTLY one
        // step row per step (replayed rows are trimmed, not duplicated).
        let log_text = std::fs::read_to_string(&log).unwrap();
        assert!(log_text.contains("\"step\":0,"), "first-session rows must survive");
        let step_rows: Vec<usize> = log_text
            .lines()
            .filter(|l| l.contains("\"loss\""))
            .map(|l| {
                crate::jsonlite::Json::parse(l).unwrap().get("step").unwrap().as_usize().unwrap()
            })
            .collect();
        assert_eq!(step_rows, (0..30).collect::<Vec<_>>(), "one step row per step");
        assert!(resumed.ckpt_note.is_empty(), "{}", resumed.ckpt_note);
        // The defining contract: deterministic outputs are byte-identical.
        assert_eq!(resumed.loss_curve.points, control.loss_curve.points);
        assert_eq!(resumed.val_curve.points, control.val_curve.points);
        assert_eq!(resumed.best_val_acc, control.best_val_acc);
        assert_eq!(resumed.best_val_step, control.best_val_step);
        assert_eq!(resumed.test_acc, control.test_acc);
        assert_eq!(resumed.test_f1, control.test_f1);
        assert_eq!(resumed.final_train_loss, control.final_train_loss);
        assert_eq!(params3.dist_sq(&params), 0.0, "final params must match bitwise");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn dataset_fingerprint_tracks_content() {
        let gen = |seed: u64, n: usize| {
            Dataset::generate(opt_task("sst2").unwrap(), 512, Some(64), seed, n, 20, 20)
        };
        let a = gen(1, 50);
        assert_eq!(dataset_fingerprint(&a), dataset_fingerprint(&gen(1, 50)));
        assert_ne!(dataset_fingerprint(&a), dataset_fingerprint(&gen(2, 50)), "data seed");
        assert_ne!(dataset_fingerprint(&a), dataset_fingerprint(&gen(1, 60)), "split size");
    }

    #[test]
    fn halt_without_checkpointing_is_refused() {
        let (mut exec, mut params, ds) = quad_setup(8);
        let mut opt = IpSgd::new(0.1, 2);
        let cfg = TrainConfig { steps: 10, halt_after: 3, ..Default::default() };
        let err = train(&mut exec, &mut params, &mut opt, &ds, 9999, &cfg).unwrap_err();
        assert!(format!("{err}").contains("checkpointing"), "{err}");
    }

    #[test]
    fn resume_refuses_a_config_edit_and_restarts_clean() {
        // Editing the optimizer between kill and restart changes the
        // derived identity, so the stale snapshots are rejected and the
        // run restarts from scratch with a note — never a silent graft.
        let dir = std::env::temp_dir()
            .join(format!("addax_coord_edit_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let cfg = TrainConfig {
            steps: 20,
            eval_every: 5,
            seed: 2,
            ckpt_dir: Some(dir.clone()),
            ..Default::default()
        };
        let (mut exec, mut params, ds) = quad_setup(8);
        let mut opt = IpSgd::new(0.1, 2);
        let halt_cfg = TrainConfig { halt_after: 6, ..cfg.clone() };
        train(&mut exec, &mut params, &mut opt, &ds, 9999, &halt_cfg).unwrap_err();

        let (mut exec2, mut params2, ds2) = quad_setup(8);
        let mut edited = IpSgd::new(0.05, 2); // different lr
        let r = train(&mut exec2, &mut params2, &mut edited, &ds2, 9999, &cfg).unwrap();
        assert_eq!(r.resumed_from_step, None, "edited config must not resume");
        assert!(r.ckpt_note.contains("invalid snapshot"), "{}", r.ckpt_note);
        assert!(r.ckpt_note.contains("scratch"), "{}", r.ckpt_note);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn adam_halt_resume_restores_moments_exactly() {
        // Adam is the stateful case: without the OptState seam the
        // moments would restart at zero and the trajectories diverge.
        let dir = std::env::temp_dir()
            .join(format!("addax_coord_adam_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let cfg = TrainConfig { steps: 24, eval_every: 6, seed: 9, ..Default::default() };
        let (mut exec, mut params, ds) = quad_setup(10);
        let mut opt = crate::optim::Adam::new(0.05, 3);
        let control = train(&mut exec, &mut params, &mut opt, &ds, 9999, &cfg).unwrap();

        let (mut exec2, mut params2, ds2) = quad_setup(10);
        let mut opt2 = crate::optim::Adam::new(0.05, 3);
        let halt_cfg = TrainConfig {
            ckpt_dir: Some(dir.clone()),
            halt_after: 11,
            ..cfg.clone()
        };
        train(&mut exec2, &mut params2, &mut opt2, &ds2, 9999, &halt_cfg).unwrap_err();
        let (mut exec3, mut params3, ds3) = quad_setup(10);
        let mut opt3 = crate::optim::Adam::new(0.05, 3);
        let resume_cfg = TrainConfig { ckpt_dir: Some(dir.clone()), ..cfg.clone() };
        let resumed = train(&mut exec3, &mut params3, &mut opt3, &ds3, 9999, &resume_cfg).unwrap();
        assert_eq!(resumed.resumed_from_step, Some(11));
        assert_eq!(resumed.loss_curve.points, control.loss_curve.points);
        assert_eq!(params3.dist_sq(&params), 0.0);
        assert_eq!(opt3.state(), opt.state(), "moments must land on the same bits");
        std::fs::remove_dir_all(&dir).ok();
    }
}
