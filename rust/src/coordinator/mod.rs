//! The training coordinator: the L3 event loop that owns the request path.
//!
//! Per run it:
//!  1. partitions the training set by sequence length into `D⁰`/`D¹`
//!     (Alg. 1 lines 2-5) according to the optimizer's needs,
//!  2. prefetches step batches on a feeder thread (deterministic in the
//!     run seed, independent of consumer timing),
//!  3. drives the optimizer's in-place updates through the [`ModelExec`]
//!     seam (PJRT artifacts in production, the quadratic mock in tests),
//!  4. evaluates validation accuracy every `eval_every` steps (the paper
//!     checks 1/20 of total steps, App. D.5), tracks the best checkpoint,
//!     and reports the paper's headline metrics: best-validation accuracy,
//!     test accuracy at best validation, and wall-clock time to best.

pub mod eval;

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::data::{partition, Dataset, Example, Sampler};
use crate::jsonlite::{obj, Json};
use crate::metrics::{Curve, JsonlLogger};
use crate::optim::{Optimizer, StepBatches};
use crate::params::ParamStore;
use crate::runtime::ModelExec;
use crate::zorng::derive_seed;

pub use eval::{evaluate, EvalOut};

/// Training-run configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub steps: usize,
    /// Validation cadence; 0 = `steps/20` (paper default).
    pub eval_every: usize,
    pub seed: u64,
    /// Cap on examples scored per evaluation (cost control).
    pub eval_examples: usize,
    /// Optional JSONL telemetry path.
    pub log_path: Option<std::path::PathBuf>,
    /// Print progress lines.
    pub verbose: bool,
    /// Worker threads for the ZO noise sweeps, pinned per run on the
    /// parameter store; 0 = auto (`ADDAX_NOISE_WORKERS`, then
    /// `min(cores, 8)`). Bit-exact at any value — the block noise is
    /// counter-addressed.
    pub noise_workers: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            steps: 200,
            eval_every: 0,
            seed: 0,
            eval_examples: 100,
            log_path: None,
            verbose: false,
            noise_workers: 0,
        }
    }
}

/// Everything the paper reports about one fine-tuning run.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub optimizer: String,
    pub task: String,
    pub steps: usize,
    pub best_val_acc: f64,
    pub best_val_step: usize,
    /// Wall-clock seconds from step 0 to the best-validation checkpoint
    /// (the paper's "time to best validation", compile time excluded).
    pub time_to_best_secs: f64,
    pub test_acc: f64,
    pub test_f1: f64,
    pub total_secs: f64,
    pub final_train_loss: f64,
    pub loss_curve: Curve,
    pub val_curve: Curve,
    /// Wall-clock at each eval point (for loss-vs-time plots, Fig. 11).
    pub val_times: Vec<f64>,
}

impl RunResult {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("optimizer", Json::from(self.optimizer.clone())),
            ("task", Json::from(self.task.clone())),
            ("steps", Json::from(self.steps)),
            ("best_val_acc", Json::from(self.best_val_acc)),
            ("best_val_step", Json::from(self.best_val_step)),
            ("time_to_best_secs", Json::from(self.time_to_best_secs)),
            ("test_acc", Json::from(self.test_acc)),
            ("test_f1", Json::from(self.test_f1)),
            ("total_secs", Json::from(self.total_secs)),
            ("final_train_loss", Json::from(self.final_train_loss)),
            ("loss_curve", self.loss_curve.to_json()),
            ("val_curve", self.val_curve.to_json()),
        ])
    }
}

/// Deterministic batch feeder running on its own thread.
///
/// Produces the `StepBatches` stream for the whole run up front-of-need
/// (bounded channel, depth 4) so batch construction overlaps XLA
/// execution — the L3 analogue of an input pipeline.
struct BatchFeeder {
    rx: mpsc::Receiver<StepBatches>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl BatchFeeder {
    fn spawn(
        examples: Arc<Vec<Example>>,
        d0: Vec<usize>,
        d1: Vec<usize>,
        needs_fo: usize,
        needs_zo: usize,
        steps: usize,
        seed: u64,
    ) -> Self {
        let (tx, rx) = mpsc::sync_channel(4);
        let handle = std::thread::spawn(move || {
            let mut s_fo = Sampler::new(&d1, derive_seed(seed, 0xF0));
            let mut s_zo = Sampler::new(&d0, derive_seed(seed, 0x20));
            for _ in 0..steps {
                let fo = (needs_fo > 0).then(|| {
                    crate::data::training_batch(&examples, &s_fo.draw(needs_fo))
                });
                let zo = (needs_zo > 0).then(|| {
                    crate::data::training_batch(&examples, &s_zo.draw(needs_zo))
                });
                if tx.send(StepBatches { fo, zo }).is_err() {
                    break; // consumer dropped (early stop)
                }
            }
        });
        Self { rx, handle: Some(handle) }
    }

    fn next(&self) -> Option<StepBatches> {
        self.rx.recv().ok()
    }
}

impl Drop for BatchFeeder {
    fn drop(&mut self) {
        // Close the channel first so the producer unblocks, then join.
        // (rx is dropped by struct drop order after this; join via take.)
        if let Some(h) = self.handle.take() {
            // Drain anything pending so the producer can finish/send-fail.
            while self.rx.try_recv().is_ok() {}
            drop(std::mem::replace(&mut self.rx, mpsc::channel().1));
            let _ = h.join();
        }
    }
}

/// Fine-tune `params` with `opt` on `dataset`, partitioned at `lt`.
///
/// This is Algorithm 1 at system level: the partition, the per-step
/// sampling of `B⁰`/`B¹`, the in-place update, and the validation loop.
pub fn train(
    exec: &mut dyn ModelExec,
    params: &mut ParamStore,
    opt: &mut dyn Optimizer,
    dataset: &Dataset,
    lt: usize,
    cfg: &TrainConfig,
) -> Result<RunResult> {
    let needs = opt.needs();
    // Pin the noise-sweep pool for the whole run (0 keeps auto selection).
    // The pin lives on the store itself, so concurrent runs in one
    // process (the sweep scheduler) cannot race each other's setting.
    params.set_noise_workers(cfg.noise_workers);
    // Paper cadence is steps/20 (App. D.5); for step budgets under 20 the
    // division truncates to 0, which would be a modulo-by-zero below — it
    // must fall back to evaluating every step.
    let eval_every = if cfg.eval_every == 0 {
        (cfg.steps / 20).max(1)
    } else {
        cfg.eval_every
    };

    // Partition (only meaningful when both batch kinds are needed; single
    // -phase optimizers sample from the full dataset, like the paper's
    // baselines which know nothing of L_T).
    let (d0, d1) = if needs.fo > 0 && needs.zo > 0 {
        partition(&dataset.train, lt)
    } else {
        let all: Vec<usize> = (0..dataset.train.len()).collect();
        (all.clone(), all)
    };

    let examples = Arc::new(dataset.train.clone());
    let feeder = BatchFeeder::spawn(
        examples,
        d0,
        d1,
        needs.fo,
        needs.zo,
        cfg.steps,
        cfg.seed,
    );

    let mut logger = JsonlLogger::new(cfg.log_path.as_deref())?;
    let mut loss_curve = Curve::default();
    let mut val_curve = Curve::default();
    let mut val_times = Vec::new();
    let mut best_val = f64::NEG_INFINITY;
    let mut best_step = 0;
    let mut best_params: Option<ParamStore> = None;
    let mut time_to_best = 0.0;
    let t0 = Instant::now();

    for step in 0..cfg.steps {
        let batches = feeder.next().expect("feeder ended early");
        let step_seed = derive_seed(cfg.seed, step as u64);
        let stats = opt.step(params, exec, &batches, step_seed)?;
        loss_curve.push(step, stats.loss);
        logger.log(obj(vec![
            ("step", Json::from(step)),
            ("loss", Json::from(stats.loss)),
            ("g0", Json::from(stats.g0)),
            ("grad_norm", Json::from(stats.grad_norm)),
            ("elapsed", Json::from(t0.elapsed().as_secs_f64())),
        ]));

        if (step + 1) % eval_every == 0 || step + 1 == cfg.steps {
            let ev = evaluate(exec, params, &dataset.val, cfg.eval_examples)?;
            val_curve.push(step + 1, ev.accuracy);
            val_times.push(t0.elapsed().as_secs_f64());
            if ev.accuracy > best_val {
                best_val = ev.accuracy;
                best_step = step + 1;
                best_params = Some(params.clone());
                time_to_best = t0.elapsed().as_secs_f64();
            }
            if cfg.verbose {
                println!(
                    "[{}] step {:>5}/{} loss {:.4} val_acc {:.3} (best {:.3} @ {})",
                    opt.name(),
                    step + 1,
                    cfg.steps,
                    loss_curve.tail_mean(eval_every),
                    ev.accuracy,
                    best_val,
                    best_step
                );
            }
            logger.log(obj(vec![
                ("step", Json::from(step + 1)),
                ("val_acc", Json::from(ev.accuracy)),
            ]));
        }
    }
    logger.flush();

    // Test accuracy at the best-validation checkpoint (paper protocol).
    let eval_params = best_params.as_ref().unwrap_or(params);
    let test =
        evaluate(exec, eval_params, &dataset.test, cfg.eval_examples.max(200))?;

    Ok(RunResult {
        optimizer: opt.name().to_string(),
        task: dataset.task.name.to_string(),
        steps: cfg.steps,
        best_val_acc: best_val.max(0.0),
        best_val_step: best_step,
        time_to_best_secs: time_to_best,
        test_acc: test.accuracy,
        test_f1: test.macro_f1,
        total_secs: t0.elapsed().as_secs_f64(),
        final_train_loss: loss_curve.tail_mean(10),
        loss_curve,
        val_curve,
        val_times,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::opt_task;
    use crate::optim::{Addax, IpSgd, MeZo};
    use crate::runtime::mock::QuadraticExec;

    fn quad_setup(d: usize) -> (QuadraticExec, ParamStore, Dataset) {
        let exec = QuadraticExec::new(d, 0.5, 2.0, 0.1, 3);
        let params = ParamStore::zeros(&[("w".to_string(), vec![d])]);
        let ds = Dataset::generate(opt_task("sst2").unwrap(), 512, Some(64), 1, 200, 50, 50);
        (exec, params, ds)
    }

    #[test]
    fn train_loop_runs_and_reports() {
        let (mut exec, mut params, ds) = quad_setup(16);
        let mut opt = IpSgd::new(0.1, 4);
        let cfg = TrainConfig { steps: 50, eval_every: 10, ..Default::default() };
        let r = train(&mut exec, &mut params, &mut opt, &ds, 9999, &cfg).unwrap();
        assert_eq!(r.steps, 50);
        assert_eq!(r.loss_curve.points.len(), 50);
        assert!(r.val_curve.points.len() >= 5);
        // quadratic mock: loss decreases
        assert!(r.final_train_loss < r.loss_curve.points[0].1);
    }

    #[test]
    fn eval_cadence_falls_back_to_one_below_twenty_steps() {
        // eval_every = 0 with steps < 20: steps/20 truncates to 0 and must
        // fall back to a cadence of 1, not divide-by-zero in the modulo.
        let (mut exec, mut params, ds) = quad_setup(8);
        let mut opt = IpSgd::new(0.1, 2);
        let cfg = TrainConfig { steps: 5, eval_every: 0, ..Default::default() };
        let r = train(&mut exec, &mut params, &mut opt, &ds, 9999, &cfg).unwrap();
        assert_eq!(r.loss_curve.points.len(), 5);
        // cadence 1 ⇒ an eval point after every step
        assert_eq!(r.val_curve.points.len(), 5);
        assert_eq!(r.val_curve.points.first().map(|&(s, _)| s), Some(1));
    }

    #[test]
    fn addax_gets_both_batches_and_trains() {
        let (mut exec, mut params, ds) = quad_setup(16);
        let mut opt = Addax::new(0.05, 1e-3, 0.3, 4, 4);
        let cfg = TrainConfig { steps: 40, eval_every: 20, ..Default::default() };
        let r = train(&mut exec, &mut params, &mut opt, &ds, 40, &cfg).unwrap();
        assert!(r.final_train_loss.is_finite());
        assert!(exec.stats().grad_calls >= 40);
        assert!(exec.stats().forward_calls >= 80);
    }

    #[test]
    fn mezo_runs_without_fo_batches() {
        let (mut exec, mut params, ds) = quad_setup(8);
        let mut opt = MeZo::new(0.02, 1e-3, 4);
        let cfg = TrainConfig { steps: 30, ..Default::default() };
        let r = train(&mut exec, &mut params, &mut opt, &ds, 9999, &cfg).unwrap();
        assert_eq!(exec.stats().grad_calls, 0);
        assert!(r.total_secs >= 0.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let run = || {
            let (mut exec, mut params, ds) = quad_setup(12);
            let mut opt = Addax::new(0.05, 1e-3, 0.3, 2, 2);
            let cfg = TrainConfig { steps: 20, seed: 7, ..Default::default() };
            let r = train(&mut exec, &mut params, &mut opt, &ds, 40, &cfg).unwrap();
            (r.final_train_loss, params.dist_sq(&ParamStore::zeros(&[("w".to_string(), vec![12])])))
        };
        let a = run();
        let b = run();
        assert_eq!(a.0, b.0);
        assert_eq!(a.1, b.1);
    }

    #[test]
    fn jsonl_log_written() {
        let dir = std::env::temp_dir().join("addax_train_log_test");
        std::fs::create_dir_all(&dir).unwrap();
        let log = dir.join("run.jsonl");
        let (mut exec, mut params, ds) = quad_setup(8);
        let mut opt = IpSgd::new(0.1, 2);
        let cfg = TrainConfig {
            steps: 10,
            eval_every: 5,
            log_path: Some(log.clone()),
            ..Default::default()
        };
        train(&mut exec, &mut params, &mut opt, &ds, 9999, &cfg).unwrap();
        let text = std::fs::read_to_string(&log).unwrap();
        assert!(text.lines().count() >= 10);
        // each line parses as JSON
        for line in text.lines() {
            crate::jsonlite::Json::parse(line).unwrap();
        }
        std::fs::remove_file(log).ok();
    }
}
