//! Evaluation: candidate scoring by average log-likelihood (App. D.3).
//!
//! For every example, each class's verbalizer is substituted into the
//! prompt and scored by the model's average token log-likelihood over the
//! verbalizer region; the prediction is the candidate with the lowest
//! average loss. Candidates of many examples are packed into one
//! [`TokenBatch`] so the runtime amortizes executions.

use anyhow::Result;

use crate::data::Example;
use crate::metrics::{accuracy, macro_f1};
use crate::params::ParamStore;
use crate::runtime::{ModelExec, TokenBatch};

/// Evaluation output.
#[derive(Clone, Copy, Debug)]
pub struct EvalOut {
    pub accuracy: f64,
    pub macro_f1: f64,
    pub n: usize,
}

/// Score up to `cap` examples.
pub fn evaluate(
    exec: &mut dyn ModelExec,
    params: &ParamStore,
    examples: &[Example],
    cap: usize,
) -> Result<EvalOut> {
    let n = examples.len().min(cap);
    if n == 0 {
        return Ok(EvalOut { accuracy: 0.0, macro_f1: 0.0, n: 0 });
    }
    let n_classes = examples[0].n_classes;
    let mut preds = Vec::with_capacity(n);
    let mut truths = Vec::with_capacity(n);

    // Pack examples into groups so each forward covers several examples'
    // candidate rows; group size chosen so a group is a few artifact
    // batches at most.
    let group = (16 / n_classes).max(1);
    for chunk in examples[..n].chunks(group) {
        let rows: Vec<(Vec<i32>, Vec<i32>)> = chunk
            .iter()
            .flat_map(|e| (0..n_classes).map(move |c| e.candidate_row(c)))
            .collect();
        let batch = TokenBatch::from_rows(&rows);
        let out = exec.forward(params, &batch)?;
        for (i, e) in chunk.iter().enumerate() {
            let mut best = (f64::INFINITY, 0usize);
            for c in 0..n_classes {
                let idx = i * n_classes + c;
                let count = out.counts[idx].max(1.0) as f64;
                let avg = out.sums[idx] as f64 / count;
                if avg < best.0 {
                    best = (avg, c);
                }
            }
            preds.push(best.1);
            truths.push(e.answer);
        }
    }
    Ok(EvalOut {
        accuracy: accuracy(&preds, &truths),
        macro_f1: macro_f1(&preds, &truths, n_classes),
        n,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate, opt_task};
    use crate::runtime::mock::QuadraticExec;

    /// On the quadratic mock the "loss" is unrelated to candidates, so
    /// evaluation should be ~chance — this pins the plumbing, not skill.
    #[test]
    fn eval_runs_on_mock_and_is_near_chance() {
        let mut exec = QuadraticExec::new(8, 1.0, 2.0, 0.5, 3);
        let params = ParamStore::zeros(&[("w".to_string(), vec![8])]);
        let ex = generate(opt_task("sst2").unwrap(), 120, 512, Some(64), 5);
        let out = evaluate(&mut exec, &params, &ex, 120).unwrap();
        assert_eq!(out.n, 120);
        assert!(out.accuracy > 0.25 && out.accuracy < 0.75, "{}", out.accuracy);
    }

    #[test]
    fn eval_respects_cap() {
        let mut exec = QuadraticExec::new(4, 1.0, 2.0, 0.0, 1);
        let params = ParamStore::zeros(&[("w".to_string(), vec![4])]);
        let ex = generate(opt_task("cb").unwrap(), 50, 512, Some(64), 2);
        let out = evaluate(&mut exec, &params, &ex, 10).unwrap();
        assert_eq!(out.n, 10);
    }

    #[test]
    fn empty_eval_is_zero() {
        let mut exec = QuadraticExec::new(4, 1.0, 2.0, 0.0, 1);
        let params = ParamStore::zeros(&[("w".to_string(), vec![4])]);
        let out = evaluate(&mut exec, &params, &[], 10).unwrap();
        assert_eq!(out.n, 0);
    }
}
