//! The sweep manifest: one JSON line per completed run, crash-safe and
//! canonical.
//!
//! Contract:
//!
//! * **Append-only while running** — each completed run is serialized as
//!   one line and appended (`O_APPEND` + flush) the moment it finishes,
//!   so a killed sweep loses at most the in-flight runs. A torn final
//!   line from a crash is skipped (and counted) on load.
//! * **Skip-completed on restart** — the scheduler loads the manifest
//!   first and only executes runs whose id is absent.
//! * **Canonical at rest** — after a sweep completes, the file is
//!   compacted: rows rewritten sorted by run id (tmp file + rename).
//!   Rows contain only deterministic quantities — accuracy, losses,
//!   curves — never wall-clock, so the compacted manifest is
//!   *byte-identical* for the same spec regardless of worker count,
//!   interruptions, or hardware. Timings go to a sibling
//!   `<stem>.times.jsonl` side file that is explicitly outside the
//!   determinism contract.
//!
//! Tables and figures aggregate over these rows alone; a manifest (plus
//! the analytic memory model) is sufficient to regenerate every report.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{Context, Result};

use crate::coordinator::{EvalOut, RunResult};
use crate::ioutil;
use crate::jsonlite::{obj, Json};
use crate::metrics::Curve;

use super::spec::RunSpec;

/// Deterministic results of one run (the paper-reported quantities).
#[derive(Clone, Debug)]
pub struct Outcome {
    /// "train" or "eval" (zero-shot, steps = 0).
    pub kind: String,
    pub best_val_acc: f64,
    pub best_val_step: usize,
    pub test_acc: f64,
    pub test_f1: f64,
    pub final_train_loss: f64,
    pub steps: usize,
    pub loss_curve: Curve,
    pub val_curve: Curve,
}

/// Clamp non-finite values (a NaN would corrupt the JSON line).
fn finite(x: f64) -> f64 {
    if x.is_finite() {
        x
    } else {
        0.0
    }
}

/// [`finite`] over every point of a curve: a diverged run (inf/NaN loss,
/// e.g. an aggressive lr grid point) must still produce a parseable —
/// and therefore resumable — manifest row.
fn finite_curve(c: &Curve) -> Curve {
    Curve { points: c.points.iter().map(|&(s, v)| (s, finite(v))).collect() }
}

/// One manifest line: the run's identity (full spec) plus its outcome.
#[derive(Clone, Debug)]
pub struct ManifestRow {
    pub run_id: String,
    pub spec: Json,
    pub outcome: Outcome,
}

impl ManifestRow {
    pub fn from_train(spec: &RunSpec, r: &RunResult) -> Self {
        Self {
            run_id: spec.run_id.clone(),
            spec: spec.to_json(),
            outcome: Outcome {
                kind: "train".to_string(),
                best_val_acc: finite(r.best_val_acc),
                best_val_step: r.best_val_step,
                test_acc: finite(r.test_acc),
                test_f1: finite(r.test_f1),
                final_train_loss: finite(r.final_train_loss),
                steps: r.steps,
                loss_curve: finite_curve(&r.loss_curve),
                val_curve: finite_curve(&r.val_curve),
            },
        }
    }

    pub fn from_eval(spec: &RunSpec, ev: &EvalOut) -> Self {
        Self {
            run_id: spec.run_id.clone(),
            spec: spec.to_json(),
            outcome: Outcome {
                kind: "eval".to_string(),
                best_val_acc: 0.0,
                best_val_step: 0,
                test_acc: finite(ev.accuracy),
                test_f1: finite(ev.macro_f1),
                final_train_loss: 0.0,
                steps: 0,
                loss_curve: Curve::default(),
                val_curve: Curve::default(),
            },
        }
    }

    pub fn to_json(&self) -> Json {
        let o = &self.outcome;
        obj(vec![
            ("run_id", Json::from(self.run_id.clone())),
            ("spec", self.spec.clone()),
            (
                "outcome",
                obj(vec![
                    ("kind", Json::from(o.kind.clone())),
                    ("best_val_acc", Json::from(o.best_val_acc)),
                    ("best_val_step", Json::from(o.best_val_step)),
                    ("test_acc", Json::from(o.test_acc)),
                    ("test_f1", Json::from(o.test_f1)),
                    ("final_train_loss", Json::from(o.final_train_loss)),
                    ("steps", Json::from(o.steps)),
                    ("loss_curve", o.loss_curve.to_json()),
                    ("val_curve", o.val_curve.to_json()),
                ]),
            ),
        ])
    }

    /// One-line serialization (newline-free by construction: `jsonlite`
    /// emits compact JSON).
    pub fn to_line(&self) -> String {
        self.to_json().dump()
    }

    pub fn from_line(line: &str) -> Result<Self> {
        Self::from_json(&Json::parse(line)?)
    }

    /// Parse from the already-parsed JSON form. Extra keys (e.g. the
    /// fleet's `lease` stamp) are ignored — the canonical [`to_line`]
    /// form never carries them, which is exactly how compaction strips
    /// lease noise from fleet manifests.
    ///
    /// [`to_line`]: ManifestRow::to_line
    pub fn from_json(v: &Json) -> Result<Self> {
        let o = v.get("outcome")?;
        Ok(Self {
            run_id: v.get("run_id")?.as_str()?.to_string(),
            spec: v.get("spec")?.clone(),
            outcome: Outcome {
                kind: o.get("kind")?.as_str()?.to_string(),
                best_val_acc: o.get("best_val_acc")?.as_f64()?,
                best_val_step: o.get("best_val_step")?.as_usize()?,
                test_acc: o.get("test_acc")?.as_f64()?,
                test_f1: o.get("test_f1")?.as_f64()?,
                final_train_loss: o.get("final_train_loss")?.as_f64()?,
                steps: o.get("steps")?.as_usize()?,
                loss_curve: Curve::from_json(o.get("loss_curve")?)?,
                val_curve: Curve::from_json(o.get("val_curve")?)?,
            },
        })
    }

    /// Convenience: a spec field as a string (e.g. `"task"`).
    pub fn spec_str(&self, key: &str) -> Result<&str> {
        self.spec.get(key)?.as_str()
    }
}

/// The fencing stamp of a parsed manifest line. Unstamped (classic or
/// compacted) rows are authoritative, so they rank above every token.
fn stamp_token(v: &Json) -> u64 {
    v.opt("lease")
        .and_then(|l| l.opt("token"))
        .and_then(|t| t.as_u64().ok())
        .unwrap_or(u64::MAX)
}

/// The on-disk manifest plus its in-memory index by run id.
#[derive(Debug)]
pub struct SweepManifest {
    pub path: PathBuf,
    rows: BTreeMap<String, ManifestRow>,
    /// Fencing stamp of each indexed row (fleet appends carry one;
    /// classic rows rank as `u64::MAX`). Only consulted when two rows
    /// claim the same run id.
    tokens: BTreeMap<String, u64>,
    /// Unparseable lines skipped on load (a crash tears at most one).
    pub corrupt_lines: usize,
    /// Rows dropped because a higher fencing token holds the same run —
    /// a zombie worker's late append, detected and ignored on load.
    pub fenced_rows: usize,
}

impl SweepManifest {
    /// Load (a missing file is an empty manifest).
    ///
    /// Torn lines — including ones torn mid-way through a multi-byte
    /// UTF-8 character, which would poison a strict whole-file read —
    /// are skipped and counted. When two rows carry the same run id,
    /// the one with the higher fencing stamp wins (ties: last wins, the
    /// historical behavior); superseded rows count as `fenced_rows`.
    pub fn load(path: &Path) -> Result<Self> {
        let mut m = Self {
            path: path.to_path_buf(),
            rows: BTreeMap::new(),
            tokens: BTreeMap::new(),
            corrupt_lines: 0,
            fenced_rows: 0,
        };
        let lines = match ioutil::read_lossy_lines(path) {
            Ok(l) => l,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(m),
            Err(e) => return Err(e).with_context(|| format!("reading {}", path.display())),
        };
        for line in &lines {
            if line.trim().is_empty() {
                continue;
            }
            let parsed = Json::parse(line).and_then(|v| {
                let token = stamp_token(&v);
                ManifestRow::from_json(&v).map(|row| (token, row))
            });
            match parsed {
                Ok((token, row)) => m.index(row, token),
                Err(_) => m.corrupt_lines += 1,
            }
        }
        Ok(m)
    }

    /// Index one row under fencing rules (see [`SweepManifest::load`]).
    fn index(&mut self, row: ManifestRow, token: u64) {
        match self.tokens.get(&row.run_id) {
            Some(&held) if token < held => self.fenced_rows += 1,
            other => {
                if matches!(other, Some(&held) if token > held) {
                    self.fenced_rows += 1; // the row being superseded
                }
                self.tokens.insert(row.run_id.clone(), token);
                self.rows.insert(row.run_id.clone(), row);
            }
        }
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn contains(&self, run_id: &str) -> bool {
        self.rows.contains_key(run_id)
    }

    pub fn get(&self, run_id: &str) -> Option<&ManifestRow> {
        self.rows.get(run_id)
    }

    /// Rows sorted by run id (BTreeMap order).
    pub fn rows(&self) -> impl Iterator<Item = &ManifestRow> {
        self.rows.values()
    }

    /// Crash-safe append: one line in one write (with bounded retry on
    /// transient errors), then indexed. A single `write_all` on an
    /// `O_APPEND` handle cannot interleave with a concurrent worker's
    /// append — the multi-process safety the fleet relies on.
    pub fn append(&mut self, row: ManifestRow) -> Result<()> {
        self.append_raw(&row.to_line())?;
        self.index(row, u64::MAX);
        Ok(())
    }

    /// Fleet append: the row plus a `lease` stamp (`token`, `worker`).
    /// The stamp lets any later load fence a zombie's duplicate (lower
    /// tokens lose), and [`SweepManifest::compact`] strips it — the
    /// canonical form is stamp-free, so a compacted fleet manifest is
    /// byte-identical to a single-process one.
    pub fn append_stamped(&mut self, row: ManifestRow, token: u64, worker: &str) -> Result<()> {
        let mut j = row.to_json();
        if let Json::Obj(map) = &mut j {
            map.insert(
                "lease".to_string(),
                obj(vec![
                    ("token", Json::from(token as usize)),
                    ("worker", Json::from(worker)),
                ]),
            );
        }
        self.append_raw(&j.dump())?;
        self.index(row, token);
        Ok(())
    }

    fn append_raw(&self, line: &str) -> Result<()> {
        if let Some(dir) = self.path.parent() {
            std::fs::create_dir_all(dir).ok();
        }
        // Durable (fsync'd) append: a manifest row is the *only* record
        // that a run completed — if it evaporates in a power loss after
        // the lease was released, the run would re-execute and the
        // byte-identity proof would compare against a half-real history.
        ioutil::append_line_retry_durable(&self.path, line, "manifest append")
            .with_context(|| format!("appending to {}", self.path.display()))
    }

    /// Rewrite the file in canonical order (sorted by run id) via a temp
    /// file + atomic rename. Run after a sweep completes; the result is
    /// byte-identical for identical row sets. Rows are re-serialized
    /// through [`ManifestRow::to_line`], which drops fleet lease stamps
    /// — compaction is where lease noise dies.
    pub fn compact(&self) -> Result<()> {
        if let Some(dir) = self.path.parent() {
            std::fs::create_dir_all(dir).ok();
        }
        // Unique per process + call: concurrent fleet workers may compact
        // the same manifest simultaneously (they write identical bytes,
        // and the rename is atomic) — a shared tmp name could tear.
        static SEQ: AtomicU64 = AtomicU64::new(0);
        let tmp = self.path.with_extension(format!(
            "jsonl.tmp.{}.{}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        let mut out = String::new();
        for row in self.rows.values() {
            out.push_str(&row.to_line());
            out.push('\n');
        }
        {
            use std::io::Write as _;
            let mut f = std::fs::File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            f.write_all(out.as_bytes())
                .with_context(|| format!("writing {}", tmp.display()))?;
            // Content on the platter before the rename exposes it — a
            // power loss right after the rename must never surface an
            // empty manifest.
            f.sync_data().with_context(|| format!("syncing {}", tmp.display()))?;
        }
        std::fs::rename(&tmp, &self.path)
            .with_context(|| format!("renaming {} into place", tmp.display()))?;
        if let Some(dir) = self.path.parent() {
            ioutil::fsync_dir(dir)
                .with_context(|| format!("fsyncing manifest directory {}", dir.display()))?;
        }
        Ok(())
    }

    /// Sibling timing side file (`manifest.jsonl` → `manifest.times.jsonl`).
    /// Timings are telemetry, not results: append-only, last write wins,
    /// and deliberately outside the bit-identical contract.
    pub fn times_path(manifest: &Path) -> PathBuf {
        manifest.with_extension("times.jsonl")
    }

    /// Append one timing/telemetry record to the side file. Besides the
    /// wall-clock fields, the row optionally carries `resumed_from_step`
    /// (the run restarted off a step-level checkpoint) and a free-form
    /// `note` (e.g. corrupt snapshots skipped before a from-scratch
    /// restart) — telemetry by design, so the deterministic manifest row
    /// of a resumed run stays byte-identical to an uninterrupted one.
    pub fn append_time(
        manifest: &Path,
        run_id: &str,
        total_secs: f64,
        time_to_best_secs: f64,
        resumed_from_step: Option<usize>,
        note: Option<&str>,
    ) -> Result<()> {
        let mut fields = vec![
            ("run_id", Json::from(run_id)),
            ("total_secs", Json::from(finite(total_secs))),
            ("time_to_best_secs", Json::from(finite(time_to_best_secs))),
        ];
        if let Some(step) = resumed_from_step {
            fields.push(("resumed_from_step", Json::from(step)));
        }
        if let Some(note) = note {
            fields.push(("note", Json::from(note)));
        }
        Self::append_telemetry(manifest, obj(fields))
    }

    /// Append a fleet lifecycle event (lease reclaim, fenced zombie
    /// append, ...) to the times side file as a telemetry note. Event
    /// rows deliberately carry no `total_secs`, so [`load_times`] can
    /// never mistake one for a timing — and events never become
    /// manifest rows, keeping the byte-identity contract untouched.
    ///
    /// [`load_times`]: SweepManifest::load_times
    pub fn append_event(manifest: &Path, run_id: &str, event: &str, note: &str) -> Result<()> {
        Self::append_telemetry(
            manifest,
            obj(vec![
                ("event", Json::from(event)),
                ("note", Json::from(note)),
                ("run_id", Json::from(run_id)),
            ]),
        )
    }

    fn append_telemetry(manifest: &Path, row: Json) -> Result<()> {
        let path = Self::times_path(manifest);
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).ok();
        }
        ioutil::append_line_retry(&path, &row.dump(), "times append")
            .with_context(|| format!("appending to {}", path.display()))
    }

    /// GC the times side file: keep every event row (the sweep's
    /// lifecycle history) plus the *last* timing row per run (matching
    /// [`load_times`]'s last-wins read); superseded timings and torn
    /// lines are dropped. No-op below `min_lines` (clamped to ≥ 1) or
    /// when already compact; returns `true` only when a rotation
    /// actually replaced the file.
    ///
    /// Same discipline — and same admitted race — as `lease::rotate`:
    /// unique tmp + `sync_data` + a pre-rename length re-check + atomic
    /// rename + directory fsync. An append landing between the re-check
    /// and the rename is lost, which is why callers only rotate at
    /// quiesced points (sweep drain, post-compaction, right after a
    /// successful lease-ledger rotation — which itself proves every
    /// lease was just released). [`load_times`] results are invariant
    /// under rotation.
    ///
    /// [`load_times`]: SweepManifest::load_times
    pub fn rotate_times(manifest: &Path, min_lines: usize) -> Result<bool> {
        let path = Self::times_path(manifest);
        let Ok(meta) = std::fs::metadata(&path) else {
            return Ok(false); // no side file yet — nothing to GC
        };
        let len_before = meta.len();
        let lines = match ioutil::read_lossy_lines(&path) {
            Ok(l) => l,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(false),
            Err(e) => return Err(e).with_context(|| format!("reading {}", path.display())),
        };
        let n_lines = lines.iter().filter(|l| !l.trim().is_empty()).count();
        if n_lines < min_lines.max(1) {
            return Ok(false);
        }
        let is_timing = |v: &Json| {
            v.opt("run_id").is_some()
                && v.opt("total_secs").is_some()
                && v.opt("time_to_best_secs").is_some()
        };
        let run_id_of =
            |v: &Json| v.opt("run_id").and_then(|j| j.as_str().ok().map(str::to_string));
        let parsed: Vec<Option<Json>> = lines.iter().map(|l| Json::parse(l).ok()).collect();
        let mut last_timing: BTreeMap<String, usize> = BTreeMap::new();
        for (i, v) in parsed.iter().enumerate() {
            if let Some(v) = v {
                if is_timing(v) {
                    if let Some(id) = run_id_of(v) {
                        last_timing.insert(id, i);
                    }
                }
            }
        }
        let mut out = String::new();
        let mut kept = 0usize;
        for (i, line) in lines.iter().enumerate() {
            let Some(v) = &parsed[i] else { continue }; // torn/garbage line
            let keep = if is_timing(v) {
                run_id_of(v).is_some_and(|id| last_timing.get(&id) == Some(&i))
            } else {
                // Events — and any parseable row of an unknown future
                // shape — survive: rotation must never destroy data it
                // does not understand.
                true
            };
            if keep {
                out.push_str(line);
                out.push('\n');
                kept += 1;
            }
        }
        if kept >= n_lines {
            return Ok(false); // already compact
        }
        static SEQ: AtomicU64 = AtomicU64::new(0);
        let tmp = path.with_extension(format!(
            "jsonl.rot.{}.{}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        {
            use std::io::Write as _;
            let mut f = std::fs::File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            f.write_all(out.as_bytes())
                .with_context(|| format!("writing {}", tmp.display()))?;
            f.sync_data().with_context(|| format!("syncing {}", tmp.display()))?;
        }
        // Length re-check narrows the lost-append window: if anyone
        // appended since the read, back off — a later quiesced point
        // will retry.
        let len_now = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
        if len_now != len_before {
            std::fs::remove_file(&tmp).ok();
            return Ok(false);
        }
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("renaming {} into place", tmp.display()))?;
        if let Some(dir) = path.parent() {
            ioutil::fsync_dir(dir).with_context(|| format!("fsyncing {}", dir.display()))?;
        }
        Ok(true)
    }

    /// Load timings: run id → (total, time-to-best); empty when absent.
    /// Torn lines (even ones tearing a multi-byte character — a worker
    /// killed mid-telemetry-append) and event rows are skipped; they
    /// must never poison the rest of the file.
    pub fn load_times(manifest: &Path) -> BTreeMap<String, (f64, f64)> {
        let mut out = BTreeMap::new();
        let Ok(lines) = ioutil::read_lossy_lines(&Self::times_path(manifest)) else {
            return out;
        };
        for line in &lines {
            let Ok(v) = Json::parse(line) else { continue };
            let (Ok(id), Ok(t), Ok(b)) = (
                v.get("run_id").and_then(|j| j.as_str()),
                v.get("total_secs").and_then(|j| j.as_f64()),
                v.get("time_to_best_secs").and_then(|j| j.as_f64()),
            ) else {
                continue;
            };
            out.insert(id.to_string(), (t, b));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::super::spec::{Backend, RunSpec};
    use super::*;
    use crate::optim::OptSpec;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("addax_manifest_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn row(seed: u64) -> ManifestRow {
        let spec = RunSpec::new(Backend::Mock, "sst2", OptSpec::named("mezo"), 10, seed);
        let mut loss_curve = Curve::default();
        loss_curve.push(0, 2.5);
        loss_curve.push(1, 1.25);
        ManifestRow {
            run_id: spec.run_id.clone(),
            spec: spec.to_json(),
            outcome: Outcome {
                kind: "train".to_string(),
                best_val_acc: 0.75,
                best_val_step: 1,
                test_acc: 0.5,
                test_f1: 0.5,
                final_train_loss: 1.25,
                steps: 2,
                loss_curve,
                val_curve: Curve::default(),
            },
        }
    }

    #[test]
    fn line_roundtrip() {
        let r = row(0);
        let back = ManifestRow::from_line(&r.to_line()).unwrap();
        assert_eq!(back.run_id, r.run_id);
        assert_eq!(back.outcome.loss_curve.points, r.outcome.loss_curve.points);
        assert_eq!(back.spec_str("task").unwrap(), "sst2");
        assert_eq!(back.to_line(), r.to_line(), "serialization is canonical");
    }

    #[test]
    fn append_load_and_torn_tail() {
        let dir = tmpdir("torn");
        let path = dir.join("m.jsonl");
        std::fs::remove_file(&path).ok();
        let mut m = SweepManifest::load(&path).unwrap();
        m.append(row(0)).unwrap();
        m.append(row(1)).unwrap();
        // simulate a crash mid-append: torn partial line at the tail
        {
            use std::io::Write as _;
            let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
            write!(f, "{{\"run_id\": \"zz").unwrap();
        }
        let loaded = SweepManifest::load(&path).unwrap();
        assert_eq!(loaded.len(), 2);
        assert_eq!(loaded.corrupt_lines, 1);
        assert!(loaded.contains(&row(0).run_id));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn compact_is_sorted_and_idempotent() {
        let dir = tmpdir("compact");
        let path = dir.join("m.jsonl");
        std::fs::remove_file(&path).ok();
        // append out of order relative to run-id sort
        let mut m = SweepManifest::load(&path).unwrap();
        for seed in [3u64, 1, 2, 0] {
            m.append(row(seed)).unwrap();
        }
        m.compact().unwrap();
        let a = std::fs::read_to_string(&path).unwrap();
        // reload + recompact must not change a byte
        let m2 = SweepManifest::load(&path).unwrap();
        m2.compact().unwrap();
        let b = std::fs::read_to_string(&path).unwrap();
        assert_eq!(a, b);
        let ids: Vec<String> =
            a.lines().map(|l| ManifestRow::from_line(l).unwrap().run_id).collect();
        let mut sorted = ids.clone();
        sorted.sort();
        assert_eq!(ids, sorted);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn times_side_file_roundtrip() {
        let dir = tmpdir("times");
        let path = dir.join("m.jsonl");
        let times = SweepManifest::times_path(&path);
        std::fs::remove_file(&times).ok();
        SweepManifest::append_time(&path, "a", 1.5, 0.5, None, None).unwrap();
        // last wins; resumed runs record their restart step + note
        SweepManifest::append_time(&path, "a", 2.5, 1.0, Some(7), None).unwrap();
        SweepManifest::append_time(&path, "b", 3.0, 2.0, None, Some("2 invalid snapshot(s)"))
            .unwrap();
        let t = SweepManifest::load_times(&path);
        assert_eq!(t.get("a"), Some(&(2.5, 1.0)));
        assert_eq!(t.get("b"), Some(&(3.0, 2.0)));
        let text = std::fs::read_to_string(&times).unwrap();
        assert!(text.contains("\"resumed_from_step\":7"), "{text}");
        assert!(text.contains("\"note\":\"2 invalid snapshot(s)\""), "{text}");
        // rows without telemetry extras do not carry the keys
        assert_eq!(text.matches("resumed_from_step").count(), 1);
        assert!(SweepManifest::load_times(&dir.join("missing.jsonl")).is_empty());
        std::fs::remove_file(&times).ok();
    }

    #[test]
    fn torn_multibyte_line_does_not_poison_the_load() {
        // A kill mid-append can tear a line inside a multi-byte UTF-8
        // character; a strict whole-file read_to_string would then fail
        // and lose every intact row.
        let dir = tmpdir("torn_utf8");
        let path = dir.join("m.jsonl");
        std::fs::remove_file(&path).ok();
        let mut m = SweepManifest::load(&path).unwrap();
        m.append(row(0)).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.extend_from_slice(b"{\"run_id\": \"caf");
        bytes.push(0xC3); // first byte of a 2-byte char; the kill ate the rest
        std::fs::write(&path, &bytes).unwrap();
        assert!(std::fs::read_to_string(&path).is_err(), "the premise");
        let loaded = SweepManifest::load(&path).unwrap();
        assert_eq!(loaded.len(), 1);
        assert_eq!(loaded.corrupt_lines, 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn torn_times_line_does_not_poison_load_times() {
        let dir = tmpdir("torn_times");
        let path = dir.join("m.jsonl");
        let times = SweepManifest::times_path(&path);
        std::fs::remove_file(&times).ok();
        SweepManifest::append_time(&path, "a", 1.0, 0.5, None, None).unwrap();
        let mut bytes = std::fs::read(&times).unwrap();
        bytes.extend_from_slice(b"{\"run_id\": \"caf");
        bytes.push(0xC3);
        bytes.push(b'\n');
        std::fs::write(&times, &bytes).unwrap();
        // a later worker appends past the torn line; both loads must see "a"
        SweepManifest::append_time(&path, "b", 2.0, 1.0, None, None).unwrap();
        let t = SweepManifest::load_times(&path);
        assert_eq!(t.get("a"), Some(&(1.0, 0.5)));
        assert_eq!(t.get("b"), Some(&(2.0, 1.0)));
        std::fs::remove_file(&times).ok();
    }

    #[test]
    fn event_rows_are_telemetry_not_timings() {
        let dir = tmpdir("events");
        let path = dir.join("m.jsonl");
        let times = SweepManifest::times_path(&path);
        std::fs::remove_file(&times).ok();
        SweepManifest::append_time(&path, "a", 1.0, 0.5, None, None).unwrap();
        SweepManifest::append_event(&path, "a", "reclaim", "w1 reclaimed lease (token 2)")
            .unwrap();
        let t = SweepManifest::load_times(&path);
        assert_eq!(t.get("a"), Some(&(1.0, 0.5)), "events must not clobber timings");
        let text = std::fs::read_to_string(&times).unwrap();
        assert!(text.contains("\"event\":\"reclaim\""), "{text}");
        // events live in the side file, never in the manifest
        assert!(SweepManifest::load(&path).unwrap().is_empty());
        std::fs::remove_file(&times).ok();
    }

    #[test]
    fn rotate_times_keeps_events_and_last_timing_per_run() {
        let dir = tmpdir("rot_times");
        let path = dir.join("m.jsonl");
        let times = SweepManifest::times_path(&path);
        std::fs::remove_file(&times).ok();
        // Below threshold → untouched, even with GC-able content.
        SweepManifest::append_time(&path, "a", 1.0, 0.5, None, None).unwrap();
        SweepManifest::append_time(&path, "a", 2.0, 1.5, Some(7), None).unwrap();
        assert!(!SweepManifest::rotate_times(&path, 100).unwrap());
        assert_eq!(std::fs::read_to_string(&times).unwrap().lines().count(), 2);
        // Events interleaved with superseded timings plus a torn tail.
        SweepManifest::append_event(&path, "a", "reclaim", "w1 reclaimed lease (token 2)")
            .unwrap();
        SweepManifest::append_time(&path, "b", 3.0, 2.5, None, None).unwrap();
        SweepManifest::append_time(&path, "a", 4.0, 3.5, None, None).unwrap();
        {
            use std::io::Write as _;
            let mut f = std::fs::OpenOptions::new().append(true).open(&times).unwrap();
            write!(f, "{{\"run_id\":\"torn").unwrap();
        }
        let before = SweepManifest::load_times(&path);
        assert!(SweepManifest::rotate_times(&path, 1).unwrap());
        assert_eq!(
            SweepManifest::load_times(&path),
            before,
            "load_times must be invariant under rotation"
        );
        let text = std::fs::read_to_string(&times).unwrap();
        assert_eq!(text.lines().count(), 3, "{text}"); // event + last a + b
        assert!(text.contains("\"event\":\"reclaim\""), "{text}");
        assert!(text.contains("\"total_secs\":4"), "{text}");
        assert!(!text.contains("\"total_secs\":1}"), "superseded row must be GC'd: {text}");
        assert!(!text.contains("\"total_secs\":2}"), "superseded row must be GC'd: {text}");
        assert!(!text.contains("torn"), "{text}");
        // Already compact → no-op rotation (and no tmp debris).
        assert!(!SweepManifest::rotate_times(&path, 1).unwrap());
        let debris: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .flatten()
            .filter(|e| e.file_name().to_string_lossy().contains(".rot."))
            .collect();
        assert!(debris.is_empty(), "{debris:?}");
        std::fs::remove_file(&times).ok();
    }

    #[test]
    fn stamped_rows_fence_by_token_and_compact_stamp_free() {
        let dir = tmpdir("fence");
        let path = dir.join("m.jsonl");
        std::fs::remove_file(&path).ok();
        let mut m = SweepManifest::load(&path).unwrap();
        // the reclaimer (token 2) commits, then a zombie's late append
        // (token 1) lands — the zombie row must lose on load
        m.append_stamped(row(0), 2, "w-reclaimer").unwrap();
        m.append_stamped(row(0), 1, "w-zombie").unwrap();
        m.append_stamped(row(1), 1, "w0").unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.matches("\"lease\":").count(), 3, "appends carry the stamp");
        let loaded = SweepManifest::load(&path).unwrap();
        assert_eq!(loaded.len(), 2);
        assert_eq!(loaded.fenced_rows, 1, "the zombie append is detected and dropped");
        // compaction strips every stamp: canonical bytes match a manifest
        // that never saw a fleet
        loaded.compact().unwrap();
        let compacted = std::fs::read_to_string(&path).unwrap();
        assert!(!compacted.contains("lease"), "{compacted}");
        let classic_path = dir.join("classic.jsonl");
        std::fs::remove_file(&classic_path).ok();
        let mut classic = SweepManifest::load(&classic_path).unwrap();
        classic.append(row(0)).unwrap();
        classic.append(row(1)).unwrap();
        classic.compact().unwrap();
        assert_eq!(compacted, std::fs::read_to_string(&classic_path).unwrap());
        // an unstamped (compacted) row outranks any later stamped one
        let mut m2 = SweepManifest::load(&path).unwrap();
        m2.append_stamped(row(0), 5, "w-late").unwrap();
        let reloaded = SweepManifest::load(&path).unwrap();
        assert_eq!(reloaded.fenced_rows, 1);
        assert_eq!(reloaded.len(), 2);
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&classic_path).ok();
    }
}
