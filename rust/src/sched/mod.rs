//! The sweep scheduler: memory-aware packing of many training runs onto
//! simulated device budgets, with a resumable manifest.
//!
//! Addax's core idea is memory-aware assignment *within* a run (Alg. 1:
//! ZO gradients for the examples that would blow the budget, FO for the
//! rest). This subsystem applies the same idea *across* runs: the repro's
//! tables and figures each need dozens of (optimizer × task × seed ×
//! hyper-parameter) runs, and the analytic model in `memory/` prices
//! exactly which of them co-fit on a device.
//!
//! Layers (one file each):
//!
//! * [`spec`] — declarative sweep grids and their expansion into sealed,
//!   deterministically-seeded [`RunSpec`]s;
//! * [`pack`] — per-run footprint pricing + first-fit-decreasing packing
//!   into concurrency waves under `--budget-gb × --gpus`;
//! * [`worker`] — the wave executor: a scoped worker pool, one manifest
//!   writer, resumable on kill — at *step* granularity via the `ckpt`
//!   subsystem (each run checkpoints into its own directory and a killed
//!   run continues from its latest valid snapshot, byte-identically);
//! * [`manifest`] — the crash-safe JSONL manifest whose compacted form is
//!   byte-identical for a given spec at any worker count;
//! * [`lease`] — the append-only lease ledger (claim / renew / reclaim /
//!   release records with monotonic fencing tokens and per-holder renewal
//!   `seq` counters) that lets *separate processes* — and, with the skew
//!   margin + logical reclaim confirmation, separate *machines* — share
//!   one manifest safely; plus rotation/GC that bounds the ledger for
//!   week-long sweeps;
//! * [`steal`] — tail work-stealing: idle workers serve bit-identical
//!   probe shards (per-example loss halves of the θ±εz evaluations) for
//!   still-leased ZO runs through a per-run side dir;
//! * [`chaos`] — seeded deterministic fault injection (worker crashes,
//!   heartbeat stalls, transient I/O bursts, per-worker clock skew)
//!   proving the fleet's failure paths instead of hoping about them.
//!
//! The repro layer (`repro/`) is a client: every table/figure expands its
//! cells into `RunSpec`s, hands them to [`run_sweep`], and aggregates
//! over manifest rows — the sweep engine owns the training loop. Multi-
//! process fleets enter through [`run_sweep_fleet`] instead: each
//! `addax sweep --worker-id <id>` invocation claims runs under leases,
//! heartbeats while executing, reclaims expired leases (resuming the run
//! from its snapshots), and fences zombie commits — with the guarantee
//! that the compacted manifest stays byte-identical to a single-process
//! sweep's under any kill/reclaim pattern.

pub mod chaos;
pub mod lease;
pub mod manifest;
pub mod pack;
pub mod spec;
pub mod steal;
pub mod worker;

pub use chaos::{ChaosPlan, RunFaults};
pub use lease::{leases_path, LeaseAction, LeaseClock, LeaseRecord, LeaseTable};
pub use manifest::{ManifestRow, SweepManifest};
pub use pack::{pack, price, PricedRun, Wave};
pub use spec::{Backend, LT_NONE, RunSpec, SweepSpec};
pub use worker::{
    execute_run, execute_run_with, fleet_commit, run_sweep, run_sweep_collect, run_sweep_fleet,
    FleetExit, FleetOptions, RunCtx, RunTiming, SweepOptions, SweepSummary,
};
