//! Memory-aware packing: price each run with the analytic memory model,
//! then bin-pack runs into concurrency "waves" under a device budget.
//!
//! This is Addax's data-assignment idea lifted one level: within a run,
//! Algorithm 1 sends memory-heavy examples down the cheap (ZO) path;
//! across runs, the scheduler uses the same `memory::footprint` model to
//! decide which runs may share a device at the same time. A wave is a set
//! of runs whose simulated peak footprints sum to at most the budget
//! (`--budget-gb × --gpus`); waves execute in order, runs inside a wave
//! concurrently on the worker pool.
//!
//! Packing is first-fit decreasing with a deterministic total order
//! (bytes descending, run id ascending on ties), so the plan — like
//! everything else in the scheduler — is a pure function of the spec.

use anyhow::{bail, Context, Result};

use crate::memory::{footprint, geometry, Method, Workload};

use super::spec::RunSpec;

/// A run plus its simulated peak footprint in bytes.
#[derive(Clone, Debug)]
pub struct PricedRun {
    pub spec: RunSpec,
    pub bytes: f64,
}

/// One concurrency group: co-resident under the device budget.
#[derive(Clone, Debug, Default)]
pub struct Wave {
    pub runs: Vec<PricedRun>,
    pub bytes: f64,
}

/// Simulated peak footprint of one run at its pricing geometry.
///
/// The workload mirrors `main.rs memory` / the table harnesses: ZO
/// methods price as inference at the task's `L_max`, Addax as the
/// two-phase mixed workload with the FO side capped at `price_lt`
/// (default: the 60th percentile of `L_max`), FO methods as a full
/// backward at `L_max`. Precision is the run's storage dtype — the same
/// bytes the live `ParamStore` allocates — except Adam, which always
/// prices fp32 (the paper's Adam runs fp32; `footprint` enforces it).
pub fn price(spec: &RunSpec) -> Result<f64> {
    let g = geometry::by_name(&spec.geometry)
        .with_context(|| format!("unknown geometry {:?}", spec.geometry))?;
    let task = spec.task_def()?;
    let method = spec.optimizer.method()?;
    let l = task.lengths.l_max;
    let b = spec.optimizer.batch;
    let wl = match method {
        Method::MeZo | Method::ZoSgdNaive => Workload::zo(b, l),
        Method::Addax => {
            let lt = if spec.price_lt > 0 { spec.price_lt } else { l * 6 / 10 };
            Workload::mixed(spec.optimizer.k1, lt.min(l), spec.optimizer.k0, l)
        }
        _ => Workload::fo(b, l),
    };
    Ok(footprint(&g, method, wl, spec.dtype).total)
}

/// Price every run and pack them into waves under `budget_bytes`.
///
/// Errors if any single run exceeds the budget — the scheduler's analogue
/// of the paper's OOM verdict (raise `--budget-gb`/`--gpus`, or shrink
/// the run).
pub fn pack(specs: Vec<RunSpec>, budget_bytes: f64) -> Result<Vec<Wave>> {
    if budget_bytes <= 0.0 {
        bail!("device budget must be positive");
    }
    let mut priced = Vec::with_capacity(specs.len());
    for spec in specs {
        let bytes = price(&spec)?;
        if bytes > budget_bytes {
            bail!(
                "run {} needs {:.1} GB but the device budget is {:.1} GB — \
                 raise --budget-gb/--gpus or shrink the run",
                spec.run_id,
                bytes / 1e9,
                budget_bytes / 1e9,
            );
        }
        priced.push(PricedRun { spec, bytes });
    }
    // First-fit decreasing over a deterministic order.
    priced.sort_by(|a, b| {
        b.bytes
            .partial_cmp(&a.bytes)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| a.spec.run_id.cmp(&b.spec.run_id))
    });
    let mut waves: Vec<Wave> = Vec::new();
    for run in priced {
        match waves.iter().position(|w| w.bytes + run.bytes <= budget_bytes) {
            Some(i) => {
                waves[i].bytes += run.bytes;
                waves[i].runs.push(run);
            }
            None => waves.push(Wave { bytes: run.bytes, runs: vec![run] }),
        }
    }
    Ok(waves)
}

#[cfg(test)]
mod tests {
    use super::super::spec::Backend;
    use super::*;
    use crate::optim::OptSpec;
    use crate::tensor::Dtype;

    /// A paper-profile (2-byte storage) run, like the tables price.
    fn run(opt: &str, task: &str, seed: u64) -> RunSpec {
        let mut s = RunSpec::new(Backend::Mock, task, OptSpec::named(opt), 10, seed);
        s.dtype = Dtype::Bf16;
        s.sealed()
    }

    #[test]
    fn pricing_matches_the_memory_model_shape() {
        // The scheduler sees what the paper sees: on a long task, the ZO
        // path is far cheaper than a full backward, and Addax sits close
        // to MeZO (the headline memory claim).
        let mezo = price(&run("mezo", "multirc", 0)).unwrap();
        let ip = price(&run("ip-sgd", "multirc", 0)).unwrap();
        let addax = price(&run("addax", "multirc", 0)).unwrap();
        assert!(ip > 2.0 * mezo, "ip {ip} vs mezo {mezo}");
        assert!(addax < 1.6 * mezo, "addax {addax} vs mezo {mezo}");
        // zero-shot prices as inference
        let zs = price(&run("zero-shot", "multirc", 0)).unwrap();
        assert!(zs <= mezo * 1.01);
    }

    #[test]
    fn price_follows_the_storage_dtype() {
        let half = price(&run("mezo", "sst2", 0)).unwrap();
        let mut wide_spec = run("mezo", "sst2", 0);
        wide_spec.dtype = Dtype::F32;
        let wide = price(&wide_spec.sealed()).unwrap();
        assert!(wide > 1.5 * half, "f32 {wide} vs bf16 {half}");
        // Adam prices fp32 regardless of the store dtype.
        let mut adam16 = run("adam", "sst2", 0);
        adam16.dtype = Dtype::Bf16;
        let mut adam32 = run("adam", "sst2", 0);
        adam32.dtype = Dtype::F32;
        assert_eq!(
            price(&adam16.sealed()).unwrap(),
            price(&adam32.sealed()).unwrap()
        );
    }

    #[test]
    fn waves_respect_the_budget() {
        let specs: Vec<RunSpec> = (0..6)
            .flat_map(|seed| ["mezo", "ip-sgd", "addax"].map(|o| run(o, "sst2", seed)))
            .collect();
        let budget = 60e9;
        let waves = pack(specs.clone(), budget).unwrap();
        let total: usize = waves.iter().map(|w| w.runs.len()).sum();
        assert_eq!(total, specs.len());
        for w in &waves {
            assert!(w.bytes <= budget);
            let sum: f64 = w.runs.iter().map(|r| r.bytes).sum();
            assert!((sum - w.bytes).abs() < 1.0);
        }
        // packing actually packs: fewer waves than runs
        assert!(waves.len() < specs.len(), "{} waves", waves.len());
    }

    #[test]
    fn packing_is_deterministic() {
        let specs: Vec<RunSpec> =
            (0..5).flat_map(|s| ["mezo", "addax"].map(|o| run(o, "rte", s))).collect();
        let a = pack(specs.clone(), 60e9).unwrap();
        let b = pack(specs, 60e9).unwrap();
        let ids = |waves: &[Wave]| -> Vec<Vec<String>> {
            waves
                .iter()
                .map(|w| w.runs.iter().map(|r| r.spec.run_id.clone()).collect())
                .collect()
        };
        assert_eq!(ids(&a), ids(&b));
    }

    #[test]
    fn oversized_run_is_an_error() {
        let err = pack(vec![run("adam", "multirc", 0)], 10e9).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("GB"), "{msg}");
        assert!(pack(vec![], 0.0).is_err());
    }
}
