//! Run leases: the coordination substrate that turns the single-process
//! sweep into a multi-process fleet.
//!
//! Workers claim runs by appending lease records to a sibling
//! `manifest.leases.jsonl` (append-only JSONL, same crash-tolerance
//! rules as the manifest), heartbeat by appending renewals, and reclaim
//! leases whose TTL lapsed. The file is the *only* shared state — there
//! is no server and no lock: `O_APPEND` serializes the records, and the
//! replay rules below make every reader agree on who holds what.
//!
//! Record shape (one JSON object per line; keys in canonical order):
//!
//! ```json
//! {"action":"claim","expires_ms":1754650000000,"run_id":"...","seq":0,"token":1,"worker":"w0"}
//! ```
//!
//! * `token` is the **fencing token**: claims carry `max token + 1` for
//!   their run, so tokens strictly increase across claim generations.
//!   A worker that lost its lease (crash, stall, partition) holds a
//!   stale token forever — its late writes are detectable and
//!   rejectable by comparing tokens, no matter when they arrive.
//! * `seq` is the holder's **renewal sequence number**: 0 on the claim,
//!   incremented on every heartbeat renewal. Unlike `expires_ms` it is
//!   a *logical* clock — observers on skewed wall clocks still agree on
//!   whether it advanced, which is what [`confirm_expired`] leans on.
//! * `action` is `claim` (fresh), `reclaim` (a claim over an expired
//!   lease — identical semantics, distinct label so reclaims are
//!   observable in telemetry and CI), `renew` (heartbeat: extends
//!   `expires_ms`, bumps `seq`), or `release` (the run's row is
//!   durable; the lease is retired).
//!
//! Replay rules (applied in file order; all readers converge):
//!
//! * a claim/reclaim with a **higher** token supersedes the current
//!   lease; an **equal** token loses to the earlier record (`O_APPEND`
//!   ordering breaks the tie — "first appender wins"); a lower token is
//!   stale noise and ignored;
//! * a renew extends the expiry (and advances `seq`) only when worker
//!   *and* token match the current lease (a zombie's renewals are
//!   no-ops);
//! * a release retires the current lease only at a matching token — or,
//!   on a run with **no prior record**, installs a released state
//!   wholesale: that is the compacted form a [ledger rotation](rotate)
//!   writes, one release line per run carrying the run's max token.
//!
//! A run is **claimable** when it has no lease, its lease was released,
//! or `now` is past `expires_ms + skew_margin` (the holder is presumed
//! dead; the next claim is a reclaim and resumes the run from its
//! step-level snapshots). Raw wall-clock comparisons are NOT trusted
//! across hosts: the skew margin absorbs loosely-synced clocks, and
//! reclaims additionally require [`confirm_expired`] — K consecutive
//! ledger reloads spaced TTL/3 apart showing no renewal-`seq` progress
//! from the holder — so a fast-clocked observer can never reclaim a
//! live run no matter how large its offset.
//!
//! The lease file is telemetry-adjacent scaffolding, *outside* the
//! manifest's byte-identity contract — like `manifest.times.jsonl`, it
//! varies with timing and worker count while the compacted manifest
//! does not.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::{SystemTime, UNIX_EPOCH};

use anyhow::{bail, Context, Result};

use crate::ioutil;
use crate::jsonlite::{obj, Json};

/// Sibling lease file (`manifest.jsonl` → `manifest.leases.jsonl`).
pub fn leases_path(manifest: &Path) -> PathBuf {
    manifest.with_extension("leases.jsonl")
}

/// Milliseconds since the Unix epoch (the lease clock). Wall-clock is
/// fine here: expiry only gates *liveness* decisions, never results —
/// nothing time-derived can reach a manifest row.
///
/// A clock before the epoch is a *broken* clock, and silently mapping
/// it to 0 (the old behavior) would make every lease in the fleet look
/// expired at once — a mass-reclaim stampede triggered by one bad CMOS
/// battery. Fail loudly instead: this host must not make liveness
/// decisions until its clock is fixed.
pub fn now_ms() -> u64 {
    match SystemTime::now().duration_since(UNIX_EPOCH) {
        Ok(d) => d.as_millis() as u64,
        Err(e) => panic!(
            "system clock is {}s BEFORE the Unix epoch — refusing to make lease \
             liveness decisions on a broken clock (fix the host's time source)",
            e.duration().as_secs()
        ),
    }
}

/// The testable clock seam every fleet-path time read goes through: a
/// wall clock plus a signed offset. Production workers run at offset 0;
/// the chaos plan (or `--clock-offset-ms`) gives each worker a
/// deterministic offset in ±TTL so skew tolerance is *provable* — the
/// skewed-fleet tests and CI job are real multi-observer scenarios, not
/// mocks of one.
#[derive(Clone, Copy, Debug, Default)]
pub struct LeaseClock {
    /// Signed skew added to the real wall clock, in ms.
    pub offset_ms: i64,
}

impl LeaseClock {
    pub fn new(offset_ms: i64) -> Self {
        Self { offset_ms }
    }

    /// This observer's (possibly skewed) view of [`now_ms`].
    pub fn now_ms(&self) -> u64 {
        let real = now_ms() as i64;
        real.saturating_add(self.offset_ms).max(0) as u64
    }
}

/// What a lease record does (see the module docs for replay rules).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LeaseAction {
    Claim,
    Reclaim,
    Renew,
    Release,
}

impl LeaseAction {
    pub fn label(&self) -> &'static str {
        match self {
            LeaseAction::Claim => "claim",
            LeaseAction::Reclaim => "reclaim",
            LeaseAction::Renew => "renew",
            LeaseAction::Release => "release",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "claim" => LeaseAction::Claim,
            "reclaim" => LeaseAction::Reclaim,
            "renew" => LeaseAction::Renew,
            "release" => LeaseAction::Release,
            other => bail!("unknown lease action {other:?}"),
        })
    }
}

/// One appended lease record.
#[derive(Clone, Debug)]
pub struct LeaseRecord {
    pub run_id: String,
    pub worker: String,
    /// Fencing token (strictly increasing per run across claims).
    pub token: u64,
    /// Per-holder renewal sequence: 0 on claim, +1 per heartbeat. A
    /// logical liveness signal that skewed wall clocks cannot distort.
    pub seq: u64,
    pub action: LeaseAction,
    /// Lease expiry, ms since epoch (claim/reclaim/renew; a release
    /// carries the append time, informational only).
    pub expires_ms: u64,
    /// The holder's probe-server address (`host:port`), advertised on
    /// claims/reclaims/renews when the worker runs `--probe-port` so a
    /// fleet aggregator can federate live `/runs` state. Absent on
    /// unprobed workers, on releases, and on every pre-probe-era ledger
    /// line — the key is only emitted when present, keeping old lines
    /// byte-stable and canonical key order intact.
    pub probe: Option<String>,
}

impl LeaseRecord {
    pub fn to_line(&self) -> String {
        let mut pairs = vec![
            ("action", Json::from(self.action.label())),
            ("expires_ms", Json::from(self.expires_ms as usize)),
            ("run_id", Json::from(self.run_id.clone())),
            ("seq", Json::from(self.seq as usize)),
            ("token", Json::from(self.token as usize)),
            ("worker", Json::from(self.worker.clone())),
        ];
        if let Some(p) = &self.probe {
            // obj() sorts keys: "probe" lands between expires_ms and
            // run_id regardless of push order.
            pairs.push(("probe", Json::from(p.clone())));
        }
        obj(pairs).dump()
    }

    pub fn from_line(line: &str) -> Result<Self> {
        let v = Json::parse(line)?;
        Ok(Self {
            run_id: v.get("run_id")?.as_str()?.to_string(),
            worker: v.get("worker")?.as_str()?.to_string(),
            token: v.get("token")?.as_u64()?,
            // Absent on pre-rotation-era ledgers: default 0 (a holder
            // that never renewed), so old ledgers replay unchanged.
            seq: v.opt("seq").and_then(|s| s.as_u64().ok()).unwrap_or(0),
            action: LeaseAction::parse(v.get("action")?.as_str()?)?,
            expires_ms: v.get("expires_ms")?.as_u64()?,
            // Absent on pre-probe-era ledgers and unprobed workers.
            probe: v.opt("probe").and_then(|p| p.as_str().ok()).map(str::to_string),
        })
    }
}

/// Append one record (single `O_APPEND` write, bounded retry). The
/// page cache is NOT flushed — this is the heartbeat-renewal path,
/// where losing a record to power loss costs at most a premature (and
/// confirmed) reclaim.
pub fn append(path: &Path, rec: &LeaseRecord) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir).ok();
    }
    ioutil::append_line_retry(path, &rec.to_line(), "lease append")
        .with_context(|| format!("appending lease record to {}", path.display()))
}

/// [`append`] + `fdatasync`: for records whose *loss* would be unsafe
/// rather than merely slow — claims, reclaims and releases, whose
/// fencing tokens must survive power loss or a zombie could be
/// un-fenced by a vanished record.
pub fn append_durable(path: &Path, rec: &LeaseRecord) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir).ok();
    }
    ioutil::append_line_retry_durable(path, &rec.to_line(), "lease append durable")
        .with_context(|| format!("appending lease record to {}", path.display()))
}

/// The current lease of one run after replay.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LeaseState {
    pub worker: String,
    pub token: u64,
    pub expires_ms: u64,
    /// Highest renewal `seq` seen from the current holder.
    pub seq: u64,
    pub released: bool,
    /// The holder's advertised probe address, if it runs a probe server.
    /// Cleared on release (a retired lease has no live probe to call)
    /// so a rotated ledger — whose release lines carry no probe —
    /// replays to the same table as the file it replaced.
    pub probe: Option<String>,
}

/// All leases, replayed from the file in append order.
#[derive(Debug, Default)]
pub struct LeaseTable {
    states: BTreeMap<String, LeaseState>,
    /// Torn/unparseable lines skipped during replay.
    pub corrupt_lines: usize,
}

impl LeaseTable {
    /// Replay the lease file (missing file = empty table). Torn lines —
    /// including ones torn mid-way through a multi-byte character — are
    /// skipped and counted, like the manifest's.
    pub fn load(path: &Path) -> Result<Self> {
        let mut t = Self::default();
        let lines = match ioutil::read_lossy_lines(path) {
            Ok(l) => l,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(t),
            Err(e) => return Err(e).with_context(|| format!("reading {}", path.display())),
        };
        for line in &lines {
            if line.trim().is_empty() {
                continue;
            }
            match LeaseRecord::from_line(line) {
                Ok(rec) => t.apply(rec),
                Err(_) => t.corrupt_lines += 1,
            }
        }
        Ok(t)
    }

    fn apply(&mut self, rec: LeaseRecord) {
        let entry = self.states.entry(rec.run_id.clone());
        match rec.action {
            LeaseAction::Claim | LeaseAction::Reclaim => {
                let fresh = LeaseState {
                    worker: rec.worker,
                    token: rec.token,
                    expires_ms: rec.expires_ms,
                    seq: rec.seq,
                    released: false,
                    probe: rec.probe,
                };
                match entry {
                    std::collections::btree_map::Entry::Vacant(v) => {
                        v.insert(fresh);
                    }
                    std::collections::btree_map::Entry::Occupied(mut o) => {
                        // higher token supersedes; an equal token lost the
                        // append race (first appender wins); lower = stale
                        if rec.token > o.get().token {
                            o.insert(fresh);
                        }
                    }
                }
            }
            LeaseAction::Renew => {
                if let std::collections::btree_map::Entry::Occupied(mut o) = entry {
                    let s = o.get_mut();
                    if s.token == rec.token && s.worker == rec.worker && !s.released {
                        s.expires_ms = s.expires_ms.max(rec.expires_ms);
                        s.seq = s.seq.max(rec.seq);
                        if rec.probe.is_some() {
                            s.probe = rec.probe;
                        }
                    }
                }
            }
            LeaseAction::Release => {
                match entry {
                    std::collections::btree_map::Entry::Occupied(mut o) => {
                        let s = o.get_mut();
                        if s.token == rec.token {
                            s.released = true;
                            s.seq = s.seq.max(rec.seq);
                            s.probe = None;
                        }
                    }
                    // A release with no prior record is the compacted
                    // form a ledger rotation writes (one max-token line
                    // per run): install the full released state so the
                    // rotated ledger replays to the same table — and
                    // the same fencing floor — as the file it replaced.
                    std::collections::btree_map::Entry::Vacant(v) => {
                        v.insert(LeaseState {
                            worker: rec.worker,
                            token: rec.token,
                            expires_ms: rec.expires_ms,
                            seq: rec.seq,
                            released: true,
                            probe: None,
                        });
                    }
                }
            }
        }
    }

    /// The run's current lease, if any record ever touched it.
    pub fn state(&self, run_id: &str) -> Option<&LeaseState> {
        self.states.get(run_id)
    }

    /// Highest claim token seen for this run (0 = never claimed). The
    /// next claim must carry `max_token + 1`; a holder whose token is
    /// below this value is fenced.
    pub fn max_token(&self, run_id: &str) -> u64 {
        self.states.get(run_id).map_or(0, |s| s.token)
    }

    /// The live holder `(worker, token)` — the winning claimant whose
    /// lease was neither released nor superseded. Expiry is deliberately
    /// NOT checked here: a claim confirmation compares identity, and an
    /// expired-but-unsuperseded holder is still the fencing reference.
    pub fn holder(&self, run_id: &str) -> Option<(&str, u64)> {
        self.states
            .get(run_id)
            .filter(|s| !s.released)
            .map(|s| (s.worker.as_str(), s.token))
    }

    /// May a new claim be appended for this run right now?
    /// `skew_margin_ms` pads the expiry: across hosts, `now_ms` and
    /// `expires_ms` were read from *different* clocks, and the margin is
    /// the declared bound on their disagreement. An expired-looking
    /// lease is additionally gated by [`confirm_expired`] on the
    /// reclaim path; the margin alone only filters the obvious cases
    /// cheaply.
    pub fn claimable(&self, run_id: &str, now_ms: u64, skew_margin_ms: u64) -> bool {
        match self.states.get(run_id) {
            None => true,
            Some(s) => s.released || now_ms >= s.expires_ms.saturating_add(skew_margin_ms),
        }
    }

    /// Is this run claimable *without* presuming anyone dead — no lease
    /// record at all, or a released one? Fresh claims need no logical
    /// confirmation, so workers prefer them over expired leases.
    pub fn fresh_claimable(&self, run_id: &str) -> bool {
        self.states.get(run_id).map_or(true, |s| s.released)
    }

    /// Is any lease still live (unreleased and unexpired, under the
    /// same skew margin as [`claimable`])? Gates fleet compaction and
    /// ledger rotation: a live lease means a worker may still append.
    pub fn any_active(&self, now_ms: u64, skew_margin_ms: u64) -> bool {
        self.states
            .values()
            .any(|s| !s.released && now_ms < s.expires_ms.saturating_add(skew_margin_ms))
    }

    /// Every recorded lease is released (the rotation precondition: a
    /// compacted ledger of release lines can represent this state
    /// exactly, and no in-flight holder can be racing us for *content*
    /// — only for brand-new claims, which the claim protocol absorbs).
    pub fn all_released(&self) -> bool {
        self.states.values().all(|s| s.released)
    }

    /// Run ids with any recorded lease, in sorted order.
    pub fn run_ids(&self) -> impl Iterator<Item = &str> {
        self.states.keys().map(String::as_str)
    }

    /// Every `(run_id, state)` pair in sorted order — the read-only view
    /// a fleet aggregator walks to reconstruct per-worker holdings.
    pub fn iter(&self) -> impl Iterator<Item = (&str, &LeaseState)> {
        self.states.iter().map(|(k, v)| (k.as_str(), v))
    }
}

/// Rotate (garbage-collect) the ledger when every recorded lease is
/// released and the raw file has grown past `min_lines`: rewrite it as
/// ONE release line per run carrying the run's max fencing token and
/// last renewal seq, via tmp + fsync + rename + parent-dir fsync.
/// Returns `true` when a rotation happened.
///
/// Invariants preserved:
///
/// * **fencing-token monotonicity** — the compacted line carries the
///   max token ever claimed, so a zombie holding any pre-rotation token
///   is still fenced after GC (its token is `≤` the recorded one, and
///   claims still go to `max_token + 1`);
/// * **replay equivalence** — replaying the rotated ledger yields the
///   same [`LeaseTable`] as the full one (release-on-vacant installs
///   the recorded state wholesale);
/// * **bounded size** — the ledger can no longer grow without bound
///   over a week-long sweep: every all-released point compacts it to
///   one line per touched run.
///
/// Concurrency: a claim appended between our load and the rename is
/// overwritten. That is safe by protocol, not by luck — the claimant
/// confirms by *re-reading* the ledger, and a claim the rotation
/// swallowed either fails confirmation (the claimant walks away) or, in
/// the worst interleaving, leads to one duplicate execution whose
/// committed row is byte-identical by seed-replay determinism and is
/// deduplicated by run id on load. The metadata re-check below shrinks
/// that window to microseconds; it cannot (and need not) close it.
pub fn rotate(path: &Path, min_lines: usize) -> Result<bool> {
    let raw_len = match std::fs::metadata(path) {
        Ok(m) => m.len(),
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(false),
        Err(e) => return Err(e).with_context(|| format!("reading metadata of {}", path.display())),
    };
    let lines = ioutil::read_lossy_lines(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let n_lines = lines.iter().filter(|l| !l.trim().is_empty()).count();
    if n_lines < min_lines.max(1) {
        return Ok(false);
    }
    let table = LeaseTable::load(path)?;
    if table.states.is_empty() || !table.all_released() {
        return Ok(false);
    }
    if n_lines <= table.states.len() {
        return Ok(false); // already compact
    }
    let mut out = String::new();
    for (run_id, s) in &table.states {
        let rec = LeaseRecord {
            run_id: run_id.clone(),
            worker: s.worker.clone(),
            token: s.token,
            seq: s.seq,
            action: LeaseAction::Release,
            expires_ms: s.expires_ms,
            // a compacted (released) line never carries a probe address
            probe: None,
        };
        out.push_str(&rec.to_line());
        out.push('\n');
    }
    // Unique per process + call: concurrent workers may rotate the same
    // ledger at the same all-released moment (they write identical
    // bytes; the rename is atomic).
    static SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let tmp = path.with_extension(format!(
        "jsonl.rot.{}.{}",
        std::process::id(),
        SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
    ));
    {
        use std::io::Write as _;
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(out.as_bytes())
            .with_context(|| format!("writing {}", tmp.display()))?;
        // The compacted content must be on the platter BEFORE the rename
        // makes it the ledger: a post-rename power loss must never
        // surface an empty (un-fenced) file.
        f.sync_data().with_context(|| format!("syncing {}", tmp.display()))?;
    }
    // Best-effort race-window shrink: if someone appended since our
    // load, skip this rotation; the next all-released point retries.
    if std::fs::metadata(path).map(|m| m.len()).unwrap_or(0) != raw_len {
        std::fs::remove_file(&tmp).ok();
        return Ok(false);
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} into place", tmp.display()))?;
    if let Some(dir) = path.parent() {
        // The rename itself is only durable once the directory is.
        ioutil::fsync_dir(dir)
            .with_context(|| format!("fsyncing ledger directory {}", dir.display()))?;
    }
    Ok(true)
}

/// Logical (skew-proof) confirmation that an expired-looking lease is
/// truly dead: reload the ledger `k` times spaced `ttl_ms/3` apart (one
/// heartbeat interval) and require that the holder shows **no sign of
/// life** across every reload — no renewal-`seq` advance, no expiry
/// extension, no token change, no release. Returns `false` the moment
/// any progress is observed (the holder is alive, or someone else
/// already acted); `true` only after `k` consecutive quiet reloads.
///
/// This is what makes reclaim correct under arbitrary clock skew: a
/// fast-clocked observer may *think* a lease expired, but a live holder
/// heartbeats every TTL/3, so its `seq` — a logical counter no clock
/// can distort — advances within the confirmation window and the
/// reclaim is vetoed.
pub fn confirm_expired(
    path: &Path,
    run_id: &str,
    k: u32,
    ttl_ms: u64,
    clock: &LeaseClock,
    skew_margin_ms: u64,
) -> Result<bool> {
    let Some(before) = LeaseTable::load(path)?.state(run_id).cloned() else {
        // no record at all: a fresh claim, nothing to confirm
        return Ok(true);
    };
    if before.released {
        return Ok(true);
    }
    let pause = std::time::Duration::from_millis((ttl_ms / 3).max(5));
    for _ in 0..k.max(1) {
        std::thread::sleep(pause);
        let table = LeaseTable::load(path)?;
        let Some(now) = table.state(run_id) else {
            // the ledger rotated underneath us and the run vanished from
            // it — only possible if everything was released; re-claim
            // decisions restart from the fresh table
            return Ok(false);
        };
        let quiet = now.token == before.token
            && now.worker == before.worker
            && now.seq == before.seq
            && now.expires_ms == before.expires_ms
            && !now.released;
        if !quiet {
            return Ok(false);
        }
        // still expired from this observer's (skew-adjusted) view?
        if !table.claimable(run_id, clock.now_ms(), skew_margin_ms) {
            return Ok(false);
        }
    }
    Ok(true)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(run: &str, worker: &str, token: u64, action: LeaseAction, expires: u64) -> LeaseRecord {
        LeaseRecord {
            run_id: run.to_string(),
            worker: worker.to_string(),
            token,
            seq: 0,
            action,
            expires_ms: expires,
            probe: None,
        }
    }

    fn rec_seq(
        run: &str,
        worker: &str,
        token: u64,
        seq: u64,
        action: LeaseAction,
        expires: u64,
    ) -> LeaseRecord {
        LeaseRecord { seq, ..rec(run, worker, token, action, expires) }
    }

    fn table(recs: &[LeaseRecord]) -> LeaseTable {
        let mut t = LeaseTable::default();
        for r in recs {
            t.apply(r.clone());
        }
        t
    }

    #[test]
    fn record_roundtrips() {
        let r = rec_seq("run-a", "w0", 3, 7, LeaseAction::Reclaim, 1_754_650_000_000);
        let back = LeaseRecord::from_line(&r.to_line()).unwrap();
        assert_eq!(back.run_id, "run-a");
        assert_eq!(back.worker, "w0");
        assert_eq!(back.token, 3);
        assert_eq!(back.seq, 7);
        assert_eq!(back.action, LeaseAction::Reclaim);
        assert_eq!(back.expires_ms, 1_754_650_000_000);
        assert_eq!(back.to_line(), r.to_line(), "serialization is canonical");
        assert!(LeaseRecord::from_line("{\"action\":\"explode\"}").is_err());
        // pre-seq-era ledger lines (no "seq" key) still parse, seq = 0
        let legacy =
            "{\"action\":\"claim\",\"expires_ms\":50,\"run_id\":\"r\",\"token\":1,\"worker\":\"w\"}";
        assert_eq!(LeaseRecord::from_line(legacy).unwrap().seq, 0);
    }

    #[test]
    fn probe_field_roundtrips_and_pre_probe_lines_parse_as_absent() {
        // a probe-less record emits no "probe" key at all
        let bare = rec("r", "w0", 1, LeaseAction::Claim, 50);
        assert!(!bare.to_line().contains("probe"), "{}", bare.to_line());
        assert_eq!(LeaseRecord::from_line(&bare.to_line()).unwrap().probe, None);
        // a probed record round-trips and stays in canonical key order
        let probed = LeaseRecord { probe: Some("127.0.0.1:9090".to_string()), ..bare.clone() };
        let line = probed.to_line();
        assert_eq!(
            line,
            "{\"action\":\"claim\",\"expires_ms\":50,\"probe\":\"127.0.0.1:9090\",\
             \"run_id\":\"r\",\"seq\":0,\"token\":1,\"worker\":\"w0\"}"
        );
        let back = LeaseRecord::from_line(&line).unwrap();
        assert_eq!(back.probe.as_deref(), Some("127.0.0.1:9090"));
        assert_eq!(back.to_line(), line, "serialization is canonical");
        // pre-probe-era ledger lines (no "probe" key) parse as absent
        let legacy =
            "{\"action\":\"renew\",\"expires_ms\":50,\"run_id\":\"r\",\"seq\":3,\"token\":1,\
             \"worker\":\"w\"}";
        assert_eq!(LeaseRecord::from_line(legacy).unwrap().probe, None);
    }

    #[test]
    fn probe_address_follows_the_lease_lifecycle() {
        let probed = |r: LeaseRecord, p: &str| LeaseRecord { probe: Some(p.to_string()), ..r };
        // installed on claim, refreshed by a probe-carrying renew
        let t = table(&[
            probed(rec("r", "w0", 1, LeaseAction::Claim, 100), "127.0.0.1:1111"),
            probed(rec_seq("r", "w0", 1, 1, LeaseAction::Renew, 200), "127.0.0.1:2222"),
        ]);
        assert_eq!(t.state("r").unwrap().probe.as_deref(), Some("127.0.0.1:2222"));
        // a probe-less renew keeps the advertised address
        let t = table(&[
            probed(rec("r", "w0", 1, LeaseAction::Claim, 100), "127.0.0.1:1111"),
            rec_seq("r", "w0", 1, 1, LeaseAction::Renew, 200),
        ]);
        assert_eq!(t.state("r").unwrap().probe.as_deref(), Some("127.0.0.1:1111"));
        // a zombie's renew cannot repoint the probe
        let t = table(&[
            probed(rec("r", "w0", 2, LeaseAction::Claim, 100), "127.0.0.1:1111"),
            probed(rec_seq("r", "w1", 1, 9, LeaseAction::Renew, 900), "127.0.0.1:6666"),
        ]);
        assert_eq!(t.state("r").unwrap().probe.as_deref(), Some("127.0.0.1:1111"));
        // release clears it: a retired lease has no live probe, matching
        // the rotated (release-on-vacant) form byte for byte
        let t = table(&[
            probed(rec("r", "w0", 1, LeaseAction::Claim, 100), "127.0.0.1:1111"),
            rec("r", "w0", 1, LeaseAction::Release, 100),
        ]);
        assert_eq!(t.state("r").unwrap().probe, None);
    }

    #[test]
    fn rotation_drops_probe_addresses_with_the_release_lines() {
        let path = tmp_ledger("rot_probe");
        let mut claim = rec("a", "w0", 1, LeaseAction::Claim, 100);
        claim.probe = Some("127.0.0.1:1234".to_string());
        append(&path, &claim).unwrap();
        append(&path, &rec("a", "w0", 1, LeaseAction::Release, 100)).unwrap();
        assert!(rotate(&path, 1).unwrap());
        let raw = std::fs::read_to_string(&path).unwrap();
        assert!(!raw.contains("probe"), "compacted lines carry no probe: {raw}");
        let t = LeaseTable::load(&path).unwrap();
        assert_eq!(t.state("a").unwrap().probe, None);
        assert_eq!(t.max_token("a"), 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn lease_clock_applies_signed_offsets() {
        let real = now_ms();
        let fast = LeaseClock::new(5_000).now_ms();
        let slow = LeaseClock::new(-5_000).now_ms();
        assert!(fast >= real + 5_000);
        assert!(slow <= real - 5_000 + 100, "slow {slow} vs real {real}");
        assert!(LeaseClock::default().now_ms() >= real);
        // an absurd negative offset clamps at 0, never wraps
        assert_eq!(LeaseClock::new(i64::MIN).now_ms().min(1), 0);
    }

    #[test]
    fn first_equal_token_claim_wins() {
        // two workers race claim(token 1); file order decides
        let t = table(&[
            rec("r", "w0", 1, LeaseAction::Claim, 100),
            rec("r", "w1", 1, LeaseAction::Claim, 120),
        ]);
        assert_eq!(t.holder("r"), Some(("w0", 1)));
        assert_eq!(t.max_token("r"), 1);
    }

    #[test]
    fn higher_token_supersedes_and_fences() {
        let t = table(&[
            rec("r", "w0", 1, LeaseAction::Claim, 100),
            rec("r", "w1", 2, LeaseAction::Reclaim, 300),
            // stale writes from the fenced original holder are no-ops
            rec("r", "w0", 1, LeaseAction::Renew, 900),
            rec("r", "w0", 1, LeaseAction::Release, 0),
        ]);
        assert_eq!(t.holder("r"), Some(("w1", 2)));
        assert_eq!(t.state("r").unwrap().expires_ms, 300, "zombie renew ignored");
        assert!(!t.state("r").unwrap().released, "zombie release ignored");
    }

    #[test]
    fn renew_extends_only_the_current_holder() {
        let t = table(&[
            rec("r", "w0", 1, LeaseAction::Claim, 100),
            rec_seq("r", "w0", 1, 1, LeaseAction::Renew, 250),
        ]);
        assert_eq!(t.state("r").unwrap().expires_ms, 250);
        assert_eq!(t.state("r").unwrap().seq, 1, "a renewal advances the holder seq");
        assert!(!t.claimable("r", 200, 0));
        assert!(t.claimable("r", 250, 0), "expired leases are reclaimable");
        // zombie renewals never advance the seq either
        let t = table(&[
            rec("r", "w0", 1, LeaseAction::Claim, 100),
            rec("r", "w1", 2, LeaseAction::Reclaim, 300),
            rec_seq("r", "w0", 1, 9, LeaseAction::Renew, 900),
        ]);
        assert_eq!(t.state("r").unwrap().seq, 0);
    }

    #[test]
    fn skew_margin_pads_expiry_decisions() {
        let t = table(&[rec("r", "w0", 1, LeaseAction::Claim, 1_000)]);
        assert!(t.claimable("r", 1_000, 0), "no margin: raw comparison");
        assert!(!t.claimable("r", 1_000, 250), "margin absorbs observer skew");
        assert!(!t.claimable("r", 1_249, 250));
        assert!(t.claimable("r", 1_250, 250));
        assert!(t.any_active(1_000, 250), "active view is padded symmetrically");
        assert!(!t.any_active(1_250, 250));
        assert!(t.fresh_claimable("never-claimed"));
        assert!(!t.fresh_claimable("r"));
    }

    #[test]
    fn release_retires_the_lease() {
        let t = table(&[
            rec("r", "w0", 1, LeaseAction::Claim, 100),
            rec("r", "w0", 1, LeaseAction::Release, 42),
        ]);
        assert!(t.claimable("r", 0, 0), "released leases are claimable before expiry");
        assert!(t.fresh_claimable("r"));
        assert_eq!(t.holder("r"), None);
        assert_eq!(t.max_token("r"), 1, "the token history survives release");
        assert!(!t.any_active(0, 0));
        assert!(t.all_released());
    }

    #[test]
    fn release_on_vacant_installs_the_rotated_state() {
        // the compacted line a rotation writes: one release per run
        let t = table(&[rec_seq("r", "w3", 5, 12, LeaseAction::Release, 777)]);
        let s = t.state("r").unwrap();
        assert!(s.released);
        assert_eq!((s.token, s.seq, s.expires_ms, s.worker.as_str()), (5, 12, 777, "w3"));
        assert_eq!(t.max_token("r"), 5, "the fencing floor survives rotation");
        assert!(t.claimable("r", 0, 10_000));
    }

    #[test]
    fn load_tolerates_torn_and_missing_files() {
        let dir = std::env::temp_dir().join(format!("addax_lease_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.leases.jsonl");
        std::fs::remove_file(&path).ok();
        assert_eq!(LeaseTable::load(&path).unwrap().corrupt_lines, 0, "missing = empty");
        append(&path, &rec("r", "w0", 1, LeaseAction::Claim, 4_102_444_800_000)).unwrap();
        // a kill mid-append tears the line — with an invalid UTF-8 tail
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.extend_from_slice(b"{\"action\":\"claim\",\"run_id\":\"caf");
        bytes.push(0xC3);
        std::fs::write(&path, &bytes).unwrap();
        let t = LeaseTable::load(&path).unwrap();
        assert_eq!(t.corrupt_lines, 1);
        assert_eq!(t.holder("r"), Some(("w0", 1)));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn leases_path_is_a_sibling() {
        let p = leases_path(Path::new("results/sweep/manifest.jsonl"));
        assert_eq!(p, PathBuf::from("results/sweep/manifest.leases.jsonl"));
    }

    fn tmp_ledger(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("addax_lease_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.leases.jsonl");
        std::fs::remove_file(&path).ok();
        path
    }

    #[test]
    fn rotation_compacts_and_replays_equivalently() {
        let path = tmp_ledger("rot");
        // two runs, a reclaim history, renewals, all released: 8 lines
        for r in [
            rec("a", "w0", 1, LeaseAction::Claim, 100),
            rec_seq("a", "w0", 1, 1, LeaseAction::Renew, 200),
            rec("b", "w1", 1, LeaseAction::Claim, 100),
            rec("a", "w1", 2, LeaseAction::Reclaim, 300),
            rec_seq("a", "w1", 2, 1, LeaseAction::Renew, 350),
            rec_seq("a", "w1", 2, 2, LeaseAction::Renew, 400),
            rec_seq("a", "w1", 2, 2, LeaseAction::Release, 400),
            rec("b", "w1", 1, LeaseAction::Release, 100),
        ] {
            append(&path, &r).unwrap();
        }
        let full = LeaseTable::load(&path).unwrap();
        assert!(full.all_released());
        assert!(rotate(&path, 1).unwrap(), "all released + 8 > 2 lines: rotates");
        let lines = ioutil::read_lossy_lines(&path).unwrap();
        assert_eq!(lines.iter().filter(|l| !l.trim().is_empty()).count(), 2,
            "one line per run after rotation");
        let compact = LeaseTable::load(&path).unwrap();
        for run in ["a", "b"] {
            let (f, c) = (full.state(run).unwrap(), compact.state(run).unwrap());
            assert_eq!((f.worker.as_str(), f.token, f.seq, f.expires_ms, f.released),
                       (c.worker.as_str(), c.token, c.seq, c.expires_ms, c.released),
                       "replaying the rotated ledger yields the same table for {run}");
        }
        assert_eq!(compact.max_token("a"), 2, "fencing floor survives");
        assert!(!rotate(&path, 1).unwrap(), "already compact: second rotation is a no-op");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rotation_refuses_while_any_lease_is_live() {
        let path = tmp_ledger("rot_live");
        append(&path, &rec("a", "w0", 1, LeaseAction::Claim, u64::MAX)).unwrap();
        append(&path, &rec("b", "w0", 1, LeaseAction::Claim, 50)).unwrap();
        append(&path, &rec("b", "w0", 1, LeaseAction::Release, 50)).unwrap();
        assert!(!rotate(&path, 1).unwrap(), "run `a` is unreleased");
        assert!(!rotate(&path, 100).unwrap(), "below min_lines is always a no-op");
        let t = LeaseTable::load(&path).unwrap();
        assert_eq!(t.holder("a"), Some(("w0", 1)), "ledger untouched");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn confirm_expired_vetoes_a_renewing_holder() {
        let path = tmp_ledger("confirm_live");
        append(&path, &rec("r", "w0", 1, LeaseAction::Claim, 10)).unwrap();
        // holder heartbeats in the background while the observer confirms
        let p2 = path.clone();
        let h = std::thread::spawn(move || {
            for seq in 1..=6u64 {
                std::thread::sleep(std::time::Duration::from_millis(8));
                append(&p2, &rec_seq("r", "w0", 1, seq, LeaseAction::Renew, 10 + seq)).unwrap();
            }
        });
        let clock = LeaseClock::new(i64::MAX / 2); // observer's clock is absurdly fast
        let ok = confirm_expired(&path, "r", 3, 60, &clock, 0).unwrap();
        h.join().unwrap();
        assert!(!ok, "a live holder's seq advances within TTL/3 and vetoes the reclaim");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn confirm_expired_passes_on_a_truly_dead_holder() {
        let path = tmp_ledger("confirm_dead");
        append(&path, &rec("r", "w0", 1, LeaseAction::Claim, 10)).unwrap();
        let clock = LeaseClock::new(0);
        assert!(confirm_expired(&path, "r", 2, 30, &clock, 0).unwrap(),
            "no renewal across k reloads: the holder is dead");
        assert!(confirm_expired(&path, "never-claimed", 2, 30, &clock, 0).unwrap(),
            "a fresh run needs no confirmation");
        append(&path, &rec("r", "w0", 1, LeaseAction::Release, 10)).unwrap();
        assert!(confirm_expired(&path, "r", 2, 30, &clock, 0).unwrap(),
            "released is as dead as it gets");
        std::fs::remove_file(&path).ok();
    }
}
