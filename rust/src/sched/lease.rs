//! Run leases: the coordination substrate that turns the single-process
//! sweep into a multi-process fleet.
//!
//! Workers claim runs by appending lease records to a sibling
//! `manifest.leases.jsonl` (append-only JSONL, same crash-tolerance
//! rules as the manifest), heartbeat by appending renewals, and reclaim
//! leases whose TTL lapsed. The file is the *only* shared state — there
//! is no server and no lock: `O_APPEND` serializes the records, and the
//! replay rules below make every reader agree on who holds what.
//!
//! Record shape (one JSON object per line; keys in canonical order):
//!
//! ```json
//! {"action":"claim","expires_ms":1754650000000,"run_id":"...","token":1,"worker":"w0"}
//! ```
//!
//! * `token` is the **fencing token**: claims carry `max token + 1` for
//!   their run, so tokens strictly increase across claim generations.
//!   A worker that lost its lease (crash, stall, partition) holds a
//!   stale token forever — its late writes are detectable and
//!   rejectable by comparing tokens, no matter when they arrive.
//! * `action` is `claim` (fresh), `reclaim` (a claim over an expired
//!   lease — identical semantics, distinct label so reclaims are
//!   observable in telemetry and CI), `renew` (heartbeat: extends
//!   `expires_ms`), or `release` (the run's row is durable; the lease
//!   is retired).
//!
//! Replay rules (applied in file order; all readers converge):
//!
//! * a claim/reclaim with a **higher** token supersedes the current
//!   lease; an **equal** token loses to the earlier record (`O_APPEND`
//!   ordering breaks the tie — "first appender wins"); a lower token is
//!   stale noise and ignored;
//! * a renew extends the expiry only when worker *and* token match the
//!   current lease (a zombie's renewals are no-ops);
//! * a release retires the current lease only at a matching token.
//!
//! A run is **claimable** when it has no lease, its lease was released,
//! or `now` is past `expires_ms` (the holder is presumed dead; the next
//! claim is a reclaim and resumes the run from its step-level
//! snapshots).
//!
//! The lease file is telemetry-adjacent scaffolding, *outside* the
//! manifest's byte-identity contract — like `manifest.times.jsonl`, it
//! varies with timing and worker count while the compacted manifest
//! does not.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::{SystemTime, UNIX_EPOCH};

use anyhow::{bail, Context, Result};

use crate::ioutil;
use crate::jsonlite::{obj, Json};

/// Sibling lease file (`manifest.jsonl` → `manifest.leases.jsonl`).
pub fn leases_path(manifest: &Path) -> PathBuf {
    manifest.with_extension("leases.jsonl")
}

/// Milliseconds since the Unix epoch (the lease clock). Wall-clock is
/// fine here: expiry only gates *liveness* decisions, never results —
/// nothing time-derived can reach a manifest row.
pub fn now_ms() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

/// What a lease record does (see the module docs for replay rules).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LeaseAction {
    Claim,
    Reclaim,
    Renew,
    Release,
}

impl LeaseAction {
    pub fn label(&self) -> &'static str {
        match self {
            LeaseAction::Claim => "claim",
            LeaseAction::Reclaim => "reclaim",
            LeaseAction::Renew => "renew",
            LeaseAction::Release => "release",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "claim" => LeaseAction::Claim,
            "reclaim" => LeaseAction::Reclaim,
            "renew" => LeaseAction::Renew,
            "release" => LeaseAction::Release,
            other => bail!("unknown lease action {other:?}"),
        })
    }
}

/// One appended lease record.
#[derive(Clone, Debug)]
pub struct LeaseRecord {
    pub run_id: String,
    pub worker: String,
    /// Fencing token (strictly increasing per run across claims).
    pub token: u64,
    pub action: LeaseAction,
    /// Lease expiry, ms since epoch (claim/reclaim/renew; a release
    /// carries the append time, informational only).
    pub expires_ms: u64,
}

impl LeaseRecord {
    pub fn to_line(&self) -> String {
        obj(vec![
            ("action", Json::from(self.action.label())),
            ("expires_ms", Json::from(self.expires_ms as usize)),
            ("run_id", Json::from(self.run_id.clone())),
            ("token", Json::from(self.token as usize)),
            ("worker", Json::from(self.worker.clone())),
        ])
        .dump()
    }

    pub fn from_line(line: &str) -> Result<Self> {
        let v = Json::parse(line)?;
        Ok(Self {
            run_id: v.get("run_id")?.as_str()?.to_string(),
            worker: v.get("worker")?.as_str()?.to_string(),
            token: v.get("token")?.as_u64()?,
            action: LeaseAction::parse(v.get("action")?.as_str()?)?,
            expires_ms: v.get("expires_ms")?.as_u64()?,
        })
    }
}

/// Append one record durably (single write, bounded retry).
pub fn append(path: &Path, rec: &LeaseRecord) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir).ok();
    }
    ioutil::append_line_retry(path, &rec.to_line(), "lease append")
        .with_context(|| format!("appending lease record to {}", path.display()))
}

/// The current lease of one run after replay.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LeaseState {
    pub worker: String,
    pub token: u64,
    pub expires_ms: u64,
    pub released: bool,
}

/// All leases, replayed from the file in append order.
#[derive(Debug, Default)]
pub struct LeaseTable {
    states: BTreeMap<String, LeaseState>,
    /// Torn/unparseable lines skipped during replay.
    pub corrupt_lines: usize,
}

impl LeaseTable {
    /// Replay the lease file (missing file = empty table). Torn lines —
    /// including ones torn mid-way through a multi-byte character — are
    /// skipped and counted, like the manifest's.
    pub fn load(path: &Path) -> Result<Self> {
        let mut t = Self::default();
        let lines = match ioutil::read_lossy_lines(path) {
            Ok(l) => l,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(t),
            Err(e) => return Err(e).with_context(|| format!("reading {}", path.display())),
        };
        for line in &lines {
            if line.trim().is_empty() {
                continue;
            }
            match LeaseRecord::from_line(line) {
                Ok(rec) => t.apply(rec),
                Err(_) => t.corrupt_lines += 1,
            }
        }
        Ok(t)
    }

    fn apply(&mut self, rec: LeaseRecord) {
        let entry = self.states.entry(rec.run_id.clone());
        match rec.action {
            LeaseAction::Claim | LeaseAction::Reclaim => {
                let fresh = LeaseState {
                    worker: rec.worker,
                    token: rec.token,
                    expires_ms: rec.expires_ms,
                    released: false,
                };
                match entry {
                    std::collections::btree_map::Entry::Vacant(v) => {
                        v.insert(fresh);
                    }
                    std::collections::btree_map::Entry::Occupied(mut o) => {
                        // higher token supersedes; an equal token lost the
                        // append race (first appender wins); lower = stale
                        if rec.token > o.get().token {
                            o.insert(fresh);
                        }
                    }
                }
            }
            LeaseAction::Renew => {
                if let std::collections::btree_map::Entry::Occupied(mut o) = entry {
                    let s = o.get_mut();
                    if s.token == rec.token && s.worker == rec.worker && !s.released {
                        s.expires_ms = s.expires_ms.max(rec.expires_ms);
                    }
                }
            }
            LeaseAction::Release => {
                if let std::collections::btree_map::Entry::Occupied(mut o) = entry {
                    let s = o.get_mut();
                    if s.token == rec.token {
                        s.released = true;
                    }
                }
            }
        }
    }

    /// The run's current lease, if any record ever touched it.
    pub fn state(&self, run_id: &str) -> Option<&LeaseState> {
        self.states.get(run_id)
    }

    /// Highest claim token seen for this run (0 = never claimed). The
    /// next claim must carry `max_token + 1`; a holder whose token is
    /// below this value is fenced.
    pub fn max_token(&self, run_id: &str) -> u64 {
        self.states.get(run_id).map_or(0, |s| s.token)
    }

    /// The live holder `(worker, token)` — the winning claimant whose
    /// lease was neither released nor superseded. Expiry is deliberately
    /// NOT checked here: a claim confirmation compares identity, and an
    /// expired-but-unsuperseded holder is still the fencing reference.
    pub fn holder(&self, run_id: &str) -> Option<(&str, u64)> {
        self.states
            .get(run_id)
            .filter(|s| !s.released)
            .map(|s| (s.worker.as_str(), s.token))
    }

    /// May a new claim be appended for this run right now?
    pub fn claimable(&self, run_id: &str, now_ms: u64) -> bool {
        match self.states.get(run_id) {
            None => true,
            Some(s) => s.released || now_ms >= s.expires_ms,
        }
    }

    /// Is any lease still live (unreleased and unexpired)? Gates fleet
    /// compaction: a live lease means a worker may still append.
    pub fn any_active(&self, now_ms: u64) -> bool {
        self.states.values().any(|s| !s.released && now_ms < s.expires_ms)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(run: &str, worker: &str, token: u64, action: LeaseAction, expires: u64) -> LeaseRecord {
        LeaseRecord {
            run_id: run.to_string(),
            worker: worker.to_string(),
            token,
            action,
            expires_ms: expires,
        }
    }

    fn table(recs: &[LeaseRecord]) -> LeaseTable {
        let mut t = LeaseTable::default();
        for r in recs {
            t.apply(r.clone());
        }
        t
    }

    #[test]
    fn record_roundtrips() {
        let r = rec("run-a", "w0", 3, LeaseAction::Reclaim, 1_754_650_000_000);
        let back = LeaseRecord::from_line(&r.to_line()).unwrap();
        assert_eq!(back.run_id, "run-a");
        assert_eq!(back.worker, "w0");
        assert_eq!(back.token, 3);
        assert_eq!(back.action, LeaseAction::Reclaim);
        assert_eq!(back.expires_ms, 1_754_650_000_000);
        assert_eq!(back.to_line(), r.to_line(), "serialization is canonical");
        assert!(LeaseRecord::from_line("{\"action\":\"explode\"}").is_err());
    }

    #[test]
    fn first_equal_token_claim_wins() {
        // two workers race claim(token 1); file order decides
        let t = table(&[
            rec("r", "w0", 1, LeaseAction::Claim, 100),
            rec("r", "w1", 1, LeaseAction::Claim, 120),
        ]);
        assert_eq!(t.holder("r"), Some(("w0", 1)));
        assert_eq!(t.max_token("r"), 1);
    }

    #[test]
    fn higher_token_supersedes_and_fences() {
        let t = table(&[
            rec("r", "w0", 1, LeaseAction::Claim, 100),
            rec("r", "w1", 2, LeaseAction::Reclaim, 300),
            // stale writes from the fenced original holder are no-ops
            rec("r", "w0", 1, LeaseAction::Renew, 900),
            rec("r", "w0", 1, LeaseAction::Release, 0),
        ]);
        assert_eq!(t.holder("r"), Some(("w1", 2)));
        assert_eq!(t.state("r").unwrap().expires_ms, 300, "zombie renew ignored");
        assert!(!t.state("r").unwrap().released, "zombie release ignored");
    }

    #[test]
    fn renew_extends_only_the_current_holder() {
        let t = table(&[
            rec("r", "w0", 1, LeaseAction::Claim, 100),
            rec("r", "w0", 1, LeaseAction::Renew, 250),
        ]);
        assert_eq!(t.state("r").unwrap().expires_ms, 250);
        assert!(!t.claimable("r", 200));
        assert!(t.claimable("r", 250), "expired leases are reclaimable");
    }

    #[test]
    fn release_retires_the_lease() {
        let t = table(&[
            rec("r", "w0", 1, LeaseAction::Claim, 100),
            rec("r", "w0", 1, LeaseAction::Release, 42),
        ]);
        assert!(t.claimable("r", 0), "released leases are claimable before expiry");
        assert_eq!(t.holder("r"), None);
        assert_eq!(t.max_token("r"), 1, "the token history survives release");
        assert!(!t.any_active(0));
    }

    #[test]
    fn load_tolerates_torn_and_missing_files() {
        let dir = std::env::temp_dir().join(format!("addax_lease_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.leases.jsonl");
        std::fs::remove_file(&path).ok();
        assert_eq!(LeaseTable::load(&path).unwrap().corrupt_lines, 0, "missing = empty");
        append(&path, &rec("r", "w0", 1, LeaseAction::Claim, 4_102_444_800_000)).unwrap();
        // a kill mid-append tears the line — with an invalid UTF-8 tail
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.extend_from_slice(b"{\"action\":\"claim\",\"run_id\":\"caf");
        bytes.push(0xC3);
        std::fs::write(&path, &bytes).unwrap();
        let t = LeaseTable::load(&path).unwrap();
        assert_eq!(t.corrupt_lines, 1);
        assert_eq!(t.holder("r"), Some(("w0", 1)));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn leases_path_is_a_sibling() {
        let p = leases_path(Path::new("results/sweep/manifest.jsonl"));
        assert_eq!(p, PathBuf::from("results/sweep/manifest.leases.jsonl"));
    }
}
