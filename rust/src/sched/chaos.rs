//! Deterministic fault injection for the sweep fleet.
//!
//! A fleet is only trustworthy if its failure paths are *exercised*, not
//! hoped-for. `--chaos-seed S` arms a [`ChaosPlan`]: a pure function
//! from `(S, run_id)` to the faults that run suffers, via
//! `derive_seed(S, fnv1a(run_id))` — the exact seed-derivation scheme
//! the trainer uses for noise replay, reused so a chaos scenario is as
//! reproducible as the training it disrupts. Same seed + same grid =
//! the same crashes at the same steps on every machine.
//!
//! Four fault families (mirroring how fleets really die):
//!
//! * **worker crash** — the process "dies" (exits, without releasing its
//!   lease) after a chosen step; the snapshot machinery makes the state
//!   identical to a SIGKILL at a snapshot boundary, since `ADDAXCK1`
//!   writes are atomic. Crashes arm only at fencing token 1 (the run's
//!   first execution): a reclaimed run never re-crashes, so every chaos
//!   scenario makes forward progress by construction.
//! * **heartbeat stall** — the holder stops renewing (a GC pause / NIC
//!   drop stand-in): the lease expires mid-run, someone reclaims it,
//!   and the original holder becomes a zombie whose late commit must be
//!   fenced. Also token-1-only.
//! * **transient I/O faults** — a bounded burst of `Interrupted` errors
//!   injected ahead of the run's manifest-row append (through
//!   `ioutil::inject_transient_faults`), exercising the retry/backoff
//!   path. Bounded below the retry budget, so injected faults are never
//!   fatal — they must be *absorbed*.
//! * **clock skew** — a per-*worker* (not per-run) signed offset in
//!   `[-TTL, +TTL]` applied to every lease-liveness clock read via
//!   [`ChaosPlan::clock_offset_ms`] and the `LeaseClock` seam. Unlike
//!   the other families it never kills anything; it tries to make a
//!   *correct* worker do something wrong (reclaim a live lease, keep a
//!   dead one), which the skew margin + seq confirmation must prevent.

use crate::zorng::{derive_seed, fnv1a};

/// The seeded fault plan (see module docs).
#[derive(Clone, Copy, Debug)]
pub struct ChaosPlan {
    pub seed: u64,
}

/// The faults one run suffers under a plan.
#[derive(Clone, Copy, Debug, Default)]
pub struct RunFaults {
    /// Crash the worker after this many steps of the run's *first*
    /// execution (fencing token 1). `None` = no crash.
    pub crash_after: Option<usize>,
    /// Stop heartbeating during the first execution, letting the lease
    /// expire under a still-running holder.
    pub stall_heartbeat: bool,
    /// Transient I/O faults injected before the row append (0–2; always
    /// below the 4-attempt retry budget).
    pub append_faults: u32,
}

impl ChaosPlan {
    pub fn new(seed: u64) -> Self {
        Self { seed }
    }

    /// The faults for `run_id` (a run of `steps` training steps). Pure
    /// and stateless: every worker, restart, and machine computes the
    /// same plan. Roughly a quarter of training runs crash (at a step in
    /// `[1, steps)` so a remainder always exists to resume), a disjoint
    /// quarter stalls, and a quarter of all runs eats an I/O burst.
    pub fn for_run(&self, run_id: &str, steps: usize) -> RunFaults {
        let h = derive_seed(self.seed, fnv1a(run_id));
        let mut f = RunFaults::default();
        match h % 4 {
            0 if steps >= 2 => f.crash_after = Some(1 + (h >> 8) as usize % (steps - 1)),
            1 => f.stall_heartbeat = true,
            _ => {}
        }
        if (h >> 4) % 4 == 0 {
            f.append_faults = 1 + ((h >> 16) % 2) as u32;
        }
        f
    }

    /// Does this plan crash at least one of the given runs? Lets tests
    /// and tools pick a seed with guaranteed kill coverage instead of
    /// hoping.
    pub fn crashes_any<'a>(&self, runs: impl IntoIterator<Item = (&'a str, usize)>) -> bool {
        runs.into_iter().any(|(id, steps)| self.for_run(id, steps).crash_after.is_some())
    }

    /// The fourth fault family: a deterministic per-worker clock offset
    /// in `[-ttl_ms, +ttl_ms]`, injected through the [`LeaseClock`] seam
    /// (every fleet-path liveness comparison flows through it). ±TTL is
    /// the worst interesting skew — at `+ttl` a worker believes every
    /// fresh lease already expired; at `-ttl` it believes expired leases
    /// are still live — so a fleet that stays correct across this span
    /// has *proved* the margin + logical-confirmation design, not
    /// assumed it.
    ///
    /// [`LeaseClock`]: crate::sched::lease::LeaseClock
    pub fn clock_offset_ms(&self, worker_id: &str, ttl_ms: u64) -> i64 {
        let h = derive_seed(self.seed, fnv1a(worker_id) ^ 0xC10C);
        let ttl = ttl_ms.min(i64::MAX as u64 / 4) as i64;
        let span = (2 * ttl + 1) as u64;
        (h % span) as i64 - ttl
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plans_are_deterministic_and_seed_sensitive() {
        let a = ChaosPlan::new(7).for_run("run-x", 40);
        let b = ChaosPlan::new(7).for_run("run-x", 40);
        assert_eq!(a.crash_after, b.crash_after);
        assert_eq!(a.stall_heartbeat, b.stall_heartbeat);
        assert_eq!(a.append_faults, b.append_faults);
        // different seeds decorrelate across a run population
        let runs: Vec<String> = (0..64).map(|i| format!("run-{i}")).collect();
        let plan = |s: u64| -> Vec<Option<usize>> {
            runs.iter().map(|r| ChaosPlan::new(s).for_run(r, 40).crash_after).collect()
        };
        assert_ne!(plan(1), plan(2));
    }

    #[test]
    fn crash_steps_leave_work_to_resume() {
        for seed in 0..16u64 {
            for i in 0..64 {
                let f = ChaosPlan::new(seed).for_run(&format!("r{i}"), 40);
                if let Some(at) = f.crash_after {
                    assert!((1..40).contains(&at), "crash at {at} leaves no remainder");
                    assert!(!f.stall_heartbeat, "crash and stall are disjoint");
                }
                assert!(f.append_faults <= 2, "bursts stay below the retry budget");
            }
        }
    }

    #[test]
    fn zero_shot_runs_never_crash() {
        for seed in 0..32u64 {
            let f = ChaosPlan::new(seed).for_run("zs", 0);
            assert_eq!(f.crash_after, None);
        }
    }

    #[test]
    fn fault_families_all_occur_across_a_population() {
        let runs: Vec<String> = (0..128).map(|i| format!("run-{i}")).collect();
        let plan = ChaosPlan::new(3);
        let fs: Vec<RunFaults> = runs.iter().map(|r| plan.for_run(r, 40)).collect();
        assert!(fs.iter().any(|f| f.crash_after.is_some()));
        assert!(fs.iter().any(|f| f.stall_heartbeat));
        assert!(fs.iter().any(|f| f.append_faults > 0));
        assert!(fs.iter().any(|f| f.crash_after.is_none() && !f.stall_heartbeat));
        assert!(plan.crashes_any(runs.iter().map(|r| (r.as_str(), 40))));
        assert!(!plan.crashes_any(runs.iter().map(|r| (r.as_str(), 0))));
    }

    #[test]
    fn clock_offsets_are_deterministic_bounded_and_worker_distinct() {
        let plan = ChaosPlan::new(11);
        let ttl = 2_000u64;
        assert_eq!(plan.clock_offset_ms("w0", ttl), plan.clock_offset_ms("w0", ttl));
        let offs: Vec<i64> =
            (0..32).map(|i| plan.clock_offset_ms(&format!("w{i}"), ttl)).collect();
        for &o in &offs {
            assert!((-(ttl as i64)..=ttl as i64).contains(&o), "offset {o} out of ±TTL");
        }
        // workers decorrelate: both signs appear and not all offsets collide
        assert!(offs.iter().any(|&o| o > 0) && offs.iter().any(|&o| o < 0));
        assert!(offs.iter().collect::<std::collections::HashSet<_>>().len() > 16);
        // a zero TTL degenerates to no skew, never a panic
        assert_eq!(plan.clock_offset_ms("w0", 0), 0);
    }
}
