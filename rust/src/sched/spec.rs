//! Declarative sweep specs and their expansion into priced, seeded runs.
//!
//! A sweep is a grid over (optimizer × task × seed × lr × eps) plus the
//! shared run shape (steps, eval budget, data sizes, backend). The spec
//! is a plain config file (the same TOML subset `config.rs` parses):
//!
//! ```toml
//! [sweep]
//! name = "smoke"
//! backend = "mock"          # mock | xla | auto
//! model = "tiny"
//! geometry = "opt-13b"      # memory-pricing geometry
//! steps = 40                # FO step budget; ZO-only methods run zo_mult x
//! zo_mult = 2
//! budget_gb = 60            # per simulated device
//!
//! [grid]
//! optimizers = "addax, mezo, ip-sgd"
//! tasks = "sst2, rte"
//! seeds = "0, 1"
//! lrs = "0.07"              # optional; empty keeps per-optimizer defaults
//! epss = ""                 # optional
//! dtypes = "f32, bf16"      # optional storage precisions (default f32)
//! ```
//!
//! Expansion is a fixed nested iteration (optimizer → task → seed → lr →
//! eps → dtype), so run ids and derived seeds are independent of worker
//! count, resume history, and everything else that varies between
//! invocations. The storage dtype is part of run identity: an f32 and a
//! bf16 cell of the same grid point are distinct runs with distinct
//! train seeds, and the memory model prices each at its own precision.
//! Each run's training seed is `derive_seed(grid_seed, fnv1a(run_id))` —
//! a pure function of the run's identity, so the same logical run
//! requested by two different experiments replays identically (and its
//! manifest row is shared).

use anyhow::{bail, Context, Result};

use crate::config::Config;
use crate::data::{self, TaskDef};
use crate::jsonlite::{obj, Json};
use crate::memory::geometry;
use crate::optim::OptSpec;
use crate::tensor::Dtype;
use crate::zorng::derive_seed;

/// `lt` sentinel: no length partitioning (Addax-WA / single-phase runs).
pub const LT_NONE: usize = usize::MAX;

/// Which execution substrate a run uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// The closed-form quadratic objective (`runtime::mock`) — runs
    /// everywhere, including CI, with no artifacts.
    Mock,
    /// AOT HLO artifacts through PJRT (`runtime::XlaExec`).
    Xla,
}

impl Backend {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "mock" => Backend::Mock,
            "xla" => Backend::Xla,
            "auto" => Backend::auto(),
            other => bail!("unknown backend {other:?} (want mock | xla | auto)"),
        })
    }

    /// `Xla` when AOT artifacts exist on this machine, else `Mock`.
    pub fn auto() -> Self {
        let manifest = crate::runtime::manifest::default_artifacts_dir().join("manifest.json");
        if manifest.exists() {
            Backend::Xla
        } else {
            Backend::Mock
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Backend::Mock => "mock",
            Backend::Xla => "xla",
        }
    }
}

/// FNV-1a over a string — the stable hash behind run-id → seed
/// derivation. Re-exported from its home next to `derive_seed` so the
/// historical `sched::spec::fnv1a` path keeps working.
pub use crate::zorng::fnv1a;

/// Everything needed to execute (and re-execute, identically) one run.
///
/// Construct with [`RunSpec::new`] and adjust fields via struct update,
/// then call [`RunSpec::sealed`] to (re)derive `run_id` and `train_seed`
/// from the other fields. An unsealed spec (empty `run_id`) is rejected
/// by the scheduler.
#[derive(Clone, Debug)]
pub struct RunSpec {
    /// Identity: readable prefix + FNV hash of the full serialized spec.
    pub run_id: String,
    pub backend: Backend,
    /// AOT model key (xla backend); a label only under mock.
    pub model_key: String,
    /// Memory-pricing geometry (`memory::geometry::by_name`).
    pub geometry: String,
    /// Task catalog: "opt" or "roberta" (names overlap between the two).
    pub catalog: String,
    pub task: String,
    pub optimizer: OptSpec,
    /// Parameter-store precision (weights storage; math stays f32).
    /// Part of run identity and of memory pricing.
    pub dtype: Dtype,
    /// Training steps; 0 = evaluation-only (zero-shot).
    pub steps: usize,
    /// The grid's seed coordinate (also the dataset seed).
    pub grid_seed: u64,
    /// Derived training seed: `derive_seed(grid_seed, fnv1a(run_id))`.
    pub train_seed: u64,
    /// Validation cadence; 0 = steps/20 (coordinator default).
    pub eval_every: usize,
    pub eval_examples: usize,
    /// `L_T` partition threshold at run scale; [`LT_NONE`] = none.
    pub lt: usize,
    /// Compute `L_T` at run time as the 60th percentile of training
    /// lengths (the repro's Addax policy for long tasks); overrides `lt`.
    pub lt_auto: bool,
    /// Paper-scale `L_T` used only for memory pricing (0 = 60% of L_max).
    pub price_lt: usize,
    /// Mock-backend problem dimension.
    pub mock_dim: usize,
    pub n_train: usize,
    pub n_val: usize,
    pub n_test: usize,
}

impl RunSpec {
    /// A run with repro-harness defaults; already sealed.
    pub fn new(
        backend: Backend,
        task: &str,
        optimizer: OptSpec,
        steps: usize,
        grid_seed: u64,
    ) -> Self {
        Self {
            run_id: String::new(),
            backend,
            model_key: "tiny".to_string(),
            geometry: "opt-13b".to_string(),
            catalog: "opt".to_string(),
            task: task.to_string(),
            optimizer,
            dtype: Dtype::F32,
            steps,
            grid_seed,
            train_seed: 0,
            eval_every: 0,
            eval_examples: 120,
            lt: LT_NONE,
            lt_auto: false,
            price_lt: 0,
            mock_dim: 48,
            n_train: 1000,
            n_val: 300,
            n_test: 500,
        }
        .sealed()
    }

    /// Re-derive `run_id` and `train_seed` from the identity fields. Call
    /// after changing any field post-construction.
    ///
    /// `geometry` and `price_lt` parameterize memory *pricing* only — they
    /// cannot change a run's outcome — so they are excluded from the
    /// identity: the same logical cell priced at different paper
    /// geometries (table12 vs table13) resolves to one manifest row.
    pub fn sealed(mut self) -> Self {
        self.run_id = String::new();
        self.train_seed = 0;
        let ident = {
            let mut i = self.clone();
            i.geometry = String::new();
            i.price_lt = 0;
            let mut j = i.to_json();
            // The optimizer contributes its *relevant* fields only
            // (`OptSpec::id`), so e.g. an lr grid collapses for zero-shot
            // and `batch` doesn't split addax identities.
            if let Json::Obj(m) = &mut j {
                m.insert("optimizer".to_string(), Json::from(i.optimizer.id()));
            }
            j.dump()
        };
        self.run_id = format!(
            "{}.{}.{}.{}.s{}.t{}.{}.h{:08x}",
            self.backend.label(),
            self.model_key,
            self.task,
            self.optimizer.id(),
            self.grid_seed,
            self.steps,
            self.dtype.label(),
            fnv1a(&ident) as u32,
        );
        self.train_seed = derive_seed(self.grid_seed, fnv1a(&self.run_id));
        self
    }

    /// Per-run checkpoint directory under `root`, derived from the run
    /// id. Run ids are unique by construction (the scheduler dedups on
    /// them), so concurrent workers can never collide on snapshot files
    /// — each run owns its directory outright.
    pub fn ckpt_dir(&self, root: &std::path::Path) -> std::path::PathBuf {
        root.join(&self.run_id)
    }

    /// The task definition this run trains on.
    pub fn task_def(&self) -> Result<&'static TaskDef> {
        let t = match self.catalog.as_str() {
            "roberta" => data::roberta_task(&self.task).or_else(|| data::opt_task(&self.task)),
            _ => data::opt_task(&self.task).or_else(|| data::roberta_task(&self.task)),
        };
        t.with_context(|| format!("unknown task {:?} (catalog {:?})", self.task, self.catalog))
    }

    /// Canonical serialization (embedded in manifest rows). Seeds are
    /// strings (u64 does not fit losslessly in a JSON number); `lt` is
    /// `"none"` or a number-as-string.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("run_id", Json::from(self.run_id.clone())),
            ("backend", Json::from(self.backend.label())),
            ("model", Json::from(self.model_key.clone())),
            ("geometry", Json::from(self.geometry.clone())),
            ("catalog", Json::from(self.catalog.clone())),
            ("task", Json::from(self.task.clone())),
            ("optimizer", self.optimizer.to_json()),
            ("dtype", Json::from(self.dtype.label())),
            ("steps", Json::from(self.steps)),
            ("grid_seed", Json::from(self.grid_seed.to_string())),
            ("train_seed", Json::from(self.train_seed.to_string())),
            ("eval_every", Json::from(self.eval_every)),
            ("eval_examples", Json::from(self.eval_examples)),
            (
                "lt",
                if self.lt == LT_NONE {
                    Json::from("none")
                } else {
                    Json::from(self.lt.to_string())
                },
            ),
            ("lt_auto", Json::from(self.lt_auto)),
            ("price_lt", Json::from(self.price_lt)),
            ("mock_dim", Json::from(self.mock_dim)),
            ("n_train", Json::from(self.n_train)),
            ("n_val", Json::from(self.n_val)),
            ("n_test", Json::from(self.n_test)),
        ])
    }
}

/// A declarative sweep: the grid plus the shared run shape.
#[derive(Clone, Debug)]
pub struct SweepSpec {
    pub name: String,
    pub backend: Backend,
    pub model_key: String,
    pub geometry: String,
    pub catalog: String,
    pub optimizers: Vec<String>,
    pub tasks: Vec<String>,
    pub seeds: Vec<u64>,
    /// Learning-rate grid; empty keeps each optimizer's default.
    pub lrs: Vec<f32>,
    /// SPSA ε grid; empty keeps the default.
    pub epss: Vec<f32>,
    /// Storage-precision grid (`"f32"`/`"bf16"`); default f32 only.
    pub dtypes: Vec<String>,
    pub steps: usize,
    /// ZO-only optimizers run `zo_mult ×` the step budget.
    pub zo_mult: usize,
    pub eval_examples: usize,
    /// Per-device budget used when no `--budget-gb` override is given.
    pub budget_gb: f64,
    pub gpus: usize,
    pub mock_dim: usize,
    pub n_train: usize,
    pub n_val: usize,
    pub n_test: usize,
    /// Addax on long tasks partitions at the 60th length percentile.
    pub lt_auto: bool,
    /// Fleet lease TTL in seconds (`--lease-ttl` overrides). A worker
    /// whose lease goes this long without a heartbeat renewal is
    /// presumed dead and its run reclaimable. Not part of run identity:
    /// TTL shapes *when* work is reclaimed, never what it computes.
    pub lease_ttl_secs: f64,
    /// Cross-node clock-skew allowance in milliseconds
    /// (`--skew-margin-ms` overrides). A lease only *looks* expired once
    /// it is this far past `expires_ms`, and reclaim still requires the
    /// logical quiet-holder confirmation. Like the TTL, not part of run
    /// identity.
    pub skew_margin_ms: u64,
    /// Probe-server port (`[sweep] probe_port`; `--probe-port`
    /// overrides). `None` (the default) keeps the observability plane
    /// off; `Some(0)` binds an ephemeral port. Pure telemetry — like
    /// the TTL, never part of run identity.
    pub probe_port: Option<u16>,
    /// Leak-detector regression window in seconds (`[sweep]
    /// mem_window_secs`; `--mem-window-secs` overrides). The probe's
    /// `/mem` endpoint fits an RSS slope over this much history — widen
    /// it to catch slow creep across a long sweep, narrow it to react
    /// to a fast leak. Telemetry only, never part of run identity.
    pub mem_window_secs: f64,
}

impl SweepSpec {
    /// Parse from the config-file form (sections `[sweep]` and `[grid]`).
    pub fn from_config(cfg: &Config) -> Result<Self> {
        let spec = Self {
            name: cfg.str_or("sweep.name", "sweep"),
            backend: Backend::parse(&cfg.str_or("sweep.backend", "auto"))?,
            model_key: cfg.str_or("sweep.model", "tiny"),
            geometry: cfg.str_or("sweep.geometry", "opt-13b"),
            catalog: cfg.str_or("sweep.catalog", "opt"),
            optimizers: cfg.list_or("grid.optimizers", &["addax", "mezo", "ip-sgd"]),
            tasks: cfg.list_or("grid.tasks", &["sst2"]),
            seeds: cfg.u64_list_or("grid.seeds", &[0])?,
            lrs: cfg.f32_list_or("grid.lrs", &[])?,
            epss: cfg.f32_list_or("grid.epss", &[])?,
            dtypes: cfg.list_or("grid.dtypes", &["f32"]),
            steps: cfg.usize_or("sweep.steps", 100)?,
            zo_mult: cfg.usize_or("sweep.zo_mult", 3)?.max(1),
            eval_examples: cfg.usize_or("sweep.eval_examples", 100)?,
            budget_gb: cfg.f32_or("sweep.budget_gb", 40.0)? as f64,
            gpus: cfg.usize_or("sweep.gpus", 1)?.max(1),
            mock_dim: cfg.usize_or("sweep.mock_dim", 48)?,
            n_train: cfg.usize_or("sweep.train", 1000)?,
            n_val: cfg.usize_or("sweep.val", 300)?,
            n_test: cfg.usize_or("sweep.test", 500)?,
            lt_auto: cfg.bool_or("sweep.lt_auto", true)?,
            lease_ttl_secs: cfg.f32_or("sweep.lease_ttl_secs", 30.0)? as f64,
            skew_margin_ms: cfg.f32_or("sweep.skew_margin_ms", 250.0)? as u64,
            // Negative sentinel = absent: the config layer has no
            // Option-valued accessor, and 0 is a meaningful port
            // ("pick an ephemeral one").
            probe_port: match cfg.f32_or("sweep.probe_port", -1.0)? {
                p if p < 0.0 => None,
                p if p <= u16::MAX as f32 => Some(p as u16),
                p => bail!("sweep.probe_port {p} out of range (0-65535)"),
            },
            mem_window_secs: match cfg.f32_or(
                "sweep.mem_window_secs",
                crate::obs::http::DEFAULT_MEM_WINDOW_SECS as f32,
            )? {
                w if w > 0.0 => w as f64,
                w => bail!("sweep.mem_window_secs {w} must be positive"),
            },
        };
        // Fail early on anything the executor would reject mid-sweep.
        geometry::by_name(&spec.geometry)
            .with_context(|| format!("unknown geometry {:?}", spec.geometry))?;
        for name in &spec.optimizers {
            OptSpec::named(name).build()?;
        }
        for d in &spec.dtypes {
            Dtype::parse(d)?;
        }
        for task in &spec.tasks {
            let found = match spec.catalog.as_str() {
                "roberta" => data::roberta_task(task).is_some(),
                _ => data::opt_task(task).is_some(),
            };
            if !found {
                bail!("unknown task {task:?} in catalog {:?}", spec.catalog);
            }
        }
        if spec.optimizers.is_empty() || spec.tasks.is_empty() || spec.seeds.is_empty() {
            bail!("empty sweep grid (need ≥1 optimizer, task and seed)");
        }
        if spec.dtypes.is_empty() {
            bail!("empty dtype grid (want e.g. \"f32\" or \"f32, bf16\")");
        }
        Ok(spec)
    }

    /// Expand the grid in fixed order (optimizer → task → seed → lr →
    /// eps → dtype), deduplicated by run id (e.g. zero-shot ignores the
    /// lr grid).
    pub fn expand(&self) -> Result<Vec<RunSpec>> {
        let lrs: Vec<Option<f32>> = if self.lrs.is_empty() {
            vec![None]
        } else {
            self.lrs.iter().copied().map(Some).collect()
        };
        let epss: Vec<Option<f32>> = if self.epss.is_empty() {
            vec![None]
        } else {
            self.epss.iter().copied().map(Some).collect()
        };
        let dtypes: Vec<Dtype> = self
            .dtypes
            .iter()
            .map(|d| Dtype::parse(d))
            .collect::<Result<_>>()?;
        let mut out: Vec<RunSpec> = Vec::new();
        let mut seen = std::collections::BTreeSet::new();
        for opt_name in &self.optimizers {
            for task in &self.tasks {
                for &seed in &self.seeds {
                    for &lr in &lrs {
                        for &eps in &epss {
                            for &dtype in &dtypes {
                                let mut o = OptSpec::named(opt_name);
                                if let Some(lr) = lr {
                                    o.lr = lr;
                                }
                                if let Some(eps) = eps {
                                    o.eps = eps;
                                }
                                let steps = if opt_name == "zero-shot" {
                                    0
                                } else if o.is_zo_only() {
                                    self.steps * self.zo_mult
                                } else {
                                    self.steps
                                };
                                let task_def = match self.catalog.as_str() {
                                    "roberta" => data::roberta_task(task),
                                    _ => data::opt_task(task),
                                }
                                .expect("validated in from_config");
                                let mut r = RunSpec::new(self.backend, task, o, steps, seed);
                                r.model_key = self.model_key.clone();
                                r.geometry = self.geometry.clone();
                                r.catalog = self.catalog.clone();
                                r.dtype = dtype;
                                r.eval_examples = self.eval_examples;
                                r.lt_auto =
                                    self.lt_auto && opt_name == "addax" && task_def.long;
                                r.mock_dim = self.mock_dim;
                                r.n_train = self.n_train;
                                r.n_val = self.n_val;
                                r.n_test = self.n_test;
                                let r = r.sealed();
                                if seen.insert(r.run_id.clone()) {
                                    out.push(r);
                                }
                            }
                        }
                    }
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn smoke() -> SweepSpec {
        let cfg = Config::parse(
            "[sweep]\nbackend = \"mock\"\nsteps = 40\nzo_mult = 2\n\
             [grid]\noptimizers = \"addax,mezo,ip-sgd\"\ntasks = \"sst2,rte\"\nseeds = \"0,1\"",
        )
        .unwrap();
        SweepSpec::from_config(&cfg).unwrap()
    }

    #[test]
    fn expansion_is_the_grid_product() {
        let specs = smoke().expand().unwrap();
        assert_eq!(specs.len(), 3 * 2 * 2);
        let ids: std::collections::BTreeSet<_> = specs.iter().map(|s| s.run_id.clone()).collect();
        assert_eq!(ids.len(), specs.len(), "run ids must be unique");
        // ZO-only optimizers get the multiplied step budget
        for s in &specs {
            let want = if s.optimizer.is_zo_only() { 80 } else { 40 };
            assert_eq!(s.steps, want, "{}", s.run_id);
        }
    }

    #[test]
    fn expansion_order_and_seeds_are_stable() {
        let a = smoke().expand().unwrap();
        let b = smoke().expand().unwrap();
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.run_id, y.run_id);
            assert_eq!(x.train_seed, y.train_seed);
        }
        // train seeds are spread (derive_seed over distinct ids)
        let seeds: std::collections::BTreeSet<_> = a.iter().map(|s| s.train_seed).collect();
        assert_eq!(seeds.len(), a.len());
    }

    #[test]
    fn probe_port_knob_defaults_off_and_validates_range() {
        assert_eq!(smoke().probe_port, None, "observability is opt-in");
        let on = |line: &str| {
            Config::parse(&format!("[sweep]\nbackend = \"mock\"\n{line}"))
                .and_then(|c| SweepSpec::from_config(&c))
        };
        assert_eq!(on("probe_port = 0").unwrap().probe_port, Some(0), "0 = ephemeral");
        assert_eq!(on("probe_port = 8791").unwrap().probe_port, Some(8791));
        assert!(on("probe_port = 70000").is_err(), "beyond u16 must fail early");
    }

    #[test]
    fn mem_window_knob_defaults_to_the_probe_window_and_rejects_nonpositive() {
        assert_eq!(smoke().mem_window_secs, crate::obs::http::DEFAULT_MEM_WINDOW_SECS);
        let on = |line: &str| {
            Config::parse(&format!("[sweep]\nbackend = \"mock\"\n{line}"))
                .and_then(|c| SweepSpec::from_config(&c))
        };
        assert_eq!(on("mem_window_secs = 30").unwrap().mem_window_secs, 30.0);
        assert!(on("mem_window_secs = 0").is_err(), "zero-width window is meaningless");
        assert!(on("mem_window_secs = -5").is_err());
    }

    #[test]
    fn sealed_tracks_field_changes() {
        let base = RunSpec::new(Backend::Mock, "sst2", OptSpec::named("addax"), 40, 0);
        let mut changed = base.clone();
        changed.eval_examples = 7;
        let changed = changed.sealed();
        assert_ne!(base.run_id, changed.run_id, "identity must cover eval_examples");
        assert_ne!(base.train_seed, changed.train_seed);
        // sealing twice is a fixpoint
        let again = changed.clone().sealed();
        assert_eq!(again.run_id, changed.run_id);
        assert_eq!(again.train_seed, changed.train_seed);
    }

    #[test]
    fn pricing_fields_are_not_identity() {
        // geometry/price_lt steer packing, not outcomes: the same logical
        // cell priced for different paper devices is one run.
        let base = RunSpec::new(Backend::Mock, "sst2", OptSpec::named("addax"), 40, 0);
        let mut priced = base.clone();
        priced.geometry = "opt-66b".to_string();
        priced.price_lt = 260;
        let priced = priced.sealed();
        assert_eq!(base.run_id, priced.run_id);
        assert_eq!(base.train_seed, priced.train_seed);
    }

    #[test]
    fn dtype_is_run_identity() {
        let base = RunSpec::new(Backend::Mock, "sst2", OptSpec::named("addax"), 40, 0);
        assert_eq!(base.dtype, Dtype::F32);
        assert!(base.run_id.contains(".f32."), "{}", base.run_id);
        let mut half = base.clone();
        half.dtype = Dtype::Bf16;
        let half = half.sealed();
        assert!(half.run_id.contains(".bf16."), "{}", half.run_id);
        assert_ne!(base.run_id, half.run_id, "dtype must split run identity");
        assert_ne!(base.train_seed, half.train_seed);
    }

    #[test]
    fn dtype_grid_doubles_the_expansion() {
        let cfg = Config::parse(
            "[sweep]\nbackend = \"mock\"\nsteps = 10\n\
             [grid]\noptimizers = \"mezo, ip-sgd\"\ntasks = \"sst2\"\nseeds = \"0\"\n\
             dtypes = \"f32, bf16\"",
        )
        .unwrap();
        let specs = SweepSpec::from_config(&cfg).unwrap().expand().unwrap();
        assert_eq!(specs.len(), 2 * 2);
        let (f32s, bf16s): (Vec<_>, Vec<_>) =
            specs.iter().partition(|s| s.dtype == Dtype::F32);
        assert_eq!(f32s.len(), 2);
        assert_eq!(bf16s.len(), 2);
        // bad dtype fails validation up front
        let bad = Config::parse("[grid]\ndtypes = \"fp16\"").unwrap();
        assert!(SweepSpec::from_config(&bad).is_err());
    }

    #[test]
    fn zero_shot_dedups_across_lr_grid() {
        let cfg = Config::parse(
            "[sweep]\nbackend = \"mock\"\n[grid]\noptimizers = \"zero-shot\"\n\
             tasks = \"sst2\"\nseeds = \"0\"\nlrs = \"0.1,0.2,0.3\"",
        )
        .unwrap();
        let specs = SweepSpec::from_config(&cfg).unwrap().expand().unwrap();
        assert_eq!(specs.len(), 1, "zero-shot ignores lr, so the grid collapses");
        assert_eq!(specs[0].steps, 0);
    }

    #[test]
    fn from_config_validates_early() {
        for bad in [
            "[sweep]\ngeometry = \"gpt-5\"",
            "[grid]\noptimizers = \"nope\"",
            "[grid]\ntasks = \"nope\"",
            "[grid]\nseeds = \"\"\n[sweep]\nbackend = \"mock\"",
            "[sweep]\nbackend = \"quantum\"",
        ] {
            let cfg = Config::parse(bad).unwrap();
            if bad.contains("seeds") {
                // empty seeds list falls back to the default [0] — fine
                assert!(SweepSpec::from_config(&cfg).is_ok());
            } else {
                assert!(SweepSpec::from_config(&cfg).is_err(), "{bad}");
            }
        }
    }

    #[test]
    fn ckpt_dirs_are_disjoint_per_run() {
        let root = std::path::Path::new("results/sweep/ckpt");
        let a = RunSpec::new(Backend::Mock, "sst2", OptSpec::named("addax"), 40, 0);
        let mut b = a.clone();
        b.dtype = Dtype::Bf16;
        let b = b.sealed();
        assert_ne!(a.ckpt_dir(root), b.ckpt_dir(root), "distinct runs, distinct dirs");
        assert_eq!(a.ckpt_dir(root), a.clone().sealed().ckpt_dir(root), "stable per run");
        assert!(a.ckpt_dir(root).starts_with(root));
    }

    #[test]
    fn task_catalog_disambiguates() {
        let mut r = RunSpec::new(Backend::Mock, "snli", OptSpec::named("mezo"), 10, 0);
        r.catalog = "roberta".to_string();
        let r = r.sealed();
        assert_eq!(r.task_def().unwrap().name, "snli");
        let opt_only = RunSpec::new(Backend::Mock, "squad", OptSpec::named("mezo"), 10, 0);
        assert_eq!(opt_only.task_def().unwrap().name, "squad");
    }
}
