//! The sweep executor: waves of runs on a scoped worker pool, one
//! manifest writer.
//!
//! Control flow per `run_sweep` call:
//!
//! 1. load the manifest; drop every spec whose run id is already present
//!    (skip-completed — this is what `--resume` resumes);
//! 2. price + pack the remaining runs into waves (`pack.rs`);
//! 3. per wave, spawn up to `workers` scoped threads that pull runs off a
//!    shared counter and send finished rows over a channel; the main
//!    thread is the only manifest writer (crash-safe appends);
//! 4. compact the manifest into canonical order.
//!
//! Determinism: every run is executed with a single in-run noise worker
//! (parallelism lives *across* runs), seeds derive from run identity, and
//! rows carry no wall-clock — so the compacted manifest is byte-identical
//! for the same spec at any `--workers`, across kills/resumes, and across
//! machines (per backend).

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::coordinator::{evaluate, train, TrainConfig};
use crate::data::Dataset;
use crate::params::ParamStore;
use crate::runtime::manifest::default_artifacts_dir;
use crate::runtime::mock::QuadraticExec;
use crate::runtime::{ModelExec, XlaExec};
use crate::zorng::derive_seed;

use super::manifest::{ManifestRow, SweepManifest};
use super::pack::pack;
use super::spec::{Backend, RunSpec};

/// Scheduler knobs (the `sweep` subcommand's flags).
#[derive(Clone, Debug)]
pub struct SweepOptions {
    /// Per simulated device, in GB.
    pub budget_gb: f64,
    /// Simulated device count; the packing budget is `budget_gb × gpus`.
    pub gpus: usize,
    /// Concurrent runs per wave.
    pub workers: usize,
    /// Skip runs already in the manifest. Without it, an existing
    /// non-empty manifest is an error (no silent clobbering).
    pub resume: bool,
    pub manifest_path: std::path::PathBuf,
    /// Print the packing plan and per-run completions.
    pub verbose: bool,
}

impl Default for SweepOptions {
    fn default() -> Self {
        Self {
            budget_gb: 40.0,
            gpus: 1,
            workers: 4,
            resume: true,
            manifest_path: std::path::PathBuf::from("results/sweep/manifest.jsonl"),
            verbose: false,
        }
    }
}

/// What a sweep did.
#[derive(Clone, Debug)]
pub struct SweepSummary {
    pub total: usize,
    pub executed: usize,
    pub skipped: usize,
    pub waves: usize,
    pub manifest_path: std::path::PathBuf,
}

impl SweepSummary {
    /// Stable one-line form (CI greps `executed=`).
    pub fn line(&self) -> String {
        format!(
            "sweep: total={} executed={} skipped={} waves={} manifest={}",
            self.total,
            self.executed,
            self.skipped,
            self.waves,
            self.manifest_path.display()
        )
    }
}

/// Execute `specs` under `opts`. See module docs for the contract.
pub fn run_sweep(specs: Vec<RunSpec>, opts: &SweepOptions) -> Result<SweepSummary> {
    run_sweep_collect(specs, opts).map(|(summary, _)| summary)
}

/// [`run_sweep`] returning the post-sweep manifest as well, so callers
/// that aggregate rows (the repro harness) skip a full re-load/re-parse
/// of the file they just wrote.
pub fn run_sweep_collect(
    specs: Vec<RunSpec>,
    opts: &SweepOptions,
) -> Result<(SweepSummary, SweepManifest)> {
    if opts.workers == 0 {
        bail!("--workers must be ≥ 1");
    }
    // Dedup by run id, first occurrence wins (different experiments may
    // request the same logical run; it executes once).
    let mut deduped: Vec<RunSpec> = Vec::with_capacity(specs.len());
    {
        let mut seen = std::collections::BTreeSet::new();
        for s in specs {
            if s.run_id.is_empty() {
                bail!("unsealed RunSpec (empty run_id) — call RunSpec::sealed()");
            }
            if seen.insert(s.run_id.clone()) {
                deduped.push(s);
            }
        }
    }
    let total = deduped.len();

    let mut manifest = SweepManifest::load(&opts.manifest_path)?;
    if !opts.resume && !manifest.is_empty() {
        bail!(
            "manifest {} already holds {} runs — pass --resume to skip \
             completed runs, or remove the file to start fresh",
            opts.manifest_path.display(),
            manifest.len()
        );
    }
    let pending: Vec<RunSpec> =
        deduped.into_iter().filter(|s| !manifest.contains(&s.run_id)).collect();
    let skipped = total - pending.len();

    let budget_bytes = opts.budget_gb * 1e9 * opts.gpus as f64;
    let waves = pack(pending, budget_bytes)?;
    let n_waves = waves.len();
    if opts.verbose {
        println!(
            "[sweep] {} runs pending ({} skipped) in {} wave(s) under {:.0} GB",
            total - skipped,
            skipped,
            n_waves,
            budget_bytes / 1e9
        );
    }

    let mut executed = 0usize;
    for (wi, wave) in waves.into_iter().enumerate() {
        if opts.verbose {
            println!(
                "[sweep] wave {}/{}: {} run(s), {:.1}/{:.0} GB",
                wi + 1,
                n_waves,
                wave.runs.len(),
                wave.bytes / 1e9,
                budget_bytes / 1e9
            );
        }
        let runs: Vec<RunSpec> = wave.runs.into_iter().map(|p| p.spec).collect();
        let n_workers = opts.workers.min(runs.len()).max(1);
        let next = AtomicUsize::new(0);
        let stop = AtomicBool::new(false);
        let mut first_err: Option<anyhow::Error> = None;

        std::thread::scope(|scope| {
            let (tx, rx) = mpsc::channel::<(String, Result<(ManifestRow, RunTiming)>)>();
            let runs_ref = &runs;
            let next_ref = &next;
            let stop_ref = &stop;
            for _ in 0..n_workers {
                let tx = tx.clone();
                scope.spawn(move || loop {
                    if stop_ref.load(Ordering::Relaxed) {
                        break;
                    }
                    let i = next_ref.fetch_add(1, Ordering::SeqCst);
                    if i >= runs_ref.len() {
                        break;
                    }
                    let spec = &runs_ref[i];
                    let res = execute_run(spec);
                    if tx.send((spec.run_id.clone(), res)).is_err() {
                        break;
                    }
                });
            }
            drop(tx);
            for (run_id, res) in rx {
                match res {
                    Ok((row, timing)) => {
                        if let Err(e) = manifest.append(row) {
                            stop.store(true, Ordering::Relaxed);
                            first_err.get_or_insert(e);
                            continue;
                        }
                        SweepManifest::append_time(
                            &opts.manifest_path,
                            &run_id,
                            timing.total_secs,
                            timing.time_to_best_secs,
                        )
                        .ok();
                        executed += 1;
                        if opts.verbose {
                            println!("[sweep]   done {} ({:.1}s)", run_id, timing.total_secs);
                        }
                    }
                    Err(e) => {
                        stop.store(true, Ordering::Relaxed);
                        first_err.get_or_insert(e.context(format!("run {run_id} failed")));
                    }
                }
            }
        });
        if let Some(e) = first_err {
            // Completed rows are already on disk — the sweep is resumable
            // from exactly this point.
            return Err(e);
        }
    }

    manifest.compact()?;
    let summary = SweepSummary {
        total,
        executed,
        skipped,
        waves: n_waves,
        manifest_path: opts.manifest_path.clone(),
    };
    Ok((summary, manifest))
}

/// Wall-clock telemetry for the side file (never enters the manifest).
pub struct RunTiming {
    pub total_secs: f64,
    pub time_to_best_secs: f64,
}

/// Execute one run on its backend and produce its manifest row.
///
/// Re-entrant: all state (executor, params, dataset, optimizer) is built
/// inside the call, nothing is printed, and the in-run noise pool is
/// pinned to one worker so run-level parallelism composes with it. The
/// parameter store is allocated at the spec's storage dtype (the AOT
/// dumps are f32 and are rounded nearest-even on load for bf16 runs).
pub fn execute_run(spec: &RunSpec) -> Result<(ManifestRow, RunTiming)> {
    match spec.backend {
        Backend::Mock => {
            let mut exec = QuadraticExec::new(
                spec.mock_dim,
                0.5,
                2.0,
                0.1,
                derive_seed(spec.grid_seed, 0xACE),
            );
            let mut params =
                ParamStore::zeros_in(&[("w".to_string(), vec![spec.mock_dim])], spec.dtype);
            run_with_exec(spec, &mut exec, &mut params, 512, 64)
        }
        Backend::Xla => {
            let mut exec = XlaExec::new(&default_artifacts_dir(), &spec.model_key)?;
            let entry = exec.entry().clone();
            let mut params = exec.load_initial_params()?.to_dtype(spec.dtype);
            run_with_exec(spec, &mut exec, &mut params, entry.vocab, entry.max_len)
        }
    }
}

fn run_with_exec(
    spec: &RunSpec,
    exec: &mut dyn ModelExec,
    params: &mut ParamStore,
    vocab: usize,
    max_len: usize,
) -> Result<(ManifestRow, RunTiming)> {
    let task = spec.task_def()?;
    let ds = Dataset::generate(
        task,
        vocab,
        Some(max_len),
        spec.grid_seed,
        spec.n_train,
        spec.n_val,
        spec.n_test,
    );
    if spec.steps == 0 {
        // Zero-shot: evaluation only, no training loop. The budget is
        // exactly `eval_examples` — no silent clamp, since that field is
        // part of run identity and must actually steer the outcome.
        let t0 = Instant::now();
        let ev = evaluate(exec, params, &ds.test, spec.eval_examples)?;
        return Ok((
            ManifestRow::from_eval(spec, &ev),
            RunTiming { total_secs: t0.elapsed().as_secs_f64(), time_to_best_secs: 0.0 },
        ));
    }
    // `LT_NONE` is usize::MAX, which `partition` already treats as "no
    // partitioning", so `spec.lt` passes straight through.
    let lt = if spec.lt_auto {
        // Addax on long tasks: partition at the 60th length percentile of
        // the (deterministic) training split — the repro's L_T policy.
        let mut lens: Vec<usize> = ds.train.iter().map(|e| e.context.len() + 1).collect();
        lens.sort_unstable();
        lens[lens.len() * 6 / 10]
    } else {
        spec.lt
    };
    let cfg = TrainConfig {
        steps: spec.steps,
        eval_every: spec.eval_every,
        seed: spec.train_seed,
        eval_examples: spec.eval_examples,
        log_path: None,
        verbose: false,
        // One in-run noise worker: the sweep parallelizes across runs,
        // so in-run pools would only oversubscribe the host. The pin is
        // per-store (no process global), so concurrent runs with
        // different settings could coexist — the scheduler just has no
        // reason to want them.
        noise_workers: 1,
    };
    let mut opt = spec.optimizer.build()?;
    let r = train(exec, params, &mut *opt, &ds, lt, &cfg)
        .with_context(|| format!("training {}", spec.run_id))?;
    let timing = RunTiming { total_secs: r.total_secs, time_to_best_secs: r.time_to_best_secs };
    Ok((ManifestRow::from_train(spec, &r), timing))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::OptSpec;

    #[test]
    fn execute_run_is_deterministic() {
        let spec = {
            let mut s = RunSpec::new(Backend::Mock, "sst2", OptSpec::named("addax"), 15, 3);
            s.eval_examples = 30;
            s.n_train = 120;
            s.n_val = 40;
            s.n_test = 40;
            s.sealed()
        };
        let (a, _) = execute_run(&spec).unwrap();
        let (b, _) = execute_run(&spec).unwrap();
        assert_eq!(a.to_line(), b.to_line());
        assert_eq!(a.outcome.loss_curve.points.len(), 15);
    }

    #[test]
    fn execute_run_is_deterministic_at_bf16() {
        // The tentpole contract at the run level: a bf16 cell reproduces
        // its manifest row exactly, and it differs from its f32 twin only
        // through the declared dtype (distinct run id).
        let mk = |dtype| {
            let mut s = RunSpec::new(Backend::Mock, "sst2", OptSpec::named("mezo"), 10, 3);
            s.dtype = dtype;
            s.eval_examples = 30;
            s.n_train = 120;
            s.n_val = 40;
            s.n_test = 40;
            s.sealed()
        };
        let spec = mk(crate::tensor::Dtype::Bf16);
        let (a, _) = execute_run(&spec).unwrap();
        let (b, _) = execute_run(&spec).unwrap();
        assert_eq!(a.to_line(), b.to_line());
        assert_ne!(spec.run_id, mk(crate::tensor::Dtype::F32).run_id);
    }

    #[test]
    fn zero_shot_runs_eval_only() {
        let mut s = RunSpec::new(Backend::Mock, "sst2", OptSpec::named("zero-shot"), 0, 1);
        s.n_test = 60;
        s.eval_examples = 50;
        let s = s.sealed();
        let (row, _) = execute_run(&s).unwrap();
        assert_eq!(row.outcome.kind, "eval");
        assert_eq!(row.outcome.steps, 0);
        assert!(row.outcome.loss_curve.points.is_empty());
        assert!(row.outcome.test_acc > 0.0);
    }

    #[test]
    fn unsealed_spec_is_rejected() {
        let mut s = RunSpec::new(Backend::Mock, "sst2", OptSpec::named("mezo"), 5, 0);
        s.run_id = String::new();
        let err = run_sweep(vec![s], &SweepOptions::default()).unwrap_err();
        assert!(format!("{err}").contains("unsealed"));
    }
}
