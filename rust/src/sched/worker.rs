//! The sweep executor: waves of runs on a scoped worker pool, one
//! manifest writer.
//!
//! Control flow per `run_sweep` call:
//!
//! 1. load the manifest; drop every spec whose run id is already present
//!    (skip-completed — this is what `--resume` resumes);
//! 2. price + pack the remaining runs into waves (`pack.rs`);
//! 3. per wave, spawn up to `workers` scoped threads that pull runs off a
//!    shared counter and send finished rows over a channel; the main
//!    thread is the only manifest writer (crash-safe appends);
//! 4. compact the manifest into canonical order.
//!
//! Resume is **step-level**: each run checkpoints into its own directory
//! (`<manifest dir>/ckpt/<run_id>/`, via the `ckpt` subsystem), so a run
//! killed mid-flight continues from its latest valid snapshot instead of
//! restarting — and lands on the *byte-identical* manifest row and
//! parameter dump. A completed run's checkpoint directory is deleted once
//! its row is safely appended (the manifest row is then the durable
//! record). The times side file gains `resumed_from_step` / `note`
//! telemetry for resumed or degraded (corrupt-snapshot) runs.
//!
//! Determinism: every run is executed with a single in-run noise worker
//! (parallelism lives *across* runs), seeds derive from run identity, and
//! rows carry no wall-clock — so the compacted manifest is byte-identical
//! for the same spec at any `--workers`, across kills/resumes (run- or
//! step-level), and across machines (per backend).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::coordinator::{evaluate, train, Halted, TrainConfig};
use crate::data::Dataset;
use crate::params::ParamStore;
use crate::runtime::manifest::default_artifacts_dir;
use crate::runtime::mock::QuadraticExec;
use crate::runtime::{ModelExec, XlaExec};
use crate::zorng::derive_seed;

use super::manifest::{ManifestRow, SweepManifest};
use super::pack::pack;
use super::spec::{Backend, RunSpec};

/// Scheduler knobs (the `sweep` subcommand's flags).
#[derive(Clone, Debug)]
pub struct SweepOptions {
    /// Per simulated device, in GB.
    pub budget_gb: f64,
    /// Simulated device count; the packing budget is `budget_gb × gpus`.
    pub gpus: usize,
    /// Concurrent runs per wave.
    pub workers: usize,
    /// Skip runs already in the manifest. Without it, an existing
    /// non-empty manifest is an error (no silent clobbering).
    pub resume: bool,
    pub manifest_path: std::path::PathBuf,
    /// Print the packing plan and per-run completions.
    pub verbose: bool,
    /// Step-level checkpointing for every run (on by default): snapshots
    /// land in `<manifest dir>/ckpt/<run_id>/` and a partially complete
    /// run resumes from its latest valid one instead of restarting.
    pub ckpt: bool,
    /// Per-run snapshot cadence in steps; 0 = the run's eval cadence.
    pub ckpt_every: usize,
    /// Keep-last-K snapshots per run (best-referenced ones always kept).
    pub ckpt_keep: usize,
    /// Deterministic preemption: halt every run after this many steps
    /// this invocation (0 = never). Runs halt *after* snapshotting, so a
    /// follow-up `--resume` sweep finishes them step-level — the CI
    /// mid-run-kill proof. A real SIGKILL leaves equivalent on-disk
    /// state (snapshot writes are atomic).
    pub halt_after: usize,
    /// Dump each completed run's final parameters (native dtype, the
    /// `save_bin` format) to `<manifest dir>/params/<run_id>.bin` — what
    /// CI byte-compares between killed+resumed and uninterrupted sweeps.
    pub dump_params: bool,
}

impl Default for SweepOptions {
    fn default() -> Self {
        Self {
            budget_gb: 40.0,
            gpus: 1,
            workers: 4,
            resume: true,
            manifest_path: std::path::PathBuf::from("results/sweep/manifest.jsonl"),
            verbose: false,
            ckpt: true,
            ckpt_every: 0,
            ckpt_keep: 2,
            halt_after: 0,
            dump_params: false,
        }
    }
}

impl SweepOptions {
    /// Root of the per-run checkpoint directories.
    pub fn ckpt_root(&self) -> PathBuf {
        self.manifest_dir().join("ckpt")
    }

    /// Directory for final-parameter dumps.
    pub fn params_dir(&self) -> PathBuf {
        self.manifest_dir().join("params")
    }

    fn manifest_dir(&self) -> PathBuf {
        match self.manifest_path.parent() {
            Some(p) if p.as_os_str().is_empty() => PathBuf::from("."),
            Some(p) => p.to_path_buf(),
            None => PathBuf::from("."),
        }
    }
}

/// What a sweep did.
#[derive(Clone, Debug)]
pub struct SweepSummary {
    pub total: usize,
    pub executed: usize,
    pub skipped: usize,
    /// Runs preempted by `halt_after` (checkpointed, not completed — a
    /// later `--resume` sweep finishes them step-level).
    pub halted: usize,
    pub waves: usize,
    pub manifest_path: std::path::PathBuf,
}

impl SweepSummary {
    /// Stable one-line form (CI greps `executed=` and `halted=`).
    pub fn line(&self) -> String {
        format!(
            "sweep: total={} executed={} skipped={} halted={} waves={} manifest={}",
            self.total,
            self.executed,
            self.skipped,
            self.halted,
            self.waves,
            self.manifest_path.display()
        )
    }
}

/// Execute `specs` under `opts`. See module docs for the contract.
pub fn run_sweep(specs: Vec<RunSpec>, opts: &SweepOptions) -> Result<SweepSummary> {
    run_sweep_collect(specs, opts).map(|(summary, _)| summary)
}

/// [`run_sweep`] returning the post-sweep manifest as well, so callers
/// that aggregate rows (the repro harness) skip a full re-load/re-parse
/// of the file they just wrote.
pub fn run_sweep_collect(
    specs: Vec<RunSpec>,
    opts: &SweepOptions,
) -> Result<(SweepSummary, SweepManifest)> {
    if opts.workers == 0 {
        bail!("--workers must be ≥ 1");
    }
    if opts.halt_after > 0 && !opts.ckpt {
        // Without snapshots a halted run restarts from step 0 every
        // resume and halts again at the same step — the sweep could never
        // finish. Refuse the combination instead of looping forever.
        bail!("--halt-after needs checkpointing (drop --no-ckpt)");
    }
    // Dedup by run id, first occurrence wins (different experiments may
    // request the same logical run; it executes once).
    let mut deduped: Vec<RunSpec> = Vec::with_capacity(specs.len());
    {
        let mut seen = std::collections::BTreeSet::new();
        for s in specs {
            if s.run_id.is_empty() {
                bail!("unsealed RunSpec (empty run_id) — call RunSpec::sealed()");
            }
            if seen.insert(s.run_id.clone()) {
                deduped.push(s);
            }
        }
    }
    let total = deduped.len();

    let mut manifest = SweepManifest::load(&opts.manifest_path)?;
    if !opts.resume && !manifest.is_empty() {
        bail!(
            "manifest {} already holds {} runs — pass --resume to skip \
             completed runs, or remove the file to start fresh",
            opts.manifest_path.display(),
            manifest.len()
        );
    }
    let ckpt_root = opts.ckpt_root();
    let mut pending: Vec<RunSpec> = Vec::with_capacity(deduped.len());
    for s in deduped {
        if manifest.contains(&s.run_id) {
            // Completed in some earlier invocation. Its checkpoints are
            // dead weight — and if a kill landed between the row append
            // and the in-flight cleanup, this is the only path that ever
            // reclaims them.
            if opts.ckpt {
                std::fs::remove_dir_all(s.ckpt_dir(&ckpt_root)).ok();
            }
        } else {
            pending.push(s);
        }
    }
    let skipped = total - pending.len();

    let budget_bytes = opts.budget_gb * 1e9 * opts.gpus as f64;
    let waves = pack(pending, budget_bytes)?;
    let n_waves = waves.len();
    if opts.verbose {
        println!(
            "[sweep] {} runs pending ({} skipped) in {} wave(s) under {:.0} GB",
            total - skipped,
            skipped,
            n_waves,
            budget_bytes / 1e9
        );
    }

    let params_dir = opts.params_dir();
    let mut executed = 0usize;
    let mut halted = 0usize;
    for (wi, wave) in waves.into_iter().enumerate() {
        if opts.verbose {
            println!(
                "[sweep] wave {}/{}: {} run(s), {:.1}/{:.0} GB",
                wi + 1,
                n_waves,
                wave.runs.len(),
                wave.bytes / 1e9,
                budget_bytes / 1e9
            );
        }
        let runs: Vec<RunSpec> = wave.runs.into_iter().map(|p| p.spec).collect();
        let n_workers = opts.workers.min(runs.len()).max(1);
        let next = AtomicUsize::new(0);
        let stop = AtomicBool::new(false);
        let mut first_err: Option<anyhow::Error> = None;

        std::thread::scope(|scope| {
            let (tx, rx) = mpsc::channel::<(String, Result<(ManifestRow, RunTiming)>)>();
            let runs_ref = &runs;
            let next_ref = &next;
            let stop_ref = &stop;
            let ckpt_root_ref = &ckpt_root;
            let params_dir_ref = &params_dir;
            for _ in 0..n_workers {
                let tx = tx.clone();
                scope.spawn(move || loop {
                    if stop_ref.load(Ordering::Relaxed) {
                        break;
                    }
                    let i = next_ref.fetch_add(1, Ordering::SeqCst);
                    if i >= runs_ref.len() {
                        break;
                    }
                    let spec = &runs_ref[i];
                    let ctx = RunCtx {
                        ckpt_dir: opts.ckpt.then(|| spec.ckpt_dir(ckpt_root_ref)),
                        ckpt_every: opts.ckpt_every,
                        ckpt_keep: opts.ckpt_keep,
                        halt_after: opts.halt_after,
                        dump_path: opts
                            .dump_params
                            .then(|| params_dir_ref.join(format!("{}.bin", spec.run_id))),
                    };
                    let res = execute_run_with(spec, &ctx);
                    if tx.send((spec.run_id.clone(), res)).is_err() {
                        break;
                    }
                });
            }
            drop(tx);
            for (run_id, res) in rx {
                match res {
                    Ok((row, timing)) => {
                        if let Err(e) = manifest.append(row) {
                            stop.store(true, Ordering::Relaxed);
                            first_err.get_or_insert(e);
                            continue;
                        }
                        SweepManifest::append_time(
                            &opts.manifest_path,
                            &run_id,
                            timing.total_secs,
                            timing.time_to_best_secs,
                            timing.resumed_from_step,
                            timing.note.as_deref(),
                        )
                        .ok();
                        // The row is durable: the run's checkpoints have
                        // served their purpose.
                        if opts.ckpt {
                            std::fs::remove_dir_all(ckpt_root.join(&run_id)).ok();
                        }
                        executed += 1;
                        if opts.verbose {
                            match timing.resumed_from_step {
                                Some(s) => println!(
                                    "[sweep]   done {} ({:.1}s, resumed from step {s})",
                                    run_id, timing.total_secs
                                ),
                                None => println!(
                                    "[sweep]   done {} ({:.1}s)",
                                    run_id, timing.total_secs
                                ),
                            }
                        }
                    }
                    Err(e) if e.downcast_ref::<Halted>().is_some() => {
                        // Preempted by halt_after: checkpointed, not a
                        // failure — the next resume sweep finishes it.
                        halted += 1;
                        if opts.verbose {
                            println!("[sweep]   halted {run_id} ({e:#})");
                        }
                    }
                    Err(e) => {
                        stop.store(true, Ordering::Relaxed);
                        first_err.get_or_insert(e.context(format!("run {run_id} failed")));
                    }
                }
            }
        });
        if let Some(e) = first_err {
            // Completed rows are already on disk — the sweep is resumable
            // from exactly this point.
            return Err(e);
        }
    }

    manifest.compact()?;
    let summary = SweepSummary {
        total,
        executed,
        skipped,
        halted,
        waves: n_waves,
        manifest_path: opts.manifest_path.clone(),
    };
    Ok((summary, manifest))
}

/// Wall-clock + resume telemetry for the side file (never enters the
/// deterministic manifest row).
pub struct RunTiming {
    pub total_secs: f64,
    pub time_to_best_secs: f64,
    /// Step this run resumed from, when it continued off a checkpoint.
    pub resumed_from_step: Option<usize>,
    /// Checkpoint anomaly note (corrupt snapshots skipped, from-scratch
    /// fallback), if any.
    pub note: Option<String>,
}

/// Per-run execution context: checkpointing, preemption and dump knobs
/// the scheduler threads into the coordinator.
#[derive(Clone, Debug, Default)]
pub struct RunCtx {
    /// This run's private checkpoint directory (None = no checkpointing).
    pub ckpt_dir: Option<PathBuf>,
    pub ckpt_every: usize,
    pub ckpt_keep: usize,
    pub halt_after: usize,
    /// Where to dump the final parameters after a completed run.
    pub dump_path: Option<PathBuf>,
}

/// [`execute_run_with`] under the default context (no checkpointing, no
/// preemption) — the historical entry point, kept for tests/clients.
pub fn execute_run(spec: &RunSpec) -> Result<(ManifestRow, RunTiming)> {
    execute_run_with(spec, &RunCtx::default())
}

/// Execute one run on its backend and produce its manifest row.
///
/// Re-entrant: all state (executor, params, dataset, optimizer) is built
/// inside the call, nothing is printed, and the in-run noise pool is
/// pinned to one worker so run-level parallelism composes with it. The
/// parameter store is allocated at the spec's storage dtype (the AOT
/// dumps are f32 and are rounded nearest-even on load for bf16 runs).
/// With `ctx.ckpt_dir` set the run resumes from its latest valid
/// snapshot; `ctx.halt_after` preempts it with a typed
/// [`Halted`] error after that many steps (snapshot written first).
pub fn execute_run_with(spec: &RunSpec, ctx: &RunCtx) -> Result<(ManifestRow, RunTiming)> {
    match spec.backend {
        Backend::Mock => {
            let mut exec = QuadraticExec::new(
                spec.mock_dim,
                0.5,
                2.0,
                0.1,
                derive_seed(spec.grid_seed, 0xACE),
            );
            let mut params =
                ParamStore::zeros_in(&[("w".to_string(), vec![spec.mock_dim])], spec.dtype);
            run_with_exec(spec, ctx, &mut exec, &mut params, 512, 64)
        }
        Backend::Xla => {
            let mut exec = XlaExec::new(&default_artifacts_dir(), &spec.model_key)?;
            let entry = exec.entry().clone();
            let mut params = exec.load_initial_params()?.to_dtype(spec.dtype);
            run_with_exec(spec, ctx, &mut exec, &mut params, entry.vocab, entry.max_len)
        }
    }
}

/// Dump the final parameter store for the byte-compare proofs (native
/// dtype, `save_bin` layout).
fn dump_params(params: &ParamStore, path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
    }
    params.save_bin(path)
}

fn run_with_exec(
    spec: &RunSpec,
    ctx: &RunCtx,
    exec: &mut dyn ModelExec,
    params: &mut ParamStore,
    vocab: usize,
    max_len: usize,
) -> Result<(ManifestRow, RunTiming)> {
    let task = spec.task_def()?;
    let ds = Dataset::generate(
        task,
        vocab,
        Some(max_len),
        spec.grid_seed,
        spec.n_train,
        spec.n_val,
        spec.n_test,
    );
    if spec.steps == 0 {
        // Zero-shot: evaluation only, no training loop (and nothing to
        // checkpoint). The budget is exactly `eval_examples` — no silent
        // clamp, since that field is part of run identity and must
        // actually steer the outcome.
        let t0 = Instant::now();
        let ev = evaluate(exec, params, &ds.test, spec.eval_examples)?;
        if let Some(path) = &ctx.dump_path {
            dump_params(params, path)?;
        }
        return Ok((
            ManifestRow::from_eval(spec, &ev),
            RunTiming {
                total_secs: t0.elapsed().as_secs_f64(),
                time_to_best_secs: 0.0,
                resumed_from_step: None,
                note: None,
            },
        ));
    }
    // `LT_NONE` is usize::MAX, which `partition` already treats as "no
    // partitioning", so `spec.lt` passes straight through.
    let lt = if spec.lt_auto {
        // Addax on long tasks: partition at the 60th length percentile of
        // the (deterministic) training split — the repro's L_T policy.
        let mut lens: Vec<usize> = ds.train.iter().map(|e| e.context.len() + 1).collect();
        lens.sort_unstable();
        lens[lens.len() * 6 / 10]
    } else {
        spec.lt
    };
    let cfg = TrainConfig {
        steps: spec.steps,
        eval_every: spec.eval_every,
        seed: spec.train_seed,
        eval_examples: spec.eval_examples,
        log_path: None,
        verbose: false,
        // One in-run noise worker: the sweep parallelizes across runs,
        // so in-run pools would only oversubscribe the host. The pin is
        // per-store (no process global), so concurrent runs with
        // different settings could coexist — the scheduler just has no
        // reason to want them.
        noise_workers: 1,
        ckpt_dir: ctx.ckpt_dir.clone(),
        ckpt_every: ctx.ckpt_every,
        ckpt_keep: ctx.ckpt_keep,
        // Snapshots are stamped with (and resume demands) the run id, so
        // a directory mix-up can never graft one run's state onto another.
        ckpt_identity: spec.run_id.clone(),
        halt_after: ctx.halt_after,
    };
    let mut opt = spec.optimizer.build()?;
    // `Halted` must propagate un-wrapped in meaning (anyhow downcasts
    // through context chains, so the scheduler still sees it).
    let r = train(exec, params, &mut *opt, &ds, lt, &cfg)
        .with_context(|| format!("training {}", spec.run_id))?;
    if let Some(path) = &ctx.dump_path {
        dump_params(params, path)?;
    }
    // Wall-clock of a resumed run covers only the final session (the
    // clock restarts; time_to_best is 0.0 when the best predates the
    // resume) — stamp the times row so downstream consumers don't read
    // it as an instantaneous result.
    let mut notes: Vec<String> = Vec::new();
    if !r.ckpt_note.is_empty() {
        notes.push(r.ckpt_note.clone());
    }
    if r.resumed_from_step.is_some() {
        notes.push("wall-clock covers the resumed session only".to_string());
    }
    let timing = RunTiming {
        total_secs: r.total_secs,
        time_to_best_secs: r.time_to_best_secs,
        resumed_from_step: r.resumed_from_step,
        note: (!notes.is_empty()).then(|| notes.join("; ")),
    };
    Ok((ManifestRow::from_train(spec, &r), timing))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::OptSpec;

    #[test]
    fn execute_run_is_deterministic() {
        let spec = {
            let mut s = RunSpec::new(Backend::Mock, "sst2", OptSpec::named("addax"), 15, 3);
            s.eval_examples = 30;
            s.n_train = 120;
            s.n_val = 40;
            s.n_test = 40;
            s.sealed()
        };
        let (a, _) = execute_run(&spec).unwrap();
        let (b, _) = execute_run(&spec).unwrap();
        assert_eq!(a.to_line(), b.to_line());
        assert_eq!(a.outcome.loss_curve.points.len(), 15);
    }

    #[test]
    fn execute_run_is_deterministic_at_bf16() {
        // The tentpole contract at the run level: a bf16 cell reproduces
        // its manifest row exactly, and it differs from its f32 twin only
        // through the declared dtype (distinct run id).
        let mk = |dtype| {
            let mut s = RunSpec::new(Backend::Mock, "sst2", OptSpec::named("mezo"), 10, 3);
            s.dtype = dtype;
            s.eval_examples = 30;
            s.n_train = 120;
            s.n_val = 40;
            s.n_test = 40;
            s.sealed()
        };
        let spec = mk(crate::tensor::Dtype::Bf16);
        let (a, _) = execute_run(&spec).unwrap();
        let (b, _) = execute_run(&spec).unwrap();
        assert_eq!(a.to_line(), b.to_line());
        assert_ne!(spec.run_id, mk(crate::tensor::Dtype::F32).run_id);
    }

    #[test]
    fn zero_shot_runs_eval_only() {
        let mut s = RunSpec::new(Backend::Mock, "sst2", OptSpec::named("zero-shot"), 0, 1);
        s.n_test = 60;
        s.eval_examples = 50;
        let s = s.sealed();
        let (row, _) = execute_run(&s).unwrap();
        assert_eq!(row.outcome.kind, "eval");
        assert_eq!(row.outcome.steps, 0);
        assert!(row.outcome.loss_curve.points.is_empty());
        assert!(row.outcome.test_acc > 0.0);
    }

    #[test]
    fn unsealed_spec_is_rejected() {
        let mut s = RunSpec::new(Backend::Mock, "sst2", OptSpec::named("mezo"), 5, 0);
        s.run_id = String::new();
        let err = run_sweep(vec![s], &SweepOptions::default()).unwrap_err();
        assert!(format!("{err}").contains("unsealed"));
    }
}
