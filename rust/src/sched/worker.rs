//! The sweep executor: waves of runs on a scoped worker pool, one
//! manifest writer.
//!
//! Control flow per `run_sweep` call:
//!
//! 1. load the manifest; drop every spec whose run id is already present
//!    (skip-completed — this is what `--resume` resumes);
//! 2. price + pack the remaining runs into waves (`pack.rs`);
//! 3. per wave, spawn up to `workers` scoped threads that pull runs off a
//!    shared counter and send finished rows over a channel; the main
//!    thread is the only manifest writer (crash-safe appends);
//! 4. compact the manifest into canonical order.
//!
//! Resume is **step-level**: each run checkpoints into its own directory
//! (`<manifest dir>/ckpt/<run_id>/`, via the `ckpt` subsystem), so a run
//! killed mid-flight continues from its latest valid snapshot instead of
//! restarting — and lands on the *byte-identical* manifest row and
//! parameter dump. A completed run's checkpoint directory is deleted once
//! its row is safely appended (the manifest row is then the durable
//! record). The times side file gains `resumed_from_step` / `note`
//! telemetry for resumed or degraded (corrupt-snapshot) runs.
//!
//! Determinism: every run is executed with a single in-run noise worker
//! (parallelism lives *across* runs), seeds derive from run identity, and
//! rows carry no wall-clock — so the compacted manifest is byte-identical
//! for the same spec at any `--workers`, across kills/resumes (run- or
//! step-level), and across machines (per backend).
//!
//! Observability: with a status board attached (`SweepOptions::probe`,
//! CLI `--probe-port`), every pending run is registered and updated at
//! step boundaries, and probe control verbs (checkpoint/pause/abort)
//! route through the same `Halted`/checkpoint rails as `halt_after` —
//! so a probed sweep compacts to the byte-identical manifest of an
//! unprobed one. See the `crate::obs` module docs for the argument.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::{evaluate, train, Halted, TrainConfig};
use crate::data::Dataset;
use crate::ioutil;
use crate::params::ParamStore;
use crate::runtime::manifest::default_artifacts_dir;
use crate::runtime::mock::QuadraticExec;
use crate::runtime::{ModelExec, XlaExec};
use crate::zorng::derive_seed;

use super::chaos::ChaosPlan;
use super::lease::{self, LeaseAction, LeaseClock, LeaseRecord, LeaseTable};
use super::manifest::{ManifestRow, SweepManifest};
use super::pack::pack;
use super::spec::{Backend, RunSpec};
use super::steal;

/// Rotate `manifest.times.jsonl` at quiesced points once it holds at
/// least this many lines (single-process sweeps; fleet mode reuses its
/// `--rotate-after` knob so both ledgers share one policy).
const TIMES_ROTATE_AFTER: usize = 512;

/// Scheduler knobs (the `sweep` subcommand's flags).
#[derive(Clone, Debug)]
pub struct SweepOptions {
    /// Per simulated device, in GB.
    pub budget_gb: f64,
    /// Simulated device count; the packing budget is `budget_gb × gpus`.
    pub gpus: usize,
    /// Concurrent runs per wave.
    pub workers: usize,
    /// Skip runs already in the manifest. Without it, an existing
    /// non-empty manifest is an error (no silent clobbering).
    pub resume: bool,
    pub manifest_path: std::path::PathBuf,
    /// Print the packing plan and per-run completions.
    pub verbose: bool,
    /// Step-level checkpointing for every run (on by default): snapshots
    /// land in `<manifest dir>/ckpt/<run_id>/` and a partially complete
    /// run resumes from its latest valid one instead of restarting.
    pub ckpt: bool,
    /// Per-run snapshot cadence in steps; 0 = the run's eval cadence.
    pub ckpt_every: usize,
    /// Keep-last-K snapshots per run (best-referenced ones always kept).
    pub ckpt_keep: usize,
    /// Deterministic preemption: halt every run after this many steps
    /// this invocation (0 = never). Runs halt *after* snapshotting, so a
    /// follow-up `--resume` sweep finishes them step-level — the CI
    /// mid-run-kill proof. A real SIGKILL leaves equivalent on-disk
    /// state (snapshot writes are atomic).
    pub halt_after: usize,
    /// Dump each completed run's final parameters (native dtype, the
    /// `save_bin` format) to `<manifest dir>/params/<run_id>.bin` — what
    /// CI byte-compares between killed+resumed and uninterrupted sweeps.
    pub dump_params: bool,
    /// Live status registry (`--probe-port`): when set, every pending run
    /// is registered and updated at step boundaries, and probe control
    /// verbs (checkpoint/pause/abort) are honored through the existing
    /// `Halted`/`Checkpointer` rails. The HTTP server itself lives in
    /// `main.rs`; tests drive the board directly. `None` = zero overhead.
    pub probe: Option<crate::obs::StatusBoard>,
}

impl Default for SweepOptions {
    fn default() -> Self {
        Self {
            budget_gb: 40.0,
            gpus: 1,
            workers: 4,
            resume: true,
            manifest_path: std::path::PathBuf::from("results/sweep/manifest.jsonl"),
            verbose: false,
            ckpt: true,
            ckpt_every: 0,
            ckpt_keep: 2,
            halt_after: 0,
            dump_params: false,
            probe: None,
        }
    }
}

impl SweepOptions {
    /// Root of the per-run checkpoint directories.
    pub fn ckpt_root(&self) -> PathBuf {
        self.manifest_dir().join("ckpt")
    }

    /// Directory for final-parameter dumps.
    pub fn params_dir(&self) -> PathBuf {
        self.manifest_dir().join("params")
    }

    fn manifest_dir(&self) -> PathBuf {
        match self.manifest_path.parent() {
            Some(p) if p.as_os_str().is_empty() => PathBuf::from("."),
            Some(p) => p.to_path_buf(),
            None => PathBuf::from("."),
        }
    }
}

/// What a sweep did.
#[derive(Clone, Debug)]
pub struct SweepSummary {
    pub total: usize,
    pub executed: usize,
    pub skipped: usize,
    /// Runs preempted by `halt_after` (checkpointed, not completed — a
    /// later `--resume` sweep finishes them step-level).
    pub halted: usize,
    /// Expired leases this worker reclaimed (fleet mode). A reclaimed
    /// run resumes step-level and is counted exactly once — here, never
    /// also under `executed` by the dead worker.
    pub reclaimed: usize,
    /// Zombie commits this worker had rejected by the fencing check
    /// (fleet mode): the run executed to completion under a stale
    /// token, so its row was discarded, not merged.
    pub fenced: usize,
    /// Probe shards this worker computed for OTHER holders as a thief
    /// (fleet mode) — fleet-wide sums count each stolen shard exactly
    /// once. Shards of this worker's own runs that a thief computed show
    /// up in the times side file (`"event":"steal"`), not here. Pure
    /// telemetry — stolen and unstolen runs commit byte-identical rows.
    pub stolen: u64,
    pub waves: usize,
    pub manifest_path: std::path::PathBuf,
}

impl SweepSummary {
    /// Stable one-line form (CI greps `executed=`, `halted=`,
    /// `reclaimed=` and `stolen=`).
    pub fn line(&self) -> String {
        format!(
            "sweep: total={} executed={} skipped={} halted={} reclaimed={} fenced={} \
             stolen={} waves={} manifest={}",
            self.total,
            self.executed,
            self.skipped,
            self.halted,
            self.reclaimed,
            self.fenced,
            self.stolen,
            self.waves,
            self.manifest_path.display()
        )
    }
}

/// Execute `specs` under `opts`. See module docs for the contract.
pub fn run_sweep(specs: Vec<RunSpec>, opts: &SweepOptions) -> Result<SweepSummary> {
    run_sweep_collect(specs, opts).map(|(summary, _)| summary)
}

/// [`run_sweep`] returning the post-sweep manifest as well, so callers
/// that aggregate rows (the repro harness) skip a full re-load/re-parse
/// of the file they just wrote.
pub fn run_sweep_collect(
    specs: Vec<RunSpec>,
    opts: &SweepOptions,
) -> Result<(SweepSummary, SweepManifest)> {
    if opts.workers == 0 {
        bail!("--workers must be ≥ 1");
    }
    if opts.halt_after > 0 && !opts.ckpt {
        // Without snapshots a halted run restarts from step 0 every
        // resume and halts again at the same step — the sweep could never
        // finish. Refuse the combination instead of looping forever.
        bail!("--halt-after needs checkpointing (drop --no-ckpt)");
    }
    // Dedup by run id, first occurrence wins (different experiments may
    // request the same logical run; it executes once).
    let mut deduped: Vec<RunSpec> = Vec::with_capacity(specs.len());
    {
        let mut seen = std::collections::BTreeSet::new();
        for s in specs {
            if s.run_id.is_empty() {
                bail!("unsealed RunSpec (empty run_id) — call RunSpec::sealed()");
            }
            if seen.insert(s.run_id.clone()) {
                deduped.push(s);
            }
        }
    }
    let total = deduped.len();

    let mut manifest = SweepManifest::load(&opts.manifest_path)?;
    if !opts.resume && !manifest.is_empty() {
        bail!(
            "manifest {} already holds {} runs — pass --resume to skip \
             completed runs, or remove the file to start fresh",
            opts.manifest_path.display(),
            manifest.len()
        );
    }
    let ckpt_root = opts.ckpt_root();
    let mut pending: Vec<RunSpec> = Vec::with_capacity(deduped.len());
    for s in deduped {
        if manifest.contains(&s.run_id) {
            // Completed in some earlier invocation. Its checkpoints are
            // dead weight — and if a kill landed between the row append
            // and the in-flight cleanup, this is the only path that ever
            // reclaims them.
            if opts.ckpt {
                std::fs::remove_dir_all(s.ckpt_dir(&ckpt_root)).ok();
            }
        } else {
            pending.push(s);
        }
    }
    let skipped = total - pending.len();

    if let Some(board) = &opts.probe {
        // Pre-register every pending run so `GET /runs` shows the whole
        // grid (phase `pending`) before its wave starts, priced with the
        // same analytic footprint the packer uses.
        for s in &pending {
            let p = board.register(&s.run_id, s.steps);
            if let Ok(bytes) = super::pack::price(s) {
                p.set_footprint_bytes(bytes);
            }
        }
    }

    let budget_bytes = opts.budget_gb * 1e9 * opts.gpus as f64;
    let waves = pack(pending, budget_bytes)?;
    let n_waves = waves.len();
    if opts.verbose {
        println!(
            "[sweep] {} runs pending ({} skipped) in {} wave(s) under {:.0} GB",
            total - skipped,
            skipped,
            n_waves,
            budget_bytes / 1e9
        );
    }

    let params_dir = opts.params_dir();
    let mut executed = 0usize;
    let mut halted = 0usize;
    for (wi, wave) in waves.into_iter().enumerate() {
        if opts.verbose {
            println!(
                "[sweep] wave {}/{}: {} run(s), {:.1}/{:.0} GB",
                wi + 1,
                n_waves,
                wave.runs.len(),
                wave.bytes / 1e9,
                budget_bytes / 1e9
            );
        }
        let runs: Vec<RunSpec> = wave.runs.into_iter().map(|p| p.spec).collect();
        let n_workers = opts.workers.min(runs.len()).max(1);
        let next = AtomicUsize::new(0);
        let stop = AtomicBool::new(false);
        let mut first_err: Option<anyhow::Error> = None;

        std::thread::scope(|scope| {
            let (tx, rx) = mpsc::channel::<(String, Result<(ManifestRow, RunTiming)>)>();
            let runs_ref = &runs;
            let next_ref = &next;
            let stop_ref = &stop;
            let ckpt_root_ref = &ckpt_root;
            let params_dir_ref = &params_dir;
            for _ in 0..n_workers {
                let tx = tx.clone();
                scope.spawn(move || loop {
                    if stop_ref.load(Ordering::Relaxed) {
                        break;
                    }
                    let i = next_ref.fetch_add(1, Ordering::SeqCst);
                    if i >= runs_ref.len() {
                        break;
                    }
                    let spec = &runs_ref[i];
                    let ctx = RunCtx {
                        ckpt_dir: opts.ckpt.then(|| spec.ckpt_dir(ckpt_root_ref)),
                        ckpt_every: opts.ckpt_every,
                        ckpt_keep: opts.ckpt_keep,
                        halt_after: opts.halt_after,
                        dump_path: opts
                            .dump_params
                            .then(|| params_dir_ref.join(format!("{}.bin", spec.run_id))),
                        probe: opts
                            .probe
                            .as_ref()
                            .map(|b| b.register(&spec.run_id, spec.steps)),
                    };
                    let res = execute_run_with(spec, &ctx);
                    if tx.send((spec.run_id.clone(), res)).is_err() {
                        break;
                    }
                });
            }
            drop(tx);
            for (run_id, res) in rx {
                match res {
                    Ok((row, timing)) => {
                        if let Err(e) = manifest.append(row) {
                            stop.store(true, Ordering::Relaxed);
                            first_err.get_or_insert(e);
                            continue;
                        }
                        SweepManifest::append_time(
                            &opts.manifest_path,
                            &run_id,
                            timing.total_secs,
                            timing.time_to_best_secs,
                            timing.resumed_from_step,
                            timing.note.as_deref(),
                        )
                        .ok();
                        // The row is durable: the run's checkpoints have
                        // served their purpose.
                        if opts.ckpt {
                            std::fs::remove_dir_all(ckpt_root.join(&run_id)).ok();
                        }
                        executed += 1;
                        if let Some(p) = opts.probe.as_ref().and_then(|b| b.get(&run_id)) {
                            // Zero-shot (eval-only) runs never enter the
                            // training loop, so mark completion here.
                            p.set_done();
                        }
                        if opts.verbose {
                            match timing.resumed_from_step {
                                Some(s) => println!(
                                    "[sweep]   done {} ({:.1}s, resumed from step {s})",
                                    run_id, timing.total_secs
                                ),
                                None => println!(
                                    "[sweep]   done {} ({:.1}s)",
                                    run_id, timing.total_secs
                                ),
                            }
                        }
                    }
                    Err(e) if e.downcast_ref::<Halted>().is_some() => {
                        // Preempted by halt_after: checkpointed, not a
                        // failure — the next resume sweep finishes it.
                        halted += 1;
                        if opts.verbose {
                            println!("[sweep]   halted {run_id} ({e:#})");
                        }
                    }
                    Err(e) => {
                        stop.store(true, Ordering::Relaxed);
                        first_err.get_or_insert(e.context(format!("run {run_id} failed")));
                    }
                }
            }
        });
        if let Some(e) = first_err {
            // Completed rows are already on disk — the sweep is resumable
            // from exactly this point.
            return Err(e);
        }
    }

    manifest.compact()?;
    // The times side file gets the same growth bound the lease ledger
    // has: once the sweep is quiesced, keep event rows plus the last
    // timing row per run.
    SweepManifest::rotate_times(&opts.manifest_path, TIMES_ROTATE_AFTER)?;
    let summary = SweepSummary {
        total,
        executed,
        skipped,
        halted,
        reclaimed: 0,
        fenced: 0,
        stolen: 0,
        waves: n_waves,
        manifest_path: opts.manifest_path.clone(),
    };
    Ok((summary, manifest))
}

/// Fleet knobs: one worker process in a lease-coordinated multi-process
/// sweep (`addax sweep --worker-id <id> --lease-ttl <secs>`).
#[derive(Clone, Debug)]
pub struct FleetOptions {
    /// This worker's identity in lease records (must be unique per live
    /// process; reusing an id after a crash is fine — fencing tokens,
    /// not ids, arbitrate).
    pub worker_id: String,
    /// Lease TTL. A lease not renewed within this window is presumed
    /// dead and reclaimable; heartbeats renew at TTL/3.
    pub lease_ttl_ms: u64,
    /// Deterministic fault injection (`--chaos-seed`). Besides crashes /
    /// stalls / I/O bursts, a chaos plan skews this worker's lease clock
    /// by a per-worker deterministic offset in ±TTL (overridable with
    /// `clock_offset_ms`).
    pub chaos: Option<ChaosPlan>,
    /// Grace added to `expires_ms` before *this observer* treats a
    /// foreign lease as expired (`--skew-margin-ms`, config
    /// `sweep.skew_margin_ms`). Absorbs ordinary cross-node clock drift;
    /// the logical reclaim confirmation handles anything bigger.
    pub skew_margin_ms: u64,
    /// Explicit clock-skew injection for this worker (`--clock-offset-ms`).
    /// `None` = the chaos plan's derived offset, or 0 without chaos.
    pub clock_offset_ms: Option<i64>,
    /// Consecutive quiet ledger reloads (spaced TTL/3) required before an
    /// expired-looking lease may actually be reclaimed. A live holder
    /// renews its `seq` every TTL/3, so any `k ≥ 1` vetoes reclaims of
    /// live runs under arbitrary skew; higher k buys margin against I/O
    /// hiccups delaying a renewal append.
    pub confirm_reloads: u32,
    /// Rotate (GC) the lease ledger at all-released points once it holds
    /// at least this many lines (`--rotate-after`; 0 disables rotation).
    pub rotate_after_lines: usize,
    /// Disable tail work-stealing (`--no-steal`).
    pub no_steal: bool,
    /// Holder-side one-shot wait for a thief marker before a run's first
    /// probe (`--steal-wait-ms`). 0 = shard opportunistically; CI sets it
    /// high to *guarantee* a stolen probe in the determinism proof.
    pub steal_wait_ms: u64,
    /// This worker's probe-server address (`host:port`), embedded in its
    /// lease claim/reclaim/renew records so a fleet aggregator
    /// (`addax fleet-status`) can federate live `/runs` state. `None`
    /// (unprobed worker) emits no `probe` key — ledger bytes are
    /// unchanged from the pre-probe era.
    pub probe_addr: Option<String>,
}

impl FleetOptions {
    /// Defaults for everything but identity and TTL.
    pub fn new(worker_id: impl Into<String>, lease_ttl_ms: u64) -> Self {
        Self {
            worker_id: worker_id.into(),
            lease_ttl_ms,
            chaos: None,
            skew_margin_ms: 250,
            clock_offset_ms: None,
            confirm_reloads: 2,
            rotate_after_lines: 512,
            no_steal: false,
            steal_wait_ms: 0,
            probe_addr: None,
        }
    }

    /// This worker's lease clock: explicit offset, else the chaos plan's
    /// derived per-worker skew, else the real clock.
    pub fn clock(&self) -> LeaseClock {
        let offset = self.clock_offset_ms.unwrap_or_else(|| {
            self.chaos
                .map(|c| c.clock_offset_ms(&self.worker_id, self.lease_ttl_ms))
                .unwrap_or(0)
        });
        LeaseClock::new(offset)
    }
}

/// How a fleet worker's invocation ended.
#[derive(Clone, Debug)]
pub struct FleetExit {
    pub summary: SweepSummary,
    /// Set when the chaos plan killed this worker mid-run (the run id it
    /// died holding). The CLI turns this into exit code 96 so a restart
    /// loop can tell a planned crash from a real failure. The lease was
    /// NOT released — it must expire and be reclaimed, exactly like a
    /// real SIGKILL.
    pub crashed: Option<String>,
}

/// Lease heartbeat: a thread renewing `run_id`'s lease at TTL/3 while
/// the run executes. A `stalled` heartbeat (chaos) never renews — the
/// lease expires under a live holder, manufacturing a zombie.
struct Heartbeat {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Heartbeat {
    fn start(
        lease_path: PathBuf,
        run_id: String,
        worker: String,
        token: u64,
        ttl_ms: u64,
        clock: LeaseClock,
        stalled: bool,
        probe: Option<Arc<crate::obs::RunProbe>>,
        probe_addr: Option<String>,
    ) -> Self {
        let stop = Arc::new(AtomicBool::new(false));
        if stalled {
            return Self { stop, handle: None };
        }
        let stop2 = Arc::clone(&stop);
        let interval = Duration::from_millis((ttl_ms / 3).max(5));
        // Sleep in short slices so `finish()` never blocks a completed
        // run for a whole renewal interval.
        let slice = interval.min(Duration::from_millis(20));
        let handle = std::thread::spawn(move || {
            let mut next = Instant::now() + interval;
            // The per-holder logical clock: every renewal advances it, so
            // an observer confirming a reclaim can tell "alive but
            // skew-shifted" from "dead" without trusting any wall clock.
            let mut seq = 0u64;
            loop {
                std::thread::sleep(slice);
                if stop2.load(Ordering::Relaxed) {
                    break;
                }
                if Instant::now() < next {
                    continue;
                }
                next = Instant::now() + interval;
                seq += 1;
                if let Some(p) = &probe {
                    // `/runs` shows the holder's logical clock advancing —
                    // the liveness signal a reclaim confirmation reads.
                    p.set_lease_seq(seq);
                }
                // Renewal failures are survivable (the next beat
                // retries; at worst the lease lapses and the run is
                // reclaimed) — which is also why renewals take the
                // cheap unsynced append: losing one to a power cut
                // costs at most a spurious reclaim, never a fence.
                lease::append(
                    &lease_path,
                    &LeaseRecord {
                        run_id: run_id.clone(),
                        worker: worker.clone(),
                        token,
                        seq,
                        action: LeaseAction::Renew,
                        expires_ms: clock.now_ms() + ttl_ms,
                        // Re-advertised on every beat: an aggregator that
                        // only sees a rotated ledger tail still learns
                        // where this holder's probe lives.
                        probe: probe_addr.clone(),
                    },
                )
                .ok();
            }
        });
        Self { stop, handle: Some(handle) }
    }

    fn finish(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            h.join().ok();
        }
    }
}

/// Commit one finished run under a lease: re-check the fencing token,
/// then append the stamped row + timing telemetry and release the
/// lease. Returns `false` — logging a `fenced` event to the times side
/// file, appending nothing to the manifest — when a higher token has
/// claimed the run (this holder is a zombie).
///
/// Public because the fleet tests drive synthetic zombies through the
/// exact commit path the workers use.
pub fn fleet_commit(
    manifest: &mut SweepManifest,
    worker_id: &str,
    token: u64,
    row: ManifestRow,
    timing: &RunTiming,
) -> Result<bool> {
    let manifest_path = manifest.path.clone();
    let lease_path = lease::leases_path(&manifest_path);
    let table = LeaseTable::load(&lease_path)?;
    let run_id = row.run_id.clone();
    let current = table.max_token(&run_id);
    if current > token {
        SweepManifest::append_event(
            &manifest_path,
            &run_id,
            "fenced",
            &format!(
                "fenced zombie append rejected: worker {worker_id} holds stale token \
                 {token} (current {current}); row discarded, not merged"
            ),
        )?;
        return Ok(false);
    }
    manifest.append_stamped(row, token, worker_id)?;
    SweepManifest::append_time(
        &manifest_path,
        &run_id,
        timing.total_secs,
        timing.time_to_best_secs,
        timing.resumed_from_step,
        timing.note.as_deref(),
    )
    .ok();
    // Durable: a release that evaporates in a power loss would leave an
    // eternal-looking lease that someone must confirm-and-reclaim.
    lease::append_durable(
        &lease_path,
        &LeaseRecord {
            run_id,
            worker: worker_id.to_string(),
            token,
            seq: 0, // replay maxes seq, so 0 preserves the renewal count
            action: LeaseAction::Release,
            expires_ms: lease::now_ms(),
            probe: None,
        },
    )?;
    Ok(true)
}

/// One fleet worker: claim → heartbeat → execute → fenced commit,
/// until every run in `specs` has a durable manifest row.
///
/// Any number of `run_sweep_fleet` processes (or threads — the tests'
/// in-process harness) may share a manifest path; the lease file is the
/// only coordination. Each worker runs one run at a time (fleet
/// parallelism lives across processes), so every run must fit the
/// device budget alone. A worker that finds an expired lease reclaims
/// it and the run *resumes* from its step-level snapshots — the ckpt
/// subsystem validates identity/dtype and falls back from corrupt
/// snapshots exactly as in the single-process path. The last worker out
/// compacts: the compacted manifest is byte-identical to a
/// single-process sweep's, at any worker count and under any
/// kill/reclaim pattern.
///
/// Cross-node hardening (all on by default):
///
/// * **skew tolerance** — every liveness decision runs on this worker's
///   [`LeaseClock`] with `skew_margin_ms` grace, and a reclaim
///   additionally requires [`lease::confirm_expired`]'s logical proof of
///   death (no renewal-`seq` advance across K reloads), so a live run is
///   never reclaimed under arbitrary clock skew;
/// * **ledger rotation** — at all-released points the lease ledger is
///   GC'd to one line per run ([`lease::rotate`]), bounding its size for
///   week-long sweeps while preserving fencing-token monotonicity;
/// * **tail stealing** — a worker finding everything leased serves probe
///   shards for running ZO runs ([`steal`]), and a holder shards its
///   probes to advertised thieves, bit-identically with local fallback.
pub fn run_sweep_fleet(
    specs: Vec<RunSpec>,
    opts: &SweepOptions,
    fleet: &FleetOptions,
) -> Result<FleetExit> {
    if fleet.worker_id.trim().is_empty() {
        bail!("fleet mode needs a non-empty --worker-id");
    }
    if fleet.lease_ttl_ms < 20 {
        bail!("--lease-ttl below 20 ms cannot outlive its own heartbeat");
    }
    if !opts.ckpt {
        bail!("fleet reclaim resumes runs from checkpoints (drop --no-ckpt)");
    }
    if opts.halt_after > 0 {
        bail!("--halt-after is a single-process kill knob; in fleet mode use --chaos-seed");
    }
    if !opts.resume {
        bail!("fleet workers join a shared manifest mid-sweep — pass --resume");
    }
    let mut deduped: Vec<RunSpec> = Vec::with_capacity(specs.len());
    {
        let mut seen = std::collections::BTreeSet::new();
        for s in specs {
            if s.run_id.is_empty() {
                bail!("unsealed RunSpec (empty run_id) — call RunSpec::sealed()");
            }
            if seen.insert(s.run_id.clone()) {
                deduped.push(s);
            }
        }
    }
    let total = deduped.len();
    // Packing is a plan-validity check here (every run must fit alone);
    // fleet workers pull one run at a time rather than executing waves.
    pack(deduped.clone(), opts.budget_gb * 1e9 * opts.gpus as f64)?;

    if let Some(board) = &opts.probe {
        for s in &deduped {
            let p = board.register(&s.run_id, s.steps);
            if let Ok(bytes) = super::pack::price(s) {
                p.set_footprint_bytes(bytes);
            }
        }
    }

    let lease_path = lease::leases_path(&opts.manifest_path);
    let ckpt_root = opts.ckpt_root();
    let params_dir = opts.params_dir();
    let steal_root = opts.manifest_dir().join("steal");
    let ttl = fleet.lease_ttl_ms;
    let clock = fleet.clock();
    let poll = Duration::from_millis((ttl / 4).clamp(5, 200));
    let mut executed = 0usize;
    let mut reclaimed = 0usize;
    let mut fenced = 0usize;
    let mut halted = 0usize;
    let mut stolen = 0u64;
    let mut crashed: Option<String> = None;
    // Runs this worker stopped on a probe `abort`: released, snapshots
    // kept, and out of *this* worker's claim set — another worker (or a
    // later resume sweep) finishes them byte-identically.
    let mut aborted: std::collections::BTreeSet<String> = std::collections::BTreeSet::new();

    loop {
        let table = LeaseTable::load(&lease_path)?;
        let manifest = SweepManifest::load(&opts.manifest_path)?;
        let pending: Vec<&RunSpec> = deduped
            .iter()
            .filter(|s| !manifest.contains(&s.run_id) && !aborted.contains(&s.run_id))
            .collect();
        if pending.is_empty() {
            // Every row is durable. Live leases can only belong to
            // workers about to discover that (or to harmless zombies);
            // wait them out so nothing appends after compaction.
            if table.any_active(clock.now_ms(), fleet.skew_margin_ms) {
                std::thread::sleep(poll);
                continue;
            }
            for s in &deduped {
                if aborted.contains(&s.run_id) {
                    // An aborted run's snapshots ARE its resume state —
                    // deleting them would turn the abort into a restart.
                    continue;
                }
                std::fs::remove_dir_all(s.ckpt_dir(&ckpt_root)).ok();
                steal::finish_run_dir(&steal_root.join(&s.run_id));
            }
            // Final ledger GC: every lease is released, so the ledger
            // compacts to one line per run — the week-long-sweep bound.
            if fleet.rotate_after_lines > 0
                && lease::rotate(&lease_path, fleet.rotate_after_lines)?
            {
                SweepManifest::append_event(
                    &opts.manifest_path,
                    "-",
                    "rotate",
                    "lease ledger rotated at drain: one release line per run",
                )?;
            }
            // Same bound for the times side file: events + the last
            // timing row per run survive, superseded rows are GC'd.
            if fleet.rotate_after_lines > 0 {
                SweepManifest::rotate_times(&opts.manifest_path, fleet.rotate_after_lines)?;
            }
            // Idempotent across workers: everyone compacts the same row
            // set to the same bytes, each through its own tmp file.
            manifest.compact()?;
            break;
        }
        // Prefer runs that were never claimed (or cleanly released): they
        // need no expiry judgment, let alone a reclaim confirmation.
        let fresh = pending.iter().find(|s| table.fresh_claimable(&s.run_id)).copied();
        let spec = match fresh {
            Some(s) => s,
            None => {
                let now = clock.now_ms();
                let expired_looking = pending
                    .iter()
                    .find(|s| table.claimable(&s.run_id, now, fleet.skew_margin_ms))
                    .copied();
                let Some(s) = expired_looking else {
                    // Everything pending is leased to someone live — the
                    // grid's tail. Serve probe shards for still-running
                    // ZO runs instead of pure idle-polling.
                    if !fleet.no_steal {
                        let mut mk = |run_id: &str| -> Option<Box<dyn ModelExec>> {
                            let s = deduped.iter().find(|s| s.run_id == run_id)?;
                            // Stealing is mock-only for now: XLA padding
                            // inside `forward` is per-chunk, so sub-batch
                            // row sums are not yet proven bit-stable.
                            matches!(s.backend, Backend::Mock).then(|| {
                                Box::new(QuadraticExec::new(
                                    s.mock_dim,
                                    0.5,
                                    2.0,
                                    0.1,
                                    derive_seed(s.grid_seed, 0xACE),
                                )) as Box<dyn ModelExec>
                            })
                        };
                        let served = steal::try_steal(
                            &steal_root,
                            &fleet.worker_id,
                            &mut mk,
                            (ttl / 2).max(20),
                        )?;
                        if served > 0 {
                            stolen += served;
                            continue; // re-check the ledger right away
                        }
                    }
                    std::thread::sleep(poll);
                    continue;
                };
                // The lease *looks* expired on this observer's (skewed,
                // margin-padded) clock. Demand logical proof of death: no
                // renewal-seq advance across K reloads spaced TTL/3 — a
                // live holder heartbeats faster than that, no matter
                // whose wall clock is wrong.
                if !lease::confirm_expired(
                    &lease_path,
                    &s.run_id,
                    fleet.confirm_reloads,
                    ttl,
                    &clock,
                    fleet.skew_margin_ms,
                )? {
                    // Signs of life (or the ledger moved): not a corpse.
                    std::thread::sleep(poll);
                    continue;
                }
                s
            }
        };
        // Claim at the next fencing token. A claim over an unreleased
        // (expired, confirmed-dead) lease is a reclaim.
        let token = table.max_token(&spec.run_id) + 1;
        let is_reclaim = matches!(table.state(&spec.run_id), Some(s) if !s.released);
        // Claims and reclaims are fencing records: fsync'd, so a power
        // loss can never un-fence a zombie by eating its successor's
        // claim line.
        lease::append_durable(
            &lease_path,
            &LeaseRecord {
                run_id: spec.run_id.clone(),
                worker: fleet.worker_id.clone(),
                token,
                seq: 0,
                action: if is_reclaim { LeaseAction::Reclaim } else { LeaseAction::Claim },
                expires_ms: clock.now_ms() + ttl,
                probe: fleet.probe_addr.clone(),
            },
        )?;
        // Confirm the claim won (equal tokens: first appender wins).
        let confirm = LeaseTable::load(&lease_path)?;
        if confirm.holder(&spec.run_id) != Some((fleet.worker_id.as_str(), token)) {
            continue;
        }
        // Post-claim re-check: the run may have completed between our
        // manifest read and the claim landing. Back off without
        // executing — a leased run is never double-executed.
        if SweepManifest::load(&opts.manifest_path)?.contains(&spec.run_id) {
            lease::append_durable(
                &lease_path,
                &LeaseRecord {
                    run_id: spec.run_id.clone(),
                    worker: fleet.worker_id.clone(),
                    token,
                    seq: 0,
                    action: LeaseAction::Release,
                    expires_ms: clock.now_ms(),
                    probe: None,
                },
            )?;
            continue;
        }
        if is_reclaim {
            reclaimed += 1;
            // Telemetry note in the times side file — never a manifest
            // row, so reclaim history cannot perturb the byte-identity
            // contract.
            SweepManifest::append_event(
                &opts.manifest_path,
                &spec.run_id,
                "reclaim",
                &format!(
                    "worker {} reclaimed expired lease at token {token}; resuming from \
                     the run's snapshots",
                    fleet.worker_id
                ),
            )?;
            if opts.verbose {
                println!("[fleet {}] reclaimed {} (token {token})", fleet.worker_id, spec.run_id);
            }
        }
        let probe = opts.probe.as_ref().map(|b| {
            let p = b.register(&spec.run_id, spec.steps);
            p.set_lease(&fleet.worker_id, token);
            p
        });
        let faults =
            fleet.chaos.map(|c| c.for_run(&spec.run_id, spec.steps)).unwrap_or_default();
        // Chaos arms only on the run's first execution (token 1): a
        // reclaimed run never re-crashes, so every plan terminates.
        let crash_after = if token == 1 { faults.crash_after } else { None };
        let stalled = token == 1 && faults.stall_heartbeat;
        let hb = Heartbeat::start(
            lease_path.clone(),
            spec.run_id.clone(),
            fleet.worker_id.clone(),
            token,
            ttl,
            clock,
            stalled,
            probe.clone(),
            fleet.probe_addr.clone(),
        );
        let ctx = RunCtx {
            ckpt_dir: Some(spec.ckpt_dir(&ckpt_root)),
            ckpt_every: opts.ckpt_every,
            ckpt_keep: opts.ckpt_keep,
            // The chaos crash rides the deterministic-preemption rail: a
            // snapshot lands, then the run "dies". A real SIGKILL leaves
            // equivalent on-disk state (ADDAXCK1 writes are atomic).
            halt_after: crash_after.unwrap_or(0),
            dump_path: opts
                .dump_params
                .then(|| params_dir.join(format!("{}.bin", spec.run_id))),
            probe: probe.clone(),
        };
        // Holder-side stealing: publish a per-run side dir so idle
        // workers can claim probe shards. Mock-only (matching the thief
        // gate above); a dead thief costs one result timeout per probe,
        // never a stall.
        let steal_dir = steal_root.join(&spec.run_id);
        let steal_guard = (!fleet.no_steal
            && matches!(spec.backend, Backend::Mock)
            && spec.steps > 0)
            .then(|| {
                steal::install(steal::StealCtx {
                    dir: steal_dir.clone(),
                    worker: fleet.worker_id.clone(),
                    first_wait_ms: fleet.steal_wait_ms,
                    wait_ms: (ttl / 2).max(50),
                    stolen: 0,
                })
            })
            .transpose()?;
        let res = execute_run_with(spec, &ctx);
        // Shards of OUR run computed by thieves — telemetry only; the
        // summary's `stolen` counts shards this worker served as a
        // thief, so fleet-wide sums count each shard once.
        let run_stolen = steal::stolen_count();
        drop(steal_guard);
        steal::finish_run_dir(&steal_dir);
        hb.finish();
        if let Some(p) = &probe {
            p.set_stolen(run_stolen);
        }
        match res {
            Err(e) if crash_after.is_some() && e.downcast_ref::<Halted>().is_some() => {
                let at = e.downcast_ref::<Halted>().map(|h| h.at_step).unwrap_or(0);
                if opts.verbose {
                    println!(
                        "[fleet {}] chaos crash in {} at step {at} (lease left to expire)",
                        fleet.worker_id, spec.run_id
                    );
                }
                crashed = Some(spec.run_id.clone());
                break;
            }
            Err(e) if e.downcast_ref::<Halted>().is_some() => {
                // A probe `abort` (the only other Halted source in fleet
                // mode — `--halt-after` is rejected above): the run
                // snapshotted and stopped at a step boundary. Release the
                // lease cleanly and drop the run from this worker's claim
                // set; its snapshots stay, so another worker or a later
                // resume sweep finishes it on the byte-identical row.
                let at = e.downcast_ref::<Halted>().map(|h| h.at_step).unwrap_or(0);
                lease::append_durable(
                    &lease_path,
                    &LeaseRecord {
                        run_id: spec.run_id.clone(),
                        worker: fleet.worker_id.clone(),
                        token,
                        seq: 0,
                        action: LeaseAction::Release,
                        expires_ms: clock.now_ms(),
                        probe: None,
                    },
                )?;
                aborted.insert(spec.run_id.clone());
                halted += 1;
                SweepManifest::append_event(
                    &opts.manifest_path,
                    &spec.run_id,
                    "abort",
                    &format!(
                        "probe abort honored at step {at}; lease released, snapshots \
                         kept for resume"
                    ),
                )?;
                if opts.verbose {
                    println!(
                        "[fleet {}] probe abort in {} at step {at}",
                        fleet.worker_id, spec.run_id
                    );
                }
            }
            Err(e) => {
                return Err(e.context(format!(
                    "run {} failed (fleet worker {})",
                    spec.run_id, fleet.worker_id
                )))
            }
            Ok((row, timing)) => {
                if faults.append_faults > 0 {
                    // a bounded burst of transient I/O errors ahead of
                    // the commit appends — absorbed by retry_io
                    ioutil::inject_transient_faults(faults.append_faults);
                }
                let mut fresh = SweepManifest::load(&opts.manifest_path)?;
                if fleet_commit(&mut fresh, &fleet.worker_id, token, row, &timing)? {
                    executed += 1;
                    std::fs::remove_dir_all(spec.ckpt_dir(&ckpt_root)).ok();
                    if run_stolen > 0 {
                        // Telemetry only: the committed row is bit-equal
                        // to an unstolen run's, so the steal history must
                        // live where reclaim history does — the times
                        // side file.
                        SweepManifest::append_event(
                            &opts.manifest_path,
                            &spec.run_id,
                            "steal",
                            &format!(
                                "{run_stolen} probe shard(s) computed by a thief worker"
                            ),
                        )?;
                    }
                    // Mid-sweep ledger GC: at an all-released moment the
                    // ledger compacts to one line per run. Disabled while
                    // any lease is live, so this is cheap to attempt.
                    if fleet.rotate_after_lines > 0
                        && lease::rotate(&lease_path, fleet.rotate_after_lines)?
                    {
                        SweepManifest::append_event(
                            &opts.manifest_path,
                            &spec.run_id,
                            "rotate",
                            "lease ledger rotated: compacted to one release line per run",
                        )?;
                        // The ledger rotating means every lease was
                        // released a moment ago — the same quiesced
                        // window the times rotation wants (it re-checks
                        // length before renaming, like `lease::rotate`).
                        SweepManifest::rotate_times(
                            &opts.manifest_path,
                            fleet.rotate_after_lines,
                        )?;
                    }
                    if opts.verbose {
                        match timing.resumed_from_step {
                            Some(s) => println!(
                                "[fleet {}] done {} ({:.1}s, resumed from step {s})",
                                fleet.worker_id, spec.run_id, timing.total_secs
                            ),
                            None => println!(
                                "[fleet {}] done {} ({:.1}s)",
                                fleet.worker_id, spec.run_id, timing.total_secs
                            ),
                        }
                    }
                } else {
                    fenced += 1;
                    if opts.verbose {
                        println!(
                            "[fleet {}] fenced on {} (stale token {token}) — row discarded",
                            fleet.worker_id, spec.run_id
                        );
                    }
                }
            }
        }
    }
    let summary = SweepSummary {
        total,
        executed,
        // A crashed worker's view is partial by design; completed-by-
        // others accounting is only meaningful on a clean exit.
        skipped: if crashed.is_some() { 0 } else { total - executed },
        // Probe-aborted runs: checkpointed and released, not completed.
        halted,
        reclaimed,
        fenced,
        stolen,
        waves: 0,
        manifest_path: opts.manifest_path.clone(),
    };
    Ok(FleetExit { summary, crashed })
}

/// Wall-clock + resume telemetry for the side file (never enters the
/// deterministic manifest row).
pub struct RunTiming {
    pub total_secs: f64,
    pub time_to_best_secs: f64,
    /// Step this run resumed from, when it continued off a checkpoint.
    pub resumed_from_step: Option<usize>,
    /// Checkpoint anomaly note (corrupt snapshots skipped, from-scratch
    /// fallback), if any.
    pub note: Option<String>,
}

/// Per-run execution context: checkpointing, preemption and dump knobs
/// the scheduler threads into the coordinator.
#[derive(Clone, Debug, Default)]
pub struct RunCtx {
    /// This run's private checkpoint directory (None = no checkpointing).
    pub ckpt_dir: Option<PathBuf>,
    pub ckpt_every: usize,
    pub ckpt_keep: usize,
    pub halt_after: usize,
    /// Where to dump the final parameters after a completed run.
    pub dump_path: Option<PathBuf>,
    /// This run's live status probe (telemetry + control flags), when a
    /// status board is attached.
    pub probe: Option<Arc<crate::obs::RunProbe>>,
}

/// [`execute_run_with`] under the default context (no checkpointing, no
/// preemption) — the historical entry point, kept for tests/clients.
pub fn execute_run(spec: &RunSpec) -> Result<(ManifestRow, RunTiming)> {
    execute_run_with(spec, &RunCtx::default())
}

/// Execute one run on its backend and produce its manifest row.
///
/// Re-entrant: all state (executor, params, dataset, optimizer) is built
/// inside the call, nothing is printed, and the in-run noise pool is
/// pinned to one worker so run-level parallelism composes with it. The
/// parameter store is allocated at the spec's storage dtype (the AOT
/// dumps are f32 and are rounded nearest-even on load for bf16 runs).
/// With `ctx.ckpt_dir` set the run resumes from its latest valid
/// snapshot; `ctx.halt_after` preempts it with a typed
/// [`Halted`] error after that many steps (snapshot written first).
pub fn execute_run_with(spec: &RunSpec, ctx: &RunCtx) -> Result<(ManifestRow, RunTiming)> {
    match spec.backend {
        Backend::Mock => {
            let mut exec = QuadraticExec::new(
                spec.mock_dim,
                0.5,
                2.0,
                0.1,
                derive_seed(spec.grid_seed, 0xACE),
            );
            let mut params =
                ParamStore::zeros_in(&[("w".to_string(), vec![spec.mock_dim])], spec.dtype);
            run_with_exec(spec, ctx, &mut exec, &mut params, 512, 64)
        }
        Backend::Xla => {
            let mut exec = XlaExec::new(&default_artifacts_dir(), &spec.model_key)?;
            let entry = exec.entry().clone();
            let mut params = exec.load_initial_params()?.to_dtype(spec.dtype);
            run_with_exec(spec, ctx, &mut exec, &mut params, entry.vocab, entry.max_len)
        }
    }
}

/// Dump the final parameter store for the byte-compare proofs (native
/// dtype, `save_bin` layout).
fn dump_params(params: &ParamStore, path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
    }
    params.save_bin(path)
}

fn run_with_exec(
    spec: &RunSpec,
    ctx: &RunCtx,
    exec: &mut dyn ModelExec,
    params: &mut ParamStore,
    vocab: usize,
    max_len: usize,
) -> Result<(ManifestRow, RunTiming)> {
    let task = spec.task_def()?;
    let ds = Dataset::generate(
        task,
        vocab,
        Some(max_len),
        spec.grid_seed,
        spec.n_train,
        spec.n_val,
        spec.n_test,
    );
    if spec.steps == 0 {
        // Zero-shot: evaluation only, no training loop (and nothing to
        // checkpoint). The budget is exactly `eval_examples` — no silent
        // clamp, since that field is part of run identity and must
        // actually steer the outcome.
        let t0 = Instant::now();
        if let Some(p) = &ctx.probe {
            p.set_running(0);
        }
        let ev = evaluate(exec, params, &ds.test, spec.eval_examples)?;
        if let Some(p) = &ctx.probe {
            p.set_done();
        }
        if let Some(path) = &ctx.dump_path {
            dump_params(params, path)?;
        }
        return Ok((
            ManifestRow::from_eval(spec, &ev),
            RunTiming {
                total_secs: t0.elapsed().as_secs_f64(),
                time_to_best_secs: 0.0,
                resumed_from_step: None,
                note: None,
            },
        ));
    }
    // `LT_NONE` is usize::MAX, which `partition` already treats as "no
    // partitioning", so `spec.lt` passes straight through.
    let lt = if spec.lt_auto {
        // Addax on long tasks: partition at the 60th length percentile of
        // the (deterministic) training split — the repro's L_T policy.
        let mut lens: Vec<usize> = ds.train.iter().map(|e| e.context.len() + 1).collect();
        lens.sort_unstable();
        lens[lens.len() * 6 / 10]
    } else {
        spec.lt
    };
    let cfg = TrainConfig {
        steps: spec.steps,
        eval_every: spec.eval_every,
        seed: spec.train_seed,
        eval_examples: spec.eval_examples,
        log_path: None,
        verbose: false,
        // One in-run noise worker: the sweep parallelizes across runs,
        // so in-run pools would only oversubscribe the host. The pin is
        // per-store (no process global), so concurrent runs with
        // different settings could coexist — the scheduler just has no
        // reason to want them.
        noise_workers: 1,
        ckpt_dir: ctx.ckpt_dir.clone(),
        ckpt_every: ctx.ckpt_every,
        ckpt_keep: ctx.ckpt_keep,
        // Snapshots are stamped with (and resume demands) the run id, so
        // a directory mix-up can never graft one run's state onto another.
        ckpt_identity: spec.run_id.clone(),
        halt_after: ctx.halt_after,
        probe: ctx.probe.clone(),
    };
    let mut opt = spec.optimizer.build()?;
    // `Halted` must propagate un-wrapped in meaning (anyhow downcasts
    // through context chains, so the scheduler still sees it).
    let r = train(exec, params, &mut *opt, &ds, lt, &cfg)
        .with_context(|| format!("training {}", spec.run_id))?;
    if let Some(path) = &ctx.dump_path {
        dump_params(params, path)?;
    }
    // Wall-clock of a resumed run covers only the final session (the
    // clock restarts; time_to_best is 0.0 when the best predates the
    // resume) — stamp the times row so downstream consumers don't read
    // it as an instantaneous result.
    let mut notes: Vec<String> = Vec::new();
    if !r.ckpt_note.is_empty() {
        notes.push(r.ckpt_note.clone());
    }
    if r.resumed_from_step.is_some() {
        notes.push("wall-clock covers the resumed session only".to_string());
    }
    let timing = RunTiming {
        total_secs: r.total_secs,
        time_to_best_secs: r.time_to_best_secs,
        resumed_from_step: r.resumed_from_step,
        note: (!notes.is_empty()).then(|| notes.join("; ")),
    };
    Ok((ManifestRow::from_train(spec, &r), timing))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::OptSpec;

    #[test]
    fn execute_run_is_deterministic() {
        let spec = {
            let mut s = RunSpec::new(Backend::Mock, "sst2", OptSpec::named("addax"), 15, 3);
            s.eval_examples = 30;
            s.n_train = 120;
            s.n_val = 40;
            s.n_test = 40;
            s.sealed()
        };
        let (a, _) = execute_run(&spec).unwrap();
        let (b, _) = execute_run(&spec).unwrap();
        assert_eq!(a.to_line(), b.to_line());
        assert_eq!(a.outcome.loss_curve.points.len(), 15);
    }

    #[test]
    fn execute_run_is_deterministic_at_bf16() {
        // The tentpole contract at the run level: a bf16 cell reproduces
        // its manifest row exactly, and it differs from its f32 twin only
        // through the declared dtype (distinct run id).
        let mk = |dtype| {
            let mut s = RunSpec::new(Backend::Mock, "sst2", OptSpec::named("mezo"), 10, 3);
            s.dtype = dtype;
            s.eval_examples = 30;
            s.n_train = 120;
            s.n_val = 40;
            s.n_test = 40;
            s.sealed()
        };
        let spec = mk(crate::tensor::Dtype::Bf16);
        let (a, _) = execute_run(&spec).unwrap();
        let (b, _) = execute_run(&spec).unwrap();
        assert_eq!(a.to_line(), b.to_line());
        assert_ne!(spec.run_id, mk(crate::tensor::Dtype::F32).run_id);
    }

    #[test]
    fn zero_shot_runs_eval_only() {
        let mut s = RunSpec::new(Backend::Mock, "sst2", OptSpec::named("zero-shot"), 0, 1);
        s.n_test = 60;
        s.eval_examples = 50;
        let s = s.sealed();
        let (row, _) = execute_run(&s).unwrap();
        assert_eq!(row.outcome.kind, "eval");
        assert_eq!(row.outcome.steps, 0);
        assert!(row.outcome.loss_curve.points.is_empty());
        assert!(row.outcome.test_acc > 0.0);
    }

    #[test]
    fn unsealed_spec_is_rejected() {
        let mut s = RunSpec::new(Backend::Mock, "sst2", OptSpec::named("mezo"), 5, 0);
        s.run_id = String::new();
        let err = run_sweep(vec![s], &SweepOptions::default()).unwrap_err();
        assert!(format!("{err}").contains("unsealed"));
    }
}
